"""Per-arch smoke tests + decode/prefill cache-consistency checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro import compat
from repro.configs.base import SHAPES, shapes_for, skipped_shapes_for
from repro.configs.registry import ARCH_IDS, get_config
from repro.models import LM

RNG = jax.random.PRNGKey(0)


def make_inputs(cfg, B, S, rng):
    if cfg.frontend == "embeddings":
        batch = {"frames": jnp.asarray(rng.normal(0, 1, (B, S, cfg.d_model)),
                                       jnp.float32),
                 "targets": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                                        jnp.int32)}
        pre = {"frames": batch["frames"]}
        dec = {"frames": batch["frames"][:, :1]}
    elif cfg.frontend == "vlm":
        St = S - cfg.n_patches
        batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, St)),
                                       jnp.int32),
                 "patches": jnp.asarray(rng.normal(0, 1, (B, cfg.n_patches,
                                                          cfg.d_model)),
                                        jnp.float32),
                 "targets": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, St)),
                                        jnp.int32)}
        pre = {k: batch[k] for k in ("tokens", "patches")}
        dec = {"tokens": batch["tokens"][:, :1]}
    else:
        toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
        batch = {"tokens": toks, "targets": toks}
        pre = {"tokens": toks}
        dec = {"tokens": toks[:, :1]}
    return batch, pre, dec


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch, rng):
    """Reduced config: one train loss + prefill + decode step, shapes + finite."""
    cfg = get_config(arch + ":smoke")
    model = LM(cfg, remat_policy="none")
    params = model.init(RNG)
    B, S = 2, 16
    batch, pre, dec = make_inputs(cfg, B, S, rng)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    logits, cache = jax.jit(model.prefill)(params, pre)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    logits2, cache2 = jax.jit(model.decode_step)(params, dec, cache)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    assert int(cache2["lengths"][0]) == S + 1


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "gemma2-2b",
                                  "recurrentgemma-2b", "rwkv6-1.6b",
                                  "phi3.5-moe-42b-a6.6b"])
def test_decode_matches_prefill(arch, rng):
    """Teacher-forced decode over the cache == prefill logits (the cache
    semantics test: KV ring buffers, RG-LRU/RWKV states, MoE routing)."""
    cfg = get_config(arch + ":smoke")
    model = LM(cfg, remat_policy="none")
    params = model.init(RNG)
    B, S = 2, 12
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    # full prefill over S tokens
    logits_full, _ = jax.jit(model.prefill)(params, {"tokens": toks})
    # prefill first half (cache sized for the whole run), decode the rest
    half = S // 2
    logits_h, cache = jax.jit(
        lambda p, i: model.prefill(p, i, max_seq=S + 1))(
        params, {"tokens": toks[:, :half]})
    dec = jax.jit(model.decode_step)
    logits_step = None
    for t in range(half, S):
        logits_step, cache = dec(params, {"tokens": toks[:, t:t + 1]}, cache)
    # after feeding token S-1 the decode logits predict position S — compare
    # with the full prefill's last-position logits
    assert_allclose(np.asarray(logits_step, np.float32),
                    np.asarray(logits_full, np.float32), rtol=2e-2, atol=2e-2)


def test_local_ring_cache_matches_full(rng):
    """Sliding-window arch: ring cache (W slots) == full-cache attention."""
    import dataclasses
    cfg = get_config("recurrentgemma-2b:smoke")
    model = LM(cfg, remat_policy="none")
    params = model.init(RNG)
    B = 1
    S = cfg.local_window + 7  # force ring wrap (window is 32 in smoke)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    logits_full, _ = jax.jit(model.prefill)(params, {"tokens": toks})
    logits_h, cache = jax.jit(
        lambda p, i: model.prefill(p, i, max_seq=S + 1))(
        params, {"tokens": toks[:, :8]})
    dec = jax.jit(model.decode_step)
    out = None
    for t in range(8, S):
        out, cache = dec(params, {"tokens": toks[:, t:t + 1]}, cache)
    assert_allclose(np.asarray(out, np.float32),
                    np.asarray(logits_full, np.float32), rtol=2e-2, atol=2e-2)


def test_rwkv_chunked_matches_scan(rng):
    """The chunked-parallel wkv (hillclimb lever) == exact sequential scan."""
    cfg = get_config("rwkv6-1.6b:smoke")
    m_scan = LM(cfg, remat_policy="none", rwkv_chunk=0)
    m_chunk = LM(cfg, remat_policy="none", rwkv_chunk=4)
    params = m_scan.init(RNG)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 13)), jnp.int32)
    l1, _ = jax.jit(m_scan.loss)(params, {"tokens": toks, "targets": toks})
    l2, _ = jax.jit(m_chunk.loss)(params, {"tokens": toks, "targets": toks})
    assert_allclose(float(l1), float(l2), rtol=1e-3)


def test_param_counts_match_analytic():
    """Declarative defs vs the analytic formula in configs/base.py."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = LM(cfg)
        got = model.param_count()
        expect = cfg.param_count()
        ratio = got / expect
        assert 0.93 < ratio < 1.07, (arch, got, expect)


def test_long_500k_skip_rule():
    subq = [a for a in ARCH_IDS if get_config(a).sub_quadratic]
    assert sorted(subq) == ["recurrentgemma-2b", "rwkv6-1.6b"]
    for a in ARCH_IDS:
        cfg = get_config(a)
        names = set(shapes_for(cfg))
        if cfg.sub_quadratic:
            assert "long_500k" in names
        else:
            assert "long_500k" in skipped_shapes_for(cfg)


def test_moe_sharded_matches_dense(rng):
    """shard_map expert parallelism == dense reference (1-device mesh)."""
    from repro.launch.mesh import make_smoke_mesh
    from repro.models.moe import moe_apply, moe_defs
    from repro.models import param as Pm
    cfg = get_config("phi3.5-moe-42b-a6.6b:smoke")
    defs = moe_defs(cfg)
    p = Pm.init(defs, RNG)
    x = jnp.asarray(rng.normal(0, 1, (2, 8, cfg.d_model)), jnp.float32)
    out_dense, aux_dense = moe_apply(p, x, cfg, shard=None)
    mesh = make_smoke_mesh()
    with compat.set_mesh(mesh):
        out_sh, aux_sh = jax.jit(
            lambda p, x: moe_apply(p, x, cfg, shard=(mesh, ("data",))))(p, x)
    # msize == 1 -> falls back to dense path; equality is exact
    assert_allclose(np.asarray(out_sh), np.asarray(out_dense),
                    rtol=1e-4, atol=1e-5)

# Developer / CI entrypoints. `make test` is the tier-1 verify command from
# ROADMAP.md; `make bench-smoke` is a ~1-minute benchmark pass covering the
# four pipeline execution axes (modular / fused / scan / scan_sharded) plus
# the scan-engine + columnar-ingest acceptance cells. The sharded mode runs
# on a forced 8-host-device CPU mesh (--host-devices) so the shard_map path
# is exercised in CI, not just on real multi-chip hardware; results are also
# written to BENCH_pr2.json (windows/s + records/s per mode).
PY ?= python

.PHONY: test bench-smoke bench-pr2 ci

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# CI pass: writes BENCH_smoke.json (untracked scratch) so repeated CI runs
# never clobber the committed BENCH_pr2.json trajectory record
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --smoke --host-devices 8 \
		--json BENCH_smoke.json

# regenerate the committed perf-trajectory artifact (run manually per PR)
bench-pr2:
	PYTHONPATH=src $(PY) -m benchmarks.run --smoke --host-devices 8 \
		--json BENCH_pr2.json

ci: test bench-smoke

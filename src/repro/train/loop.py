"""Training loop: checkpoint/restart, preemption, straggler policy, metrics.

The loop is deliberately boring — all the interesting machinery lives in
steps.build_train_step (sharded step), Checkpointer (fault tolerance),
Prefetcher (overlapped input), StragglerPolicy/PreemptionGuard (mitigation).
Runs for real on CPU with reduced configs (examples/train_retrain.py trains
a ~small model for hundreds of steps); the same code drives the full archs
on a pod.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import numpy as np

from repro import compat

from repro.configs.base import (ModelConfig, ShapeConfig, ShardingConfig,
                                TrainConfig)
from repro.data.pipeline import Prefetcher, StreamCursor, SyntheticLMStream
from repro.launch.steps import build_train_step
from repro.train import optimizer as opt_lib
from repro.train.checkpoint import Checkpointer


@dataclass
class StragglerPolicy:
    """EWMA step-time deadline detector.

    On pods a straggling host stalls the synchronous collective; the
    framework-level mitigation is (a) detect (step time > k x EWMA),
    (b) after M consecutive detections treat the host as failed:
    checkpoint and restart.  Host-side, unit-tested with a simulated
    slow worker.
    """
    k: float = 3.0                 # deadline = k * ewma
    alpha: float = 0.2
    consecutive_to_fail: int = 3
    min_steps: int = 5
    ewma: float = 0.0
    steps: int = 0
    strikes: int = 0
    slow_events: int = 0

    def observe(self, step_time_s: float) -> str:
        """Returns 'ok' | 'slow' | 'fail' (fail => trigger restart)."""
        self.steps += 1
        if self.steps <= self.min_steps:
            self.ewma = step_time_s if self.ewma == 0.0 else \
                (1 - self.alpha) * self.ewma + self.alpha * step_time_s
            return "ok"
        verdict = "ok"
        if step_time_s > self.k * max(self.ewma, 1e-9):
            self.strikes += 1
            self.slow_events += 1
            verdict = "slow"
            if self.strikes >= self.consecutive_to_fail:
                verdict = "fail"
        else:
            self.strikes = 0
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_time_s
        return verdict


@dataclass
class PreemptionGuard:
    """SIGTERM-aware: cloud preemption sends SIGTERM before the kill."""
    triggered: bool = False

    def install(self):
        import signal

        def handler(signum, frame):
            self.triggered = True

        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGINT, handler)
        except ValueError:
            pass  # not main thread (tests)
        return self


@dataclass
class TrainResult:
    steps_run: int
    final_step: int
    losses: list
    step_times: list
    restored_from: Optional[int]
    preempted: bool = False
    straggler_events: int = 0


def train(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
          perf: ShardingConfig = ShardingConfig(),
          tcfg: TrainConfig = TrainConfig(),
          max_steps: Optional[int] = None,
          stream_seed: int = 0,
          on_step: Optional[Callable[[int, dict], None]] = None,
          checkpointer: Optional[Checkpointer] = None) -> TrainResult:
    fn, (pspecs, opt_specs, in_specs), (param_sh, opt_sh, batch_sh), model = \
        build_train_step(cfg, shape, mesh, perf, tcfg)

    ckpt = checkpointer or Checkpointer(tcfg.checkpoint_dir,
                                        keep=tcfg.keep_checkpoints,
                                        async_mode=tcfg.async_checkpoint)
    guard = PreemptionGuard().install()
    straggler = StragglerPolicy()

    cursor = StreamCursor()
    restored_from = None
    latest = ckpt.latest_step()
    state_like = (pspecs, opt_specs)
    if latest is not None:
        (params, opt_state), extra = ckpt.restore(
            latest, state_like, (param_sh, opt_sh))
        cursor = StreamCursor.from_dict(extra.get("cursor", {}))
        start_step = latest
        restored_from = latest
    else:
        with compat.set_mesh(mesh):
            params = jax.jit(model.init, out_shardings=param_sh)(
                jax.random.PRNGKey(tcfg.seed))
            opt_state = jax.jit(opt_lib.init, out_shardings=opt_sh)(params)
        start_step = 0

    stream = SyntheticLMStream(cfg.vocab_size, shape.global_batch,
                               shape.seq_len, seed=stream_seed,
                               frontend=cfg.frontend, d_model=cfg.d_model,
                               n_patches=cfg.n_patches)
    prefetch = Prefetcher(stream, cursor, shardings=batch_sh)

    total = max_steps if max_steps is not None else tcfg.total_steps
    losses, times = [], []
    step = start_step
    preempted = False
    with compat.set_mesh(mesh):
        while step < total:
            batch = prefetch.next()
            t0 = time.time()
            params, opt_state, metrics = fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            step += 1
            losses.append(loss)
            times.append(dt)
            verdict = straggler.observe(dt)
            if on_step:
                on_step(step, {**{k: float(v) for k, v in metrics.items()},
                               "time_s": dt, "straggler": verdict})
            should_ckpt = (step % tcfg.checkpoint_every == 0) or step == total
            if guard.triggered or verdict == "fail":
                should_ckpt = True
            if should_ckpt:
                ckpt.save(step, (params, opt_state),
                          extra={"cursor": cursor.state_dict(),
                                 "loss": loss})
            if guard.triggered:
                preempted = True
                break
            if verdict == "fail":
                # at scale: drop the slow host and re-mesh (elastic). In a
                # single process we record the event and continue.
                straggler.strikes = 0
    ckpt.flush()
    return TrainResult(steps_run=step - start_step, final_step=step,
                       losses=losses, step_times=times,
                       restored_from=restored_from, preempted=preempted,
                       straggler_events=straggler.slow_events)

"""Jit'd public wrapper for the harmonize kernel (and its oracle)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.harmonize.kernel import ROWS_BLK, harmonize_pallas
from repro.kernels.harmonize.ref import harmonize_ref


@functools.partial(jax.jit, static_argnames=("tick_s", "n_ticks",
                                             "use_pallas", "interpret"))
def harmonize(values, timestamps, valid, window_start, *, tick_s: float,
              n_ticks: int, use_pallas: bool = True, interpret: bool = True):
    """Batched entry: (E, S, M) raw samples -> (E, S, T) tick means.

    window_start: (E,). Returns (values (E,S,T), observed (E,S,T)).
    """
    E, S, M = values.shape
    v = values.reshape(E * S, M).astype(jnp.float32)
    ts = timestamps.reshape(E * S, M).astype(jnp.float32)
    ok = valid.reshape(E * S, M).astype(jnp.float32)
    t0 = jnp.broadcast_to(window_start[:, None], (E, S)).reshape(E * S, 1)
    if not use_pallas:
        out, obs = harmonize_ref(v, ts, ok > 0, t0[:, 0], tick_s, n_ticks)
    else:
        pad = (-v.shape[0]) % ROWS_BLK
        if pad:
            zp = lambda x: jnp.pad(x, ((0, pad), (0, 0)))
            v, ts, ok, t0 = zp(v), zp(ts), zp(ok), zp(t0)
        out, obs = harmonize_pallas(v, ts, ok, t0, tick_s=tick_s,
                                    n_ticks=n_ticks, interpret=interpret)
        if pad:
            out, obs = out[:E * S], obs[:E * S]
    return out.reshape(E, S, n_ticks), obs.reshape(E, S, n_ticks)

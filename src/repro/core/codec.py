"""Encoder/Decoder — model-specific format adaptation.

"For each deployed model, an Encoder/Decoder component is implemented to
translate the standardized format produced by the Manager into the specific
format required by the model ... After inference, this component decodes the
model's decisions back into a common format."

Three encoder families cover the assigned architectures:
  * ``VectorCodec``  — continuous feature vector (classic RL policies)
  * ``TokenCodec``   — quantile-binned feature tokens for LM-family models
    (each feature -> one token in a per-feature codebook region; the decode
    shape fits every ``--arch`` LM in configs/)
  * ``EmbeddingCodec`` — projects features into d_model frame embeddings
    (musicgen/internvl2-style stub frontends)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import normalize as nz


@dataclass(frozen=True)
class VectorCodec:
    n_features: int
    clip: float = 8.0

    def encode(self, state: nz.NormState, features):
        z = nz.znorm(state, features[:, :, None])[..., 0]
        return jnp.clip(z, -self.clip, self.clip)

    def decode(self, state: nz.NormState, outputs, low, high):
        """Model emits z-scored setpoints; decode to engineering units."""
        raw = nz.denorm_z(state, outputs[:, :, None])[..., 0]
        return jnp.clip(raw, low, high)


@dataclass(frozen=True)
class TokenCodec:
    """Quantile-bin features into LM tokens.

    Feature j maps into the token range [offset + j*bins, offset + (j+1)*bins)
    so one shared vocabulary serves all features — compatible with every
    assigned LM's vocab (smallest is musicgen's 2048: 15 features x 128 bins
    + specials fit).
    """
    n_features: int
    bins: int = 128
    offset: int = 3          # 0=pad 1=bos 2=sep
    clip: float = 4.0

    @property
    def vocab_needed(self):
        return self.offset + self.n_features * self.bins

    def encode(self, state: nz.NormState, features):
        z = nz.znorm(state, features[:, :, None])[..., 0]
        u = (jnp.clip(z, -self.clip, self.clip) + self.clip) / (2 * self.clip)
        b = jnp.minimum((u * self.bins).astype(jnp.int32), self.bins - 1)
        return self.offset + jnp.arange(self.n_features) * self.bins + b

    def decode(self, state: nz.NormState, tokens, low, high):
        rel = tokens - self.offset - jnp.arange(tokens.shape[-1]) * self.bins
        u = (jnp.clip(rel, 0, self.bins - 1) + 0.5) / self.bins
        z = u * 2 * self.clip - self.clip
        raw = nz.denorm_z(state, z[:, :, None])[..., 0]
        return jnp.clip(raw, low, high)


@dataclass(frozen=True)
class EmbeddingCodec:
    """Features -> (E, n_frames, d_model) embeddings via a fixed random
    projection (the modality-frontend stub contract of the assignment)."""
    n_features: int
    d_model: int
    n_frames: int = 1
    seed: int = 0

    def _proj(self):
        k = jax.random.PRNGKey(self.seed)
        return jax.random.normal(k, (self.n_features, self.n_frames * self.d_model)) \
            / jnp.sqrt(self.n_features)

    def encode(self, state: nz.NormState, features):
        z = jnp.clip(nz.znorm(state, features[:, :, None])[..., 0], -8, 8)
        e = z @ self._proj()
        return e.reshape(features.shape[0], self.n_frames, self.d_model)

    def decode(self, state, outputs, low, high):
        raise NotImplementedError("embedding codec is input-only (stub frontend)")

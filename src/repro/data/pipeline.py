"""Deterministic, resumable training-data pipeline.

Batches are generated from a seeded stream (synthetic LM token streams, or
Percepta LogDB exports for the RL-retraining path). The pipeline's position
is a single integer ``cursor`` saved in every checkpoint — restart resumes
exactly-once at batch granularity, which is the stream-processing analogue
of Percepta's "store for retraining, deliver to the training node".

Double-buffered host->device staging overlaps batch synthesis with the
device step (the classic input-pipeline optimization).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import numpy as np


@dataclass
class StreamCursor:
    batch_index: int = 0

    def state_dict(self):
        return {"batch_index": self.batch_index}

    @staticmethod
    def from_dict(d):
        return StreamCursor(batch_index=int(d.get("batch_index", 0)))


class SyntheticLMStream:
    """Deterministic pseudo-corpus: tokens ~ per-batch seeded zipf-ish mix.

    Every batch is a pure function of (seed, batch_index) — replaying after
    restore produces bit-identical batches with no saved buffer state.
    """

    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, frontend: str = "none", d_model: int = 0,
                 n_patches: int = 0):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq_len
        self.seed = seed
        self.frontend = frontend
        self.d_model = d_model
        self.n_patches = n_patches

    def make_batch(self, index: int) -> dict:
        rng = np.random.RandomState((self.seed * 1_000_003 + index) % 2**31)
        # mixture of a few "topics" to give learnable structure
        n_topics = 8
        topic = rng.randint(0, n_topics, (self.batch,))
        base = (rng.randint(0, self.vocab // n_topics,
                            (self.batch, self.seq))
                + topic[:, None] * (self.vocab // n_topics)) % self.vocab
        # local repetition structure (next-token is learnable)
        rep = rng.rand(self.batch, self.seq) < 0.5
        shifted = np.roll(base, 1, axis=1)
        tokens = np.where(rep, shifted, base).astype(np.int32)
        if self.frontend == "embeddings":
            frames = rng.normal(0, 1, (self.batch, self.seq, self.d_model)
                                ).astype(np.float32)
            return {"frames": frames, "targets": tokens}
        if self.frontend == "vlm":
            st = self.seq - self.n_patches
            patches = rng.normal(0, 1, (self.batch, self.n_patches,
                                        self.d_model)).astype(np.float32)
            return {"tokens": tokens[:, :st], "patches": patches,
                    "targets": tokens[:, :st]}
        return {"tokens": tokens, "targets": tokens}


class Prefetcher:
    """One-batch-ahead host prefetch with optional device placement."""

    def __init__(self, stream: SyntheticLMStream, cursor: StreamCursor,
                 shardings: Optional[dict] = None):
        self.stream = stream
        self.cursor = cursor
        self.shardings = shardings
        self._next = None
        self._thread: Optional[threading.Thread] = None
        self._prefetch()

    def _make(self, idx):
        batch = self.stream.make_batch(idx)
        if self.shardings:
            batch = {k: jax.device_put(v, self.shardings.get(k))
                     for k, v in batch.items()}
        return batch

    def _prefetch(self):
        idx = self.cursor.batch_index

        def work():
            self._next = self._make(idx)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def next(self) -> dict:
        self._thread.join()
        batch = self._next
        self.cursor.batch_index += 1
        self._prefetch()
        return batch

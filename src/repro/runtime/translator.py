"""Translators — per-source format adaptation to the standardized Record.

"Each data source also has an associated Translator that adjusts to the
format of the incoming data, extracting only the relevant information ...
and submits it to an internal queue associated with the appropriate
environment."
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

from repro.runtime.records import CODECS, Record


class Translator:
    def __init__(self, source_id: str, protocol: str,
                 stream_rename: Optional[Dict[str, str]] = None,
                 unit_scale: float = 1.0):
        self.source_id = source_id
        self.decode = CODECS[protocol][1]
        self.stream_rename = stream_rename or {}
        self.unit_scale = unit_scale
        self.stats = {"records": 0, "errors": 0}

    def translate(self, env_id: str, payload: bytes) -> Optional[Record]:
        try:
            stream, ts, value = self.decode(payload)
        except Exception:
            self.stats["errors"] += 1
            return None
        self.stats["records"] += 1
        stream = self.stream_rename.get(stream, stream)
        return Record(env_id=env_id, stream=stream, timestamp=ts,
                      value=value * self.unit_scale)

"""Unit tests for the Percepta core stream operators."""
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core import aggregate as agg
from repro.core import anomaly as an
from repro.core import gapfill as gf
from repro.core import harmonize as hz
from repro.core import normalize as nz
from repro.core.frame import make_raw_window


def test_harmonize_buckets_exact():
    # 1 env, 1 stream, hand-placed samples on a 4-tick grid of 10s
    ts = np.array([[[1.0, 9.0, 11.0, 35.0, 41.0, 99.0]]], np.float32)
    vals = np.array([[[1.0, 3.0, 5.0, 7.0, 9.0, 11.0]]], np.float32)
    valid = np.array([[[1, 1, 1, 1, 0, 1]]], bool)  # 9.0 valid, 41 invalid
    raw = make_raw_window(vals, ts, valid)
    ticks = hz.tick_grid(jnp.zeros((1,)), 10.0, 4)  # ticks at 10,20,30,40
    out, obs = hz.harmonize(raw, ticks, 10.0, "mean")
    out, obs = np.asarray(out)[0, 0], np.asarray(obs)[0, 0]
    # bucket (0,10]: 1.0, 9.0 -> mean 2.0? (1+3)/2 = 2.0 ; (10,20]: 11.0 -> 5
    assert obs.tolist() == [True, True, False, True]
    assert_allclose(out, [2.0, 5.0, 0.0, 7.0])


def test_harmonize_aggs():
    ts = np.array([[[5.0, 6.0, 7.0]]], np.float32)
    vals = np.array([[[2.0, 4.0, 9.0]]], np.float32)
    raw = make_raw_window(vals, ts)
    ticks = hz.tick_grid(jnp.zeros((1,)), 10.0, 1)
    for a, expect in [("mean", 5.0), ("sum", 15.0), ("min", 2.0),
                      ("max", 9.0), ("last", 9.0)]:
        out, obs = hz.harmonize(raw, ticks, 10.0, a)
        assert_allclose(np.asarray(out)[0, 0, 0], expect, err_msg=a)


def test_harmonize_interp_bridges():
    # samples at t=0 (v=0) and t=100 (v=100): ticks interpolate linearly
    ts = np.array([[[0.0, 100.0]]], np.float32)
    vals = np.array([[[0.0, 100.0]]], np.float32)
    raw = make_raw_window(vals, ts)
    ticks = jnp.asarray([[25.0, 50.0, 75.0]], jnp.float32)
    out, obs = hz.harmonize_interp(raw, ticks)
    assert_allclose(np.asarray(out)[0, 0], [25.0, 50.0, 75.0], rtol=1e-5)
    assert np.asarray(obs).all()


def test_gapfill_locf_and_carry():
    state = gf.init_state(1, 1)
    v = jnp.asarray([[[1.0, 0.0, 0.0, 4.0, 0.0]]])
    obs = jnp.asarray([[[True, False, False, True, False]]])
    ticks = jnp.arange(5, dtype=jnp.float32)[None] * 60
    out, filled, new_state = gf.gap_fill(v, obs, state, ticks, "locf")
    assert_allclose(np.asarray(out)[0, 0], [1, 1, 1, 4, 4])
    assert np.asarray(filled)[0, 0].tolist() == [False, True, True, False, True]
    assert float(new_state.last_value[0, 0]) == 4.0
    # next window: leading gap uses carried last value
    v2 = jnp.asarray([[[0.0, 7.0, 0.0, 0.0, 0.0]]])
    obs2 = jnp.asarray([[[False, True, False, False, False]]])
    out2, filled2, _ = gf.gap_fill(v2, obs2, new_state, ticks + 300, "locf")
    assert_allclose(np.asarray(out2)[0, 0], [4, 7, 7, 7, 7])


def test_gapfill_linear_interior():
    state = gf.init_state(1, 1)
    v = jnp.asarray([[[2.0, 0.0, 0.0, 8.0]]])
    obs = jnp.asarray([[[True, False, False, True]]])
    ticks = jnp.arange(4, dtype=jnp.float32)[None]
    out, filled, _ = gf.gap_fill(v, obs, state, ticks, "linear")
    assert_allclose(np.asarray(out)[0, 0], [2.0, 4.0, 6.0, 8.0], rtol=1e-5)


def test_gapfill_seasonal_learns_slots():
    state = gf.init_state(1, 1, K=4)
    ticks = jnp.arange(4, dtype=jnp.float32)[None]
    tod = jnp.arange(4, dtype=jnp.int32)[None]
    v = jnp.asarray([[[10.0, 20.0, 30.0, 40.0]]])
    obs = jnp.ones((1, 1, 4), bool)
    _, _, state = gf.gap_fill(v, obs, state, ticks, "seasonal", tick_of_day=tod)
    # second window: slot 1 missing -> seasonal mean 20
    v2 = jnp.asarray([[[11.0, 0.0, 29.0, 41.0]]])
    obs2 = jnp.asarray([[[True, False, True, True]]])
    out2, filled2, _ = gf.gap_fill(v2, obs2, state, ticks, "seasonal",
                                   tick_of_day=tod)
    assert_allclose(np.asarray(out2)[0, 0, 1], 20.0, rtol=1e-5)
    assert bool(np.asarray(filled2)[0, 0, 1])


def test_anomaly_zscore_detects_and_clips():
    state = an.AnomalyState(mean=jnp.full((1, 1), 10.0),
                            var=jnp.full((1, 1), 1.0),
                            count=jnp.full((1, 1), 100.0))
    v = jnp.asarray([[[10.0, 10.5, 99.0, 9.5]]])
    obs = jnp.ones((1, 1, 4), bool)
    spikes = an.detect_zscore(v, obs, state, k_sigma=6.0)
    assert np.asarray(spikes)[0, 0].tolist() == [False, False, True, False]
    out, obs2, _ = an.replace(v, obs, spikes, state, "clip", 6.0)
    assert_allclose(np.asarray(out)[0, 0, 2], 16.0)  # mean + 6*sigma
    out3, obs3, _ = an.replace(v, obs, spikes, state, "missing", 6.0)
    assert not np.asarray(obs3)[0, 0, 2]


def test_anomaly_mad_window_local():
    v = jnp.asarray([[[1.0, 1.1, 0.9, 50.0, 1.05, 0.95, 1.0, 1.02]]])
    obs = jnp.ones((1, 1, 8), bool)
    spikes = an.detect_mad(v, obs, k=8.0)
    assert np.asarray(spikes)[0, 0].tolist() == [False] * 3 + [True] + [False] * 4


def test_normalize_welford_matches_numpy(rng):
    state = nz.init_state(1, 1)
    chunks = [rng.normal(3, 2, (1, 1, 16)).astype(np.float32) for _ in range(5)]
    masks = [rng.rand(1, 1, 16) > 0.3 for _ in range(5)]
    for c, m in zip(chunks, masks):
        state = nz.update(state, jnp.asarray(c), jnp.asarray(m))
    all_v = np.concatenate([c[m] for c, m in zip(chunks, masks)])
    assert_allclose(float(state.mean[0, 0]), all_v.mean(), rtol=1e-4)
    assert_allclose(float(nz.sigma(state)[0, 0]), all_v.std(ddof=1), rtol=1e-3)
    assert_allclose(float(state.min[0, 0]), all_v.min(), rtol=1e-5)
    assert_allclose(float(state.max[0, 0]), all_v.max(), rtol=1e-5)


def test_normalize_roundtrip(rng):
    state = nz.init_state(2, 3)
    v = rng.normal(5, 3, (2, 3, 8)).astype(np.float32)
    state = nz.update(state, jnp.asarray(v), jnp.ones((2, 3, 8), bool))
    z = nz.znorm(state, jnp.asarray(v))
    back = nz.denorm_z(state, z)
    assert_allclose(np.asarray(back), v, rtol=1e-4, atol=1e-4)


def test_aggregate_combine_weighted_average():
    # the paper's example: weighted average of same-area temperature sensors
    v = jnp.asarray([[[20.0, 20.0], [22.0, 22.0], [100.0, 100.0]]])  # (1,3,2)
    w = jnp.asarray([[0.5, 0.5, 0.0]])  # feature 0: avg of sensors 0,1
    feats = agg.combine(v, w)
    assert_allclose(np.asarray(feats)[0, 0], [21.0, 21.0])


@pytest.mark.parametrize("a", list(agg.AGGS))
def test_window_agg_all(a, rng):
    v = rng.normal(0, 1, (2, 3, 10)).astype(np.float32)
    m = rng.rand(2, 3, 10) > 0.4
    m[0, 0, :] = True
    out = np.asarray(agg.window_agg(jnp.asarray(v), jnp.asarray(m), a))
    row = v[0, 0][m[0, 0]]
    expect = {"last": row[-1], "mean": row.mean(), "sum": row.sum(),
              "min": row.min(), "max": row.max(), "std": row.std(),
              "count": row.size}[a]
    assert_allclose(out[0, 0], expect, rtol=1e-4, atol=1e-5)

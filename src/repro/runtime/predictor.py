"""Predictor — routes features to the decision model, validates actions,
computes rewards, logs for retraining, hands decisions to Forwarders.

The model is pluggable (``ModelAdapter``): a vector policy (edge RL), an
LM-family model through a TokenCodec, or anything callable on (E, F)
features. This is the "support any type of AI model that consumes this
data" requirement.

Two consume paths:

  * :meth:`Predictor.on_tick` — one jitted ``_step`` per window. The
    per-window reference path; fused mode and the bit-identity tests use
    it.
  * :meth:`Predictor.on_windows` — a K-window stack in ONE jitted
    dispatch: the policy and action validation run under ``lax.scan`` (so
    every window executes exactly the per-window (E, F) gemm), the
    ``prev_obs``/``prev_actions``/``have_prev`` carry materializes as
    shifted stacks, reward terms evaluate K-leading in one shot
    (elementwise over the stack, see ``RewardSpec.compute``), and the K
    replay transitions append through ``replay.add_many`` (itself a
    ``lax.scan`` carrying the buffer — exact sequential ring semantics).
    Outputs are bit-identical to K sequential ``on_tick`` calls; the
    scan-mode Manager consume uses this path so the decision side of the
    system costs one device dispatch per K windows, like the pipeline.

Long-horizon time rule (mirrors the scan engine's window-relative rebase):
the replay buffer stores the EXACT int32 tick index per transition, never a
float32 absolute time — consecutive window ends quantize to the same
float32 value past t~2^24 s. The absolute float64 time of every tick is
mirrored host-side in ``_replay_times`` (slot-aligned with the device
ring) and re-attached at export by :meth:`export_replay`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import replay as rp
from repro.core.reward import RewardSpec, validate_actions


@dataclass
class ActionSpace:
    low: np.ndarray
    high: np.ndarray

    @property
    def n(self):
        return len(self.low)


class ModelAdapter:
    """Wraps any policy fn(features (E,F)) -> actions (E,A)."""

    def __init__(self, fn: Callable, name: str = "policy"):
        self.fn = fn
        self.name = name

    def __call__(self, features):
        return self.fn(features)


def linear_policy(n_features: int, n_actions: int, seed: int = 0,
                  low=-1.0, high=1.0) -> ModelAdapter:
    """A small deterministic policy standing in for the deployed RL model."""
    k = jax.random.PRNGKey(seed)
    W = jax.random.normal(k, (n_features, n_actions)) / jnp.sqrt(n_features)

    @jax.jit
    def fn(feats):
        return jnp.tanh(feats @ W) * (high - low) / 2 + (high + low) / 2

    return ModelAdapter(fn, "linear_policy")


class Predictor:
    def __init__(self, model: ModelAdapter, reward_spec: RewardSpec,
                 action_space: ActionSpace, n_envs: int, n_features: int,
                 db=None, replay_capacity: int = 4096):
        self.model = model
        self.reward_spec = reward_spec
        self.action_space = action_space
        self.db = db
        self.replay = rp.init(n_envs, replay_capacity, n_features,
                              action_space.n)
        # host-side float64 absolute-time mirror, slot-aligned with the
        # device ring: the transition written at cursor c (tick index c+1)
        # lives in slot c % capacity of both structures
        self._replay_times = np.zeros((replay_capacity,), np.float64)
        self._prev = {
            "obs": jnp.zeros((n_envs, n_features), jnp.float32),
            "actions": jnp.zeros((n_envs, action_space.n), jnp.float32),
            "have": False,
        }
        self.stats = {"ticks": 0, "violations": 0}
        low = jnp.asarray(action_space.low, jnp.float32)
        high = jnp.asarray(action_space.high, jnp.float32)

        def _step(features, raw, prev_obs, prev_actions, replay, tick_idx,
                  have_prev):
            actions = self.model(features)
            actions, violated = validate_actions(actions, low, high)
            # rewards are computed on engineering units, not z-scores
            reward, per_term = self.reward_spec.compute(
                raw, actions, prev_actions)
            new_replay = jax.lax.cond(
                have_prev,
                lambda r: rp.add(r, prev_obs, prev_actions, reward, features,
                                 tick_idx),
                lambda r: r,
                replay)
            return actions, reward, per_term, violated, new_replay

        self._step = jax.jit(_step)

        def _steps(features, raw, tick_idx, prev_obs, prev_actions,
                   have_prev, replay):
            """K windows in one dispatch. The policy/validate scan runs the
            SAME per-window (E, F) computation ``_step`` jits (a batched
            K-leading gemm could block/accumulate differently on some
            backends, breaking bit-identity with the reference path); the
            carried prev obs/actions materialize as the shifted stacks
            below, so reward terms — elementwise over the stack — evaluate
            K-leading in one shot."""
            def body(carry, f):
                actions = self.model(f)
                actions, violated = validate_actions(actions, low, high)
                return carry, (actions, violated)

            _, (actions, violated) = jax.lax.scan(body, 0, features)
            prev_act_seq = jnp.concatenate([prev_actions[None], actions[:-1]],
                                           0)
            rewards, per_term = self.reward_spec.compute(raw, actions,
                                                         prev_act_seq)
            # transition j stores (obs/actions entering window j, reward j,
            # next_obs = window j's features); only the first row of the
            # batch can lack a predecessor
            K = features.shape[0]
            prev_obs_seq = jnp.concatenate([prev_obs[None], features[:-1]], 0)
            mask = jnp.concatenate([have_prev[None],
                                    jnp.ones((K - 1,), jnp.bool_)])
            new_replay = rp.add_many(replay, prev_obs_seq, prev_act_seq,
                                     rewards, features, tick_idx, mask)
            return (actions, rewards, per_term, violated, features[-1],
                    actions[-1], new_replay)

        self._steps = jax.jit(_steps)

    def _record_times(self, base_idx: int, tick_times) -> None:
        """Mirror absolute float64 tick times into the slot-aligned host
        ring (tick idx adds at cursor idx-1 -> slot (idx-1) % capacity)."""
        C = self.replay.capacity
        for j, t in enumerate(tick_times):
            idx = base_idx + j
            if idx >= 1:
                self._replay_times[(idx - 1) % C] = float(t)

    def on_tick(self, features, tick_time, raw=None):
        """features: (E, F) device array; returns host actions + rewards.

        The per-window reference path — :meth:`on_windows` must stay
        bit-identical to K calls of this."""
        raw = features if raw is None else raw
        idx = self.stats["ticks"]
        actions, reward, per_term, violated, self.replay = self._step(
            features, raw, self._prev["obs"], self._prev["actions"],
            self.replay, jnp.asarray(idx, jnp.int32),
            jnp.asarray(self._prev["have"]))
        self._record_times(idx, [tick_time])
        self._prev = {"obs": features, "actions": actions, "have": True}
        self.stats["ticks"] += 1
        self.stats["violations"] += int(np.asarray(violated).sum())
        return np.asarray(actions), np.asarray(reward), np.asarray(per_term)

    def on_windows(self, features, tick_times, raw=None):
        """Consume a K-window stack in ONE jitted dispatch.

        ``features``/``raw``: (K, E, F) (raw defaults to features);
        ``tick_times``: K absolute window-end times (host float64, never
        sent to device). Returns host ``(actions (K, E, A), rewards (K, E),
        per_term (K, E, n_terms))`` — bit-identical to K sequential
        :meth:`on_tick` calls, including replay contents and stats.
        """
        features = jnp.asarray(features)
        raw = features if raw is None else jnp.asarray(raw)
        K = features.shape[0]
        assert K >= 1 and len(tick_times) == K, (K, len(tick_times))
        base = self.stats["ticks"]
        tick_idx = jnp.asarray(base + np.arange(K), jnp.int32)
        (actions, rewards, per_term, violated, last_obs, last_actions,
         self.replay) = self._steps(
            features, raw, tick_idx, self._prev["obs"],
            self._prev["actions"], jnp.asarray(self._prev["have"]),
            self.replay)
        self._record_times(base, tick_times)
        self._prev = {"obs": last_obs, "actions": last_actions, "have": True}
        self.stats["ticks"] += K
        self.stats["violations"] += int(np.asarray(violated).sum())
        return np.asarray(actions), np.asarray(rewards), np.asarray(per_term)

    def export_replay(self, env_ids, salt: str) -> dict:
        """Anonymized chronological replay export with exact float64
        absolute times reconstructed from the host-side mirror."""
        return rp.export_for_training(self.replay, env_ids, salt,
                                      slot_times=self._replay_times)

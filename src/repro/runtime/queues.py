"""Per-environment internal queues (the RabbitMQ stand-in).

One queue per environment keeps environments isolated ("these environments
operate independently, do not interfere with each other").

Queue items are :class:`Record`s or columnar :class:`RecordBatch`es — the
stats count *records* either way, so one enqueued 500-row batch reads as
500 in ``enqueued``/``dequeued``, exactly like 500 individual puts.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Optional, Union

from repro.runtime.records import Record, RecordBatch

Item = Union[Record, RecordBatch]


def _n(item: Item) -> int:
    return len(item) if isinstance(item, RecordBatch) else 1


class EnvQueue:
    def __init__(self, env_id: str, maxsize: int = 100_000):
        self.env_id = env_id
        self._q: "queue.Queue[Item]" = queue.Queue(maxsize=maxsize)
        self.stats = {"enqueued": 0, "dropped": 0, "dequeued": 0}

    def put(self, item: Item) -> bool:
        try:
            self._q.put_nowait(item)
            self.stats["enqueued"] += _n(item)
            return True
        except queue.Full:
            self.stats["dropped"] += _n(item)
            return False

    def drain(self, max_items: int = 1_000_000):
        out = []
        while len(out) < max_items:
            try:
                out.append(self._q.get_nowait())
            except queue.Empty:
                break
        self.stats["dequeued"] += sum(_n(it) for it in out)
        return out

    def qsize(self):
        return self._q.qsize()


class QueueBroker:
    """Routes records to environment queues; creates them on demand."""

    def __init__(self):
        self._queues: Dict[str, EnvQueue] = {}
        self._lock = threading.Lock()

    def queue_for(self, env_id: str) -> EnvQueue:
        with self._lock:
            if env_id not in self._queues:
                self._queues[env_id] = EnvQueue(env_id)
            return self._queues[env_id]

    def publish(self, item: Item):
        self.queue_for(item.env_id).put(item)

    def stats(self):
        # depth stays in records (enqueued - dequeued holds because both
        # count records); depth_items is the raw queue length, which is
        # smaller whenever multi-row RecordBatches are in flight
        return {e: q.stats | {"depth": q.stats["enqueued"]
                              - q.stats["dequeued"],
                              "depth_items": q.qsize()}
                for e, q in self._queues.items()}

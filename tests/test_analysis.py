"""Static analysis layer (PR 6): jaxpr contract checker + AST invariant lint.

The jaxpr half must reject exactly the divergences that bit us in real PRs
— the K-leading/env-rows gemm and cross-env reductions from the PR 5
sharded fused engine, the float32 absolute-time cast from the PR 3
long-horizon collapse — while accepting every builtin policy/reward/decide
path, with diagnostics that name the offending primitive and source line.
The AST half gets a bad/good fixture pair per rule, plus the pragma,
baseline and repo-clean pins that make it a CI gate.
"""
import json
import logging
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.analysis import (
    ContractViolation, JAXPR_RULES, LINT_RULES, Rules,
    check_builtins, check_decide_fns, check_fn, check_policy,
    check_reward_fn, check_reward_terms, check_system, check_train_step,
)
from repro.analysis import lint as lint_mod
from repro.core.reward import RewardSpec, RewardTerm, energy_reward_spec
from repro.distribution import sharding
from repro.runtime.predictor import (ActionSpace, ModelAdapter, Predictor,
                                     linear_policy)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
E, F, A = 4, 6, 2


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# jaxpr checker: the shard-invariance (env) family
# ---------------------------------------------------------------------------

def test_gemm_policy_rejected_with_primitive_and_source():
    """The exact PR 5 divergence shape: an (E,F)@(F,A) policy gemm puts the
    env axis into dot_general rows (row-count-dependent CPU kernels)."""
    W = jnp.ones((F, A))
    with pytest.raises(ContractViolation) as ei:
        check_policy(ModelAdapter(lambda f: f @ W, name="gemm"), F, n_envs=E)
    msg = str(ei.value)
    assert "env-gemm-rows" in msg
    assert "dot_general" in msg              # names the primitive
    assert "test_analysis.py" in msg         # names the source line
    assert "linear_policy" in msg            # actionable: points at the fix


def test_env_contraction_rejected():
    """Contracting OVER the env axis (worse than rows: mixes envs)."""
    v, _ = check_fn(lambda f, w: jnp.einsum("ef,e->f", f, w),
                    (_sds((E, F)), _sds((E,))), ("env:0", "env:0"))
    assert [x.rule for x in v] == ["env-contraction"]


def test_cross_env_mean_reward_rejected():
    """A custom reward normalizing by the batch mean — fine per-window on
    the host, garbage per shard under the env mesh."""
    bad = lambda f, a, p: f[:, 0] - jnp.mean(f[:, 0], axis=0)
    with pytest.raises(ContractViolation) as ei:
        check_reward_fn(bad, E, F, A)
    assert "env-reduce" in str(ei.value)
    assert "reduce" in str(ei.value)         # primitive named


def test_env_axis_tracked_through_transforms():
    """Provenance survives transpose/reshape/broadcast before the reduce."""
    def fn(f):
        g = jnp.transpose(f)                 # (F, E): env now axis 1
        g = g.reshape(F, 1, E)               # env now axis 2
        return g.sum(axis=2)                 # reduces the env axis
    v, _ = check_fn(fn, (_sds((E, F)),), ("env:0",))
    assert [x.rule for x in v] == ["env-reduce"]


def test_feature_reduce_is_clean():
    """Reducing over F (linear_policy's multiply+reduce dot) is the
    sanctioned phrasing — env rows stay independent."""
    def fn(f, w):
        return jnp.sum(f[:, None, :] * w.T[None, :, :], axis=-1)
    v, _ = check_fn(fn, (_sds((E, F)), _sds((F, A))), ("env:0", ""))
    assert v == []


def test_env_rules_scoped_to_sharded():
    """Rules(env=False) (the non-sharded fused engine) accepts a gemm —
    examples/serve_edge.py's LM policy is legal there."""
    W = jnp.ones((F, A))
    check_policy(ModelAdapter(lambda f: f @ W, name="gemm"), F, n_envs=E,
                 rules=Rules(env=False))


# ---------------------------------------------------------------------------
# jaxpr checker: time, collectives, callbacks, reward shape
# ---------------------------------------------------------------------------

def test_float32_cast_of_absolute_time_rejected():
    """The PR 3 collapse shape: int32 tick * 60.0 promotes the absolute
    tick counter to float32 seconds (quantizes past t~2^24)."""
    v, _ = check_fn(lambda t: t * 60.0, (_sds((), jnp.int32),), ("time",))
    assert [x.rule for x in v] == ["time-cast"]
    assert "2^24" in v[0].message


def test_relative_time_cast_is_clean():
    """Rebase-to-relative then narrow — the documented fix — passes: the
    abs-time tag clears on sub(time, time)."""
    def fn(t, t0):
        return (t - t0).astype(jnp.float32) * 60.0
    v, _ = check_fn(fn, (_sds((), jnp.int32), _sds((), jnp.int32)),
                    ("time", "time"))
    assert v == []


def test_time_phase_mod_is_clean():
    """t mod period (seasonal slot math) clears the tag too."""
    v, _ = check_fn(lambda t: (t % 24).astype(jnp.float32),
                    (_sds((), jnp.int32),), ("time",))
    assert v == []


def test_integer_tick_arithmetic_is_clean():
    v, _ = check_fn(lambda t: t + 1, (_sds((), jnp.int32),), ("time",))
    assert v == []


def test_callback_in_scan_rejected_and_scoped():
    noisy = lambda x: (jax.debug.print("x={x}", x=x), x * 2.0)[1]
    # checked entry points are scan-body-bound by default
    v, _ = check_fn(noisy, (_sds((E,)),), ("",))
    assert [x.rule for x in v] == ["callback-in-scan"]
    # a genuinely top-level fn is fine...
    v, _ = check_fn(noisy, (_sds((E,)),), ("",), scan_bound=False)
    assert v == []
    # ...until the callback sits inside its lax.scan body
    def scanned(x):
        return jax.lax.scan(lambda c, xi: (c + noisy(xi), None), 0.0, x)[0]
    v, _ = check_fn(scanned, (_sds((E,)),), ("",), scan_bound=False)
    assert [x.rule for x in v] == ["callback-in-scan"]


def test_collective_rejected_through_shard_map():
    """The checker recurses into the shard_map eqn the compat shim emits."""
    mesh = sharding.env_mesh(E)
    def fn(x):
        body = lambda xs: jax.lax.psum(xs, sharding.ENV_AXIS)
        from jax.sharding import PartitionSpec as P
        return compat.shard_map(body, mesh=mesh,
                                in_specs=P(sharding.ENV_AXIS),
                                out_specs=P())(x)
    v, _ = check_fn(fn, (_sds((E,)),), ("env:0",))
    assert "collective" in [x.rule for x in v]


def test_reward_shape_rule():
    with pytest.raises(ContractViolation) as ei:
        check_reward_fn(lambda f, a, p: f[:1, 0], E, F, A)
    assert "reward-shape" in str(ei.value)
    assert "(E,)" in str(ei.value)


# ---------------------------------------------------------------------------
# jaxpr checker: every builtin passes
# ---------------------------------------------------------------------------

def test_all_builtins_accepted():
    """linear_policy, every RewardTerm kind (through RewardSpec.compute),
    energy_reward_spec, validate_actions, the builtin DecideFns pair (plus
    its elastic masked variant under the env-mask-gate family), and the
    four registry policies (certified against the full catalog)."""
    assert check_builtins() == 17


def test_real_predictor_decide_fns_accepted():
    pred = Predictor(linear_policy(F, A),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.full(A, -1.0), np.full(A, 1.0)),
                     E, F, replay_capacity=8)
    check_decide_fns(pred.make_decide_fn(), pred.decide_state(), E, F)


def test_decide_fns_with_bad_custom_reward_rejected():
    spec = RewardSpec((RewardTerm("custom",
                                  fn=lambda f, a, p: f[:, 0] - f[:, 0].max()),),
                      unchecked=True)      # sneak past spec-time check
    pred = Predictor(linear_policy(F, A), spec,
                     ActionSpace(np.full(A, -1.0), np.full(A, 1.0)),
                     E, F, replay_capacity=8)
    with pytest.raises(ContractViolation) as ei:
        check_decide_fns(pred.make_decide_fn(), pred.decide_state(), E, F)
    assert "env-reduce" in str(ei.value)


# ---------------------------------------------------------------------------
# jaxpr checker: the env-mask-gate family (elastic slot pools)
# ---------------------------------------------------------------------------

def test_mask_compaction_cumsum_rejected():
    """The bad fixture: cumsum of the active mask along the env axis is
    the row-compaction-offset pattern — row placement then depends on
    membership, which breaks the no-retrace bit-exactness contract."""
    def bad(feats, active):
        off = jnp.cumsum(active.astype(jnp.int32))
        return jnp.take(feats, off - 1, axis=0)

    v, _ = check_fn(bad, (_sds((E, F)), _sds((E,), jnp.bool_)),
                    ("env:0", "env:0,mask"),
                    rules=Rules(env=False, mask=True))
    rules_hit = {x.rule for x in v}
    assert "env-mask-gate" in rules_hit
    prims = {x.primitive for x in v if x.rule == "env-mask-gate"}
    assert "cumsum" in prims          # the offset scan itself
    assert "gather" in prims          # and the mask-derived indexing


def test_mask_sort_and_dynamic_slice_rejected():
    def bad_sort(feats, active):
        order = jnp.argsort(active.astype(jnp.int32))
        return feats, order

    v, _ = check_fn(bad_sort, (_sds((E, F)), _sds((E,), jnp.bool_)),
                    ("env:0", "env:0,mask"),
                    rules=Rules(env=False, mask=True))
    assert "env-mask-gate" in {x.rule for x in v}

    def bad_slice(feats, active):
        start = jnp.sum(active.astype(jnp.int32))
        return jax.lax.dynamic_slice(feats, (start, 0), (1, F))

    v, _ = check_fn(bad_slice, (_sds((E, F)), _sds((E,), jnp.bool_)),
                    ("env:0", "env:0,mask"),
                    rules=Rules(env=False, mask=True))
    assert "env-mask-gate" in {x.rule for x in v}


def test_mask_select_gating_accepted():
    """The sanctioned combinators: where/select and multiply keep row i's
    output a function of row i's mask bit alone — and the select predicate
    does NOT leak the mask tag into the selected values."""
    def good(feats, active):
        gated = jnp.where(active[:, None], feats, 0.0)
        return gated * active[:, None].astype(jnp.float32)

    v, _ = check_fn(good, (_sds((E, F)), _sds((E,), jnp.bool_)),
                    ("env:0", "env:0,mask"),
                    rules=Rules(env=False, mask=True))
    assert v == []


def test_elastic_decide_fns_accepted_and_gated():
    """The SHIPPED masked decide path passes the gate; a step that
    compacts rows with the carried mask is rejected through the same
    entry point (check_decide_fns auto-enables the family when the state
    carries an ``active`` leaf)."""
    pred = Predictor(linear_policy(F, A),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.full(A, -1.0), np.full(A, 1.0)),
                     E, F, replay_capacity=8)
    el_state = pred.decide_state()._replace(
        active=jnp.arange(E) < 2, prev_ok=jnp.zeros((E,), bool))
    decide = pred.make_decide_fn()
    check_decide_fns(decide, el_state, E, F)   # shipped path: clean

    def compacting_step(carry, feats):
        off = jnp.cumsum(carry.active.astype(jnp.int32))
        packed = jnp.take(feats.features, off - 1, axis=0)
        return decide.step(carry, feats._replace(features=packed))

    bad = decide._replace(step=compacting_step)
    with pytest.raises(ContractViolation) as ei:
        check_decide_fns(bad, el_state, E, F)
    assert "env-mask-gate" in str(ei.value)


# ---------------------------------------------------------------------------
# jaxpr checker: the online train step (OnlineTrainer's construction gate)
# ---------------------------------------------------------------------------

def _train_fixture():
    from repro.core import replay as rp
    buf = rp.init(E, 8, F, A)
    params = {"w": jnp.zeros((F, A), jnp.float32)}
    tstate = {"m": {"w": jnp.zeros((F, A), jnp.float32)},
              "step": jnp.zeros((), jnp.int32)}
    return rp, buf, params, tstate


def test_train_step_raw_tick_weighting_rejected():
    """The bad fixture: a loss that weights transitions by the RAW tick
    index casts absolute time to float32 — the t~2^24 collapse class, now
    inside the update. The replay ``tick_idx`` column enters tagged, and
    the tag must survive the minibatch gather."""
    rp_mod, buf, params, tstate = _train_fixture()

    def bad(params, tstate, replay, rng):
        batch = rp_mod.sample_device(replay, rng, 8)
        w = batch["tick_idx"].astype(jnp.float32)     # absolute-time cast
        return jnp.sum(w * batch["rewards"]) + jnp.sum(params["w"])

    with pytest.raises(ContractViolation) as ei:
        check_train_step(bad, params, tstate, buf)
    assert "time-cast" in str(ei.value)


def test_train_step_rebased_tick_weighting_and_batch_reduce_accepted():
    """The good twin: rebase tick_idx to a relative age FIRST (subtracting
    two absolute times clears the tag), then narrow — and reduce freely
    over the sampled batch axis (a minibatch mean is the point; the env
    family is off for the train step)."""
    rp_mod, buf, params, tstate = _train_fixture()

    def good(params, tstate, replay, rng):
        batch = rp_mod.sample_device(replay, rng, 8)
        age = (batch["tick_idx"] - batch["tick_idx"][0]).astype(jnp.float32)
        w = jnp.exp(-jnp.abs(age) / 100.0) * batch["valid"]
        err = jnp.sum(jnp.square(batch["actions"]), axis=-1)
        return jnp.mean(w * err) + jnp.sum(params["w"])

    check_train_step(good, params, tstate, buf)   # must not raise


def test_train_step_host_callback_rejected():
    """A host callback anywhere in the update re-serializes serving and
    training (the step overlaps the fused decide dispatch)."""
    rp_mod, buf, params, tstate = _train_fixture()

    def chatty(params, tstate, replay, rng):
        batch = rp_mod.sample_device(replay, rng, 8)
        jax.debug.callback(lambda r: None, batch["rewards"])
        return jnp.sum(batch["rewards"] * batch["valid"])

    with pytest.raises(ContractViolation) as ei:
        check_train_step(chatty, params, tstate, buf)
    assert "callback-in-scan" in str(ei.value)


def test_real_trainer_step_accepted():
    """The shipped OnlineTrainer step passes its own construction gate
    (contract_check=True is the default — this builds one for real)."""
    from repro.core.reward import energy_reward_spec as _ers
    from repro.runtime.trainer import OnlineTrainer
    pred = Predictor(linear_policy(F, A),
                     _ers(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.full(A, -1.0), np.full(A, 1.0)),
                     E, F, replay_capacity=8)
    OnlineTrainer(pred, batch_size=4, contract_check=True)


# ---------------------------------------------------------------------------
# construction-time gates: RewardSpec and PerceptaSystem
# ---------------------------------------------------------------------------

def test_reward_spec_checks_custom_terms_at_construction():
    with pytest.raises(ContractViolation) as ei:
        RewardSpec((RewardTerm("custom",
                               fn=lambda f, a, p: f[:, 0] / f[:, 0].sum()),))
    assert "env-reduce" in str(ei.value)


def test_reward_spec_unchecked_escape_hatch(caplog):
    term = RewardTerm("custom", fn=lambda f, a, p: f[:, 0] / f[:, 0].sum())
    with caplog.at_level(logging.INFO, logger="repro.core.reward"):
        spec = RewardSpec((term,), unchecked=True)
    assert spec.terms == (term,)
    assert any("unchecked" in r.message for r in caplog.records)


def test_untraceable_custom_term_warns_not_raises():
    """A fn indexing past every probe shape is deferred (with a warning)
    to the true-shape check at system construction."""
    needs_777 = lambda f, a, p: f.reshape(f.shape[0], 777)[:, 0]
    with pytest.warns(UserWarning, match="could not statically check"):
        check_reward_terms((RewardTerm("custom", fn=needs_777),))


def _mini_system(mode, policy, **kw):
    from repro.core import PipelineConfig
    from repro.runtime.receivers import SimulatedDevice
    from repro.runtime.system import PerceptaSystem, SourceSpec
    srcs = [SourceSpec("meter", "mqtt",
                       SimulatedDevice("grid_kw", 60.0, base=3.0, seed=1)),
            SourceSpec("price", "http",
                       SimulatedDevice("price_eur", 300.0, base=0.2, seed=2))]
    cfg = PipelineConfig(n_envs=2, n_streams=2, n_ticks=8, max_samples=32)
    pred = Predictor(policy,
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     2, cfg.n_features, replay_capacity=8)
    return PerceptaSystem(["bldg-0", "bldg-1"], srcs, cfg, pred,
                          speedup=5000.0, manual_time=True, mode=mode,
                          scan_k=2, **kw)


def test_system_gate_rejects_gemm_policy_in_sharded_fused():
    W = jnp.ones((2, 2))
    bad = ModelAdapter(lambda f: f @ W, name="gemm")
    with pytest.raises(ContractViolation) as ei:
        _mini_system("scan_fused_decide_sharded", bad)
    msg = str(ei.value)
    assert "env-gemm-rows" in msg and "dot_general" in msg


def test_system_gate_accepts_linear_policy_in_sharded_fused():
    sys_ = _mini_system("scan_fused_decide_sharded", linear_policy(2, 2))
    results = sys_.run_windows(2)
    sys_.stop()
    assert len(results) == 2


def test_system_gate_env_rules_off_outside_sharded_dispatch():
    """A gemm policy is legal where the decision math is not env-sharded:
    the fused non-sharded engine, and scan_sharded's host-side consume."""
    W = jnp.ones((2, 2))
    bad = ModelAdapter(lambda f: f @ W, name="gemm")
    for mode in ("scan_fused_decide", "scan_sharded"):
        sys_ = _mini_system(mode, bad)
        sys_.stop()


def test_system_gate_opt_out():
    W = jnp.ones((2, 2))
    bad = ModelAdapter(lambda f: f @ W, name="gemm")
    sys_ = _mini_system("scan_fused_decide_sharded", bad,
                        contract_check=False)
    sys_.stop()


# ---------------------------------------------------------------------------
# AST lint: one bad/good fixture pair per rule
# ---------------------------------------------------------------------------

def _lint_src(src, rel="src/repro/core/fixture.py", tmp_path=None):
    p = tmp_path / "fixture.py"
    p.write_text(src)
    return lint_mod.lint_file(str(p), rel=rel)


def _rules(violations):
    return sorted({v.rule for v in violations})


def test_lint_jax_version_branch(tmp_path):
    bad = ("import jax\n"
           "if jax.__version__.startswith('0.4'):\n    x = 1\n")
    good = "import jax\nprint('running jax', jax.__version__)\n"
    assert _rules(_lint_src(bad, tmp_path=tmp_path)) == ["jax-version-branch"]
    assert _lint_src(good, tmp_path=tmp_path) == []
    # compat.py owns the version seam
    assert _lint_src(bad, rel="src/repro/compat.py", tmp_path=tmp_path) == []


def test_lint_jax_experimental(tmp_path):
    bad = "from jax.experimental.shard_map import shard_map\n"
    good = "from jax.experimental import pallas as pl\n"
    assert _rules(_lint_src(bad, tmp_path=tmp_path)) == \
        ["jax-experimental-outside-compat"]
    assert _lint_src(good, tmp_path=tmp_path) == []
    assert _lint_src(bad, rel="src/repro/compat.py", tmp_path=tmp_path) == []


def test_lint_mesh_calls(tmp_path):
    bad = ("from jax.sharding import Mesh\n"
           "mesh = Mesh(devs, ('data',))\n")
    good = ("from repro import compat\n"
            "import jax\n"
            "def f(m: jax.sharding.Mesh):\n"      # typing ref: fine
            "    return compat.make_mesh(devs, ('data',))\n")
    assert _rules(_lint_src(bad, tmp_path=tmp_path)) == ["mesh-outside-compat"]
    assert _lint_src(good, tmp_path=tmp_path) == []


def test_lint_donate_routing(tmp_path):
    bad = "import jax\nstep = jax.jit(f, donate_argnums=(0,))\n"
    good = ("from repro import compat\n"
            "step = compat.jit_donated(f, donate_argnums=(0,))\n")
    assert _rules(_lint_src(bad, tmp_path=tmp_path)) == \
        ["donate-outside-compat"]
    assert _lint_src(good, tmp_path=tmp_path) == []


def test_lint_state_leaf_alias(tmp_path):
    bad = "norm = system.state.norm\n"
    good = "norm = system.snapshot_norm()\n"
    assert _rules(_lint_src(bad, tmp_path=tmp_path)) == ["state-leaf-alias"]
    assert _lint_src(good, tmp_path=tmp_path) == []
    # runtime/system.py itself owns the state and is exempt
    assert _lint_src(bad, rel="src/repro/runtime/system.py",
                     tmp_path=tmp_path) == []


def test_lint_async_donate(tmp_path):
    rt = "src/repro/runtime/fixture.py"
    bad_lit = "out = dispatch(batch, donate=True)\n"
    bad_mode = ("out = dispatch(batch, donate=mode in "
                "('scan', 'scan_async'))\n")
    good = "out = dispatch(batch, donate=mode in ('scan', 'scan_sharded'))\n"
    assert _rules(_lint_src(bad_lit, rel=rt, tmp_path=tmp_path)) == \
        ["async-donate"]
    assert _rules(_lint_src(bad_mode, rel=rt, tmp_path=tmp_path)) == \
        ["async-donate"]
    assert _lint_src(good, rel=rt, tmp_path=tmp_path) == []
    # outside runtime/ the rule does not bind
    assert _lint_src(bad_lit, tmp_path=tmp_path) == []


def test_lint_lock_multi_acquire(tmp_path):
    rt = "src/repro/runtime/fixture.py"
    bad = ("def flush(self, items):\n"
           "    for it in items:\n"
           "        with self._lock:\n"
           "            self._emit(it)\n")
    good = ("def flush(self, items):\n"
            "    with self._lock:\n"
            "        for it in items:\n"
            "            self._emit(it)\n")
    sibling = ("class Hub:\n"
               "    def emit(self, it):\n"
               "        with self._lock:\n"
               "            self.sink.append(it)\n"
               "    def flush(self, items):\n"
               "        with self._lock:\n"
               "            self.emit(items[0])\n")
    assert _rules(_lint_src(bad, rel=rt, tmp_path=tmp_path)) == \
        ["lock-multi-acquire"]
    assert _lint_src(good, rel=rt, tmp_path=tmp_path) == []
    assert _rules(_lint_src(sibling, rel=rt, tmp_path=tmp_path)) == \
        ["lock-multi-acquire"]
    # a daemon's `while not stopped:` poll loop legitimately locks per wake
    daemon = ("def pump(self):\n"
              "    while not self._stop:\n"
              "        with self._lock:\n"
              "            self._drain()\n")
    assert _lint_src(daemon, rel=rt, tmp_path=tmp_path) == []


def test_lint_pragma_suppression(tmp_path):
    src = ("import jax\n"
           "if jax.__version__.startswith('0.4'):  # lint: allow[jax-version-branch]\n"
           "    x = 1\n")
    assert _lint_src(src, tmp_path=tmp_path) == []
    above = ("import jax\n"
             "# lint: allow[jax-version-branch]\n"
             "if jax.__version__.startswith('0.4'):\n    x = 1\n")
    assert _lint_src(above, tmp_path=tmp_path) == []
    # pragma for a different rule does not suppress
    wrong = ("import jax\n"
             "if jax.__version__.startswith('0.4'):  # lint: allow[async-donate]\n"
             "    x = 1\n")
    assert _rules(_lint_src(wrong, tmp_path=tmp_path)) == \
        ["jax-version-branch"]


def test_lint_baseline_roundtrip(tmp_path):
    p = tmp_path / "fixture.py"
    p.write_text("import jax\nstep = jax.jit(f, donate_argnums=(0,))\n")
    base = tmp_path / "baseline.json"
    found = lint_mod.lint_file(str(p), rel=str(p))
    assert len(found) == 1
    # before a baseline exists: everything is new
    new, old = lint_mod.apply_baseline(found, str(base))
    assert (len(new), len(old)) == (1, 0)
    lint_mod.write_baseline(found, str(base))
    # fingerprint survives a line-number shift (rule+file+code, not lineno)
    p.write_text("import jax\n\n\nstep = jax.jit(f, donate_argnums=(0,))\n")
    moved = lint_mod.lint_file(str(p), rel=str(p))
    new, old = lint_mod.apply_baseline(moved, str(base))
    assert (len(new), len(old)) == (0, 1)
    data = json.loads(base.read_text())
    assert data["violations"][0]["rule"] == "donate-outside-compat"


def test_repo_is_lint_clean():
    """The committed tree carries zero un-baselined findings — the same
    pin `make lint` enforces in CI (the baseline is committed empty)."""
    paths = [os.path.join(REPO, p) for p in lint_mod.DEFAULT_PATHS]
    paths = [p for p in paths if os.path.exists(p)]
    new, old = lint_mod.apply_baseline(lint_mod.run_paths(paths),
                                       lint_mod.DEFAULT_BASELINE)
    assert new == [], "\n".join(v.format() for v in new)
    assert old == []          # baseline is empty: nothing grandfathered


def test_rule_catalogs_cover_engines():
    """Every rule either engine can emit is declared in contracts.py (the
    catalog the ROADMAP table and --list-rules mirror)."""
    assert set(JAXPR_RULES) == {
        "env-contraction", "env-gemm-rows", "env-reduce", "collective",
        "time-cast", "callback-in-scan", "reward-shape", "carry-env-mix",
        "pallas-env-block", "param-replication", "env-mask-gate"}
    assert set(LINT_RULES) == {
        "jax-version-branch", "jax-experimental-outside-compat",
        "mesh-outside-compat", "donate-outside-compat", "state-leaf-alias",
        "async-donate", "lock-multi-acquire"}
    assert lint_mod.main(["--list-rules"]) == 0

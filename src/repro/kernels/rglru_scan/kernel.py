"""Pallas TPU kernel: RG-LRU linear recurrence h_t = a_t h_{t-1} + b_t.

The recurrence is sequential in T but perfectly parallel over (batch,
channel). Tiling: grid (B, W/128) — each kernel instance owns a (T, 128)
channel stripe in VMEM and walks T with a fori_loop, so HBM sees a single
streaming read of a/b and write of h (the XLA associative_scan path
materializes O(log T) intermediate full-size arrays instead).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _kernel(a_ref, b_ref, h0_ref, out_ref, hlast_ref):
    T = a_ref.shape[1]
    a = a_ref[0].astype(jnp.float32)        # (T, W_blk)
    b = b_ref[0].astype(jnp.float32)
    h0 = h0_ref[0].astype(jnp.float32)      # (1, W_blk)

    def body(t, h):
        h = a[t][None, :] * h + b[t][None, :]
        out_ref[0, t, :] = h[0]
        return h

    h = jax.lax.fori_loop(0, T, body, h0.reshape(1, -1))
    hlast_ref[0, :] = h[0]


def rglru_scan_pallas(a, b, h0, *, interpret: bool = True):
    """a, b: (B, T, W); h0: (B, W). W % 128 == 0 (pad upstream)."""
    B, T, W = a.shape
    assert W % LANES == 0, W
    grid = (B, W // LANES)
    out, hlast = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T, LANES), lambda bi, wi: (bi, 0, wi)),
            pl.BlockSpec((1, T, LANES), lambda bi, wi: (bi, 0, wi)),
            pl.BlockSpec((1, LANES), lambda bi, wi: (bi, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, T, LANES), lambda bi, wi: (bi, 0, wi)),
            pl.BlockSpec((1, LANES), lambda bi, wi: (bi, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        interpret=interpret,
    )(a, b, h0)
    return out, hlast

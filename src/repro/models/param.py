"""Declarative parameter trees.

Models declare parameters as nested dicts of :class:`ParamDef` carrying the
shape, dtype, initializer AND the *logical dimension names* of every axis.
The distribution layer maps logical dims onto mesh axes (with divisibility
fallback), which is what lets one rule-set shard ten different architectures.

The same tree yields:
  * ``specs(tree)``        -> ShapeDtypeStruct pytree (abstract dry-run inputs)
  * ``init(tree, rng)``    -> materialized arrays (smoke tests / real training)
  * ``dims(tree)``         -> logical-dims pytree (sharding resolution)
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamDef:
    shape: tuple
    dims: tuple                 # logical dim name per axis, len == len(shape)
    dtype: Any = jnp.bfloat16
    init: str = "normal"        # normal | zeros | ones | lecun | custom
    scale: float = 0.02
    custom: Optional[Callable] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.dims), (self.shape, self.dims)

    def spec(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def materialize(self, key) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "custom":
            return self.custom(key, self.shape).astype(self.dtype)
        if self.init == "lecun":
            fan_in = self.shape[0] if len(self.shape) >= 1 else 1
            s = 1.0 / math.sqrt(max(fan_in, 1))
            return (jax.random.normal(key, self.shape) * s).astype(self.dtype)
        return (jax.random.normal(key, self.shape) * self.scale).astype(self.dtype)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_map_defs(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_def)


def specs(tree):
    return tree_map_defs(lambda d: d.spec(), tree)


def dims(tree):
    return tree_map_defs(lambda d: d.dims, tree)


def init(tree, rng):
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_def)
    keys = jax.random.split(rng, len(leaves))
    return jax.tree.unflatten(treedef, [d.materialize(k) for d, k in zip(leaves, keys)])


def stack(tree, n: int, dim_name: str = "layers"):
    """Prepend a stacking axis (for ``lax.scan`` over layer groups)."""
    return tree_map_defs(
        lambda d: dataclasses.replace(d, shape=(n,) + d.shape, dims=(dim_name,) + d.dims),
        tree,
    )


def count(tree) -> int:
    return sum(int(np.prod(d.shape)) for d in jax.tree.leaves(tree, is_leaf=is_def))


def bytes_of(tree) -> int:
    return sum(
        int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize
        for d in jax.tree.leaves(tree, is_leaf=is_def)
    )

"""Training-loop fault tolerance + serving-engine behaviour."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.configs.base import ShapeConfig, ShardingConfig, TrainConfig
from repro.configs.registry import get_config
from repro.launch.mesh import make_smoke_mesh
from repro.models import LM
from repro.train.checkpoint import Checkpointer
from repro.train.loop import train

SHAPE = ShapeConfig("test", 32, 4, "train")


def _tcfg(tmp, **kw):
    base = dict(learning_rate=1e-3, warmup_steps=2, total_steps=20,
                checkpoint_every=10, checkpoint_dir=str(tmp),
                keep_checkpoints=2, async_checkpoint=False)
    base.update(kw)
    return TrainConfig(**base)


def test_loss_decreases(tmp_path):
    cfg = get_config("qwen3-0.6b:smoke")
    mesh = make_smoke_mesh()
    res = train(cfg, SHAPE, mesh, tcfg=_tcfg(tmp_path, total_steps=60,
                                             learning_rate=3e-3))
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert last < first - 0.05, (first, last)


def test_crash_resume_is_deterministic(tmp_path):
    """train 20 straight == train 10, 'crash', restore, train 10 more."""
    cfg = get_config("qwen3-0.6b:smoke")
    mesh = make_smoke_mesh()
    a = tmp_path / "a"
    b = tmp_path / "b"
    tc = dict(total_steps=20, checkpoint_every=10)  # same LR schedule in all
    res_straight = train(cfg, SHAPE, mesh, tcfg=_tcfg(a, **tc))
    res1 = train(cfg, SHAPE, mesh, tcfg=_tcfg(b, **tc), max_steps=10)
    res2 = train(cfg, SHAPE, mesh, tcfg=_tcfg(b, **tc))
    assert res2.restored_from == 10
    # the resumed run replays the same batches: loss traces must match
    assert_allclose(res_straight.losses[10:], res2.losses, rtol=1e-4)


def test_checkpointer_atomic_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_mode=False)
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}
    for step in (1, 2, 3):
        ck.save(step, tree, extra={"cursor": {"batch_index": step}})
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert steps == ["step_00000002", "step_00000003"]  # keep=2
    like = {"w": jax.ShapeDtypeStruct((2, 3), jnp.float32)}
    restored, extra = ck.restore(3, like)
    assert_allclose(np.asarray(restored["w"]), np.arange(6).reshape(2, 3))
    assert extra["cursor"]["batch_index"] == 3
    # a stale .tmp dir must never be picked up
    (tmp_path / "step_00000009.tmp").mkdir()
    assert ck.latest_step() == 3


def test_straggler_policy_detects_slow_steps():
    from repro.train.loop import StragglerPolicy
    p = StragglerPolicy(k=3.0, consecutive_to_fail=3, min_steps=3)
    for _ in range(10):
        assert p.observe(0.1) == "ok"
    assert p.observe(1.0) == "slow"      # simulated slow worker
    assert p.observe(1.0) == "slow"
    assert p.observe(1.0) == "fail"      # third strike -> restart
    assert p.slow_events == 3


def test_elastic_pool_growth_helpers():
    from repro.distribution.elastic import grow_env_tree, next_pool_size
    assert next_pool_size(3, 4) == 4          # fits, no growth
    assert next_pool_size(5, 4) == 8          # doubles
    assert next_pool_size(17, 4, n_devices=8) == 32
    assert next_pool_size(9, 8, n_devices=3) == 18  # device-aligned round-up
    tree = {"rows": jnp.arange(8, dtype=jnp.float32).reshape(4, 2),
            "scalar": jnp.float32(7.0)}
    tmpl = {"rows": jnp.full((8, 2), -1.0, jnp.float32),
            "scalar": jnp.float32(0.0)}
    grown = grow_env_tree(tree, tmpl, old_e=4)
    assert grown["rows"].shape == (8, 2)
    assert_allclose(np.asarray(grown["rows"][:4]),
                    np.arange(8).reshape(4, 2))       # survivors bit-exact
    assert_allclose(np.asarray(grown["rows"][4:]), -1.0)  # fresh init rows
    assert float(grown["scalar"]) == 7.0  # equal shapes pass through
    with pytest.raises(ValueError):
        grow_env_tree({"x": jnp.zeros((4, 2))}, {"x": jnp.zeros((8, 3))}, 4)


def test_grad_compression_reduces_bytes_and_converges(rng):
    from repro.distribution import compression as comp
    g = {"w": jnp.asarray(rng.normal(0, 0.1, (64, 64)).astype(np.float32))}
    ef = comp.init_ef(g)
    q, s, ef2 = comp.compress_grads(g, ef)
    assert q["w"].dtype == jnp.int8  # 4x smaller payload than f32
    recon = comp.decompress_grads(q, s)
    rel = float(jnp.linalg.norm(recon["w"] - g["w"]) / jnp.linalg.norm(g["w"]))
    assert rel < 0.02


def test_serve_engine_continuous_batching(rng):
    from repro.serve.engine import Request, ServeEngine
    cfg = get_config("qwen3-0.6b:smoke")
    model = LM(cfg, remat_policy="none")
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=3, max_seq=64)
    reqs = [Request(rid=i, prompt=rng.randint(1, cfg.vocab_size, (5,))
                    .astype(np.int32), max_new_tokens=6) for i in range(7)]
    engine.run_until_drained(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.tokens) == 6 for r in reqs)
    assert engine.stats["admitted"] == 7
    # continuous batching actually overlapped: 7 reqs on 3 slots must take
    # fewer ticks than sequential (7 * 6) yet at least ceil(7/3)*6
    assert 12 <= engine.stats["ticks"] < 42


def test_serve_engine_matches_model_decode(rng):
    """Engine greedy output == hand-rolled prefill+greedy loop."""
    from repro.serve.engine import Request, ServeEngine
    cfg = get_config("qwen3-0.6b:smoke")
    model = LM(cfg, remat_policy="none")
    params = model.init(jax.random.PRNGKey(0))
    prompt = rng.randint(1, cfg.vocab_size, (4,)).astype(np.int32)

    # reference: decode-fed prompt (numerically the same path the engine
    # takes: prefill-vs-blockwise summation order would flip argmaxes)
    cache = model.init_cache(1, 64)
    dec = jax.jit(model.decode_step)
    logits = None
    for t in prompt:
        logits, cache = dec(params, {"tokens": jnp.asarray([[int(t)]])}, cache)
    want = []
    tok = int(jnp.argmax(logits[0]))
    want.append(tok)
    for _ in range(4):
        logits, cache = dec(params, {"tokens": jnp.asarray([[tok]])}, cache)
        tok = int(jnp.argmax(logits[0]))
        want.append(tok)

    engine = ServeEngine(model, params, batch_slots=2, max_seq=64)
    req = Request(rid=0, prompt=prompt, max_new_tokens=5)
    engine.run_until_drained([req])
    assert req.tokens == want


def test_serve_timeout_mitigation(rng):
    from repro.serve.engine import Request, ServeEngine
    cfg = get_config("qwen3-0.6b:smoke")
    model = LM(cfg, remat_policy="none")
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=2, max_seq=64)
    stuck = Request(rid=0, prompt=rng.randint(1, cfg.vocab_size, (3,))
                    .astype(np.int32), max_new_tokens=10_000, deadline_s=0.0)
    engine.run_until_drained([stuck], max_ticks=5)
    assert stuck.done and stuck.finish_reason == "timeout"
    assert engine.stats["timeouts"] == 1

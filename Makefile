# Developer / CI entrypoints. `make test` is the tier-1 verify command from
# ROADMAP.md; `make bench-smoke` is a ~2-minute benchmark pass covering the
# pipeline execution axes (modular / fused / scan / scan_sharded /
# scan_async / scan_fused_decide) plus the scan-engine, async-overlap,
# batched-Predictor, fused-decide, autotuner, columnar-ingest and
# ingest-fast-path acceptance cells. The sharded modes run on a forced 8-host-device CPU mesh
# (--host-devices) so the shard_map path is exercised in CI, not just on
# real multi-chip hardware; the async overlap cell runs in its own
# subprocess (accelerator-emulating XLA flags, see benchmarks/run.py).
# Results are also written as JSON (windows/s + records/s per mode) and
# diffed against the committed trajectory record by benchmarks/compare.py
# (report-only: single-run numbers drift on shared boxes).
PY ?= python

.PHONY: test lint train-smoke bench-smoke bench-pr2 bench-pr3 bench-pr4 \
	bench-pr5 bench-pr6 bench-pr7 bench-pr8 bench-pr9 bench-pr10 ci

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# invariant gate (PR 6, extended PR 8): the AST lint over
# src/examples/benchmarks, the jaxpr contract checker over every builtin
# policy/reward/decide path, AND certification of every registered policy
# (runtime.policies) against the full rule catalog; rule catalog in
# ROADMAP.md ("Invariant catalog") and
# `python -m repro.analysis.lint --list-rules`. Under GitHub Actions the
# findings surface as per-line ::error annotations on the PR diff.
lint:
	PYTHONPATH=src $(PY) -m repro.analysis.lint --jaxpr-builtins \
		$(if $(GITHUB_ACTIONS),--format=github,)

# online-retraining smoke (PR 7): the end-to-end
# sample -> update -> hot-swap -> checkpoint -> restore chain via the
# crash-recovery example (asserts version continuity and attribution)
train-smoke:
	PYTHONPATH=src $(PY) examples/train_retrain.py --windows 20

# CI pass: writes BENCH_smoke.json (untracked scratch) so repeated CI runs
# never clobber the committed BENCH_prN.json trajectory records, then
# reports >10% throughput regressions vs the NEWEST committed
# BENCH_pr<N>.json (compare.py picks it — the baseline can't go stale)
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --smoke --host-devices 8 \
		--json BENCH_smoke.json
	$(PY) -m benchmarks.compare latest BENCH_smoke.json

# regenerate the committed perf-trajectory artifacts (run manually per PR)
bench-pr2:
	PYTHONPATH=src $(PY) -m benchmarks.run --smoke --host-devices 8 \
		--json BENCH_pr2.json

bench-pr3:
	PYTHONPATH=src $(PY) -m benchmarks.run --host-devices 8 \
		--only "scan_engine|scan_sharded|scan_async|autotune|columnar" \
		--json BENCH_pr3.json

# PR 4: the batched-Predictor-consume cells (identity + before/after host
# share on the PR 3 overlap cell) next to the scan-engine trajectory cells
bench-pr4:
	PYTHONPATH=src $(PY) -m benchmarks.run --host-devices 8 \
		--only "scan_engine|scan_sharded|scan_async|predictor_batch|autotune|columnar" \
		--json BENCH_pr4.json

# PR 5: the fused-decide cells (identity, K=32/E=256 fused-vs-two-dispatch
# with phase decomposition + host-transfer bytes, sharded E=256 on the
# forced 8-device mesh) next to the scan-engine trajectory cells
bench-pr5:
	PYTHONPATH=src $(PY) -m benchmarks.run --host-devices 8 \
		--only "scan_engine|scan_sharded|scan_async|predictor_batch|fused_decide|autotune|columnar" \
		--json BENCH_pr5.json

# PR 6: the construction-time contract-check overhead cell next to the
# scan-engine trajectory cells
bench-pr6:
	PYTHONPATH=src $(PY) -m benchmarks.run --host-devices 8 \
		--only "scan_engine|scan_sharded|scan_async|predictor_batch|fused_decide|autotune|columnar|contract_check" \
		--json BENCH_pr6.json

# PR 7: the online-retraining cells (device sample+update vs host export,
# serving windows/s with overlapped training on vs off) next to the
# scan-engine trajectory cells
bench-pr7:
	PYTHONPATH=src $(PY) -m benchmarks.run --host-devices 8 \
		--only "scan_engine|scan_sharded|scan_async|predictor_batch|fused_decide|online_train|autotune|columnar|contract_check" \
		--json BENCH_pr7.json

# PR 8: the policy-certification cells (cold certify of the full registry
# vs the cached path riding a fused standup) next to the trajectory cells
bench-pr8:
	PYTHONPATH=src $(PY) -m benchmarks.run --host-devices 8 \
		--only "scan_engine|scan_sharded|scan_async|predictor_batch|fused_decide|online_train|autotune|columnar|contract_check|certify" \
		--json BENCH_pr8.json

# PR 9: the elastic-membership cells (masked slot-pool overhead at 75%
# occupancy vs a dense fixed-E baseline, one timed pool regrow) next to
# the trajectory cells
bench-pr9:
	PYTHONPATH=src $(PY) -m benchmarks.run --host-devices 8 \
		--only "scan_engine|scan_sharded|scan_async|predictor_batch|fused_decide|online_train|elastic|autotune|columnar|contract_check|certify" \
		--json BENCH_pr9.json

# PR 10: the host-ingest fast-path phase-decomposition cell (legacy vs
# arena-staged sorted-merge assembly, bit-identity asserted in-cell) next
# to the full trajectory set — the async overlap cell re-measures with the
# fast path on, so its speedup reflects the smaller A term
bench-pr10:
	PYTHONPATH=src $(PY) -m benchmarks.run --host-devices 8 \
		--only "scan_engine|scan_sharded|scan_async|predictor_batch|fused_decide|online_train|elastic|autotune|columnar|contract_check|certify|ingest_fastpath" \
		--json BENCH_pr10.json

# CI boxes should `pip install -r requirements-dev.txt` first so the
# property tests (elastic schedules, sorted-merge vs lexsort parity) run
# under real hypothesis; without it they still RUN — repro.testing falls
# back to a deterministic draw shim — they just don't shrink.
ci: lint test train-smoke bench-smoke

"""Pipelined (async double-buffered) scan engine + K/E autotuner.

``mode="scan_async"`` must be bit-identical to ``scan`` (and the sharded
composition to ``scan_sharded``): the pump thread performs exactly the
synchronous clock-advance/poll/drain sequence at the same window
boundaries, so the only per-window field allowed to differ is the wall
``latency_s`` metric. Also: prefetch-thread exceptions re-raise in the
Manager thread, and ``tune_scan_params`` is deterministic under a fixed
injected timer.
"""
import os
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.core import PipelineConfig
from repro.core.autotune import (TuneResult, candidate_device_counts,
                                 tune_scan_params)
from repro.core.reward import energy_reward_spec
from repro.runtime.predictor import ActionSpace, Predictor, linear_policy
from repro.runtime.prefetch import WindowPrefetcher
from repro.runtime.receivers import SimulatedDevice
from repro.runtime.system import PerceptaSystem, SourceSpec


def _system(mode, n_envs=2, scan_k=3, **kw):
    srcs = [
        SourceSpec("meter", "mqtt", SimulatedDevice("grid_kw", 60.0,
                                                    base=3.0, seed=1)),
        SourceSpec("price", "http", SimulatedDevice("price_eur", 300.0,
                                                    base=0.2, amplitude=0.05,
                                                    seed=2)),
    ]
    cfg = PipelineConfig(n_envs=n_envs, n_streams=2, n_ticks=8, tick_s=60.0,
                         max_samples=32)
    pred = Predictor(linear_policy(2, 2),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     n_envs, cfg.n_features, replay_capacity=64)
    envs = [f"bldg-{i}" for i in range(n_envs)]
    return PerceptaSystem(envs, srcs, cfg, pred, speedup=5000.0,
                          manual_time=True, mode=mode, scan_k=scan_k, **kw)


def _strip(results):
    """Everything but the wall-clock latency metric must match exactly."""
    return [{k: v for k, v in r.items() if k != "latency_s"}
            for r in results]


# --------------------------------------------------------------------------
# Bit-identity: scan_async == scan == scan_sharded (+ the async composition)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("async_mode", ["scan_async", "scan_async_sharded"])
def test_scan_async_matches_scan_system(async_mode):
    # 7 windows over scan_k=3 -> two full batches + a partial one, so the
    # epoch handoff covers the ragged tail too
    ref = _strip(_system("scan").run_windows(7))
    ref_sharded = _strip(_system("scan_sharded").run_windows(7))
    sys_a = _system(async_mode)
    got = _strip(sys_a.run_windows(7))
    assert got == ref
    assert got == ref_sharded
    sys_a.stop()


def test_scan_async_chained_calls_resume_epochs():
    """A second run_windows call reuses the pump thread and stays aligned."""
    a = _system("scan")
    b = _system("scan_async")
    ra = a.run_windows(3) + a.run_windows(4)
    rb = b.run_windows(3) + b.run_windows(4)
    assert [r["window"] for r in rb] == list(range(7))
    assert _strip(ra) == _strip(rb)
    # stats flow through the pump thread identically (same drain epochs)
    qa, qb = a.stats()["queues"], b.stats()["queues"]
    for env in qa:
        assert qa[env] == qb[env]
    b.stop()


_ASYNC_SHARDED_SCRIPT = """
import numpy as np
from repro.core import PipelineConfig
from repro.core.reward import energy_reward_spec
from repro.runtime.predictor import ActionSpace, Predictor, linear_policy
from repro.runtime.receivers import SimulatedDevice
from repro.runtime.system import PerceptaSystem, SourceSpec
import jax
assert len(jax.devices()) == 8, jax.devices()

def mk(mode):
    srcs = [SourceSpec("meter", "mqtt",
                       SimulatedDevice("grid_kw", 60.0, base=3.0, seed=1)),
            SourceSpec("price", "http",
                       SimulatedDevice("price_eur", 300.0, base=0.2,
                                       amplitude=0.05, seed=2))]
    cfg = PipelineConfig(n_envs=8, n_streams=2, n_ticks=4, tick_s=60.0,
                         max_samples=16)
    pred = Predictor(linear_policy(2, 2),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     8, cfg.n_features, replay_capacity=64)
    return PerceptaSystem([f"b{i}" for i in range(8)], srcs, cfg, pred,
                          speedup=5000.0, manual_time=True, mode=mode,
                          scan_k=3)

strip = lambda rs: [{k: v for k, v in r.items() if k != "latency_s"}
                    for r in rs]
ref = strip(mk("scan").run_windows(7))
sh = mk("scan_async_sharded")
assert dict(sh.pipeline.mesh.shape) == {"data": 8}, sh.pipeline.mesh
got = strip(sh.run_windows(7))
assert got == ref
sh.stop()
print("ASYNC_SHARDED_OK")
"""


def test_scan_async_sharded_multi_device_bit_identical():
    """Real 8-device forced CPU mesh in a subprocess (the XLA flag must
    precede JAX init): async + shard_map composition == plain scan."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run([sys.executable, "-c", _ASYNC_SHARDED_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ASYNC_SHARDED_OK" in out.stdout


# --------------------------------------------------------------------------
# Prefetcher: epoch protocol + exception propagation
# --------------------------------------------------------------------------

def test_prefetcher_exception_propagates_to_manager():
    calls = []

    def assemble(bounds, pump):
        calls.append(bounds)
        if len(calls) == 2:
            raise ValueError("drain exploded")
        return ("raw", list(bounds)), [0] * len(bounds)

    pf = WindowPrefetcher(assemble)
    pf.submit([(0.0, 1.0)])
    pf.submit([(1.0, 2.0)])
    first = pf.next_batch()
    assert first.epoch == 0 and first.counts == [0]
    with pytest.raises(ValueError, match="drain exploded"):
        pf.next_batch()
    # the prefetcher is poisoned afterwards: submitting again surfaces it
    with pytest.raises(RuntimeError):
        pf.submit([(2.0, 3.0)])
    pf.stop()


def test_prefetcher_epoch_order_and_backpressure():
    order = []
    gate = threading.Event()

    def assemble(bounds, pump):
        order.append(bounds[0][0])
        gate.wait(5.0)
        return ("raw", bounds[0][0]), [1]

    pf = WindowPrefetcher(assemble, depth=1)
    for j in range(4):
        pf.submit([(float(j), float(j) + 1.0)], pump=False)
    gate.set()
    got = [pf.next_batch() for _ in range(4)]
    assert [b.epoch for b in got] == [0, 1, 2, 3]
    assert order == [0.0, 1.0, 2.0, 3.0]     # strict plan order
    pf.stop()


def test_prefetcher_stop_with_abandoned_batches_and_restart():
    """A Manager that abandons its batches (consumer exception) must not
    wedge stop() on the full ready buffer, and a later submit() must start
    from a clean handoff state instead of replaying stale plans."""
    import time as _time

    assembled = []

    def assemble(bounds, pump):
        assembled.append(bounds[0][0])
        return ("raw", bounds[0][0]), [1]

    pf = WindowPrefetcher(assemble, depth=1)
    for j in range(4):          # never consumed: pump wedges on the buffer
        pf.submit([(float(j), float(j) + 1.0)], pump=False)
    t0 = _time.time()
    pf.stop()
    assert _time.time() - t0 < 5.0
    assert pf._thread is None
    # clean restart: fresh epochs, no stale plan ever re-assembled
    n_before = len(assembled)
    pf.submit([(100.0, 101.0)], pump=False)
    got = pf.next_batch()
    assert got.epoch == 0 and got.raw == ("raw", 100.0)
    assert assembled[n_before:] == [100.0]
    pf.stop()


def test_system_surfaces_pump_thread_failure(monkeypatch):
    sys_ = _system("scan_async")

    def boom(bounds):
        raise RuntimeError("accumulator corrupt")

    monkeypatch.setattr(sys_, "assemble_windows", boom)
    with pytest.raises(RuntimeError, match="accumulator corrupt"):
        sys_.run_windows(3)
    sys_.stop()


# --------------------------------------------------------------------------
# Autotuner: grid measurement, selection, determinism
# --------------------------------------------------------------------------

def _fake_measure(fn, *, k, n_devices, reps=3):
    """Deterministic synthetic timer: never executes fn, prefers K=4."""
    return {2: 0.004, 4: 0.006, 8: 0.020}[k] * n_devices


def test_autotuner_deterministic_under_fixed_measure():
    cfg = PipelineConfig(n_envs=2, n_streams=2, n_ticks=8, tick_s=60.0,
                        max_samples=32)
    a = tune_scan_params(cfg, k_grid=(2, 4, 8), device_counts=[1],
                         measure=_fake_measure)
    b = tune_scan_params(cfg, k_grid=(2, 4, 8), device_counts=[1],
                         measure=_fake_measure)
    assert a == b                       # identical TuneResult, grid included
    assert isinstance(a, TuneResult)
    # windows/s argmax of the synthetic grid: 4/0.006 > 8/0.020 > 2/0.004
    assert a.scan_k == 4 and a.mesh_devices == 1
    best = max(w for _, _, w in a.grid)
    assert a.best_windows_per_s == best


def test_autotuner_measures_real_dispatches():
    cfg = PipelineConfig(n_envs=2, n_streams=2, n_ticks=4, tick_s=60.0,
                         max_samples=16)
    res = tune_scan_params(cfg, k_grid=(2, 4), device_counts=[1], reps=1)
    assert {(k, n) for k, n, _ in res.grid} == {(2, 1), (4, 1)}
    assert all(w > 0 for _, _, w in res.grid)
    # selection is within 10% of the measured grid optimum (argmax => 0%)
    assert res.best_windows_per_s >= 0.9 * max(w for _, _, w in res.grid)


def test_candidate_device_counts_divisibility():
    assert candidate_device_counts(8, 8) == [1, 2, 4, 8]
    assert candidate_device_counts(6, 4) == [1, 2, 3]


def test_autotuner_floor_prunes_starved_mesh_splits():
    """Splits below min_envs_per_device never measure (an E=8 batch over 8
    devices is one env row per chip — pure dispatch overhead), and the
    skip is recorded on TuneResult.pruned."""
    calls = []

    def measure(fn, *, k, n_devices, reps=3):
        calls.append((k, n_devices))
        return 0.001 * k

    cfg = PipelineConfig(n_envs=8, n_streams=2, n_ticks=8, tick_s=60.0,
                         max_samples=32)
    res = tune_scan_params(cfg, k_grid=(2, 4), device_counts=[1, 4, 8],
                           measure=measure)
    assert all(n != 8 for _, n in calls)
    assert (None, 8, "envs_per_device<2") in res.pruned
    assert {n for _, n, _ in res.grid} == {1, 4}
    # the floor is a knob: relaxing it restores the split
    res2 = tune_scan_params(cfg, k_grid=(2,), device_counts=[1, 8],
                            measure=measure, min_envs_per_device=1)
    assert res2.pruned == () and {n for _, n, _ in res2.grid} == {1, 8}


def test_autotuner_early_stops_cells_far_off_incumbent():
    """A cell >prune_factor x slower than the incumbent stops the rest of
    its mesh-split column; selection stays deterministic under the
    injected timer (pruned set included)."""
    def measure(fn, *, k, n_devices, reps=3):
        if n_devices == 2:
            return 1.0          # 2 w/s at k=2: hopeless split
        return {2: 0.004, 4: 0.006}[k]

    cfg = PipelineConfig(n_envs=4, n_streams=2, n_ticks=8, tick_s=60.0,
                         max_samples=32)
    a = tune_scan_params(cfg, k_grid=(2, 4), device_counts=[1, 2],
                         measure=measure)
    b = tune_scan_params(cfg, k_grid=(2, 4), device_counts=[1, 2],
                         measure=measure)
    assert a == b
    # ndev=2 measured only at k=2; k=4 early-stopped
    assert {(k, n) for k, n, _ in a.grid} == {(2, 1), (4, 1), (2, 2)}
    assert a.pruned == ((4, 2, ">3x_off_incumbent"),)
    assert a.scan_k == 4 and a.mesh_devices == 1


def test_autotuner_fused_decide_grid_measures_fused_engine():
    """With decide=/decide_state= every cell runs the fused engine; the
    caller's decide state is never donated, so tuning leaves it intact."""
    import jax
    import numpy as np

    cfg = PipelineConfig(n_envs=2, n_streams=2, n_ticks=4, tick_s=60.0,
                         max_samples=16)
    pred = Predictor(linear_policy(2, 2),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     2, cfg.n_features, replay_capacity=8)
    dstate = pred.decide_state()
    before = jax.tree.map(lambda x: np.asarray(x).copy(), dstate)
    res = tune_scan_params(cfg, k_grid=(2, 4), device_counts=[1], reps=1,
                           decide=pred.make_decide_fn(), decide_state=dstate)
    assert {(k, n) for k, n, _ in res.grid} == {(2, 1), (4, 1)}
    assert all(w > 0 for _, _, w in res.grid)
    for x, y in zip(jax.tree.leaves(before), jax.tree.leaves(dstate)):
        assert (np.asarray(x) == np.asarray(y)).all()


def test_system_scan_k_auto_fused_decide_runs_tuned():
    """scan_k="auto" composes with the fused-decide mode end to end and
    the tuned system stays bit-identical to the scan reference."""
    sys_ = _system("scan_fused_decide", scan_k="auto",
                   autotune=dict(k_grid=(2, 4, 8), measure=_fake_measure))
    assert sys_.scan_k == 4
    ref = _strip(_system("scan", scan_k=4).run_windows(5))
    assert _strip(sys_.run_windows(5)) == ref
    sys_.stop()


def test_system_scan_k_auto_picks_measured_optimum():
    sys_ = _system("scan_async",
                   scan_k="auto",
                   autotune=dict(k_grid=(2, 4, 8), measure=_fake_measure))
    assert sys_.scan_k == 4
    assert sys_.tuned is not None and sys_.tuned.scan_k == 4
    # and the tuned system still runs, bit-identical to plain scan
    ref = _strip(_system("scan", scan_k=4).run_windows(5))
    assert _strip(sys_.run_windows(5)) == ref
    sys_.stop()

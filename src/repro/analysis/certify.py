"""Policy certification — the registration-time gate for REAL decision
models on the fused/sharded decision path.

:func:`certify_policy` traces a policy's apply fn (params pytree +
optional recurrent carry + ``(E, F)`` features) to a closed jaxpr and runs
the full :mod:`repro.analysis` rule catalog over it with three
capabilities the plain system gate does not need:

  * **recurrent-carry fixed point** — a stateful policy's carry rides the
    fused scan next to ``DecideState``, so env/time tags are propagated
    across decide steps to a fixed point (``carry_out -> carry_in`` links)
    and the ``carry-env-mix`` rule rejects carries that mix rows across
    envs (both the row-moving primitives en route and a fixed-point
    structural check: every carry leaf env-tagged exactly on dim 0, or
    fully env-free);
  * **pallas_call recursion** — BlockSpec index maps are evaluated over
    the grid and mapped onto the env tag (``pallas-env-block``), so
    ``kernels/rglru_scan`` certifies instead of conservatively poisoning
    every downstream check;
  * **param replication** — the builder is probed at two env counts and
    any param leaf whose structure or shape scales with E is rejected
    (``param-replication``): ``sharding.decide_specs`` replicates the
    whole params subtree on the env mesh, so per-env weights baked into
    params would silently mis-broadcast.

Certification emits a machine-readable :class:`PolicyCertificate` (rules
checked, jaxpr hash, carry treedef, param spec), cached by key so repeated
system standups skip re-tracing entirely (the ``bench_certify`` cell
asserts the cached path adds <1% to a fused-system standup, mirroring the
PR 6 contract-check gate).
"""
from __future__ import annotations

import dataclasses
import hashlib
import inspect
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import ContractViolation
from repro.analysis.jaxpr_check import (
    Rules, _Ctx, _check_carry_structure, _parse_tag, _run_to_fixed_point,
    _sds,
)

# default (E, F, A) probe shapes: small enough to trace in milliseconds,
# two env counts so carry/param env structure cannot hide behind E == F
DEFAULT_PROBES: Tuple[Tuple[int, int, int], ...] = ((4, 6, 2),)

# full-strictness certification rules: a certificate must hold on the
# env-sharded fused engine, so the env family and the carry row-movement
# checks are both on regardless of the mode the system is built in
CERTIFY_RULES = Rules(env=True, collectives=True, callbacks=True,
                      time=True, carry=True)

_CACHE: dict = {}


@dataclasses.dataclass(frozen=True)
class PolicyCertificate:
    """Machine-readable proof that a policy passed the full rule catalog.

    ``jaxpr_sha256`` fingerprints the traced program per probe shape (the
    cache key for skipping re-traces); ``param_spec`` records every param
    leaf as ``(path, shape, dtype)`` so the replication contract is
    auditable; ``carry_treedef`` is empty for stateless policies.
    """
    name: str
    rules: Tuple[str, ...]            # Rules fields that were enforced
    jaxpr_sha256: str                 # hash over all probe-shape jaxprs
    carry_treedef: str
    param_spec: Tuple[Tuple[str, Tuple[int, ...], str], ...]
    probe_shapes: Tuple[Tuple[int, int, int], ...]
    stateful: bool

    def describe(self) -> str:
        kind = "stateful" if self.stateful else "stateless"
        return (f"PolicyCertificate({self.name}: {kind}, "
                f"{len(self.param_spec)} param leaves, "
                f"rules={','.join(self.rules)}, "
                f"jaxpr={self.jaxpr_sha256[:12]})")


def _describe_builder(builder: Callable, name: Optional[str]) -> str:
    """Human-readable label naming the registry key AND the builder, so a
    rejection never reads ``<lambda>``: lambdas/functools.partial policies
    have no useful __name__, and the registry key is what the user typed."""
    import functools

    base = builder
    while isinstance(base, functools.partial):
        base = base.func
    bname = getattr(base, "__qualname__", None) \
        or getattr(base, "__name__", None) or type(base).__name__
    mod = getattr(base, "__module__", "")
    built = f"{mod}.{bname}" if mod else bname
    if name:
        return f"policy '{name}' (builder {built})"
    return f"policy builder {built}"


def _accepts_kwarg(fn: Callable, kw: str) -> bool:
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return True   # builtins/partials without signatures: just try
    if any(p.kind == inspect.Parameter.VAR_KEYWORD
           for p in sig.parameters.values()):
        return True
    return kw in sig.parameters


def _build(builder, F: int, A: int, E: int):
    """Build one adapter from a builder (or pass a prebuilt adapter
    through)."""
    if not callable(builder) or hasattr(builder, "fn"):
        return builder            # a ModelAdapter (it is callable too)
    kw = {}
    if _accepts_kwarg(builder, "n_envs"):
        kw["n_envs"] = E
    return builder(F, A, **kw)


def _param_paths(params):
    from jax import tree_util as jtu
    flat, _ = jtu.tree_flatten_with_path(params)
    return [(jtu.keystr(p), leaf) for p, leaf in flat]


def _trace_one(adapter, E: int, F: int, rules: Rules, label: str, ctx: _Ctx):
    """Trace one probe shape and run the rule walk with the carry fixed
    point; returns (closed jaxpr, params, carry)."""
    from repro.runtime.predictor import policy_call2

    apply2, params, init_carry = policy_call2(adapter)
    carry = init_carry(E) if init_carry is not None else None
    p_avals = jax.tree.map(
        lambda x: _sds(np.shape(x), jnp.asarray(x).dtype), params)
    c_avals = jax.tree.map(
        lambda x: _sds(np.shape(x), jnp.asarray(x).dtype), carry)
    feats = _sds((E, F))
    closed = jax.make_jaxpr(apply2)(p_avals, feats, c_avals)

    n_params = len(jax.tree.leaves(p_avals))
    carry_leaves = jax.tree.leaves(c_avals)
    n_carry = len(carry_leaves)
    in_provs = [_parse_tag("", len(a.shape))
                for a in jax.tree.leaves(p_avals)]
    in_provs.append(_parse_tag("env:0", 2))                 # features
    in_provs += [_parse_tag("env:0" if len(a.shape) and a.shape[0] == E
                            else "", len(a.shape)) for a in carry_leaves]

    # apply2 returns (actions, new_carry): actions leaves flatten first,
    # the carry's trail — link them back onto the carry inputs and run to
    # the cross-step fixed point (the fused scan feeds carry_t to step t+1)
    out_sds = jax.eval_shape(apply2, p_avals, feats, c_avals)
    n_act = len(jax.tree.leaves(out_sds[0]))
    pairs = [(n_act + i, n_params + 1 + i) for i in range(n_carry)]
    out_provs = _run_to_fixed_point(closed.jaxpr, in_provs, ctx, 1, pairs)
    if rules.env and n_carry:
        _check_carry_structure(
            c_avals, out_provs[n_act:n_act + n_carry], E, ctx,
            what=f"{label} carry")
    return closed, params, carry


def certify_policy(builder, probe_shapes: Sequence = DEFAULT_PROBES, *,
                   name: Optional[str] = None, rules: Rules = CERTIFY_RULES,
                   cache_key: Any = None) -> PolicyCertificate:
    """Statically certify a policy builder (or prebuilt adapter) for the
    fused/sharded decision path; returns a :class:`PolicyCertificate` or
    raises :class:`~repro.analysis.contracts.ContractViolation` naming
    rule, primitive and source.

    ``builder``: either ``builder(n_features, n_actions, n_envs=E, ...)
    -> ModelAdapter`` (the registry convention — probed at two env counts
    for the param-replication check) or a prebuilt ``ModelAdapter``
    (certified as-is at the probe shapes; replication is then vacuous
    since no env count was baked at build time).
    ``probe_shapes``: ``(E, F, A)`` triples; every probe must pass.
    ``cache_key``: hashable key for the certificate cache — repeated
    standups with the same key skip re-tracing entirely.
    """
    if cache_key is not None and cache_key in _CACHE:
        return _CACHE[cache_key]
    probes = tuple((int(e), int(f), int(a)) for e, f, a in probe_shapes)
    label = _describe_builder(builder, name)
    ctx = _Ctx(rules, label)
    hasher = hashlib.sha256()
    carry_treedef = ""
    param_spec: tuple = ()
    stateful = False
    is_builder = callable(builder) and not hasattr(builder, "fn")

    for E, F, A in probes:
        adapter = _build(builder, F, A, E)
        closed, params, carry = _trace_one(adapter, E, F, rules, label, ctx)
        hasher.update(str(closed.jaxpr).encode())
        stateful = stateful or carry is not None
        carry_treedef = str(jax.tree.structure(carry))
        param_spec = tuple(
            (path, tuple(np.shape(leaf)), str(np.asarray(leaf).dtype))
            for path, leaf in _param_paths(params))

        if is_builder:
            # param replication probe: rebuild at E+1 — any leaf whose
            # structure/shape moved with E means the builder baked env
            # structure into params, which decide_specs replicates
            other = _build(builder, F, A, E + 1)
            from repro.runtime.predictor import policy_call2
            params2 = policy_call2(other)[1]
            a_paths = _param_paths(params)
            b_paths = _param_paths(params2)
            if [p for p, _ in a_paths] != [p for p, _ in b_paths]:
                ctx.add("param-replication",
                        f"param tree structure changes between E={E} and "
                        f"E={E + 1} builds: params must be env-count "
                        "independent (replicated on the mesh, "
                        "sharding.decide_specs)", "", "")
            else:
                for (path, la), (_, lb) in zip(a_paths, b_paths):
                    if np.shape(la) != np.shape(lb):
                        ctx.add(
                            "param-replication",
                            f"param leaf '{path}' is env-sized: shape "
                            f"{np.shape(la)} at E={E} vs {np.shape(lb)} "
                            f"at E={E + 1} — per-env weights cannot ride "
                            "the replicated policy subtree "
                            "(sharding.decide_specs); fold the env "
                            "dependence into the carry instead", "", "")
                        break

    if ctx.violations:
        raise ContractViolation(ctx.violations, label)
    cert = PolicyCertificate(
        name=name or label,
        rules=tuple(f for f in Rules._fields if getattr(rules, f)),
        jaxpr_sha256=hasher.hexdigest(),
        carry_treedef=carry_treedef,
        param_spec=param_spec,
        probe_shapes=probes,
        stateful=stateful,
    )
    if cache_key is not None:
        _CACHE[cache_key] = cert
    return cert


def clear_cache() -> None:
    """Drop every cached certificate (tests / cold-path benchmarks)."""
    _CACHE.clear()

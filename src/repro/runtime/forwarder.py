"""Forwarders — decision delivery, one per destination system.

"If there is a smart light device that receives a 'turn on' decision, then
the decision is routed to the specific Forwarder associated with that
system. This Forwarder ensures the decision is formatted and transmitted
correctly."
"""
from __future__ import annotations

import json
import threading
from typing import Callable, Dict, List, Optional, Sequence

from repro.runtime.records import CODECS


class Forwarder:
    """Formats and 'transmits' decisions for one destination (transport
    simulated by an in-memory sink; swap `transmit` for a real client)."""

    def __init__(self, dest_id: str, protocol: str, action_indices: Sequence[int],
                 transmit: Optional[Callable[[bytes], None]] = None):
        self.dest_id = dest_id
        self.protocol = protocol
        self.action_indices = list(action_indices)
        self.sink: List[bytes] = []
        self._transmit = transmit or self.sink.append
        self.stats = {"sent": 0, "bytes": 0}
        self._lock = threading.Lock()

    def _transmit_locked(self, payloads) -> None:
        # ONE lock acquisition per call (not per action index): sent/bytes
        # move together, so a pump-thread reader never observes a dispatch
        # half-counted, and batch dispatch isn't serialized on lock churn
        with self._lock:
            for payload in payloads:
                self._transmit(payload)
                self.stats["sent"] += 1
                self.stats["bytes"] += len(payload)

    def forward(self, env_id: str, tick_time: float, actions):
        encode = CODECS[self.protocol][0]
        self._transmit_locked([
            encode(f"{self.dest_id}/act{idx}", tick_time, float(actions[idx]))
            for idx in self.action_indices])

    def forward_window(self, tick_time: float, actions):
        """Batch dispatch one window: ``actions`` is (E, A), payloads for
        every (env, action index) are encoded up front in env-major order
        (matching E sequential ``forward`` calls), then transmitted under
        one lock acquisition. Like ``forward``, the wire topic carries only
        dest/action identity — env attribution lives in the LogDB rows."""
        encode = CODECS[self.protocol][0]
        self._transmit_locked([
            encode(f"{self.dest_id}/act{idx}", tick_time, float(a[idx]))
            for a in actions for idx in self.action_indices])


class ForwarderHub:
    def __init__(self, forwarders: Sequence[Forwarder]):
        self.forwarders = list(forwarders)

    def dispatch(self, env_id: str, tick_time: float, actions):
        for f in self.forwarders:
            f.forward(env_id, tick_time, actions)

    def dispatch_window(self, tick_time: float, actions):
        """One window across all envs (actions (E, A)); each forwarder's
        sink sees the same env order as per-env ``dispatch`` calls."""
        for f in self.forwarders:
            f.forward_window(tick_time, actions)

"""Pure-jnp oracle for the fused bucketize+aggregate harmonization kernel."""
from __future__ import annotations

import jax.numpy as jnp


def harmonize_ref(values, timestamps, valid, t0, tick_s: float, n_ticks: int):
    """Rows of raw samples -> tick means.

    values/timestamps/valid: (R, M); t0: (R,) window starts.
    Returns (out (R, T) bucket means, observed (R, T)).
    """
    rel = timestamps - t0[:, None]
    idx = jnp.ceil(rel / tick_s).astype(jnp.int32) - 1
    ok = valid & (idx >= 0) & (idx < n_ticks)
    idx = jnp.clip(idx, 0, n_ticks - 1)
    onehot = ((idx[:, :, None] == jnp.arange(n_ticks)) & ok[:, :, None]
              ).astype(jnp.float32)                     # (R, M, T)
    count = onehot.sum(1)
    total = jnp.einsum("rm,rmt->rt", values.astype(jnp.float32), onehot)
    observed = count > 0
    return jnp.where(observed, total / jnp.maximum(count, 1.0), 0.0), observed

"""Elastic env-slot pool growth.

The engine allocates a *slot pool* of ``E`` env rows and threads an
``active: (E,) bool`` mask through the scan carry, so envs can attach and
detach between window batches with no retrace.  This module owns the one
operation that DOES retrace: growing the pool when it fills.

Protocol (driven by ``runtime.system.PerceptaSystem.resize``):

1. ``next_pool_size`` picks the new capacity (doubling, device-aligned so
   the env mesh can still split the slot axis evenly).
2. ``grow_env_tree`` pads every env-leading leaf of the state / decide-carry
   / replay pytrees from ``old_e`` to the new capacity, taking the fresh
   rows from a template built at the new size (templates carry the correct
   init values — e.g. ``prev_ts=-inf`` sentinels, ``NormState`` min=+inf —
   and any leaves that do not carry an env axis, such as policy params,
   pass through from the template untouched).
3. The caller re-chooses the env mesh for the new slot count, re-places the
   grown trees via ``sharding.place_env_tree``, and rebuilds the pipeline —
   the only allowed retrace point.  Surviving rows are copied bit-exactly,
   so active envs resume as if nothing happened.

``reset_env_rows`` is the attach/detach half: it rewrites individual slot
rows from a fresh init template between dispatches (out-of-place ``.at[]``
updates; donation-safe because it runs on the host between batches).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def next_pool_size(n_active: int, current_slots: int,
                   n_devices: int = 1) -> int:
    """Smallest doubled, device-aligned capacity holding ``n_active`` envs.

    Doubles ``current_slots`` until it fits ``n_active``, then rounds up to
    a multiple of ``n_devices`` so ``sharding.env_mesh`` can split the slot
    axis evenly across the env mesh.
    """
    if n_active <= current_slots:
        return current_slots
    slots = max(1, current_slots)
    while slots < n_active:
        slots *= 2
    if n_devices > 1 and slots % n_devices:
        slots += n_devices - slots % n_devices
    return slots


def grow_env_tree(tree, template, old_e: int):
    """Pad env-leading leaves of ``tree`` to the template's slot capacity.

    For each leaf pair ``(x, t)``: if their shapes differ *only* in the
    leading (env) dim, the result is ``concat([x, t[old_e:]], axis=0)`` —
    the surviving ``old_e`` rows are carried over bit-exactly and the new
    rows take the template's fresh init values.  Leaves with identical
    shapes (policy params, scalar cursors, version counters) pass through
    from ``tree`` unchanged.  Any other shape mismatch is an error.

    Works on single arrays as well as arbitrary pytrees.
    """
    def leaf(x, t):
        x = jnp.asarray(x)
        t = jnp.asarray(t)
        if x.shape == t.shape:
            return x
        if (x.ndim == t.ndim and x.ndim >= 1 and x.shape[1:] == t.shape[1:]
                and x.shape[0] == old_e and t.shape[0] > old_e):
            return jnp.concatenate([x, t[old_e:]], axis=0)
        raise ValueError(
            f"grow_env_tree: leaf shape {x.shape} does not match template "
            f"{t.shape} (expected equal, or env-dim growth from {old_e})")

    return jax.tree.map(leaf, tree, template)


def reset_env_rows(tree, template, slots):
    """Rewrite slot rows of env-leading leaves from a fresh init template.

    ``slots`` is a sequence of slot indices being attached (or detached);
    every leaf whose leading dim matches the template's env dim gets those
    rows replaced by the template's rows.  Leaves without an env axis
    (shape mismatch in dim 0) pass through unchanged.  Out-of-place
    (``.at[].set``), so it is safe between donated dispatches.
    """
    idx = jnp.asarray(list(slots), jnp.int32)
    if idx.size == 0:
        return tree
    env_dim = None

    def probe(t):
        nonlocal env_dim
        t = jnp.asarray(t)
        if env_dim is None and t.ndim >= 1:
            env_dim = t.shape[0]
        return t

    jax.tree.map(probe, template)

    def leaf(x, t):
        x = jnp.asarray(x)
        t = jnp.asarray(t)
        if x.ndim >= 1 and x.shape == t.shape and x.shape[0] == env_dim:
            return x.at[idx].set(t[idx])
        return x

    return jax.tree.map(leaf, tree, template)

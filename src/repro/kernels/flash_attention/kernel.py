"""Pallas TPU kernel: causal GQA flash attention (+ sliding window, softcap).

VMEM-tiled online-softmax: grid (batch, q_head, q_block, kv_block) with the
(acc, m, l) accumulators in VMEM scratch carried across the kv_block grid
dim (the innermost, 'arbitrary'-order dim on TPU). KV blocks entirely in the
causal future of a Q block are masked (their contribution is exactly zero —
XLA's TPU scheduler skips revisiting them via the index map when
block_causal pruning applies; interpret mode just computes zeros).

GQA is native: the kv index map folds q_head -> q_head // group so KV tiles
are fetched once per kv head group, never materialized repeated. The gemma2
variants are the same kernel with softcap/window static parameters — the
tanh softcap applies pre-masking exactly as in the reference.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_QBLK = 128
DEFAULT_KBLK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, window: int, softcap: float, kv_blocks: int,
            q_blk: int, kv_blk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale         # (Qb, D)
    k = k_ref[0, 0].astype(jnp.float32)                 # (Kb, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = q @ k.T                                          # (Qb, Kb)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = qi * q_blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * kv_blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    m_new = jnp.maximum(m_new, -1e29)  # fully-masked rows stay finite
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + p @ v
    m_ref[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, window: int = 0, softcap: float = 0.0,
                           q_blk: int = DEFAULT_QBLK,
                           kv_blk: int = DEFAULT_KBLK,
                           interpret: bool = True):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D). Returns (B, H, S, D)."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    q_blk = min(q_blk, S)
    kv_blk = min(kv_blk, S)
    assert S % q_blk == 0 and S % kv_blk == 0, (S, q_blk, kv_blk)
    nq, nk = S // q_blk, S // kv_blk
    scale = 1.0 / math.sqrt(D)
    kern = functools.partial(_kernel, scale=scale, window=window,
                             softcap=softcap, kv_blocks=nk, q_blk=q_blk,
                             kv_blk=kv_blk)
    grid = (B, H, nq, nk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, kv_blk, D),
                         lambda b, h, qi, ki, _G=G: (b, h // _G, ki, 0)),
            pl.BlockSpec((1, 1, kv_blk, D),
                         lambda b, h, qi, ki, _G=G: (b, h // _G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            # VMEM accumulators carried across the kv grid dim
            pltpu.VMEM((q_blk, D), jnp.float32),
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

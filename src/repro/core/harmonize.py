"""Data-rate harmonization — Percepta's core stream transformation.

Sources report at wildly different rates ("one device may send data every 5
minutes while another sends it once per hour") with arbitrary jitter.
``harmonize`` aligns every stream onto the model's tick grid:

  * tick t collects samples with timestamp in (tick_ts[t] - tick, tick_ts[t]]
  * multiple samples per tick are aggregated (mean/last/sum/min/max)
  * ticks with no sample are marked unobserved (gap-filling handles them)
  * alternatively ``mode='interp'`` linearly interpolates between the two
    samples bracketing the tick (for slow, smooth quantities)

Everything is vectorized over (E, S, M) x (T,): the bucket assignment is a
searchsorted-free one-hot contraction, which is what the Pallas
``kernels/harmonize`` kernel tiles through VMEM on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.frame import RawWindow

AGGS = ("mean", "last", "sum", "min", "max")


def tick_grid(window_start, tick_s: float, n_ticks: int):
    """Tick timestamps (end-of-bucket convention). window_start: (E,)."""
    return window_start[:, None] + tick_s * (1.0 + jnp.arange(n_ticks))


def bucketize(raw: RawWindow, tick_ts, tick_s: float):
    """Bucket index per raw sample. Returns (idx (E,S,M), in_range (E,S,M))."""
    t0 = tick_ts[:, 0] - tick_s  # window start
    rel = raw.timestamps - t0[:, None, None]
    idx = jnp.ceil(rel / tick_s).astype(jnp.int32) - 1
    T = tick_ts.shape[1]
    ok = raw.valid & (idx >= 0) & (idx < T)
    return jnp.clip(idx, 0, T - 1), ok


# Below this many one-hot elements per (E,S) row, the dense contraction in
# ``_harmonize_dense`` beats segment scatter. XLA:CPU lowers segment_sum to
# a serial per-element scatter loop (~350us for 4k updates — measured inside
# the scan engine); the dense mask ops vectorize and fuse. Edge windows
# (M<=64, T<=16) always take the dense path; the scatter path remains for
# large M*T where one-hot memory would dominate.
_DENSE_MT_MAX = 8192


def _harmonize_dense(values, timestamps, idx, ok, T: int, agg: str):
    """One-hot-mask aggregation for one requested ``agg`` (small M*T).

    Layout matters on XLA:CPU: reducing the (E,S,M,T) one-hot over its
    strided M axis is ~6x slower than phrasing the same sum as a dot or
    reducing a contiguous trailing axis (measured inside the scan engine).
    Sums therefore go through einsum; min/max/last build the mask directly
    as (E,S,T,M) so the reduce runs over the innermost axis.
    """
    big = jnp.float32(3.4e38)
    if agg in ("mean", "sum"):
        w = ((idx[..., None] == jnp.arange(T))
             & ok[..., None]).astype(jnp.float32)               # (E,S,M,T)
        count = jnp.einsum("esm,esmt->est", jnp.ones_like(values), w)
        observed = count > 0
        total = jnp.einsum("esm,esmt->est", values, w)
        out = total if agg == "sum" else total / jnp.maximum(count, 1.0)
        return jnp.where(observed, out, 0.0), observed

    onehot = (idx[:, :, None, :] == jnp.arange(T)[:, None]) \
        & ok[:, :, None, :]                                     # (E,S,T,M)
    count = onehot.astype(jnp.float32).sum(-1)                  # (E,S,T)
    observed = count > 0
    v_tm = values[:, :, None, :]
    if agg == "min":
        out = jnp.min(jnp.where(onehot, v_tm, big), axis=-1)
    elif agg == "max":
        out = jnp.max(jnp.where(onehot, v_tm, -big), axis=-1)
    elif agg == "last":
        ts_key = jnp.where(onehot, timestamps[:, :, None, :], -big)
        last_sel = (ts_key == ts_key.max(axis=-1, keepdims=True)) & onehot
        sel = last_sel.astype(jnp.float32)
        out = (v_tm * sel).sum(-1) / jnp.maximum(sel.sum(-1), 1.0)
    else:
        raise ValueError(agg)
    return jnp.where(observed, out, 0.0), observed


def harmonize_segment(raw: RawWindow, tick_ts, tick_s: float,
                      agg: str = "mean"):
    """Segment-reduction harmonization: O(M) per sample instead of the
    O(M*T) one-hot contraction (the §Perf pipeline optimization; same
    results as ``harmonize`` — property-tested).

    Buckets become segment ids (row-major over E*S rows x T ticks; invalid
    samples map to a trash segment) and jax.ops.segment_* does the rest.
    Small windows (M*T <= ``_DENSE_MT_MAX``) instead use a dense mask
    contraction — same bucket sums, vectorized instead of scattered.
    """
    E, S, M = raw.values.shape
    T = tick_ts.shape[1]
    idx, ok = bucketize(raw, tick_ts, tick_s)
    if M * T <= _DENSE_MT_MAX:
        return _harmonize_dense(raw.values, raw.timestamps, idx, ok, T, agg)
    rows = jnp.arange(E * S).reshape(E, S, 1)
    seg = jnp.where(ok, rows * T + idx, E * S * T).reshape(-1)
    n_seg = E * S * T + 1
    v = jnp.where(ok, raw.values, 0.0).reshape(-1)
    okf = ok.astype(jnp.float32).reshape(-1)

    count = jax.ops.segment_sum(okf, seg, num_segments=n_seg)[:-1]
    observed = (count > 0).reshape(E, S, T)
    if agg in ("mean", "sum"):
        total = jax.ops.segment_sum(v, seg, num_segments=n_seg)[:-1]
        out = total if agg == "sum" else total / jnp.maximum(count, 1.0)
    elif agg == "min":
        out = jax.ops.segment_min(
            jnp.where(ok, raw.values, 3.4e38).reshape(-1), seg,
            num_segments=n_seg)[:-1]
    elif agg == "max":
        out = jax.ops.segment_max(
            jnp.where(ok, raw.values, -3.4e38).reshape(-1), seg,
            num_segments=n_seg)[:-1]
    elif agg == "last":
        ts = jnp.where(ok, raw.timestamps, -3.4e38).reshape(-1)
        bucket_last = jax.ops.segment_max(ts, seg, num_segments=n_seg)
        is_last = (ts == bucket_last[seg]) & (okf > 0)
        den = jax.ops.segment_sum(is_last.astype(jnp.float32), seg,
                                  num_segments=n_seg)[:-1]
        num = jax.ops.segment_sum(v * is_last, seg, num_segments=n_seg)[:-1]
        out = num / jnp.maximum(den, 1.0)
    else:
        raise ValueError(agg)
    out = out.reshape(E, S, T)
    return jnp.where(observed, out, 0.0), observed


def harmonize(raw: RawWindow, tick_ts, tick_s: float, agg: str = "mean",
              stream_agg=None):
    """Align raw samples to the tick grid (one-hot contraction form).

    raw: (E, S, M); tick_ts: (E, T). agg: default aggregation; stream_agg:
    optional (S,) int32 selecting AGGS per stream (heterogeneous sources).
    Returns (values (E,S,T), observed (E,S,T)).
    """
    E, S, M = raw.values.shape
    T = tick_ts.shape[1]
    idx, ok = bucketize(raw, tick_ts, tick_s)
    onehot = (idx[..., None] == jnp.arange(T)) & ok[..., None]  # (E,S,M,T)
    w = onehot.astype(jnp.float32)
    count = w.sum(axis=2)                                       # (E,S,T)
    observed = count > 0

    v = raw.values
    sum_v = jnp.einsum("esm,esmt->est", v, w)
    mean_v = sum_v / jnp.maximum(count, 1.0)
    big = jnp.float32(3.4e38)
    min_v = jnp.min(jnp.where(onehot, v[..., None], big), axis=2)
    max_v = jnp.max(jnp.where(onehot, v[..., None], -big), axis=2)
    # last = sample with max timestamp within the bucket
    ts_key = jnp.where(onehot, raw.timestamps[..., None], -big)
    last_sel = ts_key == ts_key.max(axis=2, keepdims=True)
    last_v = jnp.einsum("esm,esmt->est", v,
                        (last_sel & onehot).astype(jnp.float32)) / \
        jnp.maximum((last_sel & onehot).sum(axis=2), 1)

    stack = jnp.stack([mean_v, last_v, sum_v, min_v, max_v])    # (5,E,S,T)
    if stream_agg is None:
        out = stack[AGGS.index(agg)]
    else:
        out = jnp.take_along_axis(
            stack, stream_agg[None, None, :, None], axis=0)[0]
    out = jnp.where(observed, out, 0.0)
    return out, observed


def harmonize_interp(raw: RawWindow, tick_ts, *, max_gap_s: float = 0.0,
                     prev_value=None, prev_ts=None):
    """Linear interpolation of each tick between bracketing samples.

    For slow-reporting sources (the paper's once-per-hour devices) bucketing
    leaves most ticks empty; interpolation reconstructs the intermediate
    resolution instead. O(M*T) masked min/max — no sort, batch-friendly.
    prev_value/prev_ts: (E, S) carry-in from the previous window so the first
    ticks can bridge across the window boundary.
    """
    E, S, M = raw.values.shape
    T = tick_ts.shape[1]
    ts = jnp.where(raw.valid, raw.timestamps, jnp.inf)          # (E,S,M)
    tsn = jnp.where(raw.valid, raw.timestamps, -jnp.inf)
    tick = tick_ts[:, None, :, None]                            # (E,1,T,1)
    before = tsn[:, :, None, :] <= tick[..., 0][..., None]      # (E,S,T,M)
    after = ts[:, :, None, :] > tick[..., 0][..., None]

    big = jnp.float32(3.4e38)
    t_lo = jnp.max(jnp.where(before, tsn[:, :, None, :], -big), axis=-1)
    t_hi = jnp.min(jnp.where(after, ts[:, :, None, :], big), axis=-1)
    sel_lo = before & (tsn[:, :, None, :] == t_lo[..., None])
    sel_hi = after & (ts[:, :, None, :] == t_hi[..., None])
    den_lo = jnp.maximum(sel_lo.sum(-1), 1)
    den_hi = jnp.maximum(sel_hi.sum(-1), 1)
    v_lo = jnp.einsum("estm,esm->est", sel_lo.astype(jnp.float32), raw.values) / den_lo
    v_hi = jnp.einsum("estm,esm->est", sel_hi.astype(jnp.float32), raw.values) / den_hi
    has_lo = t_lo > -big
    has_hi = t_hi < big

    if prev_value is not None and prev_ts is not None:
        bridge = (~has_lo) & (prev_ts[:, :, None] <= tick_ts[:, None, :])
        t_lo = jnp.where(bridge, prev_ts[:, :, None], t_lo)
        v_lo = jnp.where(bridge, prev_value[:, :, None], v_lo)
        has_lo = has_lo | bridge

    span = jnp.maximum(t_hi - t_lo, 1e-6)
    frac = jnp.clip((tick_ts[:, None, :] - t_lo) / span, 0.0, 1.0)
    both = has_lo & has_hi
    if max_gap_s > 0:
        both = both & ((t_hi - t_lo) <= max_gap_s)
    interp = v_lo + frac * (v_hi - v_lo)
    out = jnp.where(both, interp, jnp.where(has_lo, v_lo, 0.0))
    observed = both | has_lo
    return out, observed

"""AST invariant lint — host-side rules the type system can't see.

Enforces the repo's documented host-code invariants (rule catalog in
:mod:`repro.analysis.contracts`, mirrored in ROADMAP.md "Invariant
catalog"):

  * every version-sensitive JAX spelling routes through ``repro.compat``
    (``jax.__version__`` branches, ``jax.experimental`` imports, direct
    Mesh/shard_map/set_mesh construction, raw donation kwargs) — the
    exception is ``jax.experimental.pallas``, the kernels' only home
    across the supported version matrix;
  * host code never aliases ``system.state`` leaves (donated carries
    invalidate old buffers — use the snapshot accessors);
  * ``runtime/`` never donates in async modes and holds its locks once
    per call (the PR 4 one-lock-per-call rule).

Suppression: append ``# lint: allow[<rule-id>]`` to the offending line (or
the line above).  Violations that need their own PR live in the committed
``lint_baseline.json`` next to this module (``--update-baseline``
regenerates it); baselined findings never fail the run, new ones always do.

CLI::

    python -m repro.analysis.lint [paths...] [--jaxpr-builtins]
    python -m repro.analysis.lint --list-rules
"""
from __future__ import annotations

import argparse
import ast
import json
import os
import re
import sys
import time
from typing import Dict, List, Optional, Set

from repro.analysis.contracts import LINT_RULES, Violation

DEFAULT_PATHS = ("src/repro", "examples", "benchmarks")
_PRAGMA = re.compile(r"#\s*lint:\s*allow\[([a-z0-9,\- ]*)\]")

# calls that must not be spelled outside compat.py (full dotted origin)
_MESH_CALLS = {
    "jax.sharding.Mesh", "jax.sharding.AbstractMesh", "jax.make_mesh",
    "jax.set_mesh", "jax.sharding.set_mesh", "jax.sharding.use_mesh",
    "jax.experimental.shard_map.shard_map", "jax.shard_map",
    "jax.experimental.mesh_utils.create_device_mesh",
}
_VERSION_PARSERS = {"split", "startswith", "parse", "Version", "tuple",
                    "map", "LooseVersion"}


def _dotted(node) -> Optional[str]:
    """Attribute/Name chain -> dotted string, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _FileLint(ast.NodeVisitor):
    def __init__(self, rel: str, tree: ast.AST, lines: List[str]):
        self.rel = rel
        self.lines = lines
        norm = "/" + rel.replace(os.sep, "/")
        self.is_compat = norm.endswith("/compat.py") and "/repro/" in norm
        self.in_runtime = "/runtime/" in norm
        self.is_system = norm.endswith("/runtime/system.py")
        self.violations: List[Violation] = []
        self.imports: Dict[str, str] = {}   # local name -> dotted origin
        self._parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent
        # class -> method -> set of lock expr strings acquired in its body
        self._class_locks: Dict[ast.ClassDef, Dict[str, Set[str]]] = {}
        for n in ast.walk(tree):
            if isinstance(n, ast.ClassDef):
                self._class_locks[n] = {
                    m.name: self._locks_acquired(m)
                    for m in n.body if isinstance(m, ast.FunctionDef)}
        self._with_locks: List[str] = []    # lexical stack of held locks
        self._loop_depth = 0

    # --- plumbing -----------------------------------------------------------
    def _suppressed(self, lineno: int, rule: str) -> bool:
        for ln in (lineno, lineno - 1):
            if 1 <= ln <= len(self.lines):
                m = _PRAGMA.search(self.lines[ln - 1])
                if m and (not m.group(1).strip()
                          or rule in re.split(r"[,\s]+", m.group(1))):
                    return True
        return False

    def _flag(self, node, rule: str, message: str):
        if self._suppressed(node.lineno, rule):
            return
        self.violations.append(Violation(
            rule=rule, message=message, primitive=type(node).__name__,
            source=f"{self.rel}:{node.lineno}", label=self.rel))

    def _enclosing(self, node, *types):
        cur = node
        while cur in self._parents:
            prev, cur = cur, self._parents[cur]
            if isinstance(cur, types):
                yield cur, prev

    @staticmethod
    def _is_lockish(expr) -> bool:
        src = _dotted(expr) or ""
        return "lock" in src.lower()

    def _locks_acquired(self, fn: ast.FunctionDef) -> Set[str]:
        out: Set[str] = set()
        for n in ast.walk(fn):
            if isinstance(n, ast.With):
                for item in n.items:
                    if self._is_lockish(item.context_expr):
                        out.add(_dotted(item.context_expr) or "")
        return out

    # --- compat-routing rules -----------------------------------------------
    def visit_Import(self, node: ast.Import):
        for a in node.names:
            self.imports[a.asname or a.name.split(".")[0]] = a.name
            if (not self.is_compat
                    and a.name.startswith("jax.experimental")
                    and not a.name.startswith("jax.experimental.pallas")):
                self._flag(node, "jax-experimental-outside-compat",
                           f"import of '{a.name}' outside repro/compat.py; "
                           "route the version seam through repro.compat "
                           "(only jax.experimental.pallas is exempt)")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        mod = node.module or ""
        for a in node.names:
            self.imports[a.asname or a.name] = f"{mod}.{a.name}"
        if (not self.is_compat and mod.startswith("jax.experimental")
                and not mod.startswith("jax.experimental.pallas")
                and not (mod == "jax.experimental"
                         and all(a.name == "pallas" for a in node.names))):
            self._flag(node, "jax-experimental-outside-compat",
                       f"'from {mod} import ...' outside repro/compat.py; "
                       "route the version seam through repro.compat "
                       "(only jax.experimental.pallas is exempt)")
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute):
        dotted = _dotted(node)
        if dotted == "jax.__version__" and not self.is_compat:
            if self._version_branch_context(node):
                self._flag(node, "jax-version-branch",
                           "jax.__version__ used in a branch/comparison "
                           "outside repro/compat.py — add a compat shim "
                           "instead of a call-site version fork (metadata "
                           "uses are fine)")
        elif (dotted and dotted.startswith("jax.experimental")
              and not dotted.startswith("jax.experimental.pallas")
              and not self.is_compat):
            # flag once, at the outermost attribute of the chain
            parent = self._parents.get(node)
            if not (isinstance(parent, ast.Attribute)):
                self._flag(node, "jax-experimental-outside-compat",
                           f"direct '{dotted}' spelling outside "
                           "repro/compat.py")
        # system.state leaf aliasing: <receiver>.state.<leaf>
        if (isinstance(node.value, ast.Attribute)
                and node.value.attr == "state"
                and isinstance(node.value.value, ast.Name)
                and not self.is_system):
            recv = node.value.value.id.lower()
            if recv == "sys" or "system" in recv:
                self._flag(node, "state-leaf-alias",
                           f"aliases a pipeline-state leaf "
                           f"('{node.value.value.id}.state.{node.attr}'): "
                           "donated scan carries invalidate old buffers — "
                           "read through the snapshot accessors "
                           "(snapshot_norm / export_replay)")
        self.generic_visit(node)

    def _version_branch_context(self, node) -> bool:
        for anc, child in self._enclosing(node, ast.Compare, ast.BoolOp,
                                          ast.If, ast.IfExp, ast.While,
                                          ast.Call, ast.Assert):
            if isinstance(anc, (ast.Compare, ast.BoolOp, ast.Assert)):
                return True
            if isinstance(anc, (ast.If, ast.IfExp, ast.While)) \
                    and child is anc.test:
                return True
            if isinstance(anc, ast.Call):
                fn = anc.func
                name = fn.attr if isinstance(fn, ast.Attribute) else \
                    (fn.id if isinstance(fn, ast.Name) else "")
                if name in _VERSION_PARSERS:
                    return True
        return False

    def visit_Call(self, node: ast.Call):
        dotted = _dotted(node.func)
        origin = self.imports.get(dotted, dotted) if dotted else None
        if dotted and "." in dotted:   # resolve `m.f` where m was imported
            head, _, tail = dotted.partition(".")
            if head in self.imports:
                origin = f"{self.imports[head]}.{tail}"
        if not self.is_compat and origin in _MESH_CALLS:
            self._flag(node, "mesh-outside-compat",
                       f"direct call of '{origin}' outside repro/compat.py "
                       "— mesh/shard_map construction is a version seam "
                       "(axis_types, AbstractMesh signature, shard_map "
                       "location churn); use the repro.compat helpers")
        kw = {k.arg: k.value for k in node.keywords if k.arg}
        if ("donate_argnums" in kw or "donate_argnames" in kw) \
                and not self.is_compat:
            callee = (node.func.attr if isinstance(node.func, ast.Attribute)
                      else dotted) or ""
            if callee.split(".")[-1] != "jit_donated":
                self._flag(node, "donate-outside-compat",
                           "raw donation kwargs outside repro/compat.py — "
                           "route through compat.jit_donated (de-aliases "
                           "duplicate donated buffers, silences spurious "
                           "donation warnings, preserves .lower)")
        if self.in_runtime and "donate" in kw:
            val = kw["donate"]
            if isinstance(val, ast.Constant) and val.value is True:
                self._flag(node, "async-donate",
                           "donate=True literal in runtime/: async modes "
                           "must never donate (a donated input still being "
                           "computed blocks the dispatch and serializes the "
                           "prefetch overlap); gate donation on the mode")
            elif isinstance(val, ast.Compare) and len(val.ops) == 1 \
                    and isinstance(val.ops[0], ast.In):
                comp = val.comparators[0]
                elts = getattr(comp, "elts", [])
                bad = [e.value for e in elts
                       if isinstance(e, ast.Constant)
                       and isinstance(e.value, str) and "async" in e.value]
                if bad:
                    self._flag(node, "async-donate",
                               f"donation enabled for async mode(s) {bad}: "
                               "async modes must never donate")
        # lock rule (c): calling a sibling that re-acquires a held lock
        if self.in_runtime and self._with_locks \
                and isinstance(node.func, ast.Attribute) \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id == "self":
            for cls, methods in self._class_locks.items():
                locks = methods.get(node.func.attr)
                if locks is None:
                    continue
                held = set(self._with_locks) & locks
                if held and self._in_class(node, cls):
                    self._flag(node, "lock-multi-acquire",
                               f"calls self.{node.func.attr}() while "
                               f"holding {sorted(held)[0]}, which that "
                               "method re-acquires — split out a _locked "
                               "helper (one acquire per call)")
        self.generic_visit(node)

    def _in_class(self, node, cls) -> bool:
        return any(anc is cls for anc, _ in self._enclosing(node,
                                                            ast.ClassDef))

    # --- threading rules ------------------------------------------------------
    def visit_With(self, node: ast.With):
        if not self.in_runtime:
            return self.generic_visit(node)
        lock_exprs = [_dotted(i.context_expr) or "" for i in node.items
                      if self._is_lockish(i.context_expr)]
        for le in lock_exprs:
            if self._loop_depth > 0:
                self._flag(node, "lock-multi-acquire",
                           f"acquires {le} inside a for-loop: batch the "
                           "items first and hold the lock once per call "
                           "(the one-lock-per-call rule)")
            if le in self._with_locks:
                self._flag(node, "lock-multi-acquire",
                           f"nested acquire of {le} (already held by an "
                           "enclosing with) — deadlocks a non-reentrant "
                           "lock")
        self._with_locks.extend(lock_exprs)
        self.generic_visit(node)
        del self._with_locks[len(self._with_locks) - len(lock_exprs):]

    def visit_For(self, node: ast.For):
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    def visit_FunctionDef(self, node: ast.FunctionDef):
        # loop depth / held locks are per-function lexical properties: a
        # nested def runs later, outside the enclosing with/for
        saved = (self._loop_depth, self._with_locks)
        self._loop_depth, self._with_locks = 0, []
        self.generic_visit(node)
        self._loop_depth, self._with_locks = saved

    visit_AsyncFunctionDef = visit_FunctionDef


# --- runner ---------------------------------------------------------------------

def lint_file(path: str, rel: Optional[str] = None) -> List[Violation]:
    rel = rel or path
    with open(path, "r", encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Violation(rule="parse-error", message=str(e),
                          source=f"{rel}:{e.lineno or 0}", label=rel)]
    lint = _FileLint(rel, tree, src.splitlines())
    lint.visit(tree)
    return lint.violations


def iter_py_files(paths):
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            yield p
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = [d for d in dirs
                           if d not in ("__pycache__", ".git")]
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)


def run_paths(paths) -> List[Violation]:
    out: List[Violation] = []
    for f in iter_py_files(paths):
        out.extend(lint_file(f, os.path.relpath(f)))
    return out


# --- baseline --------------------------------------------------------------------

def _fingerprint(v: Violation, lines_cache: Dict[str, List[str]]) -> dict:
    """Line numbers shift; fingerprint on (rule, file, stripped code)."""
    fname, _, lineno = v.source.rpartition(":")
    code = ""
    try:
        if fname not in lines_cache:
            with open(fname, "r", encoding="utf-8") as f:
                lines_cache[fname] = f.read().splitlines()
        code = lines_cache[fname][int(lineno) - 1].strip()
    except Exception:
        pass
    return {"rule": v.rule, "file": fname.replace(os.sep, "/"),
            "code": code}


def apply_baseline(violations: List[Violation], baseline_path: str):
    """Split into (new, baselined) against the committed fingerprints."""
    try:
        with open(baseline_path, "r", encoding="utf-8") as f:
            entries = json.load(f).get("violations", [])
    except FileNotFoundError:
        entries = []
    pool = [tuple(sorted(e.items())) for e in entries]
    cache: Dict[str, List[str]] = {}
    new, old = [], []
    for v in violations:
        fp = tuple(sorted(_fingerprint(v, cache).items()))
        if fp in pool:
            pool.remove(fp)
            old.append(v)
        else:
            new.append(v)
    return new, old


def write_baseline(violations: List[Violation], baseline_path: str):
    cache: Dict[str, List[str]] = {}
    data = {"comment": "lint findings grandfathered for their own PR; "
                       "python -m repro.analysis.lint --update-baseline",
            "violations": [_fingerprint(v, cache) for v in violations]}
    with open(baseline_path, "w", encoding="utf-8") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")


DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__),
                                "lint_baseline.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Percepta invariant lint (rules in ROADMAP.md "
                    "'Invariant catalog')")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to lint (default: {DEFAULT_PATHS})")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--no-baseline", action="store_true")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline with the current findings")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--jaxpr-builtins", action="store_true",
                    help="also run the jaxpr contract checker over every "
                         "builtin policy/reward/decide path and certify "
                         "the policy registry")
    ap.add_argument("--format", choices=("text", "json", "github"),
                    default="text",
                    help="finding output: human text (default), a "
                         "machine-readable JSON document (rule, file, "
                         "line, fingerprint per finding), or GitHub "
                         "Actions ::error per-line annotations")
    args = ap.parse_args(argv)

    if args.list_rules:
        from repro.analysis.contracts import JAXPR_RULES
        for name, rules in (("AST lint", LINT_RULES),
                            ("jaxpr checker", JAXPR_RULES)):
            print(f"# {name}")
            for rid, desc in rules.items():
                print(f"  {rid}: {desc}")
        return 0

    t0 = time.perf_counter()
    paths = args.paths or [p for p in DEFAULT_PATHS if os.path.exists(p)]
    violations = run_paths(paths)
    if args.update_baseline:
        write_baseline(violations, args.baseline)
        print(f"wrote {len(violations)} finding(s) to {args.baseline}")
        return 0
    if args.no_baseline:
        new, old = violations, []
    else:
        new, old = apply_baseline(violations, args.baseline)

    if args.format == "github":
        # GitHub Actions workflow-command annotations: CI surfaces each
        # finding on its source line in the PR diff
        for v in new:
            fname, _, lineno = v.source.rpartition(":")
            msg = v.format().replace("%", "%25").replace("\n", "%0A")
            print(f"::error file={fname},line={lineno or 1},"
                  f"title=lint {v.rule}::{msg}")
    elif args.format == "text":
        for v in new:
            print(f"{v.source}: {v.format()}")

    n_builtin = 0
    builtin_error = None
    if args.jaxpr_builtins:
        from repro.analysis.jaxpr_check import check_builtins
        try:
            n_builtin = check_builtins()
        except Exception as e:
            builtin_error = str(e)
            if args.format == "text":
                print(f"jaxpr builtin check FAILED:\n{e}")
            elif args.format == "github":
                print("::error title=jaxpr builtin check::"
                      + builtin_error.replace("%", "%25").replace("\n",
                                                                  "%0A"))

    dt = time.perf_counter() - t0
    files = len(list(iter_py_files(paths)))

    if args.format == "json":
        cache: Dict[str, List[str]] = {}

        def entry(v, baselined):
            fname, _, lineno = v.source.rpartition(":")
            return {"rule": v.rule, "file": fname.replace(os.sep, "/"),
                    "line": int(lineno) if lineno.isdigit() else 0,
                    "message": v.message, "baselined": baselined,
                    "fingerprint": _fingerprint(v, cache)}

        doc = {"files": files, "new": len(new), "baselined": len(old),
               "elapsed_s": round(dt, 3),
               "findings": [entry(v, False) for v in new]
               + [entry(v, True) for v in old]}
        if args.jaxpr_builtins:
            doc["jaxpr_builtins"] = {"checked": n_builtin,
                                     "error": builtin_error}
        print(json.dumps(doc, indent=2, sort_keys=True))
    else:
        extra = (f", {n_builtin} builtin fns jaxpr-checked"
                 if n_builtin else "")
        print(f"lint: {files} files, {len(new)} new finding(s), "
              f"{len(old)} baselined{extra} [{dt:.1f}s]")
    return 1 if new or builtin_error is not None else 0


if __name__ == "__main__":
    sys.exit(main())

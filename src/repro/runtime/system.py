"""PerceptaSystem — full wiring of Figure 1, multi-environment.

Deployment modes (paper §III.C): the SAME system object serves
  * edge  — one environment, fully local
  * fog   — a few nearby environments
  * cloud — many isolated environments simultaneously
All environments are rows of the batched device pipeline; isolation is by
construction (per-env queues, per-env state rows, per-env model slots).

Time is virtual (``speedup``) so benchmarks can run days of stream time in
seconds. The Manager logic lives in ``run_window``: close each env's window,
assemble the device batch, run the (fused or modular) Percepta tick, run the
Predictor, forward the decisions, log everything.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import PerceptaPipeline, PipelineConfig
from repro.core.frame import make_raw_window
from repro.runtime.accumulator import Accumulator
from repro.runtime.forwarder import ForwarderHub
from repro.runtime.predictor import Predictor
from repro.runtime.queues import QueueBroker
from repro.runtime.receivers import Receiver, SimulatedDevice
from repro.runtime.translator import Translator


@dataclass
class SourceSpec:
    source_id: str
    protocol: str                 # mqtt | http | amqp
    device: SimulatedDevice
    unit_scale: float = 1.0


class PerceptaSystem:
    def __init__(self, env_ids: Sequence[str], sources: Sequence[SourceSpec],
                 pipeline_cfg: PipelineConfig, predictor: Predictor,
                 forwarders: Optional[ForwarderHub] = None, db=None,
                 mode: str = "fused", speedup: float = 60.0,
                 t0: float = 0.0, manual_time: bool = False):
        # manual_time: the virtual clock only advances when run_windows
        # closes a window — deterministic under arbitrary jit-compile stalls
        # (tests); wall-clock speedup mode is the realistic deployment shape.
        self.manual_time = manual_time
        self._manual_t = t0
        assert pipeline_cfg.n_envs == len(env_ids)
        assert pipeline_cfg.n_streams == len(sources)
        self.env_ids = list(env_ids)
        self.sources = list(sources)
        self.cfg = pipeline_cfg
        self.pipeline = PerceptaPipeline(pipeline_cfg, mode=mode)
        self.state = self.pipeline.init_state()
        self.predictor = predictor
        self.forwarders = forwarders
        self.db = db
        self.speedup = speedup
        self._wall0 = time.time()
        self._t0 = t0
        self.window_s = pipeline_cfg.n_ticks * pipeline_cfg.tick_s
        self.window_index = 0

        self.broker = QueueBroker()
        self.translators = {
            s.source_id: Translator(s.source_id, s.protocol,
                                    unit_scale=s.unit_scale)
            for s in sources
        }
        self.receivers: List[Receiver] = []
        for s in sources:
            r = Receiver(s.source_id, s.protocol, s.device, self.now,
                         speedup=speedup)
            tr = self.translators[s.source_id]
            for env in env_ids:
                def on_payload(env_id, payload, _tr=tr):
                    rec = _tr.translate(env_id, payload)
                    if rec is not None:
                        self.broker.publish(rec)
                r.subscribe(env, on_payload)
            self.receivers.append(r)
        stream_names = [s.device.stream for s in sources]
        self.accumulators = {
            env: Accumulator(env, stream_names, pipeline_cfg.max_samples)
            for env in env_ids
        }
        self.metrics: Dict[str, list] = {"tick_latency_s": [],
                                         "ingest_records": []}

    # --- virtual clock -------------------------------------------------------
    def now(self) -> float:
        if self.manual_time:
            return self._manual_t
        return self._t0 + (time.time() - self._wall0) * self.speedup

    def window_bounds(self):
        start = self._t0 + self.window_index * self.window_s
        return start, start + self.window_s

    # --- threaded operation ---------------------------------------------------
    def start(self):
        for r in self.receivers:
            r.start()

    def stop(self):
        for r in self.receivers:
            r.stop()

    # --- synchronous operation (benchmarks / tests) ---------------------------
    def pump_receivers(self):
        for r in self.receivers:
            r.poll_once()

    def run_window(self) -> dict:
        """Process one closed window across all environments."""
        t_start, t_end = self.window_bounds()
        E, S, M = self.cfg.n_envs, self.cfg.n_streams, self.cfg.max_samples

        n_new = 0
        for env in self.env_ids:
            recs = self.broker.queue_for(env).drain()
            n_new += len(recs)
            self.accumulators[env].ingest(recs)

        values = np.zeros((E, S, M), np.float32)
        ts = np.zeros((E, S, M), np.float32)
        valid = np.zeros((E, S, M), bool)
        for i, env in enumerate(self.env_ids):
            v, t, m = self.accumulators[env].close_window(t_start, t_end)
            values[i], ts[i], valid[i] = v, t, m

        t_proc0 = time.time()
        raw = make_raw_window(values, ts, valid)
        self.state, feats, frame = self.pipeline.run_tick(
            self.state, raw, jnp.full((E,), t_start, jnp.float32))
        actions, rewards, per_term = self.predictor.on_tick(
            feats.features, t_end, raw=feats.raw)
        latency = time.time() - t_proc0

        if self.forwarders is not None:
            for i, env in enumerate(self.env_ids):
                self.forwarders.dispatch(env, t_end, actions[i])
        if self.db is not None:
            obs = np.asarray(feats.features)
            for i, env in enumerate(self.env_ids):
                self.db.append(env, t_end, obs[i], actions[i],
                               float(rewards[i]))

        self.window_index += 1
        self.metrics["tick_latency_s"].append(latency)
        self.metrics["ingest_records"].append(n_new)
        return {
            "window": self.window_index - 1,
            "records": n_new,
            "latency_s": latency,
            "mean_reward": float(np.mean(rewards)),
            "observed_frac": float(np.asarray(frame.observed).mean()),
            "filled_frac": float(np.asarray(frame.filled).mean()),
            "anomalous": int(np.asarray(frame.anomalous).sum()),
        }

    def run_windows(self, n: int, pump: bool = True) -> List[dict]:
        out = []
        for _ in range(n):
            if pump:
                # synchronous mode: advance the virtual clock past the window
                # end, then poll every receiver once
                t_end = self.window_bounds()[1]
                if self.manual_time:
                    self._manual_t = t_end + 1e-3
                else:
                    while self.now() < t_end:
                        time.sleep(0.001)
                self.pump_receivers()
            out.append(self.run_window())
        return out

    def stats(self) -> dict:
        return {
            "queues": self.broker.stats(),
            "receivers": {r.source_id: r.stats for r in self.receivers},
            "translators": {t.source_id: t.stats
                            for t in self.translators.values()},
            "predictor": self.predictor.stats,
        }

"""RWKV-6 "Finch" 1.6B — attention-free, data-dependent decay.
[arXiv:2404.05892]"""
from repro.configs.base import RWKV, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=0,             # attention-free
    n_kv_heads=0,
    d_ff=7168,
    vocab_size=65536,
    layer_pattern=(RWKV,),
    rwkv_head_dim=64,      # 32 time-mix heads
    source="arXiv:2404.05892 (RWKV-6 Finch)",
)

"""Gap filling — detect missing ticks and impute them.

"Percepta is capable of detecting missing data and, when necessary, filling
in the gaps to maintain the continuity and reliability of the input data."

Strategies (selectable per stream):
  locf      last observation carried forward (across window boundaries via
            the carried ``last_value`` state)
  linear    bridge interior gaps linearly between observations (falls back
            to locf at the trailing edge)
  ewma      exponentially-weighted mean of past observations (state-carried)
  seasonal  mean of the same tick-of-day from history (state-carried slots)

The LOCF scan is a prefix "latest-observation" propagation — associative, so
it runs as ``jax.lax.associative_scan`` over the tick dim (O(log T) depth).

``use_pallas=True`` routes the ``locf`` strategy through the Pallas kernel
in ``repro.kernels.locf`` (one VMEM pass with the carry in VREGs on TPU;
interpret mode elsewhere). The kernel is pure selection — no arithmetic —
so its fill values are bit-identical to the XLA paths wherever the ``has``
mask is True, which is the only place ``gap_fill`` consumes them.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

STRATEGIES = ("locf", "linear", "ewma", "seasonal")


class GapFillState(NamedTuple):
    last_value: jax.Array   # (E, S) last observed value ever
    last_ts: jax.Array      # (E, S)
    ewma: jax.Array         # (E, S)
    seasonal: jax.Array     # (E, S, K) per time-of-day slot running mean
    seasonal_n: jax.Array   # (E, S, K)


def init_state(E, S, K=24) -> GapFillState:
    z = jnp.zeros((E, S), jnp.float32)
    return GapFillState(z, z - 1e30, z, jnp.zeros((E, S, K), jnp.float32),
                        jnp.zeros((E, S, K), jnp.float32))


# Below this tick count the O(T^2) masked-argmax propagation replaces the
# associative scan: XLA:CPU lowers associative_scan to log2(T) rounds of
# small strided slice/concat ops whose per-op overhead dominates at edge
# window sizes, while the dense form is two vectorized ops and a dot.
_DENSE_T_MAX = 64


def _locf_scan(values, observed, init_value, init_has):
    """Carry (value, has) of the latest observation along the tick axis.

    Positions with no observation at or before them return (0, False) on
    the dense path and (init_value, False) on the scan path — callers mask
    by the ``has`` flag, so the carried value is only meaningful when True.
    """
    v = jnp.concatenate([init_value[..., None].astype(jnp.float32), values],
                        axis=-1)
    o = jnp.concatenate([init_has[..., None], observed], axis=-1)
    T1 = v.shape[-1]
    if T1 <= _DENSE_T_MAX:
        j = jnp.arange(T1)
        tril = j[:, None] >= j[None, :]                      # (T1, T1)
        key = jnp.where(o[..., None, :] & tril, j, -1)       # (..., T1, T1)
        li = key.max(-1)                                     # latest obs <= t
        oh = (li[..., None] == j).astype(jnp.float32)
        cv = jnp.einsum("...j,...tj->...t", v, oh)
        return cv[..., 1:], (li >= 0)[..., 1:]

    def combine(a, b):
        av, ao = a
        bv, bo = b
        return jnp.where(bo, bv, av), ao | bo

    cv, co = jax.lax.associative_scan(combine, (v, o), axis=-1)
    return cv[..., 1:], co[..., 1:]


def locf(values, observed, state: GapFillState):
    has_prev = state.last_ts > -1e29
    return _locf_scan(values, observed, state.last_value, has_prev)


def linear_bridge(values, observed):
    """Interior gaps -> linear interp between neighbours (edges untouched)."""
    T = values.shape[-1]
    idx = jnp.arange(T, dtype=jnp.float32)
    big = jnp.float32(1e30)
    # distance to previous / next observation via two locf passes
    fwd_v, fwd_has = _locf_scan(values, observed,
                                jnp.zeros(values.shape[:-1]),
                                jnp.zeros(values.shape[:-1], bool))
    fwd_i, _ = _locf_scan(jnp.broadcast_to(idx, values.shape), observed,
                          -jnp.ones(values.shape[:-1]),
                          jnp.zeros(values.shape[:-1], bool))
    rev = lambda x: jnp.flip(x, axis=-1)
    bwd_v, bwd_has = _locf_scan(rev(values), rev(observed),
                                jnp.zeros(values.shape[:-1]),
                                jnp.zeros(values.shape[:-1], bool))
    bwd_i, _ = _locf_scan(jnp.broadcast_to(idx, values.shape), rev(observed),
                          -jnp.ones(values.shape[:-1]),
                          jnp.zeros(values.shape[:-1], bool))
    bwd_v, bwd_has, bwd_i = rev(bwd_v), rev(bwd_has), (T - 1) - rev(bwd_i)
    span = jnp.maximum(bwd_i - fwd_i, 1e-6)
    frac = jnp.clip((idx - fwd_i) / span, 0.0, 1.0)
    interior = fwd_has & bwd_has
    interp = fwd_v + frac * (bwd_v - fwd_v)
    out = jnp.where(observed, values, jnp.where(interior, interp, fwd_v))
    return out, interior | fwd_has


def gap_fill(values, observed, state: GapFillState, tick_ts,
             strategy, *, tick_of_day=None, ewma_alpha: float = 0.2,
             use_pallas: bool = False):
    """Fill unobserved ticks. strategy: (S,) int32 index into STRATEGIES or a
    single string. Returns (filled_values, filled_mask, new_state).

    ``use_pallas`` only affects the string ``"locf"`` strategy (the other
    strategies and the per-stream int-vector form keep the XLA paths)."""
    E, S, T = values.shape
    if tick_of_day is None:
        tick_of_day = jnp.zeros((E, T), jnp.int32)

    # Strategy branches, computed lazily: a static (string) strategy only
    # pays for the branch it selects — the linear bridge alone costs four
    # extra associative scans, which matters inside the scan-fused engine
    # where gap-fill runs once per window on-device.
    def _locf():
        if use_pallas and isinstance(strategy, str) and strategy == "locf":
            from repro.kernels.locf.ops import locf as locf_kernel
            return locf_kernel(values, observed, state.last_value,
                               state.last_ts > -1e29)
        return locf(values, observed, state)

    def _linear():
        locf_v, locf_has = _locf()
        lin_v, lin_has = linear_bridge(values, observed)
        lin_v = jnp.where(observed | lin_has, lin_v, locf_v)
        return lin_v, lin_has | locf_has

    def _ewma():
        ew = state.ewma[..., None]
        ew_v = jnp.where(observed, values,
                         jnp.broadcast_to(ew, values.shape))
        ew_has = jnp.broadcast_to(state.last_ts[..., None] > -1e29,
                                  values.shape)
        return ew_v, ew_has

    def _seasonal():
        K = state.seasonal.shape[-1]
        sea = jnp.take_along_axis(
            state.seasonal, tick_of_day[:, None, :] % K, axis=-1)
        sea_n = jnp.take_along_axis(
            state.seasonal_n, tick_of_day[:, None, :] % K, axis=-1)
        return jnp.where(observed, values, sea), sea_n > 0

    branches = {"locf": _locf, "linear": _linear, "ewma": _ewma,
                "seasonal": _seasonal}
    if isinstance(strategy, str):
        out_v, out_h = branches[strategy]()
    else:
        stack_v, stack_h = map(jnp.stack, zip(*(branches[s]()
                                                for s in STRATEGIES)))
        sel = strategy[None, None, :, None]
        out_v = jnp.take_along_axis(stack_v, sel, axis=0)[0]
        out_h = jnp.take_along_axis(stack_h, sel, axis=0)[0]

    filled = (~observed) & out_h
    out = jnp.where(observed, values, jnp.where(filled, out_v, 0.0))

    # ---- state update (from OBSERVED ticks only) ----------------------------
    any_obs = observed.any(-1)
    big = jnp.float32(3.4e38)
    ts_b = jnp.broadcast_to(tick_ts[:, None, :], values.shape)
    last_key = jnp.where(observed, ts_b, -big)
    is_last = (last_key == last_key.max(-1, keepdims=True)) & observed
    new_last = jnp.einsum("est,est->es", values,
                          is_last.astype(jnp.float32)) / \
        jnp.maximum(is_last.sum(-1), 1)
    new_last_ts = jnp.max(jnp.where(observed, ts_b, -1e30), axis=-1)
    obs_mean = jnp.einsum("est,est->es", values, observed.astype(jnp.float32)) \
        / jnp.maximum(observed.sum(-1), 1)
    sea_mean, sea_n = _seasonal_update(state, values, observed, tick_of_day)
    new_state = GapFillState(
        last_value=jnp.where(any_obs, new_last, state.last_value),
        last_ts=jnp.maximum(state.last_ts, new_last_ts),
        ewma=jnp.where(any_obs,
                       (1 - ewma_alpha) * state.ewma + ewma_alpha * obs_mean,
                       state.ewma),
        seasonal=sea_mean,
        seasonal_n=sea_n,
    )
    return out, filled, new_state


def _seasonal_update(state, values, observed, tick_of_day):
    K = state.seasonal.shape[-1]
    oh = (jax.nn.one_hot(tick_of_day % K, K, dtype=jnp.float32)[:, None])  # (E,1,T,K)
    w = oh * observed[..., None]
    s = jnp.einsum("est,estk->esk", values, w)
    # phrased as a dot: XLA:CPU's strided reduce of (E,S,T,K) over T is
    # ~6x slower than the equivalent contraction (see harmonize._harmonize_dense)
    n = jnp.einsum("est,estk->esk", jnp.ones_like(values), w)
    total_n = state.seasonal_n + n
    mean = jnp.where(total_n > 0,
                     (state.seasonal * state.seasonal_n + s) / jnp.maximum(total_n, 1),
                     state.seasonal)
    return mean, total_n

"""Window aggregation + cross-stream relationships (the Manager's logic).

"It can prioritize the most recent entries, but it can also apply
aggregation logic, such as calculating sums, averages ... the Manager
analyzes the data to identify meaningful relationships within it. For
instance, it may combine temperature readings from sensors of various
brands within the same area to compute a weighted average."

``combine`` implements exactly that: a (features x streams) weight matrix
mapping harmonized per-tick streams to derived features — weighted averages
across same-area sensors, sums across feeders, etc.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

AGGS = ("last", "mean", "sum", "min", "max", "std", "count")

# repro.kernels.window_agg stats-column layout:
# [mean, var, min, max, last, count, sum, n_spikes]
_KERNEL_COLS = {"mean": 0, "min": 2, "max": 3, "last": 4, "count": 5,
                "sum": 6}


def window_agg(values, mask, agg: str, *, use_pallas: bool = False):
    """Aggregate the tick dim away. values/mask: (E, S, T) -> (E, S).

    ``use_pallas=True`` computes every aggregate from one pass of the fused
    ``repro.kernels.window_agg`` kernel (all eight window stats in a single
    VMEM tile walk; interpret mode off-TPU) instead of a per-agg XLA
    reduction; empty windows are fixed up to this module's conventions
    (min/max saturate, the rest are 0).
    """
    w = mask.astype(jnp.float32)
    n = w.sum(-1)
    big = jnp.float32(3.4e38)
    if use_pallas and (agg == "std" or agg in _KERNEL_COLS):
        from repro.kernels.window_agg.ops import window_agg as agg_kernel
        E, S = values.shape[:2]
        zeros = jnp.zeros((E, S), jnp.float32)
        stats, _ = agg_kernel(values, mask, zeros, zeros + 1.0,
                              use_pallas=True)
        if agg == "std":
            return jnp.sqrt(stats[..., 1])
        out = stats[..., _KERNEL_COLS[agg]]
        # the kernel zeroes empty-window min/max; this module saturates
        if agg == "min":
            return jnp.where(n > 0, out, big)
        if agg == "max":
            return jnp.where(n > 0, out, -big)
        return out
    if agg == "last":
        idx = jnp.where(mask, jnp.arange(values.shape[-1]), -1).max(-1)
        take = jnp.take_along_axis(values, jnp.maximum(idx, 0)[..., None], -1)[..., 0]
        return jnp.where(idx >= 0, take, 0.0)
    if agg == "mean":
        return jnp.einsum("est,est->es", values, w) / jnp.maximum(n, 1)
    if agg == "sum":
        return jnp.einsum("est,est->es", values, w)
    if agg == "min":
        return jnp.min(jnp.where(mask, values, big), -1)
    if agg == "max":
        return jnp.max(jnp.where(mask, values, -big), -1)
    if agg == "std":
        m = jnp.einsum("est,est->es", values, w) / jnp.maximum(n, 1)
        v = jnp.einsum("est,est->es", jnp.square(values - m[..., None]), w)
        return jnp.sqrt(v / jnp.maximum(n, 1))
    if agg == "count":
        return n
    raise ValueError(agg)


def combine(values, weights):
    """Cross-stream relationships. values (E,S,T) x weights (F,S) -> (E,F,T).

    Rows of ``weights`` are derived features: a row with 1/k over k
    temperature streams is the paper's weighted-average example; a row of
    ones over feeder streams is a total-consumption sum.
    """
    return jnp.einsum("est,fs->eft", values, weights)


def feature_vector(values, mask, weights, *, per_tick: bool = False,
                   feature_agg: str = "last", use_pallas: bool = False):
    """Full Manager output: derived features flattened for the Encoder.

    values/mask (E,S,T), weights (F,S) ->
      per_tick=False: (E, F) per-window features — the value at the final
        tick position when ``feature_agg="last"`` (the original shape of
        the pipeline output), else each stream's window aggregate
        (:func:`window_agg`, e.g. "mean"/"sum") combined through
        ``weights``; ``use_pallas`` routes that aggregate through the
        fused kernel
      per_tick=True : (E, F*T) the whole harmonized window
    """
    if per_tick:
        feats = combine(values, weights)                 # (E, F, T)
        E = feats.shape[0]
        return feats.reshape(E, -1)
    if feature_agg != "last":
        per_stream = window_agg(values, mask, feature_agg,
                                use_pallas=use_pallas)   # (E, S)
        return jnp.einsum("es,fs->ef", per_stream, weights)
    return combine(values, weights)[..., -1]

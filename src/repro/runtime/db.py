"""Append-only log store — "All input data and model decisions are also
logged in a database, enabling future analysis and potential retraining."

JSONL segments with atomic rotation; env identities are stored anonymized
(salted hash) per the paper's anonymization requirement. A cursor (segment,
offset) is exposed so the training node can consume exactly-once.
"""
from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path
from typing import Iterator, Optional

from repro.core.replay import anonymize_env_ids


class LogDB:
    def __init__(self, root: str, salt: str = "percepta",
                 rotate_bytes: int = 8 * 2**20):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.salt = salt
        self.rotate_bytes = rotate_bytes
        self._lock = threading.Lock()
        self._seg = self._latest_segment()
        self._fh = None
        self.stats = {"rows": 0, "bytes": 0, "segments": 0}

    def _latest_segment(self) -> int:
        segs = sorted(self.root.glob("seg-*.jsonl"))
        return int(segs[-1].stem.split("-")[1]) if segs else 0

    def _open(self):
        if self._fh is None:
            path = self.root / f"seg-{self._seg:06d}.jsonl"
            self._fh = open(path, "a", buffering=1)
            self.stats["segments"] += 1

    def append(self, env_id: str, tick_time: float, obs, action, reward,
               extra: Optional[dict] = None):
        row = {
            "env": anonymize_env_ids([env_id], self.salt)[0],
            "t": float(tick_time),
            "obs": [float(x) for x in obs],
            "action": [float(x) for x in action],
            "reward": float(reward),
            "logged_at": time.time(),
        }
        if extra:
            row.update(extra)
        line = json.dumps(row)
        with self._lock:
            self._open()
            self._fh.write(line + "\n")
            self.stats["rows"] += 1
            self.stats["bytes"] += len(line) + 1
            if self._fh.tell() > self.rotate_bytes:
                self._fh.close()
                self._fh = None
                self._seg += 1

    def read_from(self, segment: int = 0, offset: int = 0) -> Iterator[tuple]:
        """Yield (cursor, row) from the given cursor for retraining export."""
        for path in sorted(self.root.glob("seg-*.jsonl")):
            seg = int(path.stem.split("-")[1])
            if seg < segment:
                continue
            with open(path) as fh:
                for i, line in enumerate(fh):
                    if seg == segment and i < offset:
                        continue
                    yield (seg, i + 1), json.loads(line)

    def close(self):
        with self._lock:
            if self._fh:
                self._fh.close()
                self._fh = None

"""Elastic env membership (``elastic=True``): masked slot pools.

The env axis becomes a padded slot pool: ``env_slots`` rows, an
``active`` mask riding the carry, attach/detach at batch boundaries with
NO retrace, and :meth:`PerceptaSystem.resize` pool regrowth (the one
allowed retrace). The testable contract, in order of strength:

* a live env's rows are BIT-IDENTICAL to a dense fixed-E system over the
  same envs — not close, identical (the mask combines by fenced select
  only; ``core.pipeline.mask_env_rows`` documents why the fences matter);
* membership churn (detach, reattach into a recycled slot, regrow) never
  perturbs the rows of envs that stayed attached;
* regrowth across a real mesh-split boundary (4 -> 8 slots on 8 forced
  host devices) resumes surviving rows bit-exactly.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import PipelineConfig
from repro.core.reward import energy_reward_spec
from repro.runtime.predictor import ActionSpace, Predictor, linear_policy
from repro.runtime.receivers import SimulatedDevice
from repro.runtime.system import PerceptaSystem, SourceSpec

# every engine the elastic refactor touches that runs in-process (the
# sharded modes degenerate to a 1-device mesh here; the real 8-device
# mesh is the subprocess test at the bottom)
ELASTIC_MODES = ("scan", "scan_sharded", "scan_async", "scan_fused_decide",
                 "scan_fused_decide_sharded", "scan_fused_decide_async")

STABLE = ["s0", "s1", "s2"]      # attached at construction, never touched


def _mk(env_ids, slots=None, elastic=False, mode="scan", scan_k=3, cap=16):
    # off-tick reading intervals (9.7 / 31.3 s): no reading ever lands
    # exactly on a window boundary, so window membership can't flip on a
    # float comparison between runs
    srcs = [SourceSpec("grid_kw", "mqtt",
                       SimulatedDevice("grid", 9.7, base=3.0, seed=1)),
            SourceSpec("price_eur", "http",
                       SimulatedDevice("price", 31.3, base=0.2, seed=2))]
    n = slots if slots is not None else len(env_ids)
    cfg = PipelineConfig(n_envs=n, n_streams=2, n_ticks=8, tick_s=60.0,
                         max_samples=32)
    pred = Predictor(linear_policy(cfg.n_features, 2),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     n, cfg.n_features, replay_capacity=cap)
    return PerceptaSystem(list(env_ids), srcs, cfg, pred, speedup=5000.0,
                          manual_time=True, mode=mode, scan_k=scan_k,
                          env_slots=slots, elastic=elastic)


def _strip(results):
    return [{k: v for k, v in r.items() if k != "latency_s"}
            for r in results]


def _assert_rows_equal(dense_export, elastic_export):
    """Every env of the dense export has bit-identical replay rows in the
    elastic one. Exports pseudonymize env ids, but both sides use the same
    salt, so a shared env carries the same exported id — rows join on it
    (the elastic extra rows are churned tenants and free-slot
    placeholders, not part of the dense reference)."""
    ea = {e: i for i, e in enumerate(elastic_export["env_ids"])}
    for i, env in enumerate(dense_export["env_ids"]):
        assert env in ea, env
        j = ea[env]
        for k in ("obs", "actions", "rewards", "next_obs", "tick_idx",
                  "times", "valid"):
            a = np.asarray(dense_export[k])[i]
            b = np.asarray(elastic_export[k])[j]
            assert (a == b).all(), (env, k)


# --------------------------------------------------------------------------
# Static subset: live rows of a part-full pool == a dense fixed-E system
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ELASTIC_MODES)
def test_elastic_static_subset_matches_dense(mode):
    """3 live envs in a 4-slot pool vs a dense E=3 ``scan`` reference: the
    per-window results AND the banked replay rows are bit-identical (16
    windows over scan_k=3 — full batches + a ragged tail)."""
    dense = _mk(STABLE)
    el = _mk(STABLE, slots=4, elastic=True, mode=mode)
    rd, re_ = dense.run_windows(16), el.run_windows(16)
    assert _strip(rd) == _strip(re_)
    ed, ee = dense.export_replay("s"), el.export_replay("s")
    # elastic exports at the full pool width: the 3 live rows join the
    # dense rows by exported id, the free 4th slot never banked anything
    assert ee["env_ids"][:3] == ed["env_ids"] and len(ee["env_ids"]) == 4
    _assert_rows_equal(ed, ee)
    assert not np.asarray(ee["valid"])[3].any()
    dense.stop(), el.stop()


# --------------------------------------------------------------------------
# Membership plumbing: guards, slot recycling, fresh rows on reattach
# --------------------------------------------------------------------------

def test_membership_guards():
    el = _mk(STABLE, slots=4, elastic=True, mode="scan_fused_decide")
    with pytest.raises(AssertionError, match="already attached"):
        el.attach_env("s0")
    with pytest.raises(AssertionError, match="not attached"):
        el.detach_env("ghost")
    el.stop()
    dense = _mk(STABLE)
    with pytest.raises(AssertionError, match="elastic=True"):
        dense.attach_env("s3")
    dense.stop()
    with pytest.raises(ValueError, match="scan engine"):
        _mk(STABLE, slots=4, elastic=True, mode="fused")


def test_detach_reattach_recycles_slot_with_fresh_rows():
    """Detach then reattach the same env: it returns to the SAME slot, its
    old transitions are scrubbed (a later tenant never sees them), and the
    reattached env re-banks from a fresh prev chain — exactly
    ``scan_k - 1`` transitions after one post-reattach batch."""
    el = _mk(STABLE, slots=4, elastic=True, mode="scan_fused_decide",
             scan_k=3, cap=64)
    el.run_windows(6)
    freed = el.detach_env("s1")
    assert el.env_ids == ["s0", "s2"]
    el.run_windows(3)
    got = el.attach_env("s1")
    assert got == freed                   # lowest free slot is recycled
    assert el.env_ids == STABLE
    el.run_windows(3)
    valid = np.asarray(el.export_replay("s")["valid"])
    # slots are positional: s0/s1/s2 took slots 0/1/2 at construction and
    # s1 came back to its recycled slot 1. s1 was scrubbed on detach, then
    # one 3-window batch with no predecessor for window 0 -> 2 banked
    # rows; s0/s2 banked through all 12 windows
    assert valid[1].sum() == 2
    assert valid[0].sum() == 11 and valid[2].sum() == 11
    el.stop()


def test_attach_env_grows_full_pool():
    """Attaching into a full pool regrows it (4 -> 8 slots) and the new
    env lands in the first slot of the padding."""
    el = _mk(STABLE + ["c0"], slots=4, elastic=True, mode="scan_fused_decide")
    el.run_windows(3)
    assert el.env_slots == 4 and not el._free_slots
    slot = el.attach_env("c1")
    assert el.env_slots == 8 and slot == 4
    res = el.run_windows(3)
    assert all(np.isfinite(r["mean_reward"]) for r in res)
    el.stop()


# --------------------------------------------------------------------------
# Property: random churn schedules never perturb the stable envs' rows
# --------------------------------------------------------------------------

# per-boundary ops; invalid draws degrade to no-ops so every schedule runs
OP_NONE, OP_ATTACH, OP_DETACH, OP_RECYCLE, OP_RESIZE = range(5)


def _run_schedule(ops, mode):
    """Apply attach/detach/reattach-into-recycled-slot/regrow ops between
    K=6 window batches, then assert the stable envs' replay rows are
    bit-identical to a dense fixed-E system that never churned. Replay
    capacity 4 against K=6 exercises ring wraparound under a partial mask
    on every batch (5 banked rows > 4 slots)."""
    K = 6
    el = _mk(STABLE, slots=4, elastic=True, mode=mode, scan_k=K, cap=4)
    churn, next_c = [], 0
    total = K                              # leading batch before any churn
    el.run_windows(K)
    for op in ops:
        if op == OP_ATTACH and next_c < 4:
            churn.append(f"c{next_c}")
            el.attach_env(churn[-1])       # regrows by itself when full
            next_c += 1
        elif op == OP_DETACH and churn:
            el.detach_env(churn.pop(0))
        elif op == OP_RECYCLE and churn:
            freed = el.detach_env(churn[0])
            assert el.attach_env(churn[0]) == freed
        elif op == OP_RESIZE and el.env_slots < 16:
            el.resize()
        el.run_windows(K)
        total += K
    dense = _mk(STABLE, scan_k=K, cap=4)
    dense.run_windows(total)
    _assert_rows_equal(dense.export_replay("s"), el.export_replay("s"))
    dense.stop(), el.stop()


@pytest.mark.parametrize("ops", [
    (OP_ATTACH, OP_RECYCLE, OP_DETACH),    # fill, recycle a slot, free it
    (OP_ATTACH, OP_ATTACH, OP_ATTACH),     # 3rd attach fills -> auto-regrow
    (OP_RESIZE, OP_ATTACH, OP_RECYCLE),    # explicit regrow, churn after
])
@pytest.mark.parametrize("mode", ("scan", "scan_fused_decide"))
def test_elastic_churn_schedules_match_dense(ops, mode):
    """Deterministic anchor schedules for :func:`_run_schedule` — always
    run, even where hypothesis is unavailable."""
    _run_schedule(ops, mode)


# property test: random schedules. repro.testing hands out real hypothesis
# when installed and a deterministic drop-in otherwise, so this runs (never
# skips) in every environment.
from repro.testing import given, settings, st  # noqa: E402


@given(ops=st.lists(st.integers(OP_NONE, OP_RESIZE),
                    min_size=2, max_size=3),
       mode=st.sampled_from(("scan", "scan_fused_decide")))
@settings(max_examples=8, deadline=None)
def test_elastic_random_schedule_matches_dense(ops, mode):
    """Random schedules over the same op alphabet as the anchors."""
    _run_schedule(tuple(ops), mode)


# --------------------------------------------------------------------------
# Real 8-device mesh: pool growth crosses a mesh-split boundary
# --------------------------------------------------------------------------

_MESH_GROW_SCRIPT = """
import numpy as np
from repro.core import PipelineConfig
from repro.core.reward import energy_reward_spec
from repro.runtime.predictor import ActionSpace, Predictor, linear_policy
from repro.runtime.receivers import SimulatedDevice
from repro.runtime.system import PerceptaSystem, SourceSpec
import jax
assert len(jax.devices()) == 8, jax.devices()

def mk(env_ids, slots=None, elastic=False, mode="scan"):
    srcs = [SourceSpec("grid_kw", "mqtt",
                       SimulatedDevice("grid", 9.7, base=3.0, seed=1)),
            SourceSpec("price_eur", "http",
                       SimulatedDevice("price", 31.3, base=0.2, seed=2))]
    n = slots if slots is not None else len(env_ids)
    cfg = PipelineConfig(n_envs=n, n_streams=2, n_ticks=8, tick_s=60.0,
                         max_samples=32)
    pred = Predictor(linear_policy(cfg.n_features, 2),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     n, cfg.n_features, replay_capacity=64)
    return PerceptaSystem(list(env_ids), srcs, cfg, pred, speedup=5000.0,
                          manual_time=True, mode=mode, scan_k=3,
                          env_slots=slots, elastic=elastic)

stable = ["s0", "s1", "s2"]
el = mk(stable, slots=4, elastic=True, mode="scan_fused_decide_sharded")
assert dict(el.pipeline.mesh.shape) == {"data": 4}, el.pipeline.mesh
el.run_windows(6)
el.resize()                                # 4 -> 8: mesh splits 4 -> 8 ways
assert el.env_slots == 8
assert dict(el.pipeline.mesh.shape) == {"data": 8}, el.pipeline.mesh
el.attach_env("c0")                        # new tenant in the padding
el.run_windows(6)

dense = mk(stable)
dense.run_windows(12)
ed, ee = dense.export_replay("s"), el.export_replay("s")
ea = {e: i for i, e in enumerate(ee["env_ids"])}
for i, env in enumerate(ed["env_ids"]):      # exported ids join the rows
    for k in ("obs", "actions", "rewards", "next_obs", "tick_idx", "times",
              "valid"):
        a = np.asarray(ed[k])[i]
        b = np.asarray(ee[k])[ea[env]]
        assert (a == b).all(), (env, k)
dense.stop(), el.stop()
print("ELASTIC_MESH_GROW_OK")
"""


def test_elastic_regrow_across_mesh_split_boundary():
    """Forced 8-host-device CPU mesh: an elastic fused-sharded system
    regrows 4 -> 8 slots — the env mesh re-splits from 4 to 8 devices —
    and the three surviving envs' rows stay bit-identical to a dense,
    never-resized, single-device reference."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run([sys.executable, "-c", _MESH_GROW_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ELASTIC_MESH_GROW_OK" in out.stdout

"""Standardized record format + simulated source payload encodings.

Receivers produce raw protocol payloads; Translators parse them into
:class:`Record`s — the "standardized format" flowing to the env queues.

:class:`RecordBatch` is the columnar (structure-of-arrays) form of the same
standardized data: NumPy value/timestamp/stream-index columns plus a
stream-name table. It is what the fast ingest path moves through receivers,
queues, and the Accumulator — one Python object per poll instead of one per
reading, so batch assembly is O(records) vectorized NumPy with no
Python-level inner loop. A batch is exactly equivalent to the Record list
``to_records()`` returns (and the Accumulator treats them identically).
"""
from __future__ import annotations

import json
import struct
from dataclasses import dataclass
from typing import Iterable, List, Sequence

import numpy as np


@dataclass(frozen=True)
class Record:
    env_id: str
    stream: str
    timestamp: float
    value: float


@dataclass(frozen=True)
class RecordBatch:
    """Columnar records for ONE environment (SoA mirror of ``List[Record]``).

    ``stream_ids`` indexes into the ``streams`` name table; ``timestamps``
    and ``values`` stay float64 so bucketing/sorting compares exactly like
    ``Record``'s Python floats (the float32 cast happens once, at window
    close, same as the per-record path). Row order is arrival order — the
    Accumulator's stable sorts rely on it for tie-breaking parity with the
    Record-list path.

    ``sorted_ts`` is the producer's sortedness promise: ``True`` means each
    stream's timestamp subsequence is non-decreasing (for a single-stream
    batch, simply that ``timestamps`` is non-decreasing), which lets the
    Accumulator's sorted-merge close skip its O(n) verification pass.
    ``None`` (default) means unknown — consumers verify cheaply on append.
    It must only be set ``True`` when actually true; ``False``/``None`` are
    always safe. Receivers compute it per poll; queue truncation preserves
    it (a prefix of a sorted column is sorted).
    """
    env_id: str
    streams: tuple                # stream-name table, indexed by stream_ids
    stream_ids: np.ndarray        # (N,) int32
    timestamps: np.ndarray        # (N,) float64
    values: np.ndarray            # (N,) float64
    sorted_ts: "bool | None" = None

    def __len__(self) -> int:
        return int(self.values.shape[0])

    @staticmethod
    def from_columns(env_id: str, stream: str, timestamps, values,
                     sorted_ts: "bool | None" = None) -> "RecordBatch":
        """Single-stream batch (one Receiver poll of one device)."""
        ts = np.asarray(timestamps, np.float64).ravel()
        vs = np.asarray(values, np.float64).ravel()
        assert ts.shape == vs.shape
        return RecordBatch(env_id, (stream,),
                           np.zeros(ts.shape[0], np.int32), ts, vs,
                           sorted_ts)

    @staticmethod
    def from_records(records: Sequence[Record]) -> "RecordBatch":
        """Pack a homogeneous-env Record list (arrival order preserved)."""
        assert records, "empty record list"
        env_id = records[0].env_id
        table: dict = {}
        ids = np.empty(len(records), np.int32)
        ts = np.empty(len(records), np.float64)
        vs = np.empty(len(records), np.float64)
        for i, r in enumerate(records):
            assert r.env_id == env_id, "RecordBatch rows share one env"
            ids[i] = table.setdefault(r.stream, len(table))
            ts[i] = r.timestamp
            vs[i] = r.value
        return RecordBatch(env_id, tuple(table), ids, ts, vs)

    def to_records(self) -> List[Record]:
        return [Record(self.env_id, self.streams[int(s)], float(t), float(v))
                for s, t, v in zip(self.stream_ids, self.timestamps,
                                   self.values)]


def count_records(items: Iterable) -> int:
    """Number of records in a drained mix of Records and RecordBatches."""
    return sum(len(it) if isinstance(it, RecordBatch) else 1 for it in items)


# --- simulated wire formats (one per protocol family) -----------------------

def encode_mqtt_json(stream: str, ts: float, value: float) -> bytes:
    return json.dumps({"sensor": stream, "t": ts, "v": value}).encode()


def decode_mqtt_json(payload: bytes):
    d = json.loads(payload.decode())
    return d["sensor"], float(d["t"]), float(d["v"])


def encode_http_csv(stream: str, ts: float, value: float) -> bytes:
    return f"{stream},{ts:.3f},{value:.6f}".encode()


def decode_http_csv(payload: bytes):
    s, t, v = payload.decode().split(",")
    return s, float(t), float(v)


def encode_amqp_binary(stream: str, ts: float, value: float) -> bytes:
    name = stream.encode()[:32].ljust(32, b"\0")
    return name + struct.pack("<dd", ts, value)


def decode_amqp_binary(payload: bytes):
    name = payload[:32].rstrip(b"\0").decode()
    ts, v = struct.unpack("<dd", payload[32:48])
    return name, ts, v


CODECS = {
    "mqtt": (encode_mqtt_json, decode_mqtt_json),
    "http": (encode_http_csv, decode_http_csv),
    "amqp": (encode_amqp_binary, decode_amqp_binary),
}

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real step function (train_step for train_4k,
prefill for prefill_32k, decode_step for decode_32k / long_500k) against
ShapeDtypeStruct inputs on the production mesh, compiles it, checks
memory_analysis() fits v5e HBM, extracts the three roofline terms, and caches
everything to experiments/dryrun/<cell>.json (resumable; EXPERIMENTS.md tables
are generated from these files).

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --arch all --multi-pod both      # full sweep
  python -m repro.launch.dryrun ... --set seq_parallel=false --tag sp_off
Cells are compiled in subprocesses (one per cell) so a 62-layer compile can't
poison the sweep and memory is returned between cells.
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def cell_id(arch: str, shape: str, multi_pod: bool, tag: str = "") -> str:
    mesh = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = f"__{tag}" if tag else ""
    return f"{arch}__{shape}__{mesh}{suffix}".replace("/", "_")


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: list, tag: str) -> dict:
    import jax

    from repro.configs.base import SHAPES, ShardingConfig, apply_overrides
    from repro.configs.registry import get_config
    from repro.launch import roofline
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_step

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    perf = apply_overrides(ShardingConfig(), overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size

    # gradient accumulation for the big models: bounds activation temps and
    # engages the ZeRO-sharded f32 grad accumulator (see steps.build_train_step)
    from repro.configs.base import TrainConfig
    nmicro = 4 if cfg.param_count() > 1.2e10 else 1
    nmicro = int(os.environ.get("REPRO_MICRO", nmicro))
    tcfg = TrainConfig(microbatches=nmicro)

    t0 = time.time()
    fn, specs, shardings, model = build_step(shape.kind, cfg, shape, mesh,
                                             perf, tcfg)
    from repro import compat
    with compat.set_mesh(mesh):
        lowered = fn.lower(*specs)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    cell = roofline.terms_from_compiled(compiled, n_dev)
    mf = roofline.model_flops(cfg, shape)
    cell.update({
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "tag": tag,
        "kind": shape.kind,
        "overrides": list(overrides),
        "n_params": model.param_count(),
        "n_params_active": cfg.active_param_count(),
        "model_flops": mf,
        "model_flops_per_dev": mf / n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    })
    cell["useful_flops_ratio"] = (
        cell["model_flops_per_dev"] / cell["hlo_flops_per_dev"]
        if cell["hlo_flops_per_dev"] else 0.0)
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: cell[k])
    cell["dominant"] = dom[:-2]
    # ideal step time: compute-ideal for train/prefill; decode additionally
    # must stream (params + KV cache) through HBM once per token
    ideal = cell["model_flops_per_dev"] / roofline.PEAK_FLOPS
    if shape.kind == "decode":
        import repro.models.param as Pm
        pbytes = Pm.bytes_of(model.param_defs())
        cbytes = Pm.bytes_of(model.cache_defs(shape.global_batch, shape.seq_len))
        ideal = max(ideal, (pbytes + cbytes) / n_dev / roofline.HBM_BW)
        cell["min_traffic_bytes_per_dev"] = (pbytes + cbytes) / n_dev
    cell["ideal_s"] = ideal
    cell["microbatches"] = nmicro if shape.kind == "train" else 1
    cell["roofline_fraction"] = ideal / cell[dom] if cell[dom] > 0 else 0.0
    return cell


def sweep(args) -> int:
    """Spawn one subprocess per cell; cache results; return #failures."""
    from repro.configs.base import shapes_for, skipped_shapes_for
    from repro.configs.registry import ARCH_IDS, get_config

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = shapes_for(cfg) if args.shape == "all" else {args.shape: None}
        if args.shape == "all":
            for sname in skipped_shapes_for(cfg):
                path = OUT_DIR / f"{cell_id(arch, sname, False, args.tag)}.json"
                if not path.exists():
                    path.write_text(json.dumps({
                        "arch": arch, "shape": sname, "skipped": True,
                        "reason": "long_500k requires sub-quadratic attention; "
                                  "arch has full-attention layers (DESIGN.md)"},
                        indent=1))
        for sname in shapes:
            for mp in pods:
                cid = cell_id(arch, sname, mp, args.tag)
                path = OUT_DIR / f"{cid}.json"
                if path.exists() and not args.force:
                    print(f"[skip cached] {cid}", flush=True)
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", sname,
                       "--multi-pod", "multi" if mp else "single",
                       "--tag", args.tag, "--one-cell"]
                for ov in args.set or []:
                    cmd += ["--set", ov]
                if args.force:
                    cmd += ["--force"]
                print(f"[compile] {cid} ...", flush=True)
                t0 = time.time()
                r = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=args.timeout)
                dt = time.time() - t0
                if r.returncode != 0:
                    failures += 1
                    err = (r.stderr or r.stdout).strip().splitlines()
                    print(f"[FAIL {dt:.0f}s] {cid}\n  " + "\n  ".join(err[-18:]),
                          flush=True)
                    (OUT_DIR / f"{cid}.FAILED").write_text(
                        r.stderr[-20000:] if r.stderr else r.stdout[-20000:])
                else:
                    print(f"[ok {dt:.0f}s] {cid}", flush=True)
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--set", action="append", default=[],
                    help="ShardingConfig override key=value (repeatable)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--one-cell", action="store_true",
                    help="run exactly one cell in-process (internal)")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()

    if not args.one_cell:
        n_fail = sweep(args)
        print(f"sweep done, {n_fail} failures")
        sys.exit(1 if n_fail else 0)

    assert args.arch != "all" and args.shape != "all"
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    cid = cell_id(args.arch, args.shape, args.multi_pod == "multi", args.tag)
    path = OUT_DIR / f"{cid}.json"
    if path.exists() and not args.force:
        print(f"cached: {path}")
        return
    try:
        cell = run_cell(args.arch, args.shape, args.multi_pod == "multi",
                        args.set, args.tag)
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    path.write_text(json.dumps(cell, indent=1, default=str))
    from repro.launch import roofline as rl
    print(f"{cid}: {rl.summarize(cell)}")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()

"""Accumulator — provider-agnostic per-environment collection.

"Each environment has its own dedicated Accumulator instance, which listens
to the corresponding queue. Upon receiving a message, it forwards the data
to the environment-specific Manager." Here the Accumulator also performs the
device-batch assembly: records -> padded (streams, max_samples) arrays with
validity masks for the window that just closed.

Storage is columnar: pending records live as (stream_idx, timestamp, value)
NumPy column chunks in arrival order, fed either by legacy ``Record``
objects or by whole :class:`RecordBatch`es (the zero-Python-loop path).
``close_windows`` buckets ALL pending records into the K requested windows
with one stable lexsort + searchsorted + bincount pass — O(records)
vectorized work — while reproducing the per-record reference semantics
bit-for-bit: window k takes the not-yet-taken records with ts < t_end_k in
timestamp order (arrival order breaking ties), overflow beyond
``max_samples`` drops the OLDEST and is counted, records older than
t_start_k still occupy slots but are masked invalid, and records newer than
the last window end stay pending.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.runtime.records import Record, RecordBatch

# one pending chunk = (stream_idx int32, ts float64, value float64) columns
Chunk = Tuple[np.ndarray, np.ndarray, np.ndarray]


class Accumulator:
    def __init__(self, env_id: str, streams: Sequence[str], max_samples: int):
        self.env_id = env_id
        self.streams = list(streams)
        self.stream_index = {s: i for i, s in enumerate(self.streams)}
        self.max_samples = max_samples
        self._chunks: List[Chunk] = []
        self.stats = {"records": 0, "unknown_stream": 0, "overflow": 0}

    # --- ingest ---------------------------------------------------------------
    def ingest(self, items: Sequence):
        """Accept a drained queue mix of ``Record``s and ``RecordBatch``es."""
        sid, ts, vs = [], [], []
        for it in items:
            if isinstance(it, RecordBatch):
                # flush interleaved singles first to preserve arrival order
                if sid:
                    self._push_chunk(np.asarray(sid, np.int32),
                                     np.asarray(ts, np.float64),
                                     np.asarray(vs, np.float64))
                    sid, ts, vs = [], [], []
                self.ingest_batch(it)
                continue
            idx = self.stream_index.get(it.stream)
            if idx is None:
                self.stats["unknown_stream"] += 1
                continue
            sid.append(idx)
            ts.append(it.timestamp)
            vs.append(it.value)
        if sid:
            self._push_chunk(np.asarray(sid, np.int32),
                             np.asarray(ts, np.float64),
                             np.asarray(vs, np.float64))

    def ingest_batch(self, batch: RecordBatch):
        """Columnar ingest: resolve the batch's stream table, drop unknowns."""
        table = np.asarray([self.stream_index.get(s, -1)
                            for s in batch.streams], np.int32)
        sid = table[batch.stream_ids] if len(batch) else \
            np.empty(0, np.int32)
        # float64 columns regardless of how the batch was built, so window
        # bucketing always compares like Record's Python floats
        ts = np.asarray(batch.timestamps, np.float64)
        vs = np.asarray(batch.values, np.float64)
        known = sid >= 0
        n_unknown = int((~known).sum())
        if n_unknown:
            self.stats["unknown_stream"] += n_unknown
            sid, ts, vs = sid[known], ts[known], vs[known]
        self._push_chunk(sid, ts, vs)

    def _push_chunk(self, sid: np.ndarray, ts: np.ndarray, vs: np.ndarray):
        if sid.shape[0]:
            self.stats["records"] += int(sid.shape[0])
            self._chunks.append((sid, ts, vs))

    def reset(self) -> int:
        """Discard pending records (elastic detach); returns the count."""
        n = sum(int(c[0].shape[0]) for c in self._chunks)
        self._chunks = []
        return n

    def _pending(self) -> Chunk:
        if not self._chunks:
            z = np.empty(0)
            return np.empty(0, np.int32), z, z
        if len(self._chunks) > 1:
            self._chunks = [tuple(np.concatenate(cols)
                                  for cols in zip(*self._chunks))]
        return self._chunks[0]

    # --- window close ---------------------------------------------------------
    def close_window(self, t_start: float, t_end: float, rebase: bool = False):
        """Build the padded raw-window arrays for [t_start, t_end) and retain
        newer records for later windows."""
        v, ts, m = self.close_windows([(t_start, t_end)], rebase=rebase)
        return v[0], ts[0], m[0]

    def close_windows(self, bounds, rebase: bool = False):
        """Close K consecutive windows into stacked (K, S, M) arrays.

        ``bounds`` is a chronologically ordered sequence of (t_start, t_end)
        pairs; records newer than the last window end stay pending. One
        vectorized pass buckets every pending record into its window
        (``searchsorted`` over the window ends — the first window whose end
        exceeds the record's timestamp, i.e. exactly the per-window
        "take everything with ts < t_end" of the reference loop), orders
        each (window, stream) group by timestamp with a stable lexsort
        (arrival order on ties), trims overflow from the oldest side, and
        scatters values/timestamps/validity in one shot.

        ``rebase=True`` emits WINDOW-RELATIVE timestamps: each record's ts
        has its window's ``t_start`` subtracted in float64 *before* the
        float32 cast, so sub-second deltas stay exact on arbitrarily long
        horizons (absolute float32 seconds quantize to >=1s past t~2^24,
        ~194 days of stream time — minutes of wall time at high speedup).
        This is the device-staging form the scan/fused system modes consume
        (the pipeline receives ``window_start = 0``); all bucketing /
        ordering / validity decisions are made on the float64 absolute
        columns either way, so ``rebase`` changes only the emitted frame.
        """
        K, S, M = len(bounds), len(self.streams), self.max_samples
        values = np.zeros((K, S, M), np.float32)
        ts_out = np.zeros((K, S, M), np.float32)
        valid = np.zeros((K, S, M), bool)

        sid, ts, vs = self._pending()
        if not sid.shape[0]:
            return values, ts_out, valid
        starts = np.asarray([b[0] for b in bounds], np.float64)
        ends = np.asarray([b[1] for b in bounds], np.float64)

        # window index: first k with ts < ends[k]; >= K stays pending
        bucket = np.searchsorted(ends, ts, side="right")
        taken = bucket < K
        self._chunks = [] if taken.all() else \
            [(sid[~taken], ts[~taken], vs[~taken])]
        sid, ts, vs, bucket = sid[taken], ts[taken], vs[taken], bucket[taken]
        if not sid.shape[0]:
            return values, ts_out, valid

        # stable sort by (window, stream, ts) — ties keep arrival order,
        # matching the reference's stable per-stream list sort
        group = bucket.astype(np.int64) * S + sid
        order = np.lexsort((ts, group))
        group = group[order]
        sid, ts, vs, bucket = sid[order], ts[order], vs[order], bucket[order]

        cnt = np.bincount(group, minlength=K * S)
        first = cnt.cumsum() - cnt                     # group start offsets
        pos = np.arange(group.shape[0]) - first[group]
        drop = np.maximum(cnt - M, 0)                  # overflow: drop oldest
        self.stats["overflow"] += int(drop.sum())
        keep = pos >= drop[group]
        slot = (pos - drop[group])[keep]
        kb, sb, tk, vk = bucket[keep], sid[keep], ts[keep], vs[keep]
        values[kb, sb, slot] = vk.astype(np.float32)
        tk_out = tk - starts[kb] if rebase else tk       # float64 subtract
        ts_out[kb, sb, slot] = tk_out.astype(np.float32)
        valid[kb, sb, slot] = tk >= starts[kb]
        return values, ts_out, valid

"""RecurrentGemma-2B (Griffin) — RG-LRU + local attention, 2 recurrent : 1
attention. [arXiv:2402.19427; hf]"""
from repro.configs.base import ATTN_LOCAL, RGLRU, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,          # MQA on the attention layers
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    # Griffin block pattern: (recurrent, recurrent, local attention)
    layer_pattern=(RGLRU, RGLRU, ATTN_LOCAL),
    local_window=2048,
    lru_width=2560,
    conv_width=4,
    tie_embeddings=True,
    rope_theta=10000.0,
    source="arXiv:2402.19427; hf:google/recurrentgemma-2b",
)

"""Per-environment internal queues (the RabbitMQ stand-in).

One queue per environment keeps environments isolated ("these environments
operate independently, do not interfere with each other").

Queue items are :class:`Record`s or columnar :class:`RecordBatch`es — the
stats count *records* either way, so one enqueued 500-row batch reads as
500 in ``enqueued``/``dequeued``, exactly like 500 individual puts.

Backpressure is RECORD-based too: ``maxsize`` bounds the number of buffered
*records*, not Python objects. The item-counting bound this replaces let a
columnar deployment buffer 100k RecordBatches — tens of millions of records
— before ever reporting Full, defeating the QoS-0 memory bound the Record
path enforces. A batch that does not fully fit is truncated: the prefix
that fits is enqueued (as a sliced RecordBatch) and the overflow rows are
counted in ``dropped`` — exactly the records the per-Record path would have
accepted and dropped, so the two ingest paths stay stats-identical under
overflow.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Dict, Union

from repro.runtime.records import Record, RecordBatch

Item = Union[Record, RecordBatch]


def _n(item: Item) -> int:
    return len(item) if isinstance(item, RecordBatch) else 1


def _head(batch: RecordBatch, n: int) -> RecordBatch:
    """First ``n`` rows of a batch (arrival order preserved).

    The sortedness promise carries over: a prefix of a per-stream
    time-sorted batch is still per-stream time-sorted."""
    return RecordBatch(batch.env_id, batch.streams, batch.stream_ids[:n],
                       batch.timestamps[:n], batch.values[:n],
                       batch.sorted_ts)


class EnvQueue:
    """Thread-safe bounded queue; ``maxsize`` counts records."""

    def __init__(self, env_id: str, maxsize: int = 100_000):
        self.env_id = env_id
        self.maxsize = maxsize
        self._items: deque = deque()
        self._records = 0              # records currently buffered
        self._lock = threading.Lock()
        self.stats = {"enqueued": 0, "dropped": 0, "dequeued": 0}

    def put(self, item: Item) -> bool:
        """Enqueue; returns False when any record was dropped (QoS 0)."""
        n = _n(item)
        with self._lock:
            free = self.maxsize - self._records
            if n <= free:
                self._items.append(item)
                self._records += n
                self.stats["enqueued"] += n
                return True
            # overflow: accept the prefix that fits (record-path parity —
            # per-record puts would accept exactly `free` then drop), drop
            # the rest
            if free > 0 and isinstance(item, RecordBatch):
                self._items.append(_head(item, free))
                self._records += free
                self.stats["enqueued"] += free
            else:
                free = 0
            self.stats["dropped"] += n - free
            return False

    def drain(self, max_items: int = 1_000_000):
        out = []
        with self._lock:
            while self._items and len(out) < max_items:
                it = self._items.popleft()
                self._records -= _n(it)
                out.append(it)
            self.stats["dequeued"] += sum(_n(it) for it in out)
        return out

    def qsize(self):
        """Buffered ITEM count (see ``record_depth`` for the record count)."""
        return len(self._items)

    def record_depth(self):
        return self._records


class QueueBroker:
    """Routes records to environment queues; creates them on demand.

    ``maxsize`` is the per-env RECORD capacity handed to every queue this
    broker creates (the QoS-0 bound)."""

    def __init__(self, maxsize: int = 100_000):
        self.maxsize = maxsize
        self._queues: Dict[str, EnvQueue] = {}
        self._lock = threading.Lock()

    def queue_for(self, env_id: str) -> EnvQueue:
        with self._lock:
            if env_id not in self._queues:
                self._queues[env_id] = EnvQueue(env_id,
                                                maxsize=self.maxsize)
            return self._queues[env_id]

    def publish(self, item: Item):
        self.queue_for(item.env_id).put(item)

    def remove(self, env_id: str) -> int:
        """Drop an env's queue (elastic detach); returns discarded records."""
        with self._lock:
            q = self._queues.pop(env_id, None)
        return q.record_depth() if q is not None else 0

    def stats(self):
        # depth stays in records (enqueued - dequeued holds because both
        # count records); depth_items is the raw queue length, which is
        # smaller whenever multi-row RecordBatches are in flight
        return {e: q.stats | {"depth": q.record_depth(),
                              "depth_items": q.qsize()}
                for e, q in self._queues.items()}

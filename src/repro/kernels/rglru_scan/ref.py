"""Pure-jnp oracle for the RG-LRU linear-recurrence scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a, b, h0):
    """h_t = a_t * h_{t-1} + b_t. a, b: (B, T, W) f32; h0: (B, W).

    Returns (hs (B, T, W), h_last (B, W)). Plain sequential reference.
    """
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    a_t = a.transpose(1, 0, 2)
    b_t = b.transpose(1, 0, 2)
    h_last, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                              (a_t.astype(jnp.float32), b_t.astype(jnp.float32)))
    return hs.transpose(1, 0, 2), h_last

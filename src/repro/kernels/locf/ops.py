"""Jit'd public wrapper for the LOCF kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.locf.kernel import ROWS_BLK, locf_pallas
from repro.kernels.locf.ref import locf_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def locf(values, observed, init_value, init_has, *, use_pallas: bool = True,
         interpret: bool = True):
    """Batched entry: (E, S, T) + carry (E, S). Returns (filled, has)."""
    E, S, T = values.shape
    v = values.reshape(E * S, T).astype(jnp.float32)
    o = observed.reshape(E * S, T).astype(jnp.float32)
    iv = init_value.reshape(E * S, 1).astype(jnp.float32)
    ih = init_has.reshape(E * S, 1).astype(jnp.float32)
    if not use_pallas:
        out, has = locf_ref(v, o > 0, iv[:, 0], ih[:, 0] > 0)
    else:
        pad = (-v.shape[0]) % ROWS_BLK
        if pad:
            zp = lambda x: jnp.pad(x, ((0, pad), (0, 0)))
            v, o, iv, ih = zp(v), zp(o), zp(iv), zp(ih)
        out, has = locf_pallas(v, o, iv, ih, interpret=interpret)
        if pad:
            out, has = out[:E * S], has[:E * S]
    return out.reshape(E, S, T), has.reshape(E, S, T)

"""Contract/rule registry shared by the jaxpr checker and the AST lint.

Every rule enforced anywhere in :mod:`repro.analysis` is declared here with
a one-line statement of the invariant, so the ROADMAP "Invariant catalog"
section, ``python -m repro.analysis.lint --list-rules`` and the diagnostics
all speak the same names.  Registering a new rule means: add its id +
description to :data:`JAXPR_RULES` or :data:`LINT_RULES`, implement it in
the matching engine (a primitive check in ``jaxpr_check._check_eqn`` /
propagation table, or an AST visitor in ``lint``), add a bad/good fixture
pair to ``tests/test_analysis.py``, and mirror the row in ROADMAP.md.
"""
from __future__ import annotations

from dataclasses import dataclass

# provenance tags the jaxpr checker propagates through the eqn graph
TAG_ENV = "env"        # dimension tag: this axis indexes environments
TAG_TIME = "abs-time"  # value tag: absolute time (seconds since epoch /
                       # exact tick index), quantizes in float32 past ~2^24
TAG_MASK = "env-mask"  # value tag: derived from the elastic active mask;
                       # may gate values (where/select/multiply), never
                       # drive row compaction or index math

# --- jaxpr contract rules (traced-program invariants) -----------------------
JAXPR_RULES = {
    "env-contraction":
        "no dot_general/conv contracts over the env axis — cross-env math "
        "diverges between sharded and unsharded programs",
    "env-gemm-rows":
        "env rows must not feed a dot_general/conv at all: XLA:CPU lowers "
        "(rows, F) gemms through row-count-dependent kernels (1-ulp drift "
        "per shard size) — phrase per-env dots as multiply+reduce over "
        "features (see runtime.predictor.linear_policy)",
    "env-reduce":
        "no reduction (sum/mean/max/argmax/cumsum/sort/top_k) along the env "
        "axis — decision math must be per-env row-wise",
    "collective":
        "no collectives (psum/all_gather/ppermute/axis_index/...) in "
        "shard_map-bound fns — the sharded engines are collective-free by "
        "contract and bit-identical to the unsharded build",
    "time-cast":
        "no float32 (or narrower) cast of an absolute-time value — float32 "
        "absolute seconds/ticks quantize past t~2^24 (the PR 3 collapse); "
        "rebase to window-relative (subtract a time) before narrowing",
    "callback-in-scan":
        "no host callbacks (pure_callback/io_callback/debug.print) inside "
        "scan/while bodies — they hide a host sync in the fused hot loop",
    "reward-shape":
        "custom reward fns return one reward per env row: (E,) for (E, F) "
        "features",
    "carry-env-mix":
        "a recurrent policy carry must keep env row i's state in row i: no "
        "rev/roll/concat/narrowing-slice/gather along an env-tagged axis, "
        "and at the cross-step tag fixed point every carry leaf is either "
        "env-tagged exactly on dim 0 or fully env-free — a carry that mixes "
        "rows crosses shard boundaries without a collective under the "
        "env-sharded fused scan",
    "pallas-env-block":
        "pallas_call operands with an env-tagged dim must block it size-1 "
        "with input and output BlockSpec index maps agreeing on the env "
        "block per grid instance — a kernel instance that reads env block "
        "g but writes env block f(g) moves rows across environments (and "
        "across devices under the env mesh)",
    "env-mask-gate":
        "the elastic active mask combines only multiplicatively or via "
        "select/where (row i's output depends on row i's mask bit alone): "
        "no mask-derived value may feed sort/top_k, a cumulative scan or "
        "argmax/argmin along the env axis, or gather/scatter/dynamic_slice "
        "INDEX operands — compaction/index math changes row placement with "
        "membership and breaks the no-retrace, bit-exact-active-rows "
        "contract",
    "param-replication":
        "policy params are replicated on the env mesh "
        "(sharding.decide_specs): no param leaf may carry an env-sized dim "
        "that scales with E — a builder that bakes per-env weights into "
        "params silently mis-broadcasts under replication",
}

# --- AST lint rules (host-code invariants) ----------------------------------
LINT_RULES = {
    "jax-version-branch":
        "no jax.__version__ branches outside repro/compat.py — every "
        "version seam routes through the compat layer (metadata uses are "
        "fine)",
    "jax-experimental-outside-compat":
        "no jax.experimental imports/attributes outside repro/compat.py "
        "(exception: jax.experimental.pallas, the kernels' only home "
        "across the supported version matrix)",
    "mesh-outside-compat":
        "no direct Mesh/AbstractMesh/make_mesh/set_mesh/use_mesh/shard_map "
        "construction outside repro/compat.py — axis_types/signature churn "
        "is shimmed there (typing references are fine)",
    "donate-outside-compat":
        "no raw jax.jit(..., donate_argnums=...) outside repro/compat.py — "
        "donation routes through compat.jit_donated (de-aliases duplicate "
        "buffers, silences spurious donation warnings, preserves .lower)",
    "state-leaf-alias":
        "host code never aliases system.state leaves (system.state.norm "
        "etc.) — donated carries invalidate old buffers; use the snapshot "
        "accessors (snapshot_norm / export_replay)",
    "async-donate":
        "runtime/ never donates in async modes — a donated input still "
        "being computed blocks the dispatch and serializes the overlap "
        "(donate=True literals and mode tuples naming an async mode flag)",
    "lock-multi-acquire":
        "runtime/ locks are one-acquire-per-call: no with-lock inside a "
        "loop, no nested acquire of the same lock, no call to a sibling "
        "method that re-acquires the held lock (batch first, lock once)",
}


@dataclass(frozen=True)
class Violation:
    """One contract/lint finding (shared shape across both engines)."""
    rule: str
    message: str
    primitive: str = ""   # jaxpr: offending primitive; lint: AST node kind
    source: str = ""      # "file:line" (lint) / traceback summary (jaxpr)
    label: str = ""       # which checked fn / file the finding is in

    def format(self) -> str:
        where = f" at {self.source}" if self.source else ""
        prim = f" [{self.primitive}]" if self.primitive else ""
        return f"[{self.rule}]{prim}{where}: {self.message}"


class ContractViolation(ValueError):
    """Raised when a checked fn breaks a documented invariant.

    Carries the full finding list; the message names every offending
    primitive and source line so the diagnostic is actionable at
    registration time instead of a silent divergence in production.
    """

    def __init__(self, violations, label: str = ""):
        self.violations = list(violations)
        head = (f"{len(self.violations)} contract violation(s)"
                f"{' in ' + label if label else ''} "
                "(see ROADMAP.md 'Invariant catalog'; "
                "repro.analysis docs explain each rule):")
        lines = [head] + ["  " + v.format() for v in self.violations]
        super().__init__("\n".join(lines))

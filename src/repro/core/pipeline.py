"""PerceptaPipeline — the per-tick program: Figure 1 as one tensor program.

Three execution modes (the measured §Perf axis on CPU, same math):
  * ``modular`` — paper-faithful: each module (harmonize, anomaly, gap-fill,
    normalize, aggregate, encode) is its own jitted call with host hops in
    between, exactly the RabbitMQ-separated component chain the paper draws.
  * ``fused``   — the whole tick is ONE jit (and batched across all
    environments), which is the TPU-native re-think: no host hops, XLA fuses
    across module boundaries, one dispatch per tick.
  * ``scan``    — ``run_many``: K pre-batched windows execute as a single
    ``jax.lax.scan`` over the tick function. The state pytree never leaves
    the device between windows (and is donated into the call), so the
    Manager pays ONE Python dispatch per K windows instead of one per
    window — the amortization that makes small-E edge deployments fast.
  * ``scan_sharded`` — the same K-window scan executed under ``shard_map``
    on a one-axis device mesh with the env dimension sharded (envs -> the
    ``data`` axis; see ``distribution.sharding.env_mesh``). Every per-env
    row of the batch, the state pytree, and the stacked outputs lives on
    exactly one device; the math is collective-free, so outputs are
    bit-identical to ``scan``. On a single device the mesh degenerates and
    the mode equals ``scan``; on an N-device pod it runs K windows x E envs
    with E/N env rows per chip. CPU testing recipe:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (must be set
    before JAX initializes; ``benchmarks/run.py --host-devices 8``).
  * ``scan_fused_decide`` — ``run_many_decide``: the SAME K-window scan
    with the decision path fused into the scan body. Each window's
    FeatureFrame flows directly into an injected per-window ``decide``
    step (policy gemm, action validation, reward terms, replay-ring
    write) without ever leaving the device, and the scan carry becomes
    ``(PipelineState, decide carry)`` — one donated pytree, one device
    dispatch per K windows for the WHOLE loop, ingest to banked
    transition. Host transfer shrinks from the stacked (K, E, F) features
    + raw + (K, E, S, T) frames to the small per-window outputs
    (:class:`DecideBatch`: actions, rewards, violation flags and per-env
    observed/filled/anomalous COUNTS — host metrics divide the exact
    integer counts, so the fractions match the reference bit for bit).
    ``scan_fused_decide_sharded`` runs it under ``shard_map`` on the env
    mesh: the decide carry shards on the env dim exactly like the
    pipeline state (scalars — have_prev, tick, ring cursor — replicated),
    closed-over policy weights are replicated, and the decision math is
    per-env row-wise (reward custom fns must be row-wise too), so no
    collectives and bit-identity with the unsharded engine hold just like
    ``scan_sharded``.

All mesh/shard_map spellings route through ``repro.compat`` (JAX 0.4.x ..
0.7 support matrix in ROADMAP.md).

State is a single pytree carried tick-to-tick (gap-fill memory, anomaly
stats, normalizer stats) — checkpointable alongside model params.

Time convention (long-horizon float32 safety): device-visible timestamps
are WINDOW-RELATIVE offsets. The host (``Accumulator.close_windows(...,
rebase=True)``) subtracts each window's start from the raw sample
timestamps in float64 *before* the float32 cast, and the system passes
``window_start = 0`` for every window — so sub-second deltas stay exact no
matter how far the absolute stream clock has advanced (absolute float32
seconds quantize to >=1s past t~2^24). Two pieces of absolute time survive:

  * the seasonal tick-of-day slot is computed with exact integer arithmetic
    from ``state.tick_index`` and the static ``PipelineConfig.tick0``
    offset (windows are consecutive by construction, so the absolute tick
    position is ``tick0 + tick_index * n_ticks``);
  * the ``prev_value``/``prev_ts`` carry is stored in the frame of the
    window that produced it, and each tick re-expresses it in the current
    window's frame by subtracting one window length (again: consecutive
    windows by construction).

Callers that drive ``tick``/``run_many`` directly may still pass absolute
starts with absolute raw timestamps — every in-window comparison is
shift-invariant — but the ``interp_streams`` cross-window bridge and the
seasonal slots assume the consecutive-window convention above.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import aggregate as agg
from repro.core import anomaly as an
from repro.core import gapfill as gf
from repro.core import harmonize as hz
from repro.core import normalize as nz
from repro.core.frame import FeatureFrame, RawWindow, TickFrame


class PipelineState(NamedTuple):
    gapfill: gf.GapFillState
    anomaly: an.AnomalyState
    norm: nz.NormState
    prev_value: jax.Array   # (E, S) carry for cross-window interpolation
    prev_ts: jax.Array
    tick_index: jax.Array   # () int64-ish step counter


@dataclass(frozen=True)
class PipelineConfig:
    n_envs: int
    n_streams: int
    n_ticks: int = 16            # ticks per window
    tick_s: float = 60.0         # model time resolution (e.g. 1 min)
    max_samples: int = 64        # raw samples per stream per window (padded)
    agg: str = "mean"            # harmonization aggregation
    harmonize_method: str = "segment"  # segment (O(M)) | onehot (O(M*T))
    interp_streams: bool = False # use interpolating harmonizer instead
    gap_strategy: str = "locf"   # locf | linear | ewma | seasonal
    anomaly_policy: str = "clip" # clip | mean | missing
    k_sigma: float = 6.0
    seasonal_slots: int = 24
    # cross-stream relationships: rows of (F, S) — defaults to identity
    combine_weights: Optional[tuple] = None
    per_tick_features: bool = False
    # how features summarize the tick dim: "last" keeps the final tick
    # (the original behaviour, exact); any other AGGS name routes through
    # aggregate.window_agg — the paper's Manager "sums, averages" logic
    feature_agg: str = "last"
    # route the locf gap-fill stage and the feature_agg window stats
    # through the Pallas kernels in repro.kernels.{locf,window_agg}
    # (interpret mode off-TPU); False keeps the pure-XLA paths
    use_pallas: bool = False
    # absolute tick position of the stream origin (round(t0 / tick_s)):
    # seasonal tick-of-day slots are computed exactly as
    # (tick0 + tick_index * n_ticks + tick) mod seasonal_slots, so they
    # survive window-relative timestamps and arbitrarily long horizons
    tick0: int = 0

    def weights(self):
        if self.combine_weights is None:
            return jnp.eye(self.n_streams, dtype=jnp.float32)
        return jnp.asarray(self.combine_weights, jnp.float32)

    @property
    def n_features(self):
        w = self.combine_weights
        n = self.n_streams if w is None else len(w)
        return n * (self.n_ticks if self.per_tick_features else 1)


def init_state(cfg: PipelineConfig) -> PipelineState:
    E, S = cfg.n_envs, cfg.n_streams
    return PipelineState(
        gapfill=gf.init_state(E, S, cfg.seasonal_slots),
        anomaly=an.init_state(E, S),
        norm=nz.init_state(E, S),
        prev_value=jnp.zeros((E, S), jnp.float32),
        prev_ts=jnp.full((E, S), -1e30, jnp.float32),
        tick_index=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Stage functions (shared by both modes)
# ---------------------------------------------------------------------------

def stage_harmonize(cfg: PipelineConfig, state, raw: RawWindow, window_start):
    ticks = hz.tick_grid(window_start, cfg.tick_s, cfg.n_ticks)
    if cfg.interp_streams:
        # the carry is stored in the PREVIOUS window's time frame; windows
        # are consecutive, so one window length re-expresses it here
        v, obs = hz.harmonize_interp(
            raw, ticks, prev_value=state.prev_value,
            prev_ts=state.prev_ts - cfg.n_ticks * cfg.tick_s)
    elif cfg.harmonize_method == "segment":
        v, obs = hz.harmonize_segment(raw, ticks, cfg.tick_s, cfg.agg)
    else:
        v, obs = hz.harmonize(raw, ticks, cfg.tick_s, cfg.agg)
    return v, obs, ticks


def stage_anomaly(cfg: PipelineConfig, state, v, obs):
    spikes = an.detect_zscore(v, obs, state.anomaly, cfg.k_sigma)
    v, obs, replaced = an.replace(v, obs, spikes, state.anomaly,
                                  cfg.anomaly_policy, cfg.k_sigma)
    new_anom = an.update_state(state.anomaly, v, obs)
    return v, obs, replaced, new_anom


def stage_gapfill(cfg: PipelineConfig, state, v, obs, ticks):
    # Exact integer tick-of-day. The float form mod((ticks/tick_s), slots)
    # quantizes once absolute float32 ticks pass ~2^24 s and loses the
    # absolute phase entirely under window-relative timestamps. Windows are
    # consecutive, so tick t of the current window sits at absolute tick
    # position tick0 + tick_index*n_ticks + 1 + t; every term is reduced
    # mod seasonal_slots before the multiply so int32 stays exact on any
    # horizon.
    E, T = v.shape[0], v.shape[-1]
    slots = cfg.seasonal_slots
    base = (cfg.tick0 % slots
            + (state.tick_index % slots) * (cfg.n_ticks % slots))
    tod = jnp.mod(base + 1 + jnp.arange(T, dtype=jnp.int32), slots)
    tod = jnp.broadcast_to(tod[None, :], (E, T))
    return gf.gap_fill(v, obs, state.gapfill, ticks, cfg.gap_strategy,
                       tick_of_day=tod, use_pallas=cfg.use_pallas)


def stage_normalize(cfg: PipelineConfig, state, v, obs):
    new_norm = nz.update(state.norm, v, obs)
    return nz.znorm(new_norm, v), new_norm


def stage_features(cfg: PipelineConfig, v_norm, v_raw, obs, filled, ticks):
    mask = obs | filled
    feats = agg.feature_vector(v_norm, mask, cfg.weights(),
                               per_tick=cfg.per_tick_features,
                               feature_agg=cfg.feature_agg,
                               use_pallas=cfg.use_pallas)
    raw = agg.feature_vector(v_raw, mask, cfg.weights(),
                             per_tick=cfg.per_tick_features,
                             feature_agg=cfg.feature_agg,
                             use_pallas=cfg.use_pallas)
    quality = obs.astype(jnp.float32).mean(axis=(1, 2))
    return FeatureFrame(feats, raw, quality, ticks[:, -1])


# ---------------------------------------------------------------------------
# Fused tick
# ---------------------------------------------------------------------------

def tick(cfg: PipelineConfig, state: PipelineState, raw: RawWindow,
         window_start):
    """One full Percepta tick. Returns (new_state, FeatureFrame, TickFrame)."""
    v, obs, ticks = stage_harmonize(cfg, state, raw, window_start)
    v, obs, replaced, new_anom = stage_anomaly(cfg, state, v, obs)
    v, filled, new_gap = stage_gapfill(cfg, state, v, obs, ticks)
    v_norm, new_norm = stage_normalize(cfg, state, v, obs | filled)
    features = stage_features(cfg, v_norm, v, obs, filled, ticks)

    big = jnp.float32(3.4e38)
    ts_b = jnp.where(raw.valid, raw.timestamps, -big).reshape(raw.values.shape)
    last_ts = ts_b.max(-1)
    has = last_ts > -big
    is_last = (ts_b == last_ts[..., None]) & raw.valid
    last_v = jnp.einsum("esm,esm->es", raw.values, is_last.astype(jnp.float32)) \
        / jnp.maximum(is_last.sum(-1), 1)
    new_state = PipelineState(
        gapfill=new_gap, anomaly=new_anom, norm=new_norm,
        prev_value=jnp.where(has, last_v, state.prev_value),
        # no observation this window: re-express the old carry in this
        # window's frame so it keeps receding one window length per tick
        prev_ts=jnp.where(has, last_ts,
                          state.prev_ts - cfg.n_ticks * cfg.tick_s),
        tick_index=state.tick_index + 1,
    )
    frame = TickFrame(v, obs, filled, replaced)
    return new_state, features, frame


def mask_env_rows(tree, active):
    """Zero every env row of ``tree``'s leaves where ``active`` is False.

    The elastic engine's ONLY sanctioned way of combining the slot mask
    with data: a ``select`` per leaf (broadcast over trailing dims). Active
    rows pass through untouched — ``where(True, x, 0) == x`` bit for bit —
    and inactive rows become deterministic zeros of the leaf dtype (the
    select also kills any NaN/Inf garbage a cold slot computed). Never
    compact, sort, or index by the mask; the ``env-mask-gate`` contract
    rule rejects that shape (rows would cross shards under the env mesh).

    The selects are fenced by ``lax.optimization_barrier`` on BOTH sides:
    XLA otherwise fuses them into the producing computation's epilogue
    (or into a downstream consumer's kernel — in the fused decide scan
    the masked raw feeds the reward reduction in the same body), and the
    changed fusion shape can re-contract multiply-add chains (1-ulp
    drift vs the dense build — observed on the reward reduction on
    XLA:CPU). The fences pin the surrounding math to compile exactly as
    it does without the mask, which is what makes "active rows
    bit-identical to a dense system over the same envs" hold, not just
    "close".
    """
    tree = jax.lax.optimization_barrier(tree)

    def leaf(x):
        m = active.reshape((active.shape[0],) + (1,) * (jnp.ndim(x) - 1))
        return jnp.where(m, x, jnp.zeros((), jnp.asarray(x).dtype))
    return jax.lax.optimization_barrier(jax.tree.map(leaf, tree))


def run_many(cfg: PipelineConfig, state: PipelineState, raws: RawWindow,
             window_starts, active=None):
    """K windows as ONE ``lax.scan`` over :func:`tick`.

    ``raws`` is a RawWindow whose leaves carry a leading K axis
    (K, E, S, M); ``window_starts`` is (K, E). Returns
    ``(final_state, FeatureFrame, TickFrame)`` with the frame leaves stacked
    along a leading K axis — window k's outputs are exactly what K
    sequential ``tick`` calls would have produced (same math, same order).

    ``active`` (E,) bool is the elastic slot mask: a traced input (attach/
    detach between batches never retraces), masking the stacked per-window
    outputs to garbage-free zeros on inactive rows. State updates need no
    gating — the host feeds inactive slots all-invalid raw windows, under
    which every stage's update is a natural no-op — so active-row outputs
    and the carried state stay bit-identical to the dense engine.
    """
    def body(carry, xs):
        raw, ws = xs
        new_state, feats, frame = tick(cfg, carry, raw, ws)
        if active is not None:
            feats = mask_env_rows(feats, active)
            frame = mask_env_rows(frame, active)
        return new_state, (feats, frame)

    final_state, (feats, frames) = jax.lax.scan(body, state,
                                                (raws, window_starts))
    return final_state, feats, frames


class DecideBatch(NamedTuple):
    """Per-window outputs of the fused decision scan (leading K axis).

    Everything the Manager's host loop needs, and nothing bigger: the
    decision outputs are (K, E[, A]) and the pipeline-quality metrics are
    exact per-env int32 COUNTS over the (S, T) tick grid — the host
    divides them in float64, reproducing ``np.mean`` over the full frame
    bit for bit without transferring the (K, E, S, T) frame stack.
    ``features`` stays on device unless a host sink (LogDB) actually
    fetches it — JAX only pays the device->host copy per leaf touched.
    """
    actions: jax.Array      # (K, E, A) validated actions
    rewards: jax.Array      # (K, E)
    per_term: jax.Array     # (K, E, n_terms)
    violated: jax.Array     # (K, E) bool — pre-clamp envelope violations
    features: jax.Array     # (K, E, F) — fetched only when a sink needs it
    observed: jax.Array     # (K, E) int32 counts over (S, T)
    filled: jax.Array       # (K, E) int32
    anomalous: jax.Array    # (K, E) int32


def run_many_decide(cfg: PipelineConfig, decide, state: PipelineState,
                    dstate, raws: RawWindow, window_starts):
    """K windows + K decisions as ONE ``lax.scan``: :func:`run_many` with
    the decision path fused into the scan body.

    ``decide`` is a ``(step, bank)`` pair (see
    ``runtime.predictor.DecideFns``): ``step`` runs one window's policy/
    validation/reward math inside the scan — exactly the per-window (E, F)
    computation of the reference ``on_tick`` step, so outputs stay
    bit-identical to the two-dispatch path — and emits that window's
    replay transition row; ``bank`` then writes all K stacked rows AFTER
    the scan in one exact ring scatter. Only the small prev/tick part of
    the decide carry rides the scan (the (E, C, F) replay storage through
    a scan carry measured a full copy per dispatch — as a plain donated
    input updated by one scatter, XLA aliases it in place). Returns
    ``(final_state, final_dcarry, DecideBatch)``.

    Elastic slot pools ride the decide carry: when ``dstate.active`` is
    set (an (E,) bool carry leaf — membership changes between batches
    re-dispatch with new mask VALUES, no retrace), the per-window pipeline
    outputs are masked to zeros on inactive rows (the decide step masks
    its own outputs — see ``runtime.predictor.make_decide_fn``), and the
    post-scan bank marks ring rows valid per env: window 0's transition
    closes a pair begun LAST batch, so it is valid only for envs with
    ``prev_ok & active`` (a slot attached this batch has no previous
    window; ``prev_ok`` is the per-env twin of the scalar ``have_prev``
    chain), later windows for every active env. The scalar cursor chain —
    and therefore ring positions — stays exactly the dense engine's.
    """
    step, bank = decide
    elastic = getattr(dstate, "active", None) is not None

    def body(carry, xs):
        pstate, dcarry = carry
        raw, ws = xs
        new_state, feats, frame = tick(cfg, pstate, raw, ws)
        if elastic:
            feats = mask_env_rows(feats, dcarry.active)
            frame = mask_env_rows(frame, dcarry.active)
        new_dcarry, (actions, reward, per_term, violated), trans = step(
            dcarry, feats)
        out = DecideBatch(
            actions=actions, rewards=reward, per_term=per_term,
            violated=violated, features=feats.features,
            # exact per-env counts (S*T <= int32 by construction); the
            # cross-env total is summed host-side so the sharded engine
            # stays collective-free
            observed=jnp.sum(frame.observed, axis=(1, 2), dtype=jnp.int32),
            filled=jnp.sum(frame.filled, axis=(1, 2), dtype=jnp.int32),
            anomalous=jnp.sum(frame.anomalous, axis=(1, 2), dtype=jnp.int32))
        return (new_state, new_dcarry), (out, trans)

    # the ring stays OUT of the scan carry: thread the small decide state,
    # then bank the stacked transitions with one scatter
    small = dstate._replace(replay=None)
    (final_state, final_small), (outs, trans) = jax.lax.scan(
        body, (state, small), (raws, window_starts))
    if elastic:
        K = jnp.shape(window_starts)[0]
        E = dstate.active.shape[0]
        rows = jnp.broadcast_to(dstate.active[None, :], (K, E))
        row0 = (dstate.active & dstate.prev_ok)[None, :]
        env_mask = jnp.concatenate([row0, rows[1:]], axis=0)
        final_dcarry = final_small._replace(
            replay=bank(dstate.replay, trans, env_mask=env_mask),
            prev_ok=dstate.prev_ok | dstate.active)
    else:
        final_dcarry = final_small._replace(replay=bank(dstate.replay, trans))
    return final_state, final_dcarry, outs


def make_run_many_decide_sharded(cfg: PipelineConfig, decide, dstate,
                                 mesh=None):
    """Env-sharded fused decision engine: :func:`run_many_decide` under
    ``shard_map`` on the one-axis env mesh.

    The whole fused carry shards on the env dim: pipeline state leaves and
    decide-carry leaves (prev obs/actions rows, replay ring rows) split on
    dim 0, the (K, ...) batch and stacked :class:`DecideBatch` outputs on
    dim 1, and every scalar (``tick_index``, ``have_prev``, the decide
    tick counter, the ring ``cursor``) replicated — ``sharding.env_specs``
    resolves all of that by leaf rank. Policy weights ride the carry's
    ``policy`` subtree (hot-swappable by the online trainer) and are
    explicitly replicated by ``sharding.decide_specs`` — the rank rule
    alone would mis-shard a weight whose leading dim divides E. The
    decision math must be per-env row-wise (builtin reward terms are;
    custom fns must not reduce across envs), which keeps the body
    collective-free and the outputs bit-identical to the unsharded
    engine. ``dstate`` is only a shape/dtype template for spec probing.

    Build-time trace: probing the output specs runs ``jax.eval_shape``
    over the fused body HERE, so the decide step (and any model inside
    it) must be traceable at construction time — a policy closing over
    host state must have that state populated before the system is built
    (``examples/serve_edge.py`` seeds its codec norm snapshot first).
    """
    from repro.distribution import sharding as shard_lib

    if mesh is None:
        mesh = shard_lib.env_mesh(cfg.n_envs)
    fn = functools.partial(run_many_decide, cfg, decide)
    E, S, M = cfg.n_envs, cfg.n_streams, cfg.max_samples
    state_s = jax.eval_shape(lambda: init_state(cfg))
    dstate_s = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.asarray(x).dtype),
        dstate)
    raw_s = RawWindow(jax.ShapeDtypeStruct((1, E, S, M), jnp.float32),
                      jax.ShapeDtypeStruct((1, E, S, M), jnp.float32),
                      jax.ShapeDtypeStruct((1, E, S, M), jnp.bool_))
    starts_s = jax.ShapeDtypeStruct((1, E), jnp.float32)
    out_state_s, out_dstate_s, out_batch_s = jax.eval_shape(
        fn, state_s, dstate_s, raw_s, starts_s)
    axis = mesh.axis_names[0]
    in_specs = (shard_lib.env_specs(state_s, 0, axis),
                shard_lib.decide_specs(dstate_s, 0, axis),
                shard_lib.env_specs(raw_s, 1, axis),
                shard_lib.env_specs(starts_s, 1, axis))
    out_specs = (shard_lib.env_specs(out_state_s, 0, axis),
                 shard_lib.decide_specs(out_dstate_s, 0, axis),
                 shard_lib.env_specs(out_batch_s, 1, axis))
    sharded = compat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs)
    return sharded, mesh


def make_run_many_sharded(cfg: PipelineConfig, mesh=None, elastic=False):
    """Env-sharded scan engine: :func:`run_many` under ``shard_map``.

    Returns ``(fn, mesh)`` where ``fn(state, raws, window_starts)`` has the
    same signature/outputs as :func:`run_many` but executes with the env
    dimension sharded over ``mesh``'s single ``data`` axis: state leaves are
    split on dim 0, the (K, E, S, M) batch / (K, E) starts / stacked outputs
    on dim 1, and the scalar ``tick_index`` is replicated. The tick math is
    per-env (no cross-env reductions anywhere in the stage functions), so
    the body needs no collectives and outputs are bit-identical to
    :func:`run_many`. ``mesh`` defaults to ``sharding.env_mesh(cfg.n_envs)``
    (largest device count dividing E; 1-device meshes degenerate cleanly).

    ``elastic=True`` builds the masked-slot-pool variant, whose ``fn``
    takes a trailing ``active`` (E,) bool argument sharded on the env axis
    like every other per-env row block (each shard masks only its own
    rows; the mask combines by select, so no collectives appear).
    """
    from repro.distribution import sharding as shard_lib

    if mesh is None:
        mesh = shard_lib.env_mesh(cfg.n_envs)
    fn = functools.partial(run_many, cfg)
    # PartitionSpecs depend only on leaf ranks, so probe them with a K=1
    # abstract batch; the jitted wrapper retraces per concrete K as usual.
    E, S, M = cfg.n_envs, cfg.n_streams, cfg.max_samples
    state_s = jax.eval_shape(lambda: init_state(cfg))
    raw_s = RawWindow(jax.ShapeDtypeStruct((1, E, S, M), jnp.float32),
                      jax.ShapeDtypeStruct((1, E, S, M), jnp.float32),
                      jax.ShapeDtypeStruct((1, E, S, M), jnp.bool_))
    starts_s = jax.ShapeDtypeStruct((1, E), jnp.float32)
    probe = (state_s, raw_s, starts_s)
    if elastic:
        probe = probe + (jax.ShapeDtypeStruct((E,), jnp.bool_),)
    out_state_s, out_feats_s, out_frames_s = jax.eval_shape(fn, *probe)
    axis = mesh.axis_names[0]
    in_specs = (shard_lib.env_specs(state_s, 0, axis),
                shard_lib.env_specs(raw_s, 1, axis),
                shard_lib.env_specs(starts_s, 1, axis))
    if elastic:
        in_specs = in_specs + (shard_lib.env_specs(probe[3], 0, axis),)
    out_specs = (shard_lib.env_specs(out_state_s, 0, axis),
                 shard_lib.env_specs(out_feats_s, 1, axis),
                 shard_lib.env_specs(out_frames_s, 1, axis))
    sharded = compat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs)
    return sharded, mesh


class PerceptaPipeline:
    """User-facing handle; ``mode`` selects scan_sharded/scan/fused/modular.

    ``run_tick`` treats ``scan``/``scan_sharded`` as ``fused`` (single
    windows still take one dispatch); the scan engine is reached through
    :meth:`run_many`, which dispatches to the env-sharded ``shard_map``
    build when ``mode="scan_sharded"`` (``mesh`` overrides the default
    ``distribution.sharding.env_mesh``).
    """

    def __init__(self, cfg: PipelineConfig, mode: str = "fused",
                 donate: bool = False, mesh=None, decide=None,
                 decide_state=None, elastic: bool = False):
        # donate=True requires the caller to treat the passed-in state as
        # consumed (the engine hands back the new state); it is how the
        # scan engine keeps exactly one live state pytree on device. The
        # fused-decide modes donate BOTH carries (pipeline state + decide
        # carry) so the replay ring never gets copied between batches.
        # elastic=True marks the env axis a masked slot pool: the plain
        # scan engines take a trailing (E,) active mask (fused-decide
        # modes carry it inside decide_state instead).
        self.cfg = cfg
        self.mode = mode
        self.donate = donate
        self.elastic = elastic
        tickf = functools.partial(tick, cfg)
        # both paths go through compat.jit_donated: fresh init_state leaves
        # alias their zero buffers, which raw donate_argnums rejects
        self._fused = compat.jit_donated(
            tickf, donate_argnums=(0,) if donate else ())
        donate_scan = (0,) if donate else ()
        if mode in ("scan_fused_decide", "scan_fused_decide_sharded"):
            assert decide is not None and decide_state is not None, \
                "fused-decide modes need decide= and decide_state="
            donate_scan = (0, 1) if donate else ()
            if mode == "scan_fused_decide_sharded":
                scan_fn, self.mesh = make_run_many_decide_sharded(
                    cfg, decide, decide_state, mesh)
            else:
                scan_fn = functools.partial(run_many_decide, cfg, decide)
                self.mesh = None
        elif mode == "scan_sharded":
            scan_fn, self.mesh = make_run_many_sharded(cfg, mesh,
                                                       elastic=elastic)
        else:
            scan_fn, self.mesh = functools.partial(run_many, cfg), None
        self._scan = compat.jit_donated(scan_fn, donate_argnums=donate_scan)
        # modular: one jit per module, host transitions in between — the
        # architecture exactly as drawn (baseline for §Perf)
        self._m_harm = jax.jit(functools.partial(stage_harmonize, cfg))
        self._m_anom = jax.jit(functools.partial(stage_anomaly, cfg))
        self._m_gap = jax.jit(functools.partial(stage_gapfill, cfg))
        self._m_norm = jax.jit(functools.partial(stage_normalize, cfg))
        self._m_feat = jax.jit(functools.partial(stage_features, cfg))

    def init_state(self):
        return init_state(self.cfg)

    def run_many(self, state, raws: RawWindow, window_starts, active=None):
        """Scan-fused execution of K pre-batched windows (one dispatch).

        ``active`` (E,) bool is the elastic slot mask (required iff the
        pipeline was built with ``elastic=True``; a traced value, so
        membership changes never retrace)."""
        if self.mode in ("scan_fused_decide", "scan_fused_decide_sharded"):
            raise RuntimeError("fused-decide modes carry a decide state: "
                               "use run_many_decide(state, dstate, ...)")
        if self.elastic:
            assert active is not None, \
                "elastic pipelines need the (E,) active mask per batch"
            return self._scan(state, raws, window_starts, active)
        assert active is None, \
            "active mask passed to a pipeline built with elastic=False"
        return self._scan(state, raws, window_starts)

    def run_many_decide(self, state, dstate, raws: RawWindow, window_starts):
        """Fused pipeline+decision execution of K windows (one dispatch).

        Returns ``(new_state, new_dstate, DecideBatch)``; with
        ``donate=True`` BOTH input carries are consumed."""
        return self._scan(state, dstate, raws, window_starts)

    def run_tick(self, state, raw: RawWindow, window_start):
        if self.mode in ("fused", "scan", "scan_sharded",
                         "scan_fused_decide", "scan_fused_decide_sharded"):
            return self._fused(state, raw, window_start)
        # modular: each stage returns to host before the next is dispatched
        v, obs, ticks = jax.block_until_ready(
            self._m_harm(state, raw, window_start))
        v, obs, replaced, new_anom = jax.block_until_ready(
            self._m_anom(state, v, obs))
        v, filled, new_gap = jax.block_until_ready(
            self._m_gap(state, v, obs, ticks))
        v_norm, new_norm = jax.block_until_ready(
            self._m_norm(state, v, obs | filled))
        features = jax.block_until_ready(
            self._m_feat(v_norm, v, obs, filled, ticks))
        big = jnp.float32(3.4e38)
        ts_b = jnp.where(raw.valid, raw.timestamps, -big)
        last_ts = ts_b.max(-1)
        has = last_ts > -big
        is_last = (ts_b == last_ts[..., None]) & raw.valid
        last_v = jnp.einsum("esm,esm->es", raw.values,
                            is_last.astype(jnp.float32)) / \
            jnp.maximum(is_last.sum(-1), 1)
        new_state = PipelineState(
            gapfill=new_gap, anomaly=new_anom, norm=new_norm,
            prev_value=jnp.where(has, last_v, state.prev_value),
            prev_ts=jnp.where(has, last_ts,
                              state.prev_ts
                              - self.cfg.n_ticks * self.cfg.tick_s),
            tick_index=state.tick_index + 1,
        )
        return new_state, features, TickFrame(v, obs, filled, replaced)

"""Qwen3-0.6B — dense GQA with qk-norm. [hf:Qwen/Qwen3-0.6B family]"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    layer_pattern=(ATTN_GLOBAL,),
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-0.6B",
)

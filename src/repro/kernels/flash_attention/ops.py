"""Jit'd public wrapper for flash attention (model-layout adapter)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("window", "softcap",
                                             "use_pallas", "interpret",
                                             "q_blk", "kv_blk"))
def flash_attention(q, k, v, *, window: int = 0, softcap: float = 0.0,
                    use_pallas: bool = True, interpret: bool = True,
                    q_blk: int = 128, kv_blk: int = 128):
    """Model layout in/out: q (B, S, H, D); k, v (B, S, Hkv, D)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if use_pallas:
        out = flash_attention_pallas(qt, kt, vt, window=window,
                                     softcap=softcap, interpret=interpret,
                                     q_blk=q_blk, kv_blk=kv_blk)
    else:
        out = attention_ref(qt, kt, vt, window=window, softcap=softcap)
    return out.transpose(0, 2, 1, 3)

"""Experience storage for retraining — on-device ring buffer + anonymization.

"...storing the necessary data for model retraining in the future,
anonymizing it and delivering it to the node responsible for training."

The buffer is a fixed-capacity ring over (obs, action, reward, next_obs,
tick_time) batched across environments, living on device (shardable over the
env dim). ``anonymize`` applies a salted hash to environment identities so
exported datasets can't be joined back to buildings.
"""
from __future__ import annotations

import hashlib
from typing import NamedTuple

import jax
import jax.numpy as jnp


class ReplayBuffer(NamedTuple):
    obs: jax.Array        # (E, C, F)
    actions: jax.Array    # (E, C, A)
    rewards: jax.Array    # (E, C)
    next_obs: jax.Array   # (E, C, F)
    times: jax.Array      # (E, C)
    cursor: jax.Array     # () int32 — total ticks written (ring position)

    @property
    def capacity(self):
        return self.obs.shape[1]

    def size(self):
        return jnp.minimum(self.cursor, self.capacity)


def init(E, capacity, n_features, n_actions) -> ReplayBuffer:
    return ReplayBuffer(
        obs=jnp.zeros((E, capacity, n_features), jnp.float32),
        actions=jnp.zeros((E, capacity, n_actions), jnp.float32),
        rewards=jnp.zeros((E, capacity), jnp.float32),
        next_obs=jnp.zeros((E, capacity, n_features), jnp.float32),
        times=jnp.zeros((E, capacity), jnp.float32),
        cursor=jnp.zeros((), jnp.int32),
    )


def add(buf: ReplayBuffer, obs, actions, rewards, next_obs, times) -> ReplayBuffer:
    """Write one tick for every env at the ring position (jit-safe)."""
    i = jnp.mod(buf.cursor, buf.capacity)
    upd = lambda b, x: b.at[:, i].set(x.astype(b.dtype))
    return ReplayBuffer(
        obs=upd(buf.obs, obs),
        actions=upd(buf.actions, actions),
        rewards=upd(buf.rewards, rewards),
        next_obs=upd(buf.next_obs, next_obs),
        times=upd(buf.times, times),
        cursor=buf.cursor + 1,
    )


def sample(buf: ReplayBuffer, rng, batch: int):
    """Uniform sample of (env, slot) transitions for retraining."""
    E = buf.obs.shape[0]
    n = jnp.maximum(buf.size(), 1)
    ke, ks = jax.random.split(rng)
    es = jax.random.randint(ke, (batch,), 0, E)
    ss = jax.random.randint(ks, (batch,), 0, n)
    take = lambda x: x[es, ss]
    return {"obs": take(buf.obs), "actions": take(buf.actions),
            "rewards": take(buf.rewards), "next_obs": take(buf.next_obs),
            "times": take(buf.times)}


def anonymize_env_ids(env_ids, salt: str) -> list:
    """Salted-hash pseudonyms for export (host-side; not jit)."""
    out = []
    for e in env_ids:
        h = hashlib.sha256((salt + "::" + str(e)).encode()).hexdigest()[:16]
        out.append(f"env-{h}")
    return out


def export_for_training(buf: ReplayBuffer, env_ids, salt: str) -> dict:
    """Materialize an anonymized dataset dict (host-side)."""
    import numpy as np
    n = int(buf.size())
    return {
        "env_ids": anonymize_env_ids(env_ids, salt),
        "obs": np.asarray(buf.obs[:, :n]),
        "actions": np.asarray(buf.actions[:, :n]),
        "rewards": np.asarray(buf.rewards[:, :n]),
        "next_obs": np.asarray(buf.next_obs[:, :n]),
        "times": np.asarray(buf.times[:, :n]),
    }

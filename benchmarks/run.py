"""Benchmark harness — one function per paper table/figure.

Percepta's paper defers benchmarking to future work but enumerates the plan
(§V): network I/O under load, CPU/memory across stress levels, performance
across deployment strategies. Each bench below implements one of those
tables (plus serving, kernels, and the dry-run roofline summary).

Prints ``name,us_per_call,derived`` CSV rows (CPU wall time; the TPU-target
numbers live in the roofline table from the dry-run artifacts). ``--json
PATH`` additionally writes every row plus the windows/s / records/s
summary (per execution mode and ingest path) as machine-readable JSON so
the perf trajectory is tracked across PRs (``BENCH_pr2.json``).

``--host-devices N`` forces an N-device CPU mesh
(``--xla_force_host_platform_device_count``) so the ``scan_sharded``
shard_map path is exercised without real multi-chip hardware; it must run
before JAX initializes, which is why every bench imports jax lazily.

Run: ``PYTHONPATH=src python -m benchmarks.run [--quick]
[--host-devices 8] [--json BENCH_pr2.json]``
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

RESULTS: list = []                    # every _row, for --json
SUMMARY: dict = {"windows_per_s": {}, "records_per_s": {}}


def _row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)
    RESULTS.append({"name": name, "us_per_call": round(us, 1),
                    "derived": derived})


def _subprocess_env(xla_flags: str) -> dict:
    """Environment for an acceptance-cell subprocess: fresh XLA flags plus
    this repo's src/ ahead of any inherited PYTHONPATH entries."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = xla_flags
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p])
    return env


def _time(fn, n=5, warmup=2, best=False):
    """Mean (default) or best-of-n microseconds per call.

    ``best=True`` reports the fastest rep — the robust estimator when the
    measured quantity is a dispatch-overhead ratio and the box is shared
    (one preempted rep poisons a mean but not a min).
    """
    for _ in range(warmup):
        fn()
    if best:
        out = float("inf")
        for _ in range(n):
            t0 = time.time()
            fn()
            out = min(out, time.time() - t0)
        return out * 1e6
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n * 1e6


# --------------------------------------------------------------------------
# Table 1 — ingest/network-I/O throughput under varying load
# --------------------------------------------------------------------------

def bench_ingest(quick=False):
    from repro.runtime.queues import QueueBroker
    from repro.runtime.records import CODECS
    from repro.runtime.translator import Translator

    for proto in ("mqtt", "http", "amqp"):
        enc, _ = CODECS[proto]
        tr = Translator("src", proto)
        broker = QueueBroker()
        n = 2_000 if quick else 20_000
        payloads = [enc("s", float(i), float(i) * 0.5) for i in range(n)]

        def run():
            for i, p in enumerate(payloads):
                rec = tr.translate(f"env-{i % 16}", p)
                broker.publish(rec)

        t0 = time.time()
        run()
        dt = time.time() - t0
        _row(f"ingest_{proto}", dt / n * 1e6, f"{n / dt:.0f} msg/s")


# --------------------------------------------------------------------------
# Table 2 — per-tick pipeline latency: modular vs fused vs scan (3 axes)
# --------------------------------------------------------------------------

def _pipeline(E, S=8, T=16, M=64, mode="fused", K=1):
    import jax.numpy as jnp

    from repro.core import PerceptaPipeline, PipelineConfig
    from repro.core.frame import make_raw_window

    cfg = PipelineConfig(n_envs=E, n_streams=S, n_ticks=T, tick_s=60.0,
                         max_samples=M)
    pipe = PerceptaPipeline(cfg, mode=mode,
                            donate=mode in ("scan", "scan_sharded"))
    state = pipe.init_state()
    rng = np.random.RandomState(0)
    if mode in ("scan", "scan_sharded"):
        raws = make_raw_window(
            rng.normal(5, 2, (K, E, S, M)).astype(np.float32),
            rng.uniform(0, T * 60, (K, E, S, M)).astype(np.float32),
            rng.rand(K, E, S, M) > 0.3)
        ws = jnp.zeros((K, E), jnp.float32)

        def run():
            nonlocal state
            state, feats, frames = pipe.run_many(state, raws, ws)
            feats.features.block_until_ready()

        return run

    raw = make_raw_window(rng.normal(5, 2, (E, S, M)).astype(np.float32),
                          rng.uniform(0, T * 60, (E, S, M)).astype(np.float32),
                          rng.rand(E, S, M) > 0.3)
    ws = jnp.zeros((E,), jnp.float32)

    def run():
        nonlocal state
        state, feats, frame = pipe.run_tick(state, raw, ws)
        feats.features.block_until_ready()

    return run


def bench_tick_latency(quick=False):
    import jax
    envs = (16, 256) if quick else (16, 256, 1024)
    K = 8 if quick else 16
    ndev = len(jax.devices())
    for E in envs:
        t_mod = _time(_pipeline(E, mode="modular"), n=3 if quick else 8)
        t_fus = _time(_pipeline(E, mode="fused"), n=3 if quick else 8)
        t_scan = _time(_pipeline(E, mode="scan", K=K),
                       n=3 if quick else 8) / K  # per-tick, one dispatch per K
        _row(f"tick_modular_E{E}", t_mod, "paper-faithful per-module jits")
        _row(f"tick_fused_E{E}", t_fus,
             f"speedup {t_mod / t_fus:.2f}x over modular")
        _row(f"tick_scan_E{E}", t_scan,
             f"K={K} windows/dispatch | speedup {t_fus / t_scan:.2f}x over "
             f"fused | {1e6 / t_scan:.0f} windows/s")
        # fourth measured axis: the same scan under shard_map, envs sharded
        t_shard = _time(_pipeline(E, mode="scan_sharded", K=K),
                        n=3 if quick else 8) / K
        _row(f"tick_scan_sharded_E{E}", t_shard,
             f"K={K} | {ndev}-device mesh | "
             f"{t_scan / t_shard:.2f}x vs scan | "
             f"{1e6 / t_shard:.0f} windows/s")


# --------------------------------------------------------------------------
# Table 2b — scan engine acceptance cell: K=32 windows, E=8 envs, S=8 streams
# --------------------------------------------------------------------------

def bench_scan_engine(quick=False):
    import jax
    import jax.numpy as jnp

    from repro.core import PerceptaPipeline, PipelineConfig
    from repro.core.frame import RawWindow, make_raw_window

    K, E, S, T, M = 32, 8, 8, 16, 64
    cfg = PipelineConfig(n_envs=E, n_streams=S, n_ticks=T, tick_s=60.0,
                         max_samples=M)
    rng = np.random.RandomState(0)
    raws = make_raw_window(
        rng.normal(5, 2, (K, E, S, M)).astype(np.float32),
        (rng.uniform(0, T * 60, (K, E, S, M))
         + np.arange(K)[:, None, None, None] * T * 60).astype(np.float32),
        rng.rand(K, E, S, M) > 0.3)
    starts = jnp.asarray(np.arange(K, dtype=np.float32)[:, None] * (T * 60.0)
                         * np.ones((1, E), np.float32))
    per_window = [RawWindow(raws.values[k], raws.timestamps[k], raws.valid[k])
                  for k in range(K)]

    fused = PerceptaPipeline(cfg, mode="fused")
    scan = PerceptaPipeline(cfg, mode="scan")
    state0 = fused.init_state()

    # correctness: scan must match K sequential fused ticks bit-for-bit
    s = state0
    seq_feats = []
    for k in range(K):
        s, f, _ = fused.run_tick(s, per_window[k], starts[k])
        seq_feats.append(np.asarray(f.features))
    _, feats, _ = scan.run_many(state0, raws, starts)
    err = float(np.max(np.abs(np.asarray(feats.features)
                              - np.stack(seq_feats))))

    def run_seq():
        st = state0
        for k in range(K):
            st, f, _ = fused.run_tick(st, per_window[k], starts[k])
        f.features.block_until_ready()

    def run_scan():
        st, f, _ = scan.run_many(state0, raws, starts)
        f.features.block_until_ready()

    n = 6 if quick else 12
    t_seq = _time(run_seq, n=n, best=True)
    t_scan = _time(run_scan, n=n, best=True)
    wps_seq = K / (t_seq / 1e6)
    wps_scan = K / (t_scan / 1e6)
    SUMMARY["windows_per_s"]["fused_seq"] = round(wps_seq, 1)
    SUMMARY["windows_per_s"]["scan"] = round(wps_scan, 1)
    _row(f"scan_fused_seq_K{K}_E{E}_S{S}", t_seq / K,
         f"{wps_seq:.0f} windows/s ({K} dispatches)")
    _row(f"scan_engine_K{K}_E{E}_S{S}", t_scan / K,
         f"{wps_scan:.0f} windows/s (1 dispatch) | "
         f"speedup {wps_scan / wps_seq:.2f}x | max_abs_err {err:.2e}")


# --------------------------------------------------------------------------
# Table 2c — env-sharded scan engine: same cell under shard_map on the mesh
# --------------------------------------------------------------------------

def bench_scan_sharded(quick=False):
    import jax
    import jax.numpy as jnp

    from repro.core import PerceptaPipeline, PipelineConfig
    from repro.core.frame import make_raw_window

    K, E, S, T, M = 32, 8, 8, 16, 64
    ndev = len(jax.devices())
    cfg = PipelineConfig(n_envs=E, n_streams=S, n_ticks=T, tick_s=60.0,
                         max_samples=M)
    rng = np.random.RandomState(0)
    raws = make_raw_window(
        rng.normal(5, 2, (K, E, S, M)).astype(np.float32),
        (rng.uniform(0, T * 60, (K, E, S, M))
         + np.arange(K)[:, None, None, None] * T * 60).astype(np.float32),
        rng.rand(K, E, S, M) > 0.3)
    starts = jnp.asarray(np.arange(K, dtype=np.float32)[:, None] * (T * 60.0)
                         * np.ones((1, E), np.float32))
    scan = PerceptaPipeline(cfg, mode="scan")
    shard = PerceptaPipeline(cfg, mode="scan_sharded")
    state0 = scan.init_state()

    # acceptance: sharded outputs bit-identical to the single-device scan
    _, f_ref, _ = scan.run_many(state0, raws, starts)
    _, f_sh, _ = shard.run_many(state0, raws, starts)
    err = float(np.max(np.abs(np.asarray(f_ref.features)
                              - np.asarray(f_sh.features))))

    def run_scan():
        st, f, _ = scan.run_many(state0, raws, starts)
        f.features.block_until_ready()

    def run_shard():
        st, f, _ = shard.run_many(state0, raws, starts)
        f.features.block_until_ready()

    n = 6 if quick else 12
    t_scan = _time(run_scan, n=n, best=True)
    t_shard = _time(run_shard, n=n, best=True)
    wps = K / (t_shard / 1e6)
    mesh_n = int(np.prod(list(shard.mesh.shape.values())))
    SUMMARY["windows_per_s"]["scan_sharded"] = round(wps, 1)
    SUMMARY["scan_sharded_max_abs_err"] = err
    SUMMARY["mesh_devices"] = mesh_n
    _row(f"scan_sharded_K{K}_E{E}_S{S}", t_shard / K,
         f"{wps:.0f} windows/s | {mesh_n}-device env mesh ({ndev} visible) | "
         f"{t_scan / t_shard:.2f}x vs scan | max_abs_err {err:.2e}")


# --------------------------------------------------------------------------
# Table 2d — pipelined (async double-buffered) scan engine + K/E autotuner
# --------------------------------------------------------------------------

# The overlap cell runs in a SUBPROCESS with
# ``--xla_cpu_multi_thread_eigen=false``: on a small CI box, XLA:CPU's
# contraction threadpool otherwise saturates every core during the device
# phase, so there is no spare capacity for host/device overlap to reclaim —
# the flag emulates the deployment this engine targets (an accelerator that
# does not consume host CPU) without perturbing any other cell's flags.
# The measurement itself is drift-immune: scan and scan_async reps are
# interleaved in PAIRS and the reported speedup is the MEDIAN of per-pair
# ratios, because shared-box throughput drifts ~2x on minute timescales,
# which corrupts best-of comparisons taken seconds apart.
_ASYNC_CELL_SCRIPT = """
import json, time
import numpy as np
from repro.core import PipelineConfig
from repro.core.reward import energy_reward_spec
from repro.runtime.predictor import ActionSpace, Predictor, linear_policy
from repro.runtime.receivers import SimulatedDevice
from repro.runtime.records import RecordBatch
from repro.runtime.system import PerceptaSystem, SourceSpec
import jax

E, S, K, M = 8, 8, 32, 64
T, TICK_S, PER = 64, 15.0, 160   # device-heavy tick math + dense ingest

def mk(mode):
    srcs = [SourceSpec(f"s{i}", "mqtt",
                       SimulatedDevice(f"st{i}", 60.0, base=3.0, seed=i))
            for i in range(S)]
    cfg = PipelineConfig(n_envs=E, n_streams=S, n_ticks=T, tick_s=TICK_S,
                         max_samples=M, harmonize_method="onehot",
                         gap_strategy="linear")
    pred = Predictor(linear_policy(S, 2),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     E, cfg.n_features, replay_capacity=64)
    return PerceptaSystem([f"b{i}" for i in range(E)], srcs, cfg, pred,
                          speedup=1e9, manual_time=True, mode=mode,
                          scan_k=K)

def publish(s, n_windows, rng):
    # a loaded broker: per-poll RecordBatch columns already queued, the
    # shape a real RabbitMQ consumer sees under sustained inbound load.
    # Anchored at the system's CURRENT window so repeated reps keep every
    # window fully populated (records behind the clock would be stale).
    w = s.window_s
    n = n_windows * PER
    t0 = s.window_bounds(s.window_index)[0]
    for env in s.env_ids:
        for src in s.sources:
            ts = np.sort(rng.uniform(t0, t0 + n_windows * w, n))
            s.broker.publish(RecordBatch.from_columns(
                env, src.device.stream, ts, rng.normal(5, 2, n)))

QUICK = __QUICK__
N = 96
PAIRS = 8 if QUICK else 12  # first pair is jit/cache warmup, discarded


def parallel_factor():
    # self-calibration: how much extra CPU a second worker actually buys on
    # this host (2.0 = two real cores; ~1.3 = one core + SMT sibling). The
    # overlap speedup is physically bounded by this number, so record it
    # next to the measurement.
    import multiprocessing as mp

    def burn(dur, q):
        t0 = time.time()
        n = 0
        while time.time() - t0 < dur:
            for _ in range(10000):
                n += 1
        q.put(n)

    q = mp.Queue()
    p = mp.Process(target=burn, args=(1.5, q))
    t0 = time.time(); p.start(); p.join()
    r1 = q.get() / (time.time() - t0)
    q = mp.Queue()
    ps = [mp.Process(target=burn, args=(1.5, q)) for _ in range(2)]
    t0 = time.time()
    for p in ps:
        p.start()
    for p in ps:
        p.join()
    r2 = sum(q.get() for _ in ps) / (time.time() - t0)
    return r2 / r1


ss, sa = mk("scan"), mk("scan_async")
ss.run_windows(K, pump=False)
sa.run_windows(K, pump=False)

# host-assembly share of scan wall time (phase decomposition on the twin)
publish(ss, N, np.random.RandomState(0))
A = D = C = 0.0
for b in range(N // K):
    bounds = [ss.window_bounds(ss.window_index + j) for j in range(K)]
    t0 = time.time(); raw, counts = ss.assemble_windows(bounds)
    A += time.time() - t0
    t0 = time.time()
    feats, frames, td = ss._dispatch_scan(raw, K)
    jax.block_until_ready(feats.features)
    D += time.time() - t0
    t0 = time.time(); ss._consume_scan(bounds, counts, feats, frames, td)
    C += time.time() - t0

ratios, tot_s, tot_a, best_s, best_a = [], 0.0, 0.0, 0.0, 0.0
for pair in range(PAIRS):
    publish(ss, N, np.random.RandomState(0))
    t0 = time.time(); ss.run_windows(N, pump=False); dt_s = time.time() - t0
    publish(sa, N, np.random.RandomState(0))
    t0 = time.time(); sa.run_windows(N, pump=False); dt_a = time.time() - t0
    if pair == 0:
        continue    # warmup pair: first-touch caches, thread spin-up
    ratios.append(dt_s / dt_a)
    tot_s += dt_s
    tot_a += dt_a
    best_s = max(best_s, N / dt_s)
    best_a = max(best_a, N / dt_a)
sa.stop(); ss.stop()
print(json.dumps({
    "windows_per_s_scan": round(best_s, 1),
    "windows_per_s_scan_async": round(best_a, 1),
    # ratio of interleaved totals: per-leg box noise (shared-host bursts)
    # cancels in expectation across many alternated short legs
    "speedup": round(tot_s / tot_a, 2),
    "speedup_median_of_pairs": round(float(np.median(ratios)), 2),
    "pair_ratios": [round(r, 2) for r in ratios],
    # what perfect overlap of these phases would yield...
    "ideal_speedup": round((A + D + C) / (max(A, D) + C), 2),
    # ...and the host's real concurrency budget bounding it (2.0 = two
    # full cores; ~1.3 = one physical core + SMT sibling)
    "host_parallel_factor": round(parallel_factor(), 2),
    "host_assembly_frac": round(A / (A + D + C), 2),
    # total host-side share (assembly + consume) of scan wall — the part
    # of the loop the device cannot hide; PR 4's batched Predictor consume
    # attacks the C term (see bench_predictor_batch for before/after)
    "host_share": round((A + C) / (A + D + C), 2),
    "scan_phase_ms": {"assemble": round(A / (N // K) * 1e3, 1),
                      "device": round(D / (N // K) * 1e3, 1),
                      "consume": round(C / (N // K) * 1e3, 1)},
    "cell": {"K": K, "E": E, "S": S, "T": T, "M": M,
             "records_per_stream_window": PER},
}))
"""


def bench_scan_async(quick=False):
    import subprocess

    from repro.core import PipelineConfig
    from repro.core.reward import energy_reward_spec
    from repro.runtime.predictor import (ActionSpace, Predictor,
                                         linear_policy)
    from repro.runtime.receivers import SimulatedDevice
    from repro.runtime.system import PerceptaSystem, SourceSpec

    # --- acceptance: bit-identical to scan on the K=32/E=8/S=8 cell -------
    def mk(mode):
        srcs = [SourceSpec(f"s{i}", "mqtt",
                           SimulatedDevice(f"st{i}", 60.0, base=3.0, seed=i))
                for i in range(8)]
        cfg = PipelineConfig(n_envs=8, n_streams=8, n_ticks=16, tick_s=60.0,
                             max_samples=64)
        pred = Predictor(
            linear_policy(8, 2),
            energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
            ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
            8, cfg.n_features, replay_capacity=64)
        return PerceptaSystem([f"b{i}" for i in range(8)], srcs, cfg, pred,
                              speedup=1e9, manual_time=True, mode=mode,
                              scan_k=32)

    n = 32 if quick else 64
    strip = lambda rs: [{k: v for k, v in r.items() if k != "latency_s"}
                        for r in rs]
    sa = mk("scan_async")
    ident = strip(mk("scan").run_windows(n)) == strip(sa.run_windows(n))
    sa.stop()
    SUMMARY["scan_async_bit_identical"] = bool(ident)
    _row("scan_async_identity_K32_E8_S8", 0.0,
         f"bit_identical {ident} over {n} windows")

    # --- overlap cell (subprocess; see _ASYNC_CELL_SCRIPT header) ---------
    env = _subprocess_env("--xla_cpu_multi_thread_eigen=false")
    script = _ASYNC_CELL_SCRIPT.replace("__QUICK__", str(bool(quick)))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-2000:]
    cell = json.loads(out.stdout.strip().splitlines()[-1])
    # schema rule: every number appears ONCE — the overlap cell's
    # windows/s live inside the nested scan_async block only (they used to
    # be duplicated under the top-level windows_per_s map, which made
    # artifact diffs double-count them)
    SUMMARY["scan_async"] = cell
    ph = cell["scan_phase_ms"]
    _row("scan_async_overlap_K32_E8_S8_T64",
         1e6 / cell["windows_per_s_scan_async"],
         f"{cell['windows_per_s_scan_async']:.0f} windows/s | "
         f"{cell['speedup']:.2f}x vs scan "
         f"({len(cell['pair_ratios'])} interleaved pairs, ratio of totals; "
         f"median {cell['speedup_median_of_pairs']:.2f}x, ideal "
         f"{cell['ideal_speedup']:.2f}x, host parallel factor "
         f"{cell['host_parallel_factor']:.2f}) | "
         f"host assembly {cell['host_assembly_frac']:.0%} / host total "
         f"{cell['host_share']:.0%} of scan wall "
         f"(A {ph['assemble']:.0f} / D {ph['device']:.0f} / "
         f"C {ph['consume']:.0f} ms/batch)")


# --------------------------------------------------------------------------
# Table 2e — batched Predictor consume: on_windows vs per-window on_tick
# --------------------------------------------------------------------------

# Before/after phase decomposition of the PR 3 overlap cell under the same
# accelerator-emulating XLA flag: twin scan systems consume identical
# batches, one through the per-window on_tick reference loop, one through
# the single-dispatch on_windows scan. Reported: A/D/C phase times, the
# host share (A+C)/(A+D+C) both ways, and bit-identity of every output row
# + the replay ring across the two consume paths.
_PRED_BATCH_SCRIPT = """
import json, time
import numpy as np
import jax
from repro.core import PipelineConfig
from repro.core.reward import energy_reward_spec
from repro.runtime.predictor import ActionSpace, Predictor, linear_policy
from repro.runtime.receivers import SimulatedDevice
from repro.runtime.records import RecordBatch
from repro.runtime.system import PerceptaSystem, SourceSpec

E, S, K, M = 8, 8, 32, 64
T, TICK_S, PER = 64, 15.0, 160

def mk(batched):
    srcs = [SourceSpec(f"s{i}", "mqtt",
                       SimulatedDevice(f"st{i}", 60.0, base=3.0, seed=i))
            for i in range(S)]
    cfg = PipelineConfig(n_envs=E, n_streams=S, n_ticks=T, tick_s=TICK_S,
                         max_samples=M, harmonize_method="onehot",
                         gap_strategy="linear")
    pred = Predictor(linear_policy(S, 2),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     E, cfg.n_features, replay_capacity=64)
    return PerceptaSystem([f"b{i}" for i in range(E)], srcs, cfg, pred,
                          speedup=1e9, manual_time=True, mode="scan",
                          scan_k=K, batched_consume=batched)

def publish(s, n_windows, rng):
    w = s.window_s
    n = n_windows * PER
    t0 = s.window_bounds(s.window_index)[0]
    for env in s.env_ids:
        for src in s.sources:
            ts = np.sort(rng.uniform(t0, t0 + n_windows * w, n))
            s.broker.publish(RecordBatch.from_columns(
                env, src.device.stream, ts, rng.normal(5, 2, n)))

QUICK = __QUICK__
N = 64 if QUICK else 96
REPS = 2 if QUICK else 3

def measure(s, rows):
    A = D = C = 0.0
    for b in range(N // K):
        bounds = [s.window_bounds(s.window_index + j) for j in range(K)]
        t0 = time.time(); raw, counts = s.assemble_windows(bounds)
        A += time.time() - t0
        t0 = time.time()
        feats, frames, td = s._dispatch_scan(raw, K)
        jax.block_until_ready(feats.features)
        D += time.time() - t0
        t0 = time.time()
        out = s._consume_scan(bounds, counts, feats, frames, td)
        C += time.time() - t0
        rows.extend({k: v for k, v in r.items() if k != "latency_s"}
                    for r in out)
    return A, D, C

# Interleaved legs + pooled A/D: the assemble and dispatch phases run
# IDENTICAL code on both twins (only the consume path differs), so their
# best-of is taken across both twins' legs — shared-box drift between
# sequentially-measured twins would otherwise pollute the share deltas.
sys_by = {"perwindow": mk(False), "batched": mk(True)}
rows_by = {}
legs = {"perwindow": [], "batched": []}
for s in sys_by.values():
    s.run_windows(K, pump=False)                 # jit/cache warmup
for rep in range(REPS):                          # identical publish seeds
    for name, s in sys_by.items():
        publish(s, N, np.random.RandomState(rep))
        rows = []
        legs[name].append(measure(s, rows))
        rows_by[name] = rows
A = min(a for ls in legs.values() for a, _, _ in ls)
D = min(d for ls in legs.values() for _, d, _ in ls)
res = {}
for name, ls in legs.items():
    C = min(c for _, _, c in ls)
    tot = A + D + C
    nb = N // K
    res[name] = {
        "phase_ms": {"assemble": round(A / nb * 1e3, 1),
                     "device": round(D / nb * 1e3, 1),
                     "consume": round(C / nb * 1e3, 1)},
        "host_share": round((A + C) / tot, 3),
        "host_assembly_frac": round(A / tot, 3),
        "consume_frac": round(C / tot, 3),
        "windows_per_s": round(N / tot, 1),
    }

ident = rows_by["perwindow"] == rows_by["batched"]
pa, pb = sys_by["perwindow"].predictor, sys_by["batched"].predictor
for x, y in zip(jax.tree.leaves(pa.replay), jax.tree.leaves(pb.replay)):
    ident = ident and bool((np.asarray(x) == np.asarray(y)).all())
ident = ident and pa.stats == pb.stats \
    and bool((pa._replay_times == pb._replay_times).all())
cpw = res["perwindow"]["phase_ms"]["consume"]
cb = res["batched"]["phase_ms"]["consume"]
print(json.dumps({
    "bit_identical": bool(ident),
    "perwindow": res["perwindow"],
    "batched": res["batched"],
    "consume_speedup": round(cpw / max(cb, 1e-9), 2),
    "cell": {"K": K, "E": E, "S": S, "T": T, "M": M,
             "records_per_stream_window": PER},
}))
"""


def bench_predictor_batch(quick=False):
    import subprocess

    import jax

    from repro.core.reward import energy_reward_spec
    from repro.runtime.predictor import (ActionSpace, Predictor,
                                         linear_policy)

    # --- identity + dispatch-cost cell (in-process, exact) ----------------
    E, F, K = 8, 8, 32

    def mkp():
        return Predictor(
            linear_policy(F, 2),
            energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
            ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
            E, F, replay_capacity=64)

    rng = np.random.RandomState(0)
    feats = rng.normal(0, 1, (K, E, F)).astype(np.float32)
    raw = rng.normal(5, 2, (K, E, F)).astype(np.float32)
    times = [60.0 * (j + 1) for j in range(K)]
    a, b = mkp(), mkp()
    seq = [a.on_tick(feats[j], times[j], raw=raw[j]) for j in range(K)]
    act, rew, per = b.on_windows(feats, times, raw=raw)
    ident = ((np.stack([s[0] for s in seq]) == act).all()
             and (np.stack([s[1] for s in seq]) == rew).all()
             and (np.stack([s[2] for s in seq]) == per).all()
             and all(bool((np.asarray(x) == np.asarray(y)).all())
                     for x, y in zip(jax.tree.leaves(a.replay),
                                     jax.tree.leaves(b.replay))))
    SUMMARY["predictor_batch_bit_identical"] = bool(ident)

    n = 4 if quick else 8
    t_pw = _time(lambda: [a.on_tick(feats[j], times[j], raw=raw[j])
                          for j in range(K)], n=n, best=True)
    t_b = _time(lambda: b.on_windows(feats, times, raw=raw), n=n, best=True)
    SUMMARY["predictor_consume_speedup"] = round(t_pw / t_b, 2)
    _row(f"predictor_batch_K{K}_E{E}", t_b / K,
         f"on_windows {1e6 / (t_b / K):.0f} windows/s (1 dispatch) | "
         f"Kx on_tick {t_pw / K:.0f} us/win | speedup {t_pw / t_b:.2f}x | "
         f"bit_identical {ident}")

    # --- before/after on the PR 3 overlap cell (subprocess) ---------------
    env = _subprocess_env("--xla_cpu_multi_thread_eigen=false")
    script = _PRED_BATCH_SCRIPT.replace("__QUICK__", str(bool(quick)))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-2000:]
    cell = json.loads(out.stdout.strip().splitlines()[-1])
    SUMMARY["predictor_batch"] = cell
    pw, bt = cell["perwindow"], cell["batched"]
    _row("predictor_batch_overlap_cell_K32_E8_S8_T64",
         1e6 / bt["windows_per_s"],
         f"{bt['windows_per_s']:.0f} windows/s | consume "
         f"{pw['phase_ms']['consume']:.1f} -> {bt['phase_ms']['consume']:.1f}"
         f" ms/batch ({cell['consume_speedup']:.1f}x) | host share "
         f"{pw['host_share']:.0%} -> {bt['host_share']:.0%} of scan wall | "
         f"bit_identical {cell['bit_identical']}")


# --------------------------------------------------------------------------
# Table 2i — host ingest fast path: arena staging + sorted-merge bucketing
#            + one-pass multi-env assembly
# --------------------------------------------------------------------------

# Phase decomposition of the PR 3 overlap cell focused on the A term: twin
# scan systems drain identical published batches, one through the legacy
# chunk-list + global-lexsort accumulator (``ingest_fastpath=False``), one
# through the arena-staged sorted-merge path (plus a 2-worker sharded
# variant). D and C run identical code on every twin, so only the assemble
# phase is compared; legs are interleaved with identical publish seeds and
# bit-identity of every output row is asserted across all three twins.
_INGEST_FASTPATH_SCRIPT = """
import json, time
import numpy as np
import jax
from repro.core import PipelineConfig
from repro.core.reward import energy_reward_spec
from repro.runtime.predictor import ActionSpace, Predictor, linear_policy
from repro.runtime.receivers import SimulatedDevice
from repro.runtime.records import RecordBatch
from repro.runtime.system import PerceptaSystem, SourceSpec

E, S, K, M = 8, 8, 32, 64
T, TICK_S, PER = 64, 15.0, 160

def mk(fast, workers=1):
    srcs = [SourceSpec(f"s{i}", "mqtt",
                       SimulatedDevice(f"st{i}", 60.0, base=3.0, seed=i))
            for i in range(S)]
    cfg = PipelineConfig(n_envs=E, n_streams=S, n_ticks=T, tick_s=TICK_S,
                         max_samples=M, harmonize_method="onehot",
                         gap_strategy="linear")
    pred = Predictor(linear_policy(S, 2),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     E, cfg.n_features, replay_capacity=64)
    return PerceptaSystem([f"b{i}" for i in range(E)], srcs, cfg, pred,
                          speedup=1e9, manual_time=True, mode="scan",
                          scan_k=K, ingest_fastpath=fast,
                          ingest_workers=workers)

def publish(s, n_windows, rng):
    # per-poll columns, time-sorted and honestly flagged -- the shape the
    # MQTT receiver hands over (it measures sortedness per poll)
    w = s.window_s
    n = n_windows * PER
    t0 = s.window_bounds(s.window_index)[0]
    for env in s.env_ids:
        for src in s.sources:
            ts = np.sort(rng.uniform(t0, t0 + n_windows * w, n))
            s.broker.publish(RecordBatch.from_columns(
                env, src.device.stream, ts, rng.normal(5, 2, n),
                sorted_ts=True))

QUICK = __QUICK__
N = 64 if QUICK else 96
REPS = 2 if QUICK else 3

def measure(s, rows):
    A = D = C = 0.0
    for b in range(N // K):
        bounds = [s.window_bounds(s.window_index + j) for j in range(K)]
        t0 = time.time(); raw, counts = s.assemble_windows(bounds)
        A += time.time() - t0
        t0 = time.time()
        feats, frames, td = s._dispatch_scan(raw, K)
        jax.block_until_ready(feats.features)
        D += time.time() - t0
        t0 = time.time()
        out = s._consume_scan(bounds, counts, feats, frames, td)
        C += time.time() - t0
        rows.extend({k: v for k, v in r.items() if k != "latency_s"}
                    for r in out)
    return A, D, C

sys_by = {"legacy": mk(False), "fast": mk(True), "fast_w2": mk(True, 2)}
rows_by = {}
legs = {name: [] for name in sys_by}
for s in sys_by.values():
    s.run_windows(K, pump=False)                 # jit/cache warmup
for rep in range(REPS):                          # identical publish seeds
    for name, s in sys_by.items():
        publish(s, N, np.random.RandomState(rep))
        rows = []
        legs[name].append(measure(s, rows))
        rows_by[name] = rows

nb = N // K
D = min(d for ls in legs.values() for _, d, _ in ls)
C = min(c for ls in legs.values() for _, _, c in ls)
a_ms = {name: round(min(a for a, _, _ in ls) / nb * 1e3, 1)
        for name, ls in legs.items()}
ident = (rows_by["fast"] == rows_by["legacy"]
         and rows_by["fast_w2"] == rows_by["legacy"])
ms = {"close_fast": 0, "close_sort": 0, "close_lexsort": 0}
for acc in sys_by["fast"].accumulators.values():
    for k, v in acc.merge_stats.items():
        ms[k] += v
for s in sys_by.values():
    s.stop()
n_records = E * S * N * PER                      # per leg, by construction
print(json.dumps({
    "bit_identical": bool(ident),
    "legacy_assemble_ms": a_ms["legacy"],
    "fast_assemble_ms": a_ms["fast"],
    "fast_w2_assemble_ms": a_ms["fast_w2"],
    "assemble_speedup": round(a_ms["legacy"] / max(a_ms["fast"], 1e-9), 2),
    # ingest throughput through the fast assemble phase alone
    "records_per_s": round(n_records / (a_ms["fast"] * 1e-3 * nb), 1),
    # every close on this cell should ride the promised-sorted fast path
    "merge_stats_fast": ms,
    "sorted_fastpath_hit_rate": round(
        ms["close_fast"] / max(sum(ms.values()), 1), 3),
    "scan_phase_ms": {"assemble": a_ms["fast"],
                      "device": round(D / nb * 1e3, 1),
                      "consume": round(C / nb * 1e3, 1)},
    "cell": {"K": K, "E": E, "S": S, "T": T, "M": M,
             "records_per_stream_window": PER},
}))
"""


def bench_ingest_fastpath(quick=False):
    import subprocess

    env = _subprocess_env("--xla_cpu_multi_thread_eigen=false")
    script = _INGEST_FASTPATH_SCRIPT.replace("__QUICK__", str(bool(quick)))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-2000:]
    cell = json.loads(out.stdout.strip().splitlines()[-1])
    SUMMARY["ingest_fastpath"] = cell
    _row("ingest_fastpath_overlap_cell_K32_E8_S8_T64",
         cell["fast_assemble_ms"] * 1e3 / cell["cell"]["K"],
         f"assemble {cell['legacy_assemble_ms']:.1f} -> "
         f"{cell['fast_assemble_ms']:.1f} ms/batch "
         f"({cell['assemble_speedup']:.1f}x; 2 workers "
         f"{cell['fast_w2_assemble_ms']:.1f}) | "
         f"{cell['records_per_s']:.0f} records/s | sorted fast-path hit "
         f"{cell['sorted_fastpath_hit_rate']:.0%} | "
         f"bit_identical {cell['bit_identical']}")


# --------------------------------------------------------------------------
# Table 2f — device-resident decision path: fused decide vs two dispatches
# --------------------------------------------------------------------------

def bench_fused_decide(quick=False):
    """Three cells for the fused decision engine:

    * identity (system level, K=32/E=8): ``scan_fused_decide`` results +
      replay export bit-identical to the two-dispatch reference;
    * acceptance (engine level, K=32/E=256 — the per-device regime): the
      fused single dispatch vs ``run_many`` + ``on_windows`` + the
      consume fetches, with phase decomposition and measured host-transfer
      bytes per batch (the fused path fetches only the small per-window
      outputs);
    * sharded (K=32/E=256 on the visible env mesh — 8 devices under
      ``--host-devices 8``): fused carry env-sharded, bit-identity vs the
      unsharded fused engine asserted.
    Legs of the acceptance cell are interleaved (ratio of totals) so
    shared-box drift cancels, same protocol as the overlap cells.
    """
    import functools

    import jax
    import jax.numpy as jnp

    from repro.core import PipelineConfig
    from repro.core import pipeline as pl
    from repro.core.frame import make_raw_window
    from repro.core.reward import energy_reward_spec
    from repro.runtime.predictor import (ActionSpace, Predictor,
                                         linear_policy)
    from repro.runtime.receivers import SimulatedDevice
    from repro.runtime.system import PerceptaSystem, SourceSpec

    # --- identity cell (system level) -------------------------------------
    def mk(mode):
        srcs = [SourceSpec(f"s{i}", "mqtt",
                           SimulatedDevice(f"st{i}", 60.0, base=3.0, seed=i))
                for i in range(8)]
        cfg = PipelineConfig(n_envs=8, n_streams=8, n_ticks=16, tick_s=60.0,
                             max_samples=64)
        pred = Predictor(
            linear_policy(8, 2),
            energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
            ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
            8, cfg.n_features, replay_capacity=64)
        return PerceptaSystem([f"b{i}" for i in range(8)], srcs, cfg, pred,
                              speedup=1e9, manual_time=True, mode=mode,
                              scan_k=32)

    n = 32 if quick else 64
    strip = lambda rs: [{k: v for k, v in r.items() if k != "latency_s"}
                        for r in rs]
    ref, fus = mk("scan"), mk("scan_fused_decide")
    ident = strip(ref.run_windows(n)) == strip(fus.run_windows(n))
    ea, eb = ref.export_replay("bench"), fus.export_replay("bench")
    for key in ("obs", "actions", "rewards", "next_obs", "tick_idx",
                "times"):
        ident = ident and bool(
            (np.asarray(ea[key]) == np.asarray(eb[key])).all())
    ref.stop(), fus.stop()
    SUMMARY["fused_decide_bit_identical"] = bool(ident)
    _row("fused_decide_identity_K32_E8_S8", 0.0,
         f"bit_identical {ident} over {n} windows "
         f"(results + rolled replay export w/ reconstructed times)")

    # --- acceptance cell: K=32, E=256, one dispatch vs two ----------------
    # the high-cadence edge regime the fused engine targets: short windows
    # (8 ticks), the Predictor's DEFAULT 4096-slot replay ring. The
    # two-dispatch path re-copies the full (E, 4096, F) ring storage every
    # on_windows dispatch (its jit cannot donate — the Predictor owns the
    # buffer across calls) and ships features + frames to the host; the
    # fused engine updates the donated ring in place and ships only the
    # small DecideBatch leaves.
    K, E, S, T, M, CAP = 32, 256, 8, 8, 16, 4096
    cfg = PipelineConfig(n_envs=E, n_streams=S, n_ticks=T, tick_s=60.0,
                         max_samples=M)
    F = cfg.n_features
    rng = np.random.RandomState(0)
    raws = make_raw_window(
        rng.normal(5, 2, (K, E, S, M)).astype(np.float32),
        rng.uniform(0, T * 60, (K, E, S, M)).astype(np.float32),
        rng.rand(K, E, S, M) > 0.3)
    starts = jnp.zeros((K, E), jnp.float32)
    times = [T * 60.0 * (j + 1) for j in range(K)]
    denom = float(E * S * T)

    def mkp():
        return Predictor(
            linear_policy(F, 2),
            energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
            ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
            E, F, replay_capacity=CAP)

    # two-dispatch reference: exactly the scan-mode Manager's device +
    # consume work (run_many, on_windows, the batch-wide fetches, the
    # per-window metric loop over the (E, S, T) frames)
    p_ref = mkp()
    pipe = pl.PerceptaPipeline(cfg, mode="scan", donate=True)
    ref_state = [pl.init_state(cfg)]
    ref_bytes = [0]

    def run_ref():
        t0 = time.time()
        ref_state[0], feats, frames = pipe.run_many(ref_state[0], raws,
                                                    starts)
        jax.block_until_ready(feats.features)
        t1 = time.time()
        acts, rews, _per = p_ref.on_windows(feats.features, times,
                                            raw=feats.raw)
        feat_np = np.asarray(feats.features)
        obs_np = np.asarray(frames.observed)
        fill_np = np.asarray(frames.filled)
        anom_np = np.asarray(frames.anomalous)
        metrics = [(float(np.mean(rews[j])), float(obs_np[j].mean()),
                    float(fill_np[j].mean()), int(anom_np[j].sum()))
                   for j in range(K)]
        ref_bytes[0] = (feat_np.nbytes + obs_np.nbytes + fill_np.nbytes
                        + anom_np.nbytes + acts.nbytes + rews.nbytes
                        + _per.nbytes)
        return t1 - t0, time.time() - t1, acts, rews, metrics

    # fused: one dispatch; the host touches only the small output leaves
    p_fus = mkp()
    from repro import compat
    engine = compat.jit_donated(
        functools.partial(pl.run_many_decide, cfg, p_fus.make_decide_fn()),
        donate_argnums=(0, 1))
    fus_state = [pl.init_state(cfg), p_fus.decide_state()]
    fus_bytes = [0]

    def run_fused():
        t0 = time.time()
        fus_state[0], fus_state[1], outs = engine(fus_state[0], fus_state[1],
                                                  raws, starts)
        jax.block_until_ready(outs.rewards)
        t1 = time.time()
        acts = np.asarray(outs.actions)
        rews = np.asarray(outs.rewards)
        viol = np.asarray(outs.violated)
        obs_c = np.asarray(outs.observed)
        fill_c = np.asarray(outs.filled)
        anom_c = np.asarray(outs.anomalous)
        p_fus.absorb_fused(times, viol)
        metrics = [(float(np.mean(rews[j])),
                    float(int(obs_c[j].sum()) / denom),
                    float(int(fill_c[j].sum()) / denom),
                    int(anom_c[j].sum()))
                   for j in range(K)]
        fus_bytes[0] = (acts.nbytes + rews.nbytes + viol.nbytes
                        + obs_c.nbytes + fill_c.nbytes + anom_c.nbytes)
        return t1 - t0, time.time() - t1, acts, rews, metrics

    # warmup + engine-level bit-identity (fresh twin states)
    _, _, a_ref, r_ref, m_ref = run_ref()
    _, _, a_fus, r_fus, m_fus = run_fused()
    cell_ident = (bool((a_ref == a_fus).all())
                  and bool((r_ref == r_fus).all()) and m_ref == m_fus)

    # interleaved pairs; the headline speedup is the MEDIAN of per-pair
    # ratios (same protocol as the overlap cells: shared-box throughput
    # drifts on minute timescales, and a couple of congested pairs poison
    # a ratio of totals but not a median)
    pairs = 4 if quick else 8
    legs = {"ref": [0.0, 0.0], "fused": [0.0, 0.0]}
    ratios = []
    nb = 0
    for _pair in range(pairs):
        d, c, *_ = run_ref()
        legs["ref"][0] += d
        legs["ref"][1] += c
        d2, c2, *_ = run_fused()
        legs["fused"][0] += d2
        legs["fused"][1] += c2
        ratios.append((d + c) / (d2 + c2))
        nb += 1
    tot_ref = sum(legs["ref"])
    tot_fus = sum(legs["fused"])
    wps_ref = K * nb / tot_ref
    wps_fus = K * nb / tot_fus
    speedup = float(np.median(ratios))
    xfer_ratio = ref_bytes[0] / max(fus_bytes[0], 1)
    SUMMARY["windows_per_s"]["fused_decide_two_dispatch_E256"] = \
        round(wps_ref, 1)
    SUMMARY["windows_per_s"]["fused_decide_E256"] = round(wps_fus, 1)
    SUMMARY["fused_decide"] = {
        "cell": {"K": K, "E": E, "S": S, "T": T, "M": M,
                 "replay_capacity": CAP},
        "bit_identical": cell_ident,
        "speedup": round(speedup, 2),
        "speedup_ratio_of_totals": round(tot_ref / tot_fus, 2),
        "pair_ratios": [round(r, 2) for r in ratios],
        "phase_ms_two_dispatch": {
            "device": round(legs["ref"][0] / nb * 1e3, 1),
            "consume": round(legs["ref"][1] / nb * 1e3, 1)},
        "phase_ms_fused": {
            "device": round(legs["fused"][0] / nb * 1e3, 1),
            "consume": round(legs["fused"][1] / nb * 1e3, 1)},
        "host_transfer_bytes_two_dispatch": int(ref_bytes[0]),
        "host_transfer_bytes_fused": int(fus_bytes[0]),
        "host_transfer_reduction": round(xfer_ratio, 1),
    }
    _row(f"fused_decide_K{K}_E{E}", 1e6 / wps_fus,
         f"{wps_fus:.0f} windows/s (1 dispatch end-to-end) vs "
         f"{wps_ref:.0f} two-dispatch | speedup {speedup:.2f}x "
         f"(median of {nb} interleaved pair ratios; ratio of totals "
         f"{tot_ref / tot_fus:.2f}x) | host transfer "
         f"{ref_bytes[0] / 2**20:.2f} -> "
         f"{fus_bytes[0] / 2**20:.3f} MiB/batch ({xfer_ratio:.0f}x less) | "
         f"bit_identical {cell_ident}")

    # --- sharded cell: E=256 on the visible env mesh ----------------------
    # measured with the SAME estimator as the unsharded fused cell
    # (interleaved legs, ratio of totals) so the recorded sharded-vs-fused
    # ratio doesn't mix a best-of min against drift-inclusive totals
    p_sh = mkp()
    sh_engine, mesh = pl.make_run_many_decide_sharded(
        cfg, p_sh.make_decide_fn(), p_sh.decide_state())
    sh_engine = compat.jit_donated(sh_engine, donate_argnums=(0, 1))
    sh_state = [pl.init_state(cfg), p_sh.decide_state()]

    def run_sharded():
        t0 = time.time()
        sh_state[0], sh_state[1], outs = sh_engine(sh_state[0], sh_state[1],
                                                   raws, starts)
        jax.block_until_ready(outs.rewards)
        return time.time() - t0, outs

    _, outs_sh = run_sharded()       # warmup + identity vs unsharded fused
    sh_ident = bool((np.asarray(outs_sh.actions) == a_fus).all())
    run_sharded()                    # second warmup: the first donated
    #                                  re-dispatch can trigger a slow lazy
    #                                  XLA path; exclude it like a compile
    pairs_sh = 4 if quick else 8
    tot_f2 = tot_sh = 0.0
    sh_ratios = []
    for _pair in range(pairs_sh):
        d, c, *_ = run_fused()
        tot_f2 += d + c
        dt, _ = run_sharded()
        tot_sh += dt
        sh_ratios.append((d + c) / dt)
    wps_sh = K * pairs_sh / tot_sh
    mesh_speedup = float(np.median(sh_ratios))
    mesh_n = int(np.prod(list(mesh.shape.values())))
    SUMMARY["windows_per_s"]["fused_decide_sharded_E256"] = round(wps_sh, 1)
    SUMMARY["fused_decide_sharded_bit_identical"] = sh_ident
    SUMMARY["fused_decide_mesh_speedup"] = round(mesh_speedup, 2)
    _row(f"fused_decide_sharded_K{K}_E{E}", 1e6 / wps_sh,
         f"{wps_sh:.0f} windows/s | {mesh_n}-device env mesh "
         f"({E // mesh_n} envs/device) | {mesh_speedup:.2f}x vs unsharded "
         f"fused (median of {pairs_sh} interleaved pair ratios) | "
         f"bit_identical-to-fused {sh_ident}")


def bench_contract_check(quick=False):
    """Construction-overhead guard for the PR 6 invariant gate: the jaxpr
    contract check that ``PerceptaSystem`` runs for fused/``_sharded``
    modes must add <1% to standing a fused system up (construction through
    the first K-batch dispatch — bare ``__init__`` is single-digit ms, so
    the meaningful denominator is the time to a RUNNING system, which the
    first dispatch's compile dominates).

    Two estimators, both min-of-reps (shared-box robust):

    * direct — ``analysis.check_system`` on a live system's freshly built
      ``DecideFns`` (fresh closures, so no trace-cache hits: exactly the
      cold construction-time cost). This is the asserted number.
    * paired — interleaved ``contract_check=True`` vs ``False``
      construction-to-first-dispatch legs, reported for context (its
      delta is compile-time noise plus the check).
    """
    import time as _time

    from repro import analysis
    from repro.core import PipelineConfig
    from repro.core.reward import energy_reward_spec
    from repro.runtime.predictor import (ActionSpace, Predictor,
                                         linear_policy)
    from repro.runtime.receivers import SimulatedDevice
    from repro.runtime.system import PerceptaSystem, SourceSpec

    # the fused acceptance regime (same shapes as the fused_decide cell)
    K, E, S, T, M, CAP = 32, 256, 8, 8, 16, 4096

    def stand_up(check):
        srcs = [SourceSpec(f"s{i}", "mqtt",
                           SimulatedDevice(f"st{i}", 60.0, base=3.0, seed=i))
                for i in range(S)]
        cfg = PipelineConfig(n_envs=E, n_streams=S, n_ticks=T, tick_s=60.0,
                             max_samples=M)
        pred = Predictor(
            linear_policy(S, 2),
            energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
            ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
            E, cfg.n_features, replay_capacity=CAP)
        t0 = _time.perf_counter()
        s = PerceptaSystem([f"b{i}" for i in range(E)], srcs, cfg, pred,
                           speedup=1e9, manual_time=True,
                           mode="scan_fused_decide", scan_k=K,
                           contract_check=check)
        s.run_windows(K)
        return s, _time.perf_counter() - t0

    stand_up(True)[0].stop()      # process warmup (imports, jit plumbing)
    reps = 2 if quick else 3
    base, checked, direct = [], [], []
    for _ in range(reps):
        s, dt = stand_up(False)
        base.append(dt)
        # cold check cost on THIS system: fresh DecideFns closures miss
        # every trace cache, reproducing the construction-time call
        d = s.predictor.make_decide_fn()
        t0 = _time.perf_counter()
        analysis.check_system(s.predictor, decide=d, dstate=s._dstate,
                              sharded=False)
        direct.append(_time.perf_counter() - t0)
        s.stop()
        s, dt = stand_up(True)
        checked.append(dt)
        s.stop()

    check_ms = min(direct) * 1e3
    base_s = min(base)
    pct = 100.0 * min(direct) / base_s
    paired_pct = 100.0 * (min(checked) - base_s) / base_s
    SUMMARY["contract_check"] = {
        "check_ms": round(check_ms, 1),
        "standup_s": round(base_s, 3),
        "overhead_pct": round(pct, 3),
        "paired_pct": round(paired_pct, 3),
    }
    _row(f"contract_check_K{K}_E{E}", check_ms * 1e3,
         f"{check_ms:.1f} ms cold check | {pct:.2f}% of the {base_s:.2f}s "
         f"construction-to-first-dispatch standup (paired delta "
         f"{paired_pct:+.2f}%) | budget <1%")
    assert pct < 1.0, (
        f"construction-time contract check costs {pct:.2f}% of fused-mode "
        f"system standup ({check_ms:.1f} ms / {base_s:.2f} s) — over the "
        "1% budget")


def bench_certify(quick=False):
    """Certification-cost cells for the PR 8 policy registry gate
    (``runtime.policies`` -> ``analysis.certify``):

    * cold — ``certify_policy`` over every registered policy with a
      cleared cache: trace + full rule walk (recurrent-carry fixed point,
      pallas BlockSpec recursion) + the two-env-count param-replication
      probe, per policy. This is the one-time cost a registry policy pays
      the FIRST time it is stood up in a process.
    * cached — the certificate-cache hit every repeated standup of the
      same policy pays instead (the construction path of
      ``PerceptaSystem(..., policy=...)``), measured against the fused
      acceptance-regime standup (K=32, E=256, construction through the
      first K-batch dispatch): must add <1% (asserted — mirroring the
      PR 6 contract-check budget).
    """
    import time as _time

    from repro.analysis import certify
    from repro.core import PipelineConfig
    from repro.core.reward import energy_reward_spec
    from repro.runtime.policies import POLICIES
    from repro.runtime.predictor import ActionSpace, Predictor
    from repro.runtime.receivers import SimulatedDevice
    from repro.runtime.system import PerceptaSystem, SourceSpec

    # cold path: full-catalog certification of the whole registry
    certify.clear_cache()
    cold = {}
    for key, builder in POLICIES.items():
        t0 = _time.perf_counter()
        certify.certify_policy(builder, name=key)
        cold[key] = (_time.perf_counter() - t0) * 1e3
    cold_ms = sum(cold.values())

    # cached path: populate once, then time the hits (the repeated-standup
    # cost — certify_policy returns the stored certificate by key)
    for key, builder in POLICIES.items():
        certify.certify_policy(builder, name=key, cache_key=("bench", key))
    t0 = _time.perf_counter()
    for key, builder in POLICIES.items():
        certify.certify_policy(builder, name=key, cache_key=("bench", key))
    cached_ms = (_time.perf_counter() - t0) * 1e3

    # denominator: standing up a REAL registry policy ("rglru", stateful
    # carry in the fused scan) at the fused acceptance regime; the
    # predictor resolves the name through build_policy, so construction
    # itself exercises the cached certification path after the warmup
    K, E, S, T, M, CAP = 32, 256, 8, 8, 16, 4096

    def stand_up():
        srcs = [SourceSpec(f"s{i}", "mqtt",
                           SimulatedDevice(f"st{i}", 60.0, base=3.0, seed=i))
                for i in range(S)]
        cfg = PipelineConfig(n_envs=E, n_streams=S, n_ticks=T, tick_s=60.0,
                             max_samples=M)
        pred = Predictor(
            "rglru",
            energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
            ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
            E, cfg.n_features, replay_capacity=CAP)
        t0 = _time.perf_counter()
        s = PerceptaSystem([f"b{i}" for i in range(E)], srcs, cfg, pred,
                           speedup=1e9, manual_time=True,
                           mode="scan_fused_decide", scan_k=K)
        s.run_windows(K)
        return s, _time.perf_counter() - t0

    stand_up()[0].stop()          # warmup (jit plumbing + the F-probe cache)
    reps = 1 if quick else 2
    standups = []
    for _ in range(reps):
        s, dt = stand_up()
        standups.append(dt)
        s.stop()
    base_s = min(standups)
    pct = 100.0 * (cached_ms / 1e3) / base_s
    cold_pct = 100.0 * (cold_ms / 1e3) / base_s
    SUMMARY["certify"] = {
        "cold_ms": {k: round(v, 1) for k, v in cold.items()},
        "cold_total_ms": round(cold_ms, 1),
        "cached_ms": round(cached_ms, 3),
        "standup_s": round(base_s, 3),
        "cached_overhead_pct": round(pct, 4),
        "cold_overhead_pct": round(cold_pct, 2),
    }
    _row(f"certify_cold_{len(POLICIES)}policies", cold_ms * 1e3,
         " | ".join(f"{k} {v:.0f} ms" for k, v in cold.items())
         + " | full catalog, cleared cache")
    _row(f"certify_cached_K{K}_E{E}", cached_ms * 1e3,
         f"{cached_ms:.2f} ms for all {len(POLICIES)} cache hits | "
         f"{pct:.3f}% of the {base_s:.2f}s rglru fused standup "
         f"(cold would be {cold_pct:.1f}%) | budget <1%")
    assert pct < 1.0, (
        f"cached policy certification costs {pct:.3f}% of fused-mode "
        f"system standup ({cached_ms:.2f} ms / {base_s:.2f} s) — over the "
        "1% budget")


def bench_online_train(quick=False):
    """Two cells for the device-resident online retraining path (PR 7):

    * sample+update (full E=256 x C=4096 ring, F=8, A=4): the jitted
      ``sample_device`` + AdamW step — ONE dispatch touching only
      ``batch`` sampled rows — vs the host round-trip it replaces:
      ``export_for_training`` (full-ring device->host copy, chronological
      roll, env-id anonymization) + numpy minibatch gather + the same
      closed-form TD gradients and AdamW in numpy. Acceptance: the
      device step >= 3x the export path.
    * overlapped serving (the K=32/E=256 fused cell): windows/s of the
      fused decide engine driving the trainer's batch-boundary protocol
      (``apply_pending`` before the dispatch, ``dispatch`` after) ON vs
      OFF — the train step rides the dispatch bubble, so the serving
      cost bound is <= 10%.
    Both cells interleave their legs and report the MEDIAN of per-pair
    ratios (the shared-box drift protocol of the overlap cells).
    """
    import functools

    import jax
    import jax.numpy as jnp

    from repro import compat
    from repro.core import PipelineConfig
    from repro.core import pipeline as pl
    from repro.core import replay as rp
    from repro.core.frame import make_raw_window
    from repro.core.reward import energy_reward_spec
    from repro.runtime.predictor import (ActionSpace, Predictor,
                                         linear_policy)
    from repro.runtime.trainer import OnlineTrainer, default_train_cfg

    # --- cell i: device sample+update vs host export + numpy update -------
    E, CAP, F, A, B = 256, 4096, 8, 4, 256
    cfg_t = default_train_cfg()
    rngn = np.random.RandomState(0)
    pred = Predictor(linear_policy(F, A),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.full(A, -1.0), np.full(A, 1.0)),
                     E, F, replay_capacity=CAP)
    trainer = OnlineTrainer(pred, batch_size=B, train_cfg=cfg_t)
    # fill the ring in one scatter (CAP ticks of E envs)
    buf = rp.add_batch(
        rp.init(E, CAP, F, A),
        jnp.asarray(rngn.normal(0, 1, (CAP, E, F)), jnp.float32),
        jnp.asarray(rngn.uniform(-1, 1, (CAP, E, A)), jnp.float32),
        jnp.asarray(rngn.normal(0, 2, (CAP, E)), jnp.float32),
        jnp.asarray(rngn.normal(0, 1, (CAP, E, F)), jnp.float32),
        jnp.arange(CAP, dtype=jnp.int32))
    jax.block_until_ready(buf.obs)

    steps = 2 if quick else 4
    dev = [pred.policy_params, trainer.train_state]
    key = [jax.random.PRNGKey(0)]

    def run_device():
        t0 = time.time()
        for _ in range(steps):
            key[0], sub = jax.random.split(key[0])
            p, st, loss, gn, hd = trainer.step_fn(dev[0], dev[1], buf, sub)
            dev[0], dev[1] = p, st
        jax.block_until_ready(dev[0]["w"])
        return time.time() - t0

    # numpy mirror of the SAME update: closed-form grads of td_loss
    # (critic regression + 0.1 * policy-through-critic) + global-norm
    # clip + AdamW with the same schedule (train/optimizer.py)
    env_ids = [f"env-{i}" for i in range(E)]
    hrng = np.random.RandomState(1)
    h = {"w": np.asarray(pred.policy_params["w"], np.float32).copy(),
         "qw": np.zeros(F + A, np.float32), "qb": np.float32(0.0)}
    hm = {k: np.zeros_like(v) for k, v in h.items()}
    hv = {k: np.zeros_like(v) for k, v in h.items()}
    hstep = [0]

    def run_host():
        t0 = time.time()
        for _ in range(steps):
            exp = rp.export_for_training(buf, env_ids, "bench")
            obs = np.asarray(exp["obs"]).reshape(-1, F)
            acts = np.asarray(exp["actions"]).reshape(-1, A)
            rews = np.asarray(exp["rewards"]).reshape(-1)
            idx = hrng.randint(0, obs.shape[0], B)
            o, a, r = obs[idx], acts[idx], rews[idx]
            X = np.concatenate([o, a], 1)
            e = X @ h["qw"] + h["qb"] - r
            a_pi = np.tanh(o @ h["w"])
            Xp = np.concatenate([o, a_pi], 1)
            g = {"qw": 2.0 / B * X.T @ e - 0.1 / B * Xp.sum(0),
                 "qb": np.float32(2.0 / B * e.sum() - 0.1),
                 "w": -0.1 / B * o.T @ ((1 - a_pi ** 2)
                                        * h["qw"][F:][None, :])}
            gn = np.sqrt(sum(float((x ** 2).sum()) for x in g.values()))
            scale = min(1.0, cfg_t.grad_clip / max(gn, 1e-12))
            hstep[0] += 1
            s = hstep[0]
            t = np.clip((s - cfg_t.warmup_steps)
                        / max(cfg_t.total_steps - cfg_t.warmup_steps, 1),
                        0.0, 1.0)
            lr = cfg_t.learning_rate * (0.1 + 0.9 * 0.5
                                        * (1 + np.cos(np.pi * t)))
            c1 = 1 - cfg_t.beta1 ** s
            c2 = 1 - cfg_t.beta2 ** s
            for k2 in h:
                gk = g[k2] * scale
                hm[k2] = cfg_t.beta1 * hm[k2] + (1 - cfg_t.beta1) * gk
                hv[k2] = cfg_t.beta2 * hv[k2] + (1 - cfg_t.beta2) * gk ** 2
                h[k2] = h[k2] - lr * ((hm[k2] / c1)
                                      / (np.sqrt(hv[k2] / c2) + cfg_t.eps))
        return time.time() - t0

    run_device(), run_host()          # warmup (compile / first export)
    pairs = 3 if quick else 5
    t_dev = t_host = 0.0
    ratios = []
    for _pair in range(pairs):
        th = run_host()
        td = run_device()
        t_host += th
        t_dev += td
        ratios.append(th / td)
    speedup = float(np.median(ratios))
    dev_ms = t_dev / (pairs * steps) * 1e3
    host_ms = t_host / (pairs * steps) * 1e3
    assert np.isfinite(h["w"]).all() and np.isfinite(
        np.asarray(dev[0]["w"])).all()
    SUMMARY["online_train"] = {
        "cell": {"E": E, "capacity": CAP, "F": F, "A": A, "batch": B},
        "device_step_ms": round(dev_ms, 2),
        "host_export_step_ms": round(host_ms, 2),
        "speedup": round(speedup, 2),
        "pair_ratios": [round(r, 2) for r in ratios],
    }
    _row(f"online_train_sample_update_E{E}_C{CAP}", dev_ms * 1e3,
         f"{dev_ms:.2f} ms device sample+update vs {host_ms:.1f} ms "
         f"export+numpy | {speedup:.1f}x (median of {pairs} interleaved "
         f"pair ratios) | acceptance >=3x")

    # --- cell ii: serving windows/s with overlapped training on vs off ---
    K, E2, S, T, M = 32, 256, 8, 8, 16
    cfg = PipelineConfig(n_envs=E2, n_streams=S, n_ticks=T, tick_s=60.0,
                         max_samples=M)
    F2 = cfg.n_features
    raws = make_raw_window(
        rngn.normal(5, 2, (K, E2, S, M)).astype(np.float32),
        rngn.uniform(0, T * 60, (K, E2, S, M)).astype(np.float32),
        rngn.rand(K, E2, S, M) > 0.3)
    starts = jnp.zeros((K, E2), jnp.float32)

    def mk_leg(train):
        p = Predictor(
            linear_policy(F2, 2),
            energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
            ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
            E2, F2, replay_capacity=4096)
        engine = compat.jit_donated(
            functools.partial(pl.run_many_decide, cfg, p.make_decide_fn()),
            donate_argnums=(0, 1))
        tr = OnlineTrainer(p, batch_size=B) if train else None
        state = [pl.init_state(cfg), p.decide_state()]

        def run():
            # the system's batch-boundary protocol (runtime/trainer.py
            # timeline): adopt the previous train result, serve, enqueue
            # the next train step behind the decide dispatch
            t0 = time.time()
            if tr is not None:
                state[1] = tr.apply_pending(state[1])
            state[0], state[1], outs = engine(state[0], state[1], raws,
                                              starts)
            if tr is not None:
                tr.dispatch(state[1])
            jax.block_until_ready(outs.rewards)
            # host consume of the small output leaves (fused-cell shape)
            rews = np.asarray(outs.rewards)
            _ = (np.asarray(outs.actions), np.asarray(outs.violated),
                 [float(np.mean(rews[j])) for j in range(K)])
            return time.time() - t0

        return run, (lambda: tr.train_stats() if tr else None)

    run_off, _ = mk_leg(train=False)
    run_on, stats_on = mk_leg(train=True)
    run_off(), run_on(), run_off(), run_on()     # warmup + donated redispatch
    pairs2 = 4 if quick else 8
    tot_off = tot_on = 0.0
    oh_ratios = []
    for _pair in range(pairs2):
        a_t = run_off()
        b_t = run_on()
        tot_off += a_t
        tot_on += b_t
        oh_ratios.append(b_t / a_t)
    wps_off = K * pairs2 / tot_off
    wps_on = K * pairs2 / tot_on
    overhead = float(np.median(oh_ratios))
    st = stats_on()
    SUMMARY["windows_per_s"]["fused_decide_train_off_E256"] = \
        round(wps_off, 1)
    SUMMARY["windows_per_s"]["fused_decide_train_on_E256"] = round(wps_on, 1)
    SUMMARY["online_train"]["overlap"] = {
        "overhead_ratio": round(overhead, 3),
        "pair_ratios": [round(r, 2) for r in oh_ratios],
        "train_steps_applied": st["applied"],
        "policy_version": st["version"],
    }
    _row(f"online_train_overlap_K{K}_E{E2}", 1e6 / wps_on,
         f"{wps_on:.0f} windows/s training-on vs {wps_off:.0f} off | "
         f"overhead {overhead:.3f}x (median of {pairs2} interleaved pair "
         f"ratios) | {st['applied']} updates applied, policy_version "
         f"{st['version']} | acceptance <=1.10x")


# --------------------------------------------------------------------------
# Table 2h — elastic slot pool: masked overhead at 75% occupancy + regrow
# --------------------------------------------------------------------------

def bench_elastic(quick=False):
    """Three cells for the elastic env-slot pool (PR 9):

    * identity: an elastic system holding 6 live envs in an 8-slot pool is
      bit-identical (per-window results + replay export) to a dense E=6
      fixed system over the same envs/streams;
    * overhead: interleaved batch pairs, elastic-under-churn vs the dense
      baseline — each pair the elastic system detaches one env and
      re-attaches it into the recycled slot (membership churn at a batch
      boundary, no retrace), and the MEDIAN per-pair wall ratio must stay
      <=1.10x (the 2 masked dead rows + mask select cost <10%);
    * regrow: one timed :meth:`resize` (8 -> 16 slots — pad, re-place,
      the single allowed retrace), then a post-regrow batch must produce
      finite stats on the surviving rows.
    """
    from repro.core import PipelineConfig
    from repro.core.reward import energy_reward_spec
    from repro.runtime.predictor import (ActionSpace, Predictor,
                                         linear_policy)
    from repro.runtime.receivers import SimulatedDevice
    from repro.runtime.system import PerceptaSystem, SourceSpec

    SLOTS, ACTIVE, K = 8, 6, 8

    def mk(env_ids, slots=None, elastic=False):
        # off-tick intervals (9.7 / 31.3 s) so no reading lands exactly on
        # a window boundary (the float-boundary hazard the tests avoid too)
        srcs = [SourceSpec("grid_kw", "mqtt",
                           SimulatedDevice("grid", 9.7, base=3.0, seed=1)),
                SourceSpec("price_eur", "http",
                           SimulatedDevice("price", 31.3, base=0.2, seed=2))]
        n = slots if slots is not None else len(env_ids)
        cfg = PipelineConfig(n_envs=n, n_streams=2, n_ticks=8, tick_s=60.0,
                             max_samples=32)
        pred = Predictor(
            linear_policy(cfg.n_features, 2),
            energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
            ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
            n, cfg.n_features, replay_capacity=64)
        return PerceptaSystem(list(env_ids), srcs, cfg, pred,
                              speedup=5000.0, manual_time=True,
                              mode="scan_fused_decide", scan_k=K,
                              env_slots=slots, elastic=elastic)

    ids = [f"e{i}" for i in range(ACTIVE)]
    dense = mk(ids)
    el = mk(ids, slots=SLOTS, elastic=True)

    # --- identity: 6 live rows of 8 vs a dense E=6 system -----------------
    nwin = 2 * K if quick else 4 * K
    strip = lambda rs: [{k: v for k, v in r.items() if k != "latency_s"}
                        for r in rs]
    ident = strip(dense.run_windows(nwin)) == strip(el.run_windows(nwin))
    ea, eb = dense.export_replay("bench"), el.export_replay("bench")
    for key in ("obs", "actions", "rewards", "next_obs", "tick_idx"):
        ident = ident and bool(
            (np.asarray(ea[key])[:ACTIVE]
             == np.asarray(eb[key])[:ACTIVE]).all())
    SUMMARY["elastic_bit_identical"] = bool(ident)
    _row(f"elastic_identity_E{ACTIVE}_of_{SLOTS}", 0.0,
         f"bit_identical {ident} over {nwin} windows "
         f"(results + replay export, dense E={ACTIVE} reference)")

    # --- overhead under churn: interleaved pairs, median ratio ------------
    pairs = 3 if quick else 6
    tot_d = tot_e = 0.0
    ratios = []
    for p in range(pairs):
        t0 = time.time()
        dense.run_windows(K)
        d_t = time.time() - t0
        t0 = time.time()
        el.run_windows(K)
        e_t = time.time() - t0
        tot_d += d_t
        tot_e += e_t
        ratios.append(e_t / d_t)
        # churn at the batch boundary: detach one env, re-attach it into
        # the recycled slot (occupancy stays at ACTIVE/SLOTS, no retrace)
        victim = ids[p % ACTIVE]
        el.detach_env(victim)
        el.attach_env(victim)
    overhead = float(np.median(ratios))
    wps_d = K * pairs / tot_d
    wps_e = K * pairs / tot_e
    assert overhead <= 1.10, \
        f"masked slot-pool overhead {overhead:.3f}x > 1.10x acceptance"
    SUMMARY["windows_per_s"][f"elastic_E{ACTIVE}_of_{SLOTS}"] = \
        round(wps_e, 1)
    SUMMARY["windows_per_s"][f"elastic_dense_ref_E{ACTIVE}"] = \
        round(wps_d, 1)

    # --- regrow: one timed resize (8 -> 16), finite stats after -----------
    t0 = time.time()
    new_slots = el.resize()
    regrow_s = time.time() - t0
    post = el.run_windows(K)
    finite = all(np.isfinite(r["mean_reward"]) for r in post)
    dense.stop(), el.stop()
    SUMMARY["elastic"] = {
        "cell": {"slots": SLOTS, "active": ACTIVE, "K": K,
                 "occupancy": round(ACTIVE / SLOTS, 2)},
        "overhead_ratio": round(overhead, 3),
        "pair_ratios": [round(r, 2) for r in ratios],
        "churn_ops_per_pair": 2,
        "regrow_ms": round(regrow_s * 1e3, 1),
        "regrow_slots": [SLOTS, new_slots],
        "finite_after_regrow": bool(finite),
    }
    _row(f"elastic_overhead_E{ACTIVE}_of_{SLOTS}", 1e6 / wps_e,
         f"{wps_e:.0f} windows/s masked pool vs {wps_d:.0f} dense | "
         f"overhead {overhead:.3f}x (median of {pairs} interleaved pair "
         f"ratios, 1 detach+reattach churn per pair) | acceptance <=1.10x")
    _row(f"elastic_regrow_{SLOTS}_to_{new_slots}", regrow_s * 1e6,
         f"pool regrow {SLOTS} -> {new_slots} slots in "
         f"{regrow_s * 1e3:.0f} ms (pad + re-place + 1 retrace) | "
         f"finite_after_regrow {finite}")


def bench_autotune(quick=False):
    import jax

    from repro.core import PipelineConfig
    from repro.core.autotune import tune_scan_params

    cfg = PipelineConfig(n_envs=8, n_streams=8, n_ticks=16, tick_s=60.0,
                         max_samples=64)
    ndev = len(jax.devices())
    # short grid: windows-per-dispatch x env-mesh split (1 = plain scan,
    # ndev = the full forced mesh when bench-smoke runs --host-devices 8)
    counts = [1] if ndev == 1 else [1, min(8, ndev)]
    res = tune_scan_params(cfg, k_grid=(8, 32) if quick else (8, 16, 32),
                           device_counts=counts, reps=2 if quick else 3)
    optimum = max(w for _, _, w in res.grid)
    # acceptance is a fresh INDEPENDENT re-measurement of the chosen cell
    # (selection is the grid argmax by construction, so comparing it to its
    # own grid would be tautological): the chosen config re-measured on new
    # timings must still be within 10% of the calibration-grid optimum
    recheck = tune_scan_params(cfg, k_grid=(res.scan_k,),
                               device_counts=[res.mesh_devices],
                               reps=2 if quick else 3)
    within = recheck.best_windows_per_s >= 0.9 * optimum
    SUMMARY["autotune"] = res.as_dict() | {
        "remeasured_windows_per_s": round(recheck.best_windows_per_s, 1),
        "within_10pct_of_optimum": within}
    _row("autotune_scan_params", 1e6 / res.best_windows_per_s,
         f"chose scan_k={res.scan_k} mesh_devices={res.mesh_devices} "
         f"({res.best_windows_per_s:.0f} windows/s) over "
         f"{len(res.grid)}-cell grid | re-measured "
         f"{recheck.best_windows_per_s:.0f} windows/s, within 10% of grid "
         f"optimum: {within}")


# --------------------------------------------------------------------------
# Table 1b — columnar (RecordBatch) vs per-record host ingest + assembly
# --------------------------------------------------------------------------

class _LegacyAccumulator:
    """The seed's per-record ingest/close loop, kept verbatim as the
    benchmark baseline the columnar Accumulator is measured against."""

    def __init__(self, env_id, streams, max_samples):
        from collections import defaultdict
        self.env_id = env_id
        self.streams = list(streams)
        self.stream_index = {s: i for i, s in enumerate(self.streams)}
        self.max_samples = max_samples
        self._pending = defaultdict(list)
        self.stats = {"records": 0, "unknown_stream": 0, "overflow": 0}

    def ingest(self, records):
        for r in records:
            idx = self.stream_index.get(r.stream)
            if idx is None:
                self.stats["unknown_stream"] += 1
                continue
            self.stats["records"] += 1
            self._pending[idx].append(r)

    def close_window(self, t_start, t_end):
        S, M = len(self.streams), self.max_samples
        values = np.zeros((S, M), np.float32)
        ts = np.zeros((S, M), np.float32)
        valid = np.zeros((S, M), bool)
        for s in range(S):
            recs = self._pending.get(s, [])
            take, keep = [], []
            for r in recs:
                (take if r.timestamp < t_end else keep).append(r)
            self._pending[s] = keep
            take.sort(key=lambda r: r.timestamp)
            if len(take) > M:
                self.stats["overflow"] += len(take) - M
                take = take[-M:]
            for j, r in enumerate(take):
                values[s, j] = r.value
                ts[s, j] = r.timestamp
                valid[s, j] = r.timestamp >= t_start
        return values, ts, valid

    def close_windows(self, bounds):
        K, S, M = len(bounds), len(self.streams), self.max_samples
        values = np.zeros((K, S, M), np.float32)
        ts = np.zeros((K, S, M), np.float32)
        valid = np.zeros((K, S, M), bool)
        for k, (t0, t1) in enumerate(bounds):
            values[k], ts[k], valid[k] = self.close_window(t0, t1)
        return values, ts, valid


def bench_columnar_ingest(quick=False):
    from repro.runtime.accumulator import Accumulator
    from repro.runtime.records import Record, RecordBatch

    K, E, S, M = 32, 8, 8, 64
    per_sw = 16 if quick else 48        # records per (stream, window)
    window_s = 16 * 60.0
    bounds = [(k * window_s, (k + 1) * window_s) for k in range(K)]
    streams = [f"s{i}" for i in range(S)]
    rng = np.random.RandomState(0)

    # one out-of-order record stream per env (same data to both paths)
    n = K * S * per_sw
    sid = np.tile(np.arange(S, dtype=np.int32), n // S)
    ts = rng.uniform(0, K * window_s, n)
    vs = rng.normal(5, 2, n)
    recs = [Record("env", streams[int(s)], float(t), float(v))
            for s, t, v in zip(sid, ts, vs)]
    batch = RecordBatch("env", tuple(streams), sid, ts, vs)

    def run_legacy():
        for _ in range(E):
            acc = _LegacyAccumulator("env", streams, M)
            acc.ingest(recs)
            acc.close_windows(bounds)

    def run_columnar():
        for _ in range(E):
            acc = Accumulator("env", streams, M)
            acc.ingest_batch(batch)
            acc.close_windows(bounds)

    # bit-for-bit parity of the measured paths
    a, b = _LegacyAccumulator("env", streams, M), Accumulator("env", streams, M)
    a.ingest(recs)
    b.ingest_batch(batch)
    ok = all((x == y).all() for x, y in zip(a.close_windows(bounds),
                                            b.close_windows(bounds)))

    reps = 2 if quick else 4
    t_leg = _time(run_legacy, n=reps, warmup=1, best=True)
    t_col = _time(run_columnar, n=reps, warmup=1, best=True)
    total = n * E
    rps_leg = total / (t_leg / 1e6)
    rps_col = total / (t_col / 1e6)
    SUMMARY["records_per_s"]["legacy"] = round(rps_leg, 0)
    SUMMARY["records_per_s"]["columnar"] = round(rps_col, 0)
    SUMMARY["records_per_s"]["speedup"] = round(rps_col / rps_leg, 2)
    SUMMARY["columnar_bit_identical"] = bool(ok)
    _row(f"ingest_legacy_K{K}_E{E}_S{S}", t_leg / total,
         f"{rps_leg:.0f} records/s (per-record loop)")
    _row(f"ingest_columnar_K{K}_E{E}_S{S}", t_col / total,
         f"{rps_col:.0f} records/s | speedup {rps_col / rps_leg:.2f}x | "
         f"bit_identical {ok}")


# --------------------------------------------------------------------------
# Table 3 — per-stage cost + CPU/RSS across stress levels
# --------------------------------------------------------------------------

def bench_stage_breakdown(quick=False):
    import functools

    import jax
    import jax.numpy as jnp
    import psutil

    from repro.core import PipelineConfig
    from repro.core import pipeline as pl
    from repro.core.frame import make_raw_window

    E, S, T, M = (256, 8, 16, 64)
    cfg = PipelineConfig(n_envs=E, n_streams=S, n_ticks=T, tick_s=60.0,
                         max_samples=M)
    state = pl.init_state(cfg)
    rng = np.random.RandomState(0)
    raw = make_raw_window(rng.normal(5, 2, (E, S, M)).astype(np.float32),
                          rng.uniform(0, T * 60, (E, S, M)).astype(np.float32),
                          rng.rand(E, S, M) > 0.3)
    ws = jnp.zeros((E,), jnp.float32)

    h = jax.jit(functools.partial(pl.stage_harmonize, cfg))
    v, obs, ticks = jax.block_until_ready(h(state, raw, ws))
    a = jax.jit(functools.partial(pl.stage_anomaly, cfg))
    va, oa, rep, na = jax.block_until_ready(a(state, v, obs))
    g = jax.jit(functools.partial(pl.stage_gapfill, cfg))
    vg, fg, ng = jax.block_until_ready(g(state, va, oa, ticks))
    nrm = jax.jit(functools.partial(pl.stage_normalize, cfg))

    proc = psutil.Process()
    _row("stage_harmonize", _time(lambda: jax.block_until_ready(
        h(state, raw, ws))), f"rss {proc.memory_info().rss / 2**20:.0f} MB")
    _row("stage_anomaly", _time(lambda: jax.block_until_ready(
        a(state, v, obs))), "")
    _row("stage_gapfill", _time(lambda: jax.block_until_ready(
        g(state, va, oa, ticks))), "")
    _row("stage_normalize", _time(lambda: jax.block_until_ready(
        nrm(state, vg, oa | fg))), f"cpu {psutil.cpu_percent(0.1):.0f}%")


# --------------------------------------------------------------------------
# Table 4 — deployment strategies: edge (1 env) / fog (32) / cloud (1024)
# --------------------------------------------------------------------------

def bench_deployment(quick=False):
    modes = {"edge": 1, "fog": 32, "cloud": 256 if quick else 1024}
    for name, E in modes.items():
        t = _time(_pipeline(E), n=3 if quick else 6)
        _row(f"deploy_{name}_E{E}", t,
             f"{t / E:.1f} us/env ({E / (t / 1e6):.0f} env-ticks/s)")


# --------------------------------------------------------------------------
# Table 5 — end-to-end serving throughput (Percepta -> LM, batched requests)
# --------------------------------------------------------------------------

def bench_serving(quick=False):
    import jax

    from repro.configs.registry import get_config
    from repro.models import LM
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config("qwen3-0.6b:smoke")
    model = LM(cfg, remat_policy="none")
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=4, max_seq=128)
    rng = np.random.RandomState(0)
    n_req = 8 if quick else 16
    reqs = [Request(rid=i, prompt=rng.randint(1, cfg.vocab_size, (8,))
                    .astype(np.int32), max_new_tokens=16)
            for i in range(n_req)]
    t0 = time.time()
    engine.run_until_drained(reqs)
    dt = time.time() - t0
    toks = sum(len(r.tokens) for r in reqs)
    _row("serving_engine", dt / max(toks, 1) * 1e6,
         f"{toks / dt:.1f} tok/s | {n_req} reqs on 4 slots | "
         f"{engine.stats['ticks']} ticks")


# --------------------------------------------------------------------------
# Table 6 — Pallas kernels: interpret-mode correctness vs oracle
# --------------------------------------------------------------------------

def bench_kernels(quick=False):
    rng = np.random.RandomState(0)
    from repro.kernels.window_agg.ops import window_agg
    E, S, T = 8, 8, 64
    v = rng.normal(5, 2, (E, S, T)).astype(np.float32)
    m = rng.rand(E, S, T) > 0.3
    mu = rng.normal(5, 1, (E, S)).astype(np.float32)
    var = np.abs(rng.normal(2, .5, (E, S))).astype(np.float32) + .1
    t0 = time.time()
    s1, _ = window_agg(v, m, mu, var, use_pallas=True)
    s2, _ = window_agg(v, m, mu, var, use_pallas=False)
    err = float(np.abs(np.asarray(s1) - np.asarray(s2)).max())
    _row("kernel_window_agg", (time.time() - t0) * 1e6,
         f"max_abs_err {err:.2e} (interpret vs oracle)")

    from repro.kernels.flash_attention.ops import flash_attention
    q = rng.normal(0, 1, (1, 128, 4, 32)).astype(np.float32)
    k = rng.normal(0, 1, (1, 128, 2, 32)).astype(np.float32)
    vv = rng.normal(0, 1, (1, 128, 2, 32)).astype(np.float32)
    t0 = time.time()
    o1 = flash_attention(q, k, vv, use_pallas=True, q_blk=64, kv_blk=64)
    o2 = flash_attention(q, k, vv, use_pallas=False)
    err = float(np.abs(np.asarray(o1) - np.asarray(o2)).max())
    _row("kernel_flash_attention", (time.time() - t0) * 1e6,
         f"max_abs_err {err:.2e}")

    from repro.kernels.rglru_scan.ops import rglru_scan
    a = rng.uniform(.6, .99, (2, 32, 128)).astype(np.float32)
    b = rng.normal(0, .1, (2, 32, 128)).astype(np.float32)
    h0 = np.zeros((2, 128), np.float32)
    t0 = time.time()
    o1, _ = rglru_scan(a, b, h0, use_pallas=True)
    o2, _ = rglru_scan(a, b, h0, use_pallas=False)
    err = float(np.abs(np.asarray(o1) - np.asarray(o2)).max())
    _row("kernel_rglru_scan", (time.time() - t0) * 1e6,
         f"max_abs_err {err:.2e}")

    from repro.kernels.harmonize.ops import harmonize as kharm
    ts = rng.uniform(0, 960, (4, 4, 32)).astype(np.float32)
    vals = rng.normal(0, 1, (4, 4, 32)).astype(np.float32)
    ok = rng.rand(4, 4, 32) > 0.2
    ws = np.zeros((4,), np.float32)
    t0 = time.time()
    o1, _ = kharm(vals, ts, ok, ws, tick_s=60.0, n_ticks=16, use_pallas=True)
    o2, _ = kharm(vals, ts, ok, ws, tick_s=60.0, n_ticks=16, use_pallas=False)
    err = float(np.abs(np.asarray(o1) - np.asarray(o2)).max())
    _row("kernel_harmonize", (time.time() - t0) * 1e6,
         f"max_abs_err {err:.2e}")


# --------------------------------------------------------------------------
# Table 7 — dry-run roofline summary (reads experiments/dryrun/*.json)
# --------------------------------------------------------------------------

def bench_roofline(quick=False):
    import glob
    import json
    import os
    root = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "dryrun")
    cells = []
    for f in sorted(glob.glob(os.path.join(root, "*.json"))):
        d = json.load(open(f))
        if not d.get("skipped") and not d.get("tag"):
            cells.append(d)
    if not cells:
        _row("roofline", 0.0, "no dry-run artifacts (run repro.launch.dryrun)")
        return
    fits = sum(1 for d in cells if d.get("fits_hbm"))
    _row("roofline_cells", 0.0,
         f"{len(cells)} compiled | {fits} fit 16GiB HBM (TPU-adjusted)")
    for d in cells:
        if d["mesh"] != "16x16":
            continue
        _row(f"roofline_{d['arch']}_{d['shape']}",
             max(d["compute_s"], d["memory_s"], d["collective_s"]) * 1e6,
             f"dom={d['dominant']} frac={d['roofline_fraction']:.3f}")


ALL = [bench_ingest, bench_columnar_ingest, bench_ingest_fastpath,
       bench_tick_latency,
       bench_scan_engine, bench_scan_sharded, bench_scan_async,
       bench_predictor_batch, bench_fused_decide, bench_online_train,
       bench_elastic, bench_contract_check, bench_certify, bench_autotune,
       bench_stage_breakdown,
       bench_deployment, bench_serving, bench_kernels, bench_roofline]

# --smoke: the CI-sized subset (Makefile `bench-smoke`) — quick settings:
# tick-latency axes, the scan-engine acceptance cells (incl. the sharded
# mode on the forced host-device mesh, the async overlap cell, the
# batched-Predictor identity cell, the fused-decide cells and the
# elastic slot-pool cells), the autotuner grid, the columnar-ingest
# cell, and the ingest fast-path phase-decomposition cell
SMOKE = [bench_tick_latency, bench_scan_engine, bench_scan_sharded,
         bench_scan_async, bench_predictor_batch, bench_fused_decide,
         bench_online_train, bench_elastic, bench_contract_check,
         bench_certify, bench_autotune, bench_columnar_ingest,
         bench_ingest_fastpath]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI pass: tick latency + scan engines + "
                         "columnar ingest, quick")
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default="",
                    help="also write rows + windows/s + records/s summary "
                         "to this path (e.g. BENCH_pr2.json)")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force an N-device CPU platform "
                         "(--xla_force_host_platform_device_count) so "
                         "scan_sharded runs on a real mesh; must be set "
                         "before JAX initializes")
    args = ap.parse_args()
    if args.host_devices > 0:
        assert "jax" not in sys.modules, \
            "--host-devices must be applied before JAX initializes"
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.host_devices}")
    benches = SMOKE if args.smoke else ALL
    if args.smoke:
        args.quick = True
    # --only accepts "|"- or ","-separated name fragments
    wanted = [w for w in args.only.replace(",", "|").split("|") if w]
    print("name,us_per_call,derived")
    for bench in benches:
        if wanted and not any(w in bench.__name__ for w in wanted):
            continue
        try:
            bench(quick=args.quick)
        except Exception as e:  # a failing table must not hide the others
            _row(bench.__name__, -1.0, f"ERROR {type(e).__name__}: {e}")
    if args.json:
        import jax
        out = {
            "bench": "percepta",
            "jax": jax.__version__,
            "devices": len(jax.devices()),
            "quick": bool(args.quick),
            **SUMMARY,
            "rows": RESULTS,
        }
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()

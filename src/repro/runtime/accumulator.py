"""Accumulator — provider-agnostic per-environment collection.

"Each environment has its own dedicated Accumulator instance, which listens
to the corresponding queue. Upon receiving a message, it forwards the data
to the environment-specific Manager." Here the Accumulator also performs the
device-batch assembly: records -> padded (streams, max_samples) arrays with
validity masks for the window that just closed.

Storage is columnar and arena-staged: each stream owns a preallocated
growable (timestamp, value) float64 arena that ingest appends into in place
(geometric growth, no per-batch ``np.concatenate``), together with a
sortedness flag maintained on append. ``close_windows`` buckets ALL pending
records into the K requested windows with one ``searchsorted`` over each
stream's sorted arena — O(records) vectorized work and NO sort in the
steady state — while reproducing the per-record reference semantics
bit-for-bit: window k takes the not-yet-taken records with ts < t_end_k in
timestamp order (arrival order breaking ties), overflow beyond
``max_samples`` drops the OLDEST and is counted, records older than
t_start_k still occupy slots but are masked invalid, and records newer than
the last window end stay pending.

Sorted-merge parity argument (why skipping the global lexsort is safe): the
legacy path stable-lexsorts by ``(window, stream, ts)`` with arrival order
breaking ties. Records of DIFFERENT streams never share a lexsort group, so
only the within-stream arrival order matters for tie-breaks — which the
per-stream arenas preserve exactly (boolean-mask splits keep row order).
Within one stream, a stable argsort by ts reproduces the lexsort's group
ordering verbatim; when the arena is already sorted even that argsort is
skipped. The retained tail after a close is a suffix of a sorted column, so
arenas self-heal to sorted after every close regardless of how records
arrived. ``fastpath=False`` keeps the original chunk-list + global-lexsort
implementation alive for before/after benchmarking and parity tests.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.runtime.records import Record, RecordBatch

# one pending chunk = (stream_idx int32, ts float64, value float64) columns
Chunk = Tuple[np.ndarray, np.ndarray, np.ndarray]

_MIN_ARENA = 256          # initial per-stream arena capacity (records)
_TABLE_CACHE_MAX = 256    # stream-index tables cached per accumulator


class Accumulator:
    def __init__(self, env_id: str, streams: Sequence[str], max_samples: int,
                 fastpath: bool = True):
        self.env_id = env_id
        self.streams = list(streams)
        self.stream_index = {s: i for i, s in enumerate(self.streams)}
        self.max_samples = max_samples
        self.fastpath = bool(fastpath)
        S = len(self.streams)
        # per-stream growable arenas (fast path): float64 ts/value columns,
        # fill counts, and "is this arena time-sorted" flags
        self._ts: List[np.ndarray] = [np.empty(0, np.float64)
                                      for _ in range(S)]
        self._vs: List[np.ndarray] = [np.empty(0, np.float64)
                                      for _ in range(S)]
        self._n: List[int] = [0] * S
        self._sorted: List[bool] = [True] * S
        # stream-name tuple -> stream-index table (ingest_batch no longer
        # rebuilds the mapping per call; batches reuse interned tuples)
        self._table_cache: dict = {}
        # legacy chunk list (fastpath=False)
        self._chunks: List[Chunk] = []
        self.stats = {"records": 0, "unknown_stream": 0, "overflow": 0}
        # fast-path observability, kept OUT of ``stats`` (which mirrors the
        # per-record reference accounting bit-for-bit): how often a close
        # segment skipped its sort vs had to sort / lexsort
        self.merge_stats = {"close_fast": 0, "close_sort": 0,
                            "close_lexsort": 0}

    # --- ingest ---------------------------------------------------------------
    def ingest(self, items: Sequence):
        """Accept a drained queue mix of ``Record``s and ``RecordBatch``es."""
        sid, ts, vs = [], [], []
        for it in items:
            if isinstance(it, RecordBatch):
                # flush interleaved singles first to preserve arrival order
                if sid:
                    self._push_columns(np.asarray(sid, np.int32),
                                       np.asarray(ts, np.float64),
                                       np.asarray(vs, np.float64))
                    sid, ts, vs = [], [], []
                self.ingest_batch(it)
                continue
            idx = self.stream_index.get(it.stream)
            if idx is None:
                self.stats["unknown_stream"] += 1
                continue
            sid.append(idx)
            ts.append(it.timestamp)
            vs.append(it.value)
        if sid:
            self._push_columns(np.asarray(sid, np.int32),
                               np.asarray(ts, np.float64),
                               np.asarray(vs, np.float64))

    def ingest_batch(self, batch: RecordBatch):
        """Columnar ingest: resolve the batch's stream table, drop unknowns."""
        n = len(batch)
        streams = batch.streams
        if self.fastpath and n and len(streams) == 1:
            # single-stream batch (every Receiver poll): no stream-id
            # indexing at all, straight append into that stream's arena
            idx = self.stream_index.get(streams[0])
            if idx is None:
                self.stats["unknown_stream"] += n
                return
            self.stats["records"] += n
            self._append_stream(idx,
                                np.asarray(batch.timestamps, np.float64),
                                np.asarray(batch.values, np.float64),
                                batch.sorted_ts)
            return
        table = self._table_cache.get(streams)
        if table is None:
            if len(self._table_cache) >= _TABLE_CACHE_MAX:
                self._table_cache.clear()
            table = np.asarray([self.stream_index.get(s, -1)
                                for s in streams], np.int32)
            self._table_cache[streams] = table
        sid = table[batch.stream_ids] if n else np.empty(0, np.int32)
        # float64 columns regardless of how the batch was built, so window
        # bucketing always compares like Record's Python floats
        ts = np.asarray(batch.timestamps, np.float64)
        vs = np.asarray(batch.values, np.float64)
        known = sid >= 0
        n_unknown = int((~known).sum())
        if n_unknown:
            self.stats["unknown_stream"] += n_unknown
            sid, ts, vs = sid[known], ts[known], vs[known]
        self._push_columns(sid, ts, vs)

    def _push_columns(self, sid: np.ndarray, ts: np.ndarray, vs: np.ndarray):
        """Store known-stream columns (arrival order) in the active store."""
        n = int(sid.shape[0])
        if not n:
            return
        self.stats["records"] += n
        if not self.fastpath:
            self._chunks.append((sid, ts, vs))
            return
        present = np.unique(sid)        # sorted; masks preserve row order
        if present.shape[0] == 1:
            self._append_stream(int(present[0]), ts, vs, None)
            return
        for s in present:
            m = sid == s
            self._append_stream(int(s), ts[m], vs[m], None)

    def _append_stream(self, s: int, ts: np.ndarray, vs: np.ndarray,
                       sorted_hint: Optional[bool]):
        """Append one stream's columns into its arena, growing geometrically.

        ``sorted_hint=True`` is a producer promise (``RecordBatch.sorted_ts``)
        that ``ts`` is non-decreasing — the O(n) verification is skipped.
        ``None``/``False`` verify, so a mis-flag can only cost a sort, never
        correctness.
        """
        n = int(ts.shape[0])
        if not n:
            return
        n0 = self._n[s]
        end = n0 + n
        if end > self._ts[s].shape[0]:
            cap = max(_MIN_ARENA, 2 * end)
            for cols in (self._ts, self._vs):
                grown = np.empty(cap, np.float64)
                grown[:n0] = cols[s][:n0]
                cols[s] = grown
        self._ts[s][n0:end] = ts
        self._vs[s][n0:end] = vs
        if self._sorted[s]:
            chunk_sorted = True if sorted_hint is True else (
                n < 2 or bool(np.all(ts[1:] >= ts[:-1])))
            self._sorted[s] = chunk_sorted and (
                n0 == 0 or ts[0] >= self._ts[s][n0 - 1])
        self._n[s] = end

    def reset(self) -> int:
        """Discard pending records (elastic detach); returns the count."""
        n = sum(int(c[0].shape[0]) for c in self._chunks) + sum(self._n)
        self._chunks = []
        self._n = [0] * len(self.streams)
        self._sorted = [True] * len(self.streams)
        return n

    def _pending(self) -> Chunk:
        if not self._chunks:
            z = np.empty(0)
            return np.empty(0, np.int32), z, z
        if len(self._chunks) > 1:
            self._chunks = [tuple(np.concatenate(cols)
                                  for cols in zip(*self._chunks))]
        return self._chunks[0]

    # --- window close ---------------------------------------------------------
    def close_window(self, t_start: float, t_end: float, rebase: bool = False):
        """Build the padded raw-window arrays for [t_start, t_end) and retain
        newer records for later windows."""
        v, ts, m = self.close_windows([(t_start, t_end)], rebase=rebase)
        return v[0], ts[0], m[0]

    def close_windows(self, bounds, rebase: bool = False, out=None):
        """Close K consecutive windows into stacked (K, S, M) arrays.

        ``bounds`` is a chronologically ordered sequence of (t_start, t_end)
        pairs; records newer than the last window end stay pending. Per
        stream, one ``searchsorted`` of the window ends into the sorted
        arena yields every window's contiguous record run (exactly the
        per-window "take everything with ts < t_end" of the reference
        loop); an unsorted arena first takes a stable argsort — identical
        ordering to the legacy global lexsort, see the module docstring.
        Overflow is trimmed from the oldest side, then values/timestamps/
        validity scatter in one shot.

        ``out=(values, ts, valid)`` writes into caller-provided PRE-ZEROED
        (K, S, M) arrays (may be strided views into a larger staging
        buffer) instead of allocating — the one-pass multi-env assembly
        path. The returned triple is ``out`` itself.

        ``rebase=True`` emits WINDOW-RELATIVE timestamps: each record's ts
        has its window's ``t_start`` subtracted in float64 *before* the
        float32 cast, so sub-second deltas stay exact on arbitrarily long
        horizons (absolute float32 seconds quantize to >=1s past t~2^24,
        ~194 days of stream time — minutes of wall time at high speedup).
        This is the device-staging form the scan/fused system modes consume
        (the pipeline receives ``window_start = 0``); all bucketing /
        ordering / validity decisions are made on the float64 absolute
        columns either way, so ``rebase`` changes only the emitted frame.
        """
        K, S, M = len(bounds), len(self.streams), self.max_samples
        if out is not None:
            values, ts_out, valid = out
        else:
            values = np.zeros((K, S, M), np.float32)
            ts_out = np.zeros((K, S, M), np.float32)
            valid = np.zeros((K, S, M), bool)
        starts = np.asarray([b[0] for b in bounds], np.float64)
        ends = np.asarray([b[1] for b in bounds], np.float64)
        if not self.fastpath:
            self._close_lexsort(starts, ends, rebase, values, ts_out, valid)
            return values, ts_out, valid

        for s in range(S):
            n = self._n[s]
            if not n:
                continue
            ts = self._ts[s][:n]
            vs = self._vs[s][:n]
            if self._sorted[s]:
                self.merge_stats["close_fast"] += 1
            else:
                order = np.argsort(ts, kind="stable")  # ties: arrival order
                ts = ts[order]
                vs = vs[order]
                self.merge_stats["close_sort"] += 1
            # cumulative take counts: records < ends[k] form the prefix
            # [0, cum[k]); equals bucket-by-searchsorted(ends, ts, "right")
            cum = np.searchsorted(ts, ends, side="left")
            taken = int(cum[-1])
            if taken:
                cnt = np.diff(cum, prepend=0)
                kb = np.repeat(np.arange(K), cnt)
                pos = np.arange(taken) - (cum - cnt)[kb]
                drop = np.maximum(cnt - M, 0)          # overflow: drop oldest
                n_drop = int(drop.sum())
                if n_drop:
                    self.stats["overflow"] += n_drop
                    dropb = drop[kb]
                    keep = pos >= dropb
                    slot = (pos - dropb)[keep]
                    kk = kb[keep]
                    tk = ts[:taken][keep]
                    vk = vs[:taken][keep]
                else:
                    slot, kk, tk, vk = pos, kb, ts[:taken], vs[:taken]
                values[kk, s, slot] = vk.astype(np.float32)
                tk_out = tk - starts[kk] if rebase else tk  # float64 subtract
                ts_out[kk, s, slot] = tk_out.astype(np.float32)
                valid[kk, s, slot] = tk >= starts[kk]
            rem = n - taken
            if rem:
                # sorted tail back to the arena front (numpy slice copies
                # handle the overlap); the arena is now sorted by
                # construction, healing any unsorted arrivals
                self._ts[s][:rem] = ts[taken:]
                self._vs[s][:rem] = vs[taken:]
            self._n[s] = rem
            self._sorted[s] = True
        return values, ts_out, valid

    def _close_lexsort(self, starts, ends, rebase, values, ts_out, valid):
        """Legacy close: one global stable lexsort over the chunk list.

        Kept verbatim behind ``fastpath=False`` as the bit-identity
        reference for tests and the before/after ingest benchmark.
        """
        K, S, M = ends.shape[0], len(self.streams), self.max_samples
        sid, ts, vs = self._pending()
        if not sid.shape[0]:
            return
        # window index: first k with ts < ends[k]; >= K stays pending
        bucket = np.searchsorted(ends, ts, side="right")
        taken = bucket < K
        self._chunks = [] if taken.all() else \
            [(sid[~taken], ts[~taken], vs[~taken])]
        sid, ts, vs, bucket = sid[taken], ts[taken], vs[taken], bucket[taken]
        if not sid.shape[0]:
            return
        # stable sort by (window, stream, ts) — ties keep arrival order,
        # matching the reference's stable per-stream list sort
        group = bucket.astype(np.int64) * S + sid
        order = np.lexsort((ts, group))
        group = group[order]
        sid, ts, vs, bucket = sid[order], ts[order], vs[order], bucket[order]
        self.merge_stats["close_lexsort"] += 1

        cnt = np.bincount(group, minlength=K * S)
        first = cnt.cumsum() - cnt                     # group start offsets
        pos = np.arange(group.shape[0]) - first[group]
        drop = np.maximum(cnt - M, 0)                  # overflow: drop oldest
        self.stats["overflow"] += int(drop.sum())
        keep = pos >= drop[group]
        slot = (pos - drop[group])[keep]
        kb, sb, tk, vk = bucket[keep], sid[keep], ts[keep], vs[keep]
        values[kb, sb, slot] = vk.astype(np.float32)
        tk_out = tk - starts[kb] if rebase else tk       # float64 subtract
        ts_out[kb, sb, slot] = tk_out.astype(np.float32)
        valid[kb, sb, slot] = tk >= starts[kb]

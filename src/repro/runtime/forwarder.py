"""Forwarders — decision delivery, one per destination system.

"If there is a smart light device that receives a 'turn on' decision, then
the decision is routed to the specific Forwarder associated with that
system. This Forwarder ensures the decision is formatted and transmitted
correctly."
"""
from __future__ import annotations

import json
import threading
from typing import Callable, Dict, List, Optional, Sequence

from repro.runtime.records import CODECS


class Forwarder:
    """Formats and 'transmits' decisions for one destination (transport
    simulated by an in-memory sink; swap `transmit` for a real client)."""

    def __init__(self, dest_id: str, protocol: str, action_indices: Sequence[int],
                 transmit: Optional[Callable[[bytes], None]] = None):
        self.dest_id = dest_id
        self.protocol = protocol
        self.action_indices = list(action_indices)
        self.sink: List[bytes] = []
        self._transmit = transmit or self.sink.append
        self.stats = {"sent": 0, "bytes": 0}
        self._lock = threading.Lock()

    def forward(self, env_id: str, tick_time: float, actions):
        encode = CODECS[self.protocol][0]
        for idx in self.action_indices:
            payload = encode(f"{self.dest_id}/act{idx}", tick_time,
                             float(actions[idx]))
            with self._lock:
                self._transmit(payload)
                self.stats["sent"] += 1
                self.stats["bytes"] += len(payload)


class ForwarderHub:
    def __init__(self, forwarders: Sequence[Forwarder]):
        self.forwarders = list(forwarders)

    def dispatch(self, env_id: str, tick_time: float, actions):
        for f in self.forwarders:
            f.forward(env_id, tick_time, actions)

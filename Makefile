# Developer / CI entrypoints. `make test` is the tier-1 verify command from
# ROADMAP.md; `make bench-smoke` is a ~1-minute benchmark pass covering the
# three pipeline execution axes (modular / fused / scan) plus the scan-engine
# acceptance cell.
PY ?= python

.PHONY: test bench-smoke ci

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --smoke

ci: test bench-smoke

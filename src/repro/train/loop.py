"""Training loop: checkpoint/restart, preemption, straggler policy, metrics.

The loop is deliberately boring — all the interesting machinery lives in
steps.build_train_step (sharded step), Checkpointer (fault tolerance),
Prefetcher (overlapped input), StragglerPolicy/PreemptionGuard (mitigation).
Runs for real on CPU with reduced configs (examples/train_retrain.py trains
a ~small model for hundreds of steps); the same code drives the full archs
on a pod.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import numpy as np

from repro import compat

from repro.configs.base import (ModelConfig, ShapeConfig, ShardingConfig,
                                TrainConfig)
from repro.data.pipeline import Prefetcher, StreamCursor, SyntheticLMStream
from repro.distribution.elastic import PreemptionGuard, StragglerPolicy
from repro.launch.steps import build_train_step
from repro.train import optimizer as opt_lib
from repro.train.checkpoint import Checkpointer


@dataclass
class TrainResult:
    steps_run: int
    final_step: int
    losses: list
    step_times: list
    restored_from: Optional[int]
    preempted: bool = False
    straggler_events: int = 0


def train(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
          perf: ShardingConfig = ShardingConfig(),
          tcfg: TrainConfig = TrainConfig(),
          max_steps: Optional[int] = None,
          stream_seed: int = 0,
          on_step: Optional[Callable[[int, dict], None]] = None,
          checkpointer: Optional[Checkpointer] = None) -> TrainResult:
    fn, (pspecs, opt_specs, in_specs), (param_sh, opt_sh, batch_sh), model = \
        build_train_step(cfg, shape, mesh, perf, tcfg)

    ckpt = checkpointer or Checkpointer(tcfg.checkpoint_dir,
                                        keep=tcfg.keep_checkpoints,
                                        async_mode=tcfg.async_checkpoint)
    guard = PreemptionGuard().install()
    straggler = StragglerPolicy()

    cursor = StreamCursor()
    restored_from = None
    latest = ckpt.latest_step()
    state_like = (pspecs, opt_specs)
    if latest is not None:
        (params, opt_state), extra = ckpt.restore(
            latest, state_like, (param_sh, opt_sh))
        cursor = StreamCursor.from_dict(extra.get("cursor", {}))
        start_step = latest
        restored_from = latest
    else:
        with compat.set_mesh(mesh):
            params = jax.jit(model.init, out_shardings=param_sh)(
                jax.random.PRNGKey(tcfg.seed))
            opt_state = jax.jit(opt_lib.init, out_shardings=opt_sh)(params)
        start_step = 0

    stream = SyntheticLMStream(cfg.vocab_size, shape.global_batch,
                               shape.seq_len, seed=stream_seed,
                               frontend=cfg.frontend, d_model=cfg.d_model,
                               n_patches=cfg.n_patches)
    prefetch = Prefetcher(stream, cursor, shardings=batch_sh)

    total = max_steps if max_steps is not None else tcfg.total_steps
    losses, times = [], []
    step = start_step
    preempted = False
    with compat.set_mesh(mesh):
        while step < total:
            batch = prefetch.next()
            t0 = time.time()
            params, opt_state, metrics = fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            step += 1
            losses.append(loss)
            times.append(dt)
            verdict = straggler.observe(dt)
            if on_step:
                on_step(step, {**{k: float(v) for k, v in metrics.items()},
                               "time_s": dt, "straggler": verdict})
            should_ckpt = (step % tcfg.checkpoint_every == 0) or step == total
            if guard.triggered or verdict == "fail":
                should_ckpt = True
            if should_ckpt:
                ckpt.save(step, (params, opt_state),
                          extra={"cursor": cursor.state_dict(),
                                 "loss": loss})
            if guard.triggered:
                preempted = True
                break
            if verdict == "fail":
                # at scale: drop the slow host and re-mesh (elastic). In a
                # single process we record the event and continue.
                straggler.strikes = 0
    ckpt.flush()
    return TrainResult(steps_run=step - start_step, final_step=step,
                       losses=losses, step_times=times,
                       restored_from=restored_from, preempted=preempted,
                       straggler_events=straggler.slow_events)

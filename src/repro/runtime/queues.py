"""Per-environment internal queues (the RabbitMQ stand-in).

One queue per environment keeps environments isolated ("these environments
operate independently, do not interfere with each other").
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Optional

from repro.runtime.records import Record


class EnvQueue:
    def __init__(self, env_id: str, maxsize: int = 100_000):
        self.env_id = env_id
        self._q: "queue.Queue[Record]" = queue.Queue(maxsize=maxsize)
        self.stats = {"enqueued": 0, "dropped": 0, "dequeued": 0}

    def put(self, rec: Record) -> bool:
        try:
            self._q.put_nowait(rec)
            self.stats["enqueued"] += 1
            return True
        except queue.Full:
            self.stats["dropped"] += 1
            return False

    def drain(self, max_items: int = 1_000_000):
        out = []
        while len(out) < max_items:
            try:
                out.append(self._q.get_nowait())
            except queue.Empty:
                break
        self.stats["dequeued"] += len(out)
        return out

    def qsize(self):
        return self._q.qsize()


class QueueBroker:
    """Routes records to environment queues; creates them on demand."""

    def __init__(self):
        self._queues: Dict[str, EnvQueue] = {}
        self._lock = threading.Lock()

    def queue_for(self, env_id: str) -> EnvQueue:
        with self._lock:
            if env_id not in self._queues:
                self._queues[env_id] = EnvQueue(env_id)
            return self._queues[env_id]

    def publish(self, rec: Record):
        self.queue_for(rec.env_id).put(rec)

    def stats(self):
        return {e: q.stats | {"depth": q.qsize()}
                for e, q in self._queues.items()}

"""Host runtime: protocol codecs, queues, accumulator, DB, full system."""
import threading

import numpy as np
import pytest

from repro.core import PipelineConfig
from repro.core.reward import energy_reward_spec
from repro.runtime.accumulator import Accumulator
from repro.runtime.db import LogDB
from repro.runtime.forwarder import Forwarder, ForwarderHub
from repro.runtime.predictor import ActionSpace, Predictor, linear_policy
from repro.runtime.queues import QueueBroker
from repro.runtime.receivers import SimulatedDevice
from repro.runtime.records import CODECS, Record
from repro.runtime.system import PerceptaSystem, SourceSpec
from repro.runtime.translator import Translator


@pytest.mark.parametrize("proto", ["mqtt", "http", "amqp"])
def test_protocol_roundtrip(proto):
    enc, dec = CODECS[proto]
    stream, ts, v = dec(enc("temp_c", 1234.5, -3.25))
    assert stream == "temp_c"
    assert abs(ts - 1234.5) < 1e-3 and abs(v + 3.25) < 1e-5


def test_translator_handles_garbage():
    tr = Translator("src", "mqtt")
    assert tr.translate("e", b"not json") is None
    assert tr.stats["errors"] == 1
    rec = tr.translate("e", CODECS["mqtt"][0]("s", 1.0, 2.0))
    assert rec == Record("e", "s", 1.0, 2.0)


def test_queue_isolation_between_envs():
    broker = QueueBroker()
    broker.publish(Record("env-A", "s", 1.0, 1.0))
    broker.publish(Record("env-B", "s", 1.0, 2.0))
    a = broker.queue_for("env-A").drain()
    b = broker.queue_for("env-B").drain()
    assert len(a) == 1 and len(b) == 1 and a[0].value == 1.0


def test_queue_backpressure_counts_records_not_items():
    """One 80-row batch against an 50-record bound behaves exactly like 80
    individual puts: 50 accepted (the arrival-order prefix), 30 dropped."""
    from repro.runtime.queues import EnvQueue
    from repro.runtime.records import RecordBatch

    recs = [Record("e", "s", float(i), float(i)) for i in range(80)]
    q_rec = EnvQueue("e", maxsize=50)
    q_col = EnvQueue("e", maxsize=50)
    oks = [q_rec.put(r) for r in recs]
    assert oks.count(True) == 50 and not any(oks[50:])
    assert q_col.put(RecordBatch.from_records(recs)) is False  # truncated
    for q in (q_rec, q_col):
        assert q.stats["enqueued"] == 50 and q.stats["dropped"] == 30
        assert q.record_depth() == 50
    flat = []
    for it in q_col.drain():
        flat.extend(it.to_records())
    assert flat == q_rec.drain() == recs[:50]
    # capacity is freed by the drain: the next put is accepted again
    assert q_rec.put(recs[0]) and q_col.put(RecordBatch.from_records(recs[:1]))
    for q in (q_rec, q_col):
        assert q.stats["dequeued"] == 50 and q.record_depth() == 1


def test_system_overflow_drop_parity_across_ingest_paths():
    """QoS-0 bound under overflow: ingest="columnar" and ingest="records"
    accept/drop exactly the same records (dropped stats parity) and close
    identical windows afterwards."""
    from repro.runtime.queues import QueueBroker as _QB

    results = {}
    for ingest in ("records", "columnar"):
        sys_ = _small_system("fused")
        # swap in a tiny per-env record bound AFTER construction (the
        # receiver callbacks resolve self.broker at publish time) and
        # re-subscribe through the requested path
        sys_.broker = _QB(maxsize=25)
        for r, s in zip(sys_.receivers, sys_.sources):
            tr = sys_.translators[s.source_id]
            for env in sys_.env_ids:
                if ingest == "columnar":
                    def on_batch(env_id, stream, ts, vs, srt=None,
                                 _tr=tr, _sys=sys_):
                        batch = _tr.translate_batch(env_id, stream, ts, vs,
                                                    srt)
                        if batch is not None:
                            _sys.broker.publish(batch)
                    r.subscribe(env, on_batch=on_batch)
                else:
                    def on_payload(env_id, payload, _tr=tr, _sys=sys_):
                        rec = _tr.translate(env_id, payload)
                        if rec is not None:
                            _sys.broker.publish(rec)
                    r.subscribe(env, on_payload)
        # advance far enough that one poll overflows the 25-record bound
        sys_._advance_clock(sys_.window_bounds(3)[1])
        sys_.pump_receivers()
        # depth_items legitimately differs (batches buffer fewer Python
        # objects); every RECORD count must be identical across paths
        results[ingest] = {
            env: {k: v for k, v in q.items() if k != "depth_items"}
            for env, q in sys_.stats()["queues"].items()}
    assert results["records"] == results["columnar"]
    assert any(q["dropped"] > 0 for q in results["records"].values())
    for q in results["records"].values():
        assert q["depth"] <= 25


def test_receiver_concurrent_start_pump_conserves_records():
    """run()-thread polls racing synchronous pump_receivers() must neither
    double-emit nor drop readings (the per-receiver poll lock)."""
    from repro.runtime.receivers import Receiver

    dev = SimulatedDevice("s", interval_s=1.0, dropout_p=0.0, jitter_s=0.0,
                          spike_p=0.0)
    clock = {"now": 0.0}
    r = Receiver("src", "mqtt", dev, lambda: clock["now"], speedup=1e9)
    got, glock = [], threading.Lock()

    def on_batch(env_id, stream, ts, vs, srt):
        with glock:
            got.extend(ts.tolist())

    r.subscribe("e", on_batch=on_batch)
    stop = threading.Event()

    def hammer():
        while not stop.is_set():
            r.poll_once()

    threads = [threading.Thread(target=hammer) for _ in range(3)]
    for t in threads:
        t.start()
    for _ in range(60):
        clock["now"] += 1.7
        r.poll_once()
    stop.set()
    for t in threads:
        t.join()
    r.poll_once()   # flush anything a hammer thread left behind
    expected = [ts for ts, _ in dev.readings(0.0, clock["now"],
                                             abs(hash("e")) % 100000)]
    assert sorted(got) == sorted(expected)
    assert r.stats["payloads"] == len(expected)


def test_receiver_resubscribe_batch_then_payload_and_guard():
    """Re-subscribing between delivery shapes re-routes cleanly, and a
    half-installed subscription (payload slot None, batch route missing)
    is skipped instead of calling None."""
    from repro.runtime.receivers import Receiver

    dev = SimulatedDevice("s", interval_s=1.0, dropout_p=0.0, jitter_s=0.0,
                          spike_p=0.0)
    clock = {"now": 0.0}
    r = Receiver("src", "mqtt", dev, lambda: clock["now"])
    batches, payloads = [], []
    r.subscribe("e",
                on_batch=lambda e, s, ts, vs, srt: batches.append(len(ts)))
    clock["now"] = 5.0
    r.poll_once()
    assert sum(batches) == 5 and not payloads

    # batch -> payload re-subscribe: the stale batch route must be dropped
    r.subscribe("e", on_payload=lambda e, p: payloads.append(p))
    clock["now"] = 8.0
    r.poll_once()
    assert len(payloads) == 3 and sum(batches) == 5

    # simulate the mid-re-subscribe state the lock protects against: the
    # payload slot holds None and no batch route exists — must not crash
    # and must not lose the interval (delivered after the real route lands;
    # subscribe() keeps the existing poll horizon on re-subscribe)
    r._subs["e"] = None
    r._batch_subs.pop("e", None)
    clock["now"] = 10.0
    r.poll_once()
    r.subscribe("e",
                on_batch=lambda e, s, ts, vs, srt: batches.append(len(ts)))
    clock["now"] = 11.0
    r.poll_once()
    assert sum(batches) == 5 + 3    # ts in [8, 11): nothing skipped


def test_accumulator_window_close_keeps_future_records():
    acc = Accumulator("e", ["s1", "s2"], max_samples=8)
    acc.ingest([Record("e", "s1", t, float(t)) for t in (1.0, 5.0, 12.0)])
    v, ts, valid = acc.close_window(0.0, 10.0)
    assert valid[0].sum() == 2          # 1.0 and 5.0
    v2, ts2, valid2 = acc.close_window(10.0, 20.0)
    assert valid2[0].sum() == 1         # 12.0 was retained
    assert acc.stats["records"] == 3


def test_device_reporting_interval():
    dev = SimulatedDevice("s", interval_s=60.0, dropout_p=0.0, jitter_s=0.0)
    rs = dev.readings(0.0, 600.0)
    assert len(rs) == 10


def test_logdb_cursor_and_anonymization(tmp_path):
    db = LogDB(str(tmp_path), salt="x", rotate_bytes=200)
    for i in range(5):
        db.append("bldg-1", float(i), [1.0, 2.0], [0.5], 0.1 * i)
    db.close()
    rows = list(db.read_from())
    assert len(rows) == 5
    assert all(r["env"].startswith("env-") and "bldg" not in r["env"]
               for _, r in rows)
    # resume from a cursor: exactly the remaining rows
    cursor = rows[2][0]
    rest = list(db.read_from(*cursor))
    assert len(rest) == 2


def test_logdb_segment_count_no_double_count_on_reopen(tmp_path):
    """close()/append reopens the live segment — it must not be counted as
    a new segment (the old tell()-based accounting counted every _open)."""
    db = LogDB(str(tmp_path), salt="x")
    db.append("e", 0.0, [1.0], [0.5], 0.1)
    assert db.stats["segments"] == 1
    db.close()
    db.append("e", 1.0, [1.0], [0.5], 0.1)   # reopens seg-0
    assert db.stats["segments"] == 1
    assert len(list(tmp_path.glob("seg-*.jsonl"))) == 1
    # a second instance on the same dir appends to the existing segment
    # without claiming to have created it
    db.close()
    db2 = LogDB(str(tmp_path), salt="x")
    db2.append("e", 2.0, [1.0], [0.5], 0.1)
    assert db2.stats["segments"] == 0
    assert len(list(db2.read_from())) == 3
    db2.close()


def test_logdb_rotation_uses_tracked_bytes(tmp_path):
    """Rotation triggers on explicitly tracked bytes (never tell() on the
    line-buffered text handle) and survives close()/reopen: the resumed
    byte count comes from the file's true on-disk size."""
    db = LogDB(str(tmp_path), salt="x", rotate_bytes=150)
    db.append("e", 0.0, [1.0, 2.0], [0.5], 0.1)
    assert db._seg_bytes > 0
    db.close()
    db = LogDB(str(tmp_path), salt="x", rotate_bytes=150)
    for i in range(4):
        db.append("e", float(i), [1.0, 2.0], [0.5], 0.1)
    db.close()
    segs = sorted(tmp_path.glob("seg-*.jsonl"))
    assert len(segs) >= 2                      # rotation happened
    # every rotated-away segment exceeded the bound by at most one row
    for p in segs[:-1]:
        assert p.stat().st_size > 150
    assert len(list(db.read_from())) == 5


def test_logdb_append_many_matches_appends(tmp_path, monkeypatch):
    """Batch append writes the same rows as per-env appends (single lock,
    one rotation check per batch)."""
    import repro.runtime.db as dbmod
    # pin wall time: logged_at's float repr length varies row to row,
    # which would make the byte-stats comparison below nondeterministic
    monkeypatch.setattr(dbmod.time, "time", lambda: 1234.5)
    a = LogDB(str(tmp_path / "a"), salt="x")
    b = LogDB(str(tmp_path / "b"), salt="x")
    obs = np.arange(6, dtype=np.float32).reshape(2, 3)
    act = np.arange(4, dtype=np.float32).reshape(2, 2)
    rew = np.array([0.5, -0.5])
    for i, env in enumerate(("e0", "e1")):
        a.append(env, 7.0, obs[i], act[i], float(rew[i]))
    b.append_many(["e0", "e1"], 7.0, obs, act, rew)
    a.close(), b.close()
    strip = lambda db: [{k: v for k, v in row.items() if k != "logged_at"}
                        for _, row in db.read_from()]
    assert strip(a) == strip(b)
    assert a.stats["rows"] == b.stats["rows"] == 2
    assert a.stats["bytes"] == b.stats["bytes"]


def test_logdb_anon_cache_is_bounded_lru(tmp_path):
    """The pseudonym cache never exceeds its cap under high-cardinality
    env ids, eviction follows recency, and an evicted id re-hashes to the
    SAME pseudonym (the salted hash is pure — eviction is invisible in
    the log)."""
    db = LogDB(str(tmp_path), salt="x", anon_cache_size=4)
    first = db._anon("env-0")
    for i in range(10):
        db.append(f"env-{i}", 1.0, [0.0], [0.0], 0.0)
    assert len(db._anon_cache) == 4
    assert "env-9" in db._anon_cache          # most recent survives
    assert "env-0" not in db._anon_cache      # oldest evicted
    assert db._anon("env-0") == first         # stable across eviction
    # re-reading rows: each env's pseudonym is consistent regardless of
    # when its cache entry lived
    envs = {r["env"] for _, r in db.read_from()}
    db.close()
    assert len(envs) == 10


def test_forwarder_window_dispatch_matches_per_env():
    """forward_window == E sequential forward calls: same sink order, same
    stats, one lock acquisition per call."""
    a = Forwarder("hvac", "mqtt", [0, 1])
    b = Forwarder("hvac", "mqtt", [0, 1])
    actions = np.array([[0.1, -0.2], [0.3, 0.4], [-0.5, 0.6]])
    for i in range(3):
        a.forward(f"e{i}", 9.0, actions[i])
    b.forward_window(9.0, actions)
    assert a.sink == b.sink
    assert a.stats == b.stats == {"sent": 6, "bytes": a.stats["bytes"]}


def _small_system(mode="fused", n_envs=2):
    srcs = [
        SourceSpec("meter", "mqtt", SimulatedDevice("grid_kw", 60.0, base=3.0,
                                                    seed=1)),
        SourceSpec("price", "http", SimulatedDevice("price", 300.0, base=0.2,
                                                    amplitude=0.05, seed=2)),
        SourceSpec("thermo", "amqp", SimulatedDevice("temp_c", 30.0, base=21.0,
                                                     amplitude=1.0, seed=3)),
    ]
    cfg = PipelineConfig(n_envs=n_envs, n_streams=3, n_ticks=8, tick_s=60.0,
                         max_samples=32)
    pred = Predictor(linear_policy(3, 2),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=2),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     n_envs, cfg.n_features, replay_capacity=64)
    envs = [f"bldg-{i}" for i in range(n_envs)]
    return PerceptaSystem(envs, srcs, cfg, pred, speedup=5000.0, manual_time=True, mode=mode)


def test_system_end_to_end_fused():
    sys_ = _small_system("fused")
    res = sys_.run_windows(3)
    assert len(res) == 3
    assert all(np.isfinite(r["mean_reward"]) for r in res)
    assert res[-1]["observed_frac"] > 0.3
    assert int(sys_.predictor.replay.size()) == 2  # ticks - 1 transitions


def test_system_fused_equals_modular():
    """Same streams through both execution modes -> identical features."""
    a = _small_system("fused")
    b = _small_system("modular")
    ra = a.run_windows(3)
    rb = b.run_windows(3)
    for x, y in zip(ra, rb):
        assert abs(x["mean_reward"] - y["mean_reward"]) < 1e-3
        assert abs(x["observed_frac"] - y["observed_frac"]) < 1e-9


def test_system_forwarders_and_db(tmp_path):
    db = LogDB(str(tmp_path))
    hub = ForwarderHub([Forwarder("hvac", "mqtt", [0]),
                        Forwarder("lights", "http", [1])])
    sys_ = _small_system()
    sys_.forwarders = hub
    sys_.db = db
    sys_.run_windows(2)
    assert hub.forwarders[0].stats["sent"] == 4   # 2 envs x 2 windows
    assert db.stats["rows"] == 4
    db.close()


def test_multi_env_isolation():
    """An env with wildly different data must not perturb its neighbour."""
    base = _small_system(n_envs=2)
    res = base.run_windows(2)
    # env rows are independent pipeline rows by construction; verify the
    # accumulators never mixed records across envs
    for env, acc in base.accumulators.items():
        assert acc.stats["unknown_stream"] == 0
    q = base.stats()["queues"]
    assert set(q) == {"bldg-0", "bldg-1"}

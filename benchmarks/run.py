"""Benchmark harness — one function per paper table/figure.

Percepta's paper defers benchmarking to future work but enumerates the plan
(§V): network I/O under load, CPU/memory across stress levels, performance
across deployment strategies. Each bench below implements one of those
tables (plus serving, kernels, and the dry-run roofline summary).

Prints ``name,us_per_call,derived`` CSV rows (CPU wall time; the TPU-target
numbers live in the roofline table from the dry-run artifacts).

Run: ``PYTHONPATH=src python -m benchmarks.run [--quick]``
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def _row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)


def _time(fn, n=5, warmup=2, best=False):
    """Mean (default) or best-of-n microseconds per call.

    ``best=True`` reports the fastest rep — the robust estimator when the
    measured quantity is a dispatch-overhead ratio and the box is shared
    (one preempted rep poisons a mean but not a min).
    """
    for _ in range(warmup):
        fn()
    if best:
        out = float("inf")
        for _ in range(n):
            t0 = time.time()
            fn()
            out = min(out, time.time() - t0)
        return out * 1e6
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n * 1e6


# --------------------------------------------------------------------------
# Table 1 — ingest/network-I/O throughput under varying load
# --------------------------------------------------------------------------

def bench_ingest(quick=False):
    from repro.runtime.queues import QueueBroker
    from repro.runtime.records import CODECS
    from repro.runtime.translator import Translator

    for proto in ("mqtt", "http", "amqp"):
        enc, _ = CODECS[proto]
        tr = Translator("src", proto)
        broker = QueueBroker()
        n = 2_000 if quick else 20_000
        payloads = [enc("s", float(i), float(i) * 0.5) for i in range(n)]

        def run():
            for i, p in enumerate(payloads):
                rec = tr.translate(f"env-{i % 16}", p)
                broker.publish(rec)

        t0 = time.time()
        run()
        dt = time.time() - t0
        _row(f"ingest_{proto}", dt / n * 1e6, f"{n / dt:.0f} msg/s")


# --------------------------------------------------------------------------
# Table 2 — per-tick pipeline latency: modular vs fused vs scan (3 axes)
# --------------------------------------------------------------------------

def _pipeline(E, S=8, T=16, M=64, mode="fused", K=1):
    import jax.numpy as jnp

    from repro.core import PerceptaPipeline, PipelineConfig
    from repro.core.frame import make_raw_window

    cfg = PipelineConfig(n_envs=E, n_streams=S, n_ticks=T, tick_s=60.0,
                         max_samples=M)
    pipe = PerceptaPipeline(cfg, mode=mode, donate=(mode == "scan"))
    state = pipe.init_state()
    rng = np.random.RandomState(0)
    if mode == "scan":
        raws = make_raw_window(
            rng.normal(5, 2, (K, E, S, M)).astype(np.float32),
            rng.uniform(0, T * 60, (K, E, S, M)).astype(np.float32),
            rng.rand(K, E, S, M) > 0.3)
        ws = jnp.zeros((K, E), jnp.float32)

        def run():
            nonlocal state
            state, feats, frames = pipe.run_many(state, raws, ws)
            feats.features.block_until_ready()

        return run

    raw = make_raw_window(rng.normal(5, 2, (E, S, M)).astype(np.float32),
                          rng.uniform(0, T * 60, (E, S, M)).astype(np.float32),
                          rng.rand(E, S, M) > 0.3)
    ws = jnp.zeros((E,), jnp.float32)

    def run():
        nonlocal state
        state, feats, frame = pipe.run_tick(state, raw, ws)
        feats.features.block_until_ready()

    return run


def bench_tick_latency(quick=False):
    envs = (16, 256) if quick else (16, 256, 1024)
    K = 8 if quick else 16
    for E in envs:
        t_mod = _time(_pipeline(E, mode="modular"), n=3 if quick else 8)
        t_fus = _time(_pipeline(E, mode="fused"), n=3 if quick else 8)
        t_scan = _time(_pipeline(E, mode="scan", K=K),
                       n=3 if quick else 8) / K  # per-tick, one dispatch per K
        _row(f"tick_modular_E{E}", t_mod, "paper-faithful per-module jits")
        _row(f"tick_fused_E{E}", t_fus,
             f"speedup {t_mod / t_fus:.2f}x over modular")
        _row(f"tick_scan_E{E}", t_scan,
             f"K={K} windows/dispatch | speedup {t_fus / t_scan:.2f}x over "
             f"fused | {1e6 / t_scan:.0f} windows/s")


# --------------------------------------------------------------------------
# Table 2b — scan engine acceptance cell: K=32 windows, E=8 envs, S=8 streams
# --------------------------------------------------------------------------

def bench_scan_engine(quick=False):
    import jax
    import jax.numpy as jnp

    from repro.core import PerceptaPipeline, PipelineConfig
    from repro.core.frame import RawWindow, make_raw_window

    K, E, S, T, M = 32, 8, 8, 16, 64
    cfg = PipelineConfig(n_envs=E, n_streams=S, n_ticks=T, tick_s=60.0,
                         max_samples=M)
    rng = np.random.RandomState(0)
    raws = make_raw_window(
        rng.normal(5, 2, (K, E, S, M)).astype(np.float32),
        (rng.uniform(0, T * 60, (K, E, S, M))
         + np.arange(K)[:, None, None, None] * T * 60).astype(np.float32),
        rng.rand(K, E, S, M) > 0.3)
    starts = jnp.asarray(np.arange(K, dtype=np.float32)[:, None] * (T * 60.0)
                         * np.ones((1, E), np.float32))
    per_window = [RawWindow(raws.values[k], raws.timestamps[k], raws.valid[k])
                  for k in range(K)]

    fused = PerceptaPipeline(cfg, mode="fused")
    scan = PerceptaPipeline(cfg, mode="scan")
    state0 = fused.init_state()

    # correctness: scan must match K sequential fused ticks bit-for-bit
    s = state0
    seq_feats = []
    for k in range(K):
        s, f, _ = fused.run_tick(s, per_window[k], starts[k])
        seq_feats.append(np.asarray(f.features))
    _, feats, _ = scan.run_many(state0, raws, starts)
    err = float(np.max(np.abs(np.asarray(feats.features)
                              - np.stack(seq_feats))))

    def run_seq():
        st = state0
        for k in range(K):
            st, f, _ = fused.run_tick(st, per_window[k], starts[k])
        f.features.block_until_ready()

    def run_scan():
        st, f, _ = scan.run_many(state0, raws, starts)
        f.features.block_until_ready()

    n = 6 if quick else 12
    t_seq = _time(run_seq, n=n, best=True)
    t_scan = _time(run_scan, n=n, best=True)
    wps_seq = K / (t_seq / 1e6)
    wps_scan = K / (t_scan / 1e6)
    _row(f"scan_fused_seq_K{K}_E{E}_S{S}", t_seq / K,
         f"{wps_seq:.0f} windows/s ({K} dispatches)")
    _row(f"scan_engine_K{K}_E{E}_S{S}", t_scan / K,
         f"{wps_scan:.0f} windows/s (1 dispatch) | "
         f"speedup {wps_scan / wps_seq:.2f}x | max_abs_err {err:.2e}")


# --------------------------------------------------------------------------
# Table 3 — per-stage cost + CPU/RSS across stress levels
# --------------------------------------------------------------------------

def bench_stage_breakdown(quick=False):
    import functools

    import jax
    import jax.numpy as jnp
    import psutil

    from repro.core import PipelineConfig
    from repro.core import pipeline as pl
    from repro.core.frame import make_raw_window

    E, S, T, M = (256, 8, 16, 64)
    cfg = PipelineConfig(n_envs=E, n_streams=S, n_ticks=T, tick_s=60.0,
                         max_samples=M)
    state = pl.init_state(cfg)
    rng = np.random.RandomState(0)
    raw = make_raw_window(rng.normal(5, 2, (E, S, M)).astype(np.float32),
                          rng.uniform(0, T * 60, (E, S, M)).astype(np.float32),
                          rng.rand(E, S, M) > 0.3)
    ws = jnp.zeros((E,), jnp.float32)

    h = jax.jit(functools.partial(pl.stage_harmonize, cfg))
    v, obs, ticks = jax.block_until_ready(h(state, raw, ws))
    a = jax.jit(functools.partial(pl.stage_anomaly, cfg))
    va, oa, rep, na = jax.block_until_ready(a(state, v, obs))
    g = jax.jit(functools.partial(pl.stage_gapfill, cfg))
    vg, fg, ng = jax.block_until_ready(g(state, va, oa, ticks))
    nrm = jax.jit(functools.partial(pl.stage_normalize, cfg))

    proc = psutil.Process()
    _row("stage_harmonize", _time(lambda: jax.block_until_ready(
        h(state, raw, ws))), f"rss {proc.memory_info().rss / 2**20:.0f} MB")
    _row("stage_anomaly", _time(lambda: jax.block_until_ready(
        a(state, v, obs))), "")
    _row("stage_gapfill", _time(lambda: jax.block_until_ready(
        g(state, va, oa, ticks))), "")
    _row("stage_normalize", _time(lambda: jax.block_until_ready(
        nrm(state, vg, oa | fg))), f"cpu {psutil.cpu_percent(0.1):.0f}%")


# --------------------------------------------------------------------------
# Table 4 — deployment strategies: edge (1 env) / fog (32) / cloud (1024)
# --------------------------------------------------------------------------

def bench_deployment(quick=False):
    modes = {"edge": 1, "fog": 32, "cloud": 256 if quick else 1024}
    for name, E in modes.items():
        t = _time(_pipeline(E), n=3 if quick else 6)
        _row(f"deploy_{name}_E{E}", t,
             f"{t / E:.1f} us/env ({E / (t / 1e6):.0f} env-ticks/s)")


# --------------------------------------------------------------------------
# Table 5 — end-to-end serving throughput (Percepta -> LM, batched requests)
# --------------------------------------------------------------------------

def bench_serving(quick=False):
    import jax

    from repro.configs.registry import get_config
    from repro.models import LM
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config("qwen3-0.6b:smoke")
    model = LM(cfg, remat_policy="none")
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=4, max_seq=128)
    rng = np.random.RandomState(0)
    n_req = 8 if quick else 16
    reqs = [Request(rid=i, prompt=rng.randint(1, cfg.vocab_size, (8,))
                    .astype(np.int32), max_new_tokens=16)
            for i in range(n_req)]
    t0 = time.time()
    engine.run_until_drained(reqs)
    dt = time.time() - t0
    toks = sum(len(r.tokens) for r in reqs)
    _row("serving_engine", dt / max(toks, 1) * 1e6,
         f"{toks / dt:.1f} tok/s | {n_req} reqs on 4 slots | "
         f"{engine.stats['ticks']} ticks")


# --------------------------------------------------------------------------
# Table 6 — Pallas kernels: interpret-mode correctness vs oracle
# --------------------------------------------------------------------------

def bench_kernels(quick=False):
    rng = np.random.RandomState(0)
    from repro.kernels.window_agg.ops import window_agg
    E, S, T = 8, 8, 64
    v = rng.normal(5, 2, (E, S, T)).astype(np.float32)
    m = rng.rand(E, S, T) > 0.3
    mu = rng.normal(5, 1, (E, S)).astype(np.float32)
    var = np.abs(rng.normal(2, .5, (E, S))).astype(np.float32) + .1
    t0 = time.time()
    s1, _ = window_agg(v, m, mu, var, use_pallas=True)
    s2, _ = window_agg(v, m, mu, var, use_pallas=False)
    err = float(np.abs(np.asarray(s1) - np.asarray(s2)).max())
    _row("kernel_window_agg", (time.time() - t0) * 1e6,
         f"max_abs_err {err:.2e} (interpret vs oracle)")

    from repro.kernels.flash_attention.ops import flash_attention
    q = rng.normal(0, 1, (1, 128, 4, 32)).astype(np.float32)
    k = rng.normal(0, 1, (1, 128, 2, 32)).astype(np.float32)
    vv = rng.normal(0, 1, (1, 128, 2, 32)).astype(np.float32)
    t0 = time.time()
    o1 = flash_attention(q, k, vv, use_pallas=True, q_blk=64, kv_blk=64)
    o2 = flash_attention(q, k, vv, use_pallas=False)
    err = float(np.abs(np.asarray(o1) - np.asarray(o2)).max())
    _row("kernel_flash_attention", (time.time() - t0) * 1e6,
         f"max_abs_err {err:.2e}")

    from repro.kernels.rglru_scan.ops import rglru_scan
    a = rng.uniform(.6, .99, (2, 32, 128)).astype(np.float32)
    b = rng.normal(0, .1, (2, 32, 128)).astype(np.float32)
    h0 = np.zeros((2, 128), np.float32)
    t0 = time.time()
    o1, _ = rglru_scan(a, b, h0, use_pallas=True)
    o2, _ = rglru_scan(a, b, h0, use_pallas=False)
    err = float(np.abs(np.asarray(o1) - np.asarray(o2)).max())
    _row("kernel_rglru_scan", (time.time() - t0) * 1e6,
         f"max_abs_err {err:.2e}")

    from repro.kernels.harmonize.ops import harmonize as kharm
    ts = rng.uniform(0, 960, (4, 4, 32)).astype(np.float32)
    vals = rng.normal(0, 1, (4, 4, 32)).astype(np.float32)
    ok = rng.rand(4, 4, 32) > 0.2
    ws = np.zeros((4,), np.float32)
    t0 = time.time()
    o1, _ = kharm(vals, ts, ok, ws, tick_s=60.0, n_ticks=16, use_pallas=True)
    o2, _ = kharm(vals, ts, ok, ws, tick_s=60.0, n_ticks=16, use_pallas=False)
    err = float(np.abs(np.asarray(o1) - np.asarray(o2)).max())
    _row("kernel_harmonize", (time.time() - t0) * 1e6,
         f"max_abs_err {err:.2e}")


# --------------------------------------------------------------------------
# Table 7 — dry-run roofline summary (reads experiments/dryrun/*.json)
# --------------------------------------------------------------------------

def bench_roofline(quick=False):
    import glob
    import json
    import os
    root = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "dryrun")
    cells = []
    for f in sorted(glob.glob(os.path.join(root, "*.json"))):
        d = json.load(open(f))
        if not d.get("skipped") and not d.get("tag"):
            cells.append(d)
    if not cells:
        _row("roofline", 0.0, "no dry-run artifacts (run repro.launch.dryrun)")
        return
    fits = sum(1 for d in cells if d.get("fits_hbm"))
    _row("roofline_cells", 0.0,
         f"{len(cells)} compiled | {fits} fit 16GiB HBM (TPU-adjusted)")
    for d in cells:
        if d["mesh"] != "16x16":
            continue
        _row(f"roofline_{d['arch']}_{d['shape']}",
             max(d["compute_s"], d["memory_s"], d["collective_s"]) * 1e6,
             f"dom={d['dominant']} frac={d['roofline_fraction']:.3f}")


ALL = [bench_ingest, bench_tick_latency, bench_scan_engine,
       bench_stage_breakdown, bench_deployment, bench_serving,
       bench_kernels, bench_roofline]

# --smoke: the CI-sized subset (Makefile `bench-smoke`) — quick settings,
# tick-latency axes + the scan-engine acceptance cell only
SMOKE = [bench_tick_latency, bench_scan_engine]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI pass: tick latency + scan engine, quick")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    benches = SMOKE if args.smoke else ALL
    if args.smoke:
        args.quick = True
    print("name,us_per_call,derived")
    for bench in benches:
        if args.only and args.only not in bench.__name__:
            continue
        try:
            bench(quick=args.quick)
        except Exception as e:  # a failing table must not hide the others
            _row(bench.__name__, -1.0, f"ERROR {type(e).__name__}: {e}")


if __name__ == "__main__":
    main()

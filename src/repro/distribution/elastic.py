"""Elastic scaling + straggler mitigation (host-level policies).

Elastic re-meshing: on restart after losing/gaining hosts, pick the largest
(data', model) mesh that the surviving device count supports, keeping the
model axis fixed (it must match the weight sharding factors) and shrinking
the data axis — the checkpoint restores onto the new mesh because
Checkpointer.restore re-places GLOBAL arrays with the new shardings. At
1000+ node scale this is the "drain, re-mesh, resume from step N" recovery
path; the batch size per step stays constant by raising grad-accumulation
microbatches to cover the lost data-parallel rows.

Straggler mitigation: a deadline monitor around the synchronous step. On
TPU pods a straggling host stalls the collective; the mitigation at the
framework level is (a) detect (step time > k x EWMA), (b) after M
consecutive detections, treat the host as failed: checkpoint, drop it from
the mesh (elastic path), resume. Both pieces are implemented host-side and
unit-tested with a simulated slow worker.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Optional

import jax


def best_mesh_shape(n_devices: int, model_parallel: int,
                    multi_pod_at: int = 512) -> tuple:
    """Largest usable (pod, data, model) given surviving devices."""
    if n_devices < model_parallel:
        raise ValueError(
            f"cannot keep model sharding {model_parallel} with {n_devices} devices")
    data = n_devices // model_parallel
    if n_devices >= multi_pod_at and data % 2 == 0:
        return (2, data // 2, model_parallel)
    return (data, model_parallel)


def rescale_microbatches(global_batch: int, old_data: int, new_data: int,
                         old_micro: int) -> int:
    """Keep the global batch constant when data-parallel width changes."""
    per_row = global_batch // (old_data * old_micro)
    need = global_batch // (new_data * per_row)
    return max(1, need)


@dataclass
class StragglerPolicy:
    """EWMA step-time deadline detector."""
    k: float = 3.0                 # deadline = k * ewma
    alpha: float = 0.2
    consecutive_to_fail: int = 3
    min_steps: int = 5
    ewma: float = 0.0
    steps: int = 0
    strikes: int = 0
    slow_events: int = 0

    def observe(self, step_time_s: float) -> str:
        """Returns 'ok' | 'slow' | 'fail' (fail => trigger elastic restart)."""
        self.steps += 1
        if self.steps <= self.min_steps:
            self.ewma = step_time_s if self.ewma == 0.0 else \
                (1 - self.alpha) * self.ewma + self.alpha * step_time_s
            return "ok"
        verdict = "ok"
        if step_time_s > self.k * max(self.ewma, 1e-9):
            self.strikes += 1
            self.slow_events += 1
            verdict = "slow"
            if self.strikes >= self.consecutive_to_fail:
                verdict = "fail"
        else:
            self.strikes = 0
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_time_s
        return verdict


@dataclass
class PreemptionGuard:
    """SIGTERM-aware: cloud preemption sends SIGTERM before the kill."""
    triggered: bool = False

    def install(self):
        import signal

        def handler(signum, frame):
            self.triggered = True

        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGINT, handler)
        except ValueError:
            pass  # not main thread (tests)
        return self

"""StreamFrame — the batched unit of data flowing through Percepta on device.

The paper's per-environment, per-message flow becomes tensor dimensions:
  E = environments (paper: isolated processing contexts, one per building)
  S = streams     (paper: one per Receiver/Translator source)
  M = raw samples per window (ragged; padded + validity mask)
  T = tick grid   (the model's time resolution after harmonization)

A RawWindow holds what the Accumulator collected during one Manager window;
a TickFrame is the harmonized/gap-filled/normalized result the Predictor
consumes. Both are pytrees (jit/scan/shard friendly).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class RawWindow(NamedTuple):
    """Raw samples collected in one window. Shapes (E, S, M)."""
    values: jax.Array      # float32
    # float32 seconds in the WINDOW's frame: the system stages offsets from
    # the window start (rebased in float64 before the cast, so sub-second
    # deltas stay exact on long horizons) and passes window_start=0; any
    # frame works as long as window_start shares it, since all in-window
    # tick math is shift-invariant
    timestamps: jax.Array
    valid: jax.Array       # bool — padding / lost samples are False

    @property
    def n_envs(self):
        return self.values.shape[0]

    @property
    def n_streams(self):
        return self.values.shape[1]

    @property
    def max_samples(self):
        return self.values.shape[2]


class TickFrame(NamedTuple):
    """Harmonized per-tick data. Shapes (E, S, T)."""
    values: jax.Array
    observed: jax.Array    # bool — True where a real sample backed the tick
    filled: jax.Array      # bool — True where gap-filling synthesized a value
    anomalous: jax.Array   # bool — True where anomaly handling replaced it


class FeatureFrame(NamedTuple):
    """Model-facing features after aggregation/encoding. Shapes (E, F)."""
    features: jax.Array     # normalized (what the model consumes)
    raw: jax.Array          # engineering units (what rewards are computed on)
    quality: jax.Array      # (E,) fraction of feature inputs actually observed
    tick_time: jax.Array    # (E,) timestamp of the tick


def make_raw_window(values, timestamps, valid=None) -> RawWindow:
    values = jnp.asarray(values, jnp.float32)
    timestamps = jnp.asarray(timestamps, jnp.float32)
    if valid is None:
        valid = jnp.ones(values.shape, bool)
    return RawWindow(values, timestamps, jnp.asarray(valid, bool))


def empty_tick_frame(E, S, T) -> TickFrame:
    z = jnp.zeros((E, S, T), jnp.float32)
    f = jnp.zeros((E, S, T), bool)
    return TickFrame(z, f, f, f)

"""Pallas TPU kernel: fused bucketize + per-tick aggregation.

The jnp path materializes an (R, M, T) one-hot in HBM (M raw samples x T
ticks per row) — at fleet scale that's the dominant harmonization traffic.
The kernel keeps the (ROWS, T) accumulators in VMEM and streams the M
samples with a fori_loop, so HBM sees only the (R, M) inputs and (R, T)
outputs: arithmetic-intensity goes from O(1) to O(M) per byte.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_BLK = 8


def _kernel(values_ref, ts_ref, valid_ref, t0_ref, out_ref, obs_ref, *,
            tick_s: float, n_ticks: int):
    R, M = values_ref.shape
    v = values_ref[...].astype(jnp.float32)
    ts = ts_ref[...].astype(jnp.float32)
    ok_in = valid_ref[...] > 0
    t0 = t0_ref[...].astype(jnp.float32)                 # (R, 1)

    rel = ts - t0
    idx = jnp.ceil(rel / tick_s).astype(jnp.int32) - 1   # (R, M)
    ok = ok_in & (idx >= 0) & (idx < n_ticks)

    lane = jax.lax.broadcasted_iota(jnp.int32, (R, n_ticks), 1)

    def body(m, carry):
        total, count = carry
        hit = (lane == idx[:, m][:, None]) & ok[:, m][:, None]
        h = hit.astype(jnp.float32)
        return total + h * v[:, m][:, None], count + h

    total0 = jnp.zeros((R, n_ticks), jnp.float32)
    total, count = jax.lax.fori_loop(0, M, body, (total0, total0))
    observed = count > 0
    out_ref[...] = jnp.where(observed, total / jnp.maximum(count, 1.0), 0.0)
    obs_ref[...] = observed.astype(jnp.float32)


def harmonize_pallas(values, timestamps, valid, t0, *, tick_s: float,
                     n_ticks: int, interpret: bool = True):
    """values/timestamps/valid: (R, M); t0: (R, 1)."""
    R, M = values.shape
    assert R % ROWS_BLK == 0
    kern = functools.partial(_kernel, tick_s=tick_s, n_ticks=n_ticks)
    out, obs = pl.pallas_call(
        kern,
        grid=(R // ROWS_BLK,),
        in_specs=[
            pl.BlockSpec((ROWS_BLK, M), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_BLK, M), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_BLK, M), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_BLK, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ROWS_BLK, n_ticks), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_BLK, n_ticks), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, n_ticks), jnp.float32),
            jax.ShapeDtypeStruct((R, n_ticks), jnp.float32),
        ],
        interpret=interpret,
    )(values, timestamps, valid, t0)
    return out, obs > 0

"""Serving launcher: continuous-batching engine over a selected arch.

``python -m repro.launch.serve --arch qwen3-0.6b:smoke --requests 16``
"""
from __future__ import annotations

import argparse
import json
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs.registry import get_config
    from repro.models import LM
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config(args.arch)
    model = LM(cfg, remat_policy="none")
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, args.slots, args.max_seq)

    rng = np.random.RandomState(0)
    reqs = [Request(rid=i,
                    prompt=rng.randint(1, cfg.vocab_size,
                                       (args.prompt_len,)).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    t0 = time.time()
    engine.run_until_drained(reqs)
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.tokens) for r in reqs)
    print(json.dumps({
        "requests": len(reqs), "completed": done, "tokens": toks,
        "wall_s": round(dt, 3),
        "tok_per_s": round(toks / dt, 1),
        "engine": engine.stats,
    }, indent=1))
    assert done == len(reqs)


if __name__ == "__main__":
    main()

"""Diff two BENCH_*.json artifacts and flag throughput regressions.

Walks both artifacts for comparable numeric leaves — throughput-like ones
(``windows_per_s`` / ``records_per_s`` maps and any key named
``*windows_per_s*`` / ``*records_per_s*`` / ``speedup`` nested in the cell
blocks) AND host-phase latencies (keys ending ``_ms`` or nested under a
``*phase_ms*`` block, e.g. the overlap cell's assemble/device/consume
ms/batch) — joins them by path, and reports every metric present in both
with its ratio. Throughput metrics regress DOWNWARD; latency metrics are
direction-inverted (marked ``ms↓`` in the report) and regress UPWARD, so
host-side assembly wins/losses ride the trajectory record exactly like
device ones. A metric that moves more than ``--threshold`` (default 10%)
the wrong way is flagged as a REGRESSION.

Exit status is 0 unless ``--strict`` is passed and regressions were found:
CI (``make bench-smoke``) runs it report-only, because single-run bench
numbers on shared boxes drift — the report is the signal, the committed
BENCH_prN.json trajectory is the record.

Run: ``python -m benchmarks.compare OLD.json NEW.json [--threshold 0.1]
[--strict]``.  Pass ``latest`` as OLD to diff against the newest committed
``BENCH_pr<N>.json`` (highest N, not mtime) — the CI target uses this so
the baseline can never go stale when a new trajectory record lands.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

# path components that hold raw measurement noise, not comparable metrics
_SKIP_KEYS = {"rows", "pair_ratios", "grid", "pruned", "cell"}
_METRIC_HINTS = ("windows_per_s", "records_per_s", "speedup",
                 "host_transfer_reduction")


def _is_lower_better(path: tuple) -> bool:
    """Latency-like metrics (host-phase ms/batch): smaller is faster, so
    the regression direction flips."""
    return path[-1].endswith("_ms") \
        or any("phase_ms" in p for p in path[:-1])


def _is_metric(path: tuple) -> bool:
    leaf = path[-1]
    return any(h in leaf for h in _METRIC_HINTS) \
        or any(h in p for p in path[:-1] for h in ("windows_per_s",
                                                   "records_per_s")) \
        or _is_lower_better(path)


def flatten_metrics(obj, path=()) -> dict:
    """path-tuple -> float for every throughput-like numeric leaf."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            if k in _SKIP_KEYS:
                continue
            out.update(flatten_metrics(v, path + (str(k),)))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        if _is_metric(path):
            out[path] = float(obj)
    return out


def compare(old: dict, new: dict, threshold: float = 0.1):
    """Returns (report_rows, regressions): every joined metric with its
    ratio, and the subset that moved more than ``threshold`` the wrong way
    (down for throughput, up for ``ms`` latencies)."""
    a, b = flatten_metrics(old), flatten_metrics(new)
    rows, regressions = [], []
    for path in sorted(set(a) & set(b)):
        ov, nv = a[path], b[path]
        ratio = nv / ov if ov else float("inf")
        lower_better = _is_lower_better(path)
        flag = "ms↓ " if lower_better else ""
        worse = ratio > 1.0 + threshold if lower_better \
            else ratio < 1.0 - threshold
        better = ratio < 1.0 - threshold if lower_better \
            else ratio > 1.0 + threshold
        if ov and worse:
            flag += "REGRESSION"
            regressions.append((path, ov, nv, ratio))
        elif ov and better:
            flag += "improved"
        rows.append((path, ov, nv, ratio, flag))
    only_old = sorted(set(a) - set(b))
    only_new = sorted(set(b) - set(a))
    return rows, regressions, only_old, only_new


def latest_baseline(directory: str = ".") -> str:
    """Newest committed ``BENCH_pr<N>.json`` by PR number (NOT mtime: a
    fresh checkout gives every artifact the same mtime)."""
    best, best_n = None, -1
    for p in glob.glob(os.path.join(directory, "BENCH_pr*.json")):
        m = re.fullmatch(r"BENCH_pr(\d+)\.json", os.path.basename(p))
        if m and int(m.group(1)) > best_n:
            best, best_n = p, int(m.group(1))
    if best is None:
        raise FileNotFoundError(
            f"no BENCH_pr<N>.json baseline in {os.path.abspath(directory)}")
    return best


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH artifacts, flag >threshold regressions")
    ap.add_argument("old",
                    help="baseline artifact, or 'latest' for the newest "
                         "committed BENCH_pr<N>.json")
    ap.add_argument("new")
    ap.add_argument("--threshold", type=float, default=0.1,
                    help="relative drop that counts as a regression "
                         "(default 0.10)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 when regressions are found")
    args = ap.parse_args(argv)
    if args.old == "latest":
        args.old = latest_baseline()
        print(f"# baseline: {args.old} (newest committed BENCH_pr<N>.json)")
    with open(args.old) as f:
        old = json.load(f)
    with open(args.new) as f:
        new = json.load(f)
    rows, regressions, only_old, only_new = compare(old, new,
                                                    args.threshold)
    if not rows:
        print(f"# no comparable throughput metrics between {args.old} and "
              f"{args.new}")
        return 0
    width = max(len(".".join(p)) for p, *_ in rows)
    print(f"# {args.old} -> {args.new} (threshold "
          f"{args.threshold:.0%})")
    for path, ov, nv, ratio, flag in rows:
        print(f"{'.'.join(path):<{width}}  {ov:>12.1f} -> {nv:>12.1f}  "
              f"x{ratio:5.2f}  {flag}")
    for p in only_old:
        print(f"{'.'.join(p)}: only in {args.old}")
    for p in only_new:
        print(f"{'.'.join(p)}: only in {args.new}")
    n = len(regressions)
    print(f"# {len(rows)} metrics compared, {n} regression"
          f"{'' if n == 1 else 's'} (> {args.threshold:.0%} down)")
    return 1 if (args.strict and regressions) else 0


if __name__ == "__main__":
    sys.exit(main())

"""Unified model API over all ten assigned architectures.

One ``LM`` class; the config's ``layer_pattern`` picks the blocks. Layers run
under ``lax.scan`` over *pattern groups* (stacked params) so HLO size — and
1-core CPU compile time for the 512-device dry-run — stays bounded; pattern
remainders run unscanned as ``tail`` layers.

Three entry points (what the dry-run lowers):
  * ``loss(params, batch)``            — train_4k
  * ``prefill(params, inputs)``        — prefill_32k
  * ``decode_step(params, inputs, cache)`` — decode_32k / long_500k
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, RGLRU, RWKV,
                                ModelConfig, ShapeConfig)
from repro.models import cache as cache_lib
from repro.models import layers as L
from repro.models import param as P
from repro.models.moe import moe_apply, moe_defs
from repro.models.rglru import rglru_apply, rglru_defs, rglru_step
from repro.models.rwkv6 import channel_mix, rwkv_defs, time_mix, time_mix_step


class LM:
    def __init__(self, cfg: ModelConfig, *, rwkv_chunk: int = 0,
                 q_chunk: int = 512, kv_chunk: int = 1024,
                 remat_policy: str = "full", constrain=None,
                 attn_mode: str = "heads", nq_shard: int = 1,
                 attn_constrain=None):
        self.cfg = cfg
        self.rwkv_chunk = rwkv_chunk
        self.q_chunk = q_chunk
        self.kv_chunk = kv_chunk
        self.remat_policy = remat_policy
        # sharding-constraint hook applied at block boundaries (set by the
        # distribution layer; identity on a single device)
        self.constrain = constrain if constrain is not None else (lambda x: x)
        # attention sharding: "heads" = repeat-KV + head-sharded (no attention
        # collectives; used when n_heads % model_axis == 0), "ctx" = context-
        # parallel Q chunks (used otherwise — 8/10/24/56-head archs)
        self.attn_mode = attn_mode
        self.nq_shard = max(1, nq_shard)
        ident = {"heads": (lambda x: x), "qs": (lambda x: x)}
        self.attn_constrain = attn_constrain if attn_constrain else ident
        # (mesh, dp_axes) for shard_map expert parallelism; None = dense path
        self.moe_shard = None
        # ZeRO-3 hooks (set by the distribution layer for train steps):
        # gather storage-sharded layer params to compute sharding inside scan
        self.gather_group = None
        self.gather_tail = None
        # (mesh, dp_axes) for shard_map-local KV-cache writes in decode
        self.cache_shard = None

    # ------------------------------------------------------------------ params
    def _slot_defs(self, kind: str) -> dict:
        cfg = self.cfg
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            d = {"attn": L.attention_defs(cfg)}
        elif kind == RGLRU:
            d = {"rglru": rglru_defs(cfg)}
        elif kind == RWKV:
            return {"rwkv": rwkv_defs(cfg)}  # channel-mix included
        else:
            raise ValueError(kind)
        if cfg.moe is not None:
            d["ffn"] = moe_defs(cfg)
        else:
            d["ffn"] = L.mlp_defs(cfg)
        return d

    def _group_defs(self) -> dict:
        return {f"slot{i}": self._slot_defs(k)
                for i, k in enumerate(self.cfg.layer_pattern)}

    def param_defs(self) -> dict:
        cfg = self.cfg
        defs: dict = {"embed": L.embed_defs(cfg)}
        if cfg.n_groups > 0:
            defs["groups"] = P.stack(self._group_defs(), cfg.n_groups)
        tail_kinds = cfg.layer_kinds[cfg.n_groups * len(cfg.layer_pattern):]
        if tail_kinds:
            defs["tail"] = {f"tail{i}": self._slot_defs(k)
                            for i, k in enumerate(tail_kinds)}
        return defs

    def param_specs(self):
        return P.specs(self.param_defs())

    def param_dims(self):
        return P.dims(self.param_defs())

    def init(self, rng):
        return P.init(self.param_defs(), rng)

    def param_count(self) -> int:
        return P.count(self.param_defs())

    # ------------------------------------------------------------------ cache
    def _slot_cache_defs(self, kind: str, batch: int, max_seq: int):
        cfg = self.cfg
        if kind == ATTN_GLOBAL:
            return cache_lib.kv_cache_defs(cfg, batch, max_seq)
        if kind == ATTN_LOCAL:
            return cache_lib.kv_cache_defs(cfg, batch, max_seq, window=cfg.local_window)
        if kind == RGLRU:
            return cache_lib.rglru_cache_defs(cfg, batch)
        if kind == RWKV:
            return cache_lib.rwkv_cache_defs(cfg, batch)
        raise ValueError(kind)

    def cache_defs(self, batch: int, max_seq: int) -> dict:
        cfg = self.cfg
        defs: dict = {
            "lengths": P.ParamDef((batch,), ("batch",), jnp.int32, "zeros"),
        }
        if cfg.n_groups > 0:
            defs["groups"] = P.stack(
                {f"slot{i}": self._slot_cache_defs(k, batch, max_seq)
                 for i, k in enumerate(cfg.layer_pattern)}, cfg.n_groups)
        tail_kinds = cfg.layer_kinds[cfg.n_groups * len(cfg.layer_pattern):]
        if tail_kinds:
            defs["tail"] = {f"tail{i}": self._slot_cache_defs(k, batch, max_seq)
                            for i, k in enumerate(tail_kinds)}
        return defs

    def cache_specs(self, batch: int, max_seq: int):
        return P.specs(self.cache_defs(batch, max_seq))

    def init_cache(self, batch: int, max_seq: int):
        return P.init(self.cache_defs(batch, max_seq), jax.random.PRNGKey(0))

    # ------------------------------------------------------------------ inputs
    def input_specs(self, shape: ShapeConfig) -> dict:
        """Abstract inputs for the dry-run (ShapeDtypeStruct only)."""
        cfg = self.cfg
        B = shape.global_batch
        dt = jnp.dtype(cfg.dtype)
        if shape.kind == "train":
            S = shape.seq_len
            if cfg.frontend == "embeddings":
                return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), dt),
                        "targets": jax.ShapeDtypeStruct((B, S), jnp.int32)}
            if cfg.frontend == "vlm":
                St = S - cfg.n_patches
                return {"tokens": jax.ShapeDtypeStruct((B, St), jnp.int32),
                        "patches": jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_model), dt),
                        "targets": jax.ShapeDtypeStruct((B, St), jnp.int32)}
            return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                    "targets": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if shape.kind == "prefill":
            S = shape.seq_len
            if cfg.frontend == "embeddings":
                return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)}
            if cfg.frontend == "vlm":
                return {"tokens": jax.ShapeDtypeStruct((B, S - cfg.n_patches), jnp.int32),
                        "patches": jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_model), dt)}
            return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        # decode: one new token against a cache of size seq_len
        if cfg.frontend == "embeddings":
            return {"frames": jax.ShapeDtypeStruct((B, 1, cfg.d_model), dt)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}

    # ------------------------------------------------------------------ blocks
    def _attn_block(self, p, x, kind, positions, mode, slot_cache, lengths):
        cfg = self.cfg
        window = cfg.local_window if kind == ATTN_LOCAL else 0
        H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        G = H // Hkv
        h = L.rms_norm(x, p["norm"], cfg.norm_eps)
        new_cache = slot_cache
        if mode == "decode":
            q_position = lengths  # (B,)
            q, k, v = L.attention_qkv(p, h, cfg, q_position[:, None])
            B = q.shape[0]
            q = q.reshape(B, 1, Hkv, G, Dh)
            ck = cache_lib.write_token(slot_cache["k"], k, lengths, window,
                                       shard=self.cache_shard)
            cv = cache_lib.write_token(slot_cache["v"], v, lengths, window,
                                       shard=self.cache_shard)
            kv_pos, kv_valid = cache_lib.slot_positions(
                lengths + 1, ck.shape[1], window)
            attn = L.decode_attention(q, ck.astype(h.dtype), cv.astype(h.dtype),
                                      kv_positions=kv_pos, kv_valid=kv_valid,
                                      q_position=q_position, window=window,
                                      softcap=cfg.attn_logit_softcap)
            new_cache = {"k": ck, "v": cv}
        else:
            q, k, v = L.attention_qkv(p, h, cfg, positions)
            B, S = q.shape[:2]
            valid = jnp.ones(positions.shape, jnp.bool_)
            if self.attn_mode == "heads":
                # repeat KV -> every q head has a private kv head; the head
                # dim then shards over 'model' with zero attention collectives
                ch = self.attn_constrain["heads"]
                q5 = ch(q[:, :, :, None, :])                    # (B,S,H,1,Dh)
                kr = ch(jnp.repeat(k, G, axis=2)) if G > 1 else ch(k)
                vr = ch(jnp.repeat(v, G, axis=2)) if G > 1 else ch(v)
                attn = L.blockwise_attention(
                    q5, kr, vr, q_positions=positions, kv_positions=positions,
                    kv_valid=valid, window=window,
                    softcap=cfg.attn_logit_softcap,
                    q_chunk=self.q_chunk, kv_chunk=self.kv_chunk)
                attn = attn.reshape(B, S, Hkv, G, Dh)
            else:  # context-parallel q chunks
                q5 = q.reshape(B, S, Hkv, G, Dh)
                nq = self.nq_shard
                qc = max(1, -(-S // nq))
                attn = L.blockwise_attention(
                    q5, k, v, q_positions=positions, kv_positions=positions,
                    kv_valid=valid, window=window,
                    softcap=cfg.attn_logit_softcap,
                    q_chunk=qc, kv_chunk=self.kv_chunk,
                    q_mode="shard", constrain_qs=self.attn_constrain["qs"])
            if mode == "prefill":
                size = slot_cache["k"].shape[1]
                new_cache = {"k": cache_lib.fill_from_prefill(k, size, window),
                             "v": cache_lib.fill_from_prefill(v, size, window)}
        out = L.attention_out(p, attn, x.dtype)
        if cfg.post_norms:
            out = L.rms_norm(out, p["post_norm"], cfg.norm_eps)
        return x + out, new_cache

    def _ffn_block(self, p, x, mode):
        cfg = self.cfg
        h = L.rms_norm(x, p["norm"], cfg.norm_eps)
        aux = jnp.zeros((), jnp.float32)
        if cfg.moe is not None:
            out, aux = moe_apply(p, h, cfg, shard=self.moe_shard)
        else:
            out = L.mlp_apply(p, h, constrain_ff=self.attn_constrain.get("ff"))
        if cfg.post_norms:
            out = L.rms_norm(out, p["post_norm"], cfg.norm_eps) \
                if "post_norm" in p else out
        return x + out, aux

    def _rglru_block(self, p, x, mode, slot_cache):
        cfg = self.cfg
        h = L.rms_norm(x, p["norm"], cfg.norm_eps)
        if mode == "decode":
            out, (conv, hstate) = rglru_step(p, h, cfg, slot_cache["conv"],
                                             slot_cache["h"])
            new_cache = {"conv": conv, "h": hstate}
        elif mode == "prefill":
            out, (conv, hstate) = rglru_apply(p, h, cfg, return_state=True)
            new_cache = {"conv": conv, "h": hstate}
        else:
            out, _ = rglru_apply(p, h, cfg)
            new_cache = slot_cache
        return x + out, new_cache

    def _rwkv_block(self, p, x, mode, slot_cache):
        cfg = self.cfg
        h = L.rms_norm(x, p["norm"], cfg.norm_eps)
        if mode == "decode":
            tm_state = {"shift": slot_cache["shift"], "wkv": slot_cache["wkv"]}
            out, new_tm = time_mix_step(p, h, cfg, tm_state)
            x = x + out
            h2 = L.rms_norm(x, p["cm_norm"], cfg.norm_eps)
            out2, new_cm = channel_mix(p, h2, cfg, slot_cache["cm_shift"],
                                       return_state=True)
            x = x + out2
            return x, {"shift": new_tm["shift"], "wkv": new_tm["wkv"],
                       "cm_shift": new_cm}
        want_state = mode == "prefill"
        out, new_tm = time_mix(p, h, cfg, None, chunk=self.rwkv_chunk,
                               return_state=want_state)
        x = x + out
        h2 = L.rms_norm(x, p["cm_norm"], cfg.norm_eps)
        out2, new_cm = channel_mix(p, h2, cfg, None, return_state=want_state)
        x = x + out2
        if want_state:
            return x, {"shift": new_tm["shift"], "wkv": new_tm["wkv"],
                       "cm_shift": new_cm}
        return x, slot_cache

    def _apply_slot(self, kind, p, x, positions, mode, slot_cache, lengths):
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            x, new_cache = self._attn_block(p["attn"], x, kind, positions,
                                            mode, slot_cache, lengths)
            x, aux = self._ffn_block(p["ffn"], x, mode)
            return x, new_cache, aux
        if kind == RGLRU:
            x, new_cache = self._rglru_block(p["rglru"], x, mode, slot_cache)
            x, aux = self._ffn_block(p["ffn"], x, mode)
            return x, new_cache, aux
        if kind == RWKV:
            x, new_cache = self._rwkv_block(p["rwkv"], x, mode, slot_cache)
            return x, new_cache, jnp.zeros((), jnp.float32)
        raise ValueError(kind)

    # ------------------------------------------------------------------ forward
    def _remat(self, fn):
        if self.remat_policy == "none":
            return fn
        if self.remat_policy == "dots":
            pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            return jax.checkpoint(fn, policy=pol)
        return jax.checkpoint(fn)

    def backbone(self, params, x, positions, mode="train", cache=None):
        """x: (B, S, d). Returns (x, new_cache, aux_loss)."""
        cfg = self.cfg
        pattern = cfg.layer_pattern
        lengths = cache["lengths"] if cache is not None else None
        dummy = jnp.zeros((x.shape[0],), jnp.int32)
        use_cache = cache is not None and mode != "train"

        def group_fn(x, group_params, group_cache):
            if self.gather_group is not None:
                group_params = self.gather_group(group_params)
            aux_tot = jnp.zeros((), jnp.float32)
            new_cache = {}
            for i, kind in enumerate(pattern):
                key = f"slot{i}"
                sc = group_cache.get(key) if group_cache else None
                x, nc, aux = self._apply_slot(
                    kind, group_params[key], x, positions, mode, sc,
                    lengths if lengths is not None else dummy)
                new_cache[key] = nc
                aux_tot += aux
            return self.constrain(x), new_cache, aux_tot

        aux_total = jnp.zeros((), jnp.float32)
        new_groups = None
        if cfg.n_groups > 0:
            gp = params["groups"]
            gc = cache["groups"] if use_cache else None
            fn = self._remat(group_fn) if mode == "train" else group_fn

            def scan_body(carry, xs):
                x, aux = carry
                g_params, g_cache = xs
                x, nc, a = fn(x, g_params, g_cache)
                return (x, aux + a), nc

            (x, aux_total), new_groups = jax.lax.scan(
                scan_body, (x, aux_total), (gp, gc))

        new_tail = None
        if "tail" in params:
            new_tail = {}
            if self.gather_tail is not None:
                params = dict(params, tail=self.gather_tail(params["tail"]))
            tail_kinds = cfg.layer_kinds[cfg.n_groups * len(pattern):]
            for i, kind in enumerate(tail_kinds):
                key = f"tail{i}"
                sc = cache["tail"][key] if use_cache else None
                x, nc, aux = self._apply_slot(
                    kind, params["tail"][key], x, positions, mode, sc,
                    lengths if lengths is not None else dummy)
                new_tail[key] = nc
                aux_total += aux

        x = L.rms_norm(x, params["embed"]["final_norm"], cfg.norm_eps)
        new_cache = None
        if use_cache:
            new_cache = {"lengths": lengths + (1 if mode == "decode" else 0)}
            if new_groups is not None:
                new_cache["groups"] = new_groups
            if new_tail is not None:
                new_cache["tail"] = new_tail
        return x, new_cache, aux_total

    def _embed_inputs(self, params, inputs, *, start_positions=None):
        """Returns (x, positions, target_mask_offset)."""
        cfg = self.cfg
        e = params["embed"]
        if cfg.frontend == "embeddings":
            x = inputs["frames"].astype(jnp.dtype(cfg.dtype))
        elif cfg.frontend == "vlm":
            tok = L.embed_tokens(e, inputs["tokens"], cfg)
            if "patches" in inputs:  # decode steps carry tokens only
                x = jnp.concatenate(
                    [inputs["patches"].astype(tok.dtype), tok], axis=1)
            else:
                x = tok
        else:
            x = L.embed_tokens(e, inputs["tokens"], cfg)
        B, S = x.shape[:2]
        pos = jnp.arange(S, dtype=jnp.int32)[None, :]
        if start_positions is not None:
            pos = pos + start_positions[:, None]
        pos = jnp.broadcast_to(pos, (B, S))
        return self.constrain(x), pos

    # ------------------------------------------------------------------ train
    def loss(self, params, batch):
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)
        x, _, aux = self.backbone(params, x, positions, mode="train")
        targets = batch["targets"]
        if cfg.frontend == "vlm":
            # only text positions carry next-token targets
            x = x[:, cfg.n_patches:]
        # next-token shift: predict t+1 from t
        x = x[:, :-1]
        t = targets[:, 1:]
        loss = L.chunked_cross_entropy(params["embed"], x, t, cfg)
        return loss + aux, {"ce": loss, "aux": aux}

    # ------------------------------------------------------------------ serve
    def prefill(self, params, inputs, max_seq=None):
        """max_seq sizes the cache (>= prompt + planned generation); the
        dry-run's prefill cell uses the default (cache == prompt length)."""
        cfg = self.cfg
        x, positions = self._embed_inputs(params, inputs)
        B, S = x.shape[:2]
        cache = self.cache_specs(B, max_seq or S)  # structure only
        cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), cache,
            is_leaf=lambda s: isinstance(s, jax.ShapeDtypeStruct))
        cache["lengths"] = jnp.full((B,), S, jnp.int32)
        x, new_cache, _ = self.backbone(params, x, positions, mode="prefill",
                                        cache=cache)
        new_cache["lengths"] = jnp.full((B,), S, jnp.int32)
        logits = L.lm_head(params["embed"], x[:, -1:], cfg)[:, 0]
        return logits, new_cache

    def decode_step(self, params, inputs, cache):
        cfg = self.cfg
        x, _ = self._embed_inputs(params, inputs,
                                  start_positions=cache["lengths"])
        positions = cache["lengths"][:, None]
        x, new_cache, _ = self.backbone(params, x, positions, mode="decode",
                                        cache=cache)
        logits = L.lm_head(params["embed"], x, cfg)[:, 0]
        return logits, new_cache

"""Retraining path: train an LM for a few hundred steps with the full
fault-tolerant loop (checkpoint/restart, deterministic resumable data
stream), then 'crash' it and prove resume continues bit-compatibly.

Run: PYTHONPATH=src python examples/train_retrain.py [--steps 300]
"""
import argparse
import shutil

import numpy as np

from repro.configs.base import ShapeConfig, TrainConfig
from repro.configs.registry import get_config
from repro.launch.mesh import make_smoke_mesh
from repro.train.loop import train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--arch", default="qwen3-0.6b:smoke")
args = ap.parse_args()

cfg = get_config(args.arch)
mesh = make_smoke_mesh()
shape = ShapeConfig("train", seq_len=64, global_batch=8, kind="train")
ckdir = "/tmp/percepta_retrain_ckpt"
shutil.rmtree(ckdir, ignore_errors=True)
tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=20,
                   total_steps=args.steps, checkpoint_every=50,
                   checkpoint_dir=ckdir, async_checkpoint=True)

print(f"=== training {args.arch} ({cfg.vocab_size}-vocab) for {args.steps} "
      f"steps with checkpoint/restart ===")


def log(step, m):
    if step % 50 == 0 or step in (1, 5, 10):
        print(f"step {step:4d}  loss {m['loss']:.4f}  lr {m['lr']:.2e}  "
              f"{m['time_s']*1e3:.0f} ms")


# phase 1: run 60% of the way, then "crash" (max_steps)
crash_at = int(args.steps * 0.6)
res1 = train(cfg, shape, mesh, tcfg=tcfg, max_steps=crash_at, on_step=log)
print(f"-- simulated crash at step {res1.final_step} "
      f"(loss {res1.losses[-1]:.4f}) --")

# phase 2: restart — restores the latest checkpoint + stream cursor
res2 = train(cfg, shape, mesh, tcfg=tcfg, on_step=log)
print(f"-- restored from step {res2.restored_from}, "
      f"ran {res2.steps_run} more steps --")

first = np.mean(res1.losses[:10])
last = np.mean(res2.losses[-10:])
print(f"\nloss: first10 {first:.4f} -> last10 {last:.4f} "
      f"(delta {first - last:+.4f})")
assert last < first, "training must reduce loss"
print("straggler slow-steps observed:", res1.straggler_events
      + res2.straggler_events)
print("OK: fault-tolerant training loop converges and resumes.")

"""WindowPrefetcher — double-buffered host-side window assembly.

The scan engine's Manager loop is a strict alternation: drain queues and
build the (K, E, S, M) batch on the host, THEN dispatch ``run_many`` and
wait. The device idles through every ``close_windows`` pass and the host
idles through every device batch. This module pipelines the two: a pump
thread assembles window batch *j+1* (clock advance -> receiver poll ->
queue drain -> ``Accumulator.close_windows`` -> staged ``RawWindow``)
while batch *j* executes on device via JAX's async dispatch; the Manager
blocks only when it consumes batch *j*'s results.

Bit-identity with the synchronous ``scan`` mode is BY CONSTRUCTION, via a
deterministic batch-epoch handoff protocol:

  * the Manager submits :class:`BatchPlan`s (epoch-numbered, chronologically
    ordered window bounds) on an unbounded task queue;
  * the pump thread is the ONLY pumper/drainer in async modes and processes
    plans strictly in epoch order, performing exactly the clock-advance /
    poll / drain sequence the synchronous loop would have performed at the
    same window boundaries — so every record lands in the same batch;
  * assembled batches travel back on a depth-1 buffer (the "double" in
    double-buffered: one batch on device, at most one staged ahead), which
    also bounds host memory when the device falls behind; this depth is
    what sizes the system's rotating staging-buffer pool (at most three
    batches are ever alive: assembling, staged, in flight — see
    ``PerceptaSystem._STAGE_DEPTH``), and ``ingest_workers`` composes
    cleanly because the pump thread remains the sole pumper/drainer and
    merely fans the per-env assembly work out to its worker pool;
  * the Manager consumes batches in epoch order and verifies the epoch tag
    on every handoff.

Pump-thread exceptions are captured and re-raised in the Manager thread at
the handoff point, so a failing drain/close surfaces exactly like it would
synchronously.
"""
from __future__ import annotations

import queue
import threading
from typing import List, NamedTuple, Optional, Tuple


class BatchPlan(NamedTuple):
    epoch: int                 # strictly increasing handoff tag
    bounds: List[Tuple[float, float]]
    pump: bool                 # advance the clock + poll receivers first
    membership: int = 0        # env-membership epoch the plan was built under


class AssembledBatch(NamedTuple):
    epoch: int
    bounds: List[Tuple[float, float]]
    raw: object                # RawWindow (K, E, S, M), window-relative ts
    counts: List[int]
    membership: int = 0        # echoed from the plan; Manager verifies it


class _PumpError(NamedTuple):
    epoch: int
    exc: BaseException


_STOP = object()


class WindowPrefetcher:
    """Owns the pump thread; one instance per system, lazily started.

    ``assemble(bounds, pump)`` is the system callback doing the actual
    clock-advance/poll/drain/close work — injecting it keeps this module
    free of system internals and trivially testable.
    """

    def __init__(self, assemble, depth: int = 1):
        assert depth >= 1
        self._assemble = assemble
        self._depth = depth
        self._tasks: "queue.Queue" = queue.Queue()
        self._ready: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stopping = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._next_submit = 0      # next epoch to hand to the pump
        self._next_consume = 0     # next epoch the Manager must receive
        self._failed: Optional[BaseException] = None

    # --- lifecycle -----------------------------------------------------------
    def _ensure_thread(self):
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(target=self._pump_loop,
                                            name="window-prefetch",
                                            daemon=True)
            self._thread.start()

    def stop(self):
        """Stop the pump thread; safe to call repeatedly / when never run.

        Works even when the Manager abandoned assembled batches (e.g. a
        consumer exception mid-run): the stop flag unblocks a pump stuck on
        the full ready buffer, and all queues/epoch counters are reset so a
        later submit() starts from a clean handoff state instead of
        replaying stale plans."""
        if self._thread is not None and self._thread.is_alive():
            self._stopping.set()
            self._tasks.put(_STOP)
            self._thread.join(timeout=10.0)
        self._thread = None
        self._stopping = threading.Event()
        self._tasks = queue.Queue()
        self._ready = queue.Queue(maxsize=self._depth)
        self._next_submit = 0
        self._next_consume = 0

    # --- Manager side --------------------------------------------------------
    def submit(self, bounds, pump: bool = True, membership: int = 0) -> int:
        """Queue one batch plan; returns its epoch tag.

        ``membership`` tags the plan with the env-membership epoch it was
        built under; elastic systems verify it on the assembled batch so
        attach/detach can only land at batch boundaries (no plan built
        before the change is ever consumed after it)."""
        if self._failed is not None:
            raise RuntimeError("window prefetcher failed") from self._failed
        self._ensure_thread()
        epoch = self._next_submit
        self._next_submit += 1
        self._tasks.put(BatchPlan(epoch, list(bounds), pump, membership))
        return epoch

    def in_flight(self) -> int:
        """Plans submitted but not yet consumed (0 = a true batch boundary)."""
        return self._next_submit - self._next_consume

    def next_batch(self, timeout: float = 600.0) -> AssembledBatch:
        """Block for the next assembled batch, verifying the epoch handoff.

        Re-raises any exception the pump thread hit while assembling (the
        pump stops at the first failure, so the error epoch is always the
        one the Manager is waiting on)."""
        got = self._ready.get(timeout=timeout)
        if isinstance(got, _PumpError):
            self._failed = got.exc
            raise got.exc
        assert got.epoch == self._next_consume, \
            f"epoch handoff violated: got {got.epoch}, " \
            f"expected {self._next_consume}"
        self._next_consume += 1
        return got

    # --- pump side -----------------------------------------------------------
    def _put_ready(self, item) -> bool:
        """Blocking put that stays responsive to stop(): a Manager that
        abandons its batches must not wedge the pump on the full buffer."""
        while not self._stopping.is_set():
            try:
                self._ready.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _pump_loop(self):
        while not self._stopping.is_set():
            task = self._tasks.get()
            if task is _STOP:
                return
            try:
                raw, counts = self._assemble(task.bounds, task.pump)
            except BaseException as e:  # propagate to the Manager thread
                self._put_ready(_PumpError(task.epoch, e))
                return
            if not self._put_ready(AssembledBatch(task.epoch, task.bounds,
                                                  raw, counts,
                                                  task.membership)):
                return

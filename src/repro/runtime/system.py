"""PerceptaSystem — full wiring of Figure 1, multi-environment.

Deployment modes (paper §III.C): the SAME system object serves
  * edge  — one environment, fully local
  * fog   — a few nearby environments
  * cloud — many isolated environments simultaneously
All environments are rows of the batched device pipeline; isolation is by
construction (per-env queues, per-env state rows, per-env model slots).

Time is virtual (``speedup``) so benchmarks can run days of stream time in
seconds. The Manager logic lives in ``run_window``: close each env's window,
assemble the device batch, run the (fused or modular) Percepta tick, run the
Predictor, forward the decisions, log everything.

``mode="scan"`` switches the Manager loop to the scan-fused engine: queues
are drained once per batch, each env's Accumulator closes K consecutive
windows into a stacked (K, E, S, M) RawWindow, and ONE device dispatch
(``PerceptaPipeline.run_many``) processes all K windows with the state
carried on device. The decision path is batched the same way: the
Predictor consumes the stacked (K, E, F) features in ONE jitted dispatch
(``Predictor.on_windows`` — policy/validate under ``lax.scan``, K-leading
reward terms, replay appended via the scan-carried ``add_many``), and
Forwarders/DB take per-window batch calls (``dispatch_window`` /
``append_many``, one lock per call). Host-side consumers still see one
result row per window, in window order, bit-identical to the per-window
reference (``batched_consume=False``).

``mode="scan_sharded"`` is the same Manager loop with the device dispatch
executed under ``shard_map`` on an env-sharded mesh (envs -> the ``data``
axis, per-env state rows and batch rows split across devices; see
``core.pipeline.make_run_many_sharded``). Outputs are bit-identical to
``scan``; on one device the mesh degenerates to it. CPU multi-device
recipe: ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before JAX
initializes.

``mode="scan_async"`` (and ``"scan_async_sharded"``, which composes with
the env-sharded dispatch) pipelines host ingest against device compute: a
``runtime.prefetch.WindowPrefetcher`` pump thread assembles window batch
j+1 (clock advance -> receiver poll -> queue drain -> ``close_windows``)
while batch j executes on device via JAX async dispatch, and the Manager
blocks only at result consumption. The pump performs exactly the
clock-advance/poll/drain sequence the synchronous loop would at the same
window boundaries (the deterministic batch-epoch handoff), so outputs are
bit-identical to ``scan`` by construction.

``mode="scan_fused_decide"`` collapses the LAST dispatch boundary: the
Predictor's per-window step (policy gemm, ``validate_actions``, reward
terms, ``replay.add``) is traced INTO the pipeline scan body
(``core.pipeline.run_many_decide``), the decision state
(``predictor.DecideState``: prev obs/actions, have_prev, exact tick
counter, the replay ring) joins the pipeline state in one donated device
carry, and the whole ingest->decide->bank loop costs ONE device dispatch
per K-window batch. Consume only drains host sinks from the small
per-window outputs (actions, rewards, violation flags, exact per-env
observed/filled/anomalous counts); the (K, E, F) feature stack is fetched
only when a LogDB is attached, and the (K, E, S, T) frames never leave
the device. ``"scan_fused_decide_sharded"`` runs the fused scan under
``shard_map`` on the env mesh (decide carry sharded on the env dim,
policy weights replicated, scalars replicated — collective-free, so
bit-identical); ``"scan_fused_decide_async"`` /
``"scan_fused_decide_async_sharded"`` compose with the prefetcher (and,
like all async modes, do not donate). Accessor rules: the replay ring
lives in the donated carry, so read it ONLY through
``system.export_replay(salt)`` / ``snapshot_decide()`` /
``replay_size()`` — never through ``predictor.replay``, which is a stale
snapshot of construction time in these modes.

``scan_k="auto"`` runs ``core.autotune.tune_scan_params`` at construction:
a short measured grid over windows-per-dispatch x env-mesh split picks the
windows/s-optimal configuration for this host/device/shape (result kept on
``self.tuned``).

Device-visible time is WINDOW-RELATIVE (long-horizon float32 safety): the
Accumulator subtracts each window's start in float64 before the float32
cast and every pipeline dispatch receives ``window_start = 0``; absolute
float32 seconds would quantize sub-second deltas past t~2^24 s (~194 days
of stream time — minutes of wall time at high ``speedup``). The seasonal
tick-of-day phase survives via the exact integer ``PipelineConfig.tick0``
offset derived from ``t0``.

``train="online"`` (fused-decide modes only) attaches a
``runtime.trainer.OnlineTrainer``: one jitted sample+AdamW update per
K-window batch, enqueued right AFTER the fused decide dispatch so it runs
in the dispatch bubble while the host consumes. Policy hot-swaps happen
only at batch boundaries (``apply_pending`` swaps the carry's ``policy``/
``version`` leaves before the next dispatch), ``policy_version`` increments
monotonically per applied update, and every replay row / LogDB row is
stamped with the version that produced its action — so each K-batch is
attributable to exactly one policy. With training off (or an idle trainer
on an empty ring) the decide path is bit-identical to the plain fused
modes. Accessors: ``policy_version()``, ``snapshot_policy()``,
``train_stats()``.

``elastic=True`` (scan modes only) turns the env axis into a padded SLOT
POOL: ``env_slots`` rows are allocated up front, an ``active`` (E,) bool
mask — a traced VALUE, so membership changes never retrace — rides every
dispatch (a trailing ``run_many`` input in the plain scan modes, the
``DecideState.active``/``prev_ok`` carry leaves in the fused ones), and
:meth:`attach_env` / :meth:`detach_env` flip slots between window batches
only (the prefetcher's membership epoch tag enforces the boundary in the
async modes). Inactive slots are fed all-invalid raw windows (state
updates are natural no-ops) and masked to deterministic zeros on every
output; they are excluded from decisions, reward/violation stats,
replay banking and sampling (the ring's per-cell ``valid`` column),
LogDB rows and Forwarder traffic — active-row results stay bit-identical
to a dense fixed-E system over the same envs. When the pool fills,
:meth:`resize` grows it (``distribution.elastic``): every env-leading
pytree is padded against a fresh init template, re-placed on the
re-chosen env mesh (sharded modes), and the engine is rebuilt — the one
allowed retrace point; surviving rows resume bit-exactly.

``ingest="columnar"`` (the default) moves record flow onto the
structure-of-arrays fast path: Receivers hand whole polls to
``Translator.translate_batch`` which publishes one ``RecordBatch`` per
(source, env) poll, and the Accumulator buckets them with vectorized
NumPy (argsort/searchsorted) — no Python-level per-record loop anywhere
between the device simulator and the (K, E, S, M) device batch.
``ingest="records"`` keeps the per-payload Record path — the
wire-protocol-faithful baseline the benchmarks compare against. The two
paths produce identical windows for lossless codecs (mqtt json, amqp
doubles); the http CSV codec rounds values to 6 decimals on the wire, so
there the columnar path (which skips the encode/decode) is the
higher-fidelity one.

The host ingest fast path (``ingest_fastpath=True``, default) makes batch
assembly allocation- and sort-free in the steady state: Accumulators
append into preallocated per-stream arenas, receivers attach a measured
per-poll sortedness flag that lets ``close_windows`` bucket by
``searchsorted`` alone (a stable per-stream argsort handles unsorted
arrivals — identical ordering to the legacy global lexsort), and
``assemble_windows`` closes every env directly into a rotating pool of
preallocated (K, E, S, M) staging buffers (host numpy; donation rules
untouched). ``ingest_workers=N`` additionally partitions the per-env
assembly across N persistent threads with deterministic slot-striped
ownership. Every combination is bit-identical to the legacy path —
windows, stats, tie order, drop accounting (tests/test_ingest_fastpath).
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PerceptaPipeline, PipelineConfig
from repro.core.frame import make_raw_window
from repro.runtime.accumulator import Accumulator
from repro.runtime.forwarder import ForwarderHub
from repro.runtime.predictor import Predictor
from repro.runtime.prefetch import WindowPrefetcher
from repro.runtime.queues import QueueBroker
from repro.runtime.receivers import Receiver, SimulatedDevice
from repro.runtime.records import RecordBatch, count_records
from repro.runtime.translator import Translator

# Manager-loop mode -> device-pipeline mode: the async modes reuse the scan
# engines and differ only in how the Manager overlaps host assembly
_PIPELINE_MODE = {
    "scan_async": "scan",
    "scan_async_sharded": "scan_sharded",
    "scan_fused_decide_async": "scan_fused_decide",
    "scan_fused_decide_async_sharded": "scan_fused_decide_sharded",
}
_FUSED_DECIDE_MODES = ("scan_fused_decide", "scan_fused_decide_sharded",
                       "scan_fused_decide_async",
                       "scan_fused_decide_async_sharded")
_SCAN_MODES = ("scan", "scan_sharded", "scan_async",
               "scan_async_sharded") + _FUSED_DECIDE_MODES
_ASYNC_MODES = ("scan_async", "scan_async_sharded",
                "scan_fused_decide_async", "scan_fused_decide_async_sharded")
# pipeline modes whose dispatch runs under shard_map on the env mesh
_SHARDED_PIPE_MODES = ("scan_sharded", "scan_fused_decide_sharded")


@dataclass
class SourceSpec:
    source_id: str
    protocol: str                 # mqtt | http | amqp
    device: SimulatedDevice
    unit_scale: float = 1.0


class PerceptaSystem:
    def __init__(self, env_ids: Sequence[str], sources: Sequence[SourceSpec],
                 pipeline_cfg: PipelineConfig, predictor: Predictor,
                 forwarders: Optional[ForwarderHub] = None, db=None,
                 mode: str = "fused", speedup: float = 60.0,
                 t0: float = 0.0, manual_time: bool = False,
                 scan_k=8, ingest: str = "columnar",
                 autotune: Optional[dict] = None,
                 batched_consume: bool = True,
                 contract_check: bool = True,
                 train: Optional[str] = None,
                 train_cfg: Optional[dict] = None,
                 policy=None,
                 env_slots: Optional[int] = None,
                 elastic: bool = False,
                 ingest_workers: int = 1,
                 ingest_fastpath: bool = True):
        # manual_time: the virtual clock only advances when run_windows
        # closes a window — deterministic under arbitrary jit-compile stalls
        # (tests); wall-clock speedup mode is the realistic deployment shape.
        self.manual_time = manual_time
        self._manual_t = t0
        # elastic: the env axis is a padded slot pool; E == env_slots rows,
        # of which only the masked subset is live (module docstring)
        self.elastic = bool(elastic)
        if self.elastic:
            if mode not in _SCAN_MODES:
                raise ValueError(
                    "elastic=True needs a scan engine (the active mask "
                    f"rides the scan dispatch); mode {mode!r} dispatches "
                    "per window")
            slots = int(env_slots) if env_slots is not None \
                else pipeline_cfg.n_envs
            assert len(env_ids) <= slots, (len(env_ids), slots)
            assert pipeline_cfg.n_envs == slots, \
                "elastic: pipeline_cfg.n_envs must equal env_slots " \
                f"({pipeline_cfg.n_envs} != {slots})"
            assert predictor.n_envs == slots, \
                "elastic: build the Predictor at env_slots rows " \
                f"({predictor.n_envs} != {slots})"
            self.env_slots: Optional[int] = slots
            self._slot_env: List[Optional[str]] = \
                list(env_ids) + [None] * (slots - len(env_ids))
            self._free_slots: List[int] = list(range(len(env_ids), slots))
            self._active = np.zeros(slots, bool)
            self._active[:len(env_ids)] = True
            self._prev_ok = np.zeros(slots, bool)
        else:
            assert env_slots is None or env_slots == len(env_ids), \
                "env_slots beyond len(env_ids) requires elastic=True"
            assert pipeline_cfg.n_envs == len(env_ids)
            self.env_slots = None
        self._membership_epoch = 0
        assert pipeline_cfg.n_streams == len(sources)
        self.env_ids = list(env_ids)
        self.sources = list(sources)
        # bake the absolute tick origin in (exact integer seasonal phase
        # under window-relative device timestamps; see core.pipeline)
        pipeline_cfg = dataclasses.replace(
            pipeline_cfg, tick0=int(round(t0 / pipeline_cfg.tick_s)))
        self.cfg = pipeline_cfg
        self.mode = mode
        pipe_mode = _PIPELINE_MODE.get(mode, mode)
        self.fused_decide = mode in _FUSED_DECIDE_MODES
        # policy: a registry name ("linear"|"mlp"|"rglru"|"rwkv6") or
        # runtime.policies.PolicyConfig — rebinds the predictor's model
        # through the certified registry (runtime.policies.build_policy),
        # so the adapter arrives with its PolicyCertificate attached
        if policy is not None:
            predictor.set_model(policy)
        # fused-decide: the decision step is traced into the pipeline scan
        # and the decision state (prev obs/actions, tick, replay ring)
        # becomes part of the device carry — the Predictor hands both over
        # here and only does host bookkeeping (absorb_fused) afterwards
        decide = predictor.make_decide_fn() if self.fused_decide else None
        self._decide = decide
        self._dstate = predictor.decide_state() if self.fused_decide else None
        if self.elastic and self.fused_decide:
            # the elastic mask leaves join the device carry BEFORE the
            # contract check and the pipeline build, so the masked decide
            # path is exactly what gets checked, traced and sharded
            self._dstate = self._dstate._replace(
                active=jnp.asarray(self._active),
                prev_ok=jnp.asarray(self._prev_ok))
        # construction-time invariant gate (ROADMAP item 2): statically
        # check the decision path's jaxpr BEFORE building/compiling the
        # engine, so a cross-env contraction (silent 1-ulp shard
        # divergence), a hidden host callback in the scan body, or a
        # float32 absolute-time cast fails registration with the offending
        # primitive + source line. Env-axis rules bind only under the
        # sharded dispatches (a fused non-sharded build may legally run a
        # non-row-wise model); contract_check=False skips the gate.
        self.contract_check = bool(contract_check)
        if self.contract_check and (self.fused_decide
                                    or pipe_mode in _SHARDED_PIPE_MODES):
            from repro import analysis
            # env rules bind only where the decision math itself runs
            # inside the env-sharded dispatch (fused+sharded); in plain
            # scan_sharded the Predictor consumes on the host, unsharded
            analysis.check_system(
                predictor, decide=decide, dstate=self._dstate,
                sharded=(self.fused_decide
                         and pipe_mode in _SHARDED_PIPE_MODES),
                label=f"PerceptaSystem(mode={mode!r})")
        # fused/sharded modes additionally demand a valid PolicyCertificate
        # for the model itself (repro.analysis.certify): registry policies
        # arrive with one attached (cached — repeated standups skip the
        # trace entirely); an ad-hoc adapter is certified here at the true
        # (E, F, A) shapes, with the env/carry families binding only under
        # the env-sharded dispatch (a fused non-sharded build may legally
        # run a non-row-wise model, e.g. examples/serve_edge.py's LM).
        self.policy_certificate = None
        if self.contract_check and self.fused_decide:
            cert = getattr(predictor.model, "certificate", None)
            if cert is None:
                from repro.analysis import certify
                sharded = pipe_mode in _SHARDED_PIPE_MODES
                cert = certify.certify_policy(
                    predictor.model,
                    ((predictor.n_envs, predictor.n_features,
                      predictor.action_space.n),),
                    name=getattr(predictor.model, "name", None),
                    rules=certify.Rules(env=sharded, collectives=True,
                                        callbacks=True, time=True,
                                        carry=sharded))
                predictor.model.certificate = cert
            self.policy_certificate = cert
        # predictor tick index of this system's window 0: export-time
        # reconstruction maps tick idx -> window (idx - base); ticks issued
        # BEFORE this system keep their host-mirror times
        self._tick_base = int(predictor.stats["ticks"])

        # scan_k="auto": short measured calibration grid over K x mesh split
        self.tuned = None
        mesh = None
        if scan_k == "auto":
            from repro.core.autotune import tune_scan_params
            from repro.distribution import sharding as shard_lib
            kw = dict(autotune or {})
            if pipe_mode not in _SHARDED_PIPE_MODES:
                # mesh splits only apply to the sharded dispatches
                kw.setdefault("device_counts", [1])
            if self.fused_decide:
                # tune the engine that will actually run: the fused scan
                # (pipeline tick + decision step in one dispatch)
                kw.setdefault("decide", decide)
                kw.setdefault("decide_state", self._dstate)
            self.tuned = tune_scan_params(pipeline_cfg, **kw)
            scan_k = self.tuned.scan_k
            if pipe_mode in _SHARDED_PIPE_MODES:
                # honor the measured split even when it is 1 device (the
                # mesh then degenerates to plain scan); leaving mesh=None
                # would silently shard over ALL devices instead
                mesh = shard_lib.env_mesh(
                    pipeline_cfg.n_envs,
                    devices=jax.devices()[:max(1, self.tuned.mesh_devices)])
        self.scan_k = max(1, int(scan_k))
        assert ingest in ("columnar", "records"), ingest
        self.ingest = ingest
        # ingest_fastpath: per-stream arena staging + sorted-merge window
        # bucketing in every Accumulator (bit-identical to the legacy
        # chunk-list + global-lexsort path, which False keeps alive for
        # before/after benchmarking and parity tests)
        self.ingest_fastpath = bool(ingest_fastpath)
        # ingest_workers=N: assemble_windows partitions the live envs over
        # N persistent workers with deterministic slot-striped ownership;
        # per-env work (drain -> ingest -> close into disjoint staging
        # rows) is env-isolated, and the per-window record counts are
        # summed with integer adds, so results are bit-identical to the
        # serial loop. The pump thread stays the only pumper/drainer in
        # async modes — workers only parallelize the per-env assembly the
        # pump (or Manager) already owns, so the prefetcher's epoch
        # protocol is untouched.
        self.ingest_workers = max(1, int(ingest_workers))
        self._ingest_pool = None
        if self.ingest_workers > 1:
            from concurrent.futures import ThreadPoolExecutor
            self._ingest_pool = ThreadPoolExecutor(
                max_workers=self.ingest_workers,
                thread_name_prefix="percepta-ingest")
        # (K, E, S, M)-keyed pool of reusable host staging buffers for
        # assemble_windows (see _staging_buffers)
        self._stage_pool: Dict[tuple, dict] = {}
        # scan-mode consume: one Predictor.on_windows dispatch per K-window
        # batch (default); False keeps the per-window on_tick loop — the
        # tested reference path the batched one must match bit for bit
        self.batched_consume = bool(batched_consume)
        # async modes must NOT donate: dispatching with a donated input that
        # is still being computed blocks the dispatch (and the pump thread
        # behind it), serializing the very batches the prefetcher overlaps.
        # Double-buffering two state pytrees is the async design anyway.
        self.pipeline = PerceptaPipeline(
            pipeline_cfg, mode=pipe_mode,
            donate=mode in ("scan", "scan_sharded", "scan_fused_decide",
                            "scan_fused_decide_sharded"),
            mesh=mesh, decide=decide, decide_state=self._dstate,
            elastic=self.elastic)
        self.state = self.pipeline.init_state()
        self._prefetcher: Optional[WindowPrefetcher] = None
        self.predictor = predictor
        # train="online": device-resident retraining interleaved with the
        # fused decide dispatches (runtime.trainer). The trainer needs the
        # decision state in the device carry, so it composes only with the
        # fused-decide modes; train_cfg kwargs pass through to OnlineTrainer
        # (batch_size, train_cfg, seed, checkpoint_dir, checkpoint_every).
        self.trainer = None
        if train is not None:
            if train != "online":
                raise ValueError(f"unknown train mode {train!r} "
                                 "(expected None or 'online')")
            if not self.fused_decide:
                raise ValueError(
                    "train='online' rides the fused decide carry: use a "
                    f"scan_fused_decide* mode, not {mode!r}")
            from repro.runtime.trainer import OnlineTrainer
            kw = dict(train_cfg or {})
            kw.setdefault("contract_check", self.contract_check)
            self.trainer = OnlineTrainer(predictor, **kw)
        self.forwarders = forwarders
        self.db = db
        self.speedup = speedup
        self._wall0 = time.time()
        self._t0 = t0
        self.window_s = pipeline_cfg.n_ticks * pipeline_cfg.tick_s
        self.window_index = 0

        self.broker = QueueBroker()
        self.translators = {
            s.source_id: Translator(s.source_id, s.protocol,
                                    unit_scale=s.unit_scale)
            for s in sources
        }
        self.receivers: List[Receiver] = []
        for s in sources:
            self.receivers.append(
                Receiver(s.source_id, s.protocol, s.device, self.now,
                         speedup=speedup))
        self._stream_names = [s.device.stream for s in sources]
        self.accumulators: Dict[str, Accumulator] = {}
        for env in env_ids:
            self._register_env(env)
        self.metrics: Dict[str, list] = {"tick_latency_s": [],
                                         "ingest_records": []}

    def _register_env(self, env_id: str) -> None:
        """Wire one env into every source Receiver and give it its own
        Accumulator (construction and elastic :meth:`attach_env`)."""
        for r in self.receivers:
            tr = self.translators[r.source_id]

            def on_payload(env_id, payload, _tr=tr):
                rec = _tr.translate(env_id, payload)
                if rec is not None:
                    self.broker.publish(rec)

            def on_batch(env_id, stream, ts, vals, srt=None, _tr=tr):
                batch = _tr.translate_batch(env_id, stream, ts, vals, srt)
                if batch is not None:
                    self.broker.publish(batch)

            if self.ingest == "columnar":
                r.subscribe(env_id, on_batch=on_batch)
            else:
                r.subscribe(env_id, on_payload)
        self.accumulators[env_id] = Accumulator(env_id, self._stream_names,
                                                self.cfg.max_samples,
                                                fastpath=self.ingest_fastpath)

    def _live_slots(self) -> List[tuple]:
        """``[(slot_row, env_id), ...]`` of the live envs, slot order.

        Non-elastic systems enumerate ``env_ids`` densely; elastic ones
        skip free/inactive slots, so host loops (ingest, close_windows,
        forwarders, DB, stats) never touch a dead row."""
        if not self.elastic:
            return list(enumerate(self.env_ids))
        return [(i, e) for i, e in enumerate(self._slot_env)
                if e is not None and self._active[i]]

    # --- virtual clock -------------------------------------------------------
    def now(self) -> float:
        if self.manual_time:
            return self._manual_t
        return self._t0 + (time.time() - self._wall0) * self.speedup

    def window_bounds(self, index: Optional[int] = None):
        idx = self.window_index if index is None else index
        start = self._t0 + idx * self.window_s
        return start, start + self.window_s

    # --- threaded operation ---------------------------------------------------
    def start(self):
        for r in self.receivers:
            r.start()

    def stop(self):
        for r in self.receivers:
            r.stop()
        if self._prefetcher is not None:
            self._prefetcher.stop()
        if self.trainer is not None:
            self.trainer.close()
        if self._ingest_pool is not None:
            self._ingest_pool.shutdown(wait=True)
            # a post-stop run_windows call degrades to the serial loop
            # instead of submitting to a dead executor
            self._ingest_pool = None

    # --- synchronous operation (benchmarks / tests) ---------------------------
    def pump_receivers(self):
        for r in self.receivers:
            r.poll_once()

    def run_window(self) -> dict:
        """Process one closed window across all environments."""
        t_start, t_end = self.window_bounds()
        E, S, M = self.cfg.n_envs, self.cfg.n_streams, self.cfg.max_samples

        n_new = 0
        for env in self.env_ids:
            recs = self.broker.queue_for(env).drain()
            n_new += count_records(recs)
            self.accumulators[env].ingest(recs)

        values = np.zeros((E, S, M), np.float32)
        ts = np.zeros((E, S, M), np.float32)
        valid = np.zeros((E, S, M), bool)
        for i, env in enumerate(self.env_ids):
            v, t, m = self.accumulators[env].close_window(t_start, t_end,
                                                          rebase=True)
            values[i], ts[i], valid[i] = v, t, m

        t_proc0 = time.time()
        raw = make_raw_window(values, ts, valid)
        # window-relative time: timestamps were rebased to this window's
        # start, so the device sees window_start = 0 (float32-exact on any
        # horizon); absolute time stays host-side (t_end below)
        self.state, feats, frame = self.pipeline.run_tick(
            self.state, raw, jnp.zeros((E,), jnp.float32))
        actions, rewards, per_term = self.predictor.on_tick(
            feats.features, t_end, raw=feats.raw)
        latency = time.time() - t_proc0

        if self.forwarders is not None:
            for i, env in enumerate(self.env_ids):
                self.forwarders.dispatch(env, t_end, actions[i])
        if self.db is not None:
            obs = np.asarray(feats.features)
            ver = int(self.predictor.policy_version)
            for i, env in enumerate(self.env_ids):
                self.db.append(env, t_end, obs[i], actions[i],
                               float(rewards[i]),
                               extra={"policy_version": ver})

        self.window_index += 1
        self.metrics["tick_latency_s"].append(latency)
        self.metrics["ingest_records"].append(n_new)
        return {
            "window": self.window_index - 1,
            "records": n_new,
            "latency_s": latency,
            "mean_reward": float(np.mean(rewards)),
            "observed_frac": float(np.asarray(frame.observed).mean()),
            "filled_frac": float(np.asarray(frame.filled).mean()),
            "anomalous": int(np.asarray(frame.anomalous).sum()),
        }

    # --- scan-fused operation --------------------------------------------------
    # Staging buffers alive at once in the deepest pipeline (async modes):
    # one being assembled by the pump, one staged in the depth-1 ready
    # buffer, one consumed/in flight on device. ``jnp.asarray`` may
    # zero-copy an aligned host buffer on CPU, so a buffer is only reused
    # once its batch is provably consumed — with depth 3 the epoch reusing
    # buffer b%3 starts only after batch b-3's results were consumed.
    _STAGE_DEPTH = 3

    def _staging_buffers(self, K: int, E: int):
        """Rotating preallocated (K, E, S, M) staging triple, zeroed.

        One allocation per (shape, rotation slot) for the lifetime of the
        system: steady-state assembly reuses the arrays (a memset instead
        of three fresh allocations per batch). Cleared on :meth:`resize`
        (the env width changes)."""
        S, M = self.cfg.n_streams, self.cfg.max_samples
        pool = self._stage_pool.setdefault((K, E, S, M),
                                           {"bufs": [], "next": 0})
        i = pool["next"]
        pool["next"] = (i + 1) % self._STAGE_DEPTH
        if i >= len(pool["bufs"]):
            shape = (K, E, S, M)
            pool["bufs"].append((np.zeros(shape, np.float32),
                                 np.zeros(shape, np.float32),
                                 np.zeros(shape, bool)))
        else:
            for a in pool["bufs"][i]:
                a.fill(0)
        return pool["bufs"][i]

    def _assemble_env(self, slot: int, env: str, bounds, starts,
                      values, ts, valid) -> np.ndarray:
        """Drain, count, ingest and close ONE env into its staging rows.

        The unit of work ``ingest_workers`` partitions: everything touched
        here — the env's queue, its Accumulator, column ``slot`` of the
        staging buffers — belongs to exactly one env, so concurrent calls
        for different envs share nothing."""
        K = len(bounds)
        recs = self.broker.queue_for(env).drain()
        c = np.zeros(K, np.int64)
        scalar_ts = []            # one vectorized pass per drain, not per item
        for r in recs:
            if isinstance(r, RecordBatch):
                j = np.searchsorted(starts, r.timestamps, side="right") - 1
                c += np.bincount(np.clip(j, 0, K - 1), minlength=K)
            else:
                scalar_ts.append(r.timestamp)
        if scalar_ts:
            j = np.searchsorted(starts, np.asarray(scalar_ts),
                                side="right") - 1
            c += np.bincount(np.clip(j, 0, K - 1), minlength=K)
        acc = self.accumulators[env]
        acc.ingest(recs)
        acc.close_windows(bounds, rebase=True,
                          out=(values[:, slot], ts[:, slot], valid[:, slot]))
        return c

    def assemble_windows(self, bounds) -> tuple:
        """Drain queues once and stack K closed windows per env — one pass
        straight into preallocated (K, E, S, M) staging buffers.

        Returns ``(RawWindow with leading K axis, per_window_counts)`` where
        the counts attribute each drained record to the window whose bounds
        contain its timestamp (clipped to the batch, so the counts sum to
        the drain total — mirroring fused mode's per-window ingest numbers
        for consumers like dead-source detection). Per-env isolation is
        structural: each env's records flow queue -> its own Accumulator ->
        column i of the staging stack; no cross-env array is ever indexed
        by more than one env. Inactive/free slots keep their all-invalid
        zero rows: on device their state updates are natural no-ops and
        outputs are masked.

        With ``ingest_workers=N`` the live envs are partitioned
        slot-striped across N persistent workers (env at live position p is
        owned by worker p mod N — deterministic for a given membership).
        Each env's drain -> ingest -> close sequence is unchanged and
        env-isolated, and the per-window counts are summed with integer
        adds, so the result is bit-identical to the serial loop.
        """
        E = self.cfg.n_envs
        K = len(bounds)
        starts = np.asarray([b[0] for b in bounds], np.float64)
        live = self._live_slots()
        values, ts, valid = self._staging_buffers(K, E)
        counts_arr = np.zeros(K, np.int64)
        if self._ingest_pool is not None and len(live) > 1:
            def run_shard(shard):
                return [self._assemble_env(i, env, bounds, starts,
                                           values, ts, valid)
                        for i, env in shard]
            shards = [live[w::self.ingest_workers]
                      for w in range(self.ingest_workers)]
            futs = [self._ingest_pool.submit(run_shard, sh)
                    for sh in shards if sh]
            for f in futs:
                for c in f.result():
                    counts_arr += c
        else:
            for i, env in live:
                counts_arr += self._assemble_env(i, env, bounds, starts,
                                                 values, ts, valid)
        counts = [int(c) for c in counts_arr]
        return make_raw_window(values, ts, valid), counts

    def run_windows_scan(self, k: int) -> List[dict]:
        """Process the next ``k`` windows with ONE device dispatch."""
        bounds = [self.window_bounds(self.window_index + j) for j in range(k)]
        raw, counts = self.assemble_windows(bounds)
        if self.fused_decide:
            outs, t_dispatch, ver = self._dispatch_decide(raw, k)
            return self._consume_decide(bounds, counts, outs, t_dispatch, ver)
        feats, frames, t_dispatch = self._dispatch_scan(raw, k)
        return self._consume_scan(bounds, counts, feats, frames, t_dispatch)

    def _dispatch_scan(self, raw, k: int):
        """Launch ONE ``run_many`` over a staged K-window batch (no block:
        JAX async dispatch returns futures; consumption blocks)."""
        t_dispatch = time.time()
        # window-relative time: each window's samples were rebased to its
        # own start by close_windows, so every scan step sees start = 0
        starts = jnp.zeros((k, self.cfg.n_envs), jnp.float32)
        self.state, feats, frames = self.pipeline.run_many(
            self.state, raw, starts,
            active=jnp.asarray(self._active) if self.elastic else None)
        return feats, frames, t_dispatch

    def _consume_scan(self, bounds, counts, feats, frames,
                      t_dispatch) -> List[dict]:
        """Block on a dispatched batch and run the batch host side
        (Predictor, Forwarders, DB, metrics) in window order.

        The Predictor consumes the whole K-window stack in ONE jitted
        dispatch (``on_windows`` over the stacked device features — the
        same fusion ``run_many`` applies to the pipeline, applied to the
        decision path), then the per-window loop only slices numpy for
        Forwarders/DB/metrics. ``batched_consume=False`` keeps the
        per-window ``on_tick`` loop as the tested reference; both paths
        are bit-identical (asserted in tests/test_predictor_batch.py).
        """
        k = len(bounds)
        # elastic: host sinks and stats see only the live rows (compacted,
        # slot order == attach order of the current membership); the
        # predictor gets the dense masked stack plus the mask itself
        if self.elastic:
            live = self._live_slots()
            rows: Optional[np.ndarray] = np.asarray([i for i, _ in live],
                                                    np.int64)
            ids = [e for _, e in live]
        else:
            rows, ids = None, self.env_ids
        if self.batched_consume:
            # feed the stacked DEVICE features straight into the predictor
            # scan — one dispatch, one host transfer per output leaf
            actions_b, rewards_b, _ = self.predictor.on_windows(
                feats.features, [b[1] for b in bounds], raw=feats.raw,
                active=self._active if self.elastic else None,
                prev_ok=self._prev_ok if self.elastic else None)
            if self.elastic:
                # host mirror of the device-side first-window chain rule
                self._prev_ok = self._prev_ok | self._active
            batch_latency = time.time() - t_dispatch
        else:
            jax.block_until_ready(feats.features)
            batch_latency = time.time() - t_dispatch
            raw_np = np.asarray(feats.raw)

        out = []
        # one batch-wide host transfer per leaf; the per-window loop then
        # slices numpy — per-window DEVICE slicing (feats.features[j]) costs
        # two extra device dispatches per window and, in async mode, queues
        # them behind the next batch's scan
        feat_np = np.asarray(feats.features)
        obs_np = np.asarray(frames.observed)
        fill_np = np.asarray(frames.filled)
        anom_np = np.asarray(frames.anomalous)
        for j, (t_start, t_end) in enumerate(bounds):
            t_host0 = time.time()
            if self.batched_consume:
                actions, rewards = actions_b[j], rewards_b[j]
            else:
                # reference path: the per-window dispatch stays inside the
                # timed region so latency_s keeps counting Predictor time
                actions, rewards, _ = self.predictor.on_tick(
                    feat_np[j], t_end, raw=raw_np[j],
                    active=self._active if self.elastic else None,
                    prev_ok=self._prev_ok if self.elastic else None)
                if self.elastic:
                    self._prev_ok = self._prev_ok | self._active
            if rows is not None:
                # compact to the live rows: Forwarders/DB/stats must never
                # see (or average over) a dead slot's masked zeros
                actions, rewards = actions[rows], rewards[rows]
                feat_j = feat_np[j][rows]
                obs_j, fill_j, anom_j = (obs_np[j][rows], fill_np[j][rows],
                                         anom_np[j][rows])
            else:
                feat_j = feat_np[j]
                obs_j, fill_j, anom_j = obs_np[j], fill_np[j], anom_np[j]
            if self.forwarders is not None:
                self.forwarders.dispatch_window(t_end, actions)
            if self.db is not None:
                self.db.append_many(ids, t_end, feat_j, actions,
                                    rewards,
                                    extra={"policy_version":
                                           int(self.predictor.policy_version)})
            self.window_index += 1
            # comparable to run_window's latency_s: amortized device +
            # predictor share of the batch plus this window's host work
            latency = batch_latency / k + (time.time() - t_host0)
            self.metrics["tick_latency_s"].append(latency)
            self.metrics["ingest_records"].append(counts[j])
            out.append({
                "window": self.window_index - 1,
                "records": counts[j],
                "latency_s": latency,
                "mean_reward": float(np.mean(rewards)) if rewards.size
                               else 0.0,
                "observed_frac": float(obs_j.mean()) if obs_j.size else 0.0,
                "filled_frac": float(fill_j.mean()) if fill_j.size else 0.0,
                "anomalous": int(anom_j.sum()),
            })
        return out

    # --- fused-decide operation ------------------------------------------------
    def _dispatch_decide(self, raw, k: int):
        """Launch ONE fused pipeline+decision dispatch over a staged
        K-window batch: features flow straight into the policy/validate/
        reward/replay step inside the scan, and BOTH carries (pipeline
        state + decide state) stay device-resident (donated in the sync
        modes). No block — consumption blocks.

        With an attached trainer this is the batch boundary: the previous
        train step's result hot-swaps the carry's policy/version leaves
        BEFORE the dispatch (so the whole batch runs one policy), and a
        new train step enqueues right AFTER it (so it fills the dispatch
        bubble instead of delaying serving — the PR 3 priority-inversion
        lesson). Returns ``(outs, t_dispatch, policy_version)`` with the
        version that produced this batch's actions."""
        if self.trainer is not None:
            self._dstate = self.trainer.apply_pending(self._dstate)
        ver = int(self.predictor.policy_version)
        t_dispatch = time.time()
        starts = jnp.zeros((k, self.cfg.n_envs), jnp.float32)
        self.state, self._dstate, outs = self.pipeline.run_many_decide(
            self.state, self._dstate, raw, starts)
        if self.elastic:
            # host mirror of the device-side post-scan update
            # (prev_ok = prev_ok | active, see run_many_decide)
            self._prev_ok = self._prev_ok | self._active
        if self.trainer is not None:
            self.trainer.dispatch(self._dstate)
        return outs, t_dispatch, ver

    def _consume_decide(self, bounds, counts, outs, t_dispatch,
                        version: int = 0) -> List[dict]:
        """Drain host sinks from the SMALL fused outputs.

        The host fetches only actions (K, E, A), rewards (K, E), violation
        flags and the per-env int32 observed/filled/anomalous counts — the
        (K, E, F) feature stack is fetched ONLY when a LogDB needs obs
        rows, and the (K, E, S, T) frames never leave the device (the
        fractions divide the exact counts, bit-identical to ``np.mean``
        over the full frame)."""
        k = len(bounds)
        actions_b = np.asarray(outs.actions)   # first fetch blocks the batch
        batch_latency = time.time() - t_dispatch
        rewards_b = np.asarray(outs.rewards)
        obs_c = np.asarray(outs.observed)
        fill_c = np.asarray(outs.filled)
        anom_c = np.asarray(outs.anomalous)
        feat_np = np.asarray(outs.features) if self.db is not None else None
        self.predictor.absorb_fused([b[1] for b in bounds],
                                    np.asarray(outs.violated))
        # elastic: stats normalize by the LIVE row count (frame counts from
        # inactive rows are masked zeros on device, so whole-array sums are
        # already live-only); sinks get the compacted live rows
        if self.elastic:
            live = self._live_slots()
            rows: Optional[np.ndarray] = np.asarray([i for i, _ in live],
                                                    np.int64)
            ids = [e for _, e in live]
            n_rows = max(len(live), 1)
        else:
            rows, ids, n_rows = None, self.env_ids, self.cfg.n_envs
        denom = float(n_rows * self.cfg.n_streams * self.cfg.n_ticks)
        out = []
        for j, (t_start, t_end) in enumerate(bounds):
            t_host0 = time.time()
            actions, rewards = actions_b[j], rewards_b[j]
            feat_j = feat_np[j] if feat_np is not None else None
            if rows is not None:
                actions, rewards = actions[rows], rewards[rows]
                if feat_j is not None:
                    feat_j = feat_j[rows]
            if self.forwarders is not None:
                self.forwarders.dispatch_window(t_end, actions)
            if self.db is not None:
                self.db.append_many(ids, t_end, feat_j, actions,
                                    rewards,
                                    extra={"policy_version": version})
            self.window_index += 1
            latency = batch_latency / k + (time.time() - t_host0)
            self.metrics["tick_latency_s"].append(latency)
            self.metrics["ingest_records"].append(counts[j])
            out.append({
                "window": self.window_index - 1,
                "records": counts[j],
                "latency_s": latency,
                "mean_reward": float(np.mean(rewards)) if rewards.size
                               else 0.0,
                # exact integer counts / float64 size == np.mean over the
                # live rows of the (E, S, T) bool frame, bit for bit
                "observed_frac": float(int(obs_c[j].sum()) / denom),
                "filled_frac": float(int(fill_c[j].sum()) / denom),
                "anomalous": int(anom_c[j].sum()),
            })
        return out

    def _dispatch_batch(self, batch):
        """Mode-dispatching async helper: launch one assembled batch and
        return the pending tuple ``_consume_batch`` expects."""
        k = len(batch.bounds)
        if self.fused_decide:
            outs, td, ver = self._dispatch_decide(batch.raw, k)
            return (batch.bounds, batch.counts, outs, td, ver)
        feats, frames, td = self._dispatch_scan(batch.raw, k)
        return (batch.bounds, batch.counts, feats, frames, td)

    def _consume_batch(self, pending) -> List[dict]:
        if self.fused_decide:
            return self._consume_decide(*pending)
        return self._consume_scan(*pending)

    def _advance_clock(self, t_end: float):
        if self.manual_time:
            self._manual_t = t_end + 1e-3
        else:
            while self.now() < t_end:
                time.sleep(0.001)

    # --- elastic membership (attach / detach / regrow) -------------------------
    def _assert_membership_boundary(self):
        assert self.elastic, "attach/detach/resize require elastic=True"
        if self._prefetcher is not None:
            assert self._prefetcher.in_flight() == 0, \
                "membership changes only at batch boundaries: a window " \
                "batch plan is still in flight (finish run_windows first)"

    def _refresh_env_ids(self):
        self.env_ids = [e for _, e in self._live_slots()]

    def _export_env_ids(self) -> List[str]:
        """Slot-table env ids at the FULL pool width (replay export keys
        rows by slot; free slots get a placeholder that never matches a
        valid row)."""
        if not self.elastic:
            return self.env_ids
        return [e if e is not None else f"__slot{i}__"
                for i, e in enumerate(self._slot_env)]

    def attach_env(self, env_id: str) -> int:
        """Join a new env into a free slot between window batches.

        No retrace: only the ``active`` mask value changes. The slot's
        pipeline-state rows are reset from a fresh init template (the init
        sentinels — ``prev_ts``, norm min/max — are NOT zeros), its decide
        rows are scrubbed, and its receiver subscriptions start a fresh
        poll horizon at attach time. Grows the pool first when it is full.
        Returns the slot row."""
        self._assert_membership_boundary()
        assert env_id not in self.accumulators, \
            f"env {env_id!r} is already attached"
        if not self._free_slots:
            self.resize()
        slot = self._free_slots.pop(0)
        self._slot_env[slot] = env_id
        self._active[slot] = True
        self._prev_ok[slot] = False
        self._register_env(env_id)
        from repro.distribution import elastic as elastic_lib
        self.state = elastic_lib.reset_env_rows(
            self.state, self.pipeline.init_state(), [slot])
        if self.fused_decide:
            self._dstate = self._reset_dstate_rows(self._dstate, slot)
        else:
            self.predictor.clear_env_rows([slot])
        self._refresh_env_ids()
        self._membership_epoch += 1
        return slot

    def detach_env(self, env_id: str) -> int:
        """Remove a live env, freeing its slot for reuse.

        Host plumbing is torn down (receiver subscriptions, queue,
        accumulator — pending records are discarded) and the slot's decide
        rows / replay validity are scrubbed so a later tenant never
        observes the departed env's data. Returns the freed slot row."""
        self._assert_membership_boundary()
        assert env_id in self.accumulators, f"env {env_id!r} is not attached"
        slot = self._slot_env.index(env_id)
        for r in self.receivers:
            r.unsubscribe(env_id)
        self.broker.remove(env_id)
        self.accumulators.pop(env_id).reset()
        self._slot_env[slot] = None
        self._active[slot] = False
        self._prev_ok[slot] = False
        bisect.insort(self._free_slots, slot)
        if self.fused_decide:
            self._dstate = self._reset_dstate_rows(self._dstate, slot)
        else:
            self.predictor.clear_env_rows([slot])
        self._refresh_env_ids()
        self._membership_epoch += 1
        return slot

    def _reset_dstate_rows(self, d, slot: int):
        """Scrub one slot's rows of the fused decide carry and refresh the
        mask leaves from the host mirrors (out-of-place ``.at`` updates
        between dispatches — donation aliasing is never violated)."""
        d = d._replace(
            prev_obs=d.prev_obs.at[slot].set(0.0),
            prev_actions=d.prev_actions.at[slot].set(0.0),
            replay=d.replay._replace(
                valid=d.replay.valid.at[slot].set(False)),
            active=jnp.asarray(self._active),
            prev_ok=jnp.asarray(self._prev_ok))
        model = self.predictor.model
        if d.carry is not None and model.init_carry is not None:
            tmpl = model.init_carry(self.cfg.n_envs)
            d = d._replace(carry=jax.tree.map(
                lambda x, t: x.at[slot].set(jnp.asarray(t)[slot]),
                d.carry, tmpl))
        return d

    def resize(self, new_slots: Optional[int] = None) -> int:
        """Grow the slot pool (the ONE allowed retrace point).

        Protocol (module docstring / distribution.elastic): flush any
        pending train step into the carry, pad every env-leading pytree
        against a fresh init template at the new width, rebuild the engine
        at the new shapes, and re-place state + decide carry on the
        re-chosen env mesh in the sharded modes. Surviving rows are
        byte-for-byte preserved, so live envs resume bit-exactly."""
        self._assert_membership_boundary()
        from repro.distribution import elastic as elastic_lib
        from repro.distribution import sharding as shard_lib
        old = self.env_slots
        pipe_mode = _PIPELINE_MODE.get(self.mode, self.mode)
        sharded = pipe_mode in _SHARDED_PIPE_MODES
        ndev = len(jax.devices()) if sharded else 1
        if new_slots is None:
            new_slots = elastic_lib.next_pool_size(old + 1, old, ndev)
        assert new_slots > old, (new_slots, old)
        if self.trainer is not None:
            # a train step dispatched against the old-width carry must land
            # before the carry is grown under it
            self._dstate = self.trainer.flush_pending(self._dstate)
        pad = new_slots - old
        self._active = np.concatenate([self._active, np.zeros(pad, bool)])
        self._prev_ok = np.concatenate([self._prev_ok, np.zeros(pad, bool)])
        self._slot_env.extend([None] * pad)
        self._free_slots.extend(range(old, new_slots))
        if self.fused_decide:
            # the predictor's replay/model-carry mirrors are stale donated
            # snapshots in fused modes (module docstring): refresh them from
            # the live carry so grow_envs concatenates real buffers and the
            # decide_state() template below is materialized at new width
            self.predictor.replay = self._dstate.replay
            self.predictor._prev["obs"] = np.asarray(self._dstate.prev_obs)
            self.predictor._prev["actions"] = \
                np.asarray(self._dstate.prev_actions)
            if self._dstate.carry is not None:
                self.predictor._model_carry = self._dstate.carry
        self.predictor.grow_envs(new_slots)
        new_cfg = dataclasses.replace(self.cfg, n_envs=new_slots)
        mesh = shard_lib.env_mesh(new_slots) if sharded else None
        if self.fused_decide:
            # grow against the predictor's fresh-template carry: the mask
            # leaves are None there, so strip ours first (same pytree
            # structure), then re-set them at the new width
            d = self._dstate._replace(active=None, prev_ok=None)
            d = elastic_lib.grow_env_tree(d, self.predictor.decide_state(),
                                          old)
            self._dstate = d._replace(active=jnp.asarray(self._active),
                                      prev_ok=jnp.asarray(self._prev_ok))
        self.cfg = new_cfg
        self.pipeline = PerceptaPipeline(
            new_cfg, mode=pipe_mode,
            donate=self.mode in ("scan", "scan_sharded", "scan_fused_decide",
                                 "scan_fused_decide_sharded"),
            mesh=mesh, decide=self._decide,
            decide_state=self._dstate if self.fused_decide else None,
            elastic=True)
        self.state = elastic_lib.grow_env_tree(
            self.state, self.pipeline.init_state(), old)
        self.env_slots = new_slots
        # staging buffers are keyed by (K, E, S, M); the env width just
        # changed, so drop the old-width pool (rebuilt lazily)
        self._stage_pool.clear()
        if mesh is not None:
            self.state = shard_lib.place_env_tree(self.state, 0, mesh)
            if self.fused_decide:
                # decide_specs, not the rank rule: policy weights must stay
                # replicated even when their leading dim divides the pool
                specs = shard_lib.decide_specs(self._dstate, 0,
                                               mesh.axis_names[0])
                self._dstate = shard_lib.place_env_tree(
                    self._dstate, 0, mesh, specs=specs)
        self._membership_epoch += 1
        return new_slots

    # --- donation-safe state access -------------------------------------------
    def snapshot_state(self):
        """Deep copy of the pipeline state pytree, safe to hold across windows.

        ``scan``/``scan_sharded`` donate the state buffers into every
        ``run_many`` dispatch, so a bare ``system.state.<leaf>`` reference
        becomes invalid after the next window batch; this accessor hands out
        copies so callers never have to reason about donation.
        """
        return jax.tree.map(lambda x: jnp.array(x, copy=True), self.state)

    def snapshot_norm(self):
        """Donation-safe copy of just the normalizer stats (NormState)."""
        return jax.tree.map(lambda x: jnp.array(x, copy=True),
                            self.state.norm)

    def snapshot_decide(self):
        """Deep copy of the fused decision carry (``DecideState``), safe to
        hold across window batches. Fused-decide modes donate the carry —
        including the replay ring — into every dispatch, so bare
        ``system._dstate`` leaf references become invalid after the next
        batch; this is the replay-path twin of :meth:`snapshot_state`."""
        assert self.fused_decide, "snapshot_decide: not a fused-decide mode"
        return jax.tree.map(lambda x: jnp.array(x, copy=True), self._dstate)

    def replay_size(self) -> int:
        """Live transition count of the replay ring, any mode."""
        buf = (self._dstate.replay if self.fused_decide
               else self.predictor.replay)
        return min(int(buf.cursor), buf.capacity)

    def policy_version(self) -> int:
        """Current monotone policy version (0 until a train step applies).

        Every replay row and LogDB row carries the version that produced
        its action, so exports are attributable per row; swaps land only
        at batch boundaries, so all K windows of a batch share one
        version."""
        return int(self.predictor.policy_version)

    def snapshot_policy(self):
        """Donation-safe copy of the LIVE policy params (the device carry's
        ``policy`` leaves in fused-decide modes, the predictor's host
        mirror otherwise)."""
        src = (self._dstate.policy if self.fused_decide
               else self.predictor.policy_params)
        return jax.tree.map(lambda x: jnp.array(x, copy=True), src)

    def train_stats(self) -> Optional[dict]:
        """Trainer counters (dispatched/applied/skipped_empty, last loss
        and grad norm, current version); None when training is off."""
        return None if self.trainer is None else self.trainer.train_stats()

    def restore_training(self):
        """Crash recovery: restore the newest trainer checkpoint into the
        LIVE serving path — trainer state, predictor host mirror, AND the
        device carry's policy/version leaves (``trainer.restore_latest``
        alone only covers the host side; the carry would keep serving the
        construction-time weights). Returns ``(step, params, extra)`` or
        ``None`` when no checkpoint exists."""
        if self.trainer is None:
            raise ValueError("restore_training: system built without "
                             "train='online'")
        out = self.trainer.restore_latest()
        if out is None:
            return None
        _, params, _ = out
        self._dstate = self._dstate._replace(
            policy=jax.tree.map(jnp.asarray, params),
            version=jnp.asarray(self.trainer.version, jnp.int32))
        return out

    def export_replay(self, salt: str) -> dict:
        """Anonymized chronological replay export, any mode.

        Non-fused modes delegate to ``Predictor.export_replay`` (host
        float64 mirror re-attached). Fused-decide modes snapshot the
        device carry WITHOUT donating it and reconstruct the exact float64
        absolute time of every system-era transition from its stored int32
        tick index: tick ``idx`` is this system's window ``idx - base``
        (``base`` = the predictor's tick count at construction), and
        windows are consecutive by construction, so it ended at
        ``(t0 + (idx - base) * window_s) + window_s`` — evaluated in
        float64 with exactly :meth:`window_bounds`' operation order, which
        makes the reconstruction bit-identical to the mirror the per-step
        paths maintain. Slots written BEFORE this system existed (a
        Predictor with prior ``on_tick``/``on_windows`` history) keep
        their host-mirror times — their windows were not this system's."""
        if not self.fused_decide:
            return self.predictor.export_replay(self._export_env_ids(), salt)
        from repro.core import replay as rp
        buf = self.snapshot_decide().replay
        # every env row shares the batch-wide tick index, so row 0 carries
        # the slot-aligned index ring; dead slots are never selected by the
        # export's chronological order
        idx_i = np.asarray(buf.tick_idx[0])
        idx = (idx_i - self._tick_base).astype(np.float64)
        recon = (self._t0 + idx * self.window_s) + self.window_s
        slot_times = np.where(idx_i >= self._tick_base, recon,
                              self.predictor._replay_times)
        return rp.export_for_training(buf, self._export_env_ids(), salt,
                                      slot_times=slot_times)

    def run_windows(self, n: int, pump: bool = True) -> List[dict]:
        if self.mode in _ASYNC_MODES:
            return self._run_windows_async(n, pump)
        if self.mode in _SCAN_MODES:
            out: List[dict] = []
            while len(out) < n:
                k = min(self.scan_k, n - len(out))
                if pump:
                    # advance past the LAST window of the batch so every
                    # window's samples exist before the single drain
                    t_end = self.window_bounds(self.window_index + k - 1)[1]
                    self._advance_clock(t_end)
                    self.pump_receivers()
                out.extend(self.run_windows_scan(k))
            return out
        out = []
        for _ in range(n):
            if pump:
                # synchronous mode: advance the virtual clock past the window
                # end, then poll every receiver once
                self._advance_clock(self.window_bounds()[1])
                self.pump_receivers()
            out.append(self.run_window())
        return out

    # --- pipelined (async) operation ------------------------------------------
    def _assemble_for_prefetch(self, bounds, pump: bool):
        """Pump-thread body: exactly the synchronous per-batch sequence
        (clock advance -> receiver poll -> drain/close) at the same window
        boundaries — the deterministic handoff that makes ``scan_async``
        bit-identical to ``scan``."""
        if pump:
            self._advance_clock(bounds[-1][1])
            self.pump_receivers()
        return self.assemble_windows(bounds)

    def _run_windows_async(self, n: int, pump: bool = True) -> List[dict]:
        """Double-buffered Manager loop: while batch j runs on device, the
        pump thread assembles batch j+1 and the host consumes batch j-1.

        Batch boundaries (``min(scan_k, remaining)``) match the synchronous
        scan loop exactly, so the drain epochs — and therefore the outputs —
        are identical."""
        if self._prefetcher is None:
            self._prefetcher = WindowPrefetcher(self._assemble_for_prefetch)
        plans, idx, left = [], self.window_index, n
        while left > 0:
            k = min(self.scan_k, left)
            plans.append([self.window_bounds(idx + j) for j in range(k)])
            idx, left = idx + k, left - k
        for bounds in plans:
            self._prefetcher.submit(bounds, pump=pump,
                                    membership=self._membership_epoch)

        out: List[dict] = []
        pending = None
        for _ in plans:
            batch = self._prefetcher.next_batch()
            assert batch.membership == self._membership_epoch, \
                "membership changed while a batch plan was in flight " \
                f"(plan built under epoch {batch.membership}, now " \
                f"{self._membership_epoch}); attach/detach/resize only " \
                "between run_windows calls"
            # consume j-1 BEFORE dispatching j: the Predictor's per-window
            # steps are device computations too, and the single device
            # executes its queue in order — dispatching batch j first would
            # make window j-1's small steps wait behind batch j's big scan
            # (a priority inversion that serializes the whole loop). In the
            # fused-decide composition consume is pure host-sink draining,
            # so the order only matters for result sequencing there.
            if pending is not None:
                out.extend(self._consume_batch(pending))
            pending = self._dispatch_batch(batch)
        out.extend(self._consume_batch(pending))
        return out

    def stats(self) -> dict:
        return {
            "queues": self.broker.stats(),
            "receivers": {r.source_id: r.stats for r in self.receivers},
            "translators": {t.source_id: t.stats
                            for t in self.translators.values()},
            "predictor": self.predictor.stats,
        }

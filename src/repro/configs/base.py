"""Config system for the Percepta reproduction framework.

Plain dataclasses (no external deps), a registry, CLI override parsing and a
``reduced()`` transform producing CPU-smoke-testable variants of every
architecture. All 10 assigned architectures live in sibling modules, each
exporting ``CONFIG`` with the exact published numbers.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Any, Optional, Sequence

# ---------------------------------------------------------------------------
# Layer kinds used in ``layer_pattern``. A model is a repetition of its
# pattern (truncated to n_layers), scanned over groups for compile speed.
# ---------------------------------------------------------------------------
ATTN_GLOBAL = "global"      # full causal attention
ATTN_LOCAL = "local"        # sliding-window causal attention
RGLRU = "rglru"             # RG-LRU recurrent block (RecurrentGemma / Griffin)
RWKV = "rwkv"               # RWKV-6 time-mix block (attention-free)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    experts_per_token: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # aux load-balancing loss weight (Switch-style)
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                      # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    layer_pattern: tuple = (ATTN_GLOBAL,)
    # --- attention features ------------------------------------------------
    rope_theta: float = 10000.0
    qk_norm: bool = False             # qwen3-style RMSNorm on q/k heads
    attn_logit_softcap: float = 0.0   # gemma2-style tanh softcap (0 = off)
    final_logit_softcap: float = 0.0
    local_window: int = 4096          # sliding window for ATTN_LOCAL layers
    post_norms: bool = False          # gemma2 post-attn/post-mlp RMSNorms
    tie_embeddings: bool = False
    # --- MoE ----------------------------------------------------------------
    moe: Optional[MoEConfig] = None
    # --- recurrent (RG-LRU / Griffin) ---------------------------------------
    lru_width: int = 0                # 0 -> d_model
    conv_width: int = 4               # temporal conv in recurrent block
    # --- RWKV-6 -------------------------------------------------------------
    rwkv_head_dim: int = 64
    # --- modality frontend stubs --------------------------------------------
    # 'none'      : token ids in, logits out (standard LM)
    # 'embeddings': precomputed frame embeddings in (musicgen backbone stub)
    # 'vlm'       : precomputed patch embeddings + token ids (internvl2 stub)
    frontend: str = "none"
    n_patches: int = 256              # VLM: image patches prepended to text
    n_codebooks: int = 4              # musicgen: EnCodec codebooks (codec side)
    # --- numerics ------------------------------------------------------------
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    # source provenance, for DESIGN/EXPERIMENTS tables
    source: str = ""

    # --- derived -------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attention_free(self) -> bool:
        return all(k in (RGLRU, RWKV) for k in self.layer_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True when decode cost is O(1) in context length (long_500k eligible).

        RG-LRU/RWKV layers hold O(1) state; local attention holds a bounded
        window. A single ATTN_GLOBAL layer disqualifies the arch.
        """
        return all(k in (RGLRU, RWKV, ATTN_LOCAL) for k in self.layer_pattern)

    @property
    def layer_kinds(self) -> tuple:
        """Per-layer kind list, pattern repeated and truncated to n_layers."""
        reps = -(-self.n_layers // len(self.layer_pattern))
        return tuple((self.layer_pattern * reps)[: self.n_layers])

    @property
    def n_groups(self) -> int:
        """Number of scanned pattern groups (remainder layers run unscanned)."""
        return self.n_layers // len(self.layer_pattern)

    @property
    def n_remainder_layers(self) -> int:
        return self.n_layers - self.n_groups * len(self.layer_pattern)

    # --- parameter counting (for 6ND roofline terms) -------------------------
    def _layer_params(self, kind: str) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        n = 0
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            n += q + kv + o + d  # + attn norm
            if self.qk_norm:
                n += 2 * hd
            if self.post_norms:
                n += d
        elif kind == RGLRU:
            w = self.lru_width or d
            # in-proj (x & gate), conv, rg-lru gates (a & input), out-proj
            n += 2 * d * w + self.conv_width * w + 2 * (w * w // 8 + w) + w * d + d
            if self.post_norms:
                n += d
        elif kind == RWKV:
            H = self.d_model // self.rwkv_head_dim
            # r/k/v/g/w projections + time-mix lora + output + ln + u
            n += 5 * d * d + 2 * d * 64 + d + H * self.rwkv_head_dim + d
        # FFN (dense or MoE)
        if kind == RWKV:
            # rwkv channel-mix: k (d->d_ff), v (d_ff->d), r (d->d)
            n += d * self.d_ff + self.d_ff * d + d * d + d
        elif self.moe is not None:
            n += self.moe.n_experts * 3 * d * self.moe.d_ff_expert
            n += d * self.moe.n_experts  # router
            n += d  # mlp norm
        else:
            n += 3 * d * self.d_ff + d
        return n

    def param_count(self) -> int:
        n = self.vocab_size * self.d_model  # embeddings
        if not self.tie_embeddings:
            n += self.d_model * self.vocab_size  # lm head
        n += self.d_model  # final norm
        for kind in self.layer_kinds:
            n += self._layer_params(kind)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        per_layer_experts = self.moe.n_experts * 3 * self.d_model * self.moe.d_ff_expert
        active_experts = self.moe.experts_per_token * 3 * self.d_model * self.moe.d_ff_expert
        n_moe_layers = sum(1 for k in self.layer_kinds if k not in (RWKV,))
        return full - n_moe_layers * (per_layer_experts - active_experts)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell: what gets lowered in the dry-run."""
    name: str
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'


# The four assigned LM shapes (identical across the 10 archs).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    remat_policy: str = "full"        # none | dots | full
    microbatches: int = 1             # gradient accumulation
    zero1: bool = True                # shard optimizer state over data axis
    grad_compression: str = "none"    # none | int8_ef
    seed: int = 0
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    async_checkpoint: bool = True


@dataclass(frozen=True)
class ShardingConfig:
    """The hillclimb lever: how logical dims map onto mesh axes."""
    layout: str = "zero3"             # zero3 (params stored model+data-sharded,
                                      # gathered per layer in-scan) | tp
    seq_parallel: bool = False        # Megatron-SP residual stream (hillclimb)
    shard_experts: bool = True
    zero1: bool = True
    # decode: shard KV-cache sequence dim over 'model' when heads don't divide
    shard_cache_seq: bool = True
    remat_policy: str = "full"
    scan_layers: bool = True
    offload_opt_state: bool = False   # (documented lever; host offload)
    # model-structure perf levers (hillclimb)
    attn_sharding: str = "auto"       # auto | heads | ctx
    rwkv_chunk: int = 0               # 0 = exact sequential scan
    q_chunk: int = 512                # blockwise-attention Q tile
    kv_chunk: int = 1024              # blockwise-attention KV tile
    embed_shard: str = "vocab"        # vocab | d_model (embedding table dim)


@dataclass(frozen=True)
class ExperimentConfig:
    model: ModelConfig
    shape: ShapeConfig
    sharding: ShardingConfig = field(default_factory=ShardingConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    multi_pod: bool = False


def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 64,
            d_ff: int = 128, vocab: int = 512) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests.

    Keeps the structural features (pattern, GQA ratio, MoE top-k, qk_norm,
    softcaps) while shrinking width/depth/vocab/experts.
    """
    n_heads = 4 if cfg.n_heads else 0
    n_kv = 0
    if cfg.n_heads:
        ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
        n_kv = max(1, n_heads // min(ratio, n_heads))
    pattern_len = len(cfg.layer_pattern)
    n_layers = max(n_layers, pattern_len)  # at least one full pattern group
    moe = None
    if cfg.moe is not None:
        moe = replace(cfg.moe, n_experts=min(8, cfg.moe.n_experts),
                      experts_per_token=min(2, cfg.moe.experts_per_token),
                      d_ff_expert=d_ff // 2)
    return replace(
        cfg,
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=(16 if cfg.n_heads else 0),
        d_ff=d_ff,
        vocab_size=vocab,
        moe=moe,
        lru_width=(d_model if cfg.lru_width else 0),
        rwkv_head_dim=16,
        local_window=32,
        n_patches=8,
        dtype="float32",
        param_dtype="float32",
    )


def shapes_for(cfg: ModelConfig) -> dict:
    """The dry-run cells for one arch, honoring the long_500k skip rule."""
    out = {}
    for name, shape in SHAPES.items():
        if name == "long_500k" and not cfg.sub_quadratic:
            continue
        out[name] = shape
    return out


def skipped_shapes_for(cfg: ModelConfig) -> dict:
    return {n: s for n, s in SHAPES.items() if n not in shapes_for(cfg)}


def as_flat_dict(cfg: Any, prefix: str = "") -> dict:
    out = {}
    for f in dataclasses.fields(cfg):
        v = getattr(cfg, f.name)
        key = f"{prefix}{f.name}"
        if dataclasses.is_dataclass(v):
            out.update(as_flat_dict(v, key + "."))
        else:
            out[key] = v
    return out


def apply_overrides(cfg: Any, overrides: Sequence[str]):
    """Apply ``a.b=c`` CLI overrides to a (nested) frozen dataclass."""
    for ov in overrides:
        if "=" not in ov:
            raise ValueError(f"override must be key=value, got {ov!r}")
        key, val = ov.split("=", 1)
        cfg = _set_path(cfg, key.split("."), val)
    return cfg


def _set_path(cfg, path, val):
    name = path[0]
    cur = getattr(cfg, name)
    if len(path) > 1:
        return replace(cfg, **{name: _set_path(cur, path[1:], val)})
    typ = type(cur)
    if cur is None:
        parsed = val
    elif typ is bool:
        parsed = val.lower() in ("1", "true", "yes")
    elif typ in (int, float, str):
        parsed = typ(val)
    elif typ is tuple:
        parsed = tuple(val.split(","))
    else:
        raise ValueError(f"cannot override field {name} of type {typ}")
    return replace(cfg, **{name: parsed})

"""OnlineTrainer — device-resident policy retraining that overlaps the
fused decide scan.

Percepta's retraining loop, closed ON DEVICE: PR 5 made the replay ring
device-resident, but learning from it still required ``export_replay``'s
full host round-trip (ring -> numpy -> optimizer -> new weights -> rebuild
the system). This module wires ``replay.sample_device`` (in-place minibatch
gather) and ``train/optimizer.py`` (AdamW + global-norm clip) into ONE
jitted update step and interleaves it with the fused decide dispatches
using the async machinery from PR 3:

    boundary j:   apply_pending()      # adopt step j-2's result, bump
                                       #   policy_version, swap the carry
                  decide dispatch j    # donates carry j-1 -> carry j
                  dispatch(carry j)    # train step enqueues AFTER decide
                                       #   j on the same device stream
    (host consumes batch j-1 / assembles batch j+1 meanwhile)

The single CPU/TPU device queue executes in order, so the train step runs
in the dispatch bubble while the host is busy consuming — serving pays no
extra dispatch latency (bench cell ii). Dispatching the train step AFTER
the decide scan avoids the priority inversion PR 3 hit (a train step
enqueued first would delay the serving batch behind it).

Donation discipline (the double-donation hazard): the train step reads
``dstate.policy`` and ``dstate.replay`` — the LIVE carry leaves the next
decide dispatch will donate — so it must NOT donate them. It donates only
argnum 1, the trainer-owned train state (critic + joint optimizer state),
which nothing else references. By the time decide j+1 donates carry j, the train step
holding references to carry j's buffers is already enqueued; the runtime
keeps those buffers alive until it completes.

Hot-swap is race-free and versioned: a swap replaces the ``policy`` /
``version`` leaves of the decide carry at a batch boundary only (between
two dispatches, never mid-scan), ``policy_version`` increments
monotonically on every APPLIED update, and the decide path stamps the
producing version into every replay row and LogDB row — each K-batch is
attributable to exactly one policy.

Empty-ring safety: ``sample_device`` gates on ``size == 0`` with a
``valid`` mask; the update additionally gates the new params / optimizer
state on ``has_data`` inside the jit, so a step dispatched before the
first transition banks is an exact no-op (no AdamW weight-decay drift, no
step-count advance, no version bump).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import TrainConfig
from repro.core import replay as rp
from repro.train import optimizer as opt
from repro.train.checkpoint import Checkpointer


def critic_init(n_features: int, n_actions: int) -> dict:
    """Linear reward critic ``Q(obs, act) = [obs; act] . w + b`` (the
    trainer-owned half of the update — it never enters the decide carry)."""
    return {"qw": jnp.zeros((n_features + n_actions,), jnp.float32),
            "qb": jnp.zeros((), jnp.float32)}


def critic_apply(critic, obs, actions):
    x = jnp.concatenate([obs, actions], axis=-1)
    return x @ critic["qw"] + critic["qb"]


def td_loss(apply_fn, params, critic, batch, pi_coef: float = 0.1):
    """One-step TD/regression loss on a sampled minibatch.

    Two coupled terms (DDPG-shaped, contextual-bandit horizon):

      * critic regression against the BANKED rewards:
        ``(Q(obs, banked_action) - reward)^2`` — the "regression loss
        against banked rewards" half; and
      * policy improvement through the critic:
        ``-Q(obs, policy(obs))`` — the deterministic-policy-gradient half
        (note a pure behaviour-cloning loss would be vacuous here: the
        deterministic policy reproduces its own banked actions exactly,
        so its gradient is identically zero).

    Every term is masked by ``valid`` (see ``replay.sample_device``) and
    normalized by the valid count, floored so an all-invalid batch yields
    loss 0 with zero gradients.
    """
    v = batch["valid"].astype(jnp.float32)
    nv = jnp.maximum(jnp.sum(v), 1.0)
    q_banked = critic_apply(critic, batch["obs"], batch["actions"])
    loss_q = jnp.sum(v * jnp.square(q_banked - batch["rewards"])) / nv
    a_pi = apply_fn(params, batch["obs"])
    loss_pi = -jnp.sum(v * critic_apply(critic, batch["obs"], a_pi)) / nv
    return loss_q + pi_coef * loss_pi


def default_train_cfg(**overrides) -> TrainConfig:
    """Online-policy defaults: no warmup (the first applied step should
    move), no weight decay (a deployed policy must not drift toward zero
    while the ring is sparse)."""
    kw = dict(learning_rate=3e-4, warmup_steps=0, weight_decay=0.0)
    kw.update(overrides)
    return TrainConfig(**kw)


class OnlineTrainer:
    """Interleaves jitted policy updates with the fused decide dispatches.

    Protocol (driven by ``PerceptaSystem`` at each batch boundary, in this
    order — see the module docstring's timeline):

      * :meth:`apply_pending` BEFORE the decide dispatch: adopt the
        previous train step's result; if it saw data, bump
        ``policy_version`` and return the carry with the new
        ``policy``/``version`` leaves swapped in (otherwise return it
        unchanged). Also snapshots policy+opt state through the async
        :class:`Checkpointer` every ``checkpoint_every`` applied steps.
      * :meth:`dispatch` AFTER the decide dispatch: enqueue one train step
        on the new carry's (non-donated) policy and replay ring.

    Standalone use (benchmarks, tests): ``step_fn(params, train_state,
    replay, rng)`` is the jitted update — donating ONLY ``train_state``
    (critic + joint optimizer state) — returning ``(new_params,
    new_train_state, loss, gnorm, has_data)``.
    """

    def __init__(self, predictor, batch_size: int = 128,
                 train_cfg: Optional[TrainConfig] = None, seed: int = 0,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0, contract_check: bool = True):
        from repro.runtime.predictor import policy_call

        apply_fn, params = policy_call(predictor.model)
        if not jax.tree.leaves(params):
            raise ValueError(
                "online training needs a parameterized model: give the "
                "ModelAdapter params= and apply= (see linear_policy); "
                f"model '{predictor.model.name}' exposes no trainable "
                "params")
        self.predictor = predictor
        self.batch_size = int(batch_size)
        self.cfg = train_cfg if train_cfg is not None else default_train_cfg()
        critic = critic_init(predictor.n_features,
                             predictor.replay.actions.shape[-1])
        # trainer-owned state: the critic never rides the decide carry, and
        # one optimizer state covers the joint {policy, critic} tree
        self.train_state = {
            "critic": critic,
            "opt": opt.init({"policy": params, "critic": critic}),
        }
        self.version = int(predictor.policy_version)
        self.stats = {"dispatched": 0, "applied": 0, "skipped_empty": 0,
                      "last_loss": None, "last_gnorm": None}
        self._rng = jax.random.PRNGKey(seed)
        self._pending = None
        cfg = self.cfg

        def train_step(params, tstate, replay, rng):
            batch = rp.sample_device(replay, rng, self.batch_size)
            # any() not [0]: under an elastic mask individual cells can be
            # invalid (detached-slot rows) while the ring still has data
            has_data = jnp.any(batch["valid"])
            joint = {"policy": params, "critic": tstate["critic"]}
            loss, grads = jax.value_and_grad(
                lambda pc: td_loss(apply_fn, pc["policy"], pc["critic"],
                                   batch))(joint)
            new_joint, new_opt, gnorm = opt.update(grads, tstate["opt"],
                                                   joint, cfg)
            # gate on has_data INSIDE the jit: with an empty ring the
            # gradients are zero but AdamW's weight decay / step advance
            # would still perturb params — the no-op must be exact
            gate = lambda new, old: jax.tree.map(
                lambda a, b: jnp.where(has_data, a, b), new, old)
            new_tstate = {"critic": gate(new_joint["critic"],
                                         tstate["critic"]),
                          "opt": gate(new_opt, tstate["opt"])}
            return (gate(new_joint["policy"], params), new_tstate,
                    jnp.where(has_data, loss, 0.0), gnorm, has_data)

        if contract_check:
            from repro import analysis
            analysis.check_train_step(train_step, params, self.train_state,
                                      predictor.replay,
                                      label="OnlineTrainer.train_step")
        # donate ONLY the trainer-owned opt state (argnum 1) — params and
        # replay are live decide-carry leaves the next serving dispatch
        # donates (module docstring: the double-donation hazard)
        self.step_fn = compat.jit_donated(train_step, donate_argnums=(1,))
        self._ckpt = None
        self.checkpoint_every = int(checkpoint_every)
        if checkpoint_dir is not None:
            self._ckpt = Checkpointer(checkpoint_dir,
                                      keep=self.cfg.keep_checkpoints,
                                      async_mode=self.cfg.async_checkpoint)

    # --- batch-boundary protocol ------------------------------------------

    def apply_pending(self, dstate):
        """Adopt the in-flight train result; swap the carry at the boundary.

        Host-syncs on one scalar (``has_data``) — the step was enqueued
        right after the PREVIOUS decide dispatch, which has since been
        consumed, so it has already run. Returns ``dstate`` with the new
        ``policy``/``version`` leaves when the step applied, unchanged
        otherwise. The optimizer state is adopted either way (its old
        buffer was donated into the step)."""
        if self._pending is None:
            return dstate
        new_params, new_tstate, loss, gnorm, has_data = self._pending
        self._pending = None
        self.train_state = new_tstate
        if not bool(has_data):
            self.stats["skipped_empty"] += 1
            return dstate
        self.stats["applied"] += 1
        self.stats["last_loss"] = float(loss)
        self.stats["last_gnorm"] = float(gnorm)
        self.version += 1
        # the carry's reference to new_params is donated into the next
        # decide dispatch (sync modes); the host mirror and the checkpoint
        # must hold their own buffers
        host_params = jax.tree.map(lambda x: jnp.array(x, copy=True),
                                   new_params)
        self.predictor.adopt_policy(host_params, self.version)
        self._maybe_checkpoint(host_params)
        return dstate._replace(
            policy=new_params, version=jnp.asarray(self.version, jnp.int32))

    def dispatch(self, dstate) -> None:
        """Enqueue one train step behind the decide dispatch that produced
        ``dstate`` (non-donating reads of its policy/replay leaves)."""
        self._rng, sub = jax.random.split(self._rng)
        self._pending = self.step_fn(dstate.policy, self.train_state,
                                     dstate.replay, sub)
        self.stats["dispatched"] += 1

    def flush_pending(self, dstate):
        """Drain the in-flight step (end of run / before export)."""
        return self.apply_pending(dstate)

    # --- checkpointing ----------------------------------------------------

    def _maybe_checkpoint(self, params) -> None:
        if self._ckpt is None or self.checkpoint_every <= 0:
            return
        if self.stats["applied"] % self.checkpoint_every == 0:
            self._ckpt.save(
                self.stats["applied"],
                {"params": params, "train": self.train_state},
                extra={"policy_version": self.version,
                       "applied": self.stats["applied"]})

    def save_checkpoint(self, block: bool = True) -> int:
        """Snapshot policy+opt state now; returns the step saved at."""
        if self._ckpt is None:
            raise ValueError("OnlineTrainer built without checkpoint_dir")
        step = self.stats["applied"]
        self._ckpt.save(step,
                        {"params": self.predictor.policy_params,
                         "train": self.train_state},
                        extra={"policy_version": self.version,
                               "applied": step},
                        block=block)
        return step

    def restore_latest(self):
        """Restore the newest policy+opt snapshot into the trainer and the
        predictor's host mirror; returns ``(step, params, extra)`` or
        ``None`` when no checkpoint exists.

        This restores the HOST side only. In a running fused system the
        serving weights live in the device carry — use
        ``PerceptaSystem.restore_training()``, which calls this and then
        swaps the restored policy/version leaves into the carry; a fresh
        ``predictor.decide_state()`` also picks the weights up (both
        crash-recovery paths are exercised in tests/test_trainer.py).
        """
        if self._ckpt is None:
            raise ValueError("OnlineTrainer built without checkpoint_dir")
        self._ckpt.flush()
        step = self._ckpt.latest_step()
        if step is None:
            return None
        # an in-flight step trained on the pre-restore weights: discard it
        # (its donated train_state is replaced wholesale below)
        self._pending = None
        like = {"params": self.predictor.policy_params,
                "train": self.train_state}
        tree, extra = self._ckpt.restore(step, like)
        self.train_state = tree["train"]
        self.version = int(extra.get("policy_version", self.version))
        self.stats["applied"] = int(extra.get("applied",
                                              self.stats["applied"]))
        self.predictor.adopt_policy(tree["params"], self.version)
        return step, tree["params"], extra

    def close(self) -> None:
        if self._ckpt is not None:
            self._ckpt.close()

    def train_stats(self) -> dict:
        return dict(self.stats, version=self.version)

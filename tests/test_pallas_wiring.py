"""use_pallas wiring: the kernels in repro.kernels reached through
core/gapfill.py and core/aggregate.py must match the pure-XLA paths."""
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core import PerceptaPipeline, PipelineConfig
from repro.core import aggregate as agg
from repro.core import gapfill as gf
from repro.core.frame import RawWindow, make_raw_window
from repro.core.pipeline import init_state

E, S, T, M = 3, 4, 16, 24


def _window(rng, obs_p=0.6):
    v = jnp.asarray(rng.normal(5, 2, (E, S, T)).astype(np.float32))
    o = jnp.asarray(rng.rand(E, S, T) < obs_p)
    return v, o


def test_gap_fill_locf_pallas_parity(rng):
    v, o = _window(rng)
    state = gf.init_state(E, S)
    # warm the carry so cross-window locf is exercised too
    ticks = jnp.broadcast_to(jnp.arange(T, dtype=jnp.float32) * 60.0, (E, T))
    _, _, state = gf.gap_fill(v, o, state, ticks, "locf")
    out_x, fill_x, st_x = gf.gap_fill(v, o, state, ticks, "locf",
                                      use_pallas=False)
    out_p, fill_p, st_p = gf.gap_fill(v, o, state, ticks, "locf",
                                      use_pallas=True)
    assert (np.asarray(fill_x) == np.asarray(fill_p)).all()
    assert (np.asarray(out_x) == np.asarray(out_p)).all()
    for a, b in zip(st_x, st_p):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_gap_fill_other_strategies_ignore_flag(rng):
    v, o = _window(rng)
    state = gf.init_state(E, S)
    ticks = jnp.broadcast_to(jnp.arange(T, dtype=jnp.float32) * 60.0, (E, T))
    for strat in ("linear", "ewma", "seasonal"):
        a = gf.gap_fill(v, o, state, ticks, strat, use_pallas=False)
        b = gf.gap_fill(v, o, state, ticks, strat, use_pallas=True)
        assert (np.asarray(a[0]) == np.asarray(b[0])).all()


@pytest.mark.parametrize("a", list(agg.AGGS))
def test_window_agg_pallas_parity(a, rng):
    v, o = _window(rng)
    ref = np.asarray(agg.window_agg(v, o, a, use_pallas=False))
    out = np.asarray(agg.window_agg(v, o, a, use_pallas=True))
    assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("a", ["min", "max", "mean", "count"])
def test_window_agg_pallas_empty_window(a, rng):
    """Rows with no observations keep this module's conventions
    (saturated min/max, zeros elsewhere) on the kernel path too."""
    v = jnp.asarray(rng.normal(5, 2, (E, S, T)).astype(np.float32))
    o = jnp.zeros((E, S, T), bool)
    ref = np.asarray(agg.window_agg(v, o, a, use_pallas=False))
    out = np.asarray(agg.window_agg(v, o, a, use_pallas=True))
    assert (out == ref).all()


@pytest.mark.parametrize("feature_agg", ["mean", "sum"])
def test_pipeline_feature_agg_pallas_parity(feature_agg, rng):
    """The production window_agg call site (PipelineConfig.feature_agg)
    honours use_pallas and matches the XLA path."""
    kw = dict(n_envs=E, n_streams=S, n_ticks=T, tick_s=60.0, max_samples=M,
              feature_agg=feature_agg)
    cfg_x = PipelineConfig(**kw)
    cfg_p = PipelineConfig(use_pallas=True, **kw)
    raw = make_raw_window(
        rng.normal(5, 2, (E, S, M)).astype(np.float32),
        rng.uniform(0, T * 60, (E, S, M)).astype(np.float32),
        rng.rand(E, S, M) > 0.3)
    ws = jnp.zeros((E,), jnp.float32)
    sx, fx, _ = PerceptaPipeline(cfg_x).run_tick(init_state(cfg_x), raw, ws)
    sp, fp, _ = PerceptaPipeline(cfg_p).run_tick(init_state(cfg_p), raw, ws)
    assert_allclose(np.asarray(fx.features), np.asarray(fp.features),
                    rtol=1e-5, atol=1e-5)
    # and the aggregated features differ from the default last-tick ones
    cfg_l = PipelineConfig(n_envs=E, n_streams=S, n_ticks=T, tick_s=60.0,
                           max_samples=M)
    _, fl, _ = PerceptaPipeline(cfg_l).run_tick(init_state(cfg_l), raw, ws)
    assert np.abs(np.asarray(fl.features) - np.asarray(fx.features)).max() > 0


def test_pipeline_use_pallas_tick_parity(rng):
    kw = dict(n_envs=E, n_streams=S, n_ticks=T, tick_s=60.0, max_samples=M)
    cfg_x = PipelineConfig(**kw)
    cfg_p = PipelineConfig(use_pallas=True, **kw)
    raw = make_raw_window(
        rng.normal(5, 2, (E, S, M)).astype(np.float32),
        rng.uniform(0, T * 60, (E, S, M)).astype(np.float32),
        rng.rand(E, S, M) > 0.3)
    ws = jnp.zeros((E,), jnp.float32)
    px, pp = PerceptaPipeline(cfg_x), PerceptaPipeline(cfg_p)
    sx, sp = init_state(cfg_x), init_state(cfg_p)
    for _ in range(2):
        sx, fx, frx = px.run_tick(sx, raw, ws)
        sp, fp, frp = pp.run_tick(sp, raw, ws)
    assert (np.asarray(fx.features) == np.asarray(fp.features)).all()
    assert (np.asarray(frx.filled) == np.asarray(frp.filled)).all()

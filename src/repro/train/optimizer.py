"""AdamW built from scratch (no optax in this container).

Optimizer state is kept in f32 regardless of param dtype; the distribution
layer gives the state ZeRO-1 sharding (param sharding + an extra 'data'-axis
shard on the widest free dim), so m/v never replicate across data-parallel
replicas. Supports global-norm clipping, linear-warmup + cosine schedule and
optional int8 error-feedback gradient compression (distribution/compression).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


def schedule(cfg: TrainConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)


def init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def update(grads, opt_state, params, cfg: TrainConfig):
    """One AdamW step. Returns (new_params, new_opt_state, grad_norm)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm

"""Decode-time state: KV caches (global + local ring) and recurrent states.

Ring caches keep only ``window`` slots for sliding-window layers — this is
what makes recurrentgemma's long_500k cell O(1) memory per token: its global
state is the RG-LRU hidden + a 2048-slot ring, never a 524288-token buffer.

Slot/position conventions (L = #tokens written so far, per sample):
  * global cache: slot j holds absolute position j; valid iff j < L.
  * ring cache (W slots): slot j holds the largest position p < L with
    p ≡ j (mod W); valid iff 0 <= p (i.e. once anything was written there).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat

from repro.models.param import ParamDef


def kv_cache_defs(cfg, batch: int, max_seq: int, *, window: int = 0) -> dict:
    size = min(window, max_seq) if window else max_seq
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    dims = ("batch", "cache_seq", "kv_heads", "head_dim")
    return {
        "k": ParamDef((batch, size, hkv, dh), dims, dt, "zeros"),
        "v": ParamDef((batch, size, hkv, dh), dims, dt, "zeros"),
    }


def rglru_cache_defs(cfg, batch: int) -> dict:
    w = cfg.lru_width or cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    return {
        "conv": ParamDef((batch, cfg.conv_width - 1, w), ("batch", "conv", "lru_width"), dt, "zeros"),
        "h": ParamDef((batch, w), ("batch", "lru_width"), jnp.float32, "zeros"),
    }


def rwkv_cache_defs(cfg, batch: int) -> dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    dt = jnp.dtype(cfg.dtype)
    return {
        "shift": ParamDef((batch, d), ("batch", "d_model"), dt, "zeros"),
        "wkv": ParamDef((batch, d // hd, hd, hd), ("batch", "rwkv_heads", "head_dim", "head_dim2"),
                        jnp.float32, "zeros"),
        "cm_shift": ParamDef((batch, d), ("batch", "d_model"), dt, "zeros"),
    }


def slot_positions(lengths, cache_size: int, window: int = 0):
    """Absolute positions + validity per cache slot. lengths: (B,) tokens
    written so far (AFTER the current decode token's write uses L+1)."""
    j = jnp.arange(cache_size)[None, :]                    # (1, S)
    L = lengths[:, None]
    if window:
        w = cache_size  # ring buffers are allocated at exactly min(window, S)
        pos = (L - 1) - jnp.remainder(L - 1 - j, w)
        valid = (pos >= 0) & (L > 0)
    else:
        pos = jnp.broadcast_to(j, (lengths.shape[0], cache_size))
        valid = j < L
    return pos, valid


def write_token(buf, new, lengths, window: int = 0, shard=None):
    """Write one token's k/v into the cache. buf: (B, S, H, D); new: (B, 1, H, D);
    lengths: (B,) tokens already present (write position).

    With ``shard=(mesh, dp_axes)`` and a cache whose seq dim is sharded over
    'model', the write runs under shard_map so each rank performs a purely
    local dynamic-update-slice (only the slot's owner writes). Letting the
    SPMD partitioner handle the batched scatter instead materializes a full
    f32 copy of the cache stack per step — the difference between a decode
    step fitting HBM or not on the 33B/16B archs.
    """
    size = buf.shape[1]
    idx = jnp.remainder(lengths, size) if window else jnp.clip(lengths, 0, size - 1)

    def upd(b, n, i):
        return jax.lax.dynamic_update_slice_in_dim(b, n.astype(b.dtype), i, axis=0)

    if shard is None:
        return jax.vmap(upd)(buf, new, idx)

    from jax.sharding import PartitionSpec as P
    mesh, dp_axes = shard
    msize = mesh.shape.get("model", 1)
    B = buf.shape[0]
    dp = tuple(a for a in dp_axes if a in mesh.axis_names)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    if msize <= 1 or size % msize != 0:
        return jax.vmap(upd)(buf, new, idx)
    bspec = (dp if len(dp) > 1 else dp[0]) if (dp and B % ndp == 0) else None
    s_loc = size // msize

    def local(buf_l, new_l, idx_l):
        off = jax.lax.axis_index("model") * s_loc

        def upd_local(b, n, i):
            li = i - off
            ok = (li >= 0) & (li < s_loc)
            lc = jnp.clip(li, 0, s_loc - 1)
            cur = jax.lax.dynamic_slice_in_dim(b, lc, 1, 0)
            val = jnp.where(ok, n.astype(b.dtype), cur)
            return jax.lax.dynamic_update_slice_in_dim(b, val, lc, 0)

        return jax.vmap(upd_local)(buf_l, new_l, idx_l)

    fn = compat.shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec, "model", None, None), P(bspec, None, None, None),
                  P(bspec)),
        out_specs=P(bspec, "model", None, None),
        check_rep=False)
    return fn(buf, new, idx)


def fill_from_prefill(kv, cache_size: int, window: int = 0):
    """Build a cache buffer from prefill-computed k or v: (B, S, H, D)."""
    B, S = kv.shape[:2]
    if window:
        w = cache_size
        if S >= w:
            last = kv[:, S - w:]
            return jnp.roll(last, shift=S % w, axis=1)
        return jnp.pad(kv, ((0, 0), (0, w - S), (0, 0), (0, 0)))
    if S >= cache_size:
        return kv[:, :cache_size]
    return jnp.pad(kv, ((0, 0), (0, cache_size - S), (0, 0), (0, 0)))

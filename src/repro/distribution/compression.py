"""Int8 error-feedback gradient compression.

Before the gradient all-reduce/reduce-scatter, each leaf is quantized to
int8 with a per-leaf scale; the quantization error is carried in an error-
feedback buffer and added back next step (Seide et al. / EF-SGD), which
keeps convergence while cutting gradient-sync bytes 4x (f32) / 2x (bf16).

Integration: optimizer-side transform — ``compress_grads`` runs after the
per-device grad computation; the psum/reduce-scatter then moves int8. On
GSPMD the dtype of the all-reduced tensor is what determines link bytes, so
quantize-before-sync is expressed by computing the sync on the int8 view.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    error: dict  # pytree of f32 error-feedback buffers


def init_ef(params) -> EFState:
    return EFState(error=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _quantize(x):
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_leaf(g, err):
    """Returns (int8 payload, scale, new_error). g, err: same shape f32."""
    target = g.astype(jnp.float32) + err
    q, scale = _quantize(target)
    recon = _dequantize(q, scale)
    return q, scale, target - recon


def compress_grads(grads, ef: EFState) -> Tuple[dict, dict, EFState]:
    """Compress every leaf. Returns (q_tree, scale_tree, new_ef)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef.error)
    qs, scales, errs = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = compress_leaf(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(ne)
    un = lambda xs: jax.tree.unflatten(treedef, xs)
    return un(qs), un(scales), EFState(error=un(errs))


def decompress_grads(q_tree, scale_tree):
    return jax.tree.map(_dequantize, q_tree, scale_tree)


def roundtrip(grads, ef: EFState):
    """compress -> (simulated sync) -> decompress, with error feedback."""
    q, s, ef = compress_grads(grads, ef)
    return decompress_grads(q, s), ef

"""Moonlight-16B-A3B (moonshot-v1-16b-a3b) — MoE, 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B]"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,         # MHA per the assignment (GQA kv=16)
    head_dim=128,
    d_ff=1408,             # per-expert FFN width
    vocab_size=163840,
    layer_pattern=(ATTN_GLOBAL,),
    moe=MoEConfig(n_experts=64, experts_per_token=6, d_ff_expert=1408),
    rope_theta=50000.0,
    source="hf:moonshotai/Moonlight-16B-A3B",
)

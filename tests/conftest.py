import os
import sys

# tests see the single real CPU device; only launch/dryrun.py (run as its own
# process) forces the 512-device dry-run platform
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)

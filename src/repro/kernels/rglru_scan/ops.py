"""Jit'd public wrapper for the RG-LRU scan kernel.

When reached from the decision path (the registry's ``policy="rglru"``
with ``use_pallas=True``, B = n_envs, T = 1), the ``pallas_call`` here is
statically certifiable: ``analysis/jaxpr_check`` evaluates the BlockSpec
index maps over the grid and checks the env-tagged batch axis is tiled
in size-1 blocks routed identically across inputs and outputs
(``pallas-env-block``), then walks the kernel body itself.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.kernel import LANES, rglru_scan_pallas
from repro.kernels.rglru_scan.ref import rglru_scan_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def rglru_scan(a, b, h0, *, use_pallas: bool = True, interpret: bool = True):
    """h_t = a_t h_{t-1} + b_t over axis 1. a, b: (B, T, W); h0: (B, W)."""
    if not use_pallas:
        return rglru_scan_ref(a, b, h0)
    B, T, W = a.shape
    pad = (-W) % LANES
    if pad:
        zp3 = lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, pad)))
        a, b = zp3(a), zp3(b)
        h0 = jnp.pad(h0, ((0, 0), (0, pad)))
    out, hlast = rglru_scan_pallas(a.astype(jnp.float32),
                                   b.astype(jnp.float32),
                                   h0.astype(jnp.float32),
                                   interpret=interpret)
    if pad:
        out, hlast = out[..., :W], hlast[..., :W]
    return out, hlast

"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Recurrence (per head, head_dim = hd):
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T          S: (hd_k, hd_v), w_t in (0,1)
    out_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
with data-dependent per-channel decay  w_t = exp(-exp(wb + tanh(x W_A) W_B))
(the Finch hallmark) and a learned per-head "bonus" u for the current token.

Two execution paths:
  * ``scan``    — exact sequential ``lax.scan`` over time (baseline; the
    decode path is the single-step specialization of it).
  * ``chunked`` — chunkwise-parallel: within a chunk of L tokens the
    intra-chunk contribution uses an explicit (L, L, hd) decay tensor
    ``exp(lp[t-1] - lp[s]) <= 1`` (numerically safe, no factorized
    exp(+big)), and chunks are stitched with the carried state. This is the
    flash-linear-attention idea adapted to stay overflow-free; it is the
    §Perf hillclimb lever for the rwkv6 cells.

A single-step per-env cell of this recurrence (token-shift mix + wkv
state update, phrased row-wise) also serves as the ``policy="rwkv6"``
decision model in ``runtime/policies.py``, with ``{shift, wkv}`` riding
the fused-scan carry and the env-mesh safety of the carry update
statically certified by ``analysis/certify.py``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm_defs
from repro.models.param import ParamDef

_LORA = 64  # decay LoRA rank


def rwkv_defs(cfg) -> dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    f = cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    s = 0.02
    so = s / math.sqrt(2 * cfg.n_layers)
    return {
        "norm": rms_norm_defs(d, dt),
        # token-shift mix coefficients for r,k,v,g,w
        "mu": ParamDef((5, d), ("mix5", "d_model"), dt, "custom",
                       custom=lambda k, sh: jax.random.uniform(k, sh)),
        "w_r": ParamDef((d, d), ("d_model", "heads_flat"), dt, "normal", s),
        "w_k": ParamDef((d, d), ("d_model", "heads_flat"), dt, "normal", s),
        "w_v": ParamDef((d, d), ("d_model", "heads_flat"), dt, "normal", s),
        "w_g": ParamDef((d, d), ("d_model", "heads_flat"), dt, "normal", s),
        "w_o": ParamDef((d, d), ("heads_flat", "d_model"), dt, "normal", so),
        # data-dependent decay: w = exp(-exp(wb + tanh(x A) B))
        "decay_base": ParamDef((d,), ("heads_flat",), dt, "custom",
                               custom=lambda k, sh: jax.random.uniform(k, sh, minval=-1.0, maxval=1.0)),
        "decay_A": ParamDef((d, _LORA), ("d_model", "lora"), dt, "normal", s),
        "decay_B": ParamDef((_LORA, d), ("lora", "heads_flat"), dt, "normal", s),
        "bonus_u": ParamDef((d,), ("heads_flat",), dt, "normal", s),
        "ln_out": ParamDef((d,), ("heads_flat",), dt, "zeros"),  # per-head groupnorm scale
        # channel mix
        "cm_norm": rms_norm_defs(d, dt),
        "cm_mu": ParamDef((2, d), ("mix2", "d_model"), dt, "custom",
                          custom=lambda k, sh: jax.random.uniform(k, sh)),
        "cm_k": ParamDef((d, f), ("d_model", "d_ff"), dt, "normal", s),
        "cm_v": ParamDef((f, d), ("d_ff", "d_model"), dt, "normal", so),
        "cm_r": ParamDef((d, d), ("d_model", "heads_flat"), dt, "normal", s),
    }


def _token_shift(x, x_prev_last):
    """shifted[t] = x[t-1]; shifted[0] = carried last token of prev segment."""
    return jnp.concatenate([x_prev_last[:, None, :], x[:, :-1]], axis=1)


def _rkvgw(p, x, shifted, cfg):
    """Project the five mixed streams. x, shifted: (B, S, d)."""
    mu = p["mu"].astype(x.dtype)  # (5, d)
    mix = lambda i: x + (shifted - x) * mu[i]
    xr, xk, xv, xg, xw = (mix(i) for i in range(5))
    r = xr @ p["w_r"].astype(x.dtype)
    k = xk @ p["w_k"].astype(x.dtype)
    v = xv @ p["w_v"].astype(x.dtype)
    g = xg @ p["w_g"].astype(x.dtype)
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["decay_A"].astype(jnp.float32))
    log_w = -jnp.exp(
        jnp.clip(p["decay_base"].astype(jnp.float32)
                 + lora @ p["decay_B"].astype(jnp.float32), -8.0, 3.0))
    # clamp decay so chunked exp() differences stay in f32 range
    log_w = jnp.clip(log_w, -20.0, -1e-5)
    return r, k, v, g, log_w


def _heads(x, hd):
    B, S, d = x.shape
    return x.reshape(B, S, d // hd, hd)


def _group_norm(x, scale, eps):
    """Per-head LayerNorm of the wkv output. x: (B, S, H, hd)."""
    xf = x.astype(jnp.float32)
    mean = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    n = (xf - mean) * jax.lax.rsqrt(var + eps)
    return n.reshape(x.shape[:2] + (-1,)) * (1.0 + scale.astype(jnp.float32))


def time_mix(p, x, cfg, state=None, *, chunk: int = 0, return_state: bool = False):
    """RWKV-6 time-mix over a full sequence.

    x: (B, S, d). state: dict(shift (B, d), wkv (B, H, hd, hd) f32) or None.
    """
    B, S, d = x.shape
    hd = cfg.rwkv_head_dim
    H = d // hd
    shift0 = state["shift"].astype(x.dtype) if state else jnp.zeros((B, d), x.dtype)
    S0 = state["wkv"] if state else jnp.zeros((B, H, hd, hd), jnp.float32)
    shifted = _token_shift(x, shift0)
    r, k, v, g, log_w = _rkvgw(p, x, shifted, cfg)
    rh, kh, vh = (_heads(t, hd).astype(jnp.float32) for t in (r, k, v))
    wh = _heads(log_w, hd)                                # (B, S, H, hd) log-decay
    u = p["bonus_u"].astype(jnp.float32).reshape(H, hd)

    if chunk and chunk > 1:
        wkv, S_new = _chunked_wkv(rh, kh, vh, wh, u, S0, chunk)
    else:
        wkv, S_new = _scan_wkv(rh, kh, vh, wh, u, S0)

    out = _group_norm(wkv.astype(x.dtype), p["ln_out"], cfg.norm_eps)
    out = out.astype(x.dtype) * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    out = out @ p["w_o"].astype(x.dtype)
    if return_state:
        return out, {"shift": x[:, -1], "wkv": S_new}
    return out, None


def _scan_wkv(r, k, v, w_log, u, S0):
    """Exact sequential recurrence. r/k/v/w_log: (B, S, H, hd)."""
    def step(S, t):
        rt, kt, vt, wt = t                                # (B, H, hd)
        att = S + u[None, :, :, None] * (kt[..., None] * vt[..., None, :])
        out = jnp.einsum("bhk,bhkv->bhv", rt, att)
        S = jnp.exp(wt)[..., None] * S + kt[..., None] * vt[..., None, :]
        return S, out

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, w_log))
    S_new, outs = jax.lax.scan(step, S0, xs)              # outs: (S, B, H, hd)
    B, Sq = r.shape[0], r.shape[1]
    return outs.transpose(1, 0, 2, 3).reshape(B, Sq, -1), S_new


def _chunked_wkv(r, k, v, w_log, u, S0, L):
    """Chunkwise-parallel recurrence, overflow-safe.

    Within a chunk: decay(t, s) = exp(lp[t-1] - lp[s]) for s < t (<= 1), the
    diagonal uses the bonus u. Cross-chunk: carried state decayed by
    exp(lp[t-1]) (<= 1). All exps are of non-positive numbers.
    """
    B, S, H, hd = r.shape
    n = -(-S // L)
    pad = n * L - S
    if pad:
        zr = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zr(r), zr(k), zr(v)
        w_log = jnp.pad(w_log, ((0, 0), (0, pad), (0, 0), (0, 0)),
                        constant_values=-1e-5)
    resh = lambda t: t.reshape(B, n, L, H, hd).transpose(1, 0, 2, 3, 4)
    rc, kc, vc, wc = (resh(t) for t in (r, k, v, w_log))  # (n, B, L, H, hd)

    def chunk_step(S_in, c):
        rr, kk, vv, ww = c                                # (B, L, H, hd)
        lp = jnp.cumsum(ww, axis=1)                       # inclusive log-cumprod
        lp_prev = lp - ww                                 # exclusive (lp[t-1])
        # inter-chunk: r_t decayed-dot carried state
        r_dec = rr * jnp.exp(lp_prev)
        inter = jnp.einsum("blhk,bhkv->blhv", r_dec, S_in)
        # intra-chunk: explicit (L, L, hd) decay tensor, all exps <= 0
        ddec = lp_prev[:, :, None] - lp[:, None, :]       # (B, L_t, L_s, H, hd)
        strict = (jnp.arange(L)[:, None] > jnp.arange(L)[None, :])
        ddec = jnp.where(strict[None, :, :, None, None], ddec, -jnp.inf)
        amat = jnp.einsum("blhk,bshk,blshk->blsh", rr, kk, jnp.exp(ddec))
        diag = jnp.einsum("blhk,hk,blhk->blh", rr, u, kk)
        intra = jnp.einsum("blsh,bshv->blhv", amat, vv)
        intra = intra + diag[..., None] * vv
        # state to end of chunk
        k_dec = kk * jnp.exp(lp[:, -1:, :, :] - lp)       # exps <= 0
        S_out = jnp.exp(lp[:, -1])[..., None] * S_in \
            + jnp.einsum("blhk,blhv->bhkv", k_dec, vv)
        return S_out, inter + intra

    S_new, outs = jax.lax.scan(chunk_step, S0, (rc, kc, vc, wc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, n * L, H, hd)[:, :S]
    return out.reshape(B, S, -1), S_new


def time_mix_step(p, x, cfg, state):
    """Single-token decode. x: (B, 1, d)."""
    B, _, d = x.shape
    hd = cfg.rwkv_head_dim
    H = d // hd
    shifted = state["shift"].astype(x.dtype)[:, None, :]
    r, k, v, g, log_w = _rkvgw(p, x, shifted, cfg)
    rh, kh, vh = (_heads(t, hd).astype(jnp.float32)[:, 0] for t in (r, k, v))
    wh = _heads(log_w, hd)[:, 0]                          # (B, H, hd)
    u = p["bonus_u"].astype(jnp.float32).reshape(H, hd)
    S0 = state["wkv"]
    att = S0 + u[None, :, :, None] * (kh[..., None] * vh[..., None, :])
    wkv = jnp.einsum("bhk,bhkv->bhv", rh, att).reshape(B, 1, d)
    S_new = jnp.exp(wh)[..., None] * S0 + kh[..., None] * vh[..., None, :]
    out = _group_norm(wkv.astype(x.dtype), p["ln_out"], cfg.norm_eps)
    out = out.astype(x.dtype) * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    out = out @ p["w_o"].astype(x.dtype)
    return out, {"shift": x[:, -1], "wkv": S_new}


def channel_mix(p, x, cfg, state=None, *, return_state: bool = False):
    """RWKV channel-mix (the FFN analogue). x: (B, S, d) normalized."""
    B, S, d = x.shape
    shift0 = state.astype(x.dtype) if state is not None else jnp.zeros((B, d), x.dtype)
    shifted = _token_shift(x, shift0)
    mu = p["cm_mu"].astype(x.dtype)
    xk = x + (shifted - x) * mu[0]
    xr = x + (shifted - x) * mu[1]
    kk = jnp.square(jax.nn.relu(xk @ p["cm_k"].astype(x.dtype)))
    out = jax.nn.sigmoid((xr @ p["cm_r"].astype(x.dtype)).astype(jnp.float32)).astype(x.dtype) \
        * (kk @ p["cm_v"].astype(x.dtype))
    if return_state:
        return out, x[:, -1]
    return out, None

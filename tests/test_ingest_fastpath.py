"""Host ingest fast path: bit-identity with the legacy path everywhere.

The arena-staged, sorted-merge, one-pass-assembly ingest
(``ingest_fastpath=True``, the default) and its ``ingest_workers``
sharding must reproduce the legacy chunk-list + global-lexsort path
bit for bit: windows, stats, tie order, overflow truncation, and the
replay/LogDB sinks — across codecs, ``ingest="records"`` vs
``"columnar"``, elastic masked pools, and scan/async/fused modes.
"""
import numpy as np
import pytest

from repro.core import PipelineConfig
from repro.core.reward import energy_reward_spec
from repro.runtime.accumulator import Accumulator
from repro.runtime.db import LogDB
from repro.runtime.predictor import ActionSpace, Predictor, linear_policy
from repro.runtime.queues import _head
from repro.runtime.receivers import Receiver, SimulatedDevice
from repro.runtime.records import Record, RecordBatch
from repro.runtime.system import PerceptaSystem, SourceSpec
from repro.runtime.translator import Translator
from repro.testing import given, settings, st

STREAMS = ["grid_kw", "temp_c", "price"]
BOUNDS = [(0.0, 100.0), (100.0, 200.0), (200.0, 300.0)]
LATER_BOUNDS = [(300.0, 400.0), (400.0, 500.0)]


def _mixed_items(rng, n=150, max_t=480.0):
    """A drained-queue mix: singles, multi-stream batches, single-stream
    sorted and unsorted batches — with boundary ties, stale records and
    rows past the last window end (stay pending)."""
    items, recs = [], []
    for i in range(n):
        s = STREAMS[rng.randint(len(STREAMS))]
        t = float(rng.uniform(0, max_t))
        if i % 13 == 0:                       # exact boundary ties
            t = float(BOUNDS[i % 3][1])
        recs.append(Record("env", s, t, float(rng.normal(5, 2))))
    i = 0
    while i < len(recs):
        kind = rng.randint(4)
        take = recs[i:i + 1 + rng.randint(12)]
        i += len(take)
        if kind == 0:
            items.extend(take)                # singles
        elif kind == 1:
            items.append(RecordBatch.from_records(take))   # multi-stream
        else:
            s = take[0].stream                # single-stream batch
            ts = np.asarray([r.timestamp for r in take])
            vs = np.asarray([r.value for r in take])
            if kind == 2:                     # sorted + honestly flagged
                order = np.argsort(ts, kind="stable")
                ts, vs = ts[order], vs[order]
                items.append(RecordBatch.from_columns("env", s, ts, vs,
                                                      sorted_ts=True))
            else:                             # arrival order, unflagged
                items.append(RecordBatch.from_columns("env", s, ts, vs))
    return items


def _close_twice(acc):
    """Two close rounds: the second exercises the retained tail (the
    arena's self-healing sortedness) and fresh stats accumulation."""
    r1 = acc.close_windows(BOUNDS, rebase=True)
    r2 = acc.close_windows(LATER_BOUNDS, rebase=False)
    return r1, r2


@pytest.mark.parametrize("max_samples", [4, 16])   # 4 forces overflow
def test_sorted_merge_equals_lexsort_bit_for_bit(rng, max_samples):
    items = _mixed_items(rng)
    fast = Accumulator("env", STREAMS, max_samples, fastpath=True)
    slow = Accumulator("env", STREAMS, max_samples, fastpath=False)
    fast.ingest(items)
    slow.ingest(items)
    for ra, rb in zip(_close_twice(fast), _close_twice(slow)):
        for x, y in zip(ra, rb):
            assert x.dtype == y.dtype and (x == y).all()
    assert fast.stats == slow.stats
    assert fast.merge_stats["close_lexsort"] == 0
    assert slow.merge_stats["close_fast"] == 0


@given(seed=st.integers(0, 10_000), max_samples=st.sampled_from((3, 8, 64)))
@settings(max_examples=25, deadline=None)
def test_property_sorted_merge_vs_lexsort_parity(seed, max_samples):
    """Random record streams (random batching, sortedness, ties, overflow):
    the sorted-merge close and the global-lexsort close agree bit for bit
    on every output array and every stat, across two close rounds."""
    rng = np.random.RandomState(seed)
    items = _mixed_items(rng, n=30 + rng.randint(120))
    fast = Accumulator("env", STREAMS, max_samples, fastpath=True)
    slow = Accumulator("env", STREAMS, max_samples, fastpath=False)
    fast.ingest(items)
    slow.ingest(items)
    for ra, rb in zip(_close_twice(fast), _close_twice(slow)):
        for x, y in zip(ra, rb):
            assert x.dtype == y.dtype and (x == y).all()
    assert fast.stats == slow.stats


def test_out_of_order_arrivals_sort_then_heal(rng):
    """Unsorted arrivals take the argsort fallback exactly once: the
    retained tail is stored sorted, so the NEXT close is fast again."""
    acc = Accumulator("env", ["s"], 64)
    ts = rng.uniform(0, 480.0, 50)            # unsorted, spans both closes
    acc.ingest_batch(RecordBatch.from_columns("env", "s", ts, ts))
    acc.close_windows(BOUNDS)
    assert acc.merge_stats == {"close_fast": 0, "close_sort": 1,
                               "close_lexsort": 0}
    acc.close_windows(LATER_BOUNDS)
    assert acc.merge_stats["close_fast"] == 1  # tail healed to sorted


def test_sorted_flag_skips_verification_and_buckets_fast():
    acc = Accumulator("env", ["s"], 64)
    ts = np.arange(10, dtype=np.float64) * 30.0
    acc.ingest_batch(RecordBatch.from_columns("env", "s", ts, ts,
                                              sorted_ts=True))
    v, t, m = acc.close_windows(BOUNDS)
    assert acc.merge_stats["close_fast"] == 1
    assert int(m.sum()) == 10 and acc.stats["records"] == 10


def test_sorted_flag_propagates_receiver_translator_queue():
    # receiver: measured per poll (jitter can't exceed the interval here)
    dev = SimulatedDevice("s", interval_s=60.0, dropout_p=0.0, jitter_s=0.5,
                          spike_p=0.0)
    clock = {"now": 0.0}
    r = Receiver("src", "mqtt", dev, lambda: clock["now"])
    seen = []
    r.subscribe("e", on_batch=lambda e, s, ts, vs, srt: seen.append(srt))
    clock["now"] = 600.0
    r.poll_once()
    assert seen == [True]
    # translator passes the promise through; rename/scale never reorder
    tr = Translator("src", "mqtt", unit_scale=2.0)
    b = tr.translate_batch("e", "s", [1.0, 2.0], [3.0, 4.0], True)
    assert b.sorted_ts is True
    # queue overflow truncation keeps it (prefix of sorted is sorted)
    assert _head(b, 1).sorted_ts is True
    # default stays "unknown", never a false promise
    b2 = tr.translate_batch("e", "s", [2.0, 1.0], [3.0, 4.0])
    assert b2.sorted_ts is None


# --------------------------------------------------------------------------
# System level: every ingest configuration is bit-identical
# --------------------------------------------------------------------------

def _system(mode="scan", n_envs=2, scan_k=3, protocols=("mqtt", "amqp"),
            **kw):
    srcs = [
        SourceSpec("meter", protocols[0],
                   SimulatedDevice("grid_kw", 60.0, base=3.0, seed=1)),
        SourceSpec("price", protocols[1],
                   SimulatedDevice("price_eur", 300.0, base=0.2,
                                   amplitude=0.05, seed=2)),
    ]
    cfg = PipelineConfig(n_envs=n_envs, n_streams=2, n_ticks=8, tick_s=60.0,
                         max_samples=32)
    pred = Predictor(linear_policy(2, 2),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     n_envs, cfg.n_features, replay_capacity=64)
    envs = [f"bldg-{i}" for i in range(n_envs)]
    return PerceptaSystem(envs, srcs, cfg, pred, speedup=5000.0,
                          manual_time=True, mode=mode, scan_k=scan_k, **kw)


def _strip(results):
    """Everything but the wall-clock latency metric must match exactly."""
    return [{k: v for k, v in r.items() if k != "latency_s"}
            for r in results]


@pytest.mark.parametrize("ingest", ["records", "columnar"])
@pytest.mark.parametrize("protocols", [("mqtt", "amqp"), ("http", "http")])
def test_fastpath_matches_legacy_per_ingest_path(ingest, protocols):
    """fastpath on == fastpath off for BOTH ingest paths and all codecs
    (including lossy http CSV: the wire rounding is identical on both
    sides of this comparison, so equality is exact)."""
    a = _system(ingest=ingest, protocols=protocols, ingest_fastpath=True)
    b = _system(ingest=ingest, protocols=protocols, ingest_fastpath=False)
    ra, rb = a.run_windows(7), b.run_windows(7)
    a.stop(), b.stop()
    assert _strip(ra) == _strip(rb)


@pytest.mark.parametrize("mode", ["scan", "scan_async", "scan_fused_decide"])
@pytest.mark.parametrize("workers", [2, 4])
def test_ingest_workers_bit_identical(mode, workers):
    """Worker-sharded assembly == serial assembly through the scan, async
    (prefetcher epoch protocol) and fused-decide engines; the replay sink
    sees identical rows."""
    ref = _system(mode=mode)
    got = _system(mode=mode, ingest_workers=workers)
    rr, rg = ref.run_windows(7), got.run_windows(7)
    assert _strip(rr) == _strip(rg)
    ra, rb = ref.export_replay("s"), got.export_replay("s")
    for k, v in ra.items():
        eq = (np.asarray(v) == np.asarray(rb[k]))
        assert eq if isinstance(eq, bool) else eq.all(), k
    ref.stop(), got.stop()


def test_fastpath_logdb_rows_identical(tmp_path):
    """The LogDB sink logs byte-identical rows under the fast path."""
    rows = {}
    for name, fast in (("fast", True), ("legacy", False)):
        db = LogDB(str(tmp_path / name), salt="x")
        s = _system(ingest_fastpath=fast, db=db)
        s.run_windows(6)
        s.stop(), db.close()
        rows[name] = [{k: v for k, v in r.items() if k != "logged_at"}
                      for _, r in db.read_from()]
    assert rows["fast"] == rows["legacy"] and len(rows["fast"]) == 12


def test_elastic_masked_pool_fastpath_identity():
    """Fast path under elastic churn (attach into a free slot, detach):
    identical per-window rows and replay export to the legacy path."""
    def run(fast):
        s = _system(n_envs=4, elastic=True, env_slots=4,
                    ingest_fastpath=fast)
        s.detach_env("bldg-3")                # start 3-of-4 occupied
        out = _strip(s.run_windows(3))
        s.attach_env("joiner")
        out += _strip(s.run_windows(3))
        s.detach_env("bldg-1")
        out += _strip(s.run_windows(3))
        exp = s.export_replay("s")
        s.stop()
        return out, exp
    (ra, ea), (rb, eb) = run(True), run(False)
    assert ra == rb
    for k, v in ea.items():
        eq = (np.asarray(v) == np.asarray(eb[k]))
        assert eq if isinstance(eq, bool) else eq.all(), k


def test_staging_buffers_not_reused_while_batch_alive():
    """The rotating staging pool must not overwrite a RawWindow that is
    still within the pipeline depth: the buffer an assembly returned is
    untouched for the next ``_STAGE_DEPTH - 1`` assemblies."""
    s = _system()
    k = s.scan_k
    def assemble():
        bounds = [s.window_bounds(s.window_index + j) for j in range(k)]
        s._advance_clock(bounds[-1][1])
        s.pump_receivers()
        raw, _ = s.assemble_windows(bounds)
        s.window_index += k
        return raw
    raw0 = assemble()
    snap = [np.array(np.asarray(x)) for x in
            (raw0.values, raw0.timestamps, raw0.valid)]
    for _ in range(PerceptaSystem._STAGE_DEPTH - 1):
        assemble()
    for a, b in zip(snap, (raw0.values, raw0.timestamps, raw0.valid)):
        assert (a == np.asarray(b)).all()
    s.stop()

# The paper's primary contribution — Percepta's stream-processing tick as
# batched JAX: harmonize -> anomaly -> gap-fill -> normalize -> aggregate ->
# encode -> (model) -> reward -> replay. See pipeline.PerceptaPipeline.
from repro.core.frame import FeatureFrame, RawWindow, TickFrame  # noqa: F401
from repro.core.pipeline import (DecideBatch, PerceptaPipeline,  # noqa: F401
                                 PipelineConfig, PipelineState, init_state,
                                 run_many_decide, tick)

"""Loop-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE — with
``lax.scan`` over layer groups (which we need to keep 1-core compile times
bounded) that undercounts FLOPs/bytes/collectives by ~n_layers x. This module
re-derives the three roofline inputs by walking the scheduled HLO text with
trip-count multiplication:

  * computations are parsed into (name -> instructions) with a shape table;
  * ``while`` ops multiply their body+condition cost by the trip count from
    ``backend_config={"known_trip_count":{"n":...}}`` (fallback: the largest
    s32 constant in the condition computation, else 1);
  * FLOPs: ``dot`` = 2 * result_elems * K (K = product of lhs contracting
    dims), ``convolution`` = 2 * result_elems * prod(kernel dims)/out_feat,
    everything else = result_elems (elementwise approximation — matches
    XLA's own accounting to within noise at transformer scales);
  * bytes: operands + result of every *scheduled* op (fusion call sites count
    their operands/result; fused interiors are free — post-fusion this is the
    HBM-traffic model XLA itself uses);
  * collectives: ring-model link bytes (see launch/roofline.py) accumulated
    with the enclosing trip product.

Everything is per-device (the text is the per-partition module).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "u1": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"            # result name
    # type: tuple (may contain /*index=k*/ comments; one nesting level) or array
    r"((?:\((?:[^()]|\([^()]*\))*\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"([\w\-]+)\("                                       # opcode
)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[\\"=:{\s]+n[\\":\s]+(\d+)')
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_LCD_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_NO_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "after-all", "partition-id", "replica-id",
                 "iota"}

# Fusion-optimistic HBM-traffic model: only ops that materialize buffers on
# a TPU (where elementwise chains fuse into their producers/consumers) count
# bytes. The CPU-backend HLO we analyze is less fused than TPU output would
# be; charging bytes to every unfused convert/add would overstate the memory
# term ~5x. Elementwise ops still count their (cheap) flops.
_BYTES_OPS = {
    "dot", "convolution", "fusion", "copy", "slice", "dynamic-slice",
    "dynamic-update-slice", "gather", "scatter", "reduce", "reduce-window",
    "sort", "concatenate", "pad", "select-and-scatter", "custom-call",
    "cholesky", "triangular-solve", "transpose",
}


def _shapes_in(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        shape = [int(d) for d in dims.split(",")] if dims else []
        out.append((dt, shape))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, shape in _shapes_in(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _elems(type_str: str) -> int:
    total = 0
    for _, shape in _shapes_in(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n
    return total


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=lambda: {
        k: 0.0 for k in _COLLECTIVES})
    coll_count: float = 0.0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in _COLLECTIVES:
            self.coll[k] += other.coll[k] * mult
        self.coll_count += other.coll_count * mult


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = Computation(m.group(1))
                if line.startswith("ENTRY"):
                    entry = cur.name
                continue
        else:
            if line.startswith("}"):
                comps[cur.name] = cur
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                ins = Instr(m.group(1), m.group(2), m.group(3), line)
                cur.instrs.append(ins)
                cur.shapes[ins.name] = ins.type_str
    return comps, entry


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        ids = [t for t in m.group(1).split(",") if t.strip()]
        return max(1, len(ids))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    return default


def _link_bytes(op: str, payload: float, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-gather":
        return payload * (n - 1) / n
    if op == "all-reduce":
        return 2.0 * payload * (n - 1) / n
    if op == "reduce-scatter":
        return payload * (n - 1)
    if op == "all-to-all":
        return payload * (n - 1) / n
    return float(payload)  # collective-permute


def _dot_flops(ins: Instr, comp: Computation) -> float:
    res_elems = _elems(ins.type_str)
    # operand 0 (lhs) name: first %ref inside parens after opcode
    paren = ins.line.split(ins.opcode + "(", 1)[1]
    ops = _OPERAND_RE.findall(paren)
    k = 1
    m = _LCD_RE.search(ins.line)
    if m and ops:
        lhs_type = comp.shapes.get(ops[0])
        if lhs_type:
            shapes = _shapes_in(lhs_type)
            if shapes:
                lhs_shape = shapes[0][1]
                for d in (m.group(1).split(",") if m.group(1) else []):
                    di = int(d)
                    if di < len(lhs_shape):
                        k *= lhs_shape[di]
    return 2.0 * res_elems * max(k, 1)


class Analyzer:
    def __init__(self, text: str, n_devices: int):
        self.comps, self.entry = parse_module(text)
        self.n_devices = n_devices
        self._memo: Dict[str, Cost] = {}

    def trip_count(self, ins: Instr) -> int:
        m = _TRIP_RE.search(ins.line)
        if m:
            return int(m.group(1))
        mc = _COND_RE.search(ins.line)
        if mc and mc.group(1) in self.comps:
            consts = []
            for i2 in self.comps[mc.group(1)].instrs:
                consts += [int(x) for x in _CONST_RE.findall(i2.line)]
            if consts:
                return max(consts)
        return 1

    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()  # break recursion defensively
        comp = self.comps.get(name)
        if comp is None:
            return self._memo[name]
        total = Cost()
        for ins in comp.instrs:
            op = ins.opcode
            base_op = op[:-6] if op.endswith("-start") else op
            if base_op in _COLLECTIVES:
                payload = _type_bytes(ins.type_str)
                if op.endswith("-start"):
                    shapes = _shapes_in(ins.type_str)
                    if len(shapes) > 1:
                        dt, shape = shapes[-1]
                        n = 1
                        for d in shape:
                            n *= d
                        payload = n * _DTYPE_BYTES[dt]
                n = _group_size(ins.line, self.n_devices)
                total.coll[base_op] += _link_bytes(base_op, payload, n)
                total.coll_count += 1
                total.bytes += _type_bytes(ins.type_str)
                continue
            if op.endswith("-done"):
                continue
            if op == "while":
                trips = self.trip_count(ins)
                mb = _BODY_RE.search(ins.line)
                mc = _COND_RE.search(ins.line)
                if mb:
                    total.add(self.comp_cost(mb.group(1)), trips)
                if mc:
                    total.add(self.comp_cost(mc.group(1)), trips)
                continue
            if op == "fusion":
                mcall = _CALLS_RE.search(ins.line)
                if mcall:
                    inner = self.comp_cost(mcall.group(1))
                    total.flops += inner.flops  # dots inside fusions
                total.bytes += self._fusion_bytes(ins, mcall, comp)
                total.flops += _elems(ins.type_str)
                continue
            if op in ("call", "conditional", "async-start"):
                for cname in _CALLS_RE.findall(ins.line):
                    total.add(self.comp_cost(cname))
                total.bytes += self._io_bytes(ins, comp)
                continue
            if op == "dot":
                total.flops += _dot_flops(ins, comp)
                total.bytes += self._io_bytes(ins, comp)
                continue
            if op == "convolution":
                total.flops += 2.0 * _elems(ins.type_str) * 64  # coarse
                total.bytes += self._io_bytes(ins, comp)
                continue
            if op in _NO_BYTES_OPS:
                continue
            # generic op: elementwise flops; bytes only if it materializes
            total.flops += _elems(ins.type_str)
            if op in _BYTES_OPS:
                total.bytes += self._io_bytes(ins, comp)
        self._memo[name] = total
        return total

    def _fusion_bytes(self, ins: Instr, mcall, comp: Computation) -> float:
        """HBM traffic of a fusion, slice-access aware.

        Two patterns dominate scan-heavy modules and must NOT be charged at
        full-buffer size:
          * slice-read:  the fusion reads ONE layer's window of a stacked
            (L, ...) param/cache via an inner dynamic-slice;
          * in-place update (root dynamic-update-slice of the result shape):
            writes ONE slice of an aliased ys/cache buffer.
        Operands are matched to inner ``parameter(i)`` positions; operands
        accessed only through inner dynamic-slices are charged the slice
        window, everything else full size.
        """
        inner = self.comps.get(mcall.group(1)) if mcall else None
        try:
            paren = ins.line.split(ins.opcode + "(", 1)[1].split(")", 1)[0]
            operands = _OPERAND_RE.findall(paren)
        except IndexError:
            return float(_type_bytes(ins.type_str))
        if inner is None:
            return self._io_bytes(ins, comp)

        # inner parameter name -> operand index
        pidx = {}
        for i2 in inner.instrs:
            if i2.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", i2.line)
                if m:
                    pidx[i2.name] = int(m.group(1))
        slice_access: dict = {}   # operand index -> window bytes
        dus_update_bytes = None
        out_dims = [s for _, s in _shapes_in(ins.type_str)]
        for i2 in inner.instrs:
            if i2.opcode in ("dynamic-slice", "slice", "gather"):
                try:
                    p2 = i2.line.split(i2.opcode + "(", 1)[1].split(")", 1)[0]
                    ops2 = _OPERAND_RE.findall(p2)
                except IndexError:
                    continue
                if ops2 and ops2[0] in pidx:
                    oi = pidx[ops2[0]]
                    slice_access[oi] = slice_access.get(oi, 0.0) \
                        + _type_bytes(i2.type_str)
            if i2.opcode == "dynamic-update-slice" \
                    and [s for _, s in _shapes_in(i2.type_str)] == out_dims:
                try:
                    p2 = i2.line.split("dynamic-update-slice(", 1)[1] \
                        .split(")", 1)[0]
                    ops2 = _OPERAND_RE.findall(p2)
                except IndexError:
                    ops2 = []
                if len(ops2) > 1 and ops2[1] in inner.shapes:
                    dus_update_bytes = _type_bytes(inner.shapes[ops2[1]])

        # result side
        b = float(2.0 * dus_update_bytes if dus_update_bytes is not None
                  else _type_bytes(ins.type_str))
        # operand side
        for i, opnd in enumerate(operands):
            t = comp.shapes.get(opnd)
            if t is None:
                continue
            full = _type_bytes(t)
            if i in slice_access:
                b += min(slice_access[i], full)
            elif dus_update_bytes is not None \
                    and [s for _, s in _shapes_in(t)] == out_dims:
                continue  # the aliased in-place buffer: already charged
            else:
                b += full
        return b

    def _io_bytes(self, ins: Instr, comp: Computation) -> float:
        """HBM traffic of one scheduled op.

        Sliced accesses are charged their SLICE, not the whole operand:
        an in-place dynamic-update-slice on a donated KV cache touches one
        token's rows, not the 4 GB buffer (charging the buffer would claim a
        33B decode step moves ~200 GB). dynamic-slice/gather similarly read
        only their result-sized window.
        """
        b = float(_type_bytes(ins.type_str))
        op = ins.opcode
        if op in ("dynamic-slice", "gather", "slice"):
            return 2.0 * b  # read window ~= result + write result
        try:
            paren = ins.line.split(ins.opcode + "(", 1)[1]
            # cut attrs off at '), ' boundary to avoid matching comp names
            paren = paren.split(")", 1)[0]
        except IndexError:
            return b
        operands = _OPERAND_RE.findall(paren)
        if op in ("dynamic-update-slice", "scatter"):
            # update (operand 1 for DUS, 2 for scatter) read+written in place
            idx = 1 if op == "dynamic-update-slice" else 2
            if len(operands) > idx:
                t = comp.shapes.get(operands[idx])
                if t:
                    return 2.0 * _type_bytes(t)
            return b
        for opnd in operands:
            t = comp.shapes.get(opnd)
            if t:
                b += _type_bytes(t)
        return b

    def analyze(self) -> dict:
        cost = self.comp_cost(self.entry) if self.entry else Cost()
        coll_total = sum(cost.coll.values())
        return {
            "flops": cost.flops,
            "bytes": cost.bytes,
            "collective_link_bytes": coll_total,
            "collectives": dict(cost.coll, count=cost.coll_count,
                                total=coll_total),
        }


def analyze_hlo(text: str, n_devices: int) -> dict:
    return Analyzer(text, n_devices).analyze()


def analyze_hlo_file(path, n_devices: int) -> dict:
    """``analyze_hlo`` over an HLO text dump on disk.

    Raises FileNotFoundError with an actionable message instead of the bare
    ``open`` error — missing dump paths are the most common operator mistake
    when pointing the roofline tooling at ``--xla_dump_to`` output.
    """
    import os
    if not os.path.isfile(path):
        raise FileNotFoundError(
            f"HLO dump not found: {path!r}. Pass a path to a scheduled HLO "
            "text file (e.g. an --xla_dump_to '*after_optimizations*.txt' "
            "artifact, or tests/data_hlo_sample.txt for the test fixture).")
    with open(path) as f:
        return analyze_hlo(f.read(), n_devices)


def cpu_bf16_upcast_bytes(text: str, min_bytes: int = 32 * 2**20) -> int:
    """Bytes of f32 temp copies that exist ONLY because the CPU backend
    legalizes bf16 compute to f32.

    The pre-optimization module is pure bf16 for these tensors (verified via
    --xla_dump_to); XLA:CPU then inserts whole-buffer `f32 convert(bf16)`
    round-trips for loop-carried caches and weight stacks. XLA:TPU consumes
    bf16 natively in the MXU and does not materialize these. We count every
    large `f32[dims] convert(x)` whose operand is bf16 with identical dims —
    the dry-run reports HBM fit both raw and adjusted by this amount.
    """
    comps, _ = parse_module(text)
    total = 0
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.opcode != "convert":
                continue
            shapes = _shapes_in(ins.type_str)
            if len(shapes) != 1 or shapes[0][0] != "f32":
                continue
            n = 1
            for d in shapes[0][1]:
                n *= d
            if n * 4 < min_bytes:
                continue
            paren = ins.line.split("convert(", 1)[1].split(")", 1)[0]
            ops = _OPERAND_RE.findall(paren)
            if not ops:
                continue
            src = comp.shapes.get(ops[0], "")
            src_shapes = _shapes_in(src)
            if len(src_shapes) == 1 and src_shapes[0][0] == "bf16" \
                    and src_shapes[0][1] == shapes[0][1]:
                total += n * 4
    return total

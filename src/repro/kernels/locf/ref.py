"""Pure-jnp oracle for the LOCF (last-observation-carried-forward) kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def locf_ref(values, observed, init_value, init_has):
    """Carry the latest observation along the tick axis.

    values/observed: (R, T); init_value/init_has: (R,) carry-in from the
    previous window. Returns (filled (R, T), has (R, T)).
    """
    v = jnp.concatenate([init_value[:, None], values], axis=1)
    o = jnp.concatenate([init_has[:, None], observed], axis=1)

    def combine(a, b):
        av, ao = a
        bv, bo = b
        return jnp.where(bo, bv, av), ao | bo

    cv, co = jax.lax.associative_scan(combine, (v, o), axis=1)
    return cv[:, 1:], co[:, 1:]

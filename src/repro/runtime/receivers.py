"""Receivers — the system's input components (one per data source).

Each Receiver adapts to how the asset delivers data (MQTT push, HTTP poll,
AMQP queue). In this container the transports are simulated by
:class:`SimulatedDevice` objects generating timestamped readings at each
source's own reporting interval (the 5-min vs 1-h heterogeneity the paper
harmonizes); the Receiver/Translator code paths are identical to what a real
broker client would drive.

Per the paper's multi-environment design, a Receiver serves every
environment that subscribes to its source ("each Receiver allocates a
separate thread for every environment that requires data from that source").
"""
from __future__ import annotations

import math
import random
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.runtime.records import CODECS


@dataclass
class SimulatedDevice:
    """A data source: reports `stream` every `interval_s` with noise, drop-
    outs (sensor turned off) and occasional spikes (the anomalies)."""
    stream: str
    interval_s: float
    base: float = 10.0
    amplitude: float = 2.0
    period_s: float = 3600.0
    noise: float = 0.2
    dropout_p: float = 0.05
    spike_p: float = 0.002
    spike_scale: float = 50.0
    jitter_s: float = 0.5
    seed: int = 0

    def readings(self, t_start: float, t_end: float, env_seed: int = 0):
        """Deterministic readings in [t_start, t_end) for reproducibility.

        All randomness is derived per-sample from ``hash((stream, seed,
        env_seed, k))`` so a poll's output depends only on the interval it
        covers, never on how many polls preceded it."""
        n0 = int(math.floor(t_start / self.interval_s))
        out = []
        k = n0
        while True:
            t = k * self.interval_s
            k += 1
            if t >= t_end:
                break
            if t < t_start:
                continue
            r = random.Random(hash((self.stream, self.seed, env_seed, k)))
            if r.random() < self.dropout_p:
                continue  # lost sample — gap filling's job
            v = self.base + self.amplitude * math.sin(2 * math.pi * t / self.period_s)
            v += r.gauss(0.0, self.noise)
            if r.random() < self.spike_p:
                v += self.spike_scale * (1 if r.random() < 0.5 else -1)
            out.append((t + r.uniform(0, self.jitter_s), v))
        return out


class Receiver(threading.Thread):
    """Polls/receives from one source and hands raw payloads to the
    Translator callback per subscribed environment.

    Two delivery shapes per subscription:
      * ``on_payload`` — one encoded wire payload per reading (the
        protocol-faithful path; exercises the codecs end to end).
      * ``on_batch``   — one ``(env_id, stream, ts_column, value_column,
        sorted_ts)`` call per poll (the columnar fast path: a poll's
        readings cross the receiver boundary as two NumPy columns plus a
        sortedness flag, no per-reading Python). ``sorted_ts`` is computed
        here — the receiver is the one place that sees the columns exactly
        once — and lets the Accumulator's sorted-merge close skip both its
        verification pass and its sort. Device jitter can reorder adjacent
        readings (jitter_s > interval_s), so the flag is measured, never
        assumed.
    When both are given the batch path wins; stats count logical readings
    either way (bytes on the batch path are the 16-byte binary-equivalent
    per reading, so load accounting stays comparable across paths).
    """

    def __init__(self, source_id: str, protocol: str, device: SimulatedDevice,
                 clock: Callable[[], float], speedup: float = 1.0,
                 max_backlog_s: float = 3600.0):
        super().__init__(daemon=True, name=f"receiver-{source_id}")
        self.source_id = source_id
        self.protocol = protocol
        self.device = device
        self.clock = clock
        self.speedup = speedup
        # QoS-0 semantics: when the consumer stalls (e.g. jit compiles), data
        # older than the backlog horizon is dropped, not replayed
        self.max_backlog_s = max_backlog_s
        self.encode = CODECS[protocol][0]
        self._subs: Dict[str, Optional[Callable[[str, bytes], None]]] = {}
        self._batch_subs: Dict[str, Callable] = {}
        self._stop = threading.Event()
        self._last_t: Dict[str, float] = {}
        # serializes poll cycles: the run() thread and synchronous
        # pump_receivers() callers both invoke poll_once, and an unguarded
        # read-emit-advance of _last_t double-emits (both see the same t0)
        # or drops (one overwrites the other's advance) readings
        self._poll_lock = threading.Lock()
        self.stats = {"payloads": 0, "bytes": 0}

    def subscribe(self, env_id: str,
                  on_payload: Optional[Callable[[str, bytes], None]] = None,
                  on_batch: Optional[Callable] = None):
        assert on_payload is not None or on_batch is not None
        with self._poll_lock:   # atomic wrt a concurrent poll cycle
            self._subs[env_id] = on_payload
            if on_batch is not None:
                self._batch_subs[env_id] = on_batch
            else:  # re-subscribing payload-only must drop a stale batch route
                self._batch_subs.pop(env_id, None)
            # first subscription starts the poll horizon NOW; a re-subscribe
            # keeps it, so any interval skipped while the subscription was
            # half-installed is delivered to the new callback instead of
            # silently dropped (max_backlog_s still bounds staleness)
            self._last_t.setdefault(env_id, self.clock())

    def unsubscribe(self, env_id: str) -> None:
        """Detach an env from this source (elastic membership).

        Atomic wrt a concurrent poll cycle; the poll horizon entry is
        dropped too, so a later re-subscribe of the same env id starts a
        FRESH horizon at attach time instead of replaying the gap."""
        with self._poll_lock:
            self._subs.pop(env_id, None)
            self._batch_subs.pop(env_id, None)
            self._last_t.pop(env_id, None)

    def poll_once(self):
        """One poll cycle: emit all new readings per environment.

        The whole cycle holds the receiver's poll lock, so concurrent
        ``start()``-thread polls and synchronous ``pump_receivers()`` calls
        interleave as atomic cycles over disjoint [t0, now) intervals —
        every reading is emitted exactly once."""
        with self._poll_lock:
            self._poll_cycle()

    def _poll_cycle(self):
        now = self.clock()
        for env_id, cb in list(self._subs.items()):
            t0 = max(self._last_t[env_id], now - self.max_backlog_s)
            if now <= t0:
                continue
            env_seed = abs(hash(env_id)) % 100000
            readings = self.device.readings(t0, now, env_seed)
            cb_batch = self._batch_subs.get(env_id)
            if cb_batch is not None:
                if readings:
                    ts = np.fromiter((r[0] for r in readings), np.float64,
                                     len(readings))
                    vs = np.fromiter((r[1] for r in readings), np.float64,
                                     len(readings))
                    self.stats["payloads"] += len(readings)
                    self.stats["bytes"] += 16 * len(readings)
                    srt = bool(np.all(ts[1:] >= ts[:-1]))
                    cb_batch(env_id, self.device.stream, ts, vs, srt)
            elif cb is None:
                # a half-installed subscription (e.g. a batch re-subscribe
                # that lost its route): keep _last_t so nothing is skipped
                # once a real callback lands, and never call None
                continue
            else:
                for ts, v in readings:
                    payload = self.encode(self.device.stream, ts, v)
                    self.stats["payloads"] += 1
                    self.stats["bytes"] += len(payload)
                    cb(env_id, payload)
            self._last_t[env_id] = now

    def run(self):
        while not self._stop.is_set():
            self.poll_once()
            time.sleep(max(self.device.interval_s / self.speedup / 4, 0.001))

    def stop(self):
        self._stop.set()

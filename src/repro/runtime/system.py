"""PerceptaSystem — full wiring of Figure 1, multi-environment.

Deployment modes (paper §III.C): the SAME system object serves
  * edge  — one environment, fully local
  * fog   — a few nearby environments
  * cloud — many isolated environments simultaneously
All environments are rows of the batched device pipeline; isolation is by
construction (per-env queues, per-env state rows, per-env model slots).

Time is virtual (``speedup``) so benchmarks can run days of stream time in
seconds. The Manager logic lives in ``run_window``: close each env's window,
assemble the device batch, run the (fused or modular) Percepta tick, run the
Predictor, forward the decisions, log everything.

``mode="scan"`` switches the Manager loop to the scan-fused engine: queues
are drained once per batch, each env's Accumulator closes K consecutive
windows into a stacked (K, E, S, M) RawWindow, and ONE device dispatch
(``PerceptaPipeline.run_many``) processes all K windows with the state
carried on device. The decision path is batched the same way: the
Predictor consumes the stacked (K, E, F) features in ONE jitted dispatch
(``Predictor.on_windows`` — policy/validate under ``lax.scan``, K-leading
reward terms, replay appended via the scan-carried ``add_many``), and
Forwarders/DB take per-window batch calls (``dispatch_window`` /
``append_many``, one lock per call). Host-side consumers still see one
result row per window, in window order, bit-identical to the per-window
reference (``batched_consume=False``).

``mode="scan_sharded"`` is the same Manager loop with the device dispatch
executed under ``shard_map`` on an env-sharded mesh (envs -> the ``data``
axis, per-env state rows and batch rows split across devices; see
``core.pipeline.make_run_many_sharded``). Outputs are bit-identical to
``scan``; on one device the mesh degenerates to it. CPU multi-device
recipe: ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before JAX
initializes.

``mode="scan_async"`` (and ``"scan_async_sharded"``, which composes with
the env-sharded dispatch) pipelines host ingest against device compute: a
``runtime.prefetch.WindowPrefetcher`` pump thread assembles window batch
j+1 (clock advance -> receiver poll -> queue drain -> ``close_windows``)
while batch j executes on device via JAX async dispatch, and the Manager
blocks only at result consumption. The pump performs exactly the
clock-advance/poll/drain sequence the synchronous loop would at the same
window boundaries (the deterministic batch-epoch handoff), so outputs are
bit-identical to ``scan`` by construction.

``mode="scan_fused_decide"`` collapses the LAST dispatch boundary: the
Predictor's per-window step (policy gemm, ``validate_actions``, reward
terms, ``replay.add``) is traced INTO the pipeline scan body
(``core.pipeline.run_many_decide``), the decision state
(``predictor.DecideState``: prev obs/actions, have_prev, exact tick
counter, the replay ring) joins the pipeline state in one donated device
carry, and the whole ingest->decide->bank loop costs ONE device dispatch
per K-window batch. Consume only drains host sinks from the small
per-window outputs (actions, rewards, violation flags, exact per-env
observed/filled/anomalous counts); the (K, E, F) feature stack is fetched
only when a LogDB is attached, and the (K, E, S, T) frames never leave
the device. ``"scan_fused_decide_sharded"`` runs the fused scan under
``shard_map`` on the env mesh (decide carry sharded on the env dim,
policy weights replicated, scalars replicated — collective-free, so
bit-identical); ``"scan_fused_decide_async"`` /
``"scan_fused_decide_async_sharded"`` compose with the prefetcher (and,
like all async modes, do not donate). Accessor rules: the replay ring
lives in the donated carry, so read it ONLY through
``system.export_replay(salt)`` / ``snapshot_decide()`` /
``replay_size()`` — never through ``predictor.replay``, which is a stale
snapshot of construction time in these modes.

``scan_k="auto"`` runs ``core.autotune.tune_scan_params`` at construction:
a short measured grid over windows-per-dispatch x env-mesh split picks the
windows/s-optimal configuration for this host/device/shape (result kept on
``self.tuned``).

Device-visible time is WINDOW-RELATIVE (long-horizon float32 safety): the
Accumulator subtracts each window's start in float64 before the float32
cast and every pipeline dispatch receives ``window_start = 0``; absolute
float32 seconds would quantize sub-second deltas past t~2^24 s (~194 days
of stream time — minutes of wall time at high ``speedup``). The seasonal
tick-of-day phase survives via the exact integer ``PipelineConfig.tick0``
offset derived from ``t0``.

``train="online"`` (fused-decide modes only) attaches a
``runtime.trainer.OnlineTrainer``: one jitted sample+AdamW update per
K-window batch, enqueued right AFTER the fused decide dispatch so it runs
in the dispatch bubble while the host consumes. Policy hot-swaps happen
only at batch boundaries (``apply_pending`` swaps the carry's ``policy``/
``version`` leaves before the next dispatch), ``policy_version`` increments
monotonically per applied update, and every replay row / LogDB row is
stamped with the version that produced its action — so each K-batch is
attributable to exactly one policy. With training off (or an idle trainer
on an empty ring) the decide path is bit-identical to the plain fused
modes. Accessors: ``policy_version()``, ``snapshot_policy()``,
``train_stats()``.

``ingest="columnar"`` (the default) moves record flow onto the
structure-of-arrays fast path: Receivers hand whole polls to
``Translator.translate_batch`` which publishes one ``RecordBatch`` per
(source, env) poll, and the Accumulator buckets them with vectorized
NumPy (argsort/searchsorted) — no Python-level per-record loop anywhere
between the device simulator and the (K, E, S, M) device batch.
``ingest="records"`` keeps the per-payload Record path — the
wire-protocol-faithful baseline the benchmarks compare against. The two
paths produce identical windows for lossless codecs (mqtt json, amqp
doubles); the http CSV codec rounds values to 6 decimals on the wire, so
there the columnar path (which skips the encode/decode) is the
higher-fidelity one.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PerceptaPipeline, PipelineConfig
from repro.core.frame import make_raw_window
from repro.runtime.accumulator import Accumulator
from repro.runtime.forwarder import ForwarderHub
from repro.runtime.predictor import Predictor
from repro.runtime.prefetch import WindowPrefetcher
from repro.runtime.queues import QueueBroker
from repro.runtime.receivers import Receiver, SimulatedDevice
from repro.runtime.records import RecordBatch, count_records
from repro.runtime.translator import Translator

# Manager-loop mode -> device-pipeline mode: the async modes reuse the scan
# engines and differ only in how the Manager overlaps host assembly
_PIPELINE_MODE = {
    "scan_async": "scan",
    "scan_async_sharded": "scan_sharded",
    "scan_fused_decide_async": "scan_fused_decide",
    "scan_fused_decide_async_sharded": "scan_fused_decide_sharded",
}
_FUSED_DECIDE_MODES = ("scan_fused_decide", "scan_fused_decide_sharded",
                       "scan_fused_decide_async",
                       "scan_fused_decide_async_sharded")
_SCAN_MODES = ("scan", "scan_sharded", "scan_async",
               "scan_async_sharded") + _FUSED_DECIDE_MODES
_ASYNC_MODES = ("scan_async", "scan_async_sharded",
                "scan_fused_decide_async", "scan_fused_decide_async_sharded")
# pipeline modes whose dispatch runs under shard_map on the env mesh
_SHARDED_PIPE_MODES = ("scan_sharded", "scan_fused_decide_sharded")


@dataclass
class SourceSpec:
    source_id: str
    protocol: str                 # mqtt | http | amqp
    device: SimulatedDevice
    unit_scale: float = 1.0


class PerceptaSystem:
    def __init__(self, env_ids: Sequence[str], sources: Sequence[SourceSpec],
                 pipeline_cfg: PipelineConfig, predictor: Predictor,
                 forwarders: Optional[ForwarderHub] = None, db=None,
                 mode: str = "fused", speedup: float = 60.0,
                 t0: float = 0.0, manual_time: bool = False,
                 scan_k=8, ingest: str = "columnar",
                 autotune: Optional[dict] = None,
                 batched_consume: bool = True,
                 contract_check: bool = True,
                 train: Optional[str] = None,
                 train_cfg: Optional[dict] = None,
                 policy=None):
        # manual_time: the virtual clock only advances when run_windows
        # closes a window — deterministic under arbitrary jit-compile stalls
        # (tests); wall-clock speedup mode is the realistic deployment shape.
        self.manual_time = manual_time
        self._manual_t = t0
        assert pipeline_cfg.n_envs == len(env_ids)
        assert pipeline_cfg.n_streams == len(sources)
        self.env_ids = list(env_ids)
        self.sources = list(sources)
        # bake the absolute tick origin in (exact integer seasonal phase
        # under window-relative device timestamps; see core.pipeline)
        pipeline_cfg = dataclasses.replace(
            pipeline_cfg, tick0=int(round(t0 / pipeline_cfg.tick_s)))
        self.cfg = pipeline_cfg
        self.mode = mode
        pipe_mode = _PIPELINE_MODE.get(mode, mode)
        self.fused_decide = mode in _FUSED_DECIDE_MODES
        # policy: a registry name ("linear"|"mlp"|"rglru"|"rwkv6") or
        # runtime.policies.PolicyConfig — rebinds the predictor's model
        # through the certified registry (runtime.policies.build_policy),
        # so the adapter arrives with its PolicyCertificate attached
        if policy is not None:
            predictor.set_model(policy)
        # fused-decide: the decision step is traced into the pipeline scan
        # and the decision state (prev obs/actions, tick, replay ring)
        # becomes part of the device carry — the Predictor hands both over
        # here and only does host bookkeeping (absorb_fused) afterwards
        decide = predictor.make_decide_fn() if self.fused_decide else None
        self._dstate = predictor.decide_state() if self.fused_decide else None
        # construction-time invariant gate (ROADMAP item 2): statically
        # check the decision path's jaxpr BEFORE building/compiling the
        # engine, so a cross-env contraction (silent 1-ulp shard
        # divergence), a hidden host callback in the scan body, or a
        # float32 absolute-time cast fails registration with the offending
        # primitive + source line. Env-axis rules bind only under the
        # sharded dispatches (a fused non-sharded build may legally run a
        # non-row-wise model); contract_check=False skips the gate.
        self.contract_check = bool(contract_check)
        if self.contract_check and (self.fused_decide
                                    or pipe_mode in _SHARDED_PIPE_MODES):
            from repro import analysis
            # env rules bind only where the decision math itself runs
            # inside the env-sharded dispatch (fused+sharded); in plain
            # scan_sharded the Predictor consumes on the host, unsharded
            analysis.check_system(
                predictor, decide=decide, dstate=self._dstate,
                sharded=(self.fused_decide
                         and pipe_mode in _SHARDED_PIPE_MODES),
                label=f"PerceptaSystem(mode={mode!r})")
        # fused/sharded modes additionally demand a valid PolicyCertificate
        # for the model itself (repro.analysis.certify): registry policies
        # arrive with one attached (cached — repeated standups skip the
        # trace entirely); an ad-hoc adapter is certified here at the true
        # (E, F, A) shapes, with the env/carry families binding only under
        # the env-sharded dispatch (a fused non-sharded build may legally
        # run a non-row-wise model, e.g. examples/serve_edge.py's LM).
        self.policy_certificate = None
        if self.contract_check and self.fused_decide:
            cert = getattr(predictor.model, "certificate", None)
            if cert is None:
                from repro.analysis import certify
                sharded = pipe_mode in _SHARDED_PIPE_MODES
                cert = certify.certify_policy(
                    predictor.model,
                    ((predictor.n_envs, predictor.n_features,
                      predictor.action_space.n),),
                    name=getattr(predictor.model, "name", None),
                    rules=certify.Rules(env=sharded, collectives=True,
                                        callbacks=True, time=True,
                                        carry=sharded))
                predictor.model.certificate = cert
            self.policy_certificate = cert
        # predictor tick index of this system's window 0: export-time
        # reconstruction maps tick idx -> window (idx - base); ticks issued
        # BEFORE this system keep their host-mirror times
        self._tick_base = int(predictor.stats["ticks"])

        # scan_k="auto": short measured calibration grid over K x mesh split
        self.tuned = None
        mesh = None
        if scan_k == "auto":
            from repro.core.autotune import tune_scan_params
            from repro.distribution import sharding as shard_lib
            kw = dict(autotune or {})
            if pipe_mode not in _SHARDED_PIPE_MODES:
                # mesh splits only apply to the sharded dispatches
                kw.setdefault("device_counts", [1])
            if self.fused_decide:
                # tune the engine that will actually run: the fused scan
                # (pipeline tick + decision step in one dispatch)
                kw.setdefault("decide", decide)
                kw.setdefault("decide_state", self._dstate)
            self.tuned = tune_scan_params(pipeline_cfg, **kw)
            scan_k = self.tuned.scan_k
            if pipe_mode in _SHARDED_PIPE_MODES:
                # honor the measured split even when it is 1 device (the
                # mesh then degenerates to plain scan); leaving mesh=None
                # would silently shard over ALL devices instead
                mesh = shard_lib.env_mesh(
                    pipeline_cfg.n_envs,
                    devices=jax.devices()[:max(1, self.tuned.mesh_devices)])
        self.scan_k = max(1, int(scan_k))
        assert ingest in ("columnar", "records"), ingest
        self.ingest = ingest
        # scan-mode consume: one Predictor.on_windows dispatch per K-window
        # batch (default); False keeps the per-window on_tick loop — the
        # tested reference path the batched one must match bit for bit
        self.batched_consume = bool(batched_consume)
        # async modes must NOT donate: dispatching with a donated input that
        # is still being computed blocks the dispatch (and the pump thread
        # behind it), serializing the very batches the prefetcher overlaps.
        # Double-buffering two state pytrees is the async design anyway.
        self.pipeline = PerceptaPipeline(
            pipeline_cfg, mode=pipe_mode,
            donate=mode in ("scan", "scan_sharded", "scan_fused_decide",
                            "scan_fused_decide_sharded"),
            mesh=mesh, decide=decide, decide_state=self._dstate)
        self.state = self.pipeline.init_state()
        self._prefetcher: Optional[WindowPrefetcher] = None
        self.predictor = predictor
        # train="online": device-resident retraining interleaved with the
        # fused decide dispatches (runtime.trainer). The trainer needs the
        # decision state in the device carry, so it composes only with the
        # fused-decide modes; train_cfg kwargs pass through to OnlineTrainer
        # (batch_size, train_cfg, seed, checkpoint_dir, checkpoint_every).
        self.trainer = None
        if train is not None:
            if train != "online":
                raise ValueError(f"unknown train mode {train!r} "
                                 "(expected None or 'online')")
            if not self.fused_decide:
                raise ValueError(
                    "train='online' rides the fused decide carry: use a "
                    f"scan_fused_decide* mode, not {mode!r}")
            from repro.runtime.trainer import OnlineTrainer
            kw = dict(train_cfg or {})
            kw.setdefault("contract_check", self.contract_check)
            self.trainer = OnlineTrainer(predictor, **kw)
        self.forwarders = forwarders
        self.db = db
        self.speedup = speedup
        self._wall0 = time.time()
        self._t0 = t0
        self.window_s = pipeline_cfg.n_ticks * pipeline_cfg.tick_s
        self.window_index = 0

        self.broker = QueueBroker()
        self.translators = {
            s.source_id: Translator(s.source_id, s.protocol,
                                    unit_scale=s.unit_scale)
            for s in sources
        }
        self.receivers: List[Receiver] = []
        for s in sources:
            r = Receiver(s.source_id, s.protocol, s.device, self.now,
                         speedup=speedup)
            tr = self.translators[s.source_id]
            for env in env_ids:
                def on_payload(env_id, payload, _tr=tr):
                    rec = _tr.translate(env_id, payload)
                    if rec is not None:
                        self.broker.publish(rec)

                def on_batch(env_id, stream, ts, vals, _tr=tr):
                    batch = _tr.translate_batch(env_id, stream, ts, vals)
                    if batch is not None:
                        self.broker.publish(batch)

                if self.ingest == "columnar":
                    r.subscribe(env, on_batch=on_batch)
                else:
                    r.subscribe(env, on_payload)
            self.receivers.append(r)
        stream_names = [s.device.stream for s in sources]
        self.accumulators = {
            env: Accumulator(env, stream_names, pipeline_cfg.max_samples)
            for env in env_ids
        }
        self.metrics: Dict[str, list] = {"tick_latency_s": [],
                                         "ingest_records": []}

    # --- virtual clock -------------------------------------------------------
    def now(self) -> float:
        if self.manual_time:
            return self._manual_t
        return self._t0 + (time.time() - self._wall0) * self.speedup

    def window_bounds(self, index: Optional[int] = None):
        idx = self.window_index if index is None else index
        start = self._t0 + idx * self.window_s
        return start, start + self.window_s

    # --- threaded operation ---------------------------------------------------
    def start(self):
        for r in self.receivers:
            r.start()

    def stop(self):
        for r in self.receivers:
            r.stop()
        if self._prefetcher is not None:
            self._prefetcher.stop()
        if self.trainer is not None:
            self.trainer.close()

    # --- synchronous operation (benchmarks / tests) ---------------------------
    def pump_receivers(self):
        for r in self.receivers:
            r.poll_once()

    def run_window(self) -> dict:
        """Process one closed window across all environments."""
        t_start, t_end = self.window_bounds()
        E, S, M = self.cfg.n_envs, self.cfg.n_streams, self.cfg.max_samples

        n_new = 0
        for env in self.env_ids:
            recs = self.broker.queue_for(env).drain()
            n_new += count_records(recs)
            self.accumulators[env].ingest(recs)

        values = np.zeros((E, S, M), np.float32)
        ts = np.zeros((E, S, M), np.float32)
        valid = np.zeros((E, S, M), bool)
        for i, env in enumerate(self.env_ids):
            v, t, m = self.accumulators[env].close_window(t_start, t_end,
                                                          rebase=True)
            values[i], ts[i], valid[i] = v, t, m

        t_proc0 = time.time()
        raw = make_raw_window(values, ts, valid)
        # window-relative time: timestamps were rebased to this window's
        # start, so the device sees window_start = 0 (float32-exact on any
        # horizon); absolute time stays host-side (t_end below)
        self.state, feats, frame = self.pipeline.run_tick(
            self.state, raw, jnp.zeros((E,), jnp.float32))
        actions, rewards, per_term = self.predictor.on_tick(
            feats.features, t_end, raw=feats.raw)
        latency = time.time() - t_proc0

        if self.forwarders is not None:
            for i, env in enumerate(self.env_ids):
                self.forwarders.dispatch(env, t_end, actions[i])
        if self.db is not None:
            obs = np.asarray(feats.features)
            ver = int(self.predictor.policy_version)
            for i, env in enumerate(self.env_ids):
                self.db.append(env, t_end, obs[i], actions[i],
                               float(rewards[i]),
                               extra={"policy_version": ver})

        self.window_index += 1
        self.metrics["tick_latency_s"].append(latency)
        self.metrics["ingest_records"].append(n_new)
        return {
            "window": self.window_index - 1,
            "records": n_new,
            "latency_s": latency,
            "mean_reward": float(np.mean(rewards)),
            "observed_frac": float(np.asarray(frame.observed).mean()),
            "filled_frac": float(np.asarray(frame.filled).mean()),
            "anomalous": int(np.asarray(frame.anomalous).sum()),
        }

    # --- scan-fused operation --------------------------------------------------
    def assemble_windows(self, bounds) -> tuple:
        """Drain queues once and stack K closed windows per env.

        Returns ``(RawWindow with leading K axis, per_window_counts)`` where
        the counts attribute each drained record to the window whose bounds
        contain its timestamp (clipped to the batch, so the counts sum to
        the drain total — mirroring fused mode's per-window ingest numbers
        for consumers like dead-source detection). Per-env isolation is
        structural: each env's records flow queue -> its own Accumulator ->
        row i of every window in the stack; no cross-env array is ever
        indexed by more than one env.
        """
        E, S, M = self.cfg.n_envs, self.cfg.n_streams, self.cfg.max_samples
        K = len(bounds)
        counts_arr = np.zeros(K, np.int64)
        starts = np.asarray([b[0] for b in bounds], np.float64)
        for env in self.env_ids:
            recs = self.broker.queue_for(env).drain()
            scalar_ts = []        # one vectorized pass per drain, not per item
            for r in recs:
                if isinstance(r, RecordBatch):
                    j = np.searchsorted(starts, r.timestamps, side="right") - 1
                    counts_arr += np.bincount(np.clip(j, 0, K - 1),
                                              minlength=K)
                else:
                    scalar_ts.append(r.timestamp)
            if scalar_ts:
                j = np.searchsorted(starts, np.asarray(scalar_ts),
                                    side="right") - 1
                counts_arr += np.bincount(np.clip(j, 0, K - 1), minlength=K)
            self.accumulators[env].ingest(recs)
        counts = [int(c) for c in counts_arr]
        values = np.zeros((K, E, S, M), np.float32)
        ts = np.zeros((K, E, S, M), np.float32)
        valid = np.zeros((K, E, S, M), bool)
        for i, env in enumerate(self.env_ids):
            v, t, m = self.accumulators[env].close_windows(bounds,
                                                           rebase=True)
            values[:, i], ts[:, i], valid[:, i] = v, t, m
        return make_raw_window(values, ts, valid), counts

    def run_windows_scan(self, k: int) -> List[dict]:
        """Process the next ``k`` windows with ONE device dispatch."""
        bounds = [self.window_bounds(self.window_index + j) for j in range(k)]
        raw, counts = self.assemble_windows(bounds)
        if self.fused_decide:
            outs, t_dispatch, ver = self._dispatch_decide(raw, k)
            return self._consume_decide(bounds, counts, outs, t_dispatch, ver)
        feats, frames, t_dispatch = self._dispatch_scan(raw, k)
        return self._consume_scan(bounds, counts, feats, frames, t_dispatch)

    def _dispatch_scan(self, raw, k: int):
        """Launch ONE ``run_many`` over a staged K-window batch (no block:
        JAX async dispatch returns futures; consumption blocks)."""
        t_dispatch = time.time()
        # window-relative time: each window's samples were rebased to its
        # own start by close_windows, so every scan step sees start = 0
        starts = jnp.zeros((k, self.cfg.n_envs), jnp.float32)
        self.state, feats, frames = self.pipeline.run_many(
            self.state, raw, starts)
        return feats, frames, t_dispatch

    def _consume_scan(self, bounds, counts, feats, frames,
                      t_dispatch) -> List[dict]:
        """Block on a dispatched batch and run the batch host side
        (Predictor, Forwarders, DB, metrics) in window order.

        The Predictor consumes the whole K-window stack in ONE jitted
        dispatch (``on_windows`` over the stacked device features — the
        same fusion ``run_many`` applies to the pipeline, applied to the
        decision path), then the per-window loop only slices numpy for
        Forwarders/DB/metrics. ``batched_consume=False`` keeps the
        per-window ``on_tick`` loop as the tested reference; both paths
        are bit-identical (asserted in tests/test_predictor_batch.py).
        """
        k = len(bounds)
        if self.batched_consume:
            # feed the stacked DEVICE features straight into the predictor
            # scan — one dispatch, one host transfer per output leaf
            actions_b, rewards_b, _ = self.predictor.on_windows(
                feats.features, [b[1] for b in bounds], raw=feats.raw)
            batch_latency = time.time() - t_dispatch
        else:
            jax.block_until_ready(feats.features)
            batch_latency = time.time() - t_dispatch
            raw_np = np.asarray(feats.raw)

        out = []
        # one batch-wide host transfer per leaf; the per-window loop then
        # slices numpy — per-window DEVICE slicing (feats.features[j]) costs
        # two extra device dispatches per window and, in async mode, queues
        # them behind the next batch's scan
        feat_np = np.asarray(feats.features)
        obs_np = np.asarray(frames.observed)
        fill_np = np.asarray(frames.filled)
        anom_np = np.asarray(frames.anomalous)
        for j, (t_start, t_end) in enumerate(bounds):
            t_host0 = time.time()
            if self.batched_consume:
                actions, rewards = actions_b[j], rewards_b[j]
            else:
                # reference path: the per-window dispatch stays inside the
                # timed region so latency_s keeps counting Predictor time
                actions, rewards, _ = self.predictor.on_tick(
                    feat_np[j], t_end, raw=raw_np[j])
            if self.forwarders is not None:
                self.forwarders.dispatch_window(t_end, actions)
            if self.db is not None:
                self.db.append_many(self.env_ids, t_end, feat_np[j], actions,
                                    rewards,
                                    extra={"policy_version":
                                           int(self.predictor.policy_version)})
            self.window_index += 1
            # comparable to run_window's latency_s: amortized device +
            # predictor share of the batch plus this window's host work
            latency = batch_latency / k + (time.time() - t_host0)
            self.metrics["tick_latency_s"].append(latency)
            self.metrics["ingest_records"].append(counts[j])
            out.append({
                "window": self.window_index - 1,
                "records": counts[j],
                "latency_s": latency,
                "mean_reward": float(np.mean(rewards)),
                "observed_frac": float(obs_np[j].mean()),
                "filled_frac": float(fill_np[j].mean()),
                "anomalous": int(anom_np[j].sum()),
            })
        return out

    # --- fused-decide operation ------------------------------------------------
    def _dispatch_decide(self, raw, k: int):
        """Launch ONE fused pipeline+decision dispatch over a staged
        K-window batch: features flow straight into the policy/validate/
        reward/replay step inside the scan, and BOTH carries (pipeline
        state + decide state) stay device-resident (donated in the sync
        modes). No block — consumption blocks.

        With an attached trainer this is the batch boundary: the previous
        train step's result hot-swaps the carry's policy/version leaves
        BEFORE the dispatch (so the whole batch runs one policy), and a
        new train step enqueues right AFTER it (so it fills the dispatch
        bubble instead of delaying serving — the PR 3 priority-inversion
        lesson). Returns ``(outs, t_dispatch, policy_version)`` with the
        version that produced this batch's actions."""
        if self.trainer is not None:
            self._dstate = self.trainer.apply_pending(self._dstate)
        ver = int(self.predictor.policy_version)
        t_dispatch = time.time()
        starts = jnp.zeros((k, self.cfg.n_envs), jnp.float32)
        self.state, self._dstate, outs = self.pipeline.run_many_decide(
            self.state, self._dstate, raw, starts)
        if self.trainer is not None:
            self.trainer.dispatch(self._dstate)
        return outs, t_dispatch, ver

    def _consume_decide(self, bounds, counts, outs, t_dispatch,
                        version: int = 0) -> List[dict]:
        """Drain host sinks from the SMALL fused outputs.

        The host fetches only actions (K, E, A), rewards (K, E), violation
        flags and the per-env int32 observed/filled/anomalous counts — the
        (K, E, F) feature stack is fetched ONLY when a LogDB needs obs
        rows, and the (K, E, S, T) frames never leave the device (the
        fractions divide the exact counts, bit-identical to ``np.mean``
        over the full frame)."""
        k = len(bounds)
        actions_b = np.asarray(outs.actions)   # first fetch blocks the batch
        batch_latency = time.time() - t_dispatch
        rewards_b = np.asarray(outs.rewards)
        obs_c = np.asarray(outs.observed)
        fill_c = np.asarray(outs.filled)
        anom_c = np.asarray(outs.anomalous)
        feat_np = np.asarray(outs.features) if self.db is not None else None
        self.predictor.absorb_fused([b[1] for b in bounds],
                                    np.asarray(outs.violated))
        denom = float(self.cfg.n_envs * self.cfg.n_streams * self.cfg.n_ticks)
        out = []
        for j, (t_start, t_end) in enumerate(bounds):
            t_host0 = time.time()
            actions, rewards = actions_b[j], rewards_b[j]
            if self.forwarders is not None:
                self.forwarders.dispatch_window(t_end, actions)
            if self.db is not None:
                self.db.append_many(self.env_ids, t_end, feat_np[j], actions,
                                    rewards,
                                    extra={"policy_version": version})
            self.window_index += 1
            latency = batch_latency / k + (time.time() - t_host0)
            self.metrics["tick_latency_s"].append(latency)
            self.metrics["ingest_records"].append(counts[j])
            out.append({
                "window": self.window_index - 1,
                "records": counts[j],
                "latency_s": latency,
                "mean_reward": float(np.mean(rewards)),
                # exact integer counts / float64 size == np.mean over the
                # (E, S, T) bool frame, bit for bit
                "observed_frac": float(int(obs_c[j].sum()) / denom),
                "filled_frac": float(int(fill_c[j].sum()) / denom),
                "anomalous": int(anom_c[j].sum()),
            })
        return out

    def _dispatch_batch(self, batch):
        """Mode-dispatching async helper: launch one assembled batch and
        return the pending tuple ``_consume_batch`` expects."""
        k = len(batch.bounds)
        if self.fused_decide:
            outs, td, ver = self._dispatch_decide(batch.raw, k)
            return (batch.bounds, batch.counts, outs, td, ver)
        feats, frames, td = self._dispatch_scan(batch.raw, k)
        return (batch.bounds, batch.counts, feats, frames, td)

    def _consume_batch(self, pending) -> List[dict]:
        if self.fused_decide:
            return self._consume_decide(*pending)
        return self._consume_scan(*pending)

    def _advance_clock(self, t_end: float):
        if self.manual_time:
            self._manual_t = t_end + 1e-3
        else:
            while self.now() < t_end:
                time.sleep(0.001)

    # --- donation-safe state access -------------------------------------------
    def snapshot_state(self):
        """Deep copy of the pipeline state pytree, safe to hold across windows.

        ``scan``/``scan_sharded`` donate the state buffers into every
        ``run_many`` dispatch, so a bare ``system.state.<leaf>`` reference
        becomes invalid after the next window batch; this accessor hands out
        copies so callers never have to reason about donation.
        """
        return jax.tree.map(lambda x: jnp.array(x, copy=True), self.state)

    def snapshot_norm(self):
        """Donation-safe copy of just the normalizer stats (NormState)."""
        return jax.tree.map(lambda x: jnp.array(x, copy=True),
                            self.state.norm)

    def snapshot_decide(self):
        """Deep copy of the fused decision carry (``DecideState``), safe to
        hold across window batches. Fused-decide modes donate the carry —
        including the replay ring — into every dispatch, so bare
        ``system._dstate`` leaf references become invalid after the next
        batch; this is the replay-path twin of :meth:`snapshot_state`."""
        assert self.fused_decide, "snapshot_decide: not a fused-decide mode"
        return jax.tree.map(lambda x: jnp.array(x, copy=True), self._dstate)

    def replay_size(self) -> int:
        """Live transition count of the replay ring, any mode."""
        buf = (self._dstate.replay if self.fused_decide
               else self.predictor.replay)
        return min(int(buf.cursor), buf.capacity)

    def policy_version(self) -> int:
        """Current monotone policy version (0 until a train step applies).

        Every replay row and LogDB row carries the version that produced
        its action, so exports are attributable per row; swaps land only
        at batch boundaries, so all K windows of a batch share one
        version."""
        return int(self.predictor.policy_version)

    def snapshot_policy(self):
        """Donation-safe copy of the LIVE policy params (the device carry's
        ``policy`` leaves in fused-decide modes, the predictor's host
        mirror otherwise)."""
        src = (self._dstate.policy if self.fused_decide
               else self.predictor.policy_params)
        return jax.tree.map(lambda x: jnp.array(x, copy=True), src)

    def train_stats(self) -> Optional[dict]:
        """Trainer counters (dispatched/applied/skipped_empty, last loss
        and grad norm, current version); None when training is off."""
        return None if self.trainer is None else self.trainer.train_stats()

    def restore_training(self):
        """Crash recovery: restore the newest trainer checkpoint into the
        LIVE serving path — trainer state, predictor host mirror, AND the
        device carry's policy/version leaves (``trainer.restore_latest``
        alone only covers the host side; the carry would keep serving the
        construction-time weights). Returns ``(step, params, extra)`` or
        ``None`` when no checkpoint exists."""
        if self.trainer is None:
            raise ValueError("restore_training: system built without "
                             "train='online'")
        out = self.trainer.restore_latest()
        if out is None:
            return None
        _, params, _ = out
        self._dstate = self._dstate._replace(
            policy=jax.tree.map(jnp.asarray, params),
            version=jnp.asarray(self.trainer.version, jnp.int32))
        return out

    def export_replay(self, salt: str) -> dict:
        """Anonymized chronological replay export, any mode.

        Non-fused modes delegate to ``Predictor.export_replay`` (host
        float64 mirror re-attached). Fused-decide modes snapshot the
        device carry WITHOUT donating it and reconstruct the exact float64
        absolute time of every system-era transition from its stored int32
        tick index: tick ``idx`` is this system's window ``idx - base``
        (``base`` = the predictor's tick count at construction), and
        windows are consecutive by construction, so it ended at
        ``(t0 + (idx - base) * window_s) + window_s`` — evaluated in
        float64 with exactly :meth:`window_bounds`' operation order, which
        makes the reconstruction bit-identical to the mirror the per-step
        paths maintain. Slots written BEFORE this system existed (a
        Predictor with prior ``on_tick``/``on_windows`` history) keep
        their host-mirror times — their windows were not this system's."""
        if not self.fused_decide:
            return self.predictor.export_replay(self.env_ids, salt)
        from repro.core import replay as rp
        buf = self.snapshot_decide().replay
        # every env row shares the batch-wide tick index, so row 0 carries
        # the slot-aligned index ring; dead slots are never selected by the
        # export's chronological order
        idx_i = np.asarray(buf.tick_idx[0])
        idx = (idx_i - self._tick_base).astype(np.float64)
        recon = (self._t0 + idx * self.window_s) + self.window_s
        slot_times = np.where(idx_i >= self._tick_base, recon,
                              self.predictor._replay_times)
        return rp.export_for_training(buf, self.env_ids, salt,
                                      slot_times=slot_times)

    def run_windows(self, n: int, pump: bool = True) -> List[dict]:
        if self.mode in _ASYNC_MODES:
            return self._run_windows_async(n, pump)
        if self.mode in _SCAN_MODES:
            out: List[dict] = []
            while len(out) < n:
                k = min(self.scan_k, n - len(out))
                if pump:
                    # advance past the LAST window of the batch so every
                    # window's samples exist before the single drain
                    t_end = self.window_bounds(self.window_index + k - 1)[1]
                    self._advance_clock(t_end)
                    self.pump_receivers()
                out.extend(self.run_windows_scan(k))
            return out
        out = []
        for _ in range(n):
            if pump:
                # synchronous mode: advance the virtual clock past the window
                # end, then poll every receiver once
                self._advance_clock(self.window_bounds()[1])
                self.pump_receivers()
            out.append(self.run_window())
        return out

    # --- pipelined (async) operation ------------------------------------------
    def _assemble_for_prefetch(self, bounds, pump: bool):
        """Pump-thread body: exactly the synchronous per-batch sequence
        (clock advance -> receiver poll -> drain/close) at the same window
        boundaries — the deterministic handoff that makes ``scan_async``
        bit-identical to ``scan``."""
        if pump:
            self._advance_clock(bounds[-1][1])
            self.pump_receivers()
        return self.assemble_windows(bounds)

    def _run_windows_async(self, n: int, pump: bool = True) -> List[dict]:
        """Double-buffered Manager loop: while batch j runs on device, the
        pump thread assembles batch j+1 and the host consumes batch j-1.

        Batch boundaries (``min(scan_k, remaining)``) match the synchronous
        scan loop exactly, so the drain epochs — and therefore the outputs —
        are identical."""
        if self._prefetcher is None:
            self._prefetcher = WindowPrefetcher(self._assemble_for_prefetch)
        plans, idx, left = [], self.window_index, n
        while left > 0:
            k = min(self.scan_k, left)
            plans.append([self.window_bounds(idx + j) for j in range(k)])
            idx, left = idx + k, left - k
        for bounds in plans:
            self._prefetcher.submit(bounds, pump=pump)

        out: List[dict] = []
        pending = None
        for _ in plans:
            batch = self._prefetcher.next_batch()
            # consume j-1 BEFORE dispatching j: the Predictor's per-window
            # steps are device computations too, and the single device
            # executes its queue in order — dispatching batch j first would
            # make window j-1's small steps wait behind batch j's big scan
            # (a priority inversion that serializes the whole loop). In the
            # fused-decide composition consume is pure host-sink draining,
            # so the order only matters for result sequencing there.
            if pending is not None:
                out.extend(self._consume_batch(pending))
            pending = self._dispatch_batch(batch)
        out.extend(self._consume_batch(pending))
        return out

    def stats(self) -> dict:
        return {
            "queues": self.broker.stats(),
            "receivers": {r.source_id: r.stats for r in self.receivers},
            "translators": {t.source_id: t.stats
                            for t in self.translators.values()},
            "predictor": self.predictor.stats,
        }

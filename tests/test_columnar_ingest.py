"""Columnar (RecordBatch) ingest: bit-for-bit parity with the per-record
Record-list path — same windows, same overflow/unknown-stream stats — under
out-of-order timestamps, cross-window-boundary records, and overflow."""
import numpy as np
import pytest

from repro.runtime.accumulator import Accumulator
from repro.runtime.queues import QueueBroker
from repro.runtime.records import Record, RecordBatch, count_records

STREAMS = ["grid_kw", "temp_c", "price"]
BOUNDS = [(0.0, 100.0), (100.0, 200.0), (200.0, 300.0)]


def _reference_close(records, streams, max_samples, bounds):
    """The seed's per-record close loop, as the parity oracle."""
    pending = {s: [] for s in range(len(streams))}
    idx = {s: i for i, s in enumerate(streams)}
    stats = {"records": 0, "unknown_stream": 0, "overflow": 0}
    for r in records:
        i = idx.get(r.stream)
        if i is None:
            stats["unknown_stream"] += 1
            continue
        stats["records"] += 1
        pending[i].append(r)
    K, S, M = len(bounds), len(streams), max_samples
    values = np.zeros((K, S, M), np.float32)
    ts = np.zeros((K, S, M), np.float32)
    valid = np.zeros((K, S, M), bool)
    for k, (t0, t1) in enumerate(bounds):
        for s in range(S):
            take = [r for r in pending[s] if r.timestamp < t1]
            pending[s] = [r for r in pending[s] if r.timestamp >= t1]
            take.sort(key=lambda r: r.timestamp)
            if len(take) > M:
                stats["overflow"] += len(take) - M
                take = take[-M:]
            for j, r in enumerate(take):
                values[k, s, j] = r.value
                ts[k, s, j] = r.timestamp
                valid[k, s, j] = r.timestamp >= t0
    return (values, ts, valid), stats


def _records(rng, n=120, max_t=350.0, unknown_frac=0.1):
    """Out-of-order records crossing every window boundary, some stale
    (< first window start would need negatives — use dups near edges),
    some for streams the accumulator doesn't know."""
    names = STREAMS + ["rogue_stream"]
    out = []
    for i in range(n):
        s = names[rng.randint(len(names) if rng.rand() < unknown_frac
                              else len(STREAMS))]
        t = float(rng.uniform(0, max_t))
        if i % 17 == 0:     # exact-boundary ties, incl. the t_end edge
            t = float(BOUNDS[i % 3][1])
        out.append(Record("env", s, t, float(rng.normal(5, 2))))
    return out


@pytest.mark.parametrize("max_samples", [4, 16])  # 4 forces overflow
def test_batch_equals_record_list_bit_for_bit(rng, max_samples):
    recs = _records(rng)
    a = Accumulator("env", STREAMS, max_samples)
    b = Accumulator("env", STREAMS, max_samples)
    a.ingest(recs)
    b.ingest_batch(RecordBatch.from_records(recs))
    ra = a.close_windows(BOUNDS)
    rb = b.close_windows(BOUNDS)
    for x, y in zip(ra, rb):
        assert x.dtype == y.dtype and (x == y).all()
    assert a.stats == b.stats
    (ref, ref_stats) = _reference_close(recs, STREAMS, max_samples, BOUNDS)
    for x, y in zip(ra, ref):
        assert (x == y).all()
    assert a.stats == ref_stats


def test_batch_round_trip(rng):
    recs = _records(rng, n=40)
    batch = RecordBatch.from_records(recs)
    assert len(batch) == 40
    assert batch.to_records() == recs
    # single-stream constructor
    b2 = RecordBatch.from_columns("env", "grid_kw", [1.0, 2.0], [3.0, 4.0])
    assert b2.to_records() == [Record("env", "grid_kw", 1.0, 3.0),
                               Record("env", "grid_kw", 2.0, 4.0)]


def test_stale_and_future_records(rng):
    """Stale records occupy slots but are invalid; future ones stay pending
    — identically on both paths."""
    recs = [Record("env", "grid_kw", t, float(i))
            for i, t in enumerate([150.0, 50.0, 250.0, 310.0, 99.999])]
    a = Accumulator("env", STREAMS, 8)
    b = Accumulator("env", STREAMS, 8)
    a.ingest(recs)
    b.ingest_batch(RecordBatch.from_records(recs))
    for x, y in zip(a.close_windows(BOUNDS), b.close_windows(BOUNDS)):
        assert (x == y).all()
    # ts=310 is beyond the last bound: retained for the next close
    for acc in (a, b):
        v, t, m = acc.close_window(300.0, 400.0)
        assert m[0].sum() == 1 and t[0, 0] == np.float32(310.0)


def test_interleaved_mixed_queue_items(rng):
    """A drain mixing Records and RecordBatches keeps arrival order."""
    broker = QueueBroker()
    broker.publish(Record("e", "grid_kw", 10.0, 1.0))
    broker.publish(RecordBatch.from_columns("e", "temp_c", [20.0, 30.0],
                                            [2.0, 3.0]))
    broker.publish(Record("e", "price", 40.0, 4.0))
    items = broker.queue_for("e").drain()
    assert count_records(items) == 4
    assert broker.queue_for("e").stats["enqueued"] == 4
    assert broker.queue_for("e").stats["dequeued"] == 4
    acc = Accumulator("e", STREAMS, 8)
    acc.ingest(items)
    ref = Accumulator("e", STREAMS, 8)
    ref.ingest([Record("e", "grid_kw", 10.0, 1.0),
                Record("e", "temp_c", 20.0, 2.0),
                Record("e", "temp_c", 30.0, 3.0),
                Record("e", "price", 40.0, 4.0)])
    for x, y in zip(acc.close_windows(BOUNDS), ref.close_windows(BOUNDS)):
        assert (x == y).all()
    assert acc.stats == ref.stats


def test_unknown_streams_in_batch():
    acc = Accumulator("e", STREAMS, 8)
    batch = RecordBatch("e", ("grid_kw", "nope"),
                        np.array([0, 1, 1, 0], np.int32),
                        np.array([1.0, 2.0, 3.0, 4.0]),
                        np.array([1.0, 2.0, 3.0, 4.0]))
    acc.ingest_batch(batch)
    assert acc.stats["records"] == 2
    assert acc.stats["unknown_stream"] == 2
    v, t, m = acc.close_window(0.0, 10.0)
    assert m[0].sum() == 2 and m[1:].sum() == 0


def test_timestamp_tie_breaking_matches(rng):
    """Equal timestamps keep arrival order on both paths (stable sorts)."""
    recs = [Record("env", "grid_kw", 50.0, float(i)) for i in range(6)]
    a = Accumulator("env", STREAMS, 8)
    b = Accumulator("env", STREAMS, 8)
    a.ingest(recs)
    b.ingest_batch(RecordBatch.from_records(recs))
    va, ta, ma = a.close_window(0.0, 100.0)
    vb, tb, mb = b.close_window(0.0, 100.0)
    assert (va == vb).all() and (va[0, :6] == np.arange(6)).all()


def test_columnar_system_equals_record_system():
    """Full system: ingest="columnar" == ingest="records" bit-for-bit.

    Uses the lossless wire codecs (mqtt json / amqp doubles): the http CSV
    codec rounds values to 6 decimals ON THE WIRE, so for http sources the
    per-payload path delivers quantized floats and the columnar path is the
    *higher-fidelity* one — equality there is wire-format loss, not an
    ingest-path property."""
    from repro.core import PipelineConfig
    from repro.core.reward import energy_reward_spec
    from repro.runtime.predictor import ActionSpace, Predictor, linear_policy
    from repro.runtime.receivers import SimulatedDevice
    from repro.runtime.system import PerceptaSystem, SourceSpec

    def mk(ingest):
        srcs = [SourceSpec("meter", "mqtt",
                           SimulatedDevice("grid_kw", 60.0, base=3.0, seed=1)),
                SourceSpec("price", "amqp",
                           SimulatedDevice("price", 300.0, base=0.2,
                                           amplitude=0.05, seed=2))]
        cfg = PipelineConfig(n_envs=2, n_streams=2, n_ticks=8, tick_s=60.0,
                             max_samples=32)
        pred = Predictor(
            linear_policy(2, 2),
            energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
            ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
            2, cfg.n_features, replay_capacity=64)
        return PerceptaSystem(["b0", "b1"], srcs, cfg, pred, speedup=5000.0,
                              manual_time=True, mode="scan", scan_k=3,
                              ingest=ingest)

    ra = mk("records").run_windows(6)
    rb = mk("columnar").run_windows(6)
    for x, y in zip(ra, rb):
        assert x["records"] == y["records"]
        assert x["mean_reward"] == y["mean_reward"]
        assert x["observed_frac"] == y["observed_frac"]
        assert x["anomalous"] == y["anomalous"]

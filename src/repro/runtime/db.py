"""Append-only log store — "All input data and model decisions are also
logged in a database, enabling future analysis and potential retraining."

JSONL segments with atomic rotation; env identities are stored anonymized
(salted hash, pseudonyms cached in a bounded LRU — ``anon_cache_size``
caps host memory under high-cardinality env ids; eviction only costs a
re-hash on the next append) per the paper's anonymization requirement.
A cursor (segment, offset) is exposed so the training node can consume
exactly-once.

Absolute times are float64 host values end-to-end here (the ``t`` column) —
the device-side replay ring stores int32 tick indices instead (see
``core.replay``); this log is where exact wall-clock time is preserved.

Accounting rules: ``stats["segments"]`` counts segments CREATED by this
instance (reopening an existing segment after ``close()`` or process
restart does not re-count), and rotation is driven by an explicitly
tracked byte total per segment — never ``tell()`` on the line-buffered
text handle, whose cookie is not a byte count on text streams.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import OrderedDict
from pathlib import Path
from typing import Iterator, Optional

from repro.core.replay import anonymize_env_ids


class LogDB:
    def __init__(self, root: str, salt: str = "percepta",
                 rotate_bytes: int = 8 * 2**20,
                 anon_cache_size: int = 4096):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.salt = salt
        self.rotate_bytes = rotate_bytes
        self._lock = threading.Lock()
        self._seg = self._latest_segment()
        self._fh = None
        self._seg_bytes = 0
        assert anon_cache_size >= 1, anon_cache_size
        self.anon_cache_size = int(anon_cache_size)
        self._anon_cache: OrderedDict = OrderedDict()
        # rows are encoded OUTSIDE the write lock (append_many), so the
        # LRU needs its own guard: a get/evict race on the shared
        # OrderedDict could move_to_end an already-evicted key
        self._anon_lock = threading.Lock()
        self.stats = {"rows": 0, "bytes": 0, "segments": 0}

    def _latest_segment(self) -> int:
        segs = sorted(self.root.glob("seg-*.jsonl"))
        return int(segs[-1].stem.split("-")[1]) if segs else 0

    def _anon(self, env_id: str) -> str:
        """Pseudonym lookup through the bounded LRU (hash is pure, so an
        evicted id simply re-hashes to the same pseudonym later)."""
        cache = self._anon_cache
        with self._anon_lock:
            p = cache.get(env_id)
            if p is not None:
                cache.move_to_end(env_id)
                return p
        p = anonymize_env_ids([env_id], self.salt)[0]   # hash outside lock
        with self._anon_lock:
            cache[env_id] = p
            if len(cache) > self.anon_cache_size:
                cache.popitem(last=False)      # evict least recently used
        return p

    def _open(self):
        if self._fh is None:
            path = self.root / f"seg-{self._seg:06d}.jsonl"
            fresh = not path.exists()
            self._fh = open(path, "a", buffering=1)
            # resume the byte count from disk when reopening an existing
            # segment so rotation still triggers at the true size
            self._seg_bytes = 0 if fresh else path.stat().st_size
            if fresh:
                self.stats["segments"] += 1

    def _write_locked(self, lines) -> None:
        """Caller holds the lock: write rows, account bytes, rotate once."""
        self._open()
        self._fh.write("".join(l + "\n" for l in lines))
        nb = sum(len(l) + 1 for l in lines)
        self.stats["rows"] += len(lines)
        self.stats["bytes"] += nb
        self._seg_bytes += nb
        if self._seg_bytes > self.rotate_bytes:
            self._fh.close()
            self._fh = None
            self._seg += 1

    def _row(self, env_id, tick_time, obs, action, reward, extra):
        row = {
            "env": self._anon(env_id),
            "t": float(tick_time),
            "obs": [float(x) for x in obs],
            "action": [float(x) for x in action],
            "reward": float(reward),
            "logged_at": time.time(),
        }
        if extra:
            row.update(extra)
        return json.dumps(row)

    def append(self, env_id: str, tick_time: float, obs, action, reward,
               extra: Optional[dict] = None):
        line = self._row(env_id, tick_time, obs, action, reward, extra)
        with self._lock:
            self._write_locked([line])

    def append_many(self, env_ids, tick_time: float, obs, actions, rewards,
                    extra: Optional[dict] = None):
        """One window across all envs in a single call: rows are encoded up
        front, the lock is taken ONCE, and rotation is checked once per
        batch (a segment may overshoot ``rotate_bytes`` by at most one
        batch). This is the batched-consume path's DB write — the host loop
        shrinks with the device loop."""
        lines = [self._row(env_id, tick_time, o, a, r, extra)
                 for env_id, o, a, r in zip(env_ids, obs, actions, rewards)]
        if not lines:
            return
        with self._lock:
            self._write_locked(lines)

    def read_from(self, segment: int = 0, offset: int = 0) -> Iterator[tuple]:
        """Yield (cursor, row) from the given cursor for retraining export."""
        for path in sorted(self.root.glob("seg-*.jsonl")):
            seg = int(path.stem.split("-")[1])
            if seg < segment:
                continue
            with open(path) as fh:
                for i, line in enumerate(fh):
                    if seg == segment and i < offset:
                        continue
                    yield (seg, i + 1), json.loads(line)

    def close(self):
        with self._lock:
            if self._fh:
                self._fh.close()
                self._fh = None

"""Property-test support: hypothesis when installed, deterministic shim else.

The tier-1 suite has property tests (random elastic-membership schedules,
sorted-merge vs lexsort bucketing parity) that should run EVERYWHERE — but
``hypothesis`` is a dev extra some deployment images lack. Importing
``given`` / ``settings`` / ``st`` from here gives tests the real library
when it is installed and otherwise a small deterministic stand-in that
draws ``max_examples`` pseudo-random examples from a seed derived from the
test's qualified name — every run samples the same examples, so a failure
reproduces without example databases or shrinking.

The shim implements only the subset this suite uses (``st.integers``,
``st.lists``, ``st.sampled_from``, ``st.booleans``, ``st.floats``,
``@given`` with keyword strategies, ``@settings(max_examples, deadline)``)
and intentionally nothing more: richer property tests that need real
hypothesis features should keep ``pytest.importorskip("hypothesis")``.
"""
from __future__ import annotations

try:                                        # pragma: no cover - env dependent
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A draw function ``random.Random -> value``."""

        def __init__(self, draw):
            self.draw = draw

    class _strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def floats(min_value: float, max_value: float) -> _Strategy:
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            elems = list(elements)
            return _Strategy(lambda rng: elems[rng.randrange(len(elems))])

        @staticmethod
        def lists(elem: _Strategy, min_size: int = 0,
                  max_size: int = 10) -> _Strategy:
            return _Strategy(
                lambda rng: [elem.draw(rng)
                             for _ in range(rng.randint(min_size,
                                                        max_size))])

    st = _strategies()

    def settings(max_examples: int = 16, deadline=None, **_ignored):
        """Record the example budget on the test (order-independent with
        ``@given`` — ``functools.wraps`` carries the attribute outward)."""
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            drawn_names = set(strategies)

            @functools.wraps(fn)
            def runner(*args, **kwargs):
                n = int(getattr(runner, "_shim_max_examples", 16))
                seed = zlib.crc32(fn.__qualname__.encode())
                for i in range(n):
                    rng = random.Random(seed * 1_000_003 + i)
                    example = {k: s.draw(rng)
                               for k, s in strategies.items()}
                    try:
                        fn(*args, **{**kwargs, **example})
                    except BaseException as e:
                        e.args = (f"falsifying example ({i + 1}/{n}): "
                                  f"{example!r}",) + e.args
                        raise

            # pytest must not see the strategy-drawn params as fixtures
            sig = inspect.signature(fn)
            runner.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in drawn_names])
            return runner
        return deco

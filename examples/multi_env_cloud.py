"""Cloud deployment: one Percepta instance serving MANY isolated
environments simultaneously (paper §III.B/C) — scaling sweep with per-env
latency, demonstrating that environments are rows of one SPMD tick.

Run: PYTHONPATH=src python examples/multi_env_cloud.py
"""
import time

import numpy as np

from repro.core import PipelineConfig
from repro.core.reward import energy_reward_spec
from repro.runtime.predictor import ActionSpace, Predictor, linear_policy
from repro.runtime.receivers import SimulatedDevice
from repro.runtime.system import PerceptaSystem, SourceSpec

print("=== Percepta cloud mode: environment-count scaling ===")
print(f"{'envs':>6s} {'tick ms':>9s} {'us/env':>8s} {'env-ticks/s':>12s}")

for E in (1, 8, 64, 256):  # add 1024+ on a real host (1-core CI budget here)
    sources = [
        SourceSpec("meter", "mqtt", SimulatedDevice("grid_kw", 60.0,
                                                    base=3.0, seed=1)),
        SourceSpec("price", "http", SimulatedDevice("price", 300.0, base=0.2,
                                                    amplitude=0.05, seed=2)),
        SourceSpec("thermo", "amqp", SimulatedDevice("temp_c", 30.0,
                                                     base=21.0, seed=3)),
    ]
    pcfg = PipelineConfig(n_envs=E, n_streams=3, n_ticks=8, tick_s=60.0,
                          max_samples=16)
    pred = Predictor(linear_policy(3, 2),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=2),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     E, pcfg.n_features, replay_capacity=8)
    sys_ = PerceptaSystem([f"b{i}" for i in range(E)], sources, pcfg, pred,
                          speedup=50000.0)
    sys_.run_windows(1)            # compile + warm
    res = sys_.run_windows(2)
    lat = np.mean([r["latency_s"] for r in res])
    print(f"{E:6d} {lat*1e3:9.2f} {lat/E*1e6:8.1f} {E/lat:12.0f}")

print("\nisolation: each env keeps its own queue/accumulator/state row;"
      "\nthe batched tick scales sub-linearly in env count (SPMD rows).")

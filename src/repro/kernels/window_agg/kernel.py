"""Pallas TPU kernel: fused window statistics + anomaly mask.

This is the Manager's hot loop at fleet scale — thousands of environments x
streams per tick. One VMEM pass over each (row, tick) tile produces all
eight statistics AND the spike mask, instead of the eight separate
reductions (8x HBM reads) the unfused pipeline issues.

Layout: rows = E*S flattened, ticks padded to the 128-lane boundary. Blocks
are (ROWS_BLK, T_pad) in VMEM; the stats output is (ROWS_BLK, 128) with the
first N_STATS lanes used (TPU stores need full lanes — documented waste).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.window_agg.ref import N_STATS

ROWS_BLK = 8
LANES = 128


def _kernel(values_ref, mask_ref, mean_ref, var_ref, stats_ref, spikes_ref,
            *, k_sigma: float):
    v = values_ref[...].astype(jnp.float32)          # (R, T)
    m = mask_ref[...] > 0
    w = m.astype(jnp.float32)
    n = w.sum(-1)
    s = (v * w).sum(-1)
    mean = s / jnp.maximum(n, 1.0)
    var = (jnp.square(v - mean[:, None]) * w).sum(-1) / jnp.maximum(n, 1.0)
    big = jnp.float32(3.4e38)
    vmin = jnp.where(n > 0, jnp.min(jnp.where(m, v, big), -1), 0.0)
    vmax = jnp.where(n > 0, jnp.max(jnp.where(m, v, -big), -1), 0.0)
    T = v.shape[-1]
    tick_idx = jax.lax.broadcasted_iota(jnp.int32, v.shape, 1)
    idx = jnp.max(jnp.where(m, tick_idx, -1), -1)
    onehot = (tick_idx == idx[:, None]) & m
    last = (v * onehot.astype(jnp.float32)).sum(-1)

    # state refs are (R, 1) blocks — broadcast directly against (R, T)
    sigma = jnp.sqrt(jnp.maximum(var_ref[...].astype(jnp.float32), 1e-12))
    z = jnp.abs(v - mean_ref[...].astype(jnp.float32)) / sigma
    spikes = m & (z > k_sigma)
    spikes_ref[...] = spikes.astype(jnp.float32)

    cols = jax.lax.broadcasted_iota(jnp.int32, (v.shape[0], LANES), 1)
    stat_rows = [mean, var, vmin, vmax, last, n, s,
                 spikes.sum(-1).astype(jnp.float32)]
    out = jnp.zeros((v.shape[0], LANES), jnp.float32)
    for i, sr in enumerate(stat_rows):
        out = jnp.where(cols == i, sr[:, None], out)
    stats_ref[...] = out


def window_agg_pallas(values, mask, state_mean, state_var, *,
                      k_sigma: float = 6.0, interpret: bool = True):
    """values/mask: (R, T); state_mean/var: (R, 1) f32 (lane-padded)."""
    R, T = values.shape
    assert R % ROWS_BLK == 0, R
    grid = (R // ROWS_BLK,)
    kern = functools.partial(_kernel, k_sigma=k_sigma)
    stats, spikes = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS_BLK, T), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_BLK, T), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_BLK, 1), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_BLK, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ROWS_BLK, LANES), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_BLK, T), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, LANES), jnp.float32),
            jax.ShapeDtypeStruct((R, T), jnp.float32),
        ],
        interpret=interpret,
    )(values, mask, state_mean, state_var)
    return stats[:, :N_STATS], spikes > 0

"""Device-resident decision path (``mode="scan_fused_decide"``).

The fused engine runs pipeline tick + policy + validation + reward +
replay write in ONE ``lax.scan`` dispatch per K-window batch, carrying
``(PipelineState, DecideState)`` as a single donated (and, sharded,
env-split) pytree. Everything the host can observe — window results,
forwarder sinks, DB rows, predictor stats, the replay export — must be
bit-identical to the PR 4 two-dispatch reference (``mode="scan"`` with
``batched_consume=True``), across batch splits, replay-ring wraparound,
1- and 8-device meshes, large E, and long horizons (t0 = 2^24 with the
float64 time reconstruction from exact int32 tick indices).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PipelineConfig
from repro.core import pipeline as pl
from repro.core.frame import make_raw_window
from repro.core.reward import energy_reward_spec
from repro.runtime.db import LogDB
from repro.runtime.forwarder import Forwarder, ForwarderHub
from repro.runtime.predictor import ActionSpace, Predictor, linear_policy
from repro.runtime.receivers import SimulatedDevice
from repro.runtime.system import PerceptaSystem, SourceSpec

T0_FAR = float(2 ** 24)     # float32 absolute seconds quantize to >=2s here

FUSED_MODES = ("scan_fused_decide", "scan_fused_decide_sharded",
               "scan_fused_decide_async", "scan_fused_decide_async_sharded")


def _system(mode, scan_k=3, cap=16, tmp_db=None, t0=0.0, tick_s=60.0,
            forwarders=True, **kw):
    srcs = [
        SourceSpec("meter", "mqtt", SimulatedDevice("grid_kw", 60.0,
                                                    base=3.0, seed=1)),
        SourceSpec("price", "http", SimulatedDevice("price_eur", 300.0,
                                                    base=0.2, amplitude=0.05,
                                                    seed=2)),
    ]
    cfg = PipelineConfig(n_envs=2, n_streams=2, n_ticks=8, tick_s=tick_s,
                         max_samples=32)
    pred = Predictor(linear_policy(2, 2),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     2, cfg.n_features, replay_capacity=cap)
    hub = ForwarderHub([Forwarder("hvac", "mqtt", [0]),
                        Forwarder("ev", "amqp", [1])]) if forwarders else None
    db = LogDB(tmp_db, salt="x") if tmp_db else None
    return PerceptaSystem(["bldg-0", "bldg-1"], srcs, cfg, pred,
                          forwarders=hub, db=db, speedup=5000.0,
                          manual_time=True, mode=mode, scan_k=scan_k,
                          t0=t0, **kw)


def _strip(results):
    return [{k: v for k, v in r.items() if k != "latency_s"}
            for r in results]


def _rows(db):
    return [{k: v for k, v in row.items() if k != "logged_at"}
            for _, row in db.read_from()]


def _assert_export_equal(a: dict, b: dict):
    assert a["env_ids"] == b["env_ids"]
    for k in ("obs", "actions", "rewards", "next_obs", "tick_idx", "times"):
        assert (np.asarray(a[k]) == np.asarray(b[k])).all(), k


# --------------------------------------------------------------------------
# System level: every composing mode == the PR 4 batched-consume reference
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", FUSED_MODES)
def test_fused_decide_matches_batched_consume_reference(mode, tmp_path):
    # 7 windows over scan_k=3: two full batches + a ragged tail; the
    # in-process sharded modes run the degenerate 1-device mesh (the real
    # 8-device mesh is the subprocess test below)
    ref = _system("scan", tmp_db=str(tmp_path / "ref"),
                  batched_consume=True)
    fus = _system(mode, tmp_db=str(tmp_path / "fus"))
    rr, rf = ref.run_windows(7), fus.run_windows(7)
    ref.stop(), fus.stop()
    assert _strip(rr) == _strip(rf)
    # identical decision delivery: every forwarder sink + stats
    for fa, fb in zip(ref.forwarders.forwarders, fus.forwarders.forwarders):
        assert fa.sink == fb.sink and fa.stats == fb.stats
    # identical DB rows — the fused path fetches the (K, E, F) features
    # only because a LogDB is attached
    assert _rows(ref.db) == _rows(fus.db)
    # host-side predictor bookkeeping advanced in lockstep
    assert ref.predictor.stats == fus.predictor.stats
    # replay export: mirror-reattached (reference) vs snapshot +
    # tick_idx->float64 reconstruction (fused) agree bit for bit
    _assert_export_equal(ref.export_replay("s"), fus.export_replay("s"))
    ref.db.close(), fus.db.close()


def test_fused_decide_split_invariance():
    """21 windows as 21x(K=1), 3x(K=7), and scan_k=5 ragged batches —
    identical results and replay everywhere (the donated carry threads
    through batch boundaries exactly like the host-side _prev did)."""
    outs, exports = [], []
    for k in (1, 7, 5):
        s = _system("scan_fused_decide", scan_k=k, forwarders=False, cap=64)
        outs.append(_strip(s.run_windows(21)))
        exports.append(s.export_replay("s"))
        s.stop()
    # per-window `records` attribution follows the drain schedule (scan_k=1
    # drains every window, scan_k=7 once per batch — the documented scan
    # caveat); every decision/pipeline output must be split-invariant and
    # the ingest totals must agree
    norecs = [[{k: v for k, v in r.items() if k != "records"} for r in o]
              for o in outs]
    assert norecs[0] == norecs[1] == norecs[2]
    totals = [sum(r["records"] for r in o) for o in outs]
    assert totals[0] == totals[1] == totals[2]
    _assert_export_equal(exports[0], exports[1])
    _assert_export_equal(exports[0], exports[2])


def test_fused_decide_replay_wraparound_k_exceeds_capacity(tmp_path):
    """scan_k=7 against a capacity-4 ring: a single fused batch overwrites
    the whole ring (K > capacity), and repeated batches keep wrapping —
    cursor semantics must stay bit-identical to the sequential reference,
    and the export must come back rolled to chronological order."""
    ref = _system("scan", cap=4, scan_k=7, tmp_db=str(tmp_path / "ref"))
    fus = _system("scan_fused_decide", cap=4, scan_k=7,
                  tmp_db=str(tmp_path / "fus"))
    rr, rf = ref.run_windows(11), fus.run_windows(11)
    ref.stop(), fus.stop()
    assert _strip(rr) == _strip(rf)
    assert _rows(ref.db) == _rows(fus.db)
    ea, eb = ref.export_replay("s"), fus.export_replay("s")
    _assert_export_equal(ea, eb)
    # 11 ticks -> 10 transitions through a 4-slot ring: live rows are the
    # last 4, strictly chronological after the roll
    assert (eb["tick_idx"][0] == np.arange(7, 11)).all()
    assert (np.diff(eb["times"][0]) > 0).all()
    assert ref.replay_size() == fus.replay_size() == 4
    ref.db.close(), fus.db.close()


def test_fused_decide_export_exact_at_long_horizon():
    """t0 = 2^24 with sub-second windows: absolute float32 times collapse
    (regression premise), but the fused export's float64 reconstruction
    from the stored int32 tick indices reproduces the exact window ends —
    and matches the reference predictor's host-mirror export bit for
    bit."""
    ref = _system("scan", t0=T0_FAR, tick_s=0.1, forwarders=False)
    fus = _system("scan_fused_decide", t0=T0_FAR, tick_s=0.1,
                  forwarders=False)
    assert _strip(ref.run_windows(6, pump=False)) \
        == _strip(fus.run_windows(6, pump=False))
    ends = np.asarray([ref.window_bounds(j)[1] for j in range(6)],
                      np.float64)
    assert len(np.unique(ends.astype(np.float32))) < 6   # premise
    ea, eb = ref.export_replay("s"), fus.export_replay("s")
    _assert_export_equal(ea, eb)
    assert (eb["times"][0] == ends[1:]).all()
    assert (np.diff(eb["times"][0]) > 0).all()
    ref.stop(), fus.stop()


def test_fused_decide_export_with_pre_system_predictor_history(rng):
    """A Predictor that already consumed windows BEFORE the system exists:
    the fused export must keep the host-mirror times for those pre-system
    slots and offset the reconstruction by the construction-time tick
    base — matching the reference mirror export bit for bit."""
    def mk(mode):
        srcs = [SourceSpec("meter", "mqtt",
                           SimulatedDevice("grid_kw", 60.0, base=3.0,
                                           seed=1)),
                SourceSpec("price", "http",
                           SimulatedDevice("price_eur", 300.0, base=0.2,
                                           amplitude=0.05, seed=2))]
        cfg = PipelineConfig(n_envs=2, n_streams=2, n_ticks=8, tick_s=60.0,
                             max_samples=32)
        pred = Predictor(
            linear_policy(2, 2),
            energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
            ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
            2, cfg.n_features, replay_capacity=16)
        # prior host-side history at arbitrary (non-window-grid) times
        feats = rng.normal(0, 1, (3, 2, cfg.n_features)).astype(np.float32)
        pred.on_windows(feats, [7.5, 11.25, 200.0])
        return PerceptaSystem(["bldg-0", "bldg-1"], srcs, cfg, pred,
                              speedup=5000.0, manual_time=True, mode=mode,
                              scan_k=3)

    rng_state = rng.get_state()
    ref = mk("scan")
    rng.set_state(rng_state)      # identical prior history for both
    fus = mk("scan_fused_decide")
    assert _strip(ref.run_windows(5)) == _strip(fus.run_windows(5))
    ea, eb = ref.export_replay("s"), fus.export_replay("s")
    _assert_export_equal(ea, eb)
    # premise: both eras present in the export (tick 0's transition is
    # masked — no predecessor — so the prior-era times are ticks 1 and 2)
    assert eb["tick_idx"][0].min() < 3 <= eb["tick_idx"][0].max()
    assert 11.25 in eb["times"][0] and 200.0 in eb["times"][0]
    ref.stop(), fus.stop()


def test_fused_decide_accessors_and_guards():
    s = _system("scan_fused_decide", forwarders=False)
    s.run_windows(4)
    # snapshot_decide is a deep copy: safe across the donated dispatches
    snap = s.snapshot_decide()
    s.run_windows(3)
    assert int(snap.tick) == 4 and int(s.snapshot_decide().tick) == 7
    assert s.replay_size() == 6            # 7 ticks -> 6 transitions
    # the raw scan entry point refuses fused mode (wrong carry signature)
    with pytest.raises(RuntimeError, match="run_many_decide"):
        s.pipeline.run_many(s.state, None, None)
    # non-fused systems reject the fused-only accessor
    ref = _system("scan", forwarders=False)
    with pytest.raises(AssertionError):
        ref.snapshot_decide()
    s.stop(), ref.stop()


# --------------------------------------------------------------------------
# replay.add_batch: the fused engine's one-scatter ring write
# --------------------------------------------------------------------------

@pytest.mark.parametrize("K,cap", [(3, 8), (8, 8), (11, 4), (21, 4)])
def test_add_batch_matches_sequential_adds(K, cap, rng):
    """One unique-indices scatter == K guarded sequential add() calls bit
    for bit — masked rows, exact cursor advance, and K > capacity
    wraparound where only the last `capacity` masked writes survive."""
    from repro.core import replay as rp

    E, F, A = 3, 4, 2
    obs = rng.normal(0, 1, (K, E, F)).astype(np.float32)
    acts = rng.normal(0, 1, (K, E, A)).astype(np.float32)
    rews = rng.normal(0, 1, (K, E)).astype(np.float32)
    nxt = rng.normal(0, 1, (K, E, F)).astype(np.float32)
    idx = np.arange(K, dtype=np.int32)
    mask = rng.rand(K) > 0.3
    a = rp.init(E, cap, F, A)
    for j in range(K):
        if mask[j]:
            a = rp.add(a, obs[j], acts[j], rews[j], nxt[j], idx[j])
    b = rp.add_batch(rp.init(E, cap, F, A), obs, acts, rews, nxt, idx,
                     mask=jnp.asarray(mask))
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert (np.asarray(x) == np.asarray(y)).all()
    # and consecutive batches across the same ring (cursor mid-stream)
    a2 = rp.add_many(a, obs, acts, rews, nxt, idx + K)
    b2 = rp.add_batch(b, obs, acts, rews, nxt, idx + K)
    for x, y in zip(jax.tree.leaves(a2), jax.tree.leaves(b2)):
        assert (np.asarray(x) == np.asarray(y)).all()


# --------------------------------------------------------------------------
# Engine level: large-E smoke cell (E=256 — the per-device regime the
# benchmarked cells target)
# --------------------------------------------------------------------------

def test_fused_decide_large_e_engine_identity():
    import functools

    E, S, M, T, K = 256, 8, 16, 8, 4
    cfg = PipelineConfig(n_envs=E, n_streams=S, n_ticks=T, tick_s=60.0,
                         max_samples=M)
    rng = np.random.RandomState(0)
    raws = make_raw_window(
        rng.normal(5, 2, (K, E, S, M)).astype(np.float32),
        rng.uniform(0, T * 60, (K, E, S, M)).astype(np.float32),
        rng.rand(K, E, S, M) > 0.3)
    starts = jnp.zeros((K, E), jnp.float32)

    def mkp():
        return Predictor(
            linear_policy(cfg.n_features, 2),
            energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
            ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
            E, cfg.n_features, replay_capacity=32)

    # reference: two dispatches (pipeline scan, then on_windows)
    p_ref = mkp()
    pipe = pl.PerceptaPipeline(cfg, mode="scan")
    _, feats, frames = pipe.run_many(pl.init_state(cfg), raws, starts)
    acts, rews, _ = p_ref.on_windows(feats.features,
                                     [T * 60.0 * (j + 1) for j in range(K)],
                                     raw=feats.raw)
    # fused: one dispatch, decide state carried on device
    p_fus = mkp()
    engine = jax.jit(functools.partial(pl.run_many_decide, cfg,
                                       p_fus.make_decide_fn()))
    _, dstate, outs = engine(pl.init_state(cfg), p_fus.decide_state(),
                             raws, starts)
    assert (np.asarray(outs.actions) == acts).all()
    assert (np.asarray(outs.rewards) == rews).all()
    for x, y in zip(jax.tree.leaves(p_ref.replay),
                    jax.tree.leaves(dstate.replay)):
        assert (np.asarray(x) == np.asarray(y)).all()
    # the count outputs reproduce np.mean over the frames exactly
    obs_np = np.asarray(frames.observed)
    got = np.asarray(outs.observed)
    for j in range(K):
        assert float(int(got[j].sum()) / float(E * S * T)) \
            == float(obs_np[j].mean())


# --------------------------------------------------------------------------
# Real multi-device mesh (subprocess: the XLA flag must precede JAX init)
# --------------------------------------------------------------------------

_SHARDED_SCRIPT = """
import numpy as np
from repro.core import PipelineConfig
from repro.core.reward import energy_reward_spec
from repro.runtime.predictor import ActionSpace, Predictor, linear_policy
from repro.runtime.receivers import SimulatedDevice
from repro.runtime.system import PerceptaSystem, SourceSpec
import jax
assert len(jax.devices()) == 8, jax.devices()

def mk(mode):
    srcs = [SourceSpec("meter", "mqtt",
                       SimulatedDevice("grid_kw", 60.0, base=3.0, seed=1)),
            SourceSpec("price", "http",
                       SimulatedDevice("price_eur", 300.0, base=0.2,
                                       amplitude=0.05, seed=2))]
    cfg = PipelineConfig(n_envs=8, n_streams=2, n_ticks=4, tick_s=60.0,
                         max_samples=16)
    pred = Predictor(linear_policy(2, 2),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     8, cfg.n_features, replay_capacity=8)
    return PerceptaSystem([f"b{i}" for i in range(8)], srcs, cfg, pred,
                          speedup=5000.0, manual_time=True, mode=mode,
                          scan_k=3)

strip = lambda rs: [{k: v for k, v in r.items() if k != "latency_s"}
                    for r in rs]
ref = mk("scan")
rr = strip(ref.run_windows(11))          # ring wraps: 10 adds, capacity 8
ea = ref.export_replay("s")
for mode in ("scan_fused_decide_sharded", "scan_fused_decide_async_sharded"):
    s = mk(mode)
    assert dict(s.pipeline.mesh.shape) == {"data": 8}, s.pipeline.mesh
    assert strip(s.run_windows(11)) == rr, mode
    eb = s.export_replay("s")
    assert ea["env_ids"] == eb["env_ids"]
    for k in ("obs", "actions", "rewards", "next_obs", "tick_idx", "times"):
        assert (np.asarray(ea[k]) == np.asarray(eb[k])).all(), (mode, k)
    s.stop()
print("FUSED_SHARDED_OK")
"""


def test_fused_decide_sharded_multi_device_bit_identical():
    """Real 8-device forced CPU mesh: the fused carry (pipeline state +
    decide state + replay ring) env-sharded over 8 chips, with ring
    wraparound, == plain scan + batched consume on one device."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "FUSED_SHARDED_OK" in out.stdout

"""Translators — per-source format adaptation to the standardized Record.

"Each data source also has an associated Translator that adjusts to the
format of the incoming data, extracting only the relevant information ...
and submits it to an internal queue associated with the appropriate
environment."

``translate`` is the per-payload path (decode one wire message -> one
Record). ``translate_batch`` is the columnar path: a whole receiver poll
(two NumPy columns) becomes one :class:`RecordBatch` with rename and unit
scaling applied vectorized.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import numpy as np

from repro.runtime.records import CODECS, Record, RecordBatch


class Translator:
    def __init__(self, source_id: str, protocol: str,
                 stream_rename: Optional[Dict[str, str]] = None,
                 unit_scale: float = 1.0):
        self.source_id = source_id
        self.decode = CODECS[protocol][1]
        self.stream_rename = stream_rename or {}
        self.unit_scale = unit_scale
        self.stats = {"records": 0, "errors": 0}

    def translate(self, env_id: str, payload: bytes) -> Optional[Record]:
        try:
            stream, ts, value = self.decode(payload)
        except Exception:
            self.stats["errors"] += 1
            return None
        self.stats["records"] += 1
        stream = self.stream_rename.get(stream, stream)
        return Record(env_id=env_id, stream=stream, timestamp=ts,
                      value=value * self.unit_scale)

    def translate_batch(self, env_id: str, stream: str, timestamps,
                        values,
                        sorted_ts: Optional[bool] = None
                        ) -> Optional[RecordBatch]:
        """Columnar poll -> one RecordBatch (rename + unit scale, no loop).

        The receiver already decoded/simulated the columns, so there is no
        per-row parse step to fail — malformed data is a per-payload-path
        concern, which is why ``errors`` only moves on ``translate``.
        ``sorted_ts`` (the receiver's measured sortedness promise) passes
        through untouched — rename and unit scaling never reorder rows.
        """
        ts = np.asarray(timestamps, np.float64)
        vs = np.asarray(values, np.float64)
        if ts.shape[0] == 0:
            return None
        if self.unit_scale != 1.0:
            vs = vs * self.unit_scale
        self.stats["records"] += int(ts.shape[0])
        stream = self.stream_rename.get(stream, stream)
        return RecordBatch.from_columns(env_id, stream, ts, vs, sorted_ts)

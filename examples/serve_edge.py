"""END-TO-END DRIVER (the paper's kind: real-time inference support).

Percepta at the edge with a CERTIFIED registry policy driving decisions
and a real transformer serving ad-hoc requests: simulated MQTT/HTTP/AMQP
devices -> Receivers -> Translators -> env queues -> Accumulator -> fused
device tick (harmonize/gap-fill/de-spike/normalize) -> rg-LRU recurrent
policy -> decisions -> reward -> replay + LogDB -> Forwarders, while a
qwen3-family LM (reduced config) answers batched text requests through
the continuous-batching engine between ticks.

The decision model comes from the policy registry
(``repro.runtime.policies``): ``PerceptaSystem(..., policy="rglru")``
resolves the name to a builder at the system's env/feature/action shapes
and statically CERTIFIES it at registration (``repro.analysis.certify``)
— row-wise env math, recurrent-carry row stability across the decide-step
fixed point, pallas BlockSpec env routing, param replication — before the
fused/sharded engines will accept it. The rg-LRU's recurrent state rides
the donated device carry (``DecideState.carry``) through the fused scan,
env-sharded on the mesh in the ``_sharded`` compositions. Pass a
``PolicyConfig`` to override builder kwargs, e.g.
``PolicyConfig("rglru", {"hidden": 32, "use_pallas": True})`` to run the
hidden-state update through the pallas kernel (``kernels/rglru_scan``) —
bit-identical to the ``lax.scan`` reference, and certifiable because the
checker recurses into ``pallas_call``.

The Percepta tick runs in ``scan`` mode by default: the Manager batches
``SCAN_K`` windows per device dispatch (``PerceptaPipeline.run_many`` —
one ``lax.scan`` with the state carried on device). ``--mode fused``
dispatches one jitted tick per window; ``--mode scan_sharded`` runs the
same scan under ``shard_map`` with envs sharded over the local device
mesh (on one CPU device it degenerates to ``scan``; force a multi-device
CPU mesh with ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
before launch); ``--mode scan_async`` overlaps host ingest with device
compute. ``--mode scan_fused_decide`` (and its ``_sharded`` / ``_async``
/ ``_async_sharded`` compositions) fuses the DECISION path into the same
dispatch: policy, action validation, rewards and the replay-ring write
execute inside the window scan, so the whole ingest->decide->bank loop
costs one device dispatch per batch. Unlike the LM-decides variant of
this example (pre-registry), the rg-LRU policy is per-env row-wise, so
the fused ``_sharded`` compositions work here too — that is exactly what
its certificate proves.

Accessor rules in scan modes: hold pipeline state only through the
donation-safe ``system.snapshot_state()`` / ``snapshot_norm()`` copies,
and read the replay through ``system.export_replay(salt)`` /
``system.replay_size()`` — the device ring stores exact int32 tick
indices (float32 absolute seconds would collapse consecutive window ends
past t~2^24 s), and in the fused-decide modes the ring itself lives in
the DONATED device carry, so ``pred.replay`` is a stale construction-time
snapshot there; the system export snapshots the live carry without
donating it and reconstructs exact float64 absolute times.

Run: PYTHONPATH=src python examples/serve_edge.py \
         [--mode scan|scan_async|scan_sharded|scan_fused_decide|\
          scan_fused_decide_sharded|...|fused]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.core import PipelineConfig
from repro.core.reward import energy_reward_spec
from repro.models import LM
from repro.runtime.db import LogDB
from repro.runtime.forwarder import Forwarder, ForwarderHub
from repro.runtime.predictor import ActionSpace, Predictor
from repro.runtime.receivers import SimulatedDevice
from repro.runtime.system import PerceptaSystem, SourceSpec
from repro.serve.engine import Request, ServeEngine

# --- the ad-hoc serving model: a real (reduced-config) transformer ---------
cfg_lm = get_config("qwen3-0.6b:smoke")
model = LM(cfg_lm, remat_policy="none")
params = model.init(jax.random.PRNGKey(0))

# --- Percepta wiring ---------------------------------------------------------
ap = argparse.ArgumentParser()
ap.add_argument("--mode", default="scan",
                choices=["scan", "scan_async", "scan_sharded",
                         "scan_async_sharded", "scan_fused_decide",
                         "scan_fused_decide_sharded",
                         "scan_fused_decide_async",
                         "scan_fused_decide_async_sharded", "fused"],
                help="device execution mode; the scan_fused_decide modes "
                     "fuse the policy/reward/replay step into the window "
                     "scan (one dispatch per batch, device-resident replay "
                     "ring + recurrent policy carry). The *_sharded "
                     "compositions split envs over the device mesh — "
                     "admissible because the registry rg-LRU policy is "
                     "certified per-env row-wise at registration")
ap.add_argument("--ingest-workers", type=int, default=1,
                help="shard host-side window assembly (drain -> ingest -> "
                     "close) across N threads, envs striped by slot "
                     "(live[w::N]) so ownership is deterministic under "
                     "elastic churn; bit-identical to serial assembly "
                     "(disjoint staging columns, order-independent count "
                     "sums) and composes with the scan_async prefetcher. "
                     "Worth it once E x records/window is large enough "
                     "that assembly rivals the device phase — at this "
                     "example's tiny E=4 it only adds thread overhead")
args = ap.parse_args()
SCAN_K = 2  # windows per scan-fused dispatch
E = 4
sources = [
    SourceSpec("meter", "mqtt", SimulatedDevice("grid_kw", 60.0, base=3.0,
                                                seed=1)),
    SourceSpec("price", "http", SimulatedDevice("price_eur", 300.0, base=0.2,
                                                amplitude=0.05, seed=2)),
    SourceSpec("thermo", "amqp", SimulatedDevice("temp_c", 30.0, base=21.0,
                                                 amplitude=1.5, seed=3)),
]
pcfg = PipelineConfig(n_envs=E, n_streams=3, n_ticks=8, tick_s=60.0,
                      max_samples=32)
# the registry policy: Predictor accepts the registry NAME (or a
# PolicyConfig) and resolves it at its own (n_features, n_actions, n_envs)
# — build_policy certifies the builder before the adapter is returned, and
# the certificate travels on the model for the system's fused-mode gate
pred = Predictor("rglru",
                 energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=2),
                 ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                 E, pcfg.n_features, db=None, replay_capacity=256)
print(f"decision policy: {pred.model.certificate.describe()}")
db = LogDB("/tmp/percepta_serve_db", salt="opeva")
hub = ForwarderHub([Forwarder("hvac", "mqtt", [0]),
                    Forwarder("ev-charger", "amqp", [1])])
system = PerceptaSystem([f"bldg-{i}" for i in range(E)], sources, pcfg, pred,
                        forwarders=hub, db=db, speedup=4000.0,
                        mode=args.mode, scan_k=SCAN_K,
                        ingest_workers=args.ingest_workers)

# --- ad-hoc batched request serving between ticks ---------------------------
engine = ServeEngine(model, params, batch_slots=4, max_seq=64)
rng = np.random.RandomState(0)

batch = 1 if args.mode == "fused" else SCAN_K
print(f"=== Percepta edge serving: 6 windows ({args.mode} mode, "
      f"{batch} windows/dispatch), 12 ad-hoc requests ===")
t_start = time.time()
tok_count = 0
for w in range(0, 6, batch):
    results = system.run_windows(batch)
    # serve batched ad-hoc requests while streams accumulate (2 per window
    # regardless of dispatch batching, so both modes serve 12 total)
    reqs = [Request(rid=w * 10 + j,
                    prompt=rng.randint(1, cfg_lm.vocab_size, (6,))
                    .astype(np.int32), max_new_tokens=8)
            for j in range(2 * batch)]
    engine.run_until_drained(reqs)
    tok_count += sum(len(q.tokens) for q in reqs)
    for r in results:
        print(f"window {r['window']}: {r['records']:4d} records  "
              f"tick {r['latency_s']*1e3:6.1f} ms  "
              f"reward {r['mean_reward']:+.3f}  "
              f"observed {r['observed_frac']:.0%}  "
              f"filled {r['filled_frac']:.0%}")

dt = time.time() - t_start
print(f"\nforwarded decisions: "
      f"{ {f.dest_id: f.stats['sent'] for f in hub.forwarders} }")
# replay accessor rule: device-side times are exact int32 tick indices;
# the system export re-attaches exact float64 absolute times (host mirror,
# or tick-index reconstruction in fused-decide modes where the ring lives
# in the donated device carry) and rolls the ring chronological — never
# read replay.tick_idx as seconds, never alias pred.replay in fused modes
dataset = system.export_replay(salt="opeva")
print(f"DB rows (anonymized): {db.stats['rows']}  "
      f"replay transitions: {system.replay_size()}  "
      f"export t=[{dataset['times'][0, 0]:.0f}"
      f"..{dataset['times'][0, -1]:.0f}]s")
print(f"ad-hoc serving: {tok_count} tokens via continuous batching "
      f"({engine.stats['ticks']} engine ticks)")
print(f"wall time {dt:.1f}s for 48 stream-minutes x {E} buildings + serving")
db.close()

"""Batched Predictor consume: ``on_windows`` == K sequential ``on_tick``
calls bit for bit (actions, rewards, per-term, replay contents, violation
stats), across system modes and batch-boundary splits, plus the replay
long-horizon time rule (exact int32 tick index device-side, float64
absolute time reconstructed at export) and ring-order export."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PipelineConfig
from repro.core import replay as rp
from repro.core.reward import (RewardSpec, RewardTerm, energy_reward_spec)
from repro.runtime.predictor import ActionSpace, Predictor, linear_policy
from repro.runtime.db import LogDB
from repro.runtime.forwarder import Forwarder, ForwarderHub
from repro.runtime.receivers import SimulatedDevice
from repro.runtime.system import PerceptaSystem, SourceSpec

E, F, A, CAP = 3, 4, 2, 8

T0_FAR = float(2 ** 24)     # float32 absolute seconds quantize to >=2s here


def _pred(cap=CAP, seed=3):
    return Predictor(linear_policy(F, A, seed=seed),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=2),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     E, F, replay_capacity=cap)


def _assert_predictors_equal(a: Predictor, b: Predictor):
    assert a.stats == b.stats
    for x, y in zip(jax.tree.leaves(a.replay), jax.tree.leaves(b.replay)):
        assert (np.asarray(x) == np.asarray(y)).all()
    assert (a._replay_times == b._replay_times).all()
    for k in ("obs", "actions"):
        assert (np.asarray(a._prev[k]) == np.asarray(b._prev[k])).all()
    assert a._prev["have"] == bool(b._prev["have"])


# --------------------------------------------------------------------------
# Unit level: one batched dispatch == K per-window reference steps
# --------------------------------------------------------------------------

@pytest.mark.parametrize("K", [1, 3, CAP, 13])   # 13 > capacity: ring wraps
def test_on_windows_matches_on_tick_bitwise(K, rng):
    feats = rng.normal(0, 1, (K, E, F)).astype(np.float32)
    # raw scaled up so some actions violate the envelope (violation stats)
    raw = rng.normal(5, 2, (K, E, F)).astype(np.float32)
    times = [100.0 + 60.0 * j for j in range(K)]
    a, b = _pred(), _pred()
    seq = [a.on_tick(feats[j], times[j], raw=raw[j]) for j in range(K)]
    act, rew, per = b.on_windows(feats, times, raw=raw)
    assert (np.stack([s[0] for s in seq]) == act).all()
    assert (np.stack([s[1] for s in seq]) == rew).all()
    assert (np.stack([s[2] for s in seq]) == per).all()
    _assert_predictors_equal(a, b)

    # continuation across the batch boundary: a second (ragged) batch fed
    # to both paths stays identical, including the have_prev carry
    feats2 = rng.normal(0, 1, (2, E, F)).astype(np.float32)
    times2 = [100.0 + 60.0 * (K + j) for j in range(2)]
    seq2 = [a.on_tick(feats2[j], times2[j]) for j in range(2)]
    act2, rew2, per2 = b.on_windows(feats2, times2)
    assert (np.stack([s[0] for s in seq2]) == act2).all()
    assert (np.stack([s[1] for s in seq2]) == rew2).all()
    _assert_predictors_equal(a, b)


def test_on_windows_split_invariance(rng):
    """7 windows as 7x(K=1), 1x(K=7), and (4, 3) — identical everywhere."""
    feats = rng.normal(0, 1, (7, E, F)).astype(np.float32)
    times = [60.0 * (j + 1) for j in range(7)]
    outs = []
    preds = []
    for splits in ([1] * 7, [7], [4, 3]):
        p = _pred()
        got = []
        j = 0
        for k in splits:
            got.append(p.on_windows(feats[j:j + k], times[j:j + k]))
            j += k
        outs.append(np.concatenate([g[0] for g in got]))
        preds.append(p)
    assert (outs[0] == outs[1]).all() and (outs[0] == outs[2]).all()
    _assert_predictors_equal(preds[0], preds[1])
    _assert_predictors_equal(preds[0], preds[2])


def test_reward_compute_k_leading_matches_per_window(rng):
    """Every term kind evaluates a K-leading stack bit-identically to
    per-window calls (the batched consume's reward path)."""
    spec = RewardSpec(terms=(
        RewardTerm("linear", weight=0.5, feature=0),
        RewardTerm("abs_error", weight=1.1, feature=1, target=2.0),
        RewardTerm("quadratic_error", weight=0.3, feature=2, target=-1.0),
        RewardTerm("band_penalty", weight=2.0, feature=3, target=21.0,
                   band=1.5),
        RewardTerm("threshold_bonus", weight=0.7, feature=0, target=0.5),
        RewardTerm("action_smoothness", weight=0.1, action=1),
        RewardTerm("custom", weight=1.0,
                   fn=lambda f, a, p: -f[:, 1] * jnp.maximum(f[:, 0], 0.0)),
        # contraction-bearing custom term: custom fns run per-window under
        # lax.map (never vmap — a K-batched dot could accumulate
        # differently), so even this must match EXACTLY. The env-rows gemm
        # is legal on this host-side (non-sharded) path but breaks the
        # shard contract, so it needs the spec-time check's escape hatch
        RewardTerm("custom", weight=0.9,
                   fn=lambda f, a, p: (f @ jnp.full((F, 1), 0.37))[:, 0]),
    ), unchecked=True)
    K = 5
    feats = jnp.asarray(rng.normal(0, 2, (K, E, F)).astype(np.float32))
    acts = jnp.asarray(rng.uniform(-1, 1, (K, E, A)).astype(np.float32))
    prev = jnp.asarray(rng.uniform(-1, 1, (K, E, A)).astype(np.float32))
    tot_k, per_k = spec.compute(feats, acts, prev)
    assert tot_k.shape == (K, E) and per_k.shape == (K, E, 8)
    for k in range(K):
        tot, per = spec.compute(feats[k], acts[k], prev[k])
        assert (np.asarray(tot) == np.asarray(tot_k[k])).all()
        assert (np.asarray(per) == np.asarray(per_k[k])).all()


# --------------------------------------------------------------------------
# Replay: scan-safe add_many, ring-order export, empty-sample guard
# --------------------------------------------------------------------------

def test_add_many_matches_sequential_adds(rng):
    """add_many == K add() calls bit for bit, including masked rows and
    K > capacity wraparound (the batched consume's write path)."""
    K, cap = 11, 4
    obs = rng.normal(0, 1, (K, E, F)).astype(np.float32)
    acts = rng.normal(0, 1, (K, E, A)).astype(np.float32)
    rews = rng.normal(0, 1, (K, E)).astype(np.float32)
    nxt = rng.normal(0, 1, (K, E, F)).astype(np.float32)
    idx = np.arange(K, dtype=np.int32)
    mask = rng.rand(K) > 0.3
    a = rp.init(E, cap, F, A)
    for j in range(K):
        if mask[j]:
            a = rp.add(a, obs[j], acts[j], rews[j], nxt[j], idx[j])
    b = rp.add_many(rp.init(E, cap, F, A), obs, acts, rews, nxt, idx,
                    mask=jnp.asarray(mask))
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert (np.asarray(x) == np.asarray(y)).all()


def test_export_rolls_ring_to_chronological_order(rng):
    """Once cursor > capacity the raw slot order is scrambled; export must
    hand back rows in write order (strictly increasing tick_idx)."""
    cap, n = 4, 7
    buf = rp.init(E, cap, F, A)
    for j in range(n):
        buf = rp.add(buf, jnp.full((E, F), float(j)), jnp.zeros((E, A)),
                     jnp.full((E,), float(j)), jnp.zeros((E, F)),
                     jnp.int32(j))
    # premise: the raw ring really is scrambled at this cursor
    raw_idx = np.asarray(buf.tick_idx[0])
    assert not (np.diff(raw_idx) > 0).all()
    out = rp.export_for_training(buf, [f"e{i}" for i in range(E)], "s")
    assert out["tick_idx"].shape == (E, cap)
    assert (out["tick_idx"] == np.arange(n - cap, n)[None, :]).all()
    assert (out["rewards"][0] == np.arange(n - cap, n, dtype=np.float32)).all()
    # pre-wrap: plain prefix, still chronological
    buf2 = rp.init(E, cap, F, A)
    buf2 = rp.add(buf2, jnp.ones((E, F)), jnp.zeros((E, A)), jnp.ones((E,)),
                  jnp.zeros((E, F)), jnp.int32(5))
    out2 = rp.export_for_training(buf2, [f"e{i}" for i in range(E)], "s")
    assert out2["tick_idx"].shape == (E, 1) and out2["tick_idx"][0, 0] == 5


def test_sample_empty_buffer_raises():
    buf = rp.init(E, CAP, F, A)
    with pytest.raises(ValueError, match="empty"):
        rp.sample(buf, jax.random.PRNGKey(0), 4)
    # one add makes it sampleable
    buf = rp.add(buf, jnp.ones((E, F)), jnp.ones((E, A)), jnp.ones((E,)),
                 jnp.ones((E, F)), jnp.int32(0))
    batch = rp.sample(buf, jax.random.PRNGKey(0), 4)
    assert (np.asarray(batch["rewards"]) == 1.0).all()


# --------------------------------------------------------------------------
# Long horizons: replay times survive t~2^24 (the PR 3 timestamp-collapse
# class on the replay path; mirrors test_scan_engine's rebase test)
# --------------------------------------------------------------------------

def test_replay_times_exact_at_long_horizon(rng):
    """Consecutive window ends 0.25 s apart at t0=2^24: the old float32
    storage collapses them into one value; the int32-index + host-float64
    path reproduces them exactly, and matches the t0=0 run bit for bit on
    the device side."""
    K = 6
    feats = rng.normal(0, 1, (K, E, F)).astype(np.float32)

    def run(t0):
        p = _pred(cap=16)
        times = [t0 + 0.25 * (j + 1) for j in range(K)]
        p.on_windows(feats, times)
        return p, times

    far, far_times = run(T0_FAR)
    near, _ = run(0.0)
    # regression premise: the absolute float32 form really does collapse
    assert len(np.unique(np.asarray(far_times, np.float32))) < K
    out = far.export_replay([f"e{i}" for i in range(E)], salt="s")
    # exact float64 reconstruction: all K-1 transitions distinct, exact
    expect = np.asarray(far_times[1:], np.float64)
    assert out["times"].shape == (E, K - 1)
    assert (out["times"][0] == expect).all()
    assert (np.diff(out["times"][0]) == 0.25).all()
    # device-side leaves are identical regardless of the absolute origin
    for x, y in zip(jax.tree.leaves(far.replay), jax.tree.leaves(near.replay)):
        assert (np.asarray(x) == np.asarray(y)).all()


def test_system_replay_times_exact_at_long_horizon():
    """Through the full scan-mode system: sub-2s windows starting at
    t0=2^24 must export distinct, exact float64 window-end times."""
    cfg = PipelineConfig(n_envs=2, n_streams=1, n_ticks=8, tick_s=0.1,
                         max_samples=8)
    pred = Predictor(linear_policy(1, 2),
                     energy_reward_spec(price_idx=0, grid_idx=0, temp_idx=0),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     2, cfg.n_features, replay_capacity=16)
    srcs = [SourceSpec("m", "mqtt", SimulatedDevice("s", 60.0, seed=1))]
    sys_ = PerceptaSystem(["a", "b"], srcs, cfg, pred, t0=T0_FAR,
                          manual_time=True, mode="scan", scan_k=3)
    sys_.run_windows(6, pump=False)
    # 0.8 s windows: the exact float64 ends the Manager handed the Predictor
    ends = np.asarray([sys_.window_bounds(j)[1] for j in range(6)],
                      np.float64)
    assert len(np.unique(ends.astype(np.float32))) < 6   # premise
    out = pred.export_replay(["a", "b"], salt="s")
    assert (out["times"][0] == ends[1:]).all()
    assert (out["tick_idx"][0] == np.arange(1, 6)).all()


# --------------------------------------------------------------------------
# System level: batched consume == per-window reference, per mode
# --------------------------------------------------------------------------

def _system(mode, batched_consume=True, tmp_db=None, scan_k=3):
    srcs = [
        SourceSpec("meter", "mqtt", SimulatedDevice("grid_kw", 60.0,
                                                    base=3.0, seed=1)),
        SourceSpec("price", "http", SimulatedDevice("price_eur", 300.0,
                                                    base=0.2, amplitude=0.05,
                                                    seed=2)),
    ]
    cfg = PipelineConfig(n_envs=2, n_streams=2, n_ticks=8, tick_s=60.0,
                         max_samples=32)
    pred = Predictor(linear_policy(2, 2),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     2, cfg.n_features, replay_capacity=16)
    hub = ForwarderHub([Forwarder("hvac", "mqtt", [0]),
                        Forwarder("ev", "amqp", [1])])
    db = LogDB(tmp_db, salt="x") if tmp_db else None
    return PerceptaSystem(["bldg-0", "bldg-1"], srcs, cfg, pred,
                          forwarders=hub, db=db, speedup=5000.0,
                          manual_time=True, mode=mode, scan_k=scan_k,
                          batched_consume=batched_consume)


def _strip(results):
    return [{k: v for k, v in r.items() if k != "latency_s"}
            for r in results]


def _rows(db):
    return [{k: v for k, v in row.items() if k != "logged_at"}
            for _, row in db.read_from()]


@pytest.mark.parametrize("mode", ["scan", "scan_async"])
def test_batched_consume_matches_per_window_reference(mode, tmp_path):
    # 7 windows over scan_k=3: two full batches + a ragged tail
    a = _system(mode, batched_consume=True, tmp_db=str(tmp_path / "a"))
    b = _system(mode, batched_consume=False, tmp_db=str(tmp_path / "b"))
    ra, rb = a.run_windows(7), b.run_windows(7)
    a.stop(), b.stop()
    assert _strip(ra) == _strip(rb)
    _assert_predictors_equal(a.predictor, b.predictor)
    # identical decision delivery: every forwarder sink + stats
    for fa, fb in zip(a.forwarders.forwarders, b.forwarders.forwarders):
        assert fa.sink == fb.sink and fa.stats == fb.stats
    # identical DB rows (logged_at is wall time, everything else exact)
    assert _rows(a.db) == _rows(b.db)
    a.db.close(), b.db.close()


def test_scan_batched_consume_matches_fused_reference(tmp_path):
    """Across the mode axis: the fused per-window system (run_window +
    on_tick, the original reference) and the scan system with batched
    consume agree on rewards and replay (pipeline features are allclose
    across the fused/scan engines, so tolerance-based here)."""
    a = _system("fused", tmp_db=str(tmp_path / "a"))
    b = _system("scan", batched_consume=True, tmp_db=str(tmp_path / "b"))
    ra, rb = a.run_windows(6), b.run_windows(6)
    for x, y in zip(ra, rb):
        assert abs(x["mean_reward"] - y["mean_reward"]) < 1e-3
        assert x["anomalous"] == y["anomalous"]
    # per-window record attribution differs across drain schedules (fused
    # drains every window, scan once per batch) but totals must agree
    assert (sum(r["records"] for r in ra) == sum(r["records"] for r in rb))
    assert a.predictor.stats["ticks"] == b.predictor.stats["ticks"]
    assert int(a.predictor.replay.size()) == int(b.predictor.replay.size())
    np.testing.assert_allclose(np.asarray(a.predictor.replay.rewards),
                               np.asarray(b.predictor.replay.rewards),
                               rtol=1e-4, atol=1e-4)
    assert (np.asarray(a.predictor.replay.tick_idx)
            == np.asarray(b.predictor.replay.tick_idx)).all()
    assert (a.predictor._replay_times == b.predictor._replay_times).all()
    assert a.db.stats["rows"] == b.db.stats["rows"]
    a.db.close(), b.db.close()

"""Gap filling — detect missing ticks and impute them.

"Percepta is capable of detecting missing data and, when necessary, filling
in the gaps to maintain the continuity and reliability of the input data."

Strategies (selectable per stream):
  locf      last observation carried forward (across window boundaries via
            the carried ``last_value`` state)
  linear    bridge interior gaps linearly between observations (falls back
            to locf at the trailing edge)
  ewma      exponentially-weighted mean of past observations (state-carried)
  seasonal  mean of the same tick-of-day from history (state-carried slots)

The LOCF scan is a prefix "latest-observation" propagation — associative, so
it runs as ``jax.lax.associative_scan`` over the tick dim (O(log T) depth).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

STRATEGIES = ("locf", "linear", "ewma", "seasonal")


class GapFillState(NamedTuple):
    last_value: jax.Array   # (E, S) last observed value ever
    last_ts: jax.Array      # (E, S)
    ewma: jax.Array         # (E, S)
    seasonal: jax.Array     # (E, S, K) per time-of-day slot running mean
    seasonal_n: jax.Array   # (E, S, K)


def init_state(E, S, K=24) -> GapFillState:
    z = jnp.zeros((E, S), jnp.float32)
    return GapFillState(z, z - 1e30, z, jnp.zeros((E, S, K), jnp.float32),
                        jnp.zeros((E, S, K), jnp.float32))


def _locf_scan(values, observed, init_value, init_has):
    """Carry (value, has) of the latest observation along the tick axis."""
    v = jnp.concatenate([init_value[..., None], values], axis=-1)
    o = jnp.concatenate([init_has[..., None], observed], axis=-1)

    def combine(a, b):
        av, ao = a
        bv, bo = b
        return jnp.where(bo, bv, av), ao | bo

    cv, co = jax.lax.associative_scan(combine, (v, o), axis=-1)
    return cv[..., 1:], co[..., 1:]


def locf(values, observed, state: GapFillState):
    has_prev = state.last_ts > -1e29
    return _locf_scan(values, observed, state.last_value, has_prev)


def linear_bridge(values, observed):
    """Interior gaps -> linear interp between neighbours (edges untouched)."""
    T = values.shape[-1]
    idx = jnp.arange(T, dtype=jnp.float32)
    big = jnp.float32(1e30)
    # distance to previous / next observation via two locf passes
    fwd_v, fwd_has = _locf_scan(values, observed,
                                jnp.zeros(values.shape[:-1]),
                                jnp.zeros(values.shape[:-1], bool))
    fwd_i, _ = _locf_scan(jnp.broadcast_to(idx, values.shape), observed,
                          -jnp.ones(values.shape[:-1]),
                          jnp.zeros(values.shape[:-1], bool))
    rev = lambda x: jnp.flip(x, axis=-1)
    bwd_v, bwd_has = _locf_scan(rev(values), rev(observed),
                                jnp.zeros(values.shape[:-1]),
                                jnp.zeros(values.shape[:-1], bool))
    bwd_i, _ = _locf_scan(jnp.broadcast_to(idx, values.shape), rev(observed),
                          -jnp.ones(values.shape[:-1]),
                          jnp.zeros(values.shape[:-1], bool))
    bwd_v, bwd_has, bwd_i = rev(bwd_v), rev(bwd_has), (T - 1) - rev(bwd_i)
    span = jnp.maximum(bwd_i - fwd_i, 1e-6)
    frac = jnp.clip((idx - fwd_i) / span, 0.0, 1.0)
    interior = fwd_has & bwd_has
    interp = fwd_v + frac * (bwd_v - fwd_v)
    out = jnp.where(observed, values, jnp.where(interior, interp, fwd_v))
    return out, interior | fwd_has


def gap_fill(values, observed, state: GapFillState, tick_ts,
             strategy, *, tick_of_day=None, ewma_alpha: float = 0.2):
    """Fill unobserved ticks. strategy: (S,) int32 index into STRATEGIES or a
    single string. Returns (filled_values, filled_mask, new_state)."""
    E, S, T = values.shape
    locf_v, locf_has = locf(values, observed, state)
    lin_v, lin_has = linear_bridge(values, observed)
    lin_v = jnp.where(observed | lin_has, lin_v, locf_v)
    lin_has = lin_has | locf_has
    ew = state.ewma[..., None]
    ew_v = jnp.where(observed, values, jnp.broadcast_to(ew, values.shape))
    ew_has = jnp.broadcast_to(state.last_ts[..., None] > -1e29, values.shape)
    if tick_of_day is None:
        tick_of_day = jnp.zeros((E, T), jnp.int32)
    K = state.seasonal.shape[-1]
    sea = jnp.take_along_axis(
        state.seasonal, tick_of_day[:, None, :] % K, axis=-1)
    sea_n = jnp.take_along_axis(
        state.seasonal_n, tick_of_day[:, None, :] % K, axis=-1)
    sea_v = jnp.where(observed, values, sea)
    sea_has = sea_n > 0

    stack_v = jnp.stack([locf_v, lin_v, ew_v, sea_v])        # (4,E,S,T)
    stack_h = jnp.stack([locf_has, lin_has, ew_has, sea_has])
    if isinstance(strategy, str):
        out_v = stack_v[STRATEGIES.index(strategy)]
        out_h = stack_h[STRATEGIES.index(strategy)]
    else:
        sel = strategy[None, None, :, None]
        out_v = jnp.take_along_axis(stack_v, sel, axis=0)[0]
        out_h = jnp.take_along_axis(stack_h, sel, axis=0)[0]

    filled = (~observed) & out_h
    out = jnp.where(observed, values, jnp.where(filled, out_v, 0.0))

    # ---- state update (from OBSERVED ticks only) ----------------------------
    any_obs = observed.any(-1)
    big = jnp.float32(3.4e38)
    ts_b = jnp.broadcast_to(tick_ts[:, None, :], values.shape)
    last_key = jnp.where(observed, ts_b, -big)
    is_last = (last_key == last_key.max(-1, keepdims=True)) & observed
    new_last = jnp.einsum("est,est->es", values,
                          is_last.astype(jnp.float32)) / \
        jnp.maximum(is_last.sum(-1), 1)
    new_last_ts = jnp.max(jnp.where(observed, ts_b, -1e30), axis=-1)
    obs_mean = jnp.einsum("est,est->es", values, observed.astype(jnp.float32)) \
        / jnp.maximum(observed.sum(-1), 1)
    new_state = GapFillState(
        last_value=jnp.where(any_obs, new_last, state.last_value),
        last_ts=jnp.maximum(state.last_ts, new_last_ts),
        ewma=jnp.where(any_obs,
                       (1 - ewma_alpha) * state.ewma + ewma_alpha * obs_mean,
                       state.ewma),
        seasonal=_seasonal_update(state, values, observed, tick_of_day)[0],
        seasonal_n=_seasonal_update(state, values, observed, tick_of_day)[1],
    )
    return out, filled, new_state


def _seasonal_update(state, values, observed, tick_of_day):
    K = state.seasonal.shape[-1]
    oh = (jax.nn.one_hot(tick_of_day % K, K, dtype=jnp.float32)[:, None])  # (E,1,T,K)
    w = oh * observed[..., None]
    s = jnp.einsum("est,estk->esk", values, w)
    n = w.sum(axis=2)
    total_n = state.seasonal_n + n
    mean = jnp.where(total_n > 0,
                     (state.seasonal * state.seasonal_n + s) / jnp.maximum(total_n, 1),
                     state.seasonal)
    return mean, total_n

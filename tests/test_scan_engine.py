"""Scan-fused engine: run_many == K sequential run_tick calls, the
env-sharded shard_map build is bit-identical to the plain scan, batch
assembly preserves per-env isolation, and the dense harmonize fast path
matches the scatter path it replaces on small windows."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core import PerceptaPipeline, PipelineConfig
from repro.core import harmonize as hz
from repro.core.frame import RawWindow, make_raw_window
from repro.core.pipeline import init_state
from repro.core.reward import energy_reward_spec
from repro.runtime.accumulator import Accumulator
from repro.runtime.predictor import ActionSpace, Predictor, linear_policy
from repro.runtime.receivers import SimulatedDevice
from repro.runtime.system import PerceptaSystem, SourceSpec

K, E, S, T, M = 4, 2, 3, 8, 16


def _raws(rng):
    window_s = T * 60.0
    ts = (rng.uniform(0, window_s, (K, E, S, M))
          + np.arange(K)[:, None, None, None] * window_s)
    return make_raw_window(rng.normal(5, 2, (K, E, S, M)).astype(np.float32),
                           ts.astype(np.float32),
                           rng.rand(K, E, S, M) > 0.3)


def _starts():
    return jnp.asarray(np.arange(K, dtype=np.float32)[:, None]
                       * (T * 60.0) * np.ones((1, E), np.float32))


@pytest.mark.parametrize("gap_strategy", ["locf", "linear", "ewma",
                                          "seasonal"])
@pytest.mark.parametrize("anomaly_policy", ["clip", "mean", "missing"])
def test_scan_matches_sequential(gap_strategy, anomaly_policy, rng):
    cfg = PipelineConfig(n_envs=E, n_streams=S, n_ticks=T, tick_s=60.0,
                         max_samples=M, gap_strategy=gap_strategy,
                         anomaly_policy=anomaly_policy, k_sigma=3.0)
    raws = _raws(rng)
    starts = _starts()
    fused = PerceptaPipeline(cfg, mode="fused")
    scan = PerceptaPipeline(cfg, mode="scan")

    s = init_state(cfg)
    seq_feats, seq_frames = [], []
    for k in range(K):
        s, f, fr = fused.run_tick(
            s, RawWindow(raws.values[k], raws.timestamps[k], raws.valid[k]),
            starts[k])
        seq_feats.append(np.asarray(f.features))
        seq_frames.append(fr)

    s2, feats, frames = scan.run_many(init_state(cfg), raws, starts)

    assert_allclose(np.asarray(feats.features), np.stack(seq_feats),
                    rtol=1e-6, atol=1e-6)
    for k in range(K):
        assert (np.asarray(frames.observed[k])
                == np.asarray(seq_frames[k].observed)).all()
        assert (np.asarray(frames.filled[k])
                == np.asarray(seq_frames[k].filled)).all()
        assert (np.asarray(frames.anomalous[k])
                == np.asarray(seq_frames[k].anomalous)).all()
        assert_allclose(np.asarray(frames.values[k]),
                        np.asarray(seq_frames[k].values),
                        rtol=1e-6, atol=1e-6)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(s2)):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_scan_donation_reuses_state_safely(rng):
    """donate=True consumes the passed state; chained calls stay correct."""
    cfg = PipelineConfig(n_envs=E, n_streams=S, n_ticks=T, tick_s=60.0,
                         max_samples=M)
    raws = _raws(rng)
    starts = _starts()
    plain = PerceptaPipeline(cfg, mode="scan")
    donated = PerceptaPipeline(cfg, mode="scan", donate=True)
    s1, f1, _ = plain.run_many(init_state(cfg), raws, starts)
    s1, f1b, _ = plain.run_many(s1, raws, starts)
    s2, f2, _ = donated.run_many(init_state(cfg), raws, starts)
    s2, f2b, _ = donated.run_many(s2, raws, starts)
    assert_allclose(np.asarray(f1b.features), np.asarray(f2b.features),
                    rtol=1e-6, atol=1e-6)
    assert int(s2.tick_index) == 2 * K


# --------------------------------------------------------------------------
# Env-sharded scan: shard_map build == plain scan, bit for bit
# --------------------------------------------------------------------------

def test_scan_sharded_matches_scan_single_device(rng):
    """On one device the env mesh degenerates but the whole shard_map path
    (spec resolution, compat shims, donation) still executes."""
    cfg = PipelineConfig(n_envs=E, n_streams=S, n_ticks=T, tick_s=60.0,
                         max_samples=M)
    raws = _raws(rng)
    starts = _starts()
    scan = PerceptaPipeline(cfg, mode="scan")
    shard = PerceptaPipeline(cfg, mode="scan_sharded", donate=True)
    s1, f1, fr1 = scan.run_many(init_state(cfg), raws, starts)
    s2, f2, fr2 = shard.run_many(init_state(cfg), raws, starts)
    s2, f2b, _ = shard.run_many(s2, raws, starts)  # chained donated dispatch
    s1, f1b, _ = scan.run_many(s1, raws, starts)
    assert (np.asarray(f1.features) == np.asarray(f2.features)).all()
    assert (np.asarray(f1b.features) == np.asarray(f2b.features)).all()
    for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
        assert (np.asarray(a) == np.asarray(b)).all()
    for a, b in zip(jax.tree.leaves(fr1), jax.tree.leaves(fr2)):
        assert (np.asarray(a) == np.asarray(b)).all()


_SHARDED_SCRIPT = """
import numpy as np, jax, jax.numpy as jnp
assert len(jax.devices()) == 4, jax.devices()
from repro.core import PerceptaPipeline, PipelineConfig
from repro.core.frame import make_raw_window
from repro.core.pipeline import init_state
K, E, S, T, M = 3, 8, 2, 4, 8
cfg = PipelineConfig(n_envs=E, n_streams=S, n_ticks=T, tick_s=60.0,
                     max_samples=M)
rng = np.random.RandomState(0)
w = T * 60.0
ts = (rng.uniform(0, w, (K, E, S, M))
      + np.arange(K)[:, None, None, None] * w).astype(np.float32)
raws = make_raw_window(rng.normal(5, 2, (K, E, S, M)).astype(np.float32),
                       ts, rng.rand(K, E, S, M) > 0.3)
starts = jnp.asarray(np.arange(K, dtype=np.float32)[:, None] * w
                     * np.ones((1, E), np.float32))
scan = PerceptaPipeline(cfg, mode="scan")
shard = PerceptaPipeline(cfg, mode="scan_sharded", donate=True)
assert dict(shard.mesh.shape) == {"data": 4}, shard.mesh
s1, f1, fr1 = scan.run_many(init_state(cfg), raws, starts)
s2, f2, fr2 = shard.run_many(init_state(cfg), raws, starts)
assert (np.asarray(f1.features) == np.asarray(f2.features)).all()
for a, b in zip(jax.tree.leaves(s1) + jax.tree.leaves(fr1),
                jax.tree.leaves(s2) + jax.tree.leaves(fr2)):
    assert (np.asarray(a) == np.asarray(b)).all()
print("SHARDED_OK")
"""


def test_scan_sharded_multi_device_bit_identical():
    """Real >=2-device mesh: force a 4-device CPU platform in a subprocess
    (the flag must precede JAX init, so it can't run in this process)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARDED_OK" in out.stdout


# --------------------------------------------------------------------------
# Batch assembly: queue drain -> (K, E, S, M) stack keeps envs isolated
# --------------------------------------------------------------------------

def _small_system(mode, n_envs=2, scan_k=3):
    srcs = [
        SourceSpec("meter", "mqtt", SimulatedDevice("grid_kw", 60.0,
                                                    base=3.0, seed=1)),
        SourceSpec("price", "http", SimulatedDevice("price_eur", 300.0,
                                                    base=0.2, amplitude=0.05,
                                                    seed=2)),
    ]
    cfg = PipelineConfig(n_envs=n_envs, n_streams=2, n_ticks=8, tick_s=60.0,
                         max_samples=32)
    pred = Predictor(linear_policy(2, 2),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     n_envs, cfg.n_features, replay_capacity=64)
    envs = [f"bldg-{i}" for i in range(n_envs)]
    return PerceptaSystem(envs, srcs, cfg, pred, speedup=5000.0,
                          manual_time=True, mode=mode, scan_k=scan_k)


def test_scan_system_matches_fused_system():
    a = _small_system("fused")
    b = _small_system("scan", scan_k=3)
    ra = a.run_windows(6)
    rb = b.run_windows(6)
    assert len(rb) == 6
    for x, y in zip(ra, rb):
        assert abs(x["mean_reward"] - y["mean_reward"]) < 1e-3
        assert abs(x["observed_frac"] - y["observed_frac"]) < 1e-9
        assert x["anomalous"] == y["anomalous"]


def test_batch_assembly_matches_per_window_close(rng):
    """close_windows == stacked close_window on an identical record set."""
    from repro.runtime.records import Record
    streams = ["a", "b"]
    recs = [Record("env", streams[i % 2], float(t), float(i))
            for i, t in enumerate(rng.uniform(0, 300, 40))]
    acc1 = Accumulator("env", streams, 16)
    acc2 = Accumulator("env", streams, 16)
    acc1.ingest(recs)
    acc2.ingest(recs)
    bounds = [(0.0, 100.0), (100.0, 200.0), (200.0, 300.0)]
    v, t, m = acc1.close_windows(bounds)
    for k, (t0, t1) in enumerate(bounds):
        v1, t1_, m1 = acc2.close_window(t0, t1)
        assert (v[k] == v1).all() and (t[k] == t1_).all() \
            and (m[k] == m1).all()


def test_batch_assembly_env_isolation():
    """Records published to one env never appear in another env's rows."""
    from repro.runtime.records import Record
    sys_ = _small_system("scan")
    # publish records ONLY to bldg-0
    for i in range(20):
        sys_.broker.publish(Record("bldg-0", "grid_kw", 10.0 + i * 20.0,
                                   float(i + 1)))
    bounds = [sys_.window_bounds(j) for j in range(2)]
    raw, counts = sys_.assemble_windows(bounds)
    # records are timestamped 10..390 at 20s spacing; windows are 480s wide,
    # so every record lands in window 0 and the counts sum to the drain total
    assert counts == [20, 0]
    valid = np.asarray(raw.valid)        # (K, E, S, M)
    assert valid[:, 0].any()             # bldg-0 got its records
    assert not valid[:, 1].any()         # bldg-1 saw none of them
    assert np.asarray(raw.values)[:, 1].sum() == 0.0


# --------------------------------------------------------------------------
# Long horizons: window-relative device time keeps float32 exact at t~2^24
# --------------------------------------------------------------------------

T0_FAR = float(2 ** 24)     # ~194 days of stream time: absolute float32
                            # seconds quantize to >=1s here


def test_accumulator_rebase_preserves_subsecond_deltas(rng):
    """Rebased staging emits float64-exact window offsets; the absolute
    float32 cast it replaces collapses sub-second jitter at t~2^24."""
    from repro.runtime.records import Record
    offs = np.sort(rng.uniform(0.0, 100.0, 32))
    win = [(T0_FAR, T0_FAR + 100.0)]
    acc = Accumulator("e", ["s"], 64)
    acc.ingest([Record("e", "s", T0_FAR + float(o), 1.0) for o in offs])
    _, ts_rel, m = acc.close_windows(win, rebase=True)
    got = ts_rel[0, 0, m[0, 0]]
    assert np.array_equal(got, offs.astype(np.float32))
    # the absolute form really does degrade (regression guard's premise):
    acc2 = Accumulator("e", ["s"], 64)
    acc2.ingest([Record("e", "s", T0_FAR + float(o), 1.0) for o in offs])
    _, ts_abs, m2 = acc2.close_windows(win, rebase=False)
    deltas = np.diff(ts_abs[0, 0, m2[0, 0]].astype(np.float64))
    assert (deltas % 1.0 == 0.0).all()      # sub-second structure is gone


@pytest.mark.parametrize("gap_strategy", ["locf", "linear", "ewma",
                                          "seasonal"])
@pytest.mark.parametrize("mode", ["fused", "scan"])
def test_long_horizon_features_bit_identical_to_t0_zero(gap_strategy, mode,
                                                        rng):
    """The same relative record pattern streamed at t0=0 and t0=2^24 must
    produce bit-identical features/frames/rewards (tick_s=64 and 16
    seasonal slots make 2^24 a whole number of seasonal periods, so even
    the absolute tick-of-day phase coincides)."""
    from repro.runtime.records import Record
    window_s = 8 * 64.0
    offs = rng.uniform(0.0, 4 * window_s, 160)
    vals = rng.normal(5, 2, 160)

    def run(t0):
        from repro.core.reward import energy_reward_spec
        from repro.runtime.predictor import (ActionSpace, Predictor,
                                             linear_policy)
        srcs = [SourceSpec("m", "mqtt", SimulatedDevice("a", 60.0, seed=1)),
                SourceSpec("p", "http", SimulatedDevice("b", 300.0, seed=2))]
        cfg = PipelineConfig(n_envs=2, n_streams=2, n_ticks=8, tick_s=64.0,
                             max_samples=32, seasonal_slots=16,
                             gap_strategy=gap_strategy, k_sigma=3.0)
        pred = Predictor(linear_policy(2, 2),
                         energy_reward_spec(price_idx=1, grid_idx=0,
                                            temp_idx=0),
                         ActionSpace(np.array([-1., -1.]),
                                     np.array([1., 1.])),
                         2, cfg.n_features, replay_capacity=64)
        sys_ = PerceptaSystem(["e0", "e1"], srcs, cfg, pred, t0=t0,
                              manual_time=True, mode=mode, scan_k=2)
        for env, stream in (("e0", "a"), ("e1", "b")):
            for o, v in zip(offs, vals):
                sys_.broker.publish(Record(env, stream, t0 + float(o),
                                           float(v)))
        return [{k: v for k, v in r.items() if k != "latency_s"}
                for r in sys_.run_windows(4, pump=False)]

    assert run(0.0) == run(T0_FAR)

@pytest.mark.parametrize("agg", list(hz.AGGS))
def test_harmonize_dense_matches_scatter(agg, rng, monkeypatch):
    raw = make_raw_window(rng.normal(5, 2, (3, 4, 24)).astype(np.float32),
                          rng.uniform(0, 600, (3, 4, 24)).astype(np.float32),
                          rng.rand(3, 4, 24) > 0.3)
    ticks = hz.tick_grid(jnp.zeros((3,)), 60.0, 10)
    v_dense, o_dense = hz.harmonize_segment(raw, ticks, 60.0, agg)
    monkeypatch.setattr(hz, "_DENSE_MT_MAX", 0)   # force the scatter path
    v_seg, o_seg = hz.harmonize_segment(raw, ticks, 60.0, agg)
    v_oh, o_oh = hz.harmonize(raw, ticks, 60.0, agg)
    assert (np.asarray(o_dense) == np.asarray(o_seg)).all()
    assert (np.asarray(o_dense) == np.asarray(o_oh)).all()
    assert_allclose(np.asarray(v_dense), np.asarray(v_seg),
                    rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(v_dense), np.asarray(v_oh),
                    rtol=1e-5, atol=1e-5)

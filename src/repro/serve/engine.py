"""Serving engine: continuous batching over decode slots.

A fixed pool of B slots (B = the arch's decode batch) runs one fused
``decode_step`` per engine tick; requests are admitted into free slots at
prefill time (their prompt is prefilled into the slot's rows of the batched
KV cache via the per-sample ``lengths``). Finished slots (eos/max-tokens)
free immediately — admission and retirement never stall the running batch,
which is the throughput-critical property (vLLM-style, adapted to fixed
TPU-friendly shapes: no paging, per-slot ring/global caches as the arch
dictates).

Straggler/timeout mitigation at the request level: requests exceeding their
deadline are retired with partial output so one stuck request can't hold a
slot hostage.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat


@dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (L,) int32
    max_new_tokens: int = 32
    eos_id: int = -1               # -1 = never
    deadline_s: float = 60.0
    submitted_at: float = field(default_factory=time.time)
    tokens: list = field(default_factory=list)
    done: bool = False
    finish_reason: str = ""


class ServeEngine:
    def __init__(self, model, params, batch_slots: int, max_seq: int,
                 *, greedy: bool = True, seed: int = 0):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_seq = max_seq
        self.greedy = greedy
        self.rng = jax.random.PRNGKey(seed)
        self.cache = model.init_cache(batch_slots, max_seq)
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        n_groups = model.cfg.n_groups

        def step(params, inputs, cache, adv):
            """decode + per-slot advance masking: non-live slots keep their
            cache rows and lengths (recurrent states must not see pad
            tokens; KV writes are naturally masked by lengths)."""
            logits, new_cache = model.decode_step(params, inputs, cache)

            def merge(old, new):
                if old.ndim >= 1 and old.shape[0] == batch_slots \
                        and not (old.ndim >= 2 and old.shape[0] == n_groups
                                 and old.shape[1] == batch_slots):
                    m = adv.reshape((batch_slots,) + (1,) * (old.ndim - 1))
                    return jnp.where(m > 0, new, old)
                if old.ndim >= 2 and old.shape[0] == n_groups \
                        and old.shape[1] == batch_slots:
                    m = adv.reshape((1, batch_slots) + (1,) * (old.ndim - 2))
                    return jnp.where(m > 0, new, old)
                return new
            merged = jax.tree.map(merge, cache, new_cache)
            return logits, merged

        # donation routes through compat.jit_donated (the repo-wide rule:
        # it de-aliases duplicate donated buffers and keeps .lower working)
        self._decode = compat.jit_donated(step, donate_argnums=(2,))
        self._last_tokens = np.zeros((batch_slots, 1), np.int32)
        self.stats = {"ticks": 0, "tokens_out": 0, "admitted": 0,
                      "retired": 0, "timeouts": 0}

    # ---------------------------------------------------------------- intake
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        """Prefill queued requests into free slots (token-by-token feed —
        batched single-slot prefill keeps one jitted shape; a production
        deployment adds a bucketed prefill step per prompt-length bin)."""
        for i in range(self.B):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            self.stats["admitted"] += 1
            # reset slot: lengths[i]=0 kills the slot's old cache rows (all
            # reads are masked by slot_positions validity)
            self.cache["lengths"] = self.cache["lengths"].at[i].set(0)
            # feed prompt[:-1] through decode steps for this slot only;
            # prompt[-1] stays pending so the next engine tick's logits
            # produce the FIRST generated token (no spurious pad feed)
            for t in req.prompt[:-1]:
                toks = self._last_tokens.copy()
                toks[i, 0] = int(t)
                mask = np.zeros((self.B,), np.int32)
                mask[i] = 1
                self._step_masked(toks, mask)
            self._last_tokens[i, 0] = int(req.prompt[-1])
            self.slots[i] = req

    def _step_masked(self, tokens: np.ndarray, advance_mask: np.ndarray):
        """One decode step where only masked slots advance."""
        adv = jnp.asarray(advance_mask, jnp.int32)
        logits, self.cache = self._decode(self.params,
                                          {"tokens": jnp.asarray(tokens)},
                                          self.cache, adv)
        return logits

    # ----------------------------------------------------------------- tick
    def tick(self) -> Dict[int, int]:
        """One engine iteration: admit, decode one token for live slots,
        retire finished/timed-out requests. Returns {rid: token}."""
        self._admit()
        live = np.array([1 if r is not None else 0 for r in self.slots],
                        np.int32)
        if live.sum() == 0:
            return {}
        logits = self._step_masked(self._last_tokens, live)
        self.stats["ticks"] += 1
        if self.greedy:
            nxt = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
        else:
            self.rng, k = jax.random.split(self.rng)
            nxt = np.asarray(jax.random.categorical(k, logits)).astype(np.int32)
        out = {}
        now = time.time()
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[i])
            req.tokens.append(tok)
            self._last_tokens[i, 0] = tok
            out[req.rid] = tok
            self.stats["tokens_out"] += 1
            timeout = (now - req.submitted_at) > req.deadline_s
            if tok == req.eos_id or len(req.tokens) >= req.max_new_tokens \
                    or timeout:
                req.done = True
                req.finish_reason = ("timeout" if timeout else
                                     "eos" if tok == req.eos_id else "length")
                if timeout:
                    self.stats["timeouts"] += 1
                self.stats["retired"] += 1
                self.slots[i] = None
                self._last_tokens[i, 0] = 0
                self.cache["lengths"] = self.cache["lengths"].at[i].set(0)
        return out

    def run_until_drained(self, requests: List[Request],
                          max_ticks: int = 10_000) -> List[Request]:
        for r in requests:
            self.submit(r)
        for _ in range(max_ticks):
            self.tick()
            if not self.queue and all(s is None for s in self.slots):
                break
        return requests

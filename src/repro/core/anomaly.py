"""Anomaly handling — spike detection + replacement before the model.

"...detecting anomalies such as data spikes, and replacing missing values
based on historical patterns or recent observations."

Detection: robust z-score against carried running statistics (mean/var via a
numerically-stable exponential Welford) or median-absolute-deviation within
the window. Replacement: clip to the k-sigma envelope, substitute the
running mean, or mark-as-missing so gap-filling handles it.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

POLICIES = ("clip", "mean", "missing")


class AnomalyState(NamedTuple):
    mean: jax.Array    # (E, S) running mean
    var: jax.Array     # (E, S) running variance
    count: jax.Array   # (E, S)


def init_state(E, S) -> AnomalyState:
    z = jnp.zeros((E, S), jnp.float32)
    return AnomalyState(z, jnp.ones((E, S), jnp.float32), z)


def detect_zscore(values, observed, state: AnomalyState, k_sigma: float = 6.0):
    """Spike where |x - mean| > k * sigma (only once stats have warmed up)."""
    sigma = jnp.sqrt(jnp.maximum(state.var, 1e-12))
    z = jnp.abs(values - state.mean[..., None]) / sigma[..., None]
    warm = (state.count > 8.0)[..., None]
    return observed & warm & (z > k_sigma)


def detect_mad(values, observed, k: float = 8.0):
    """Window-local median-absolute-deviation detector (no state needed)."""
    big = jnp.float32(3.4e38)
    masked = jnp.where(observed, values, jnp.nan)
    med = jnp.nanmedian(masked, axis=-1, keepdims=True)
    mad = jnp.nanmedian(jnp.abs(masked - med), axis=-1, keepdims=True)
    mad = jnp.where(jnp.isnan(mad) | (mad < 1e-9), big, mad)
    dev = jnp.abs(values - jnp.where(jnp.isnan(med), 0.0, med))
    return observed & (dev > k * 1.4826 * mad)


def replace(values, observed, spikes, state: AnomalyState,
            policy: str = "clip", k_sigma: float = 6.0):
    """Returns (values', observed', replaced_mask)."""
    sigma = jnp.sqrt(jnp.maximum(state.var, 1e-12))[..., None]
    mean = state.mean[..., None]
    if policy == "clip":
        clipped = jnp.clip(values, mean - k_sigma * sigma, mean + k_sigma * sigma)
        out = jnp.where(spikes, clipped, values)
        return out, observed, spikes
    if policy == "mean":
        out = jnp.where(spikes, jnp.broadcast_to(mean, values.shape), values)
        return out, observed, spikes
    if policy == "missing":
        return jnp.where(spikes, 0.0, values), observed & ~spikes, spikes
    raise ValueError(policy)


def update_state(state: AnomalyState, values, observed,
                 alpha: float = 0.05) -> AnomalyState:
    """Exponential Welford over clean observed ticks (batched over E, S)."""
    n = observed.sum(-1)
    mean_w = jnp.einsum("est,est->es", values, observed.astype(jnp.float32)) \
        / jnp.maximum(n, 1)
    var_w = jnp.einsum("est,est->es", jnp.square(values - mean_w[..., None]),
                       observed.astype(jnp.float32)) / jnp.maximum(n, 1)
    has = n > 0
    boot = state.count < 1
    new_mean = jnp.where(boot, mean_w,
                         (1 - alpha) * state.mean + alpha * mean_w)
    new_var = jnp.where(boot, jnp.maximum(var_w, 1e-6),
                        (1 - alpha) * state.var
                        + alpha * (var_w + jnp.square(mean_w - state.mean)))
    return AnomalyState(
        mean=jnp.where(has, new_mean, state.mean),
        var=jnp.where(has, new_var, state.var),
        count=state.count + n,
    )

"""MusicGen-medium — decoder-only LM over EnCodec tokens (MHA).
The EnCodec frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings; the backbone is the transformer below.
[arXiv:2306.05284; hf]"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,         # MHA
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,       # EnCodec codebook size
    layer_pattern=(ATTN_GLOBAL,),
    frontend="embeddings",  # precomputed EnCodec frame embeddings in
    n_codebooks=4,
    rope_theta=10000.0,
    source="arXiv:2306.05284; hf:facebook/musicgen-medium",
)

"""Predictor — routes features to the decision model, validates actions,
computes rewards, logs for retraining, hands decisions to Forwarders.

The model is pluggable (``ModelAdapter``): a vector policy (edge RL), an
LM-family model through a TokenCodec, or anything callable on (E, F)
features. This is the "support any type of AI model that consumes this
data" requirement.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import replay as rp
from repro.core.reward import RewardSpec, validate_actions


@dataclass
class ActionSpace:
    low: np.ndarray
    high: np.ndarray

    @property
    def n(self):
        return len(self.low)


class ModelAdapter:
    """Wraps any policy fn(features (E,F)) -> actions (E,A)."""

    def __init__(self, fn: Callable, name: str = "policy"):
        self.fn = fn
        self.name = name

    def __call__(self, features):
        return self.fn(features)


def linear_policy(n_features: int, n_actions: int, seed: int = 0,
                  low=-1.0, high=1.0) -> ModelAdapter:
    """A small deterministic policy standing in for the deployed RL model."""
    k = jax.random.PRNGKey(seed)
    W = jax.random.normal(k, (n_features, n_actions)) / jnp.sqrt(n_features)

    @jax.jit
    def fn(feats):
        return jnp.tanh(feats @ W) * (high - low) / 2 + (high + low) / 2

    return ModelAdapter(fn, "linear_policy")


class Predictor:
    def __init__(self, model: ModelAdapter, reward_spec: RewardSpec,
                 action_space: ActionSpace, n_envs: int, n_features: int,
                 db=None, replay_capacity: int = 4096):
        self.model = model
        self.reward_spec = reward_spec
        self.action_space = action_space
        self.db = db
        self.replay = rp.init(n_envs, replay_capacity, n_features,
                              action_space.n)
        self._prev = {
            "obs": jnp.zeros((n_envs, n_features), jnp.float32),
            "actions": jnp.zeros((n_envs, action_space.n), jnp.float32),
            "have": False,
        }
        self.stats = {"ticks": 0, "violations": 0}
        low = jnp.asarray(action_space.low, jnp.float32)
        high = jnp.asarray(action_space.high, jnp.float32)

        def _step(features, raw, prev_obs, prev_actions, replay, tick_time,
                  have_prev):
            actions = self.model(features)
            actions, violated = validate_actions(actions, low, high)
            # rewards are computed on engineering units, not z-scores
            reward, per_term = self.reward_spec.compute(
                raw, actions, prev_actions)
            new_replay = jax.lax.cond(
                have_prev,
                lambda r: rp.add(r, prev_obs, prev_actions, reward, features,
                                 tick_time),
                lambda r: r,
                replay)
            return actions, reward, per_term, violated, new_replay

        self._step = jax.jit(_step)

    def on_tick(self, features, tick_time, raw=None):
        """features: (E, F) device array; returns host actions + rewards."""
        raw = features if raw is None else raw
        actions, reward, per_term, violated, self.replay = self._step(
            features, raw, self._prev["obs"], self._prev["actions"],
            self.replay, jnp.asarray(tick_time, jnp.float32),
            jnp.asarray(self._prev["have"]))
        self._prev = {"obs": features, "actions": actions, "have": True}
        self.stats["ticks"] += 1
        self.stats["violations"] += int(np.asarray(violated).sum())
        return np.asarray(actions), np.asarray(reward), np.asarray(per_term)

"""Streaming normalization so "data can be effectively used by models".

Running per-(env, stream) statistics with Welford-style merging of each
window's batch statistics; z-score or min-max normalization; exact
denormalization for decoding model outputs back to engineering units.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class NormState(NamedTuple):
    count: jax.Array  # (E, S)
    mean: jax.Array
    m2: jax.Array     # sum of squared deviations
    min: jax.Array
    max: jax.Array


def init_state(E, S) -> NormState:
    z = jnp.zeros((E, S), jnp.float32)
    return NormState(z, z, z, jnp.full((E, S), jnp.inf, jnp.float32),
                     jnp.full((E, S), -jnp.inf, jnp.float32))


def update(state: NormState, values, observed) -> NormState:
    """Chan/Welford parallel merge of this window's stats into the running
    stats — one vectorized step per window, no per-sample loop."""
    w = observed.astype(jnp.float32)
    nb = w.sum(-1)
    mb = jnp.einsum("est,est->es", values, w) / jnp.maximum(nb, 1)
    m2b = jnp.einsum("est,est->es", jnp.square(values - mb[..., None]), w)
    na = state.count
    n = na + nb
    delta = mb - state.mean
    mean = jnp.where(n > 0, state.mean + delta * nb / jnp.maximum(n, 1), state.mean)
    m2 = state.m2 + m2b + jnp.square(delta) * na * nb / jnp.maximum(n, 1)
    big = jnp.float32(3.4e38)
    vmin = jnp.minimum(state.min, jnp.min(jnp.where(observed, values, big), -1))
    vmax = jnp.maximum(state.max, jnp.max(jnp.where(observed, values, -big), -1))
    has = nb > 0
    return NormState(
        count=n,
        mean=mean,
        m2=jnp.where(has, m2, state.m2),
        min=jnp.where(has, vmin, state.min),
        max=jnp.where(has, vmax, state.max),
    )


def sigma(state: NormState):
    return jnp.sqrt(jnp.maximum(state.m2 / jnp.maximum(state.count - 1, 1), 1e-12))


def znorm(state: NormState, values):
    """values (E, S, ...) -> z-scores using running stats."""
    ex = (...,) + (None,) * (values.ndim - 2)
    return (values - state.mean[ex]) / jnp.maximum(sigma(state)[ex], 1e-6)


def denorm_z(state: NormState, z):
    ex = (...,) + (None,) * (z.ndim - 2)
    return z * jnp.maximum(sigma(state)[ex], 1e-6) + state.mean[ex]


def minmax(state: NormState, values):
    ex = (...,) + (None,) * (values.ndim - 2)
    span = jnp.maximum(state.max[ex] - state.min[ex], 1e-6)
    return jnp.clip((values - state.min[ex]) / span, 0.0, 1.0)


def denorm_minmax(state: NormState, u):
    ex = (...,) + (None,) * (u.ndim - 2)
    span = jnp.maximum(state.max[ex] - state.min[ex], 1e-6)
    return u * span + state.min[ex]

"""Experience storage for retraining — on-device ring buffer + anonymization.

"...storing the necessary data for model retraining in the future,
anonymizing it and delivering it to the node responsible for training."

The buffer is a fixed-capacity ring over (obs, action, reward, next_obs,
tick_idx, policy_version, valid) batched across environments, living on
device (shardable over the env dim). ``anonymize`` applies a salted hash to
environment identities so exported datasets can't be joined back to
buildings. ``policy_version`` attributes every banked action to the policy
that produced it (online retraining hot-swaps policies at batch
boundaries; see ``runtime.trainer``).

Elastic slot pools: under ``PerceptaSystem(elastic=True)`` the env axis is
a padded slot pool and only a masked subset of rows is live. The ring keeps
ONE scalar cursor — the write chain is per-window, shared by every slot, so
the slot-pool ring stays bit-identical to the dense ring on the surviving
rows — and records liveness per cell in the ``valid`` (E, C) column: a
write with ``env_mask`` still materializes every env row of the window
(garbage rows are cheaper than a row-compacting scatter, which would break
the env-mask-gate contract) but marks only the active rows valid.
``sample_device`` ANDs cell validity into its ``valid`` output so masked
garbage never weights a loss; dense writes mark every row valid, keeping
the non-elastic path's outputs unchanged.

Long-horizon time rule: the device-side per-transition time is the EXACT
int32 predictor tick index, never a float32 absolute timestamp — absolute
float32 seconds quantize to >=1s past t~2^24 s (~194 days of stream time),
which collapses consecutive window ends into the same stored value (the
same failure class the scan engine's window-relative rebase fixed for raw
samples). The absolute float64 wall time of each tick lives host-side (the
``Predictor`` keeps a slot-aligned float64 mirror) and is reconstructed at
export time by :func:`export_for_training`.
"""
from __future__ import annotations

import hashlib
from typing import NamedTuple

import jax
import jax.numpy as jnp


class ReplayBuffer(NamedTuple):
    obs: jax.Array        # (E, C, F)
    actions: jax.Array    # (E, C, A)
    rewards: jax.Array    # (E, C)
    next_obs: jax.Array   # (E, C, F)
    tick_idx: jax.Array   # (E, C) int32 — exact predictor tick index
    version: jax.Array    # (E, C) int32 — policy_version that produced the
                          # banked action (attribution column; monotone in
                          # chronological order under online retraining)
    valid: jax.Array      # (E, C) bool — cell liveness: True iff the env
                          # row was ACTIVE when its window was banked
                          # (always True for dense writes; the elastic slot
                          # pool gates garbage rows out of sampling here)
    cursor: jax.Array     # () int32 — total ticks written (ring position)

    @property
    def capacity(self):
        return self.obs.shape[1]

    def size(self):
        return jnp.minimum(self.cursor, self.capacity)


def init(E, capacity, n_features, n_actions) -> ReplayBuffer:
    return ReplayBuffer(
        obs=jnp.zeros((E, capacity, n_features), jnp.float32),
        actions=jnp.zeros((E, capacity, n_actions), jnp.float32),
        rewards=jnp.zeros((E, capacity), jnp.float32),
        next_obs=jnp.zeros((E, capacity, n_features), jnp.float32),
        tick_idx=jnp.zeros((E, capacity), jnp.int32),
        version=jnp.zeros((E, capacity), jnp.int32),
        valid=jnp.zeros((E, capacity), jnp.bool_),
        cursor=jnp.zeros((), jnp.int32),
    )


def add(buf: ReplayBuffer, obs, actions, rewards, next_obs,
        tick_idx, version=0, env_mask=None) -> ReplayBuffer:
    """Write one tick for every env at the ring position (jit-safe).

    ``tick_idx`` is the integer tick index (scalar or (E,)), stored exactly
    as int32 — see the module docstring's long-horizon time rule.
    ``version`` is the policy_version that produced the banked action
    (scalar or (E,)), defaulting to 0 for callers without online training.
    ``env_mask`` (E,) bool marks which rows are live this tick (elastic
    slot pools); None means every row (the dense contract).
    """
    i = jnp.mod(buf.cursor, buf.capacity)
    upd = lambda b, x: b.at[:, i].set(jnp.asarray(x).astype(b.dtype))
    E = buf.obs.shape[0]
    live = (jnp.ones((E,), jnp.bool_) if env_mask is None
            else jnp.broadcast_to(jnp.asarray(env_mask, jnp.bool_), (E,)))
    return ReplayBuffer(
        obs=upd(buf.obs, obs),
        actions=upd(buf.actions, actions),
        rewards=upd(buf.rewards, rewards),
        next_obs=upd(buf.next_obs, next_obs),
        tick_idx=upd(buf.tick_idx, tick_idx),
        version=upd(buf.version, version),
        valid=upd(buf.valid, live),
        cursor=buf.cursor + 1,
    )


def add_many(buf: ReplayBuffer, obs, actions, rewards, next_obs, tick_idx,
             mask=None, version=None, env_mask=None) -> ReplayBuffer:
    """Write K stacked ticks in ONE jit-safe call (leading K axis on every
    argument; ``tick_idx`` is (K,)).

    Implemented as a ``lax.scan`` carrying the buffer over :func:`add`, so
    the ring semantics — write order, cursor advance, wraparound, even
    K > capacity overwrites — are bit-identical to K sequential ``add``
    calls. ``mask`` (K,) bool skips rows without advancing the cursor
    (scan-safe replacement for the host-side have-prev ``cond``);
    ``env_mask`` (K, E) bool marks per-window row liveness (the ``valid``
    column), None meaning every row live.
    """
    K = obs.shape[0]
    E = buf.obs.shape[0]
    if mask is None:
        mask = jnp.ones((K,), jnp.bool_)
    if version is None:
        version = jnp.zeros((K,), jnp.int32)
    if env_mask is None:
        env_mask = jnp.ones((K, E), jnp.bool_)

    def body(b, xs):
        m, o, a, r, n, t, ver, em = xs
        return jax.lax.cond(
            m, lambda bb: add(bb, o, a, r, n, t, ver, em),
            lambda bb: bb, b), None

    out, _ = jax.lax.scan(body, buf,
                          (mask, obs, actions, rewards, next_obs, tick_idx,
                           jnp.asarray(version, jnp.int32),
                           jnp.asarray(env_mask, jnp.bool_)))
    return out


def add_batch(buf: ReplayBuffer, obs, actions, rewards, next_obs, tick_idx,
              mask=None, version=None, env_mask=None) -> ReplayBuffer:
    """Write K stacked ticks as ONE unique-indices scatter (jit-safe).

    Final buffer contents and cursor are bit-identical to K sequential
    :func:`add` calls under ``mask`` (:func:`add_many` semantics), but the
    buffer never threads a ``lax.scan`` carry: the fused decision engine
    measured a full ring copy per dispatch when the (E, C, F) storage rode
    the scan carry, which grew with capacity and ate the fusion win. Here
    the ring is an ordinary donated input updated by one scatter, which
    XLA aliases in place.

    Ring semantics drop out of a pre-reduction instead of write order:
    masked row j lands at position ``cursor + (#masked rows <= j) - 1``;
    once K exceeds capacity only the LAST ``capacity`` masked rows are
    visible after wraparound, so earlier rows are routed to distinct
    out-of-range slots and dropped by the scatter (``mode="drop"``) —
    every surviving slot is written exactly once, so ``unique_indices``
    holds and no ordering ambiguity exists.

    ``env_mask`` (K, E) bool is per-window row liveness: slot positions
    stay a function of the SCALAR chain ``mask`` alone (the cursor is
    shared by every slot), and ``env_mask`` lands only in the ``valid``
    column's scatter VALUES — never in index math, which is exactly the
    combining discipline the ``env-mask-gate`` contract rule enforces.
    """
    K = obs.shape[0]
    if mask is None:
        mask = jnp.ones((K,), jnp.bool_)
    if version is None:
        version = jnp.zeros((K,), jnp.int32)
    if env_mask is None:
        env_mask = jnp.ones((K, buf.obs.shape[0]), jnp.bool_)
    nm = mask.astype(jnp.int32)
    pos = buf.cursor + jnp.cumsum(nm) - 1      # write position per masked row
    total = buf.cursor + nm.sum()
    C = buf.capacity
    keep = mask & (pos >= total - C)           # last C masked writes survive
    # dropped rows get distinct out-of-range slots: unique_indices stays a
    # true promise and mode="drop" discards them
    slot = jnp.where(keep, jnp.mod(pos, C),
                     C + jnp.arange(K, dtype=pos.dtype))

    def upd(b, x):
        # b (E, C, ...), x (K, E, ...) -> rows swap to (E, K, ...)
        v = jnp.moveaxis(jnp.asarray(x).astype(b.dtype), 0, 1)
        return b.at[:, slot].set(v, mode="drop", unique_indices=True)

    E = buf.obs.shape[0]
    tick_b = jnp.broadcast_to(jnp.asarray(tick_idx, jnp.int32)[:, None],
                              (K, E))
    ver_b = jnp.broadcast_to(jnp.asarray(version, jnp.int32)[:, None],
                             (K, E))
    return ReplayBuffer(
        obs=upd(buf.obs, obs),
        actions=upd(buf.actions, actions),
        rewards=upd(buf.rewards, rewards),
        next_obs=upd(buf.next_obs, next_obs),
        tick_idx=upd(buf.tick_idx, tick_b),
        version=upd(buf.version, ver_b),
        valid=upd(buf.valid, jnp.asarray(env_mask, jnp.bool_)),
        cursor=total,
    )


def sample(buf: ReplayBuffer, rng, batch: int):
    """Uniform sample of (env, slot) transitions for retraining (host-side
    entry point: raises on an empty buffer instead of fabricating all-zero
    transitions from the untouched ring storage)."""
    if int(buf.cursor) == 0:
        raise ValueError("cannot sample from an empty ReplayBuffer "
                         "(no transitions have been added)")
    E = buf.obs.shape[0]
    n = buf.size()
    ke, ks = jax.random.split(rng)
    es = jax.random.randint(ke, (batch,), 0, E)
    ss = jax.random.randint(ks, (batch,), 0, n)
    take = lambda x: x[es, ss]
    return {"obs": take(buf.obs), "actions": take(buf.actions),
            "rewards": take(buf.rewards), "next_obs": take(buf.next_obs),
            "tick_idx": take(buf.tick_idx), "version": take(buf.version),
            "valid": take(buf.valid)}


def sample_device(buf: ReplayBuffer, rng, batch: int):
    """Jit-safe uniform minibatch draw FROM THE RING IN PLACE.

    The device-side twin of :func:`sample` for the online training path:
    no host transfer, no ``export_for_training`` round-trip — the gather
    reads the live (donation-managed) ring storage directly, so a train
    step jitted around this costs one dispatch and touches only
    ``batch`` rows.

    Where the host entry point RAISES on an empty buffer, a jitted fn
    cannot branch on the traced ``cursor`` — instead the draw gates on
    ``size == 0`` with a ``valid`` mask: slot indices are drawn uniformly
    from ``[0, max(size, 1))`` (so a partially-filled ring only ever
    yields live rows, and a wrapped ring samples every slot) and
    ``valid`` is False for every row when the ring holds no transitions.
    Consumers weight their loss by ``valid``; with the same threaded PRNG
    ``rng`` and the same ring size the draw is bit-deterministic.

    Under an elastic slot pool the per-cell ``valid`` column ANDs into the
    returned ``valid`` — the draw itself stays the SAME (es, ss) gather
    for the same rng (no mask-dependent index math), so a masked pool and
    the dense reference consume identical PRNG streams; rows that landed
    on an inactive slot simply weight to zero.
    """
    E = buf.obs.shape[0]
    n = buf.size()
    ke, ks = jax.random.split(rng)
    es = jax.random.randint(ke, (batch,), 0, E)
    ss = jax.random.randint(ks, (batch,), 0, jnp.maximum(n, 1))
    take = lambda x: x[es, ss]
    valid = jnp.broadcast_to(n > 0, (batch,)) & take(buf.valid)
    return {"obs": take(buf.obs), "actions": take(buf.actions),
            "rewards": take(buf.rewards), "next_obs": take(buf.next_obs),
            "tick_idx": take(buf.tick_idx), "version": take(buf.version),
            "valid": valid}


def anonymize_env_ids(env_ids, salt: str) -> list:
    """Salted-hash pseudonyms for export (host-side; not jit)."""
    out = []
    for e in env_ids:
        h = hashlib.sha256((salt + "::" + str(e)).encode()).hexdigest()[:16]
        out.append(f"env-{h}")
    return out


def chronological_order(buf: ReplayBuffer):
    """Slot permutation putting the ring's live rows in write order.

    Until the ring wraps (``cursor <= capacity``) slots 0..size-1 already
    are chronological; past that the oldest live row sits at
    ``cursor % capacity`` and the raw slot order is scrambled — exporting
    it as-is interleaves new and old transitions, corrupting any
    order-sensitive consumer (n-step returns, episode reconstruction).
    """
    import numpy as np
    c = int(buf.cursor)
    C = buf.capacity
    if c > C:
        head = c % C
        return np.concatenate([np.arange(head, C), np.arange(head)])
    return np.arange(c)


def export_for_training(buf: ReplayBuffer, env_ids, salt: str,
                        slot_times=None) -> dict:
    """Materialize an anonymized dataset dict (host-side), rows rolled to
    chronological order even after the ring has wrapped.

    ``slot_times`` is the optional (capacity,) float64 host-side mirror of
    absolute tick times (``Predictor._replay_times``); when given, the
    exported ``times`` column is the exact float64 absolute time of every
    transition. Without it, ``times`` falls back to the float64 value of
    the stored integer tick index — still exact and strictly ordered on
    any horizon, just not in wall seconds.
    """
    import numpy as np
    order = chronological_order(buf)
    take = lambda x: np.asarray(x)[:, order]
    tick_idx = take(buf.tick_idx)
    if slot_times is not None:
        times = np.asarray(slot_times, np.float64)[order]
        times = np.broadcast_to(times[None, :], tick_idx.shape).copy()
    else:
        times = tick_idx.astype(np.float64)
    return {
        "env_ids": anonymize_env_ids(env_ids, salt),
        "obs": take(buf.obs),
        "actions": take(buf.actions),
        "rewards": take(buf.rewards),
        "next_obs": take(buf.next_obs),
        "tick_idx": tick_idx,
        "version": take(buf.version),
        "valid": take(buf.valid),
        "times": times,
    }

"""Static analysis for Percepta's documented invariants (ROADMAP item 2).

Two engines, one registry:

  * :mod:`repro.analysis.jaxpr_check` — traces a policy / custom reward fn /
    ``DecideFns.step`` to a closed jaxpr and verifies, WITHOUT executing it,
    the contracts the sharded/fused engines rest on: no cross-env
    contractions or reductions (the ``linear_policy`` dot-phrasing rule),
    no collectives in shard_map-bound fns, no float32 narrowing of
    absolute-time values (the t~2^24 s quantization class fixed in PR 3/4),
    and no host callbacks hiding inside scan bodies.
    ``PerceptaSystem`` runs :func:`check_system` at construction for the
    ``*_sharded`` and fused-decide modes; ``RewardSpec`` runs
    :func:`check_reward_terms` on custom fns at spec construction.

  * :mod:`repro.analysis.lint` — an AST lint over the repo source enforcing
    the host-side invariants (compat routing, snapshot accessors, async
    donation, one-lock-per-call).  CLI: ``python -m repro.analysis.lint``
    (``--format=json|github`` for machine-readable findings / CI per-line
    annotations).

On top of the per-fn checks, :mod:`repro.analysis.certify` runs the FULL
catalog over a policy builder — recurrent-carry fixed point
(``carry-env-mix``), pallas BlockSpec env routing (``pallas-env-block``)
and the two-env-count param-replication probe — and emits a cached
:class:`~repro.analysis.certify.PolicyCertificate` that the fused/sharded
system modes demand at construction (``runtime.policies`` registry).

The rule catalog lives in :mod:`repro.analysis.contracts` and is mirrored in
ROADMAP.md ("Invariant catalog").
"""
from repro.analysis.contracts import (  # noqa: F401
    ContractViolation, Violation, JAXPR_RULES, LINT_RULES,
    TAG_ENV, TAG_TIME,
)
from repro.analysis.jaxpr_check import (  # noqa: F401
    Rules, check_fn, check_policy, check_reward_fn, check_reward_terms,
    check_decide_fns, check_system, check_train_step, check_builtins,
)
from repro.analysis.certify import (  # noqa: F401
    CERTIFY_RULES, PolicyCertificate, certify_policy,
)

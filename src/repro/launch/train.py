"""Training launcher: ``python -m repro.launch.train --arch qwen3-0.6b:smoke``.

On this CPU container run reduced (``:smoke``) configs; on a pod the same
entrypoint takes the full arch ids and the production mesh.
"""
from __future__ import annotations

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 pod mesh (needs 256 devices)")
    ap.add_argument("--set", action="append", default=[],
                    help="ShardingConfig override key=value")
    args = ap.parse_args()

    import jax

    from repro.configs.base import (ShapeConfig, ShardingConfig, TrainConfig,
                                    apply_overrides)
    from repro.configs.registry import get_config
    from repro.launch.mesh import make_production_mesh, make_smoke_mesh
    from repro.train.loop import train

    cfg = get_config(args.arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    mesh = make_production_mesh() if args.production_mesh else make_smoke_mesh()
    perf = apply_overrides(ShardingConfig(), args.set)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 10, 1),
                       checkpoint_every=args.ckpt_every,
                       checkpoint_dir=args.ckpt_dir,
                       microbatches=args.micro)

    def log(step, metrics):
        if step % max(args.steps // 20, 1) == 0 or step <= 3:
            print(f"step {step:5d} loss {metrics['loss']:.4f} "
                  f"lr {metrics['lr']:.2e} gnorm {metrics['grad_norm']:.3f} "
                  f"{metrics['time_s']*1e3:.0f} ms", flush=True)

    res = train(cfg, shape, mesh, perf=perf, tcfg=tcfg, on_step=log)
    print(json.dumps({
        "steps_run": res.steps_run, "final_step": res.final_step,
        "first_loss": res.losses[0] if res.losses else None,
        "last_loss": res.losses[-1] if res.losses else None,
        "restored_from": res.restored_from,
        "mean_step_s": sum(res.step_times) / max(len(res.step_times), 1),
    }, indent=1))


if __name__ == "__main__":
    main()

"""Device-resident online retraining (``runtime/trainer.py``).

Three layers: ``replay.sample_device`` (the jit-safe in-place minibatch
draw — masked on empty rings, live-slots-only when partially filled,
whole-ring after wraparound, bit-deterministic under a threaded PRNG),
the ``OnlineTrainer`` unit protocol (empty-ring exact no-op, applied
updates move weights and bump ``policy_version``, host mirror and carry
stay in sync), and the system-level guarantees: training disabled is
bit-identical to the PR 5 fused path, every LogDB row and replay export
row is stamped with the policy version that PRODUCED its action, and the
checkpoint save -> restore cycle round-trips policy + train state.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import replay as rp
from repro.core.reward import energy_reward_spec
from repro.runtime.predictor import (ActionSpace, ModelAdapter, Predictor,
                                     linear_policy)
from repro.runtime.trainer import OnlineTrainer, default_train_cfg

from test_fused_decide import _system, _rows, _strip

E, F, A = 2, 2, 2


def _filled(cap, n, seed=0):
    """Ring with n sequential adds of recognisable rows: obs[:, 0] ==
    reward == tick index, so any sampled row can be cross-checked."""
    r = np.random.RandomState(seed)
    buf = rp.init(E, cap, F, A)
    for j in range(n):
        buf = rp.add(buf, jnp.full((E, F), float(j)),
                     jnp.asarray(r.normal(0, 1, (E, A)), jnp.float32),
                     jnp.full((E,), float(j)), jnp.zeros((E, F)),
                     jnp.int32(j), version=jnp.int32(j % 3))
    return buf


def _pred(cap=16, seed=3):
    return Predictor(linear_policy(F, A, seed=seed),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     E, F, replay_capacity=cap)


# --------------------------------------------------------------------------
# sample_device: the masked in-place minibatch draw
# --------------------------------------------------------------------------

def test_sample_device_empty_ring_masks_where_host_raises():
    buf = rp.init(E, 8, F, A)
    batch = rp.sample_device(buf, jax.random.PRNGKey(0), 16)
    assert not np.asarray(batch["valid"]).any()
    # rows are in-range garbage (slot 0), never NaN/OOB — safe to compute on
    assert np.isfinite(np.asarray(batch["obs"])).all()
    with pytest.raises(ValueError, match="empty"):
        rp.sample(buf, jax.random.PRNGKey(0), 16)


def test_sample_device_partial_ring_samples_live_slots_only():
    buf = _filled(cap=8, n=3)
    batch = rp.sample_device(buf, jax.random.PRNGKey(1), 64)
    ticks = np.asarray(batch["tick_idx"])
    assert np.asarray(batch["valid"]).all()
    assert set(ticks.tolist()) == {0, 1, 2}      # no dead slots, all live
    # row coherence: every column gathered from the SAME (env, slot)
    assert (np.asarray(batch["obs"])[:, 0] == ticks).all()
    assert (np.asarray(batch["rewards"]) == ticks).all()
    assert (np.asarray(batch["version"]) == ticks % 3).all()


def test_sample_device_post_wraparound_reaches_every_slot():
    buf = _filled(cap=4, n=7)                    # live ticks: 3, 4, 5, 6
    batch = rp.sample_device(buf, jax.random.PRNGKey(2), 64)
    ticks = np.asarray(batch["tick_idx"])
    assert set(ticks.tolist()) == {3, 4, 5, 6}
    assert (np.asarray(batch["rewards"]) == ticks).all()


def test_sample_device_bit_deterministic_and_jit_stable():
    buf = _filled(cap=8, n=5)
    key = jax.random.PRNGKey(7)
    a = rp.sample_device(buf, key, 32)
    b = rp.sample_device(buf, key, 32)
    c = jax.jit(rp.sample_device, static_argnums=2)(buf, key, 32)
    for k in a:
        assert (np.asarray(a[k]) == np.asarray(b[k])).all(), k
        assert (np.asarray(a[k]) == np.asarray(c[k])).all(), k


# --------------------------------------------------------------------------
# OnlineTrainer unit protocol
# --------------------------------------------------------------------------

def test_trainer_rejects_model_without_params():
    pred = Predictor(ModelAdapter(lambda f: jnp.zeros(f.shape[:-1] + (A,)),
                                  "opaque"),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     E, F)
    with pytest.raises(ValueError, match="parameterized"):
        OnlineTrainer(pred)


def test_trainer_empty_ring_step_is_exact_noop():
    pred = _pred()
    tr = OnlineTrainer(pred, batch_size=8)
    ds = pred.decide_state()
    before = jax.tree.map(np.asarray, ds.policy)
    tr.dispatch(ds)
    ds2 = tr.apply_pending(ds)
    assert tr.stats["skipped_empty"] == 1 and tr.stats["applied"] == 0
    assert tr.version == 0 and pred.policy_version == 0
    for x, y in zip(jax.tree.leaves(before), jax.tree.leaves(ds2.policy)):
        assert (np.asarray(y) == x).all()       # no AdamW drift, bit-exact


def test_trainer_applied_step_moves_weights_and_syncs_mirror():
    pred = _pred()
    tr = OnlineTrainer(pred, batch_size=16,
                       train_cfg=default_train_cfg(learning_rate=1e-2))
    ds = pred.decide_state()._replace(replay=_filled(cap=16, n=6))
    w0 = np.asarray(ds.policy["w"]).copy()
    tr.dispatch(ds)
    ds = tr.apply_pending(ds)
    assert tr.stats["applied"] == 1 and tr.version == 1
    assert int(ds.version) == 1
    assert np.isfinite(tr.stats["last_loss"]) and tr.stats["last_loss"] > 0
    # step 1 fits the critic against the banked rewards (the policy term's
    # gradient is zero while the critic is zero) ...
    assert np.abs(np.asarray(tr.train_state["critic"]["qw"])).max() > 0
    # ... so the policy moves from step 2 onward
    tr.dispatch(ds)
    ds = tr.apply_pending(ds)
    assert tr.stats["applied"] == 2 and tr.version == 2
    assert np.abs(np.asarray(ds.policy["w"]) - w0).max() > 0
    # host mirror adopted the SAME weights (own buffer, not the carry's)
    assert pred.policy_version == 2
    assert (np.asarray(pred.policy_params["w"])
            == np.asarray(ds.policy["w"])).all()


def test_trainer_requires_fused_mode():
    with pytest.raises(ValueError, match="fused"):
        _system("scan", train="online")


# --------------------------------------------------------------------------
# System level: attribution + training-off bit-identity
# --------------------------------------------------------------------------

def test_training_disabled_bit_identical_and_version_zero(tmp_path):
    """With no trainer attached the fused path must not move: results, DB
    rows and replay export stay bit-identical to the PR 4/5 reference, and
    every row carries policy_version 0 (attribution is total, not
    training-gated)."""
    ref = _system("scan", tmp_db=str(tmp_path / "ref"), batched_consume=True)
    off = _system("scan_fused_decide", tmp_db=str(tmp_path / "off"))
    rr, ro = ref.run_windows(7), off.run_windows(7)
    ref.stop(), off.stop()
    assert _strip(rr) == _strip(ro)
    rows_ref, rows_off = _rows(ref.db), _rows(off.db)
    assert rows_ref == rows_off
    assert all(row["policy_version"] == 0 for row in rows_off)
    exp = off.export_replay("s")
    assert (np.asarray(exp["version"]) == 0).all()
    ref.db.close(), off.db.close()


def test_policy_version_attribution_rides_rows_and_replay(tmp_path):
    """9 windows / scan_k=3 -> 3 batches. The trainer applies at each
    boundary after the first, so batches serve versions 0, 1, 2; every
    LogDB row is stamped with the version that served its window, and the
    replay version column follows ACTION-producer semantics: the
    transition banked at tick t carries the version that produced the
    action at t-1, so only the first row of a batch carries the previous
    batch's version."""
    sys = _system("scan_fused_decide", tmp_db=str(tmp_path / "db"),
                  train="online", train_cfg={"batch_size": 16})
    sys.run_windows(9)
    sys.stop()
    assert sys.policy_version() == 2
    st = sys.train_stats()
    assert st["dispatched"] == 3 and st["applied"] == 2
    rows = _rows(sys.db)
    assert len(rows) == 9 * E
    served = [row["policy_version"] for row in rows]
    assert served == [0] * 6 + [1] * 6 + [2] * 6   # E rows per window
    exp = sys.export_replay("s")
    ver = np.asarray(exp["version"])
    # ticks 1..8 (tick 0 has no predecessor); actions at ticks 0-2 came
    # from v0, 3-5 from v1 (but tick 3's action is tick 2's successor ...
    # the banked ACTION at tick t is the PREVIOUS action, hence the shift)
    expect = np.array([0, 0, 0, 1, 1, 1, 2, 2], np.int32)
    assert (ver == expect[None, :]).all()
    # attribution is monotone in time for every env
    assert (np.diff(ver, axis=1) >= 0).all()
    sys.db.close()


def test_training_on_matches_training_off_until_first_swap(tmp_path):
    """The first served batch predates any applied update: its results and
    rows must be bit-identical with training on vs off (the train step
    overlaps serving but cannot perturb it)."""
    on = _system("scan_fused_decide", tmp_db=str(tmp_path / "on"),
                 train="online", train_cfg={"batch_size": 16})
    off = _system("scan_fused_decide", tmp_db=str(tmp_path / "off"))
    r_on, r_off = on.run_windows(3), off.run_windows(3)   # one K=3 batch
    on.stop(), off.stop()
    assert _strip(r_on) == _strip(r_off)
    assert _rows(on.db) == _rows(off.db)
    on.db.close(), off.db.close()


# --------------------------------------------------------------------------
# Checkpoint cycle: save -> fresh system -> restore
# --------------------------------------------------------------------------

def test_checkpoint_restore_roundtrips_policy_and_version(tmp_path):
    ck = str(tmp_path / "ck")
    sys1 = _system("scan_fused_decide", train="online",
                   train_cfg={"batch_size": 16, "checkpoint_dir": ck,
                              "checkpoint_every": 1})
    sys1.run_windows(9)
    sys1.stop()
    v1 = sys1.policy_version()
    w1 = np.asarray(sys1.predictor.policy_params["w"]).copy()
    assert v1 == 2

    sys2 = _system("scan_fused_decide", train="online",
                   train_cfg={"batch_size": 16, "checkpoint_dir": ck})
    assert sys2.policy_version() == 0
    restored = sys2.restore_training()
    assert restored is not None
    step, params, extra = restored
    assert step == 2 and extra["policy_version"] == v1
    assert sys2.policy_version() == v1
    assert (np.asarray(sys2.predictor.policy_params["w"]) == w1).all()
    # the LIVE carry serves the restored weights, not construction-time ones
    assert (np.asarray(sys2.snapshot_policy()["w"]) == w1).all()
    # trainer bookkeeping resumed too: next applied step numbers from here
    assert sys2.trainer.stats["applied"] == 2
    # ... and the FIRST post-restore batch is stamped with the restored
    # version (the stale-carry bug the carry swap above prevents)
    sys2.run_windows(3)
    exp2 = sys2.export_replay("s")
    assert (np.asarray(exp2["version"]) == v1).all()
    sys2.stop()


def test_save_checkpoint_explicit(tmp_path):
    pred = _pred()
    tr = OnlineTrainer(pred, batch_size=8, checkpoint_dir=str(tmp_path))
    step = tr.save_checkpoint(block=True)
    assert step == 0
    out = tr.restore_latest()
    assert out is not None and out[0] == 0
    tr.close()

"""Standardized record format + simulated source payload encodings.

Receivers produce raw protocol payloads; Translators parse them into
:class:`Record`s — the "standardized format" flowing to the env queues.
"""
from __future__ import annotations

import json
import struct
from dataclasses import dataclass
from typing import List


@dataclass(frozen=True)
class Record:
    env_id: str
    stream: str
    timestamp: float
    value: float


# --- simulated wire formats (one per protocol family) -----------------------

def encode_mqtt_json(stream: str, ts: float, value: float) -> bytes:
    return json.dumps({"sensor": stream, "t": ts, "v": value}).encode()


def decode_mqtt_json(payload: bytes):
    d = json.loads(payload.decode())
    return d["sensor"], float(d["t"]), float(d["v"])


def encode_http_csv(stream: str, ts: float, value: float) -> bytes:
    return f"{stream},{ts:.3f},{value:.6f}".encode()


def decode_http_csv(payload: bytes):
    s, t, v = payload.decode().split(",")
    return s, float(t), float(v)


def encode_amqp_binary(stream: str, ts: float, value: float) -> bytes:
    name = stream.encode()[:32].ljust(32, b"\0")
    return name + struct.pack("<dd", ts, value)


def decode_amqp_binary(payload: bytes):
    name = payload[:32].rstrip(b"\0").decode()
    ts, v = struct.unpack("<dd", payload[32:48])
    return name, ts, v


CODECS = {
    "mqtt": (encode_mqtt_json, decode_mqtt_json),
    "http": (encode_http_csv, decode_http_csv),
    "amqp": (encode_amqp_binary, decode_amqp_binary),
}

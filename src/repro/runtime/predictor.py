"""Predictor — routes features to the decision model, validates actions,
computes rewards, logs for retraining, hands decisions to Forwarders.

The model is pluggable (``ModelAdapter``): a vector policy (edge RL), an
LM-family model through a TokenCodec, or anything callable on (E, F)
features. This is the "support any type of AI model that consumes this
data" requirement.

Three consume paths:

  * :meth:`Predictor.on_tick` — one jitted ``_step`` per window. The
    per-window reference path; fused mode and the bit-identity tests use
    it.
  * :meth:`Predictor.on_windows` — a K-window stack in ONE jitted
    dispatch: the policy and action validation run under ``lax.scan`` (so
    every window executes exactly the per-window (E, F) gemm), the
    ``prev_obs``/``prev_actions``/``have_prev`` carry materializes as
    shifted stacks, reward terms evaluate K-leading in one shot
    (elementwise over the stack, see ``RewardSpec.compute``), and the K
    replay transitions append through ``replay.add_many`` (itself a
    ``lax.scan`` carrying the buffer — exact sequential ring semantics).
    Outputs are bit-identical to K sequential ``on_tick`` calls; the
    scan-mode Manager consume uses this path so the decision side of the
    system costs one device dispatch per K windows, like the pipeline.
  * :meth:`Predictor.make_decide_fn` — the fully fused path
    (``mode="scan_fused_decide"``): a pure per-window decision step the
    pipeline scan body calls directly, with :class:`DecideState` (prev
    obs/actions, have_prev, the exact tick counter, and the
    :class:`~repro.core.replay.ReplayBuffer`) carried ON DEVICE inside
    the same donated scan carry as the pipeline state. The Predictor
    object then holds no live replay/prev state — the system owns the
    carry, :meth:`absorb_fused` keeps the host-side stats/time mirror in
    sync per batch, and replay export goes through the system's
    non-donating snapshot (``PerceptaSystem.export_replay``). The step
    runs exactly the per-window ops of ``_step``, so fused outputs are
    bit-identical to both reference consume paths.

Long-horizon time rule (mirrors the scan engine's window-relative rebase):
the replay buffer stores the EXACT int32 tick index per transition, never a
float32 absolute time — consecutive window ends quantize to the same
float32 value past t~2^24 s. The absolute float64 time of every tick is
mirrored host-side in ``_replay_times`` (slot-aligned with the device
ring) and re-attached at export by :meth:`export_replay`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import replay as rp
from repro.core.reward import RewardSpec, validate_actions


@dataclass
class ActionSpace:
    low: np.ndarray
    high: np.ndarray

    @property
    def n(self):
        return len(self.low)


class DecideState(NamedTuple):
    """Device-resident decision carry for the fused scan engine.

    Lives inside the same donated/env-sharded carry pytree as the pipeline
    state: ``prev_obs``/``prev_actions`` and every replay-ring row shard on
    the env dim, the scalars (``have_prev``, ``tick``, the ring cursor)
    replicate, and the ``policy`` params subtree replicates explicitly
    (weights are batch-global, not per-env rows — see
    ``sharding.decide_specs``). ``tick`` is the EXACT int32 predictor tick
    index of the next window — the long-horizon time rule's device half;
    absolute float64 times are reconstructed host-side at export. Only the
    small prev/tick/policy part rides the per-window ``lax.scan`` carry;
    the replay ring is written once per batch by the ``bank`` half of
    :class:`DecideFns` (threading the (E, C, F) storage through the scan
    carry measured a full ring copy per dispatch).

    ``policy`` is the live policy-params pytree for parameterized models
    (``{}`` for closure-only models), and ``version``/``prev_version``
    carry the monotone policy_version attribution: ``version`` names the
    policy producing THIS batch's actions, ``prev_version`` the one that
    produced ``prev_actions`` (they differ exactly on the first window
    after a hot-swap). Swaps happen host-side at batch boundaries only
    (``runtime.trainer.OnlineTrainer``), so every K-batch is attributable
    to exactly one policy.

    ``carry`` is the OPTIONAL recurrent model state of a stateful policy
    (``ModelAdapter.apply_carry``/``init_carry`` — e.g. the registry's
    ``rglru``/``rwkv6`` models): ``None`` (a leafless pytree — invisible
    to the scan carry, donation and the spec trees) for stateless
    policies, otherwise a pytree of per-env ``(E, ...)`` leaves the env
    mesh shards on dim 0 by the ``env_specs`` rank rule.  The
    certification pass (``repro.analysis.certify``) proves every carry
    leaf is env-row-stable (``carry-env-mix``) before a stateful policy
    may ride the fused/sharded engines.

    ``active``/``prev_ok`` are the ELASTIC slot-pool mask leaves: ``None``
    (leafless — dense pytrees, traces, specs and donation are unchanged)
    for fixed-E systems; under ``PerceptaSystem(elastic=True)`` they are
    (E,) bool carry leaves sharded on the env axis like every row block.
    ``active`` marks which slots are live THIS batch (the decide step
    gates its outputs on it by select; the host flips values between
    batches — no retrace); ``prev_ok`` is the per-env twin of the scalar
    ``have_prev`` chain — True once a slot has produced a window since it
    last attached — gating the batch's first banked transition per row.
    """
    prev_obs: jax.Array      # (E, F)
    prev_actions: jax.Array  # (E, A)
    have_prev: jax.Array     # () bool
    tick: jax.Array          # () int32
    replay: rp.ReplayBuffer
    policy: dict             # params pytree ({} when not hot-swappable)
    version: jax.Array       # () int32 — policy_version of ``policy``
    prev_version: jax.Array  # () int32 — version that made prev_actions
    carry: object = None     # recurrent model state (None = stateless)
    active: object = None    # (E,) bool slot mask (None = dense fixed-E)
    prev_ok: object = None   # (E,) bool per-env have-prev (None = dense)


class DecideFns(NamedTuple):
    """The fused engine's decision protocol (see ``make_decide_fn``).

    ``step(DecideState, FeatureFrame) -> (DecideState, (actions, reward,
    per_term, violated), transition)`` runs one window's decision math
    inside the scan body (the carried ``replay`` field passes through
    untouched — it may be ``None`` there); ``transition`` is the
    ``(prev_obs, prev_actions, reward, next_obs, tick, version,
    have_prev)`` row the window banks (7 flat trailing outputs — the
    arity ``analysis.check_decide_fns`` keys on). ``bank(ReplayBuffer,
    stacked transitions, env_mask=None) -> ReplayBuffer`` writes the
    whole batch after the scan in one exact ring scatter
    (``replay.add_batch``); ``env_mask`` (K, E) bool is the elastic
    per-row liveness landing in the ring's ``valid`` column.
    """
    step: Callable
    bank: Callable


class ModelAdapter:
    """Wraps any policy fn(features (E,F)) -> actions (E,A).

    Parameterized models additionally expose ``params`` (a trainable
    pytree) and ``apply(params, features) -> actions``, with
    ``fn == apply(params, .)``. The fused engine then threads the weights
    as an EXPLICIT input (the ``DecideState.policy`` carry leaf) instead
    of a traced-in closure constant, which is what makes race-free policy
    hot-swap possible without retracing: the trainer replaces the carry
    leaf at a batch boundary and the already-compiled scan runs the new
    weights. Closure-only models (``params is None``) keep the old
    behaviour and are not hot-swappable.

    RECURRENT models (the registry's ``rglru``/``rwkv6``) instead expose
    ``apply_carry(params, features, carry) -> (actions, new_carry)`` plus
    ``init_carry(n_envs) -> carry`` (a pytree of per-env ``(E, ...)``
    leaves). Their state threads through every consume path's scan carry
    (and ``DecideState.carry`` on the fused engines); ``fn`` may be
    ``None`` — there is no stateless view to call.
    """

    def __init__(self, fn: Optional[Callable], name: str = "policy",
                 params=None, apply: Optional[Callable] = None,
                 apply_carry: Optional[Callable] = None,
                 init_carry: Optional[Callable] = None):
        if apply_carry is not None and init_carry is None:
            raise ValueError(
                f"stateful policy '{name}': apply_carry requires "
                "init_carry(n_envs) so every consume path can materialize "
                "the recurrent state at the system's env count")
        self.fn = fn
        self.name = name
        self.params = params
        self.apply = apply
        self.apply_carry = apply_carry
        self.init_carry = init_carry

    def __call__(self, features):
        if self.fn is None:
            raise TypeError(
                f"policy '{self.name}' is stateful (apply_carry) and has "
                "no stateless fn view — call apply_carry(params, features, "
                "carry) or route it through a Predictor consume path")
        return self.fn(features)


def policy_call(model):
    """``(apply_fn, params)`` view of a STATELESS model.

    Parameterized adapters route their weights explicitly; closure-only
    models get an empty params pytree and an apply that ignores it — both
    shapes trace to the same per-window ops, so fused outputs stay
    bit-identical to the reference paths either way.

    Stateful (``apply_carry``) models are rejected here: callers of this
    view (e.g. ``runtime.trainer.OnlineTrainer``'s train step) cannot
    thread a recurrent carry, so offering them a carry-less apply would
    silently re-run the policy from blank state every call.
    """
    if getattr(model, "apply_carry", None) is not None:
        raise ValueError(
            f"policy '{getattr(model, 'name', model)}' is stateful "
            "(apply_carry): the stateless (apply, params) view cannot "
            "thread its recurrent carry — use policy_call2 / the decide "
            "paths; online retraining (train='online') supports stateless "
            "policies only")
    if getattr(model, "apply", None) is not None \
            and getattr(model, "params", None) is not None:
        return model.apply, model.params
    return (lambda params, feats: model(feats)), {}


def policy_call2(model):
    """``(apply2, params, init_carry)`` view — the carry-capable calling
    convention every Predictor consume path traces.

    ``apply2(params, features, carry) -> (actions, new_carry)``. Stateless
    models wrap with a pass-through carry (``None`` in, ``None`` out, a
    leafless pytree — invisible to scans/donation/spec trees) and
    ``init_carry is None``; stateful adapters pass their ``apply_carry``
    through unchanged. One convention means one trace shape everywhere,
    so stateless policies cost nothing for the generality.
    """
    if getattr(model, "apply_carry", None) is not None:
        params = getattr(model, "params", None)
        return model.apply_carry, ({} if params is None else params), \
            model.init_carry
    apply_fn, params = policy_call(model)

    def apply2(p, feats, carry):
        return apply_fn(p, feats), carry

    return apply2, params, None


def linear_policy(n_features: int, n_actions: int, seed: int = 0,
                  low=-1.0, high=1.0) -> ModelAdapter:
    """A small deterministic policy standing in for the deployed RL model.

    The policy dot is phrased as multiply+reduce over F rather than
    ``feats @ W``: under the env-sharded fused engine each device sees
    E/N feature rows, and XLA:CPU lowers the (rows, F) x (F, A) dot
    through row-count-dependent kernels inside the fused scan (1-ulp
    divergence between the sharded and full-E programs). The reduce
    form's per-element add order depends only on F, so the same bits come
    out at every shard size — the property the fused-sharded mode's
    bit-identity guarantee rests on (a custom model must preserve it too
    to compose with ``mode="scan_fused_decide_sharded"``).
    """
    k = jax.random.PRNGKey(seed)
    W = jax.random.normal(k, (n_features, n_actions)) / jnp.sqrt(n_features)
    params = {"w": W}

    def apply(params, feats):
        logits = (feats[..., :, None] * params["w"][None, :, :]).sum(-2)
        return jnp.tanh(logits) * (high - low) / 2 + (high + low) / 2

    # construction-time snapshot for direct ``model(feats)`` callers; the
    # runtime paths route through (apply, params) and see hot-swapped weights
    fn = jax.jit(lambda feats: apply(params, feats))
    return ModelAdapter(fn, "linear_policy", params=params, apply=apply)


class Predictor:
    def __init__(self, model, reward_spec: RewardSpec,
                 action_space: ActionSpace, n_envs: int, n_features: int,
                 db=None, replay_capacity: int = 4096):
        self.reward_spec = reward_spec
        self.action_space = action_space
        # recorded so the construction-time contract checker
        # (repro.analysis.check_system) can probe the decide path at the
        # true (E, F) shapes without re-deriving them from the pipeline
        self.n_envs = n_envs
        self.n_features = n_features
        self.db = db
        self.replay = rp.init(n_envs, replay_capacity, n_features,
                              action_space.n)
        # host-side float64 absolute-time mirror, slot-aligned with the
        # device ring: the transition written at cursor c (tick index c+1)
        # lives in slot c % capacity of both structures
        self._replay_times = np.zeros((replay_capacity,), np.float64)
        self._prev = {
            "obs": jnp.zeros((n_envs, n_features), jnp.float32),
            "actions": jnp.zeros((n_envs, action_space.n), jnp.float32),
            "have": False,
            "version": 0,  # policy_version that produced prev_actions
        }
        self.stats = {"ticks": 0, "violations": 0}
        self.policy_version = 0
        self.set_model(model)

    def set_model(self, model) -> None:
        """Bind (or rebind) the decision model and (re)build the jitted
        consume paths around it.

        ``model`` may be a prebuilt :class:`ModelAdapter`, a registry name
        (``"linear" | "mlp" | "rglru" | "rwkv6"``) or a
        ``runtime.policies.PolicyConfig`` — names/configs resolve through
        the certified registry (``runtime.policies.build_policy``), so a
        registry policy arrives with its
        :class:`~repro.analysis.certify.PolicyCertificate` attached.
        Rebinding resets the recurrent model carry (if any) to its
        ``init_carry`` state; replay/stats/prev are untouched.
        """
        if isinstance(model, str) or type(model).__name__ == "PolicyConfig":
            from repro.runtime.policies import build_policy
            model = build_policy(model, self.n_features,
                                 self.action_space.n, self.n_envs)
        self.model = model
        # (apply2, params, init_carry) view: parameterized models thread
        # weights as explicit jit inputs on EVERY consume path (reference
        # and fused) — one calling convention traces everywhere, hot-swapped
        # weights reuse the compiled programs without retracing, and
        # stateful models thread their recurrent carry the same way
        apply2, params0, init_carry = policy_call2(model)
        self._apply2 = apply2
        self.policy_params = params0
        # host mirror of the recurrent model state (None for stateless
        # policies); the fused engines carry it in DecideState.carry
        self._model_carry = (init_carry(self.n_envs)
                             if init_carry is not None else None)
        low = jnp.asarray(self.action_space.low, jnp.float32)
        high = jnp.asarray(self.action_space.high, jnp.float32)

        def _step(features, raw, prev_obs, prev_actions, replay, tick_idx,
                  have_prev, params, version, mcarry, active=None,
                  prev_ok=None):
            actions, new_mcarry = apply2(params, features, mcarry)
            actions, violated = validate_actions(actions, low, high)
            # rewards are computed on engineering units, not z-scores
            reward, per_term = self.reward_spec.compute(
                raw, actions, prev_actions)
            if active is not None:
                # elastic slot pool: gate outputs by select (active rows
                # bit-exact, inactive rows deterministic zeros) and mark
                # only rows that close a real prev->next pair valid
                actions = jnp.where(active[:, None], actions, 0.0)
                reward = jnp.where(active, reward, 0.0)
                per_term = jnp.where(active[:, None], per_term, 0.0)
                violated = active & violated
                row_ok = active & prev_ok
            else:
                row_ok = None
            new_replay = jax.lax.cond(
                have_prev,
                lambda r: rp.add(r, prev_obs, prev_actions, reward, features,
                                 tick_idx, version, env_mask=row_ok),
                lambda r: r,
                replay)
            return actions, reward, per_term, violated, new_replay, new_mcarry

        self._step = jax.jit(_step)

        def _steps(features, raw, tick_idx, prev_obs, prev_actions,
                   have_prev, replay, params, version, prev_version, mcarry,
                   active=None, prev_ok=None):
            """K windows in one dispatch. The policy/validate scan runs the
            SAME per-window (E, F) computation ``_step`` jits (a batched
            K-leading gemm could block/accumulate differently on some
            backends, breaking bit-identity with the reference path) and
            threads the recurrent model carry exactly as K sequential
            steps would; the carried prev obs/actions materialize as the
            shifted stacks below, so reward terms — elementwise over the
            stack — evaluate K-leading in one shot."""
            def body(mc, f):
                actions, mc = apply2(params, f, mc)
                actions, violated = validate_actions(actions, low, high)
                return mc, (actions, violated)

            mcarry_out, (actions, violated) = jax.lax.scan(
                body, mcarry, features)
            if active is not None:
                # elastic slot pool: gate per-window outputs by select
                # BEFORE the prev-chain materializes, so inactive rows
                # carry deterministic zeros into the shifted stacks (the
                # barrier seals the policy math from the select's fusion
                # — see make_decide_fn)
                actions, violated = jax.lax.optimization_barrier(
                    (actions, violated))
                actions = jnp.where(active[None, :, None], actions, 0.0)
                violated = active[None, :] & violated
                # trailing fence: the masked actions feed the reward
                # compute below — the select must not fuse into it either
                actions, violated = jax.lax.optimization_barrier(
                    (actions, violated))
            prev_act_seq = jnp.concatenate([prev_actions[None], actions[:-1]],
                                           0)
            rewards, per_term = self.reward_spec.compute(raw, actions,
                                                         prev_act_seq)
            if active is not None:
                rewards, per_term = jax.lax.optimization_barrier(
                    (rewards, per_term))
                rewards = jnp.where(active[None, :], rewards, 0.0)
                per_term = jnp.where(active[None, :, None], per_term, 0.0)
            # transition j stores (obs/actions entering window j, reward j,
            # next_obs = window j's features); only the first row of the
            # batch can lack a predecessor — and only row 0's banked action
            # can carry a different (earlier) policy_version
            K = features.shape[0]
            prev_obs_seq = jnp.concatenate([prev_obs[None], features[:-1]], 0)
            mask = jnp.concatenate([have_prev[None],
                                    jnp.ones((K - 1,), jnp.bool_)])
            ver_seq = jnp.concatenate(
                [prev_version[None], jnp.full((K - 1,), version, jnp.int32)])
            if active is not None:
                # per-row liveness: window 0 closes a pair begun last
                # batch (needs the per-env prev_ok), later windows need
                # only active — membership is constant within a batch
                E = features.shape[1]
                rows = jnp.broadcast_to(active[None, :], (K, E))
                env_mask = jnp.concatenate(
                    [(active & prev_ok)[None, :], rows[1:]], axis=0)
            else:
                env_mask = None
            new_replay = rp.add_many(replay, prev_obs_seq, prev_act_seq,
                                     rewards, features, tick_idx, mask,
                                     ver_seq, env_mask=env_mask)
            return (actions, rewards, per_term, violated, features[-1],
                    actions[-1], new_replay, mcarry_out)

        self._steps = jax.jit(_steps)

    # --- fused decision path (mode="scan_fused_decide") --------------------
    def decide_state(self) -> DecideState:
        """Materialize the current decision state as the device carry the
        fused scan engine threads (and donates) between batches. Taking it
        hands ownership to the caller: from here on the Predictor's own
        ``replay``/``_prev``/``_model_carry`` references are a stale
        snapshot of this moment — export through the system's non-donating
        snapshot."""
        return DecideState(
            prev_obs=jnp.asarray(self._prev["obs"], jnp.float32),
            prev_actions=jnp.asarray(self._prev["actions"], jnp.float32),
            have_prev=jnp.asarray(bool(self._prev["have"])),
            tick=jnp.asarray(self.stats["ticks"], jnp.int32),
            replay=self.replay,
            policy=self.policy_params,
            version=jnp.asarray(self.policy_version, jnp.int32),
            prev_version=jnp.asarray(self._prev["version"], jnp.int32),
            carry=self._model_carry,
        )

    def adopt_policy(self, params, version: int) -> None:
        """Sync the Predictor's host-side policy mirror after a fused-carry
        hot-swap (the live weights travel in ``DecideState.policy``; this
        keeps ``policy_params``/``policy_version`` — and any later
        ``decide_state()`` rebuild — consistent with the device carry)."""
        self.policy_params = params
        if getattr(self.model, "params", None) is not None:
            self.model.params = params
        self.policy_version = int(version)

    def make_decide_fn(self) -> DecideFns:
        """Decision protocol for the fused pipeline scan (:class:`DecideFns`).

        The ``step`` half runs exactly the per-window ops of the jitted
        ``_step`` (policy on the (E, F) features, validate, rewards on
        engineering units with the carried prev actions) and emits the
        window's replay transition row at the carried exact tick index;
        the ``bank`` half writes the K stacked rows in one unique-indices
        ring scatter (``replay.add_batch`` — final contents bit-identical
        to K sequential guarded ``add`` calls, without the ring ever
        riding the scan carry). Everything is per-env row-wise — both
        halves run unchanged under the env-sharded ``shard_map`` build
        (custom reward fns and models must be row-wise too; see
        ``linear_policy`` for the shard-size-invariant dot phrasing)."""
        low = jnp.asarray(self.action_space.low, jnp.float32)
        high = jnp.asarray(self.action_space.high, jnp.float32)
        apply2, spec = self._apply2, self.reward_spec

        def step(carry: DecideState, feats):
            actions, new_mcarry = apply2(carry.policy, feats.features,
                                         carry.carry)
            actions, violated = validate_actions(actions, low, high)
            reward, per_term = spec.compute(feats.raw, actions,
                                            carry.prev_actions)
            if carry.active is not None:
                # elastic slot pool: combine the mask by select only
                # (active rows keep their exact bits; inactive rows
                # become deterministic zeros) — the env-mask-gate rule
                # rejects any row-compacting alternative. The barrier
                # stops XLA fusing the selects into the reward reduction
                # epilogue (changed fusion re-contracts multiply-adds:
                # 1-ulp drift vs the dense build on XLA:CPU)
                act = carry.active
                actions, reward, per_term, violated = \
                    jax.lax.optimization_barrier(
                        (actions, reward, per_term, violated))
                actions = jnp.where(act[:, None], actions, 0.0)
                reward = jnp.where(act, reward, 0.0)
                per_term = jnp.where(act[:, None], per_term, 0.0)
                violated = act & violated
            # transition entering this window: only bankable once a
            # predecessor exists (the mask the bank applies); it is
            # attributed to the version that produced its ACTION —
            # carry.prev_version, which trails carry.version by exactly the
            # first window after a batch-boundary hot-swap
            transition = (carry.prev_obs, carry.prev_actions, reward,
                          feats.features, carry.tick, carry.prev_version,
                          carry.have_prev)
            new = DecideState(prev_obs=feats.features, prev_actions=actions,
                              have_prev=jnp.ones((), jnp.bool_),
                              tick=carry.tick + 1, replay=carry.replay,
                              policy=carry.policy, version=carry.version,
                              prev_version=carry.version, carry=new_mcarry,
                              active=carry.active, prev_ok=carry.prev_ok)
            return new, (actions, reward, per_term, violated), transition

        def bank(replay, transitions, env_mask=None):
            obs, actions, rewards, next_obs, tick, version, mask = transitions
            return rp.add_batch(replay, obs, actions, rewards, next_obs,
                                tick, mask, version, env_mask=env_mask)

        return DecideFns(step, bank)

    def absorb_fused(self, tick_times, violated) -> None:
        """Post-consume host bookkeeping for one fused batch: advance the
        tick/violation stats and the slot-aligned float64 time mirror in
        lockstep with the device carry (which advanced by ``len(
        tick_times)`` inside the dispatch). The mirror stays maintained so
        mirror-based and reconstructed exports agree; the fused export
        itself reconstructs times from ``tick_idx`` (see
        ``PerceptaSystem.export_replay``)."""
        base = self.stats["ticks"]
        self._record_times(base, tick_times)
        self.stats["ticks"] += len(tick_times)
        self.stats["violations"] += int(np.asarray(violated).sum())

    def _record_times(self, base_idx: int, tick_times) -> None:
        """Mirror absolute float64 tick times into the slot-aligned host
        ring (tick idx adds at cursor idx-1 -> slot (idx-1) % capacity)."""
        C = self.replay.capacity
        for j, t in enumerate(tick_times):
            idx = base_idx + j
            if idx >= 1:
                self._replay_times[(idx - 1) % C] = float(t)

    def on_tick(self, features, tick_time, raw=None, active=None,
                prev_ok=None):
        """features: (E, F) device array; returns host actions + rewards.

        The per-window reference path — :meth:`on_windows` must stay
        bit-identical to K calls of this. ``active``/``prev_ok`` (E,) bool
        are the elastic slot-pool masks (None = dense)."""
        raw = features if raw is None else raw
        idx = self.stats["ticks"]
        (actions, reward, per_term, violated, self.replay,
         self._model_carry) = self._step(
            features, raw, self._prev["obs"], self._prev["actions"],
            self.replay, jnp.asarray(idx, jnp.int32),
            jnp.asarray(self._prev["have"]), self.policy_params,
            jnp.asarray(self._prev["version"], jnp.int32),
            self._model_carry,
            None if active is None else jnp.asarray(active, jnp.bool_),
            None if prev_ok is None else jnp.asarray(prev_ok, jnp.bool_))
        self._record_times(idx, [tick_time])
        self._prev = {"obs": features, "actions": actions, "have": True,
                      "version": self.policy_version}
        self.stats["ticks"] += 1
        self.stats["violations"] += int(np.asarray(violated).sum())
        return np.asarray(actions), np.asarray(reward), np.asarray(per_term)

    def on_windows(self, features, tick_times, raw=None, active=None,
                   prev_ok=None):
        """Consume a K-window stack in ONE jitted dispatch.

        ``features``/``raw``: (K, E, F) (raw defaults to features);
        ``tick_times``: K absolute window-end times (host float64, never
        sent to device). Returns host ``(actions (K, E, A), rewards (K, E),
        per_term (K, E, n_terms))`` — bit-identical to K sequential
        :meth:`on_tick` calls, including replay contents and stats.
        ``active``/``prev_ok`` (E,) bool are the elastic slot-pool masks
        (None = dense; membership is constant within a batch).
        """
        features = jnp.asarray(features)
        raw = features if raw is None else jnp.asarray(raw)
        K = features.shape[0]
        assert K >= 1 and len(tick_times) == K, (K, len(tick_times))
        base = self.stats["ticks"]
        tick_idx = jnp.asarray(base + np.arange(K), jnp.int32)
        (actions, rewards, per_term, violated, last_obs, last_actions,
         self.replay, self._model_carry) = self._steps(
            features, raw, tick_idx, self._prev["obs"],
            self._prev["actions"], jnp.asarray(self._prev["have"]),
            self.replay, self.policy_params,
            jnp.asarray(self.policy_version, jnp.int32),
            jnp.asarray(self._prev["version"], jnp.int32),
            self._model_carry,
            None if active is None else jnp.asarray(active, jnp.bool_),
            None if prev_ok is None else jnp.asarray(prev_ok, jnp.bool_))
        self._record_times(base, tick_times)
        self._prev = {"obs": last_obs, "actions": last_actions, "have": True,
                      "version": self.policy_version}
        self.stats["ticks"] += K
        self.stats["violations"] += int(np.asarray(violated).sum())
        return np.asarray(actions), np.asarray(rewards), np.asarray(per_term)

    def export_replay(self, env_ids, salt: str) -> dict:
        """Anonymized chronological replay export with exact float64
        absolute times reconstructed from the host-side mirror."""
        return rp.export_for_training(self.replay, env_ids, salt,
                                      slot_times=self._replay_times)

    # --- elastic slot-pool hooks (PerceptaSystem(elastic=True)) ------------
    def clear_env_rows(self, slots) -> None:
        """Scrub env rows for recycled slots (scan-mode attach/detach):
        zero the prev carry rows and invalidate every replay cell of the
        slot, so a later tenant of the same row never observes — or banks
        against — the departed env's transitions. Out-of-place ``.at``
        updates between dispatches, so donation aliasing is never
        violated."""
        slots = np.asarray(slots, np.int64).reshape(-1)
        if slots.size == 0:
            return
        self._prev["obs"] = jnp.asarray(self._prev["obs"]).at[slots].set(0.0)
        self._prev["actions"] = \
            jnp.asarray(self._prev["actions"]).at[slots].set(0.0)
        self.replay = self.replay._replace(
            valid=self.replay.valid.at[slots].set(False))
        if self._model_carry is not None and self.model.init_carry is not None:
            tmpl = self.model.init_carry(self.n_envs)
            self._model_carry = jax.tree.map(
                lambda x, t: jnp.asarray(x).at[slots].set(
                    jnp.asarray(t)[slots]),
                self._model_carry, tmpl)

    def grow_envs(self, n_envs_new: int) -> None:
        """Pad the env axis of every per-env structure to ``n_envs_new``
        slots (elastic pool regrow). New rows come from a FRESH init
        template — never raw zeros — and existing rows are byte-for-byte
        preserved, so surviving envs resume bit-exactly."""
        from repro.distribution import elastic as el

        old_e = self.n_envs
        assert n_envs_new > old_e, (n_envs_new, old_e)
        self.n_envs = n_envs_new
        tmpl_replay = rp.init(n_envs_new, self.replay.capacity,
                              self.n_features, self.action_space.n)
        self.replay = el.grow_env_tree(self.replay, tmpl_replay, old_e)
        prev_tmpl = {
            "obs": jnp.zeros((n_envs_new, self.n_features), jnp.float32),
            "actions": jnp.zeros((n_envs_new, self.action_space.n),
                                 jnp.float32),
        }
        self._prev["obs"] = el.grow_env_tree(
            jnp.asarray(self._prev["obs"]), prev_tmpl["obs"], old_e)
        self._prev["actions"] = el.grow_env_tree(
            jnp.asarray(self._prev["actions"]), prev_tmpl["actions"], old_e)
        if self._model_carry is not None and self.model.init_carry is not None:
            self._model_carry = el.grow_env_tree(
                self._model_carry, self.model.init_carry(n_envs_new), old_e)

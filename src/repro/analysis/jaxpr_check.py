"""Jaxpr contract checker — shard-safety/time/callback invariants, statically.

The engine traces a fn to a closed jaxpr (``jax.make_jaxpr`` — no execution,
no compilation) and propagates abstract *provenance tags* through the eqn
graph:

  * a **dimension tag** ``env`` marks axes that index environments (seeded
    on dim 0 of ``(E, ...)`` inputs, following them through broadcasts,
    transposes, reshapes, slices, scans, ...);
  * a **value tag** ``abs-time`` marks absolute-time values (the int32 tick
    counter, float64 absolute seconds).  Subtracting two absolute times
    yields a relative duration, which clears the tag — so the documented
    "rebase to window-relative, then narrow" pattern passes while a direct
    ``.astype(float32)`` of absolute time is flagged.

Rules checked per eqn (see :mod:`repro.analysis.contracts` for the catalog):
``env-contraction`` / ``env-gemm-rows`` (dot_general/conv touching an
env-tagged dim), ``env-reduce`` (reduce/cumsum/sort/argmax/top_k along an
env-tagged axis), ``collective``, ``time-cast`` (convert_element_type /
reduce_precision narrowing an abs-time value below float64 mantissa), and
``callback-in-scan`` (host callbacks at loop depth >= 1 — the checked entry
points are all scan-body-bound, so they start at depth 1 by default).

Propagation is conservative: an unknown primitive spreads every input tag
to every output dim, which can only create false positives, never false
negatives.  Higher-order primitives (pjit, scan, while, cond, shard_map,
custom_jvp/vjp, remat) are walked recursively; scan/while carries run to a
tag fixed point.
"""
from __future__ import annotations

import logging
import warnings
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import (
    ContractViolation, Violation, TAG_ENV, TAG_MASK, TAG_TIME,
)

logger = logging.getLogger(__name__)

EMPTY = frozenset()


class Rules(NamedTuple):
    """Which rule families a check enforces.

    ``env`` is the shard-invariance family — enforced for the ``*_sharded``
    modes (the fused non-sharded engine may legally run a non-row-wise
    model, e.g. examples/serve_edge.py's LM policy).  ``carry`` enables the
    ``carry-env-mix`` row-movement checks (rev/roll/narrowing-slice/gather
    along an env-tagged axis) — on for policy certification
    (:mod:`repro.analysis.certify`), where a recurrent carry rides the
    fused scan and a row permutation would silently cross shard boundaries;
    off by default so pre-certification callers keep their exact rule set.
    ``mask`` enables the ``env-mask-gate`` family (elastic slot pools):
    mask-derived values must combine multiplicatively/by-select and never
    drive compaction or index math — auto-enabled by
    :func:`check_decide_fns` when the decide state carries an ``active``
    mask leaf.  The other families hold for every checked fn.
    """
    env: bool = True
    collectives: bool = True
    callbacks: bool = True
    time: bool = True
    carry: bool = False
    mask: bool = False


class Prov(NamedTuple):
    """Provenance of one jaxpr value: per-dimension tag sets + value tags."""
    dims: tuple            # tuple[frozenset[str], ...], len == ndim
    val: frozenset = EMPTY


def _empty(ndim: int) -> Prov:
    return Prov((EMPTY,) * ndim)


def _fit(p: Prov, ndim: int) -> Prov:
    """Defensive rank fix-up: never lose a tag to a rank mismatch."""
    if len(p.dims) == ndim:
        return p
    spread = frozenset().union(*p.dims) if p.dims else EMPTY
    return Prov((spread,) * ndim, p.val)


def _align_union(ins: Sequence[Prov], out_ndim: int) -> Prov:
    """Right-aligned per-dim union (elementwise ops with rank broadcasting)."""
    dims = [EMPTY] * out_ndim
    val = EMPTY
    for p in ins:
        off = out_ndim - len(p.dims)
        for j, t in enumerate(p.dims):
            if 0 <= j + off < out_ndim:
                dims[j + off] = dims[j + off] | t
        val = val | p.val
    return Prov(tuple(dims), val)


# --- primitive classification ------------------------------------------------

_ELEMENTWISE = frozenset("""
abs add and atan2 cbrt ceil clamp copy cos cosh cumlogsumexp device_put div
eq erf erfc erf_inv exp exp2 expm1 floor ge gt imag integer_pow is_finite le
log log1p logistic lt max min mul ne neg nextafter not or population_count
pow real regularized_incomplete_beta rem round rsqrt select_n shift_left
shift_right_arithmetic shift_right_logical sign sin sinh sqrt square
stop_gradient sub tan tanh xor acos asin atan acosh asinh atanh digamma
lgamma igamma igammac bessel_i0e bessel_i1e clz
""".split())

_REDUCES = frozenset(
    ["reduce_sum", "reduce_prod", "reduce_max", "reduce_min",
     "reduce_and", "reduce_or", "reduce_xor", "argmax", "argmin",
     "reduce_precision_reduce"])  # last: defensive name, never matches

_CUMULATIVE = frozenset(["cumsum", "cumprod", "cummax", "cummin",
                         "cumlogsumexp"])

_COLLECTIVES = frozenset(
    ["psum", "pmax", "pmin", "pmean", "ppermute", "pshuffle", "all_gather",
     "all_to_all", "reduce_scatter", "psum_scatter", "axis_index",
     "pbroadcast", "pgather", "pdot"])

_CALLBACKS = frozenset(
    ["pure_callback", "io_callback", "debug_callback", "callback",
     "outside_call", "host_callback_call", "python_callback"])

# higher-order prims handled structurally
_SUBJAXPR_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr")


def _src_of(eqn) -> str:
    try:
        from jax._src import source_info_util
        return str(source_info_util.summarize(eqn.source_info))
    except Exception:
        return "<unknown>"


def _is_jaxpr_like(obj) -> bool:
    return hasattr(obj, "eqns") or (hasattr(obj, "jaxpr")
                                    and hasattr(obj, "consts"))


def _open(j):
    """ClosedJaxpr -> Jaxpr (constvars get empty provs in _run)."""
    return j.jaxpr if hasattr(j, "consts") else j


class _Ctx:
    def __init__(self, rules: Rules, label: str):
        self.rules = rules
        self.label = label
        self.violations = []
        self._seen = set()

    def add(self, rule, message, primitive, source):
        key = (rule, primitive, source)
        if key in self._seen:    # scan fixed-point re-runs revisit eqns
            return
        self._seen.add(key)
        self.violations.append(Violation(rule=rule, message=message,
                                         primitive=primitive, source=source,
                                         label=self.label))


# --- per-eqn rule checks ------------------------------------------------------

def _check_eqn(eqn, name, ins, ctx: _Ctx, loop_depth: int):
    rules = ctx.rules
    if rules.collectives and name in _COLLECTIVES:
        ctx.add("collective",
                f"collective '{name}' in a shard_map-bound fn: the sharded "
                "engines are collective-free by contract (cross-env math "
                "belongs on the host)", name, _src_of(eqn))
    if rules.callbacks and name in _CALLBACKS and loop_depth >= 1:
        ctx.add("callback-in-scan",
                f"host callback '{name}' inside a scan/while body: a hidden "
                "host sync per scan step defeats the one-dispatch-per-batch "
                "engine (log after the batch instead)", name, _src_of(eqn))
    if rules.time and name == "convert_element_type":
        new = np.dtype(eqn.params["new_dtype"])
        p = ins[0]
        if TAG_TIME in p.val and np.issubdtype(new, np.floating):
            nmant = np.finfo(new).nmant
            old = np.dtype(eqn.invars[0].aval.dtype)
            already_narrow = (np.issubdtype(old, np.floating)
                              and np.finfo(old).nmant <= nmant)
            if nmant < 52 and not already_narrow:
                ctx.add("time-cast",
                        f"absolute-time value cast {old.name} -> {new.name}:"
                        " float32 absolute seconds/ticks quantize past "
                        "t~2^24 (consecutive window ends collapse to the "
                        "same value). Keep absolute time in float64/int32 "
                        "and rebase to window-relative (subtract a time) "
                        "before narrowing", name, _src_of(eqn))
    if rules.time and name == "reduce_precision":
        if TAG_TIME in ins[0].val and eqn.params.get("mantissa_bits", 53) < 52:
            ctx.add("time-cast",
                    "reduce_precision narrows an absolute-time value below "
                    "float64 mantissa; rebase to window-relative first",
                    name, _src_of(eqn))
    if rules.mask:
        _check_mask_gate(eqn, name, ins, ctx)
    if not rules.env:
        return
    if name == "dot_general":
        (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
        lhs, rhs = ins[0], ins[1]
        contracted = (any(TAG_ENV in lhs.dims[d] for d in lc)
                      or any(TAG_ENV in rhs.dims[d] for d in rc))
        anywhere = (any(TAG_ENV in t for t in lhs.dims)
                    or any(TAG_ENV in t for t in rhs.dims))
        if contracted:
            ctx.add("env-contraction",
                    "dot_general contracts over the env axis: the result "
                    "mixes rows across environments and diverges between "
                    "the sharded and unsharded engines", name, _src_of(eqn))
        elif anywhere:
            ctx.add("env-gemm-rows",
                    "env rows feed a dot_general: XLA:CPU lowers (rows, F) "
                    "gemms through row-count-dependent kernels, so the bits "
                    "depend on rows-per-device (1-ulp shard drift). Phrase "
                    "per-env dots as multiply+reduce over features (see "
                    "runtime.predictor.linear_policy)", name, _src_of(eqn))
    elif name == "conv_general_dilated":
        if any(TAG_ENV in t for p in ins[:2] for t in p.dims):
            ctx.add("env-gemm-rows",
                    "env rows feed a convolution: lowering is "
                    "row-count-dependent; keep the env axis out of conv "
                    "operands (vmap-free per-env math)", name, _src_of(eqn))
    elif name in _REDUCES and "axes" in eqn.params:
        bad = [a for a in eqn.params["axes"] if TAG_ENV in ins[0].dims[a]]
        if bad:
            ctx.add("env-reduce",
                    f"'{name}' reduces along the env axis (axis {bad[0]}): "
                    "per-env decision math must not mix rows across "
                    "environments (a cross-env mean/sum diverges under the "
                    "env-sharded engine)", name, _src_of(eqn))
    elif name in _CUMULATIVE:
        ax = eqn.params.get("axis", 0)
        if TAG_ENV in ins[0].dims[ax]:
            ctx.add("env-reduce",
                    f"'{name}' scans along the env axis: row i depends on "
                    "rows < i, which is cross-env math", name, _src_of(eqn))
    elif name == "sort":
        d = eqn.params.get("dimension", len(ins[0].dims) - 1)
        if any(TAG_ENV in p.dims[d] for p in ins if len(p.dims) > d):
            ctx.add("env-reduce",
                    "'sort' permutes along the env axis: rows move across "
                    "environments", name, _src_of(eqn))
    elif name == "top_k":
        if ins[0].dims and TAG_ENV in ins[0].dims[-1]:
            ctx.add("env-reduce",
                    "'top_k' selects along the env axis: rows mix across "
                    "environments", name, _src_of(eqn))
    if rules.carry:
        _check_row_moves(eqn, name, ins, ctx)


def _check_mask_gate(eqn, name, ins, ctx: _Ctx):
    """``env-mask-gate`` eqn checks: a mask-derived value (the elastic
    ``active``/``prev_ok`` carry leaves and anything computed from them)
    may GATE values — multiply/AND/where — but must never DRIVE structure:
    row-compaction offsets (a cumsum of the mask along the env axis),
    ordering (sort/top_k), or index math (gather/scatter/dynamic_slice
    start operands).  Structural use changes row placement with membership
    — exactly what the no-retrace, bit-exact-active-rows contract
    forbids."""
    def flag(detail):
        ctx.add("env-mask-gate",
                f"{detail} — the elastic active mask combines only "
                "multiplicatively or via select/where (row i's output "
                "depends on row i's mask bit alone); mask-derived "
                "compaction/ordering/index math moves rows with membership "
                "and breaks the no-retrace, bit-exact-active-rows contract",
                name, _src_of(eqn))

    if name in ("sort", "top_k"):
        if any(TAG_MASK in p.val for p in ins):
            flag(f"'{name}' orders by a mask-derived value")
    elif name in _CUMULATIVE:
        ax = eqn.params.get("axis", 0)
        if TAG_MASK in ins[0].val and ax < len(ins[0].dims) \
                and TAG_ENV in ins[0].dims[ax]:
            flag(f"'{name}' scans a mask-derived value along the env axis "
                 "(the row-compaction-offset pattern)")
    elif name in ("argmax", "argmin"):
        axes = eqn.params.get("axes", ())
        if TAG_MASK in ins[0].val and any(
                a < len(ins[0].dims) and TAG_ENV in ins[0].dims[a]
                for a in axes):
            flag(f"'{name}' picks a row position from a mask-derived value "
                 "along the env axis")
    elif name == "gather":
        if len(ins) > 1 and TAG_MASK in ins[1].val:
            flag("'gather' indexes with a mask-derived value")
    elif name.startswith("scatter"):
        if len(ins) > 1 and TAG_MASK in ins[1].val:
            flag("'scatter' indexes with a mask-derived value (masking "
                 "belongs in the UPDATE values, not the indices)")
    elif name == "dynamic_slice":
        if any(TAG_MASK in p.val for p in ins[1:]):
            flag("'dynamic_slice' start indices derive from the mask")
    elif name == "dynamic_update_slice":
        if any(TAG_MASK in p.val for p in ins[2:]):
            flag("'dynamic_update_slice' start indices derive from the "
                 "mask")


def _check_row_moves(eqn, name, ins, ctx: _Ctx):
    """``carry-env-mix`` eqn checks: primitives that MOVE rows along an
    env-tagged axis (reverse/roll/subset-slice/gather).  Elementwise math
    keeps row i's data in row i, so the base rules let these pass; for a
    recurrent carry they re-route state across environments — and across
    shard boundaries, without a collective, under the env mesh."""
    def flag(detail):
        ctx.add("carry-env-mix",
                f"{detail} — a recurrent carry (and everything feeding it) "
                "must keep env row i's state in row i; under the "
                "env-sharded fused scan this crosses shard boundaries "
                "without a collective", name, _src_of(eqn))

    if name == "rev":
        bad = [d for d in eqn.params["dimensions"]
               if d < len(ins[0].dims) and TAG_ENV in ins[0].dims[d]]
        if bad:
            flag(f"'rev' reverses the env axis (dim {bad[0]})")
    elif name == "concatenate":
        d = eqn.params["dimension"]
        if any(len(p.dims) > d and TAG_ENV in p.dims[d] for p in ins):
            flag(f"'concatenate' stacks along the env axis (dim {d}): "
                 "row order/count changes (the jnp.roll lowering)")
    elif name == "slice":
        starts = eqn.params["start_indices"]
        limits = eqn.params["limit_indices"]
        strides = eqn.params["strides"] or (1,) * len(starts)
        shape = tuple(eqn.invars[0].aval.shape)
        for d, t in enumerate(ins[0].dims):
            if TAG_ENV in t and (starts[d] != 0 or limits[d] != shape[d]
                                 or strides[d] != 1):
                flag(f"'slice' selects a subset of env rows (dim {d}: "
                     f"[{starts[d]}:{limits[d]}:{strides[d]}] of "
                     f"{shape[d]})")
                break
    elif name == "dynamic_slice":
        sizes = eqn.params["slice_sizes"]
        shape = tuple(eqn.invars[0].aval.shape)
        for d, t in enumerate(ins[0].dims):
            if TAG_ENV in t and sizes[d] != shape[d]:
                flag(f"'dynamic_slice' narrows the env axis (dim {d}: "
                     f"{sizes[d]} of {shape[d]} rows)")
                break
    elif name == "dynamic_update_slice":
        op_shape = tuple(eqn.invars[0].aval.shape)
        upd_shape = tuple(eqn.invars[1].aval.shape)
        for d, t in enumerate(ins[0].dims):
            if TAG_ENV in t and d < len(upd_shape) \
                    and upd_shape[d] != op_shape[d]:
                flag(f"'dynamic_update_slice' writes a subset of env rows "
                     f"(dim {d}: {upd_shape[d]} of {op_shape[d]})")
                break
    elif name == "gather":
        sizes = eqn.params.get("slice_sizes", ())
        shape = tuple(eqn.invars[0].aval.shape)
        for d, t in enumerate(ins[0].dims):
            if TAG_ENV in t and d < len(sizes) and sizes[d] != shape[d]:
                flag(f"'gather' indexes along the env axis (dim {d}: "
                     f"slice size {sizes[d]} of {shape[d]} rows)")
                break
    elif name == "pad":
        cfg = eqn.params["padding_config"]
        for d, t in enumerate(ins[0].dims):
            if TAG_ENV in t and d < len(cfg) and any(cfg[d]):
                flag(f"'pad' shifts row alignment on the env axis (dim "
                     f"{d}: padding {cfg[d]})")
                break


# --- propagation --------------------------------------------------------------

def _out_ndims(eqn):
    return [getattr(v.aval, "ndim", 0) for v in eqn.outvars]


def _reshape_prov(p: Prov, in_shape, out_shape) -> Prov:
    """Map dim tags through a reshape by matching size-group boundaries."""
    if 0 in in_shape or 0 in out_shape:
        return _fit(p, len(out_shape))
    out = [EMPTY] * len(out_shape)
    i = j = 0
    while i < len(in_shape) or j < len(out_shape):
        ip, jp, gi, gj = 1, 1, [], []
        if i < len(in_shape):
            ip *= in_shape[i]; gi.append(i); i += 1
        if j < len(out_shape):
            jp *= out_shape[j]; gj.append(j); j += 1
        while ip != jp:
            if ip < jp and i < len(in_shape):
                ip *= in_shape[i]; gi.append(i); i += 1
            elif jp < ip and j < len(out_shape):
                jp *= out_shape[j]; gj.append(j); j += 1
            else:
                return _fit(p, len(out_shape))   # unmatched (trailing 1s...)
        tags = frozenset().union(*(p.dims[d] for d in gi)) if gi else EMPTY
        for d in gj:
            out[d] = out[d] | tags
    return Prov(tuple(out), p.val)


def _prop_scanlike(body, ins, n_consts, n_carry, ctx, loop_depth,
                   xs_drop_leading=True):
    """scan-style propagation with a carry tag fixed point."""
    consts = list(ins[:n_consts])
    carry = list(ins[n_consts:n_consts + n_carry])
    xs = [Prov(p.dims[1:], p.val) if (xs_drop_leading and p.dims) else p
          for p in ins[n_consts + n_carry:]]
    outs = []
    for _ in range(8):
        outs = _run(_open(body), consts + carry + xs, ctx, loop_depth + 1)
        new_carry = []
        changed = False
        for old, new in zip(carry, outs[:n_carry]):
            new = _fit(new, len(old.dims))
            merged = Prov(tuple(a | b for a, b in zip(old.dims, new.dims)),
                          old.val | new.val)
            changed = changed or merged != old
            new_carry.append(merged)
        carry = new_carry
        if not changed:
            break
    ys = [Prov((EMPTY,) + p.dims, p.val) for p in outs[n_carry:]]
    return outs[:n_carry] + ys


_PALLAS_GRID_CAP = 4096  # max grid points to evaluate index maps over


def _eval_index_map(bm, point):
    """Evaluate one BlockSpec index map at a concrete grid point."""
    cj = bm.index_map_jaxpr
    from jax._src.core import eval_jaxpr as _eval
    res = _eval(cj.jaxpr, cj.consts, *(np.int32(i) for i in point))
    return tuple(int(np.asarray(r)) for r in res)


def _prop_pallas(eqn, ins, ctx, loop_depth):
    """Descend into a ``pallas_call``: map BlockSpec index maps onto the env
    tag instead of conservatively poisoning the outputs.

    Per grid instance, an env-tagged operand dim must be blocked size-1
    (each kernel instance sees exactly one env row), and every env-tagged
    input and output must agree on WHICH env block the instance touches —
    an input map reading env block ``g(i)`` while the output writes block
    ``i`` routes rows across environments (``pallas-env-block``).  The
    kernel jaxpr is then walked with the env dim dropped (a size-1 block
    carries no cross-env structure) so callback/time/collective rules see
    inside the kernel.  Any unexpected structure raises, which the caller
    turns into the conservative spread-all fallback.
    """
    params = eqn.params
    gm = params["grid_mapping"]
    kernel = _open(params["jaxpr"])
    grid = tuple(gm.grid)
    nouts = _out_ndims(eqn)
    if (getattr(gm, "num_dynamic_grid_bounds", 0)
            or not all(isinstance(g, (int, np.integer)) for g in grid)
            or int(np.prod(grid, dtype=np.int64) if grid else 1)
            > _PALLAS_GRID_CAP):
        raise NotImplementedError("dynamic or oversized pallas grid")
    n_in, n_out = gm.num_inputs, gm.num_outputs
    mappings = list(gm.block_mappings)
    assert len(mappings) == n_in + n_out, (len(mappings), n_in, n_out)
    # eqn.invars may lead with scalar-prefetch/index operands; the block
    # operands are the trailing n_in
    off = len(ins) - n_in
    assert off >= 0, (len(ins), n_in)
    points = list(np.ndindex(*grid)) if grid else [()]

    def block_size(bm, d):
        b = bm.block_shape[d]
        return 1 if b is None else int(b)

    # the env-block index function each instance must agree on, from the
    # env-tagged inputs
    env_fn = None          # tuple of per-point env block indices
    env_extent = None
    for i in range(n_in):
        p = ins[off + i]
        bm = mappings[i]
        shape = tuple(eqn.invars[off + i].aval.shape)
        for d, t in enumerate(p.dims):
            if TAG_ENV not in t:
                continue
            if block_size(bm, d) != 1:
                if ctx.rules.env:
                    ctx.add("pallas-env-block",
                            f"input {i} blocks its env axis (dim {d}) with "
                            f"size {block_size(bm, d)}: each kernel "
                            "instance sees multiple env rows, so the "
                            "kernel body can mix them; block env dims "
                            "size-1", "pallas_call", _src_of(eqn))
                raise NotImplementedError("env dim not size-1 blocked")
            fn = tuple(_eval_index_map(bm, pt)[d] for pt in points)
            if env_fn is None:
                env_fn, env_extent = fn, shape[d]
            elif fn != env_fn:
                if ctx.rules.env:
                    ctx.add("pallas-env-block",
                            f"input {i}'s env-axis index map (dim {d}) "
                            "disagrees with another env-tagged operand's: "
                            "one kernel instance combines rows of "
                            "different environments", "pallas_call",
                            _src_of(eqn))
                raise NotImplementedError("env index maps disagree")

    if env_fn is None:
        # no env-tagged operands: nothing shard-shaped to track precisely
        raise NotImplementedError("no env-tagged pallas operands")

    # outputs: an output dim matching (extent, size-1 block, same index
    # function) inherits the env tag; an output with a candidate env dim
    # whose index function DIFFERS is cross-env routing
    out_provs = []
    in_val = frozenset().union(EMPTY, *(p.val for p in ins))
    for o in range(n_out):
        bm = mappings[n_in + o]
        shape = tuple(eqn.outvars[o].aval.shape)
        dims = [EMPTY] * nouts[o]
        matched = False
        mismatched = None
        for d in range(len(shape)):
            if shape[d] != env_extent or block_size(bm, d) != 1:
                continue
            fn = tuple(_eval_index_map(bm, pt)[d] for pt in points)
            if fn == env_fn:
                dims[d] = frozenset({TAG_ENV})
                matched = True
            else:
                mismatched = d
        if not matched and mismatched is not None:
            if ctx.rules.env:
                ctx.add("pallas-env-block",
                        f"output {o}'s index map routes env blocks "
                        f"differently from the inputs' (dim {mismatched}): "
                        "a kernel instance reading env block g writes a "
                        "different env block — rows cross environments",
                        "pallas_call", _src_of(eqn))
            dims = [frozenset({TAG_ENV})] * nouts[o]   # poison, it's wrong
        elif not matched:
            dims = [frozenset({TAG_ENV})] * nouts[o]   # conservative
        out_provs.append(Prov(tuple(dims), in_val))

    # walk the kernel body with env dims dropped (size-1 blocks): the
    # callback/time/collective rules apply inside the kernel too
    k_provs = []
    for j, v in enumerate(kernel.invars):
        knd = getattr(v.aval, "ndim", 0)
        i = j - (len(kernel.invars) - n_in - n_out - (
            getattr(gm, "num_scratch_operands", 0)))
        src = ins[off + i] if 0 <= i < n_in else _empty(knd)
        p = _fit(src, knd)
        k_provs.append(Prov(tuple(t - {TAG_ENV} for t in p.dims), p.val))
    _run(kernel, k_provs, ctx, loop_depth + 1)
    return out_provs


def _propagate(eqn, name, ins, ctx, loop_depth):
    params = eqn.params
    nouts = _out_ndims(eqn)

    if name in _ELEMENTWISE or name in _CUMULATIVE or name == "select_n" \
            or name == "clamp" or name == "reduce_precision":
        out = _align_union(ins, nouts[0])
        if name == "select_n" and len(ins) >= 2 \
                and TAG_MASK in ins[0].val:
            # the predicate only GATES a select: the output's VALUES come
            # from the branches, so the mask tag does not leak through a
            # where/select — the sanctioned mask combinator stays clean
            branch_val = frozenset().union(EMPTY,
                                           *(p.val for p in ins[1:]))
            if TAG_MASK not in branch_val:
                out = Prov(out.dims, out.val - {TAG_MASK})
        if name == "sub" and len(ins) == 2 \
                and TAG_TIME in ins[0].val and TAG_TIME in ins[1].val:
            # t_a - t_b is a relative duration: the abs-time tag clears,
            # so "rebase to window-relative, then narrow" passes
            out = Prov(out.dims, out.val - {TAG_TIME})
        if name == "rem" and len(ins) == 2 \
                and TAG_TIME in ins[0].val and TAG_TIME not in ins[1].val:
            # t mod period is phase, bounded by the (untagged) divisor
            out = Prov(out.dims, out.val - {TAG_TIME})
        return [out] * len(nouts)

    if name == "convert_element_type" or name == "copy" \
            or name == "device_put":
        return [_fit(ins[0], nouts[0])]

    if name == "optimization_barrier":
        # identity per operand (out i is in i, fusion-sealed) — the elastic
        # mask discipline barriers decision math before its gating selects
        return [_fit(p, n) for p, n in zip(ins, nouts)]

    if name == "broadcast_in_dim":
        bd = params["broadcast_dimensions"]
        dims = [EMPTY] * nouts[0]
        for src, dst in enumerate(bd):
            dims[dst] = ins[0].dims[src]
        return [Prov(tuple(dims), ins[0].val)]

    if name == "transpose":
        perm = params["permutation"]
        return [Prov(tuple(ins[0].dims[p] for p in perm), ins[0].val)]

    if name == "reshape":
        in_shape = tuple(eqn.invars[0].aval.shape)
        out_shape = tuple(eqn.outvars[0].aval.shape)
        return [_reshape_prov(ins[0], in_shape, out_shape)]

    if name == "squeeze":
        drop = set(params["dimensions"])
        dims = tuple(t for d, t in enumerate(ins[0].dims) if d not in drop)
        return [Prov(dims, ins[0].val)]

    if name == "expand_dims":
        add = set(params["dimensions"])
        dims, src = [], iter(ins[0].dims)
        for d in range(nouts[0]):
            dims.append(EMPTY if d in add else next(src, EMPTY))
        return [Prov(tuple(dims), ins[0].val)]

    if name in ("slice", "rev", "dynamic_slice"):
        return [_fit(ins[0], nouts[0])]

    if name == "split":
        return [_fit(ins[0], n) for n in nouts]

    if name == "concatenate":
        return [_align_union(ins, nouts[0])]

    if name == "pad":
        return [_fit(ins[0], nouts[0])]

    if name == "dynamic_update_slice":
        return [_align_union(ins[:2], nouts[0])]

    if name in _REDUCES:
        axes = set(params.get("axes", ()))
        dims = tuple(t for d, t in enumerate(ins[0].dims) if d not in axes)
        return [Prov(dims, ins[0].val)] * len(nouts)

    if name == "dot_general":
        (lc, rc), (lb, rb) = params["dimension_numbers"]
        lhs, rhs = ins[0], ins[1]
        lf = [d for d in range(len(lhs.dims)) if d not in lc and d not in lb]
        rf = [d for d in range(len(rhs.dims)) if d not in rc and d not in rb]
        dims = ([lhs.dims[a] | rhs.dims[b] for a, b in zip(lb, rb)]
                + [lhs.dims[d] for d in lf] + [rhs.dims[d] for d in rf])
        return [Prov(tuple(dims), lhs.val | rhs.val)]

    if name.startswith("scatter"):
        op, upd = ins[0], ins[2] if len(ins) > 2 else ins[0]
        if len(upd.dims) == len(op.dims):
            return [_align_union([op, upd], nouts[0])]
        spread = frozenset().union(EMPTY, *op.dims, *upd.dims)
        return [Prov(tuple(t | spread for t in op.dims), op.val | upd.val)]

    if name == "gather":
        spread = frozenset().union(EMPTY, *(t for p in ins for t in p.dims))
        val = frozenset().union(EMPTY, *(p.val for p in ins))
        return [Prov((spread,) * nouts[0], val)]

    if name in ("iota", "rng_bit_generator", "random_seed", "random_wrap",
                "random_bits", "random_unwrap"):
        return [_empty(n) for n in nouts]

    if name == "sort":
        return [_fit(p, n) for p, n in zip(ins, nouts)]

    if name == "top_k":
        return [_fit(ins[0], n) for n in nouts]

    if name == "pallas_call":
        return _prop_pallas(eqn, ins, ctx, loop_depth)

    if name == "scan":
        return _prop_scanlike(params["jaxpr"], ins, params["num_consts"],
                              params["num_carry"], ctx, loop_depth)

    if name == "while":
        cn, bn = params["cond_nconsts"], params["body_nconsts"]
        carry_in = ins[cn + bn:]
        # cond runs with (cond_consts + carry); walk it for rule checks
        _run(_open(params["cond_jaxpr"]), list(ins[:cn]) + list(carry_in),
             ctx, loop_depth + 1)
        body_ins = list(ins[cn:cn + bn]) + list(carry_in)
        outs = _prop_scanlike(params["body_jaxpr"], body_ins, bn,
                              len(carry_in), ctx, loop_depth,
                              xs_drop_leading=False)
        return outs[:len(carry_in)]

    if name == "cond":
        branch_outs = [_run(_open(br), ins[1:], ctx, loop_depth)
                       for br in params["branches"]]
        merged = []
        for i, n in enumerate(nouts):
            ps = [_fit(bo[i], n) for bo in branch_outs]
            merged.append(_align_union(ps, n))
        return merged

    # generic higher-order fallback (pjit, custom_jvp/vjp, remat,
    # shard_map, closed_call, ...): exactly one jaxpr-like param whose
    # invars line up 1:1 with the eqn's
    sub = None
    for k in _SUBJAXPR_KEYS:
        if k in params and _is_jaxpr_like(params[k]):
            sub = params[k]
            break
    if sub is not None:
        inner = _open(sub)
        n = len(inner.invars)
        sub_ins = list(ins[:n]) + [_empty(getattr(v.aval, "ndim", 0))
                                   for v in inner.invars[len(ins):]]
        sub_ins = [_fit(p, getattr(v.aval, "ndim", 0))
                   for p, v in zip(sub_ins, inner.invars)]
        outs = _run(inner, sub_ins, ctx, loop_depth)
        outs = outs[:len(nouts)]
        outs += [_empty(n) for n in nouts[len(outs):]]
        return [_fit(p, n) for p, n in zip(outs, nouts)]

    # unknown primitive: conservative — spread every tag over every out dim
    spread = frozenset().union(EMPTY, *(t for p in ins for t in p.dims))
    val = frozenset().union(EMPTY, *(p.val for p in ins))
    return [Prov((spread,) * n, val) for n in nouts]


def _run(jaxpr, in_provs, ctx: _Ctx, loop_depth: int):
    """Walk one (open) jaxpr; returns the outvar provs."""
    env = {}

    def read(a):
        if hasattr(a, "val"):          # Literal
            return _empty(np.ndim(a.val))
        return env.get(a, _empty(getattr(a.aval, "ndim", 0)))

    for v, p in zip(jaxpr.invars, in_provs):
        env[v] = _fit(p, getattr(v.aval, "ndim", 0))
    for v in jaxpr.constvars:
        env[v] = _empty(getattr(v.aval, "ndim", 0))

    for eqn in jaxpr.eqns:
        ins = [read(x) for x in eqn.invars]
        name = eqn.primitive.name
        _check_eqn(eqn, name, ins, ctx, loop_depth)
        try:
            outs = _propagate(eqn, name, ins, ctx, loop_depth)
        except Exception:   # propagation must never mask the real trace
            logger.debug("propagation fell back for '%s'", name,
                         exc_info=True)
            spread = frozenset().union(
                EMPTY, *(t for p in ins for t in p.dims))
            val = frozenset().union(EMPTY, *(p.val for p in ins))
            outs = [Prov((spread,) * n, val) for n in _out_ndims(eqn)]
        for v, p in zip(eqn.outvars, outs):
            env[v] = _fit(p, getattr(v.aval, "ndim", 0))
    return [read(x) for x in jaxpr.outvars]


# --- public API ----------------------------------------------------------------

def _parse_tag(tag: str, ndim: int) -> Prov:
    """Tag spec -> Prov.  '' | 'env:0' | 'time' | 'mask' | 'env:0,mask'."""
    dims = [EMPTY] * ndim
    val = EMPTY
    for part in filter(None, (tag or "").split(",")):
        if part == "mask":
            val = val | {TAG_MASK}
        elif part.startswith("env"):
            d = int(part.split(":")[1]) if ":" in part else 0
            if d < ndim:
                dims[d] = dims[d] | {TAG_ENV}
        elif part == "time":
            val = val | {TAG_TIME}
        else:
            raise ValueError(f"unknown provenance tag {part!r}")
    return Prov(tuple(dims), val)


def check_fn(fn: Callable, args, tags, *, rules: Rules = Rules(),
             label: str = "", scan_bound: bool = True):
    """Trace ``fn(*args)`` and return ``(violations, closed_jaxpr)``.

    ``args``: pytrees of arrays / ShapeDtypeStructs (never executed).
    ``tags``: matching pytrees with a string tag spec per leaf — ``""``
    (untagged), ``"env:<dim>"``, ``"time"``, or a comma-joined combination.
    ``scan_bound``: the checked entry points (policies, reward fns, decide
    steps) all execute inside ``lax.scan``/``lax.map`` bodies, so host
    callbacks are flagged at top level too; pass False for a fn that is
    genuinely dispatched outside any loop.
    """
    closed = jax.make_jaxpr(fn)(*args)
    flat_args = jax.tree.leaves(args)
    flat_tags = jax.tree.leaves(tags)
    if len(flat_args) != len(flat_tags):
        raise ValueError("args/tags pytrees do not match: "
                         f"{len(flat_args)} leaves vs {len(flat_tags)} tags")
    in_provs = [_parse_tag(t, int(np.ndim(a) if not hasattr(a, "shape")
                                  else len(a.shape)))
                for a, t in zip(flat_args, flat_tags)]
    ctx = _Ctx(rules, label or getattr(fn, "__name__", "fn"))
    _run(closed.jaxpr, in_provs, ctx, 1 if scan_bound else 0)
    return ctx.violations, closed


def _raise_if(violations, label):
    if violations:
        raise ContractViolation(violations, label)


def _run_to_fixed_point(jaxpr, in_provs, ctx, loop_depth, pairs,
                        max_iter: int = 8):
    """Run ``_run`` with output->input carry links propagated to a tag
    fixed point (``pairs``: (out_idx, in_idx) leaf links).  The same
    mechanism scan bodies use, lifted one level: a decide step / stateful
    policy runs once per window, so tags its carry picks up in step t must
    be visible to the rule checks of step t+1.  ``ctx`` dedups violations
    across re-runs."""
    in_provs = list(in_provs)
    outs = _run(jaxpr, in_provs, ctx, loop_depth)
    for _ in range(max_iter):
        changed = False
        for oi, ii in pairs:
            if oi >= len(outs) or ii >= len(in_provs):
                continue
            old = in_provs[ii]
            new = _fit(outs[oi], len(old.dims))
            merged = Prov(tuple(a | b for a, b in zip(old.dims, new.dims)),
                          old.val | new.val)
            if merged != old:
                changed = True
                in_provs[ii] = merged
        if not changed or not pairs:
            break
        outs = _run(jaxpr, in_provs, ctx, loop_depth)
    return outs


def _check_carry_structure(carry_tree, provs, n_envs, ctx, what="carry"):
    """Fixed-point structural half of ``carry-env-mix``: every carry leaf
    is either env-tagged exactly on its leading dim (a per-env (E, ...)
    row block the mesh shards on dim 0) or fully env-free (identical on
    every shard).  Anything else — env tags on a trailing dim, or an
    env-tagged leaf whose dim 0 isn't E — cannot shard consistently and
    diverges per device."""
    from jax import tree_util as jtu

    flat, _ = jtu.tree_flatten_with_path(carry_tree)
    for (path, leaf), p in zip(flat, provs):
        shape = tuple(getattr(leaf, "shape", ()))
        env_dims = [d for d, t in enumerate(p.dims) if TAG_ENV in t]
        ok = (not env_dims) or (env_dims == [0] and shape
                                and shape[0] == n_envs)
        if not ok:
            ctx.add(
                "carry-env-mix",
                f"{what} leaf '{jtu.keystr(path)}' (shape {shape}) picks "
                f"up env tags on dims {env_dims} across decide steps: a "
                "carry leaf must be env-tagged exactly on dim 0 (a per-env "
                f"(E={n_envs}, ...) block) or fully env-free, or its "
                "sharded and unsharded fixed points diverge",
                "", "")


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def check_policy(model: Callable, n_features: int, n_envs: int = 4, *,
                 rules: Rules = Rules(), label: Optional[str] = None) -> None:
    """Check a policy ``fn((E, F)) -> (E, A)`` against the shard contract."""
    label = label or getattr(model, "name", None) \
        or getattr(model, "__name__", "policy")
    v, _ = check_fn(lambda f: model(f), (_sds((n_envs, n_features)),),
                    ("env:0",), rules=rules, label=label)
    _raise_if(v, f"policy '{label}'")


def check_reward_fn(fn: Callable, n_envs: int, n_features: int,
                    n_actions: int, *, rules: Rules = Rules(),
                    label: str = "custom reward fn") -> None:
    """Check a custom reward ``fn((E,F), (E,A), (E,A)) -> (E,)``."""
    args = (_sds((n_envs, n_features)), _sds((n_envs, n_actions)),
            _sds((n_envs, n_actions)))
    v, closed = check_fn(fn, args, ("env:0", "env:0", "env:0"),
                         rules=rules, label=label)
    out = closed.out_avals[0]
    if tuple(out.shape) != (n_envs,):
        v = list(v) + [Violation(
            rule="reward-shape", primitive="", source="", label=label,
            message=f"returns shape {tuple(out.shape)} for (E={n_envs}, "
                    f"F={n_features}) features; the contract is one reward "
                    "per env row: (E,)")]
    _raise_if(v, label)


def check_reward_terms(terms, n_features: Optional[int] = None,
                       n_actions: Optional[int] = None, n_envs: int = 4, *,
                       rules: Rules = Rules()) -> None:
    """Check every ``custom`` term of a RewardSpec (duck-typed).

    Feature/action counts are unknown at spec construction, so tracing
    retries up a probe-shape ladder when a fn indexes past the probe; a fn
    that cannot be traced at any probe shape is skipped with a warning
    (it will still be checked at true shapes at system construction).
    """
    ladder = ([(n_features, n_actions)]
              if n_features is not None and n_actions is not None
              else [(8, 4), (32, 8), (128, 16)])
    for i, t in enumerate(terms):
        if getattr(t, "kind", None) != "custom" or t.fn is None:
            continue
        label = f"custom reward term #{i}"
        last_exc = None
        for F, A in ladder:
            try:
                check_reward_fn(t.fn, n_envs, F, A, rules=rules, label=label)
                last_exc = None
                break
            except ContractViolation:
                raise
            except Exception as e:   # probe shape too small, etc.
                last_exc = e
        if last_exc is not None:
            warnings.warn(
                f"repro.analysis: could not statically check {label} at "
                f"probe shapes {ladder}: {last_exc!r} — it will be checked "
                "at true shapes at system construction", stacklevel=2)


def check_decide_fns(decide, dstate, n_envs: int, n_features: int, *,
                     rules: Rules = Rules(), label: str = "decide") -> None:
    """Check a :class:`~repro.runtime.predictor.DecideFns` pair as the fused
    scan will run it: ``step`` on a per-window FeatureFrame with the small
    (replay-free) carry, ``bank`` on the stacked transitions + ring.

    Env tags resolve by leaf rank exactly like ``sharding.env_specs``
    (leading dim == E ⇒ env axis); the int32 tick counter carries the
    abs-time tag, so a ``tick.astype(float32)`` anywhere in a custom step
    is caught here.  An elastic decide state (``dstate.active`` is not
    None) auto-enables the ``env-mask-gate`` family: the ``active``/
    ``prev_ok`` leaves enter mask-tagged, and the bank half is traced with
    the (K, E) ``env_mask`` the fused scan hands it.
    """
    from repro.core.frame import FeatureFrame   # lazy: keep import graph flat

    E, F = n_envs, n_features
    elastic = getattr(dstate, "active", None) is not None
    if elastic:
        rules = rules._replace(mask=True)

    def rank_env(x):
        nd = len(getattr(x, "shape", ()))
        return "env:0" if nd > 0 and x.shape[0] == E else ""

    small = dstate._replace(replay=None)
    s_avals = jax.tree.map(
        lambda x: _sds(jnp.shape(x), jnp.asarray(x).dtype), small)
    s_tags = jax.tree.map(rank_env, s_avals)
    if hasattr(s_tags, "_replace") and hasattr(s_tags, "tick"):
        s_tags = s_tags._replace(tick="time")
    if elastic and hasattr(s_tags, "_replace"):
        s_tags = s_tags._replace(active="env:0,mask",
                                 prev_ok="env:0,mask")
    if hasattr(s_tags, "_replace") and hasattr(s_tags, "policy"):
        # policy weights are batch-global: a (F, A) leaf whose F happens to
        # equal E must not be env-tagged (the rank heuristic can't tell),
        # or the policy's own multiply+reduce over F would false-positive
        # as an env reduction
        s_tags = s_tags._replace(
            policy=jax.tree.map(lambda _: "", s_avals.policy))
    frame = FeatureFrame(features=_sds((E, F)), raw=_sds((E, F)),
                         quality=_sds((E,)), tick_time=_sds((E,)))
    f_tags = FeatureFrame("env:0", "env:0", "env:0", "env:0")

    # trace once, then run the rule walk with the state->state carry links
    # propagated to a fixed point: the fused scan feeds step t's new state
    # to step t+1, so tags a recurrent model carry acquires in one window
    # must be visible to the next window's checks (the ``carry-env-mix``
    # structural rule keys on the fixed-point tags)
    closed = jax.make_jaxpr(decide.step)(s_avals, frame)
    state_leaves = jax.tree.leaves(s_avals)
    n_state = len(state_leaves)
    flat_args = jax.tree.leaves((s_avals, frame))
    flat_tags = jax.tree.leaves((s_tags, f_tags))
    in_provs = [_parse_tag(t, len(a.shape))
                for a, t in zip(flat_args, flat_tags)]
    ctx = _Ctx(rules, f"{label}.step")
    # step returns (new_state, outs, transition): the new state's leaves
    # flatten first, aligning 1:1 with the state input leaves
    out_provs = _run_to_fixed_point(
        closed.jaxpr, in_provs, ctx, 1, [(i, i) for i in range(n_state)])
    mcarry = getattr(small, "carry", None)
    n_mcarry = len(jax.tree.leaves(mcarry))
    if rules.env and n_mcarry:
        # the model carry is DecideState's trailing field, so its leaves
        # are the trailing n_mcarry of the state flatten
        _check_carry_structure(mcarry, out_provs[n_state - n_mcarry:n_state],
                               E, ctx, what=f"{label}.step carry")
    _raise_if(ctx.violations, f"{label}.step")

    # bank runs once per batch outside the scan: trace it on a K-stack of
    # the transition rows the traced step actually emits (step returns
    # (new_state, outs, transition) — the transition is the trailing 7
    # flat outputs (obs, actions, reward, next_obs, tick, version,
    # have_prev) by the DecideFns contract)
    K = 3
    trans_flat = closed.out_avals[-7:]
    trans_avals = [_sds((K,) + tuple(a.shape), a.dtype) for a in trans_flat]
    trans_tags = ["env:1" if len(a.shape) > 1 and a.shape[1] == E else ""
                  for a in trans_avals]
    # the tick column (position -3) is int32 abs-time; the version column
    # beside it is an ordinal counter, NOT a time — it may narrow freely
    if trans_flat[-3].dtype == jnp.int32 and trans_flat[-3].ndim == 0:
        trans_tags[-3] = "time"
    replay_avals = jax.tree.map(
        lambda x: _sds(jnp.shape(x), jnp.asarray(x).dtype), dstate.replay)
    r_tags = jax.tree.map(rank_env, replay_avals)
    if elastic:
        # trace bank exactly as the elastic fused scan calls it: with the
        # (K, E) per-row validity mask, mask-tagged so structural use of
        # it inside the ring write is caught (it may only land in the
        # ``valid`` column's VALUES)
        m_aval = _sds((K, E), jnp.bool_)
        v, _ = check_fn(
            lambda r, tr, m: decide.bank(r, tuple(tr), env_mask=m),
            (replay_avals, trans_avals, m_aval),
            (r_tags, trans_tags, "env:1,mask"),
            rules=rules, label=f"{label}.bank", scan_bound=False)
    else:
        v, _ = check_fn(lambda r, tr: decide.bank(r, tuple(tr)),
                        (replay_avals, trans_avals), (r_tags, trans_tags),
                        rules=rules, label=f"{label}.bank",
                        scan_bound=False)
    _raise_if(v, f"{label}.bank")


def check_train_step(fn: Callable, params, opt_state, replay, *,
                     label: str = "train_step") -> None:
    """Contract gate for the online policy-update step (run at
    ``OnlineTrainer`` construction).

    The loss MAY reduce over the sampled batch axis — a minibatch mean is
    the whole point — so the env family is off (``Rules(env=False)``).
    What must hold: no absolute-time float32 casts (the replay
    ``tick_idx`` column enters tagged abs-time, so a loss that weights by
    raw tick index is caught; rebase with a subtraction first) and no
    host callbacks anywhere in the update (``scan_bound=True``: the step
    overlaps the fused decide dispatch, and a hidden host sync inside it
    re-serializes serving and training).

    ``fn(params, opt_state, replay, rng)`` is traced on the real
    arguments' shapes/dtypes; nothing executes.
    """
    to_aval = lambda t: jax.tree.map(
        lambda x: _sds(jnp.shape(x), jnp.asarray(x).dtype), t)
    blank = lambda t: jax.tree.map(lambda _: "", t)
    p_avals, o_avals, r_avals = (to_aval(params), to_aval(opt_state),
                                 to_aval(replay))
    r_tags = blank(r_avals)
    if hasattr(r_tags, "_replace") and hasattr(r_tags, "tick_idx"):
        r_tags = r_tags._replace(tick_idx="time")
    rng = _sds((2,), jnp.uint32)
    v, _ = check_fn(fn, (p_avals, o_avals, r_avals, rng),
                    (blank(p_avals), blank(o_avals), r_tags, ""),
                    rules=Rules(env=False), label=label, scan_bound=True)
    _raise_if(v, label)


def check_system(predictor, decide=None, dstate=None, *, sharded: bool,
                 label: str = "PerceptaSystem") -> None:
    """Construction-time gate for ``PerceptaSystem`` (``*_sharded``/fused).

    The env-axis family only binds under the env-sharded dispatches; the
    callback/collective/time families hold for every fused build (the
    decide step is traced into the window scan either way).
    """
    rules = Rules(env=sharded)
    E = predictor.n_envs
    F = predictor.n_features
    A = predictor.action_space.n
    if decide is not None:
        check_decide_fns(decide, dstate, E, F, rules=rules,
                         label=f"{label} fused decide")
    else:
        check_policy(predictor.model, F, n_envs=E, rules=rules)
        check_reward_terms(predictor.reward_spec.terms, n_features=F,
                           n_actions=A, n_envs=E, rules=rules)


def check_builtins(verbose: bool = False) -> int:
    """Check every builtin policy/reward/decide path; returns #fns checked.

    ``make lint`` runs this next to the AST lint so a regression in a
    builtin (or in the checker itself) fails CI, not a user's registration.
    """
    from repro.core.reward import (RewardSpec, RewardTerm, KINDS,
                                   energy_reward_spec, validate_actions)
    from repro.runtime.predictor import ActionSpace, Predictor, linear_policy

    E, F, A = 4, 6, 2
    n = 0

    check_policy(linear_policy(F, A), F, n_envs=E)
    n += 1

    # every builtin term kind, checked through RewardSpec.compute at (E, ...)
    terms = [RewardTerm(k, feature=1, action=0, target=1.0, band=0.5)
             for k in KINDS if k != "custom"]
    terms.append(RewardTerm("custom", fn=lambda f, a, p:
                            -f[:, 1] * jnp.maximum(f[:, 0], 0.0)))
    spec = RewardSpec(tuple(terms))
    check_reward_fn(lambda f, a, p: spec.compute(f, a, p)[0], E, F, A,
                    label="RewardSpec.compute[builtin kinds]")
    n += len(terms)

    espec = energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0)
    check_reward_fn(lambda f, a, p: espec.compute(f, a, p)[0], E, F, A,
                    label="energy_reward_spec.compute")
    n += 1

    v, _ = check_fn(
        lambda a: validate_actions(a, -jnp.ones((A,)), jnp.ones((A,))),
        (_sds((E, A)),), ("env:0",), label="validate_actions")
    _raise_if(v, "validate_actions")
    n += 1

    pred = Predictor(linear_policy(F, A), espec,
                     ActionSpace(np.full(A, -1.0), np.full(A, 1.0)),
                     E, F, replay_capacity=16)
    check_decide_fns(pred.make_decide_fn(), pred.decide_state(), E, F,
                     label="builtin DecideFns")
    n += 2

    # the elastic masked decide path: active/prev_ok enter mask-tagged and
    # the env-mask-gate family is auto-enabled — the shipped masked step/
    # bank must stay select-only clean
    el_state = pred.decide_state()._replace(
        active=jnp.arange(E) < 2, prev_ok=jnp.zeros((E,), bool))
    check_decide_fns(pred.make_decide_fn(), el_state, E, F,
                     label="builtin elastic DecideFns")
    n += 1

    # every registered policy certifies against the FULL rule catalog
    # (carry fixed point, pallas recursion, param replication) — a registry
    # model that stops certifying fails CI here, not a user's standup
    from repro.analysis.certify import certify_policy
    from repro.runtime.policies import POLICIES
    for key, builder in POLICIES.items():
        certify_policy(builder, name=key, cache_key=("builtin", key))
        n += 1
    if verbose:
        print(f"jaxpr contract check: {n} builtin fns clean")
    return n

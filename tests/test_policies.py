"""Certified policy registry (PR 8): ``runtime.policies`` +
``analysis.certify``.

Every registered policy (linear / mlp / rglru / rwkv6) must statically
certify against the FULL rule catalog — row-wise env math, recurrent-carry
row stability across the decide-step fixed point, pallas BlockSpec env
routing, param replication — and then run the fused/sharded engines
bit-identical to the unsharded per-window reference, stateful carries
riding ``DecideState.carry``. Bad builders (gemm phrasing, cross-env
carries, env-sized params, cross-env pallas index maps) are rejected AT
REGISTRATION with rule, primitive and source named.
"""
import functools
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import certify as certify_mod
from repro.analysis.certify import PolicyCertificate, certify_policy
from repro.analysis.contracts import ContractViolation
from repro.core import PipelineConfig
from repro.core.reward import energy_reward_spec
from repro.distribution import sharding
from repro.runtime.policies import (POLICIES, PolicyConfig, build_policy,
                                    rglru_builder)
from repro.runtime.predictor import (ActionSpace, ModelAdapter, Predictor,
                                     policy_call, policy_call2)
from repro.runtime.receivers import SimulatedDevice
from repro.runtime.system import PerceptaSystem, SourceSpec

E, F, A = 4, 6, 2
STATEFUL = ("rglru", "rwkv6")


def _predictor(model, n_envs=E, n_features=F, cap=16):
    return Predictor(model,
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.full(A, -1.0), np.full(A, 1.0)),
                     n_envs, n_features, replay_capacity=cap)


def _system(mode, policy, n_envs=2, scan_k=3, **kw):
    srcs = [SourceSpec("meter", "mqtt",
                       SimulatedDevice("grid_kw", 60.0, base=3.0, seed=1)),
            SourceSpec("price", "http",
                       SimulatedDevice("price_eur", 300.0, base=0.2,
                                       amplitude=0.05, seed=2))]
    cfg = PipelineConfig(n_envs=n_envs, n_streams=2, n_ticks=8, tick_s=60.0,
                         max_samples=32)
    pred = _predictor(policy, n_envs=n_envs, n_features=cfg.n_features)
    return PerceptaSystem([f"b{i}" for i in range(n_envs)], srcs, cfg, pred,
                          speedup=5000.0, manual_time=True, mode=mode,
                          scan_k=scan_k, **kw)


def _strip(results):
    return [{k: v for k, v in r.items() if k != "latency_s"}
            for r in results]


# --------------------------------------------------------------------------
# registry + certification happy path
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(POLICIES))
def test_registry_policy_certifies_with_certificate_attached(name):
    adapter = build_policy(name, F, A, E)
    cert = adapter.certificate
    assert isinstance(cert, PolicyCertificate)
    assert cert.name == name
    assert cert.stateful == (name in STATEFUL)
    # full-strictness certification: every rule family was enforced
    assert set(cert.rules) == {"env", "collectives", "callbacks", "time",
                               "carry"}
    assert cert.param_spec and cert.jaxpr_sha256
    if name == "rglru":
        assert "'h'" in cert.carry_treedef
    if name == "rwkv6":
        assert "'wkv'" in cert.carry_treedef


def test_certificate_cache_skips_retracing():
    certify_mod.clear_cache()
    a = build_policy("mlp", F, A, E)
    t0 = time.perf_counter()
    b = build_policy("mlp", F, A, E)
    cached_s = time.perf_counter() - t0
    # identical certificate OBJECT: the second standup hit the cache (and
    # paid dict-lookup time, not a re-trace)
    assert b.certificate is a.certificate
    assert cached_s < 0.5


def test_unknown_policy_name_rejected():
    with pytest.raises(KeyError, match="Unrecognized policy .*registered"):
        build_policy("transformer9000", F, A, E)


def test_policy_config_kwargs_flow_to_builder():
    adapter = build_policy(PolicyConfig("rglru", {"hidden": 8}), F, A, E)
    assert adapter.init_carry(E)["h"].shape == (E, 8)
    assert adapter.certificate.stateful


def test_rglru_pallas_kernel_is_certifiable():
    """The pallas_call path certifies — BlockSpec index maps are mapped
    onto the env tag instead of conservatively poisoning the outputs."""
    cert = certify_policy(functools.partial(rglru_builder, use_pallas=True),
                          name="rglru")
    assert cert.stateful


# --------------------------------------------------------------------------
# bad builders rejected at registration, with rule + primitive + source
# --------------------------------------------------------------------------

def _gemm_builder(n_features, n_actions, n_envs=None, **kw):
    W = jnp.ones((n_features, n_actions)) / n_features

    def apply(p, f):
        return jnp.tanh(f @ p["w"])          # the banned gemm phrasing

    return ModelAdapter(lambda f: apply({"w": W}, f), "gemm",
                        params={"w": W}, apply=apply)


def test_gemm_policy_rejected_naming_rule_primitive_source():
    with pytest.raises(ContractViolation) as ei:
        certify_policy(_gemm_builder, name="bad-gemm")
    msg = str(ei.value)
    assert "env-gemm-rows" in msg and "dot_general" in msg
    assert "test_policies.py:" in msg          # source line named
    # satellite: the diagnostic names the registry key AND the builder —
    # never a bare "<lambda>"
    assert "policy 'bad-gemm'" in msg and "_gemm_builder" in msg


def test_lambda_partial_builder_diagnostics_name_builder():
    """functools.partial-wrapped builders unwrap to the underlying fn in
    the diagnostic label (a partial has no __name__ of its own)."""
    bound = functools.partial(_gemm_builder)
    with pytest.raises(ContractViolation) as ei:
        certify_policy(bound, name="bad-gemm-partial")
    msg = str(ei.value).splitlines()[0]
    assert "policy 'bad-gemm-partial'" in msg
    assert "_gemm_builder" in msg


def _roll_carry_builder(n_features, n_actions, n_envs=None, **kw):
    W = jnp.ones((n_features, n_actions)) / n_features

    def apply_carry(p, f, c):
        # row i's new state depends on row i-1's old state: cross-env
        h = jnp.roll(c["h"], 1, axis=0) \
            + (f[..., :, None] * p["w"][None]).sum(-2)
        return jnp.tanh(h), {"h": h}

    return ModelAdapter(None, "roll_carry", params={"w": W},
                        apply_carry=apply_carry,
                        init_carry=lambda E: {"h": jnp.zeros((E, n_actions))})


def test_cross_env_carry_rejected_naming_rule_primitive():
    with pytest.raises(ContractViolation) as ei:
        certify_policy(_roll_carry_builder, name="bad-carry")
    msg = str(ei.value)
    assert "carry-env-mix" in msg
    # the jnp.roll lowering (concatenate of shifted slices) is named with
    # its source line
    assert "concatenate" in msg or "slice" in msg
    assert "test_policies.py:" in msg


def _env_params_builder(n_features, n_actions, n_envs=4, **kw):
    W = jnp.ones((n_envs, n_features, n_actions)) / n_features

    def apply(p, f):
        return (f[..., :, None] * p["w"]).sum(-2)

    return ModelAdapter(lambda f: apply({"w": W}, f), "env_params",
                        params={"w": W}, apply=apply)


def test_env_sized_params_rejected_naming_leaf():
    with pytest.raises(ContractViolation) as ei:
        certify_policy(_env_params_builder, name="bad-params")
    msg = str(ei.value)
    assert "param-replication" in msg and "'w'" in msg
    assert "decide_specs" in msg


def _bad_pallas_builder(n_features, n_actions, n_envs=None, **kw):
    from jax.experimental import pallas as pl_mod

    W = jnp.ones((n_features, n_actions)) / n_features

    def kernel(h_ref, o_ref):
        o_ref[...] = h_ref[...] * 2.0

    def apply_carry(p, f, c):
        h = c["h"]
        nE, H = h.shape
        hp = jnp.pad(h, ((0, 0), (0, 128 - H)))
        # input index map reads the REVERSED env block: instance i reads
        # env row nE-1-i but writes env row i
        out = pl_mod.pallas_call(
            kernel, grid=(nE, 1),
            in_specs=[pl_mod.BlockSpec((1, 128),
                                       lambda bi, wi: (nE - 1 - bi, wi))],
            out_specs=pl_mod.BlockSpec((1, 128), lambda bi, wi: (bi, wi)),
            out_shape=jax.ShapeDtypeStruct((nE, 128), jnp.float32),
            interpret=True)(hp)
        h2 = out[:, :H] + (f[..., :, None] * p["w"][None]).sum(-2)
        return jnp.tanh(h2), {"h": h2}

    return ModelAdapter(None, "bad_pallas", params={"w": W},
                        apply_carry=apply_carry,
                        init_carry=lambda E: {"h": jnp.zeros((E, n_actions))})


def test_cross_env_pallas_index_map_rejected():
    with pytest.raises(ContractViolation) as ei:
        certify_policy(_bad_pallas_builder, name="bad-pallas")
    msg = str(ei.value)
    assert "pallas-env-block" in msg and "pallas_call" in msg
    assert "test_policies.py:" in msg


# --------------------------------------------------------------------------
# stateful policies through the consume paths
# --------------------------------------------------------------------------

def test_stateless_view_rejects_stateful_models():
    """``policy_call`` (the OnlineTrainer's view) refuses apply_carry
    models — online retraining supports stateless policies only."""
    adapter = build_policy("rglru", F, A, E)
    with pytest.raises(ValueError, match="stateful.*stateless"):
        policy_call(adapter)
    with pytest.raises(TypeError, match="stateful"):
        adapter(jnp.zeros((E, F)))           # no stateless __call__ either
    apply2, params, init_carry = policy_call2(adapter)
    acts, carry = apply2(params, jnp.zeros((E, F)), init_carry(E))
    assert acts.shape == (E, A)


def test_predictor_accepts_registry_name_and_threads_carry():
    pred = _predictor("rglru")
    assert pred.model.certificate is not None
    feats = jnp.asarray(np.random.RandomState(0)
                        .normal(size=(E, F)).astype(np.float32))
    pred.on_tick(feats, 60.0)
    c1 = np.asarray(pred._model_carry["h"])
    pred.on_tick(feats * 0.5, 120.0)
    c2 = np.asarray(pred._model_carry["h"])
    assert (c1 != 0).any() and (c1 != c2).any()   # carry actually advances
    # rebinding resets the recurrent state
    pred.set_model("mlp")
    assert pred._model_carry is None


def test_on_windows_matches_on_tick_for_stateful_policy():
    """The K-window batched consume threads the model carry through its
    inner scan exactly as K sequential per-window steps."""
    rng = np.random.RandomState(1)
    feats = rng.normal(size=(6, E, F)).astype(np.float32)
    times = [60.0 * (j + 1) for j in range(6)]
    p_ref = _predictor("rwkv6")
    p_bat = _predictor("rwkv6")
    ref = [p_ref.on_tick(jnp.asarray(feats[j]), times[j]) for j in range(6)]
    acts, rews, per = p_bat.on_windows(jnp.asarray(feats), times)
    for j in range(6):
        assert (np.asarray(ref[j][0]) == np.asarray(acts[j])).all()
        assert (np.asarray(ref[j][1]) == np.asarray(rews[j])).all()
    for a, b in zip(jax.tree.leaves(p_ref._model_carry),
                    jax.tree.leaves(p_bat._model_carry)):
        assert (np.asarray(a) == np.asarray(b)).all()


@pytest.mark.parametrize("name", sorted(POLICIES))
def test_fused_and_sharded_modes_match_per_window_reference(name):
    """System level, every registered policy: fused decide (and the
    degenerate 1-device sharded build) == the per-window on_tick
    reference, stateful carry riding ``DecideState.carry``."""
    ref = _system("scan", name, batched_consume=False)
    fus = _system("scan_fused_decide", name)
    shd = _system("scan_fused_decide_sharded", name)
    rr = _strip(ref.run_windows(7))
    assert rr == _strip(fus.run_windows(7))
    assert rr == _strip(shd.run_windows(7))
    assert fus.policy_certificate is not None
    for s in (ref, fus, shd):
        s.stop()


def test_rglru_pallas_bit_parity_through_fused_decide():
    """``use_pallas=True`` (interpreter-mode kernel) and the lax.scan
    reference produce bit-identical actions through the fused engine."""
    a = _system("scan_fused_decide",
                PolicyConfig("rglru", {"use_pallas": False}))
    b = _system("scan_fused_decide",
                PolicyConfig("rglru", {"use_pallas": True}))
    ra, rb = a.run_windows(5), b.run_windows(5)
    assert _strip(ra) == _strip(rb)
    for x, y in zip(jax.tree.leaves(a.snapshot_decide().carry),
                    jax.tree.leaves(b.snapshot_decide().carry)):
        assert (np.asarray(x) == np.asarray(y)).all()
    a.stop(), b.stop()


def test_decide_specs_shard_model_carry_on_env_dim():
    """The recurrent carry's (E, ...) leaves pick up the env sharding by
    the ``env_specs`` rank rule; the policy params stay replicated."""
    from jax.sharding import PartitionSpec as P

    pred = _predictor("rwkv6")
    specs = sharding.decide_specs(pred.decide_state(), 0)
    assert specs.carry["shift"] == P("data", None)
    assert specs.carry["wkv"] == P("data", None, None)
    assert all(s == P() for s in jax.tree.leaves(specs.policy))


def test_online_training_refuses_stateful_policy():
    with pytest.raises(ValueError, match="stateless"):
        _system("scan_fused_decide", "rglru", train="online")


# --------------------------------------------------------------------------
# acceptance regime: E=256 on the real 8-device mesh (subprocess)
# --------------------------------------------------------------------------

_SHARDED_SCRIPT = """
import numpy as np
from repro.core import PipelineConfig
from repro.core.reward import energy_reward_spec
from repro.runtime.policies import POLICIES
from repro.runtime.predictor import ActionSpace, Predictor
from repro.runtime.receivers import SimulatedDevice
from repro.runtime.system import PerceptaSystem, SourceSpec
import jax
assert len(jax.devices()) == 8, jax.devices()

E = 256

def mk(mode, policy):
    srcs = [SourceSpec("meter", "mqtt",
                       SimulatedDevice("grid_kw", 60.0, base=3.0, seed=1)),
            SourceSpec("price", "http",
                       SimulatedDevice("price_eur", 300.0, base=0.2,
                                       amplitude=0.05, seed=2))]
    cfg = PipelineConfig(n_envs=E, n_streams=2, n_ticks=4, tick_s=60.0,
                         max_samples=16)
    pred = Predictor(policy,
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     E, cfg.n_features, replay_capacity=8)
    return PerceptaSystem([f"b{i}" for i in range(E)], srcs, cfg, pred,
                          speedup=5000.0, manual_time=True, mode=mode,
                          scan_k=3, **({"batched_consume": False}
                                       if mode == "scan" else {}))

strip = lambda rs: [{k: v for k, v in r.items() if k != "latency_s"}
                    for r in rs]
for policy in sorted(POLICIES):
    ref = mk("scan", policy)                 # per-window on_tick reference
    rr = strip(ref.run_windows(5))
    s = mk("scan_fused_decide_sharded", policy)
    assert dict(s.pipeline.mesh.shape) == {"data": 8}, s.pipeline.mesh
    assert s.policy_certificate is not None, policy
    assert strip(s.run_windows(5)) == rr, policy
    ea, eb = ref.export_replay("s"), s.export_replay("s")
    for k in ("obs", "actions", "rewards", "next_obs", "tick_idx", "times"):
        assert (np.asarray(ea[k]) == np.asarray(eb[k])).all(), (policy, k)
    ref.stop(), s.stop()
    print(policy, "OK")
print("POLICY_SHARDED_OK")
"""


def test_registry_policies_sharded_e256_bit_identical():
    """Every registered policy at E=256 on the forced 8-device mesh:
    ``scan_fused_decide_sharded`` == the unsharded per-window reference,
    bit for bit, replay export included — stateful carries env-sharded on
    dim 0 of ``DecideState.carry``."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "POLICY_SHARDED_OK" in out.stdout


# --------------------------------------------------------------------------
# lint surfaces (satellite: machine-readable output + CI annotations)
# --------------------------------------------------------------------------

def test_lint_json_format(tmp_path, capsys):
    import json

    from repro.analysis import lint as lint_mod

    bad = tmp_path / "bad.py"
    bad.write_text("import jax\n"
                   "if jax.__version__ >= '0.5':\n"
                   "    x = 1\n")
    rc = lint_mod.main([str(bad), "--no-baseline", "--format=json"])
    doc = json.loads(capsys.readouterr().out)
    assert rc == 1 and doc["new"] == 1
    (f,) = doc["findings"]
    assert f["rule"] == "jax-version-branch"
    assert f["file"].endswith("bad.py") and f["line"] == 2
    assert f["fingerprint"]["code"].startswith("if jax.__version__")


def test_lint_github_format_emits_per_line_annotations(tmp_path, capsys):
    from repro.analysis import lint as lint_mod

    bad = tmp_path / "bad.py"
    bad.write_text("from jax.experimental import mesh_utils\n")
    rc = lint_mod.main([str(bad), "--no-baseline", "--format=github"])
    out = capsys.readouterr().out
    assert rc == 1
    line = [l for l in out.splitlines() if l.startswith("::error")][0]
    assert "file=" in line and "line=1" in line
    assert "jax-experimental-outside-compat" in line


def test_lint_stage_with_registry_certification_under_30s():
    """The whole ``make lint`` stage — AST lint + builtin jaxpr checks +
    certification of every registered policy — stays under 30 s."""
    from repro.analysis import lint as lint_mod

    t0 = time.perf_counter()
    rc = lint_mod.main(["--jaxpr-builtins"])
    dt = time.perf_counter() - t0
    assert rc == 0
    assert dt < 30.0, f"lint stage took {dt:.1f}s"

"""Roofline-term extraction from compiled XLA artifacts.

Three terms per (arch x shape x mesh), all in seconds (per-device, which for
uniform SPMD equals the global formulae in the brief):

  compute    = HLO_FLOPs_per_dev / PEAK_FLOPS        (cost_analysis 'flops')
  memory     = HLO_bytes_per_dev / HBM_BW            (cost_analysis 'bytes accessed')
  collective = link_bytes_per_dev / ICI_BW           (parsed from compiled HLO)

cost_analysis() is per-device post-SPMD (verified against a hand-sharded
matmul). Collective link-bytes use ring-algorithm multipliers on the result
shape with the group size n parsed from replica_groups:
  all-gather: out*(n-1)/n        all-reduce: 2*out*(n-1)/n
  reduce-scatter: out*(n-1)      all-to-all: out*(n-1)/n
  collective-permute: out
(reduce-scatter's input is n x its output, hence (n-1) on the output.)

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI,
16 GiB HBM per chip.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_BYTES = 16 * 1024**3

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<type>\([^()]*(?:\([^()]*\)[^()]*)*\)|[a-z0-9]+\[[^\]]*\][^\s]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _type_bytes(type_str: str, last_only: bool) -> int:
    shapes = _SHAPE_RE.findall(type_str)
    if not shapes:
        return 0
    if last_only and len(shapes) > 1:
        shapes = shapes[-1:]
    total = 0
    for dt, dims in shapes:
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        ids = [t for t in m.group(1).split(",") if t.strip()]
        return max(1, len(ids))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    return default


def collective_link_bytes(hlo_text: str, n_devices: int) -> Dict[str, float]:
    """Per-device bytes over ICI links, by collective kind + total."""
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0, "count": 0}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        op = m.group("op")
        is_start = m.group("start") is not None
        payload = _type_bytes(m.group("type"), last_only=is_start)
        n = _group_size(line, n_devices)
        if n <= 1:
            continue
        if op == "all-gather":
            b = payload * (n - 1) / n
        elif op == "all-reduce":
            b = 2.0 * payload * (n - 1) / n
        elif op == "reduce-scatter":
            b = payload * (n - 1)
        elif op == "all-to-all":
            b = payload * (n - 1) / n
        else:  # collective-permute
            b = float(payload)
        out[op] += b
        out["count"] += 1
    out["total"] = sum(v for k, v in out.items() if k not in ("count", "total"))
    return out


def terms_from_compiled(compiled, n_devices: int) -> dict:
    from repro.launch import hlo_analysis
    text = compiled.as_text()
    hlo = hlo_analysis.analyze_hlo(text, n_devices)
    flops = float(hlo["flops"])
    bytes_acc = float(hlo["bytes"])
    colls = hlo["collectives"]
    # XLA's own (loop-body-counted-once) numbers, kept for cross-checking
    from repro import compat
    xla_cost = compat.cost_analysis(compiled)
    mem = compiled.memory_analysis()
    mem_d = {
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "code_bytes": int(mem.generated_code_size_in_bytes),
    }
    # live working set per device: args + outputs + temps - aliased(donated)
    peak = mem_d["argument_bytes"] + mem_d["output_bytes"] + \
        mem_d["temp_bytes"] - mem_d["alias_bytes"]
    # CPU-backend bf16->f32 legalization copies (absent on the TPU target;
    # see hlo_analysis.cpu_bf16_upcast_bytes docstring for the evidence).
    # Clamped: arguments/outputs (params, caches, opt state) always live.
    upcast = hlo_analysis.cpu_bf16_upcast_bytes(text)
    floor = mem_d["argument_bytes"] + mem_d["output_bytes"] - mem_d["alias_bytes"]
    peak_tpu = max(peak - upcast, floor)
    return {
        "cpu_upcast_bytes": int(upcast),
        "peak_bytes_per_dev_tpu_adjusted": int(peak_tpu),
        "fits_hbm_cpu_raw": bool(peak <= HBM_BYTES),
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": bytes_acc,
        "coll_link_bytes_per_dev": colls["total"],
        "collectives": colls,
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_acc / HBM_BW,
        "collective_s": colls["total"] / ICI_BW,
        "memory_analysis": mem_d,
        "peak_bytes_per_dev": int(peak),
        "fits_hbm": bool(peak_tpu <= HBM_BYTES),
        "xla_flops_loopfree": float(xla_cost.get("flops", 0.0)),
        "xla_bytes_loopfree": float(xla_cost.get("bytes accessed", 0.0)),
    }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (forward-only serve cells).

    N excludes the input embedding table (a gather, not a matmul) but keeps
    the LM head; tied models count the shared table once (as the head).
    """
    n_active = cfg.active_param_count()
    if not cfg.tie_embeddings:
        n_active -= cfg.vocab_size * cfg.d_model
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token


def summarize(cell: dict) -> str:
    t = cell
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: t[k])
    frac = t.get("model_flops_per_dev", 0.0) / PEAK_FLOPS / max(
        t[dom], 1e-30)
    return (f"compute={t['compute_s']:.4g}s memory={t['memory_s']:.4g}s "
            f"collective={t['collective_s']:.4g}s dominant={dom[:-2]} "
            f"roofline_frac={frac:.3f} fits={t['fits_hbm']}")

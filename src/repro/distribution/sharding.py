"""Logical-dim -> mesh-axis resolution with divisibility fallback.

Every tensor in the system (params, optimizer state, activations, caches,
batches) carries a tuple of *logical dim names* (see models/param.py). This
module maps those names onto mesh axes through an ordered candidate list:
the first candidate whose axis product divides the dim size — and whose axes
are still unused in this tensor — wins; otherwise the dim is replicated.

That one mechanism covers all ten architectures: head counts in
{8, 10, 16, 24, 32, 48, 56} (kv-head sharding when it divides, head_dim
sharding otherwise — interleaved RoPE keeps that shard-local), a vocab of
92553 that refuses to divide 16 (falls back to d_model), 64- and 16-expert
MoEs, ring caches, recurrent states.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ShardingConfig


ENV_AXIS = "data"  # scan-engine mesh axis name: envs -> data parallelism


def env_mesh(n_envs: int, devices=None, axis_name: str = ENV_AXIS) -> Mesh:
    """One-axis device mesh for the env-sharded scan engine.

    The (K, E, S, M) scan batch is data-parallel over E (per-env state rows
    never interact), so the mesh is a single ``data`` axis over the host's
    devices. The "never interact" part is a checkable contract: everything
    dispatched on this mesh must be per-env row-wise, with dots phrased so
    rounding is independent of rows-per-device (``linear_policy``'s
    multiply+reduce) — ``repro.analysis`` enforces it on the decision path
    at system construction by jaxpr provenance (ROADMAP.md "Invariant
    catalog"). Uses the largest device count that divides ``n_envs`` — on a
    lone CPU device this degenerates to a 1-device mesh and ``shard_map``
    becomes a no-op partitioning, which is what lets the sharded mode run
    (and be tested) everywhere. Multi-device CPU recipe:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``, set before JAX
    initializes (``benchmarks/run.py --host-devices 8`` does this).
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    n = len(devices)
    while n > 1 and n_envs % n:
        n -= 1
    return compat.make_mesh(np.asarray(devices[:n]), (axis_name,))


def env_specs(shape_tree, env_axis: int, axis_name: str = ENV_AXIS):
    """PartitionSpec pytree sharding dim ``env_axis`` of every array leaf.

    Leaves with too few dims to carry an env axis are replicated — that
    one rank rule places every carry in the system: the pipeline state's
    scalar ``tick_index``, and the fused decision carry's ``have_prev`` /
    ``tick`` / replay-ring ``cursor`` scalars all replicate while the
    per-env rows (state leaves, prev obs/actions, (E, C, ...) replay
    storage) split on the env dim. Used by
    ``core.pipeline.make_run_many_sharded`` and
    ``make_run_many_decide_sharded`` for the carries (env_axis=0) and the
    K-leading scan batch / stacked outputs (env_axis=1).
    """
    def one(s):
        if s.ndim <= env_axis:
            return P()
        spec = [None] * s.ndim
        spec[env_axis] = axis_name
        return P(*spec)

    return jax.tree.map(one, shape_tree,
                        is_leaf=lambda x: hasattr(x, "ndim"))


def place_env_tree(tree, env_axis: int, mesh: Mesh,
                   axis_name: str = ENV_AXIS, specs=None):
    """Device-put a pytree onto the env mesh with :func:`env_specs` layout.

    The elastic regrow path uses this after ``elastic.grow_env_tree``: the
    grown host-side state / decide-carry / replay trees are re-placed on
    the (possibly re-chosen) env mesh before the rebuilt pipeline's first
    dispatch, so surviving rows land on their new owner devices without a
    layout-change inside jit. Scalars (rank <= env_axis) replicate, per the
    same rank rule that places the carries. ``specs`` overrides the spec
    tree — the decide carry passes :func:`decide_specs` so policy weights
    replicate instead of rank-rule sharding."""
    if specs is None:
        specs = env_specs(tree, env_axis, axis_name)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        tree, specs, is_leaf=lambda x: hasattr(x, "ndim"))


def decide_specs(dstate_tree, env_axis: int, axis_name: str = ENV_AXIS):
    """:func:`env_specs` for the fused decision carry, with the ``policy``
    params subtree forced to replicate.

    Policy weights are batch-global — a (F, A) weight has no env dim — but
    the rank rule of :func:`env_specs` can't know that: any weight whose
    leading dim happened to divide E would silently shard on the feature
    dim and each device would run a different slice of the policy. The
    carry travels as a ``DecideState`` NamedTuple, so the policy subtree's
    specs are replaced wholesale with replicated ``P()``.

    The model's recurrent carry (``DecideState.carry``, PR 8) is NOT
    special-cased: its leaves are per-env ``(E, ...)`` by the certified
    registry contract (``analysis/certify.py``'s carry structural check),
    so the plain rank rule shards them on dim 0 like every other env
    buffer — and ``certify_policy``'s ``param-replication`` probe is what
    guarantees per-env state never hides in the replicated params subtree
    instead.
    """
    specs = env_specs(dstate_tree, env_axis, axis_name)
    rep = jax.tree.map(lambda _: P(), dstate_tree.policy,
                       is_leaf=lambda x: hasattr(x, "ndim"))
    return specs._replace(policy=rep)


def make_abstract_mesh(mesh_shape) -> "jax.sharding.AbstractMesh":
    """Planner-only mesh from ``((name, size), ...)`` — no devices needed.

    Routes through ``repro.compat`` because ``AbstractMesh``'s constructor
    signature differs between JAX 0.4.x and newer releases; every
    NamedSharding the planner emits is mesh-shape-only, so an abstract mesh
    is enough to unit-test resolution against a 256-chip topology.
    """
    names = tuple(n for n, _ in mesh_shape)
    sizes = tuple(s for _, s in mesh_shape)
    return compat.abstract_mesh(sizes, names)

# Data-parallel submesh: prefer pod+data, fall back to data alone.
DP = [("pod", "data"), ("data",)]
MODEL = [("model",)]

# ---------------------------------------------------------------------------
# Rule tables. Order inside each list = preference order.
# ---------------------------------------------------------------------------

def param_rules(perf: ShardingConfig) -> dict:
    rules = {
        "vocab": [("model",)],
        "d_ff": [("model",)],
        "experts": [("model",)],
        "heads_flat": [("model",)],   # H*Dh — divides 16 for every arch
        "kv_flat": [("model",)],      # Hkv*Dh — ditto
        "lru_width": [("model",)],
        # d_model only shards when nothing narrower could (embed fallback)
        "d_model": [("model",)],
    }
    if perf.embed_shard == "d_model":
        # force embedding tables onto d_model (hillclimb lever): handled by
        # resolve() because 'vocab' is removed so d_model picks up 'model'.
        rules = dict(rules)
        rules["vocab"] = []
    return rules


def act_rules(perf: ShardingConfig, *, seq_parallel: Optional[bool] = None) -> dict:
    sp = perf.seq_parallel if seq_parallel is None else seq_parallel
    rules = {
        "batch": list(DP),
        "envs": list(DP),
    }
    if sp:
        rules["seq"] = [("model",)]
    return rules


def cache_rules(perf: ShardingConfig) -> dict:
    rules = {
        "batch": list(DP),
        "kv_heads": [("model",)],
        "lru_width": [("model",)],
        "heads_flat": [("model",)],
        "d_model": [],
        "rwkv_heads": [("model",)],
        # spread the 32k/500k KV cache over 'model' (flash-decode style):
        # decode contracts over cache_seq, giving a small per-step psum
        "cache_seq": [("model",)] if perf.shard_cache_seq else [],
    }
    return rules


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------

def _axes_size(mesh: Mesh, axes: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


# Dims earlier in this list get first pick of mesh axes. d_model is LAST on
# purpose: it is the fallback (e.g. the 92553-vocab embed table) and must not
# steal 'model' from d_ff/heads_flat just because it is dim 0 of every weight.
PRIORITY = ("experts", "vocab", "d_ff", "heads_flat", "kv_flat", "lru_width",
            "cache_seq", "kv_heads", "rwkv_heads", "batch", "envs", "seq",
            "heads", "head_dim", "d_model")


def resolve(shape: Sequence[int], dims: Sequence[str], mesh: Mesh,
            rules: dict) -> NamedSharding:
    assert len(shape) == len(dims), (shape, dims)
    order = sorted(range(len(dims)),
                   key=lambda i: (PRIORITY.index(dims[i])
                                  if dims[i] in PRIORITY else len(PRIORITY)))
    spec = [None] * len(dims)
    used: set = set()
    for i in order:
        size, dim = shape[i], dims[i]
        for cand in rules.get(dim, []):
            axes = tuple(a for a in cand if a in mesh.axis_names)
            if not axes or any(a in used for a in axes):
                continue
            if size % _axes_size(mesh, axes) == 0:
                spec[i] = axes if len(axes) > 1 else axes[0]
                used.update(axes)
                break
    return NamedSharding(mesh, P(*spec))


def tree_shardings(spec_tree, dims_tree, mesh: Mesh, rules: dict):
    """specs: ShapeDtypeStruct pytree; dims: matching logical-dims pytree."""
    return jax.tree.map(
        lambda s, d: resolve(s.shape, d, mesh, rules),
        spec_tree, dims_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def zero1_shardings(spec_tree, dims_tree, mesh: Mesh, perf: ShardingConfig):
    """Optimizer-state shardings: param sharding + extra 'data' shard.

    For every leaf, first resolve the param rules, then give the first dim
    that is still replicated AND divisible by the data-axis size to 'data'
    (and 'pod' too when it also divides). This is ZeRO-1: m/v (and the
    fp32 view of the update) are partitioned across data-parallel peers.
    """
    rules = param_rules(perf)
    if not perf.zero1:
        return tree_shardings(spec_tree, dims_tree, mesh, rules)

    def one(s, d):
        base = resolve(s.shape, d, mesh, rules)
        parts = list(base.spec) + [None] * (len(s.shape) - len(base.spec))
        used = set()
        for p in parts:
            if p is None:
                continue
            used.update(p if isinstance(p, tuple) else (p,))
        for dp_axes in DP:
            axes = tuple(a for a in dp_axes if a in mesh.axis_names)
            if not axes or any(a in used for a in axes):
                continue
            n = _axes_size(mesh, axes)
            for i, (size, part) in enumerate(zip(s.shape, parts)):
                # never shard the scan ('layers') dim: per-iteration
                # dynamic-slice/update of a layers-sharded stack forces GSPMD
                # to materialize the whole (unsharded!) grad stack in-loop
                if d[i] == "layers":
                    continue
                if part is None and size % n == 0:
                    parts[i] = axes if len(axes) > 1 else axes[0]
                    return NamedSharding(mesh, P(*parts))
        return base

    return jax.tree.map(one, spec_tree, dims_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def strip_leading_dim(sh: NamedSharding, mesh: Mesh) -> NamedSharding:
    """Sharding for a per-layer slice of a layer-stacked param."""
    parts = list(sh.spec)
    if parts:
        parts = parts[1:]
    return NamedSharding(mesh, P(*parts))


def gather_hook(mesh: Mesh, perf: ShardingConfig, dims_subtree):
    """ZeRO-3: constrain a scanned group's param slices to their compute
    (model-axis-only) sharding; storage keeps the extra 'data' shard. The
    constraint's transpose reduce-scatters the grads back — ZeRO gradient
    semantics fall out of GSPMD for free."""
    rules = param_rules(perf)

    def hook(group_params):
        def one(x, d):
            # d includes the leading 'layers' dim of the stacked def; the
            # slice inside scan has lost it
            sub = d[1:] if len(d) == x.ndim + 1 else d
            sh = resolve(x.shape, sub, mesh, rules)
            return jax.lax.with_sharding_constraint(x, sh)

        return jax.tree.map(one, group_params, dims_subtree)

    return hook


def batch_sharding(mesh: Mesh, ndim: int, perf: ShardingConfig,
                   *, seq_axis: Optional[int] = None,
                   batch_size: Optional[int] = None) -> NamedSharding:
    """Sharding for a batch array: dim 0 = batch over DP, rest replicated
    (optionally seq over 'model')."""
    for dp_axes in DP:
        axes = tuple(a for a in dp_axes if a in mesh.axis_names)
        if not axes:
            continue
        if batch_size is not None and batch_size % _axes_size(mesh, axes) != 0:
            continue
        spec = [axes if len(axes) > 1 else axes[0]] + [None] * (ndim - 1)
        if seq_axis is not None:
            spec[seq_axis] = "model"
        return NamedSharding(mesh, P(*spec))
    spec = [None] * ndim
    if seq_axis is not None:
        spec[seq_axis] = "model"
    return NamedSharding(mesh, P(*spec))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def constrain_batch(x, mesh: Mesh, perf: ShardingConfig):
    """with_sharding_constraint helper used at block boundaries.

    With ``seq_parallel`` the residual stream (B, S, D) is additionally
    sharded over 'model' on S — Megatron-style sequence parallelism: GSPMD
    turns the per-layer psums into reduce-scatter/all-gather pairs and the
    norms/residual adds run seq-sharded.
    """
    seq_axis = None
    if perf.seq_parallel and x.ndim >= 3 and "model" in mesh.axis_names \
            and x.shape[1] % mesh.shape["model"] == 0:
        seq_axis = 1
    sh = batch_sharding(mesh, x.ndim, perf, batch_size=x.shape[0],
                        seq_axis=seq_axis)
    return jax.lax.with_sharding_constraint(x, sh)


def attn_constrainers(mesh: Mesh, perf: ShardingConfig) -> dict:
    """Constraint hooks for the two attention sharding modes.

    "heads": tensors shaped (B, S, H, ...) -> batch over DP, dim 2 over
             'model' (requires H % model == 0 — checked by the caller).
    "qs":    tensors shaped (B, nq, ...)  -> batch over DP, dim 1 over
             'model' (context-parallel q chunks).
    """
    msize = mesh.shape.get("model", 1)

    def _dp(batch_size):
        for dp_axes in DP:
            axes = tuple(a for a in dp_axes if a in mesh.axis_names)
            if axes and batch_size % _axes_size(mesh, axes) == 0:
                return axes if len(axes) > 1 else axes[0]
        return None

    def c_heads(x):
        if msize <= 1 or x.shape[2] % msize != 0:
            return constrain_batch(x, mesh, perf)
        spec = [_dp(x.shape[0]), None, "model"] + [None] * (x.ndim - 3)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))

    def c_qs(x):
        if msize <= 1 or x.shape[1] % msize != 0:
            return constrain_batch(x, mesh, perf)
        spec = [_dp(x.shape[0]), "model"] + [None] * (x.ndim - 2)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))

    def c_ff(x):
        # keep d_ff-wide activations sharded through the pointwise ops so the
        # backward pass never materializes (B, S, d_ff) unsharded
        if msize <= 1 or x.shape[-1] % msize != 0:
            return x
        spec = [_dp(x.shape[0])] + [None] * (x.ndim - 2) + ["model"]
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))

    return {"heads": c_heads, "qs": c_qs, "ff": c_ff}

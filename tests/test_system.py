"""End-to-end behaviour of the paper's system: streams in -> harmonized
features -> (LM) inference -> rewards -> replay -> retraining."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PipelineConfig
from repro.core import normalize as nz
from repro.core import replay as rp
from repro.core.codec import TokenCodec
from repro.core.reward import energy_reward_spec
from repro.models import LM
from repro.configs.registry import get_config
from repro.runtime.predictor import ActionSpace, ModelAdapter, Predictor
from repro.runtime.receivers import SimulatedDevice
from repro.runtime.system import PerceptaSystem, SourceSpec


def _sources():
    return [
        SourceSpec("meter", "mqtt", SimulatedDevice("grid_kw", 60.0, base=3.0,
                                                    seed=1)),
        SourceSpec("price", "http", SimulatedDevice("price", 300.0, base=0.2,
                                                    amplitude=0.05, seed=2)),
        SourceSpec("thermo", "amqp", SimulatedDevice("temp_c", 30.0,
                                                     base=21.0, amplitude=1.0,
                                                     seed=3)),
    ]


def test_percepta_feeds_an_lm_policy(rng):
    """The paper's headline: Percepta prepares model input for ANY model —
    here an actual transformer consumes TokenCodec tokens per tick."""
    cfg_lm = get_config("qwen3-0.6b:smoke")
    model = LM(cfg_lm, remat_policy="none")
    params = model.init(jax.random.PRNGKey(0))
    codec = TokenCodec(n_features=3, bins=64, clip=4.0)
    assert codec.vocab_needed <= cfg_lm.vocab_size

    state_holder = {}

    def policy(feats):
        # encode features -> tokens -> LM prefill -> logits -> 2 actions
        toks = codec.encode(state_holder["norm"], feats)
        logits, _ = model.prefill(params, {"tokens": toks})
        return jnp.tanh(logits[:, :2])

    pcfg = PipelineConfig(n_envs=2, n_streams=3, n_ticks=8, tick_s=60.0,
                          max_samples=32)
    pred = Predictor(ModelAdapter(policy, "lm_policy"),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=2),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     2, pcfg.n_features, replay_capacity=32)
    sys_ = PerceptaSystem(["a", "b"], _sources(), pcfg, pred, speedup=5000.0, manual_time=True)
    state_holder["norm"] = sys_.state.norm
    res = []
    for _ in range(3):
        state_holder["norm"] = sys_.state.norm
        res.extend(sys_.run_windows(1))
    assert all(np.isfinite(r["mean_reward"]) for r in res)
    assert pred.stats["ticks"] == 3


def test_replay_to_retraining_loop(rng):
    """Stored transitions retrain a policy — the paper's 'data storage for
    model retraining' requirement, closed loop."""
    buf = rp.init(E=4, capacity=64, n_features=3, n_actions=2)
    # fill with a synthetic linear task: reward = -|a - W f|
    W = np.array([[0.5, -0.2], [0.1, 0.3], [-0.4, 0.2]], np.float32)
    for t in range(40):
        obs = rng.normal(0, 1, (4, 3)).astype(np.float32)
        act = rng.uniform(-1, 1, (4, 2)).astype(np.float32)
        rew = -np.abs(act - obs @ W).sum(-1)
        buf = rp.add(buf, jnp.asarray(obs), jnp.asarray(act),
                     jnp.asarray(rew), jnp.asarray(obs),
                     jnp.full((4,), float(t)))
    assert int(buf.size()) == 40

    # behavioural-cloning-style fit of the best actions from replay
    theta = jnp.zeros((3, 2))

    @jax.jit
    def update(theta, batch):
        def loss(th):
            pred = batch["obs"] @ th
            w = jax.nn.softmax(batch["rewards"])  # reward-weighted regression
            return jnp.sum(w[:, None] * jnp.square(pred - batch["actions"]))
        g = jax.grad(loss)(theta)
        return theta - 0.5 * g

    key = jax.random.PRNGKey(0)
    for i in range(200):
        key, k = jax.random.split(key)
        theta = update(theta, rp.sample(buf, k, 64))
    err = float(jnp.abs(theta - W).mean())
    assert err < 0.4  # learned the task structure from replay


def test_anonymized_export_has_no_raw_ids():
    buf = rp.init(E=2, capacity=8, n_features=2, n_actions=1)
    buf = rp.add(buf, jnp.ones((2, 2)), jnp.ones((2, 1)), jnp.ones((2,)),
                 jnp.ones((2, 2)), jnp.zeros((2,)))
    out = rp.export_for_training(buf, ["building-secret-42", "plant-7"],
                                 salt="s")
    assert all("secret" not in e and "plant" not in e for e in out["env_ids"])
    # deterministic pseudonyms (same salt -> same ids), distinct per env
    out2 = rp.export_for_training(buf, ["building-secret-42", "plant-7"],
                                  salt="s")
    assert out["env_ids"] == out2["env_ids"]
    assert len(set(out["env_ids"])) == 2


def test_cloud_mode_many_envs_scale():
    """Paper: 'cloud-based deployments that serve multiple isolated
    environments simultaneously' — 64 envs through one batched tick."""
    from repro.runtime.predictor import linear_policy
    E = 64
    pcfg = PipelineConfig(n_envs=E, n_streams=3, n_ticks=8, tick_s=60.0,
                          max_samples=16)
    pred = Predictor(linear_policy(3, 2),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=2),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     E, pcfg.n_features, replay_capacity=16)
    envs = [f"b{i}" for i in range(E)]
    sys_ = PerceptaSystem(envs, _sources(), pcfg, pred, speedup=20000.0, manual_time=True)
    res = sys_.run_windows(2)
    assert all(np.isfinite(r["mean_reward"]) for r in res)
    assert len(sys_.stats()["queues"]) == E

"""Window aggregation + cross-stream relationships (the Manager's logic).

"It can prioritize the most recent entries, but it can also apply
aggregation logic, such as calculating sums, averages ... the Manager
analyzes the data to identify meaningful relationships within it. For
instance, it may combine temperature readings from sensors of various
brands within the same area to compute a weighted average."

``combine`` implements exactly that: a (features x streams) weight matrix
mapping harmonized per-tick streams to derived features — weighted averages
across same-area sensors, sums across feeders, etc.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

AGGS = ("last", "mean", "sum", "min", "max", "std", "count")


def window_agg(values, mask, agg: str):
    """Aggregate the tick dim away. values/mask: (E, S, T) -> (E, S)."""
    w = mask.astype(jnp.float32)
    n = w.sum(-1)
    big = jnp.float32(3.4e38)
    if agg == "last":
        idx = jnp.where(mask, jnp.arange(values.shape[-1]), -1).max(-1)
        take = jnp.take_along_axis(values, jnp.maximum(idx, 0)[..., None], -1)[..., 0]
        return jnp.where(idx >= 0, take, 0.0)
    if agg == "mean":
        return jnp.einsum("est,est->es", values, w) / jnp.maximum(n, 1)
    if agg == "sum":
        return jnp.einsum("est,est->es", values, w)
    if agg == "min":
        return jnp.min(jnp.where(mask, values, big), -1)
    if agg == "max":
        return jnp.max(jnp.where(mask, values, -big), -1)
    if agg == "std":
        m = jnp.einsum("est,est->es", values, w) / jnp.maximum(n, 1)
        v = jnp.einsum("est,est->es", jnp.square(values - m[..., None]), w)
        return jnp.sqrt(v / jnp.maximum(n, 1))
    if agg == "count":
        return n
    raise ValueError(agg)


def combine(values, weights):
    """Cross-stream relationships. values (E,S,T) x weights (F,S) -> (E,F,T).

    Rows of ``weights`` are derived features: a row with 1/k over k
    temperature streams is the paper's weighted-average example; a row of
    ones over feeder streams is a total-consumption sum.
    """
    return jnp.einsum("est,fs->eft", values, weights)


def feature_vector(values, mask, weights, *, per_tick: bool = False):
    """Full Manager output: derived features flattened for the Encoder.

    values/mask (E,S,T), weights (F,S) ->
      per_tick=False: (E, F) last-tick features
      per_tick=True : (E, F*T) the whole harmonized window
    """
    feats = combine(values, weights)                     # (E, F, T)
    if per_tick:
        E = feats.shape[0]
        return feats.reshape(E, -1)
    return feats[..., -1]

"""Host runtime: protocol codecs, queues, accumulator, DB, full system."""
import numpy as np
import pytest

from repro.core import PipelineConfig
from repro.core.reward import energy_reward_spec
from repro.runtime.accumulator import Accumulator
from repro.runtime.db import LogDB
from repro.runtime.forwarder import Forwarder, ForwarderHub
from repro.runtime.predictor import ActionSpace, Predictor, linear_policy
from repro.runtime.queues import QueueBroker
from repro.runtime.receivers import SimulatedDevice
from repro.runtime.records import CODECS, Record
from repro.runtime.system import PerceptaSystem, SourceSpec
from repro.runtime.translator import Translator


@pytest.mark.parametrize("proto", ["mqtt", "http", "amqp"])
def test_protocol_roundtrip(proto):
    enc, dec = CODECS[proto]
    stream, ts, v = dec(enc("temp_c", 1234.5, -3.25))
    assert stream == "temp_c"
    assert abs(ts - 1234.5) < 1e-3 and abs(v + 3.25) < 1e-5


def test_translator_handles_garbage():
    tr = Translator("src", "mqtt")
    assert tr.translate("e", b"not json") is None
    assert tr.stats["errors"] == 1
    rec = tr.translate("e", CODECS["mqtt"][0]("s", 1.0, 2.0))
    assert rec == Record("e", "s", 1.0, 2.0)


def test_queue_isolation_between_envs():
    broker = QueueBroker()
    broker.publish(Record("env-A", "s", 1.0, 1.0))
    broker.publish(Record("env-B", "s", 1.0, 2.0))
    a = broker.queue_for("env-A").drain()
    b = broker.queue_for("env-B").drain()
    assert len(a) == 1 and len(b) == 1 and a[0].value == 1.0


def test_accumulator_window_close_keeps_future_records():
    acc = Accumulator("e", ["s1", "s2"], max_samples=8)
    acc.ingest([Record("e", "s1", t, float(t)) for t in (1.0, 5.0, 12.0)])
    v, ts, valid = acc.close_window(0.0, 10.0)
    assert valid[0].sum() == 2          # 1.0 and 5.0
    v2, ts2, valid2 = acc.close_window(10.0, 20.0)
    assert valid2[0].sum() == 1         # 12.0 was retained
    assert acc.stats["records"] == 3


def test_device_reporting_interval():
    dev = SimulatedDevice("s", interval_s=60.0, dropout_p=0.0, jitter_s=0.0)
    rs = dev.readings(0.0, 600.0)
    assert len(rs) == 10


def test_logdb_cursor_and_anonymization(tmp_path):
    db = LogDB(str(tmp_path), salt="x", rotate_bytes=200)
    for i in range(5):
        db.append("bldg-1", float(i), [1.0, 2.0], [0.5], 0.1 * i)
    db.close()
    rows = list(db.read_from())
    assert len(rows) == 5
    assert all(r["env"].startswith("env-") and "bldg" not in r["env"]
               for _, r in rows)
    # resume from a cursor: exactly the remaining rows
    cursor = rows[2][0]
    rest = list(db.read_from(*cursor))
    assert len(rest) == 2


def _small_system(mode="fused", n_envs=2):
    srcs = [
        SourceSpec("meter", "mqtt", SimulatedDevice("grid_kw", 60.0, base=3.0,
                                                    seed=1)),
        SourceSpec("price", "http", SimulatedDevice("price", 300.0, base=0.2,
                                                    amplitude=0.05, seed=2)),
        SourceSpec("thermo", "amqp", SimulatedDevice("temp_c", 30.0, base=21.0,
                                                     amplitude=1.0, seed=3)),
    ]
    cfg = PipelineConfig(n_envs=n_envs, n_streams=3, n_ticks=8, tick_s=60.0,
                         max_samples=32)
    pred = Predictor(linear_policy(3, 2),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=2),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     n_envs, cfg.n_features, replay_capacity=64)
    envs = [f"bldg-{i}" for i in range(n_envs)]
    return PerceptaSystem(envs, srcs, cfg, pred, speedup=5000.0, manual_time=True, mode=mode)


def test_system_end_to_end_fused():
    sys_ = _small_system("fused")
    res = sys_.run_windows(3)
    assert len(res) == 3
    assert all(np.isfinite(r["mean_reward"]) for r in res)
    assert res[-1]["observed_frac"] > 0.3
    assert int(sys_.predictor.replay.size()) == 2  # ticks - 1 transitions


def test_system_fused_equals_modular():
    """Same streams through both execution modes -> identical features."""
    a = _small_system("fused")
    b = _small_system("modular")
    ra = a.run_windows(3)
    rb = b.run_windows(3)
    for x, y in zip(ra, rb):
        assert abs(x["mean_reward"] - y["mean_reward"]) < 1e-3
        assert abs(x["observed_frac"] - y["observed_frac"]) < 1e-9


def test_system_forwarders_and_db(tmp_path):
    db = LogDB(str(tmp_path))
    hub = ForwarderHub([Forwarder("hvac", "mqtt", [0]),
                        Forwarder("lights", "http", [1])])
    sys_ = _small_system()
    sys_.forwarders = hub
    sys_.db = db
    sys_.run_windows(2)
    assert hub.forwarders[0].stats["sent"] == 4   # 2 envs x 2 windows
    assert db.stats["rows"] == 4
    db.close()


def test_multi_env_isolation():
    """An env with wildly different data must not perturb its neighbour."""
    base = _small_system(n_envs=2)
    res = base.run_windows(2)
    # env rows are independent pipeline rows by construction; verify the
    # accumulators never mixed records across envs
    for env, acc in base.accumulators.items():
        assert acc.stats["unknown_stream"] == 0
    q = base.stats()["queues"]
    assert set(q) == {"bldg-0", "bldg-1"}

"""Build the jitted train/prefill/decode steps with their shardings.

These are the exact programs the multi-pod dry-run lowers and the train/serve
launchers execute. Buffer donation: params+opt donated in train (in-place
update), cache donated in decode (in-place KV writes).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import (ModelConfig, ShapeConfig, ShardingConfig,
                                TrainConfig)
from repro.distribution import sharding as shd
from repro.models import LM
from repro.models import param as Pm
from repro.train import optimizer as opt_lib


def make_model(cfg: ModelConfig, perf: ShardingConfig, mesh: Optional[Mesh]):
    constrain = None
    attn_constrain = None
    msize = 1
    if mesh is not None:
        constrain = lambda x: shd.constrain_batch(x, mesh, perf)
        attn_constrain = shd.attn_constrainers(mesh, perf)
        msize = mesh.shape.get("model", 1)
    if perf.attn_sharding == "auto":
        attn_mode = "heads" if (cfg.n_heads == 0 or cfg.n_heads % msize == 0) \
            else "ctx"
    else:
        attn_mode = perf.attn_sharding
    model = LM(cfg, rwkv_chunk=perf.rwkv_chunk, q_chunk=perf.q_chunk,
               kv_chunk=perf.kv_chunk, remat_policy=perf.remat_policy,
               constrain=constrain, attn_mode=attn_mode, nq_shard=msize,
               attn_constrain=attn_constrain)
    if mesh is not None and cfg.moe is not None and perf.shard_experts:
        model.moe_shard = (mesh, ("pod", "data"))
    if mesh is not None and perf.shard_cache_seq:
        model.cache_shard = (mesh, ("pod", "data"))
    return model


def _batch_shardings(specs: dict, mesh: Mesh, perf: ShardingConfig):
    return {
        k: shd.batch_sharding(mesh, len(s.shape), perf, batch_size=s.shape[0])
        for k, s in specs.items()
    }


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     perf: ShardingConfig = ShardingConfig(),
                     tcfg: TrainConfig = TrainConfig()):
    """Returns (jitted_fn, example_args=(param_specs, opt_specs, batch_specs))."""
    model = make_model(cfg, perf, mesh)
    pdefs = model.param_defs()
    pspecs, pdims = Pm.specs(pdefs), Pm.dims(pdefs)
    opt_leaf_sh = shd.zero1_shardings(pspecs, pdims, mesh, perf)
    if perf.layout == "zero3":
        # params STORED with the extra data-axis shard; gathered to compute
        # sharding per layer inside the scan (hooks below). Grad reduce-
        # scatter falls out of the gather constraint's transpose.
        param_sh = opt_leaf_sh
        dims_tree = pdims
        if "groups" in dims_tree:
            model.gather_group = shd.gather_hook(mesh, perf, dims_tree["groups"])
        if "tail" in dims_tree:
            model.gather_tail = shd.gather_hook(mesh, perf, dims_tree["tail"])
    else:
        param_sh = shd.tree_shardings(pspecs, pdims, mesh, shd.param_rules(perf))
    f32 = lambda tree: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), tree)
    opt_specs = {"m": f32(pspecs), "v": f32(pspecs),
                 "step": jax.ShapeDtypeStruct((), jnp.int32)}
    opt_sh = {"m": opt_leaf_sh, "v": opt_leaf_sh, "step": shd.replicated(mesh)}
    in_specs = model.input_specs(shape)
    batch_sh = _batch_shardings(in_specs, mesh, perf)
    rep = shd.replicated(mesh)

    nmicro = tcfg.microbatches

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def train_step(params, opt_state, batch):
        if nmicro > 1:
            B = next(iter(batch.values())).shape[0]
            mb = {k: v.reshape((nmicro, B // nmicro) + v.shape[1:])
                  for k, v in batch.items()}
            # accumulated grads carry the ZeRO (param + data-axis) sharding —
            # a 42B-param f32 accumulator sharded only 16-way is 10.5 GB/dev
            shard_acc = lambda t: jax.tree.map(
                jax.lax.with_sharding_constraint, t, opt_leaf_sh)

            def micro(acc, b):
                (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, b)
                acc = shard_acc(jax.tree.map(jnp.add, acc, g))
                return acc, (loss, metrics)

            zero = shard_acc(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            grads, (losses, metricses) = jax.lax.scan(micro, zero, mb)
            grads = jax.tree.map(lambda g: g / nmicro, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda m: m.mean(), metricses)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            # pin grads to the (ZeRO) storage sharding at the loop boundary so
            # XLA can't materialize an unsharded f32 grad stack
            grads = jax.tree.map(jax.lax.with_sharding_constraint,
                                 grads, opt_leaf_sh)
        new_params, new_opt, gnorm = opt_lib.update(grads, opt_state, params, tcfg)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm,
                       lr=opt_lib.schedule(tcfg, new_opt["step"]))
        return new_params, new_opt, metrics

    metrics_sh = {k: rep for k in ("ce", "aux", "loss", "grad_norm", "lr")}
    fn = compat.jit_donated(
        train_step,
        donate_argnums=(0, 1),
        in_shardings=(param_sh, opt_sh, batch_sh),
        out_shardings=(param_sh, opt_sh, metrics_sh),
    )
    return fn, (pspecs, opt_specs, in_specs), (param_sh, opt_sh, batch_sh), model


# ---------------------------------------------------------------------------
# Serve
# ---------------------------------------------------------------------------

def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       perf: ShardingConfig = ShardingConfig()):
    model = make_model(cfg, perf, mesh)
    pdefs = model.param_defs()
    pspecs, pdims = Pm.specs(pdefs), Pm.dims(pdefs)
    param_sh = shd.tree_shardings(pspecs, pdims, mesh, shd.param_rules(perf))
    in_specs = model.input_specs(shape)
    batch_sh = _batch_shardings(in_specs, mesh, perf)

    B = shape.global_batch
    cdefs = model.cache_defs(B, shape.seq_len)
    cache_sh = shd.tree_shardings(Pm.specs(cdefs), Pm.dims(cdefs), mesh,
                                  shd.cache_rules(perf))
    logits_sh = shd.batch_sharding(mesh, 2, perf, batch_size=B)

    fn = jax.jit(model.prefill,
                 in_shardings=(param_sh, batch_sh),
                 out_shardings=(logits_sh, cache_sh))
    return fn, (pspecs, in_specs), (param_sh, batch_sh), model


def build_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      perf: ShardingConfig = ShardingConfig()):
    model = make_model(cfg, perf, mesh)
    pdefs = model.param_defs()
    pspecs, pdims = Pm.specs(pdefs), Pm.dims(pdefs)
    param_sh = shd.tree_shardings(pspecs, pdims, mesh, shd.param_rules(perf))
    in_specs = model.input_specs(shape)
    batch_sh = _batch_shardings(in_specs, mesh, perf)

    B = shape.global_batch
    cdefs = model.cache_defs(B, shape.seq_len)
    cache_specs = Pm.specs(cdefs)
    cache_sh = shd.tree_shardings(cache_specs, Pm.dims(cdefs), mesh,
                                  shd.cache_rules(perf))
    logits_sh = shd.batch_sharding(mesh, 2, perf, batch_size=B)

    fn = compat.jit_donated(model.decode_step,
                            donate_argnums=(2,),
                            in_shardings=(param_sh, batch_sh, cache_sh),
                            out_shardings=(logits_sh, cache_sh))
    return fn, (pspecs, in_specs, cache_specs), (param_sh, batch_sh, cache_sh), model


def build_step(kind: str, cfg, shape, mesh, perf=ShardingConfig(),
               tcfg=TrainConfig()):
    if kind == "train":
        return build_train_step(cfg, shape, mesh, perf, tcfg)
    if kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, perf)
    if kind == "decode":
        return build_decode_step(cfg, shape, mesh, perf)
    raise ValueError(kind)

"""InternVL2-26B — InternViT frontend (STUB) + InternLM2-20B language
backbone. ``input_specs()`` provides precomputed patch embeddings per the
assignment. [arXiv:2404.16821; hf]"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,      # NOT divisible by 16: exercises sharding fallback
    layer_pattern=(ATTN_GLOBAL,),
    frontend="vlm",
    n_patches=256,
    rope_theta=1_000_000.0,
    source="arXiv:2404.16821; hf:OpenGVLab/InternVL2-26B",
)

"""Sharding-rule resolution, HLO analysis, and an 8-fake-device mini dry-run
(subprocess: device count must be set before jax initializes)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs.base import ShardingConfig
from repro.distribution import sharding as shd
from repro.launch import hlo_analysis


def FakeMesh(shape):
    """Abstract 16x16 mesh — NamedSharding-compatible without 256 devices."""
    return shd.make_abstract_mesh(shape)


def _spec(shape, dims, mesh_shape=(("data", 16), ("model", 16))):
    mesh = FakeMesh(mesh_shape)
    return shd.resolve(shape, dims, mesh, shd.param_rules(ShardingConfig()))


def test_vocab_not_divisible_falls_back_to_d_model():
    # internvl2 vocab 92553 % 16 != 0 -> d_model takes 'model'
    import jax.sharding as js
    sh = _spec((92553, 6144), ("vocab", "d_model"))
    assert sh.spec == js.PartitionSpec(None, "model")


def test_priority_experts_beat_d_ff():
    import jax.sharding as js
    sh = _spec((16, 4096, 6400), ("experts", "d_model", "d_ff"))
    assert sh.spec == js.PartitionSpec("model", None, None)


def test_d_model_never_steals_from_heads_flat():
    import jax.sharding as js
    sh = _spec((4096, 2048), ("d_model", "heads_flat"))
    assert sh.spec == js.PartitionSpec(None, "model")


def test_zero1_never_shards_layers():
    import jax.sharding as js
    from repro.models import param as Pm
    mesh = FakeMesh((("data", 16), ("model", 16)))
    spec_tree = {"w": jax.ShapeDtypeStruct((32, 4096, 6400), jnp_dtype())}
    dims_tree = {"w": ("layers", "d_model", "d_ff")}
    out = shd.zero1_shardings(spec_tree, dims_tree, mesh, ShardingConfig())
    # layers (32, divisible by 16) must NOT get 'data'; d_model does
    assert out["w"].spec == js.PartitionSpec(None, "data", "model")


def jnp_dtype():
    import jax.numpy as jnp
    return jnp.float32


def test_hlo_trip_count_multiplication():
    res = hlo_analysis.analyze_hlo_file(
        os.path.join(os.path.dirname(__file__), "data_hlo_sample.txt"), 8)
    # dot: 2*32*128*512 per trip * 7 trips ~ 2.94e7 (+ elementwise noise)
    assert 2.9e7 < res["flops"] < 3.2e7
    assert res["collectives"]["all-gather"] > 0


def test_hlo_missing_file_clear_error():
    with pytest.raises(FileNotFoundError, match="HLO dump not found"):
        hlo_analysis.analyze_hlo_file("/no/such/dump.txt", 8)


DRYRUN_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import numpy as np, jax, json
    from repro import compat
    from repro.configs.base import SHAPES, ShapeConfig, ShardingConfig
    from repro.configs.registry import get_config
    from repro.launch.steps import build_step
    from repro.launch import roofline

    mesh = compat.make_mesh(np.asarray(jax.devices()).reshape(2, 4),
                            ("data", "model"))
    cfg = get_config({arch!r} + ":smoke")
    shape = ShapeConfig("t", 64, 8, {kind!r})
    fn, specs, shardings, model = build_step(shape.kind, cfg, shape, mesh)
    with compat.set_mesh(mesh):
        compiled = fn.lower(*specs).compile()
    cell = roofline.terms_from_compiled(compiled, 8)
    print(json.dumps({{"flops": cell["hlo_flops_per_dev"],
                       "coll": cell["coll_link_bytes_per_dev"],
                       "fits": cell["fits_hbm"]}}))
""")


@pytest.mark.parametrize("arch,kind", [
    ("qwen3-0.6b", "train"),
    ("phi3.5-moe-42b-a6.6b", "train"),   # shard_map MoE under 8 devices
    ("recurrentgemma-2b", "decode"),     # ring cache + shard_map writes
])
def test_mini_dryrun_8_devices(arch, kind):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = DRYRUN_SNIPPET.format(src=os.path.abspath(src), arch=arch,
                                 kind=kind)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=420)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["flops"] > 0
    assert out["fits"]

"""Property-based tests (hypothesis) on the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.core import anomaly as an
from repro.core import codec as cd
from repro.core import gapfill as gf
from repro.core import harmonize as hz
from repro.core import normalize as nz
from repro.core.frame import make_raw_window
from repro.core.reward import RewardSpec, RewardTerm
from repro.distribution import compression as comp

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def raw_windows(draw, max_e=3, max_s=3, max_m=12):
    E = draw(st.integers(1, max_e))
    S = draw(st.integers(1, max_s))
    M = draw(st.integers(1, max_m))
    seed = draw(st.integers(0, 2**16))
    rng = np.random.RandomState(seed)
    vals = rng.normal(0, 5, (E, S, M)).astype(np.float32)
    ts = rng.uniform(0, 600, (E, S, M)).astype(np.float32)
    valid = rng.rand(E, S, M) > rng.uniform(0, 0.8)
    return make_raw_window(vals, ts, valid)


@given(raw_windows())
@settings(**SETTINGS)
def test_harmonize_sum_conserves_mass(raw):
    """'sum' aggregation conserves the total of in-window valid samples."""
    ticks = hz.tick_grid(jnp.zeros((raw.n_envs,)), 60.0, 10)
    out, obs = hz.harmonize(raw, ticks, 60.0, "sum")
    in_window = np.asarray(raw.valid) & (np.asarray(raw.timestamps) > 0) \
        & (np.asarray(raw.timestamps) <= 600.0)
    total_in = (np.asarray(raw.values) * in_window).sum()
    assert_allclose(np.asarray(out).sum(), total_in, rtol=1e-3, atol=1e-3)


@given(raw_windows())
@settings(**SETTINGS)
def test_harmonize_mean_bounded_by_extremes(raw):
    ticks = hz.tick_grid(jnp.zeros((raw.n_envs,)), 60.0, 10)
    out, obs = hz.harmonize(raw, ticks, 60.0, "mean")
    o = np.asarray(out)[np.asarray(obs)]
    if o.size:
        v = np.asarray(raw.values)[np.asarray(raw.valid)]
        assert o.min() >= v.min() - 1e-4 and o.max() <= v.max() + 1e-4


@given(st.integers(0, 2**16), st.integers(1, 4), st.integers(2, 16))
@settings(**SETTINGS)
def test_locf_fills_everything_after_first_obs(seed, S, T):
    rng = np.random.RandomState(seed)
    v = rng.normal(0, 1, (1, S, T)).astype(np.float32)
    obs = rng.rand(1, S, T) > 0.5
    state = gf.init_state(1, S)
    ticks = (np.arange(T, dtype=np.float32) * 60)[None]
    out, filled, _ = gf.gap_fill(jnp.asarray(v), jnp.asarray(obs), state,
                                 jnp.asarray(ticks), "locf")
    filled = np.asarray(filled)
    for s in range(S):
        row_obs = obs[0, s]
        if row_obs.any():
            first = row_obs.argmax()
            # every tick after the first observation is observed or filled
            assert (row_obs | filled[0, s])[first:].all()


@given(st.integers(0, 2**16), st.integers(2, 20))
@settings(**SETTINGS)
def test_welford_merge_equals_two_pass(seed, n_windows):
    rng = np.random.RandomState(seed)
    state = nz.init_state(1, 1)
    rows = []
    for _ in range(n_windows):
        v = rng.normal(rng.uniform(-5, 5), rng.uniform(0.5, 3),
                       (1, 1, 8)).astype(np.float32)
        m = rng.rand(1, 1, 8) > 0.4
        rows.append(v[m])
        state = nz.update(state, jnp.asarray(v), jnp.asarray(m))
    allv = np.concatenate(rows) if rows else np.zeros((0,))
    if allv.size > 2:
        assert_allclose(float(state.mean[0, 0]), allv.mean(),
                        rtol=1e-3, atol=1e-3)
        assert_allclose(float(nz.sigma(state)[0, 0]), allv.std(ddof=1),
                        rtol=1e-2, atol=1e-3)


@given(st.integers(0, 2**16))
@settings(**SETTINGS)
def test_token_codec_roundtrip_within_bin(seed):
    rng = np.random.RandomState(seed)
    state = nz.init_state(4, 6)
    v = rng.normal(10, 4, (4, 6, 32)).astype(np.float32)
    state = nz.update(state, jnp.asarray(v), jnp.ones(v.shape, bool))
    codec = cd.TokenCodec(n_features=6, bins=128, clip=4.0)
    feats = jnp.asarray(v[..., -1])
    toks = codec.encode(state, feats)
    assert (np.asarray(toks) >= codec.offset).all()
    assert (np.asarray(toks) < codec.vocab_needed).all()
    back = codec.decode(state, toks, -1e9, 1e9)
    # max roundtrip error = half a bin in z-space
    half_bin_z = (2 * codec.clip / codec.bins) / 2
    sig = np.asarray(nz.sigma(state))
    z_err = np.abs(np.asarray(back) - np.asarray(feats)) / np.maximum(sig, 1e-6)
    clipped = np.abs(np.asarray(nz.znorm(state, feats[..., None])[..., 0])) > codec.clip
    assert (z_err[~clipped] <= half_bin_z + 1e-3).all()


@given(st.integers(0, 2**16), st.integers(1, 8))
@settings(**SETTINGS)
def test_reward_terms_are_additive_and_scale(seed, E):
    rng = np.random.RandomState(seed)
    f = jnp.asarray(rng.normal(0, 1, (E, 4)).astype(np.float32))
    a = jnp.asarray(rng.normal(0, 1, (E, 2)).astype(np.float32))
    t1 = RewardTerm("linear", weight=2.0, feature=1)
    t2 = RewardTerm("quadratic_error", weight=0.5, feature=2, target=1.0)
    total, per = RewardSpec((t1, t2)).compute(f, a)
    assert_allclose(np.asarray(total), np.asarray(per).sum(-1), rtol=1e-5)
    total2, _ = RewardSpec((t1,)).compute(f, a)
    assert_allclose(np.asarray(total2),
                    2.0 * np.asarray(f)[:, 1], rtol=1e-5)


@given(st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_compression_error_feedback_converges(seed):
    """EF quantization: the mean of reconstructions over steps approaches the
    true (constant) gradient — the defining EF-SGD property."""
    rng = np.random.RandomState(seed)
    g = {"w": jnp.asarray(rng.normal(0, 1, (32, 16)).astype(np.float32))}
    ef = comp.init_ef(g)
    recon_sum = np.zeros((32, 16), np.float32)
    steps = 24
    for _ in range(steps):
        recon, ef = comp.roundtrip(g, ef)
        recon_sum += np.asarray(recon["w"])
    err = np.abs(recon_sum / steps - np.asarray(g["w"])).max()
    one_step_err = np.abs(
        np.asarray(comp.roundtrip(g, comp.init_ef(g))[0]["w"])
        - np.asarray(g["w"])).max()
    assert err <= one_step_err + 1e-6
    assert err < 0.02  # time-averaged EF error shrinks ~1/steps


@given(st.integers(0, 2**16), st.integers(1, 3), st.integers(4, 16))
@settings(**SETTINGS)
def test_anomaly_replacement_never_widens_range(seed, S, T):
    rng = np.random.RandomState(seed)
    state = an.AnomalyState(mean=jnp.zeros((1, S)), var=jnp.ones((1, S)),
                            count=jnp.full((1, S), 100.0))
    v = rng.normal(0, 3, (1, S, T)).astype(np.float32)
    obs = jnp.ones((1, S, T), bool)
    spikes = an.detect_zscore(jnp.asarray(v), obs, state, 3.0)
    out, _, _ = an.replace(jnp.asarray(v), obs, spikes, state, "clip", 3.0)
    assert np.abs(np.asarray(out)).max() <= max(np.abs(v).max(), 3.0) + 1e-5

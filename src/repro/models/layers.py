"""Core transformer layers as pure functions over param dicts.

Numerics/structure notes (all deliberate, see DESIGN.md):
  * RoPE uses the *interleaved* (even/odd pair) formulation so that when the
    sharding fallback puts the mesh ``model`` axis on ``head_dim`` (archs whose
    head counts don't divide 16, e.g. gemma2's 8 or deepseek's 56), the
    rotation stays shard-local (pairs are adjacent) instead of forcing a
    cross-shard permute as rotate-half would.
  * Attention is *blockwise* (online-softmax over KV chunks, scanned over Q
    chunks) — the flash-attention recurrence expressed at the jnp level so the
    (S, S) score matrix is never materialized. ``kernels/flash_attention`` is
    the VMEM-tiled Pallas version of the same recurrence for TPU hot paths.
  * GQA never materializes repeated KV heads: Q is reshaped to
    (…, kv_heads, q_per_kv, head_dim) and contracted against KV directly.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.param import ParamDef

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))).astype(dt)


def rms_norm_defs(d: int, dtype) -> ParamDef:
    # gemma-style (1 + w) scaling; zero-init == identity
    return ParamDef((d,), ("d_model",), dtype, "zeros")


# ---------------------------------------------------------------------------
# Rotary position embeddings (interleaved pairs)
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x_f = x.astype(jnp.float32).reshape(x.shape[:-1] + (half, 2))
    even, odd = x_f[..., 0], x_f[..., 1]
    r_even = even * cos - odd * sin
    r_odd = even * sin + odd * cos
    out = jnp.stack([r_even, r_odd], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise causal attention (online softmax; GQA; local windows; softcap)
# ---------------------------------------------------------------------------

def _softcap(scores, cap: float):
    if cap and cap > 0.0:
        return jnp.tanh(scores / cap) * cap
    return scores


NEG_INF = -1e30


def blockwise_attention(q, k, v, *, q_positions, kv_positions, kv_valid,
                        window: int = 0, softcap: float = 0.0,
                        q_chunk: int = 512, kv_chunk: int = 1024,
                        scale: Optional[float] = None,
                        q_mode: str = "scan", constrain_qs=None):
    """Causal (optionally sliding-window) attention without an (S,S) buffer.

    q:  (B, Sq, Hkv, G, Dh)   -- G = q heads per kv head
    k,v:(B, Skv, Hkv, Dh)
    q_positions: (B, Sq) absolute positions of the queries
    kv_positions:(B, Skv) absolute positions of the keys
    kv_valid:    (B, Skv) bool; invalid slots are masked out
    window: 0 = global causal; >0 = only attend where 0 <= qpos-kpos < window
    q_mode: "scan"  — sequential scan over Q chunks (head-sharded TP path);
            "shard" — Q-chunk dim kept as a tensor dim so the mesh 'model'
            axis shards it (context parallelism for archs whose head counts
            don't divide the axis). ``constrain_qs`` places the constraint.
    Returns (B, Sq, Hkv, G, Dh).
    """
    B, Sq, Hkv, G, Dh = q.shape
    Skv = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    q = (q * scale).astype(q.dtype)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq = -(-Sq // q_chunk)
    nk = -(-Skv // kv_chunk)
    # pad seq dims to multiples of the chunk sizes
    pq = nq * q_chunk - Sq
    pk = nk * kv_chunk - Skv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pq)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pk)))
        kv_valid = jnp.pad(kv_valid, ((0, 0), (0, pk)))

    ks = k.reshape(B, nk, kv_chunk, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kv_chunk, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    kpos = kv_positions.reshape(B, nk, kv_chunk).transpose(1, 0, 2)
    kval = kv_valid.reshape(B, nk, kv_chunk).transpose(1, 0, 2)

    if q_mode == "shard":
        return _blockwise_attention_ctx(
            q, q_positions, ks, vs, kpos, kval, nq=nq, q_chunk=q_chunk,
            window=window, softcap=softcap, constrain_qs=constrain_qs,
            out_len=Sq)

    qs = q.reshape(B, nq, q_chunk, Hkv, G, Dh).transpose(1, 0, 2, 3, 4, 5)
    qpos = q_positions.reshape(B, nq, q_chunk).transpose(1, 0, 2)

    def q_step(_, qc):
        q_i, qpos_i = qc  # (B, qc, Hkv, G, Dh), (B, qc)

        @jax.checkpoint  # recompute scores in backward: residuals stay O(chunk)
        def kv_step(carry, kc):
            acc, m, denom = carry
            k_j, v_j, kpos_j, kval_j = kc
            # scores: (B, qc, Hkv, G, kc)
            s = jnp.einsum("bqhgd,bkhd->bqhgk", q_i, k_j,
                           preferred_element_type=jnp.float32)
            s = _softcap(s, softcap)
            causal = qpos_i[:, :, None] >= kpos_j[:, None, :]
            mask = causal & kval_j[:, None, :]
            if window and window > 0:
                mask &= (qpos_i[:, :, None] - kpos_j[:, None, :]) < window
            s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
            # clamp so fully-masked rows give p == exp(NEG_INF - m) == 0,
            # not exp(0); keeps padded rows at exactly zero output.
            m_new = jnp.maximum(jnp.maximum(m, s.max(axis=-1)), -1e29)
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            denom = denom * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(v_j.dtype), v_j,
                            preferred_element_type=jnp.float32)
            acc = acc * alpha[..., None] + pv
            return (acc, m_new, denom), None

        acc0 = jnp.zeros((B, q_i.shape[1], Hkv, G, Dh), jnp.float32)
        m0 = jnp.full((B, q_i.shape[1], Hkv, G), NEG_INF, jnp.float32)
        d0 = jnp.zeros((B, q_i.shape[1], Hkv, G), jnp.float32)
        (acc, m, denom), _ = jax.lax.scan(
            kv_step, (acc0, m0, d0), (ks, vs, kpos, kval))
        out = acc / jnp.maximum(denom[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(jax.checkpoint(q_step), None, (qs, qpos))
    # outs: (nq, B, qc, Hkv, G, Dh)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * q_chunk, Hkv, G, Dh)
    return out[:, :Sq]


def _blockwise_attention_ctx(q, q_positions, ks, vs, kpos, kval, *, nq,
                             q_chunk, window, softcap, constrain_qs, out_len):
    """Context-parallel online-softmax attention.

    The Q-chunk count ``nq`` stays a tensor dim (sharded over 'model' via
    ``constrain_qs``); KV chunks are scanned sequentially and stay replicated,
    so no (S, S) score matrix ever crosses a link — the only collective is
    the small q/out reshard at the boundary.
    """
    B = q.shape[0]
    Hkv, G, Dh = q.shape[-3:]
    qs = q.reshape(B, nq, q_chunk, Hkv, G, Dh)
    qpos = q_positions.reshape(B, nq, q_chunk)
    if constrain_qs is not None:
        qs = constrain_qs(qs)

    @jax.checkpoint
    def kv_step(carry, kc):
        acc, m, denom = carry
        k_j, v_j, kpos_j, kval_j = kc
        s = jnp.einsum("bnqhgd,bkhd->bnqhgk", qs, k_j,
                       preferred_element_type=jnp.float32)
        s = _softcap(s, softcap)
        causal = qpos[:, :, :, None] >= kpos_j[:, None, None, :]
        mask = causal & kval_j[:, None, None, :]
        if window and window > 0:
            mask &= (qpos[:, :, :, None] - kpos_j[:, None, None, :]) < window
        s = jnp.where(mask[:, :, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(jnp.maximum(m, s.max(axis=-1)), -1e29)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        denom = denom * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bnqhgk,bkhd->bnqhgd", p.astype(v_j.dtype), v_j,
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (acc, m_new, denom), None

    acc0 = jnp.zeros((B, nq, q_chunk, Hkv, G, Dh), jnp.float32)
    m0 = jnp.full((B, nq, q_chunk, Hkv, G), NEG_INF, jnp.float32)
    d0 = jnp.zeros((B, nq, q_chunk, Hkv, G), jnp.float32)
    if constrain_qs is not None:
        acc0, m0, d0 = constrain_qs(acc0), constrain_qs(m0), constrain_qs(d0)
    (acc, m, denom), _ = jax.lax.scan(kv_step, (acc0, m0, d0),
                                      (ks, vs, kpos, kval))
    out = acc / jnp.maximum(denom[..., None], 1e-30)
    out = out.astype(q.dtype).reshape(B, nq * q_chunk, Hkv, G, Dh)
    return out[:, :out_len]


def decode_attention(q, k, v, *, kv_positions, kv_valid, q_position,
                     window: int = 0, softcap: float = 0.0,
                     scale: Optional[float] = None):
    """Single-position attention against a (possibly ring) KV cache.

    q: (B, 1, Hkv, G, Dh); k,v: (B, Skv, Hkv, Dh);
    kv_positions/kv_valid: (B, Skv); q_position: (B,) absolute position.
    """
    Dh = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", (q * scale), k,
                   preferred_element_type=jnp.float32)
    s = _softcap(s, softcap)
    mask = kv_valid & (kv_positions <= q_position[:, None])
    if window and window > 0:
        mask &= (q_position[:, None] - kv_positions) < window
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (params + apply)
# ---------------------------------------------------------------------------

def attention_defs(cfg) -> dict:
    """Attention projections with *flattened* head dims.

    H*Dh and Hkv*Dh are divisible by the 16-wide mesh 'model' axis for every
    assigned arch (head counts 8..56 are not — that's the whole point), so
    the projections' compute always shards fully. The q flat layout is
    (kv_group, q_per_kv, head_dim) row-major so the grouped-GQA reshape is a
    local view.
    """
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.param_dtype)
    s = 0.02
    defs = {
        "norm": rms_norm_defs(d, dt),
        "wq": ParamDef((d, H * Dh), ("d_model", "heads_flat"), dt, "normal", s),
        "wk": ParamDef((d, Hkv * Dh), ("d_model", "kv_flat"), dt, "normal", s),
        "wv": ParamDef((d, Hkv * Dh), ("d_model", "kv_flat"), dt, "normal", s),
        "wo": ParamDef((H * Dh, d), ("heads_flat", "d_model"), dt, "normal",
                       s / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((Dh,), ("head_dim",), dt, "zeros")
        defs["k_norm"] = ParamDef((Dh,), ("head_dim",), dt, "zeros")
    if cfg.post_norms:
        defs["post_norm"] = rms_norm_defs(d, dt)
    return defs


def attention_qkv(p, x, cfg, positions):
    """Project + rope. Returns q (B,S,H,Dh), k,v (B,S,Hkv,Dh) (unrepeated)."""
    B, S = x.shape[:2]
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, Dh)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, Hkv, Dh)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_out(p, attn, x_dtype):
    """attn: (B, S, Hkv, G, Dh) or (B, S, H, 1, Dh) -> (B, S, d)."""
    B, S = attn.shape[:2]
    flat = attn.reshape(B, S, -1)
    return flat @ p["wo"].astype(x_dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_defs(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    defs = {
        "norm": rms_norm_defs(d, dt),
        "w_gate": ParamDef((d, f), ("d_model", "d_ff"), dt, "normal", 0.02),
        "w_up": ParamDef((d, f), ("d_model", "d_ff"), dt, "normal", 0.02),
        "w_down": ParamDef((f, d), ("d_ff", "d_model"), dt, "normal",
                           0.02 / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.post_norms:
        defs["post_norm"] = rms_norm_defs(d, dt)
    return defs


def mlp_apply(p, x, constrain_ff=None):
    c = constrain_ff if constrain_ff is not None else (lambda t: t)
    g = c(jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype)))
    u = c(jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype)))
    h = c(jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Embedding / LM head / loss
# ---------------------------------------------------------------------------

def embed_defs(cfg) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    defs = {
        # ~N(0, 1/d): tied heads get O(1) logits; the sqrt(d) input scaling
        # for tied models restores unit-variance embeddings
        "table": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "d_model"),
                          dt, "normal", 1.0 / math.sqrt(cfg.d_model)),
        "final_norm": rms_norm_defs(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        defs["head"] = ParamDef((cfg.d_model, cfg.vocab_size), ("d_model", "vocab"), dt, "normal", 0.02)
    return defs


def embed_tokens(p, tokens, cfg):
    x = jnp.take(p["table"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.tie_embeddings:
        x = x * math.sqrt(cfg.d_model)  # gemma-style scaled tied embedding
    return x


def lm_head(p, x, cfg):
    w = p.get("head")
    if w is None:
        w = p["table"].T
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    return _softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)


def chunked_cross_entropy(p, x, targets, cfg, *, chunk: int = 256,
                          mask=None):
    """CE over huge vocabs without a (B, S, V) f32 buffer.

    Scans over sequence chunks; within a chunk the logits stay vocab-sharded
    (the head weight carries the 'vocab' logical dim) and the logsumexp /
    target-pick contract over vocab, so only (B, chunk) leaves each step.
    """
    B, S, D = x.shape
    chunk = min(chunk, S)
    n = -(-S // chunk)
    pad = n * chunk - S
    if mask is None:
        mask = jnp.ones((B, S), jnp.bool_)
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    xs = x.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    ts = targets.reshape(B, n, chunk).transpose(1, 0, 2)
    ms = mask.reshape(B, n, chunk).transpose(1, 0, 2)

    w = p.get("head")
    tied = w is None
    if tied:
        w = p["table"].T

    @jax.checkpoint  # recompute chunk logits in backward; carry is O(1)
    def step(carry, c):
        tot, cnt = carry
        xc, tc, mc = c
        logits = jnp.einsum("bsd,dv->bsv", xc, w.astype(xc.dtype)).astype(jnp.float32)
        if cfg.final_logit_softcap:
            logits = _softcap(logits, cfg.final_logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(tc, cfg.vocab_size, dtype=logits.dtype)
        picked = jnp.sum(logits * onehot, axis=-1)
        nll = (lse - picked) * mc.astype(jnp.float32)
        return (tot + nll.sum(), cnt + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)), (xs, ts, ms))
    return tot / jnp.maximum(cnt, 1.0)

"""Production mesh construction (function, never module-level state).

Single pod : (data=16, model=16)            = 256 chips (one v5e pod)
Multi-pod  : (pod=2, data=16, model=16)     = 512 chips

Importing this module never touches jax device state; ``make_production_mesh``
slices ``jax.devices()`` explicitly so a 512-virtual-device dry-run process
can also build the 256-device single-pod mesh.
"""
from __future__ import annotations

import math

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)}; the dry-run "
            "entrypoint must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import")
    import numpy as np
    dev = np.asarray(devices[:n]).reshape(shape)
    return compat.make_mesh(dev, axes)


def make_smoke_mesh(shape=(1, 1), axes=("data", "model")):
    """A 1x1 mesh over the single real CPU device (smoke tests)."""
    import numpy as np
    dev = np.asarray(jax.devices()[:1]).reshape(shape)
    return compat.make_mesh(dev, axes)

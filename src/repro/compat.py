"""JAX cross-version compatibility shims.

The repo targets the JAX that ships on the edge image (0.4.x today) while
staying runnable on newer releases. Three API seams moved between 0.4.x and
0.5+/0.6+, and every call site routes through here instead of branching
locally:

  * ``AxisType`` / ``Mesh(..., axis_types=...)`` — ``jax.sharding.AxisType``
    does not exist in 0.4.x and ``Mesh`` only grew the ``axis_types``
    keyword later. ``make_mesh`` builds a Mesh with explicit-Auto axis
    types when the installed JAX understands them and plain axes otherwise
    (0.4.x treats every axis as Auto already, so the semantics match).
  * ``AbstractMesh`` — 0.4.x takes one ``((name, size), ...)`` shape tuple;
    newer JAX takes ``(axis_sizes, axis_names)``. ``abstract_mesh`` accepts
    the new-style arguments and adapts.
  * ``jax.set_mesh`` — newer JAX's context setter. 0.4.x spells it
    ``jax.sharding.use_mesh`` (briefly) or just the Mesh's own context
    manager. ``set_mesh`` returns whichever works.

Donation quirk: some backend/version combinations warn ("Some donated
buffers were not usable") instead of donating. ``jit_donated`` applies
``donate_argnums`` and silences that warning so benchmark CSVs stay clean;
donation is an optimization, never a semantic requirement, in this repo.
"""
from __future__ import annotations

import contextlib
import re
import warnings

import jax


def _version_tuple() -> tuple:
    parts = []
    for p in jax.__version__.split(".")[:3]:
        m = re.match(r"\d+", p)
        parts.append(int(m.group(0)) if m else 0)
    return tuple(parts)


JAX_VERSION = _version_tuple()

try:  # JAX >= 0.5-ish
    from jax.sharding import AxisType  # type: ignore
except ImportError:  # 0.4.x: no explicit/auto axis-type distinction
    AxisType = None


def mesh_supports_axis_types() -> bool:
    return AxisType is not None


def make_mesh(devices, axis_names):
    """``jax.sharding.Mesh`` with Auto axis types when supported."""
    if AxisType is not None:
        return jax.sharding.Mesh(
            devices, axis_names,
            axis_types=(AxisType.Auto,) * len(axis_names))
    return jax.sharding.Mesh(devices, axis_names)


def abstract_mesh(axis_sizes, axis_names):
    """``AbstractMesh`` from (sizes, names) across both signatures."""
    axis_sizes = tuple(int(s) for s in axis_sizes)
    axis_names = tuple(axis_names)
    try:  # new signature: AbstractMesh(axis_sizes, axis_names)
        return jax.sharding.AbstractMesh(axis_sizes, axis_names)
    except TypeError:  # 0.4.x signature: AbstractMesh(((name, size), ...))
        return jax.sharding.AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    Newer JAX: ``jax.set_mesh``. 0.4.x: ``jax.sharding.use_mesh`` when
    present, else the concrete Mesh's own context manager (which is what
    pjit-era code used); AbstractMesh falls back to a no-op — shardings in
    this repo are always passed explicitly, the ambient mesh is only a
    convenience for ``jax.jit`` sharding propagation.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    if isinstance(mesh, jax.sharding.Mesh):
        return mesh  # Mesh is itself a context manager in 0.4.x
    return contextlib.nullcontext()


def shard_map(f, *, mesh, in_specs, out_specs, check_rep=False):
    """``jax.shard_map`` (new) / ``jax.experimental.shard_map`` (0.4.x).

    The 0.4.x spelling of the replication-check kwarg is ``check_rep``;
    newer JAX renamed it ``check_vma``. Callers here always want it off —
    the MoE/cache bodies do collective-free per-rank work.
    """
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check_rep)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_rep)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_rep)


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized to one flat dict.

    JAX 0.4.x returns a list with one properties-dict per partition (often
    length 1 post-SPMD); newer JAX returns the dict directly. Callers always
    want the single per-device dict.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def _dealias_donated(args, donate_argnums):
    """Copy duplicate buffers among donated arguments.

    XLA rejects donating the same underlying buffer twice, and zero-
    initialized pytrees (``init_state``) routinely alias their zero pages
    across leaves. Donation is an optimization, so the cheap fix is a copy
    of the duplicates, not an error surfaced to the caller.
    """
    import jax.numpy as jnp
    out = list(args)
    seen = set()
    for i in donate_argnums:
        if i >= len(out):
            continue
        leaves, treedef = jax.tree.flatten(out[i])
        fresh = []
        for x in leaves:
            if isinstance(x, jax.Array):
                try:
                    key = x.unsafe_buffer_pointer()
                except Exception:
                    key = id(x)
                if key in seen:
                    x = jnp.array(x, copy=True)
                else:
                    seen.add(key)
            fresh.append(x)
        out[i] = jax.tree.unflatten(treedef, fresh)
    return tuple(out)


def jit_donated(fn, donate_argnums=(), **jit_kwargs):
    """``jax.jit`` with ``donate_argnums``, absorbing donation quirks.

    Two backend/version quirks are handled here so call sites stay clean:
    duplicate-buffer donation (aliased zero pages in freshly initialized
    state pytrees) is de-aliased per call, and the "donated buffers were
    not usable" warning some backends emit instead of donating is
    silenced. When ``donate_argnums`` is empty this is exactly
    ``jax.jit(fn, **jit_kwargs)``.
    """
    if not donate_argnums:
        return jax.jit(fn, **jit_kwargs)
    donate_argnums = tuple(donate_argnums)
    jitted = jax.jit(fn, donate_argnums=donate_argnums, **jit_kwargs)

    def call(*args, **kwargs):
        args = _dealias_donated(args, donate_argnums)
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message=".*[Dd]onated buffers.*")
            return jitted(*args, **kwargs)

    # keep lower/compile reachable for dry-run tooling
    call.lower = jitted.lower
    call._jitted = jitted
    return call

"""Reward computation — Percepta's RL-specific contribution.

"Percepta is designed to ... computing reward functions directly from
real-world interactions at each edge device."

Rewards are declared as a list of :class:`RewardTerm` (weighted references
to feature/action indices with a shape function) compiled into one
vectorized evaluation over all environments per tick. The OPEVA energy
use-case rewards (grid-import cost, comfort band, export gain, action
smoothness) are expressible directly; ``custom`` takes any jnp-traceable fn.
"""
from __future__ import annotations

import logging
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

KINDS = ("linear", "abs_error", "quadratic_error", "band_penalty",
         "threshold_bonus", "action_smoothness", "custom")


@dataclass(frozen=True)
class RewardTerm:
    kind: str
    weight: float = 1.0
    feature: int = 0              # feature index the term reads
    action: Optional[int] = None  # action index (for action-dependent terms)
    target: float = 0.0           # setpoint / threshold
    band: float = 0.0             # tolerance band half-width
    fn: Optional[Callable] = None # custom: fn(features, actions, prev_actions)->(E,)

    def evaluate(self, features, actions, prev_actions):
        """Evaluate on (..., E, F)/(..., E, A) — trailing-axis indexing, so
        a K-leading stack of windows evaluates in one call, elementwise
        bit-identical to K per-window evaluations (the batched Predictor
        consume relies on this). Built-in terms index the last axis
        directly; ``custom`` fns keep their (E, F) contract and run
        per-window under ``lax.map`` over any leading axes.

        Sharding contract: every built-in kind is per-env row-wise, which
        is what lets the fused decision engine evaluate terms inside the
        env-sharded window scan (``mode="scan_fused_decide_sharded"``)
        with no collectives and bit-identical outputs. A ``custom`` fn
        must honor the same contract — no reductions across the env axis,
        and any contraction phrased so its rounding is independent of the
        number of env rows a device holds (see ``linear_policy``'s
        multiply+reduce dot) — to compose with the sharded modes. The
        contract is enforced statically: ``repro.analysis`` traces custom
        fns at spec construction (and again at true shapes when a sharded/
        fused system is built) and rejects env-axis contractions/reductions
        with the offending primitive and source line (see ROADMAP.md
        "Invariant catalog")."""
        f = features[..., self.feature]
        a = actions[..., self.action] if self.action is not None else 0.0
        if self.kind == "linear":
            return self.weight * f
        if self.kind == "abs_error":
            return -self.weight * jnp.abs(f - self.target)
        if self.kind == "quadratic_error":
            return -self.weight * jnp.square(f - self.target)
        if self.kind == "band_penalty":
            over = jnp.maximum(jnp.abs(f - self.target) - self.band, 0.0)
            return -self.weight * over
        if self.kind == "threshold_bonus":
            return self.weight * (f > self.target).astype(jnp.float32)
        if self.kind == "action_smoothness":
            pa = prev_actions[..., self.action]
            return -self.weight * jnp.square(actions[..., self.action] - pa)
        if self.kind == "custom":
            # per-window execution (lax.map = scan), never vmap: a custom
            # fn with an inner contraction would become a batched op under
            # vmap and could accumulate differently than K per-window
            # calls, breaking the batched-consume bit-identity guarantee
            def apply(f, a, p):
                if f.ndim == 2:
                    return self.fn(f, a, p)
                return jax.lax.map(lambda xs: apply(*xs), (f, a, p))
            return self.weight * apply(features, actions, prev_actions)
        raise ValueError(self.kind)


@dataclass(frozen=True)
class RewardSpec:
    """A reward program: weighted terms summed per env per tick.

    Custom terms are statically checked at construction against the
    per-env row-wise contract (no cross-env reductions, no env-axis
    contractions, no float32 absolute-time casts — the jaxpr checker in
    :mod:`repro.analysis`; rules in ROADMAP.md "Invariant catalog").
    ``unchecked=True`` skips the check (logged) for fns the tracer cannot
    probe at spec time; they are still checked at true shapes when a
    ``*_sharded``/fused ``PerceptaSystem`` is constructed.
    """
    terms: tuple
    unchecked: bool = False

    def __post_init__(self):
        if self.unchecked:
            logging.getLogger(__name__).info(
                "RewardSpec(unchecked=True): skipping the static contract "
                "check on %d term(s); custom fns will still be checked at "
                "system construction for sharded/fused modes",
                len(self.terms))
            return
        if any(t.kind == "custom" for t in self.terms):
            # lazy import: analysis depends on jax only, but keep reward's
            # import graph flat for everything that never builds a spec
            from repro.analysis import check_reward_terms
            check_reward_terms(self.terms)

    def compute(self, features, actions, prev_actions=None):
        """features (..., E, F), actions (..., E, A) ->
        (total (..., E), per_term (..., E, n_terms)).

        Leading batch axes (e.g. a K-window stack) are supported directly:
        every term is elementwise over the leading dims, so the stacked
        result is bit-identical to per-window calls.

        The total is NOT a ``per.sum(-1)``: the term stack is sealed
        behind ``lax.optimization_barrier`` and totalled by an explicit
        left-fold of adds. Without the barrier XLA rematerializes the
        total from the term EXPRESSIONS and contracts their
        multiply-adds into FMAs, and a reduce's association order is
        itself a codegen choice — both depend on what else fused into
        the kernel, so the same spec could total to different bits in
        different builds (dense vs elastic-masked was 1 ulp apart on
        XLA:CPU). Explicit adds over sealed term bits are order-fixed by
        HLO semantics in every build."""
        if prev_actions is None:
            prev_actions = jnp.zeros_like(actions)
        per = jnp.stack([t.evaluate(features, actions, prev_actions)
                         for t in self.terms], axis=-1)
        per = jax.lax.optimization_barrier(per)
        total = per[..., 0]
        for i in range(1, len(self.terms)):
            total = total + per[..., i]
        return total, per


def energy_reward_spec(price_idx: int, grid_idx: int, temp_idx: int,
                       comfort_target: float = 21.0, comfort_band: float = 1.5,
                       hvac_action: int = 0) -> RewardSpec:
    """The OPEVA building-energy reward: cost + comfort + smoothness."""
    return RewardSpec(terms=(
        RewardTerm("custom", weight=1.0, fn=lambda f, a, p:
                   -f[:, price_idx] * jnp.maximum(f[:, grid_idx], 0.0)),
        RewardTerm("band_penalty", weight=2.0, feature=temp_idx,
                   target=comfort_target, band=comfort_band),
        RewardTerm("action_smoothness", weight=0.1, action=hvac_action),
    ))


def validate_actions(actions, low, high):
    """The Predictor "validates" decisions before forwarding: clamp into the
    actuator envelope and flag violations. Returns (clamped, violated (E,))."""
    clamped = jnp.clip(actions, low, high)
    violated = jnp.any((actions < low) | (actions > high), axis=-1)
    return clamped, violated
